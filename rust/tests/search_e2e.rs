//! End-to-end search quality: WHAM vs the baselines and the paper's
//! qualitative claims, on real workload graphs (native backend for
//! speed; PJRT equivalence is covered by pjrt_vs_native.rs).

use wham::arch::presets;
use wham::baselines::{confuciux, spotlight};
use wham::cost::native::NativeCost;
use wham::graph::autodiff::Optimizer;
use wham::metrics::Metric;
use wham::search::engine::{evaluate_design, SearchOptions, WhamSearch};

#[test]
fn wham_matches_or_beats_all_baselines_on_every_workload() {
    let mut nc = NativeCost;
    for name in wham::models::single_acc_models() {
        let g = wham::models::training(name, Optimizer::Adam).unwrap();
        let batch = wham::models::info(name).unwrap().batch;
        let w = WhamSearch::new(&g, batch, SearchOptions::default()).run(&mut nc);
        let cx = confuciux::run(
            &g,
            batch,
            &mut nc,
            confuciux::ConfuciuxOpts { iterations: 120, ..Default::default() },
        );
        let sp = spotlight::run(
            &g,
            batch,
            &mut nc,
            spotlight::SpotlightOpts { iterations: 120, ..Default::default() },
        );
        let tpu = evaluate_design(&g, batch, &presets::tpuv2(), &mut nc);
        let t = w.best.eval.throughput;
        assert!(t >= cx.eval.throughput * 0.995, "{name}: wham {t} < confuciux+ {}", cx.eval.throughput);
        assert!(t >= sp.eval.throughput * 0.995, "{name}: wham {t} < spotlight+ {}", sp.eval.throughput);
        assert!(t >= tpu.throughput * 0.999, "{name}: wham {t} < tpuv2 {}", tpu.throughput);
    }
}

#[test]
fn wham_converges_in_far_fewer_evaluations() {
    let mut nc = NativeCost;
    let g = wham::models::training("bert-large", Optimizer::Adam).unwrap();
    let w = WhamSearch::new(&g, 8, SearchOptions::default()).run(&mut nc);
    // The paper's framing: baselines need 500 objective evaluations;
    // WHAM explores tens of dimension configs.
    assert!(w.dims_evaluated < 50, "dims evaluated: {}", w.dims_evaluated);
}

#[test]
fn perf_tdp_search_dominates_throughput_search_on_efficiency() {
    let mut nc = NativeCost;
    let g = wham::models::training("vgg16", Optimizer::Adam).unwrap();
    let tpu = evaluate_design(&g, 64, &presets::tpuv2(), &mut nc);
    let thpt = WhamSearch::new(&g, 64, SearchOptions::default()).run(&mut nc);
    let eff_opts = SearchOptions {
        metric: Metric::PerfPerTdp,
        min_throughput: tpu.throughput,
        ..Default::default()
    };
    let eff = WhamSearch::new(&g, 64, eff_opts).run(&mut nc);
    assert!(eff.best.eval.perf_per_tdp >= thpt.best.eval.perf_per_tdp * 0.999);
    assert!(eff.best.eval.throughput >= tpu.throughput * 0.99);
}

#[test]
fn fused_graphs_never_slower_than_unfused() {
    let mut nc = NativeCost;
    for name in ["vgg16", "resnet18"] {
        let fwd = wham::models::forward(name).unwrap();
        let (fused, n) = wham::graph::fusion::fuse(&fwd);
        assert!(n > 0, "{name} should fuse conv+relu pairs");
        let gu = wham::graph::autodiff::training_graph(&fwd, Optimizer::SgdMomentum);
        let gf = wham::graph::autodiff::training_graph(&fused, Optimizer::SgdMomentum);
        let eu = evaluate_design(&gu, 8, &presets::tpuv2(), &mut nc);
        let ef = evaluate_design(&gf, 8, &presets::tpuv2(), &mut nc);
        assert!(
            ef.seconds <= eu.seconds * 1.02,
            "{name}: fusion regressed latency {} -> {}",
            eu.seconds,
            ef.seconds
        );
    }
}

#[test]
fn top_k_is_sorted_and_feasible() {
    let mut nc = NativeCost;
    let g = wham::models::training("inception_v3", Optimizer::Adam).unwrap();
    let r = WhamSearch::new(&g, 64, SearchOptions::default()).run(&mut nc);
    let pts = r.top.points();
    assert!(!pts.is_empty());
    for w in pts.windows(2) {
        assert!(w[0].score >= w[1].score);
    }
    for p in pts {
        assert!(p.config.in_template());
        assert!(SearchOptions::default().constraints.allows(&p.config), "{}", p.config);
    }
}

#[test]
fn common_design_tradeoff_bounded() {
    // The common design may lose to per-model designs, but not
    // catastrophically (paper: individual adds only a few % over common).
    let mut nc = NativeCost;
    let names = ["bert-base", "bert-large", "gnmt4"];
    let graphs: Vec<_> = names
        .iter()
        .map(|n| {
            (
                n.to_string(),
                wham::models::training(n, Optimizer::Adam).unwrap(),
                wham::models::info(n).unwrap().batch,
            )
        })
        .collect();
    let ws: Vec<wham::search::common::Workload> = graphs
        .iter()
        .map(|(n, g, b)| wham::search::common::Workload {
            name: n.clone(),
            graph: g,
            batch: *b,
            min_throughput: 0.0,
            weight: 1.0,
        })
        .collect();
    let common = wham::search::common::search_common(&ws, SearchOptions::default(), &mut nc);
    for (n, g, b) in &graphs {
        let ind = WhamSearch::new(g, *b, SearchOptions::default()).run(&mut nc);
        let com = evaluate_design(g, *b, &common.best.0, &mut nc);
        let ratio = com.throughput / ind.best.eval.throughput;
        assert!(ratio > 0.5, "{n}: common design loses too much ({ratio:.2})");
        assert!(ratio <= 1.001, "{n}: common cannot beat individual ({ratio:.2})");
    }
}
