//! Property tests over random operator DAGs: scheduler feasibility,
//! critical-path bounds, MCR monotonicity, ILP optimality envelope
//! (hand-rolled harness in wham::util::prop — no proptest offline).

use wham::arch::Constraints;
use wham::cost::annotate::AnnotatedGraph;
use wham::cost::native::NativeCost;
use wham::cost::Dims;
use wham::graph::{GraphBuilder, OpKind, OperatorGraph};
use wham::search::ilp::ilp_search;
use wham::search::mcr::mcr;
use wham::sched::{asap_alap, greedy_schedule, CoreCount};
use wham::util::prop::{forall, Gen};

const D: Dims = Dims { tc_x: 32, tc_y: 32, vc_w: 32 };

/// Random DAG: each node picks preds among earlier nodes; mixed op kinds.
fn random_graph(g: &mut Gen) -> OperatorGraph {
    let n = 2 + g.len(18);
    let mut b = GraphBuilder::new();
    for i in 0..n {
        let preds: Vec<usize> = (0..i).filter(|_| g.rng.chance(0.3)).collect();
        let dim = 8 << g.rng.below(4); // 8..64
        match g.rng.below(4) {
            0 => b.gemm(format!("g{i}"), dim, dim, dim, &preds),
            1 => b.eltwise(format!("e{i}"), dim * dim, 1 + g.rng.below(4) as u64, &preds),
            2 => b.fwd(
                format!("f{i}"),
                OpKind::FusedGemmAct { m: dim, n: dim, k: dim },
                0,
                &preds,
            ),
            _ => b.softmax(format!("s{i}"), dim, dim, &preds),
        };
    }
    b.finish()
}

#[test]
fn schedule_respects_dependencies_and_capacity() {
    forall(11, 150, random_graph, |g| {
        let ann = AnnotatedGraph::new(g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        for (tc, vc) in [(1, 1), (2, 1), (1, 2), (3, 2)] {
            let s = greedy_schedule(&ann, &cp, CoreCount { tc, vc });
            // Dependencies.
            for v in 0..g.len() {
                for &p in g.preds(v) {
                    let p = p as usize;
                    if s.start[v] < s.finish[p] {
                        return Err(format!("dep violated: {v} starts before pred {p} ends"));
                    }
                }
            }
            // Capacity per core type (fused takes one of each).
            let mut events: Vec<(u64, i64, i64)> = Vec::new();
            for v in 0..g.len() {
                let (dt, dv) = match ann.core[v] {
                    wham::graph::CoreType::Tensor => (1, 0),
                    wham::graph::CoreType::Vector => (0, 1),
                    wham::graph::CoreType::Fused => (1, 1),
                };
                events.push((s.start[v], dt, dv));
                events.push((s.finish[v], -dt, -dv));
            }
            events.sort();
            let (mut ct, mut cv) = (0i64, 0i64);
            for (_, dt, dv) in events {
                ct += dt;
                cv += dv;
                if ct > tc as i64 || cv > vc as i64 {
                    return Err(format!("capacity exceeded at tc={tc},vc={vc}"));
                }
            }
            // Makespan bounds.
            if s.makespan < cp.best_latency {
                return Err("makespan below the critical path".into());
            }
            if s.makespan > ann.serial_cycles() {
                return Err("makespan exceeds serial execution".into());
            }
        }
        Ok(())
    });
}

#[test]
fn asap_alap_invariants() {
    forall(22, 200, random_graph, |g| {
        let ann = AnnotatedGraph::new(g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        for v in 0..g.len() {
            if cp.alap[v] < cp.asap[v] {
                return Err(format!("alap < asap at node {v}"));
            }
            if cp.asap[v] + ann.cycles[v] > cp.best_latency {
                return Err(format!("node {v} ASAP-finishes past best latency"));
            }
            for &p in g.preds(v) {
                let p = p as usize;
                if cp.asap[v] < cp.asap[p] + ann.cycles[p] {
                    return Err(format!("ASAP precedence violated {p}->{v}"));
                }
            }
        }
        if !cp.critical_ops().is_empty() {
            Ok(())
        } else {
            Err("graph must have at least one critical op".into())
        }
    });
}

#[test]
fn mcr_never_worse_than_single_core() {
    forall(33, 100, random_graph, |g| {
        let ann = AnnotatedGraph::new(g, D, &mut NativeCost);
        let out = mcr(&ann, &Constraints::default());
        let cp = &out.critical;
        let single = greedy_schedule(&ann, cp, CoreCount { tc: 1, vc: 1 });
        if out.schedule.makespan > single.makespan {
            return Err(format!(
                "MCR made things worse: {} > {}",
                out.schedule.makespan, single.makespan
            ));
        }
        // Bound: never exceeds parallelism limits.
        let max_tc = cp.max_parallelism(&ann, wham::graph::CoreType::Tensor).max(1);
        let max_vc = cp.max_parallelism(&ann, wham::graph::CoreType::Vector).max(1);
        if out.cores.tc > max_tc || out.cores.vc > max_vc {
            return Err(format!("cores {:?} exceed parallelism bound", out.cores));
        }
        // Trajectory: makespans strictly improve along accepted additions.
        for w in out.trajectory.windows(2) {
            if w[1].1 >= w[0].1 {
                return Err("trajectory makespan not strictly improving".into());
            }
        }
        Ok(())
    });
}

#[test]
fn ilp_at_least_as_good_as_greedy_everywhere() {
    forall(44, 40, |g| {
        // Keep graphs small so the exact solver stays exact.
        let mut g2 = Gen { rng: g.rng, size: g.size.min(8) };
        random_graph(&mut g2)
    }, |g| {
        let ann = AnnotatedGraph::new(g, D, &mut NativeCost);
        let out = ilp_search(&ann, &Constraints::default(), 300_000);
        let cp = asap_alap(&ann);
        if out.makespan < cp.best_latency {
            return Err("ILP beat the critical path (impossible)".into());
        }
        let greedy = greedy_schedule(&ann, &cp, out.cores);
        if out.optimal && out.makespan > greedy.makespan {
            return Err(format!(
                "optimal ILP worse than greedy at same cores: {} > {}",
                out.makespan, greedy.makespan
            ));
        }
        Ok(())
    });
}

#[test]
fn fusion_preserves_dag_and_reduces_ops() {
    forall(55, 150, random_graph, |g| {
        let (fused, n) = wham::graph::fusion::fuse(g);
        wham::graph::validate::validate(&fused).map_err(|e| e.to_string())?;
        if fused.len() + n != g.len() {
            return Err("fusion op accounting mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn autodiff_mirror_structure() {
    forall(66, 100, random_graph, |fwd| {
        let g = wham::graph::autodiff::training_graph(
            fwd,
            wham::graph::autodiff::Optimizer::Adam,
        );
        wham::graph::validate::validate(&g).map_err(|e| e.to_string())?;
        let [f, b, u, l] = g.pass_counts();
        if f != fwd.len() {
            return Err("forward ops must be preserved".into());
        }
        if b < f {
            return Err("every forward op needs at least one backward peer".into());
        }
        if l != 1 {
            return Err("exactly one loss node".into());
        }
        let params = fwd.ops.iter().filter(|o| o.param_elems > 0).count();
        if u != params {
            return Err(format!("updates {u} != parameterized ops {params}"));
        }
        Ok(())
    });
}
