//! Parity suite for the hot-path overhaul: the interned cost annotation,
//! the galloping MCR growth, and the parallel sibling evaluation are all
//! *outcome-preserving* optimizations. These tests pin the contract —
//! identical per-op costs, identical `best.config`, identical top-k set,
//! identical workload fingerprints — between the fast (default) paths
//! and the legacy paths kept behind `SearchOptions` knobs, on random
//! specs and on Table-4 workloads, while the fast paths pay no more (and
//! on real workloads strictly fewer) scheduler evaluations.

use wham::api::resolve_workload;
use wham::arch::Constraints;
use wham::coordinator::{make_backend, BackendChoice};
use wham::cost::annotate::AnnotatedGraph;
use wham::cost::native::NativeCost;
use wham::cost::Dims;
use wham::graph::fingerprint;
use wham::search::engine::{SearchOptions, WhamSearch};
use wham::search::mcr::{mcr_with, mcr_with_scratch, GrowthMode, McrScratch};
use wham::util::prop::forall;
use wham::workload::testgen::random_spec_json;
use wham::workload::{lower, parse_spec};

/// The pre-overhaul configuration: per-op backend rows, one reschedule
/// per core addition, and schedule-from-scratch MCR probes.
fn legacy_opts() -> SearchOptions {
    SearchOptions {
        mcr_one_at_a_time: true,
        naive_annotation: true,
        full_reschedule: true,
        ..Default::default()
    }
}

/// A power-of-two dims ladder value in [4, 256].
fn pick_dim(g: &mut wham::util::prop::Gen) -> u64 {
    1u64 << (2 + g.rng.below(7))
}

#[test]
fn interned_annotation_equals_naive_across_random_specs_and_dims() {
    forall(
        0x1A7E_12BE,
        30,
        |g| {
            let text = random_spec_json(g);
            let d = Dims { tc_x: pick_dim(g), tc_y: pick_dim(g), vc_w: pick_dim(g) };
            (text, d)
        },
        |(text, d)| {
            let spec = parse_spec(text).map_err(|e| format!("parse: {e}"))?;
            let graph = lower::training(&spec).map_err(|e| format!("lower: {e}"))?;
            let fast = AnnotatedGraph::new(&graph, *d, &mut NativeCost);
            let naive = AnnotatedGraph::new_naive(&graph, *d, &mut NativeCost);
            if fast.costs != naive.costs {
                return Err("interned costs differ from naive per-op costs".into());
            }
            if fast.cycles != naive.cycles {
                return Err("interned cycles differ".into());
            }
            if (fast.total_energy_pj() - naive.total_energy_pj()).abs() > 0.0 {
                return Err("interned energy differs".into());
            }
            // The class table really is smaller or equal, never larger.
            if graph.cost_classes().len() > graph.len() {
                return Err("more classes than ops".into());
            }
            Ok(())
        },
    );
}

#[test]
fn search_best_config_identical_with_and_without_interning_across_random_specs() {
    // Isolates the interning knob: the class table feeds the backend
    // bit-identical rows, so the whole search — pruner walk, MCR, best
    // design — must be exactly reproduced on arbitrary graphs.
    forall(0x5EA2_C4B1, 10, random_spec_json, |text| {
        let spec = parse_spec(text).map_err(|e| format!("parse: {e}"))?;
        let graph = lower::training(&spec).map_err(|e| format!("lower: {e}"))?;
        let interned = WhamSearch::new(&graph, spec.batch, SearchOptions::default())
            .run(&mut NativeCost);
        let naive_opts = SearchOptions { naive_annotation: true, ..Default::default() };
        let naive = WhamSearch::new(&graph, spec.batch, naive_opts).run(&mut NativeCost);
        if interned.best.config != naive.best.config {
            return Err(format!(
                "best diverged: interned {} vs naive {}",
                interned.best.config.display(),
                naive.best.config.display()
            ));
        }
        if interned.best.eval.cycles != naive.best.eval.cycles {
            return Err("best makespan diverged".into());
        }
        if interned.scheduler_evals != naive.scheduler_evals {
            return Err(format!(
                "eval counts diverged: {} vs {}",
                interned.scheduler_evals, naive.scheduler_evals
            ));
        }
        Ok(())
    });
}

#[test]
fn interned_annotation_matches_naive_on_pjrt_backend_when_available() {
    // The batched artifact backend must scatter identically too; skipped
    // (like `wham selftest`) when no artifacts are installed.
    let Ok(mut pjrt) = make_backend(BackendChoice::Pjrt) else {
        return;
    };
    let (graph, _) = resolve_workload("bert-base").unwrap();
    let d = Dims { tc_x: 128, tc_y: 128, vc_w: 128 };
    let fast = AnnotatedGraph::new(&graph, d, pjrt.as_mut());
    let naive = AnnotatedGraph::new_naive(&graph, d, pjrt.as_mut());
    assert_eq!(fast.cycles, naive.cycles);
    assert_eq!(fast.costs, naive.costs);
}

#[test]
fn table4_workloads_pin_fast_vs_legacy_best_topk_and_fingerprint() {
    // Acceptance criterion: `best.config`, the top-k set, and the
    // workload fingerprint are identical between the fast paths and the
    // legacy paths on Table-4 workloads.
    for name in ["bert-base", "vgg16"] {
        let (graph, batch) = resolve_workload(name).unwrap();
        let (graph2, _) = resolve_workload(name).unwrap();
        assert_eq!(
            fingerprint(&graph),
            fingerprint(&graph2),
            "{name}: fingerprint must be stable across resolutions"
        );
        let fast = WhamSearch::new(&graph, batch, SearchOptions::default()).run(&mut NativeCost);
        let slow = WhamSearch::new(&graph, batch, legacy_opts()).run(&mut NativeCost);
        assert_eq!(
            fast.best.config, slow.best.config,
            "{name}: fast and legacy paths must find the same best design"
        );
        assert_eq!(fast.best.eval.cycles, slow.best.eval.cycles, "{name}: best makespan");
        let fast_top: Vec<_> = fast.top.points().iter().map(|p| p.config).collect();
        let slow_top: Vec<_> = slow.top.points().iter().map(|p| p.config).collect();
        assert_eq!(fast_top, slow_top, "{name}: top-k set must be identical");
        assert_eq!(fast.dims_evaluated, slow.dims_evaluated, "{name}: same pruner walk");
        assert!(
            fast.scheduler_evals <= slow.scheduler_evals,
            "{name}: fast {} vs legacy {} evals",
            fast.scheduler_evals,
            slow.scheduler_evals
        );
    }
}

#[test]
fn incremental_rescheduling_matches_full_oracle_on_random_specs() {
    // The cone-rescheduling contract on arbitrary graphs: checkpointed
    // resume + bounded-probe aborts on the incremental engine must
    // reproduce the schedule-from-scratch oracle *bit for bit* — same
    // cores, same per-op start/finish, same trajectory, same eval count —
    // under both growth modes, while sharing one scratch across runs (the
    // engine's usage pattern, so stale checkpoints/cones would be caught).
    forall(
        0xC0DE_5EED,
        12,
        |g| {
            let text = random_spec_json(g);
            let d = Dims { tc_x: pick_dim(g), tc_y: pick_dim(g), vc_w: pick_dim(g) };
            (text, d)
        },
        |(text, d)| {
            let spec = parse_spec(text).map_err(|e| format!("parse: {e}"))?;
            let graph = lower::training(&spec).map_err(|e| format!("lower: {e}"))?;
            let ann = AnnotatedGraph::new(&graph, *d, &mut NativeCost);
            let mut scratch = McrScratch::new();
            for mode in [GrowthMode::Gallop, GrowthMode::OneAtATime] {
                let fast =
                    mcr_with_scratch(&ann, &Constraints::default(), mode, &mut scratch, false);
                let full =
                    mcr_with_scratch(&ann, &Constraints::default(), mode, &mut scratch, true);
                if fast.cores != full.cores {
                    return Err(format!(
                        "{mode:?}: cores diverged: {:?} vs {:?}",
                        fast.cores, full.cores
                    ));
                }
                if fast.schedule.makespan != full.schedule.makespan {
                    return Err(format!(
                        "{mode:?}: makespan diverged: {} vs {}",
                        fast.schedule.makespan, full.schedule.makespan
                    ));
                }
                if fast.schedule.start != full.schedule.start
                    || fast.schedule.finish != full.schedule.finish
                    || fast.schedule.ready_at != full.schedule.ready_at
                {
                    return Err(format!("{mode:?}: per-op schedule diverged"));
                }
                if fast.evals != full.evals {
                    return Err(format!(
                        "{mode:?}: eval counts diverged: {} vs {}",
                        fast.evals, full.evals
                    ));
                }
                if fast.trajectory != full.trajectory {
                    return Err(format!("{mode:?}: growth trajectory diverged"));
                }
                if fast.hit_bound != full.hit_bound || fast.last_conflict != full.last_conflict {
                    return Err(format!("{mode:?}: outcome flags diverged"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn incremental_engine_pins_search_outcomes_on_table4_graphs() {
    // Isolates the `full_reschedule` knob at the engine level (the
    // combined-legacy pin above covers it jointly with the other knobs):
    // the whole search — best design, top-k, pruner walk, eval counts —
    // is bit-identical with the oracle probes.
    for name in ["bert-base", "vgg16"] {
        let (graph, batch) = resolve_workload(name).unwrap();
        let fast = WhamSearch::new(&graph, batch, SearchOptions::default()).run(&mut NativeCost);
        let oracle_opts = SearchOptions { full_reschedule: true, ..Default::default() };
        let oracle = WhamSearch::new(&graph, batch, oracle_opts).run(&mut NativeCost);
        assert_eq!(fast.best.config, oracle.best.config, "{name}: best design");
        assert_eq!(fast.best.eval.cycles, oracle.best.eval.cycles, "{name}: best makespan");
        let fast_top: Vec<_> = fast.top.points().iter().map(|p| p.config).collect();
        let oracle_top: Vec<_> = oracle.top.points().iter().map(|p| p.config).collect();
        assert_eq!(fast_top, oracle_top, "{name}: top-k set");
        assert_eq!(fast.dims_evaluated, oracle.dims_evaluated, "{name}: pruner walk");
        assert_eq!(
            fast.scheduler_evals, oracle.scheduler_evals,
            "{name}: probe accounting must be engine-independent"
        );
    }
}

#[test]
fn gallop_matches_one_at_a_time_on_table4_graphs() {
    // The MCR-level pin at a fixed dims (engine-level pins above cover
    // the full pruner walk).
    for name in ["bert-base", "gnmt4"] {
        let (graph, _) = resolve_workload(name).unwrap();
        let ann = AnnotatedGraph::new(&graph, Dims { tc_x: 128, tc_y: 128, vc_w: 128 }, &mut NativeCost);
        let fast = mcr_with(&ann, &Constraints::default(), GrowthMode::Gallop);
        let slow = mcr_with(&ann, &Constraints::default(), GrowthMode::OneAtATime);
        assert_eq!(fast.cores, slow.cores, "{name}: MCR endpoint");
        assert_eq!(fast.schedule.makespan, slow.schedule.makespan, "{name}: MCR makespan");
        assert!(
            fast.evals <= slow.evals,
            "{name}: gallop evals {} vs one-at-a-time {}",
            fast.evals,
            slow.evals
        );
    }
}
