//! Cluster-subsystem acceptance tests: event-simulator parity against
//! the closed-form pipeline formulas on the cases they cover, and the
//! strategy sweep's ranked-report guarantees on Table-4 models.

use wham::api::{ClusterRequest, Session};
use wham::arch::presets;
use wham::cluster::{simulate_events, Placement, SimSchedule, Topology};
use wham::coordinator::BackendChoice;
use wham::cost::native::NativeCost;
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::pipeline::{simulate_with_times, stage_times, StageTimes};
use wham::distributed::Scheme;
use wham::graph::autodiff::Optimizer;

fn mini_part(stages: u64) -> wham::distributed::partition::PartitionedModel {
    let mut cfg = wham::models::transformer::gpt2_xl();
    cfg.layers = 8;
    partition_transformer("mini", &cfg, stages, 1, Optimizer::SgdMomentum)
}

/// Acceptance: the event-driven simulator agrees with the closed-form
/// `pipeline::simulate` within 1% on homogeneous GPipe and 1F1B cases.
#[test]
fn event_sim_matches_closed_form_on_homogeneous_schedules() {
    let part = mini_part(4);
    let net = Network::default();
    let topo = Topology::flat(&net, 4);
    let placement = Placement::linear(&topo, 4, 1).unwrap();
    let cfgs = vec![presets::tpuv2(); 4];
    // Homogeneous stage times: what the closed 1F1B bound is defined for.
    let uniform = vec![StageTimes { fwd_s: 8e-3, bwd_s: 16e-3, energy_j: 0.0 }; 4];
    for (scheme, schedule) in [
        (Scheme::GPipe, SimSchedule::GPipe),
        (Scheme::PipeDream1F1B, SimSchedule::OneF1B),
    ] {
        let closed = simulate_with_times(&part, &cfgs, &uniform, scheme, &net);
        let sim = simulate_events(&part, &uniform, schedule, &topo, &placement).unwrap();
        let rel = (sim.iter_seconds - closed.iter_seconds).abs() / closed.iter_seconds;
        assert!(
            rel < 0.01,
            "{schedule:?}: event {} vs closed {} (rel {rel:.4})",
            sim.iter_seconds,
            closed.iter_seconds
        );
    }
}

/// GPipe parity is exact even with heterogeneous real stage times —
/// the event timeline reproduces the wavefront recurrence.
#[test]
fn event_sim_gpipe_parity_with_real_stage_times() {
    let part = mini_part(4);
    let net = Network::default();
    let cfgs = vec![presets::tpuv2(); 4];
    let times: Vec<StageTimes> = part
        .stages
        .iter()
        .map(|s| stage_times(s, &presets::tpuv2(), part.tmp, &net, &mut NativeCost))
        .collect();
    let closed = simulate_with_times(&part, &cfgs, &times, Scheme::GPipe, &net);
    let topo = Topology::flat(&net, 4);
    let placement = Placement::linear(&topo, 4, 1).unwrap();
    let sim = simulate_events(&part, &times, SimSchedule::GPipe, &topo, &placement).unwrap();
    let rel = (sim.iter_seconds - closed.iter_seconds).abs() / closed.iter_seconds;
    assert!(rel < 1e-6, "event {} vs closed {}", sim.iter_seconds, closed.iter_seconds);
}

/// Acceptance: the sweep returns a ranked report whose top strategy's
/// simulated throughput is at least the fixed-(pp, tp) baseline's, on
/// every Table-4 model it runs on.
#[test]
fn sweep_top_strategy_beats_fixed_baseline_on_table4_models() {
    for model in ["bert-base"] {
        let mut session = Session::new(BackendChoice::Native).unwrap();
        let req = ClusterRequest::new(model)
            .devices(2)
            .schedules(["gpipe", "1f1b"])
            .mine_top(0);
        let reply = session.cluster(&req).unwrap();
        assert!(
            reply.baseline.fits_hbm,
            "{model}: the Table-4 baseline placement must fit HBM"
        );
        assert!(
            reply.ranked[0].throughput >= reply.baseline.throughput,
            "{model}: top {} < baseline {}",
            reply.ranked[0].throughput,
            reply.baseline.throughput
        );
        for w in reply.ranked.windows(2) {
            assert!(w[0].throughput >= w[1].throughput, "{model}: report must be ranked");
        }
        assert_eq!(reply.baseline.tp, 1, "{model}: baseline is the fixed-(pp, tp=1) strategy");
        assert!(reply.candidates as usize == reply.ranked.len());
    }
}

/// Interleaved-1F1B on a hierarchical topology end to end: virtual
/// stages round-robin over devices, transfers routed over the islands.
#[test]
fn interleaved_on_hierarchical_topology_runs() {
    let part = mini_part(8); // 8 virtual stages on 4 devices
    let net = Network::default();
    let times: Vec<StageTimes> = part
        .stages
        .iter()
        .map(|s| stage_times(s, &presets::tpuv2(), part.tmp, &net, &mut NativeCost))
        .collect();
    let topo = Topology::preset("nvlink-island", 4).unwrap();
    let placement = Placement::linear(&topo, 4, 1).unwrap();
    let sim = simulate_events(
        &part,
        &times,
        SimSchedule::Interleaved1F1B { devices: 4 },
        &topo,
        &placement,
    )
    .unwrap();
    assert!(sim.iter_seconds > 0.0 && sim.iter_seconds.is_finite());
    assert!(sim.events > 0);
    assert!(sim.comm_seconds > 0.0);
    // Every virtual stage stashed at least one microbatch.
    assert!(sim.per_stage_peak_stash.iter().all(|&p| p >= 1));
}
