//! Integration tests of `wham::workload` — the declarative spec
//! subsystem.
//!
//! The load-bearing guarantee: the spec language is expressive enough to
//! re-express the builtin zoo *exactly*. The three shipped specs (one
//! vision, one GNMT-class, one transformer LLM) must produce forward and
//! training graphs whose structural fingerprints are identical to the
//! Rust constructors' — same ops, shapes, edges, parameter counts — so a
//! design database mined against a builtin stays valid for the spec form
//! and vice versa. On top of that: serialize/parse round-trip goldens, a
//! shape-inference property test (every generated valid spec lowers to a
//! `validate()`-clean graph, deterministically), and the end-to-end
//! `--workload-dir` path acceptance criterion.

use wham::api::{resolve_workload, GlobalRequest, SearchRequest, Session};
use wham::cost::native::NativeCost;
use wham::graph::autodiff::Optimizer;
use wham::graph::fingerprint;
use wham::util::prop::forall;
use wham::workload::{self, lower, parse_spec, Source, BUILTIN_SPECS};

fn builtin_text(file: &str) -> &'static str {
    BUILTIN_SPECS
        .iter()
        .find(|(f, _)| *f == file)
        .unwrap_or_else(|| panic!("{file} not shipped"))
        .1
}

#[test]
fn shipped_specs_fingerprint_identical_to_rust_constructors() {
    for (file, name) in
        [("vgg16.json", "vgg16"), ("gnmt4.json", "gnmt4"), ("bert-base.json", "bert-base")]
    {
        let spec = parse_spec(builtin_text(file)).unwrap_or_else(|e| panic!("{file}: {e}"));
        assert_eq!(spec.name, name);
        assert_eq!(spec.batch, wham::models::info(name).unwrap().batch, "{name} batch");

        let spec_fwd = lower::lower(&spec).unwrap_or_else(|e| panic!("{file}: {e}"));
        let rust_fwd = wham::models::forward(name).unwrap();
        assert_eq!(spec_fwd.len(), rust_fwd.len(), "{name}: forward op count");
        assert_eq!(spec_fwd.num_edges(), rust_fwd.num_edges(), "{name}: forward edge count");
        assert_eq!(spec_fwd.param_elems(), rust_fwd.param_elems(), "{name}: parameter count");
        assert_eq!(
            fingerprint(&spec_fwd),
            fingerprint(&rust_fwd),
            "{name}: forward graphs must be structurally identical"
        );

        let spec_training = lower::training(&spec).unwrap();
        let rust_training = wham::models::training(name, Optimizer::Adam).unwrap();
        assert_eq!(
            fingerprint(&spec_training),
            fingerprint(&rust_training),
            "{name}: training graphs must be structurally identical"
        );
    }
}

#[test]
fn spec_serialization_round_trips_golden() {
    for (file, text) in BUILTIN_SPECS {
        let spec = parse_spec(text).unwrap_or_else(|e| panic!("{file}: {e}"));
        let emitted = spec.to_json();
        let reparsed = parse_spec(&emitted)
            .unwrap_or_else(|e| panic!("{file}: canonical form does not reparse: {e}"));
        assert_eq!(reparsed, spec, "{file}: parse(to_json(spec)) must reproduce the spec");
        assert_eq!(
            reparsed.to_json(),
            emitted,
            "{file}: second serialization must be byte-identical"
        );
        // And the canonical form lowers to the same graph.
        assert_eq!(
            fingerprint(&lower::training(&reparsed).unwrap()),
            fingerprint(&lower::training(&spec).unwrap()),
            "{file}: round-trip must preserve the lowered graph"
        );
    }
}

// The random-but-valid spec generator now lives in the library
// (`wham::workload::testgen`) so the hot-path parity suite draws the
// same distribution.
use wham::workload::testgen::random_spec_json;

#[test]
fn random_valid_specs_always_lower_to_clean_graphs() {
    forall(
        0x5EED_0A11,
        40,
        random_spec_json,
        |text| {
            let spec = parse_spec(text).map_err(|e| format!("parse: {e}"))?;
            let fwd = lower::lower(&spec).map_err(|e| format!("lower: {e}"))?;
            wham::graph::validate::validate(&fwd).map_err(|e| format!("validate fwd: {e}"))?;
            let t = lower::training(&spec).map_err(|e| format!("training: {e}"))?;
            wham::graph::validate::validate(&t).map_err(|e| format!("validate training: {e}"))?;
            // Lowering is deterministic: same spec, same fingerprint.
            let t2 = lower::training(&spec).map_err(|e| format!("relower: {e}"))?;
            if fingerprint(&t) != fingerprint(&t2) {
                return Err("lowering is nondeterministic".to_string());
            }
            // Serialization round-trip preserves the graph.
            let spec2 = parse_spec(&spec.to_json()).map_err(|e| format!("reparse: {e}"))?;
            if fingerprint(&lower::training(&spec2).map_err(|e| e.to_string())?)
                != fingerprint(&t)
            {
                return Err("round-trip changed the lowered graph".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn workload_dir_spec_mines_end_to_end_without_recompiling() {
    // Acceptance criterion: a JSON file dropped in a workload dir is
    // mineable by name through the same path `wham search` uses.
    let dir = std::env::temp_dir().join(format!("wham-workload-dir-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("dir-tiny-mlp.json"),
        r#"{
            "name": "dir-tiny-mlp", "task": "test", "batch": 2,
            "params": {"h": 8},
            "graph": [
                {"op": "embed", "elems": "8*h", "params": "4*h"},
                {"op": "linear", "m": 8, "n": "h", "k": "h"},
                {"op": "activation", "elems": "8*h"}
            ]
        }"#,
    )
    .unwrap();
    // Non-spec files are ignored.
    std::fs::write(dir.join("README.txt"), "not a spec").unwrap();

    let names = workload::add_dir(&dir).unwrap();
    assert_eq!(names, vec!["dir-tiny-mlp".to_string()]);

    let (graph, batch) = resolve_workload("dir-tiny-mlp").unwrap();
    assert_eq!(batch, 2);
    assert!(graph.len() >= 3);

    let mut session = Session::with_backend(Box::new(NativeCost));
    let reply = session.search(&SearchRequest::new("dir-tiny-mlp")).unwrap();
    assert_eq!(reply.model, "dir-tiny-mlp");
    assert_eq!(reply.fingerprint, fingerprint(&graph));
    assert!(reply.best.config.in_template());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn invalid_dir_specs_fail_with_file_and_path() {
    let dir = std::env::temp_dir().join(format!("wham-workload-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("broken.json"),
        r#"{"name":"broken","batch":1,"graph":[{"op":"linear","name":"z","m":0,"n":4,"k":4}]}"#,
    )
    .unwrap();
    let e = workload::add_dir(&dir).unwrap_err();
    assert!(e.path.contains("broken.json"), "{e}");
    assert!(e.path.contains("graph/z"), "{e}");
    assert!(resolve_workload("broken").is_err(), "invalid specs must not register");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn llama_example_spec_lints_registers_and_partitions() {
    // The shipped non-Table-4 example: a llama-style decoder with a
    // `transformer` section, so it is eligible for the distributed paths.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/workloads/llama-decoder.json");
    let text = std::fs::read_to_string(path).unwrap();
    let report = workload::lint(&text).unwrap();
    assert_eq!(report.name, "llama-decoder");
    assert_eq!(report.batch, 8);
    // 1 embed + 8 layers x 18 ops (16 items, attention lowers to 3) +
    // final norm + head.
    assert_eq!(report.forward_ops, 1 + 8 * 18 + 2);
    assert!(report.training_ops > report.forward_ops);

    workload::add_spec_text(&text, Source::User).unwrap();
    let cfg = workload::transformer_cfg("llama-decoder").expect("transformer section");
    assert_eq!((cfg.layers, cfg.hidden, cfg.tmp), (8, 1024, 1));

    // `wham global`-shaped validation partitions it like a builtin LLM.
    let plan = GlobalRequest::new().models(["llama-decoder"]).depth(2).validate().unwrap();
    assert_eq!(plan.parts.len(), 1);
    assert_eq!(plan.parts[0].stages.len(), 2);
    assert!(plan.parts[0].stages.iter().all(|s| s.graph.len() > 10));

    // A spec without the section still 404s on /global.
    workload::add_spec_text(
        r#"{"name":"no-tf-section","batch":1,"graph":[{"op":"linear","m":4,"n":4,"k":4}]}"#,
        Source::User,
    )
    .unwrap();
    let e = GlobalRequest::new().models(["no-tf-section"]).validate().unwrap_err();
    assert_eq!(e.http_status(), 404);
}

#[test]
fn uploaded_specs_warm_start_the_design_db_like_builtins() {
    use std::sync::Arc;
    // Acceptance criterion: custom specs cache under their fingerprint
    // exactly like builtins — a second session over the same DB answers
    // without scheduler work.
    workload::add_spec_text(
        r#"{"name":"db-warm-spec","batch":2,"graph":[
            {"op":"embed","elems":64,"params":32},
            {"op":"linear","m":8,"n":8,"k":8},
            {"op":"activation","elems":64}
        ]}"#,
        Source::Uploaded,
    )
    .unwrap();
    let db = Arc::new(wham::service::cache::DesignDb::in_memory());
    let mut a = Session::with_backend(Box::new(NativeCost)).with_db(Arc::clone(&db));
    let cold = a.search(&SearchRequest::new("db-warm-spec")).unwrap();
    assert!(cold.scheduler_evals > 0);
    let mut b = Session::with_backend(Box::new(NativeCost)).with_db(db);
    let warm = b.search(&SearchRequest::new("db-warm-spec")).unwrap();
    assert_eq!(warm.scheduler_evals, 0, "spec workloads must warm-start from the DB");
    assert_eq!(warm.best.config, cold.best.config);
}
