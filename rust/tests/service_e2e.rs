//! End-to-end tests of `wham serve`: boot the real server on ephemeral
//! ports, drive it over real `TcpStream`s, and verify the service
//! guarantees — repeat searches are answered from the design database,
//! identical concurrent requests coalesce to one computation, a restart
//! with the same `--db` file answers previously-mined searches without
//! re-running the scheduler, and the async job tier admits, streams,
//! cancels, rate-limits, and crash-resumes jobs.

use std::net::TcpListener;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use wham::api::JobKind;
use wham::coordinator::BackendChoice;
use wham::jobs::store::JobStore;
use wham::jobs::JobsOptions;
use wham::service::http::{request, request_full, request_stream};
use wham::service::{start, ServeOptions, ServerHandle};
use wham::telemetry::tsdb::TsdbOptions;
use wham::util::json::{dump, parse, JsonValue};

fn boot_opts(opts: ServeOptions) -> ServerHandle {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    start(listener, opts).unwrap()
}

fn boot(db_path: Option<PathBuf>, workers: usize) -> ServerHandle {
    boot_opts(ServeOptions {
        workers,
        db_path,
        backend: BackendChoice::Native,
        ..Default::default()
    })
}

fn get_json(h: &ServerHandle, method: &str, path: &str, body: Option<&str>) -> (u16, JsonValue) {
    let (status, body) = request(h.addr, method, path, body).unwrap();
    let v = parse(&body).unwrap_or_else(|e| panic!("unparseable response {body:?}: {e}"));
    (status, v)
}

fn u(v: &JsonValue, path: &[&str]) -> u64 {
    let mut cur = v;
    for p in path {
        cur = cur.get(p).unwrap_or_else(|| panic!("missing field {p:?} in {v:?}"));
    }
    cur.as_u64().unwrap_or_else(|| panic!("field {path:?} is not a number"))
}

const SEARCH_BODY: &str = "{\"model\":\"bert-base\"}";

fn temp_db(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("wham-service-e2e-{}-{tag}.jsonl", std::process::id()))
}

#[test]
fn second_search_is_served_from_the_design_db() {
    let h = boot(None, 2);

    let (status, first) = get_json(&h, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);
    assert_eq!(first.get("model").unwrap().as_str(), Some("bert-base"));
    assert!(u(&first, &["scheduler_evals"]) > 0, "cold search must run the scheduler");
    assert_eq!(u(&first, &["cache_hits"]), 0);
    let fp = first.get("fingerprint").unwrap().as_str().unwrap().to_string();
    assert_eq!(fp.len(), 16, "fingerprint is 16 hex digits");

    let (status, second) = get_json(&h, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);
    assert_eq!(u(&second, &["scheduler_evals"]), 0, "repeat search must be all cache hits");
    assert_eq!(u(&second, &["cache_hits"]), u(&second, &["dims_evaluated"]));
    assert_eq!(second.get("fingerprint").unwrap().as_str().unwrap(), fp);
    assert_eq!(
        second.get("best").unwrap().get("display").unwrap().as_str(),
        first.get("best").unwrap().get("display").unwrap().as_str(),
    );

    let (status, st) = get_json(&h, "GET", "/status", None);
    assert_eq!(status, 200);
    assert_eq!(u(&st, &["search", "cold"]), 1);
    assert_eq!(u(&st, &["search", "warm"]), 1);
    assert!(u(&st, &["db", "hits"]) > 0, "second search must hit the db");
    assert!(u(&st, &["db", "entries"]) > 0);
}

#[test]
fn concurrent_identical_searches_run_the_search_once() {
    const CLIENTS: usize = 8;
    let h = boot(None, CLIENTS);

    let threads: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let addr = h.addr;
            std::thread::spawn(move || request(addr, "POST", "/search", Some(SEARCH_BODY)).unwrap())
        })
        .collect();
    let mut bests = Vec::new();
    for t in threads {
        let (status, body) = t.join().unwrap();
        assert_eq!(status, 200, "body: {body}");
        let v = parse(&body).unwrap();
        bests.push(
            v.get("best").unwrap().get("display").unwrap().as_str().unwrap().to_string(),
        );
    }
    assert!(bests.windows(2).all(|w| w[0] == w[1]), "all clients must agree: {bests:?}");

    let (_, st) = get_json(&h, "GET", "/status", None);
    // Exactly one request paid for scheduler work; everyone else either
    // joined the in-flight leader or read the warm database.
    assert_eq!(u(&st, &["search", "cold"]), 1, "status: {st:?}");
    assert_eq!(u(&st, &["search", "requests"]), CLIENTS as u64);
    let coalesced = u(&st, &["coalescer", "coalesced"]);
    let warm = u(&st, &["search", "warm"]);
    assert_eq!(coalesced + warm, (CLIENTS - 1) as u64, "status: {st:?}");
}

#[test]
fn restart_with_same_db_answers_without_scheduler() {
    let db = temp_db("restart");
    let _ = std::fs::remove_file(&db);

    let a = boot(Some(db.clone()), 2);
    let (status, cold) = get_json(&a, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);
    assert!(u(&cold, &["scheduler_evals"]) > 0);
    assert!(a.state.db.stats().appended > 0, "mined designs must reach the file");
    drop(a);

    // "Restart": a brand-new server process state over the same file.
    let b = boot(Some(db.clone()), 2);
    let (_, st) = get_json(&b, "GET", "/status", None);
    assert!(u(&st, &["db", "loaded"]) > 0, "boot must load the mined designs");

    let (status, warm) = get_json(&b, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);
    assert_eq!(
        u(&warm, &["scheduler_evals"]),
        0,
        "warm path after restart must not run the scheduler"
    );
    assert_eq!(
        warm.get("best").unwrap().get("display").unwrap().as_str(),
        cold.get("best").unwrap().get("display").unwrap().as_str(),
    );
    let (_, st) = get_json(&b, "GET", "/status", None);
    assert_eq!(u(&st, &["search", "cold"]), 0);
    assert_eq!(u(&st, &["search", "warm"]), 1);

    let _ = std::fs::remove_file(&db);
}

#[test]
fn uploaded_spec_is_mined_end_to_end() {
    let h = boot(None, 2);

    // A tiny custom workload, defined purely as data.
    let spec = r#"{
        "name": "e2e-tiny", "task": "test", "batch": 2,
        "params": {"h": 8, "bs": "batch*4"},
        "graph": [
            {"op": "embed", "elems": "bs*h", "params": "16*h"},
            {"op": "linear", "name": "fc1", "m": "bs", "n": "h", "k": "h"},
            {"op": "activation", "elems": "bs*h"},
            {"op": "linear", "m": "bs", "n": 4, "k": "h"}
        ]
    }"#;
    let (status, up) = get_json(&h, "POST", "/workloads", Some(spec));
    assert_eq!(status, 200, "upload failed: {up:?}");
    assert_eq!(up.get("name").unwrap().as_str(), Some("e2e-tiny"));
    assert_eq!(up.get("source").unwrap().as_str(), Some("uploaded"));
    let fp = up.get("fingerprint").unwrap().as_str().unwrap().to_string();
    assert_eq!(fp.len(), 16);
    assert!(u(&up, &["training_ops"]) > u(&up, &["forward_ops"]));

    // The uploaded name is now searchable like any builtin, and the
    // reply's fingerprint matches the upload's (one design-DB context).
    let (status, cold) = get_json(&h, "POST", "/search", Some("{\"model\":\"e2e-tiny\"}"));
    assert_eq!(status, 200, "search failed: {cold:?}");
    assert_eq!(cold.get("fingerprint").unwrap().as_str().unwrap(), fp);
    assert!(u(&cold, &["scheduler_evals"]) > 0);

    // And warm-cached by fingerprint, exactly like builtins.
    let (_, warm) = get_json(&h, "POST", "/search", Some("{\"model\":\"e2e-tiny\"}"));
    assert_eq!(u(&warm, &["scheduler_evals"]), 0, "repeat search must hit the design DB");

    // GET /models lists it with its registry layer.
    let (_, models) = get_json(&h, "GET", "/models", None);
    let list = models.get("models").unwrap().as_arr().unwrap();
    assert!(list.iter().any(|m| m.get("name").unwrap().as_str() == Some("e2e-tiny")
        && m.get("source").unwrap().as_str() == Some("uploaded")));

    // Malformed specs are 400s carrying the layer path.
    let bad = "{\"name\":\"bad\",\"batch\":1,\"graph\":[{\"op\":\"linear\",\"name\":\"z\",\"m\":0,\"n\":4,\"k\":4}]}";
    let (status, err) = get_json(&h, "POST", "/workloads", Some(bad));
    assert_eq!(status, 400);
    assert!(
        err.get("error").unwrap().as_str().unwrap().contains("graph/z"),
        "diagnostic must carry the layer path: {err:?}"
    );

    // Builtin names are reserved.
    let shadow = "{\"name\":\"bert-base\",\"batch\":1,\"graph\":[{\"op\":\"linear\",\"m\":4,\"n\":4,\"k\":4}]}";
    let (status, err) = get_json(&h, "POST", "/workloads", Some(shadow));
    assert_eq!(status, 400, "{err:?}");

    // Wrong method on the new endpoint.
    let (status, _) = get_json(&h, "GET", "/workloads", None);
    assert_eq!(status, 405);
}

/// Poll `GET /jobs/:id` until the job leaves queued/running.
fn poll_terminal(h: &ServerHandle, id: &str, secs: u64) -> JsonValue {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        let (status, v) = get_json(h, "GET", &format!("/jobs/{id}"), None);
        assert_eq!(status, 200, "{v:?}");
        let state = v.get("state").unwrap().as_str().unwrap().to_string();
        if state != "queued" && state != "running" {
            return v;
        }
        assert!(Instant::now() < deadline, "job {id} stuck in {state:?}");
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Canonical re-dump with the wall-clock field zeroed — the only part of
/// a search reply that may differ between two warm runs of the same plan.
fn normalize_reply(body: &str) -> String {
    let mut v = parse(body).unwrap_or_else(|e| panic!("unparseable reply {body:?}: {e}"));
    if let JsonValue::Obj(m) = &mut v {
        m.insert("wall_ms".to_string(), JsonValue::Num(0.0));
    }
    dump(&v)
}

#[test]
fn async_job_matches_sync_search_and_streams_events() {
    let h = boot(None, 2);

    // Cold sync search fills the design DB, so both comparands below run
    // warm (and therefore deterministically, modulo wall-clock).
    let (status, _) = get_json(&h, "POST", "/search", Some("{\"model\":\"alexnet\"}"));
    assert_eq!(status, 200);
    let (status, sync_body) =
        request(h.addr, "POST", "/search", Some("{\"model\":\"alexnet\"}")).unwrap();
    assert_eq!(status, 200);

    let (status, sub) =
        get_json(&h, "POST", "/jobs", Some("{\"request\":{\"model\":\"alexnet\"}}"));
    assert_eq!(status, 202, "submission must answer 202 Accepted: {sub:?}");
    assert_eq!(sub.get("state").unwrap().as_str(), Some("queued"));
    assert_eq!(sub.get("kind").unwrap().as_str(), Some("search"));
    let id = sub.get("id").unwrap().as_str().unwrap().to_string();

    // The listing knows the job immediately.
    let (status, list) = get_json(&h, "GET", "/jobs", None);
    assert_eq!(status, 200);
    let jobs = list.get("jobs").unwrap().as_arr().unwrap();
    assert!(jobs.iter().any(|j| j.get("id").unwrap().as_str() == Some(id.as_str())));

    let rec = poll_terminal(&h, &id, 60);
    assert_eq!(rec.get("state").unwrap().as_str(), Some("done"), "{rec:?}");
    assert_eq!(u(&rec, &["attempts"]), 1);

    // The stored reply is the sync endpoint's reply, byte for byte.
    let (status, job_body) =
        request(h.addr, "GET", &format!("/jobs/{id}/reply"), None).unwrap();
    assert_eq!(status, 200);
    assert_eq!(normalize_reply(&job_body), normalize_reply(&sync_body));

    // SSE replay for a finished job: one state frame, one done frame,
    // then the server closes the stream (no hanging watchers).
    let mut lines = Vec::new();
    let status = request_stream(h.addr, "GET", &format!("/jobs/{id}/events"), None, |l| {
        lines.push(l.to_string());
        true
    })
    .unwrap();
    assert_eq!(status, 200);
    assert!(lines.iter().any(|l| l == "event: state"), "{lines:?}");
    assert!(lines.iter().any(|l| l == "event: done"), "{lines:?}");
    assert!(
        lines.iter().any(|l| l.starts_with("data: ") && l.contains("\"state\":\"done\"")),
        "{lines:?}"
    );

    // The status document counts it.
    let (_, st) = get_json(&h, "GET", "/status", None);
    assert!(u(&st, &["jobs", "done"]) >= 1, "{st:?}");
    assert!(u(&st, &["jobs", "submitted"]) >= 1, "{st:?}");
}

#[test]
fn http_cancel_reaches_a_terminal_state_without_running() {
    // One job worker keeps the second submission queued behind the first.
    let h = boot_opts(ServeOptions {
        workers: 2,
        db_path: None,
        backend: BackendChoice::Native,
        jobs: JobsOptions { workers: 1, ..Default::default() },
        ..Default::default()
    });
    let body = "{\"request\":{\"model\":\"alexnet\"}}";
    let (status, first) = get_json(&h, "POST", "/jobs", Some(body));
    assert_eq!(status, 202, "{first:?}");
    let (status, second) = get_json(&h, "POST", "/jobs", Some(body));
    assert_eq!(status, 202, "{second:?}");
    let id = second.get("id").unwrap().as_str().unwrap().to_string();

    let (status, del) = get_json(&h, "DELETE", &format!("/jobs/{id}"), None);
    assert_eq!(status, 200, "{del:?}");
    let rec = poll_terminal(&h, &id, 60);
    let state = rec.get("state").unwrap().as_str().unwrap();
    // Still queued at cancel time -> cancelled without ever running;
    // if the first job finished improbably fast, the cooperative path
    // may have let it complete. Never failed, never stuck.
    assert!(state == "cancelled" || state == "done", "unexpected state {state:?}");

    // Unknown ids are 404 on every job route.
    let (status, _) = get_json(&h, "DELETE", "/jobs/j-nope-0000", None);
    assert_eq!(status, 404);
}

#[test]
fn saturated_quota_answers_429_with_retry_after() {
    // Burst of one and a near-zero refill rate: the second submission
    // from the same client must bounce, other clients must not.
    let h = boot_opts(ServeOptions {
        workers: 2,
        db_path: None,
        backend: BackendChoice::Native,
        jobs: JobsOptions { quota_rate: 0.001, quota_burst: 1.0, ..Default::default() },
        ..Default::default()
    });
    let body = "{\"client\":\"ci\",\"request\":{\"model\":\"alexnet\"}}";
    let (status, _, _) = request_full(h.addr, "POST", "/jobs", Some(body)).unwrap();
    assert_eq!(status, 202);
    let (status, headers, resp) = request_full(h.addr, "POST", "/jobs", Some(body)).unwrap();
    assert_eq!(status, 429, "expected quota rejection, got {resp}");
    let retry_after = headers
        .iter()
        .find(|(k, _)| k == "retry-after")
        .map(|(_, v)| v.clone())
        .expect("429 must carry Retry-After");
    assert!(retry_after.parse::<u64>().unwrap() >= 1, "Retry-After {retry_after:?}");
    assert!(resp.contains("quota"), "{resp}");

    let other = "{\"client\":\"other\",\"request\":{\"model\":\"alexnet\"}}";
    let (status, _, _) = request_full(h.addr, "POST", "/jobs", Some(other)).unwrap();
    assert_eq!(status, 202, "a different client has its own bucket");

    let (_, st) = get_json(&h, "GET", "/status", None);
    assert!(u(&st, &["jobs", "rejected_quota"]) >= 1, "{st:?}");
}

#[test]
fn crash_interrupted_job_resumes_warm_after_restart() {
    let db = temp_db("jobs-resume-db");
    let wal = temp_db("jobs-resume-wal");
    let _ = std::fs::remove_file(&db);
    let _ = std::fs::remove_file(&wal);

    // Boot A mines alexnet into the design DB, then "crashes" (drop).
    let a = boot(Some(db.clone()), 2);
    let (status, cold) = get_json(&a, "POST", "/search", Some("{\"model\":\"alexnet\"}"));
    assert_eq!(status, 200);
    assert!(u(&cold, &["scheduler_evals"]) > 0);
    drop(a);

    // Forge the crash scene: a WAL whose job was mid-run when the
    // process died, plus the torn partial line a kill -9 leaves behind.
    let id = {
        let store = JobStore::open(&wal).unwrap();
        let rec = store.submit(JobKind::Search, "ci", "{\"model\":\"alexnet\"}");
        store.mark_running(&rec.id).unwrap();
        rec.id
    };
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&wal).unwrap();
        f.write_all(b"{\"id\":\"j-torn\",\"sta").unwrap();
    }

    // Boot B over the same files: replay demotes running -> queued and
    // skips the torn tail; the dispatcher re-runs the job against the
    // warm design DB without being asked.
    let b = boot_opts(ServeOptions {
        workers: 2,
        db_path: Some(db.clone()),
        backend: BackendChoice::Native,
        jobs_path: Some(wal.clone()),
        ..Default::default()
    });
    assert_eq!(b.state.jobs.store().resumed(), 1, "interrupted job must re-queue");
    assert_eq!(b.state.jobs.store().skipped(), 1, "torn tail must be skipped, not fatal");

    let rec = poll_terminal(&b, &id, 60);
    assert_eq!(rec.get("state").unwrap().as_str(), Some("done"), "{rec:?}");

    // The resumed run warm-started from the mined design DB: zero
    // scheduler invocations end to end.
    let (status, reply) = request(b.addr, "GET", &format!("/jobs/{id}/reply"), None).unwrap();
    assert_eq!(status, 200);
    let v = parse(&reply).unwrap();
    assert_eq!(u(&v, &["scheduler_evals"]), 0, "resumed job must not re-run the scheduler");

    let (_, st) = get_json(&b, "GET", "/status", None);
    assert!(u(&st, &["jobs", "done"]) >= 1, "{st:?}");

    let _ = std::fs::remove_file(&db);
    let _ = std::fs::remove_file(&wal);
}

#[test]
fn graceful_shutdown_drains_and_checkpoints() {
    let wal = temp_db("jobs-drain-wal");
    let _ = std::fs::remove_file(&wal);

    let h = boot_opts(ServeOptions {
        workers: 2,
        db_path: None,
        backend: BackendChoice::Native,
        jobs_path: Some(wal.clone()),
        ..Default::default()
    });
    let (status, sub) =
        get_json(&h, "POST", "/jobs", Some("{\"request\":{\"model\":\"alexnet\"}}"));
    assert_eq!(status, 202, "{sub:?}");
    let id = sub.get("id").unwrap().as_str().unwrap().to_string();

    let summary = h.shutdown(Duration::from_secs(60));
    assert_eq!(summary.completed + summary.requeued + summary.queued_left, 1, "{summary:?}");

    // After the drain the acceptor is closed and the WAL survives with
    // the job's full history (a later boot could resume it).
    assert!(request(h.addr, "GET", "/status", None).is_err(), "acceptor must be closed");
    let text = std::fs::read_to_string(&wal).unwrap();
    assert!(text.lines().any(|l| l.contains(&id)), "checkpointed WAL must carry the job");

    let _ = std::fs::remove_file(&wal);
}

#[test]
fn cluster_endpoint_sweeps_and_counts_events() {
    let h = boot(None, 2);

    // Screening-only sweep on a 2-device cluster keeps the test fast.
    let body = "{\"model\":\"bert-base\",\"devices\":2,\"schedules\":[\"gpipe\"],\"mine\":0}";
    let (status, r) = get_json(&h, "POST", "/cluster", Some(body));
    assert_eq!(status, 200, "cluster sweep failed: {r:?}");
    assert_eq!(r.get("model").unwrap().as_str(), Some("bert-base"));
    assert_eq!(u(&r, &["devices"]), 2);
    assert!(u(&r, &["candidates"]) >= 2, "{r:?}");
    let ranked = r.get("ranked").unwrap().as_arr().unwrap();
    assert_eq!(ranked.len() as u64, u(&r, &["candidates"]));
    let top = ranked[0].get("throughput").unwrap().as_f64().unwrap();
    let base = r.get("baseline").unwrap().get("throughput").unwrap().as_f64().unwrap();
    assert!(top >= base, "top {top} must not fall below the fixed baseline {base}");

    // The cluster-sim event counter surfaces in /status (process-wide,
    // so only monotone assertions are safe across tests).
    let (_, st) = get_json(&h, "GET", "/status", None);
    assert!(u(&st, &["perf", "cluster_sim_events_total"]) > 0, "status: {st:?}");

    // Bad shapes are request errors, not worker panics.
    let (status, _) = get_json(&h, "POST", "/cluster", Some("{\"model\":\"bert-base\",\"devices\":0}"));
    assert_eq!(status, 400);
    let (status, _) =
        get_json(&h, "POST", "/cluster", Some("{\"model\":\"bert-base\",\"topology\":\"torus\"}"));
    assert_eq!(status, 400);
    let (status, _) = get_json(&h, "POST", "/cluster", Some("{\"model\":\"vgg16\"}"));
    assert_eq!(status, 404, "non-LLM workloads cannot be pipelined");
    let (status, _) = get_json(&h, "GET", "/cluster", None);
    assert_eq!(status, 405);
}

#[test]
fn status_exposes_perf_counters() {
    let h = boot(None, 2);
    let (status, _) = get_json(&h, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);
    let (status, st) = get_json(&h, "GET", "/status", None);
    assert_eq!(status, 200);
    // Process-wide hot-path counters (shared with other tests in this
    // binary, so only monotone assertions are safe).
    assert!(u(&st, &["perf", "backend_rows_total"]) > 0, "status: {st:?}");
    assert!(u(&st, &["perf", "scheduler_evals_total"]) > 0, "status: {st:?}");
    let rate = st.get("perf").unwrap().get("db_hit_rate").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&rate), "hit rate {rate}");
    let eps = st.get("perf").unwrap().get("endpoints").unwrap().as_arr().unwrap();
    let search = eps
        .iter()
        .find(|e| e.get("endpoint").unwrap().as_str() == Some("/search"))
        .expect("per-endpoint digest for /search");
    assert!(u(search, &["count"]) >= 1);
    let p50 = search.get("p50_ms").unwrap().as_f64().unwrap();
    let p95 = search.get("p95_ms").unwrap().as_f64().unwrap();
    assert!(p95 >= p50 && p50 >= 0.0, "p50={p50} p95={p95}");
}

/// A fast-scraping tsdb shape for the observability tests: 25ms ticks
/// instead of 2s, so history fills and alerts evaluate within a test's
/// patience rather than a deployment's.
fn fast_tsdb() -> TsdbOptions {
    TsdbOptions { fine_every: Duration::from_millis(25), ..Default::default() }
}

/// Find one alert entry in a `/status` document by rule name.
fn alert<'v>(st: &'v JsonValue, rule: &str) -> &'v JsonValue {
    st.get("alerts")
        .and_then(|a| a.as_arr())
        .and_then(|a| a.iter().find(|e| e.get("rule").and_then(|r| r.as_str()) == Some(rule)))
        .unwrap_or_else(|| panic!("no alert {rule:?} in {st:?}"))
}

#[test]
fn dashboard_and_history_populate_after_a_search() {
    let h = boot_opts(ServeOptions {
        workers: 2,
        db_path: None,
        backend: BackendChoice::Native,
        tsdb: fast_tsdb(),
        ..Default::default()
    });

    // A real search gives the scraper counters worth recording.
    let (status, _) = get_json(&h, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);

    // Rates need two scrapes of the same counter; poll instead of
    // trusting one fixed sleep.
    let deadline = Instant::now() + Duration::from_secs(20);
    let series = loop {
        let (status, hist) = get_json(&h, "GET", "/metrics/history", None);
        assert_eq!(status, 200);
        let series =
            hist.get("series").and_then(|s| s.as_arr()).map(<[JsonValue]>::to_vec).unwrap_or_default();
        let has = |n: &str| {
            series.iter().any(|s| s.get("name").and_then(|v| v.as_str()) == Some(n))
        };
        // Gauges land after one scrape; counter *rates* need two. Wait
        // for both shapes so the assertions below can't race the scraper.
        if has("wham_http_requests_total") && has("wham_process_uptime_seconds") {
            break series;
        }
        assert!(Instant::now() < deadline, "history stayed empty: {hist:?}");
        std::thread::sleep(Duration::from_millis(25));
    };
    let name = |s: &JsonValue| s.get("name").unwrap().as_str().unwrap().to_string();
    assert!(
        series.iter().any(|s| name(s) == "wham_http_requests_total"),
        "request counter must be in the history"
    );
    assert!(
        series.iter().any(|s| name(s) == "wham_process_uptime_seconds"),
        "process gauges must be in the history"
    );
    for s in &series {
        assert!(
            !s.get("points").unwrap().as_arr().unwrap().is_empty(),
            "series {} has no points",
            name(s)
        );
    }

    // Series filtering and window validation.
    let (status, filtered) =
        get_json(&h, "GET", "/metrics/history?series=wham_http_*", None);
    assert_eq!(status, 200);
    for s in filtered.get("series").unwrap().as_arr().unwrap() {
        assert!(name(s).starts_with("wham_http_"), "filter leaked {}", name(s));
    }
    let (status, _) = get_json(&h, "GET", "/metrics/history?window=0", None);
    assert_eq!(status, 400);

    // The dashboard renders entirely from local state: one HTML
    // document, inline SVG, zero external assets.
    let (status, html) = request(h.addr, "GET", "/dashboard", None).unwrap();
    assert_eq!(status, 200);
    assert!(html.contains("<svg") || html.contains("collecting"), "no sparklines: {html:?}");
    assert!(html.contains("job-queue-pressure"), "alert table missing");
    for banned in ["http://", "https://", "<script src", "<link "] {
        assert!(!html.contains(banned), "dashboard must be self-contained, found {banned:?}");
    }
}

#[test]
fn queue_saturation_fires_then_resolves_an_alert() {
    // Queue of 2 with one worker: the cold job runs for seconds while
    // the rest wait, so the 25ms scraper sees depth >= 2 long enough to
    // fire job-queue-pressure (threshold 80% of 2), then sees the drain
    // and resolves it.
    let h = boot_opts(ServeOptions {
        workers: 2,
        db_path: None,
        backend: BackendChoice::Native,
        jobs: JobsOptions { workers: 1, queue_depth: 2, ..Default::default() },
        tsdb: fast_tsdb(),
        ..Default::default()
    });

    // Watch the SSE feed from before the saturation so the fire frame
    // cannot be missed.
    let addr = h.addr;
    let sse = std::thread::spawn(move || {
        let mut lines = Vec::new();
        let mut saw_resolve = false;
        let _ = request_stream(addr, "GET", "/alerts/events", None, |l| {
            if l == "event: resolve" {
                saw_resolve = true;
            }
            lines.push(l.to_string());
            // Read through the resolve frame's data line, then hang up.
            !(saw_resolve && lines.last().map(String::as_str) != Some("event: resolve"))
        });
        lines
    });

    let body = "{\"request\":{\"model\":\"alexnet\"}}";
    let mut ids = Vec::new();
    for _ in 0..3 {
        let (status, sub) = get_json(&h, "POST", "/jobs", Some(body));
        if status == 202 {
            ids.push(sub.get("id").unwrap().as_str().unwrap().to_string());
        } else {
            // Depth rejections (429) are fine — the queue is saturated,
            // which is exactly the condition under test.
            assert_eq!(status, 429, "{sub:?}");
        }
    }
    assert!(!ids.is_empty());

    // Fire: /status flips the rule active, /metrics mirrors it.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (_, st) = get_json(&h, "GET", "/status", None);
        if alert(&st, "job-queue-pressure").get("active").unwrap().as_bool() == Some(true) {
            assert!(u(alert(&st, "job-queue-pressure"), &["since_ms"]) > 0);
            break;
        }
        assert!(Instant::now() < deadline, "alert never fired: {st:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    let (status, metrics) = request(h.addr, "GET", "/metrics", None).unwrap();
    assert_eq!(status, 200);
    assert!(
        metrics.contains("wham_alert_active{rule=\"job-queue-pressure\"} 1"),
        "metrics must mirror the firing alert"
    );

    // Resolve: wait for the jobs to drain, then for the hysteresis to
    // clear the rule.
    for id in &ids {
        poll_terminal(&h, id, 120);
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (_, st) = get_json(&h, "GET", "/status", None);
        let a = alert(&st, "job-queue-pressure");
        if a.get("active").unwrap().as_bool() == Some(false) {
            assert_eq!(u(a, &["since_ms"]), 0, "resolved alert must clear its episode start");
            break;
        }
        assert!(Instant::now() < deadline, "alert never resolved: {st:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    let (_, metrics) = request(h.addr, "GET", "/metrics", None).unwrap();
    assert!(metrics.contains("wham_alert_active{rule=\"job-queue-pressure\"} 0"));

    // The SSE stream saw the full episode in order.
    let lines = sse.join().unwrap();
    let fire = lines.iter().position(|l| l == "event: fire");
    let resolve = lines.iter().position(|l| l == "event: resolve");
    assert!(fire.is_some(), "no fire frame in {lines:?}");
    assert!(resolve.is_some(), "no resolve frame in {lines:?}");
    assert!(fire < resolve, "fire must precede resolve: {lines:?}");
    assert!(
        lines.iter().any(|l| l.starts_with("data: ") && l.contains("job-queue-pressure")),
        "frames must carry the rule payload: {lines:?}"
    );
}

#[test]
fn models_evaluate_and_errors() {
    let h = boot(None, 2);

    let (status, models) = get_json(&h, "GET", "/models", None);
    assert_eq!(status, 200);
    let list = models.get("models").unwrap().as_arr().unwrap();
    // The workload registry is process-global, so other tests in this
    // binary may have registered extra specs; the builtin layer is
    // always exactly the Table-4 zoo.
    let builtin =
        list.iter().filter(|m| m.get("source").unwrap().as_str() == Some("builtin")).count();
    assert_eq!(builtin, 11);
    assert!(list.iter().any(|m| m.get("name").unwrap().as_str() == Some("bert-base")));

    let (status, ev) = get_json(
        &h,
        "POST",
        "/evaluate",
        Some("{\"model\":\"bert-base\",\"config\":[2,128,128,2,128]}"),
    );
    assert_eq!(status, 200);
    assert_eq!(ev.get("config").unwrap().as_str(), Some("<2, 128x128, 2, 128>"));
    assert!(ev.get("eval").unwrap().get("throughput").unwrap().as_f64().unwrap() > 0.0);

    let (status, _) = get_json(&h, "POST", "/search", Some("{\"model\":\"no-such-model\"}"));
    assert_eq!(status, 404);
    let (status, _) = get_json(&h, "POST", "/global", Some("{\"depth\":0}"));
    assert_eq!(status, 400, "zero depth must be rejected, not panic a worker");
    let (status, _) = get_json(&h, "POST", "/search", Some("{not json"));
    assert_eq!(status, 400);
    let (status, _) = get_json(&h, "GET", "/nope", None);
    assert_eq!(status, 404);
    let (status, _) = get_json(&h, "GET", "/search", None);
    assert_eq!(status, 405);
}
