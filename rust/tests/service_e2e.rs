//! End-to-end tests of `wham serve`: boot the real server on ephemeral
//! ports, drive it over real `TcpStream`s, and verify the three service
//! guarantees — repeat searches are answered from the design database,
//! identical concurrent requests coalesce to one computation, and a
//! restart with the same `--db` file answers previously-mined searches
//! without re-running the scheduler.

use std::net::TcpListener;
use std::path::PathBuf;

use wham::coordinator::BackendChoice;
use wham::service::http::request;
use wham::service::{start, ServeOptions, ServerHandle};
use wham::util::json::{parse, JsonValue};

fn boot(db_path: Option<PathBuf>, workers: usize) -> ServerHandle {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    start(listener, ServeOptions { workers, db_path, backend: BackendChoice::Native }).unwrap()
}

fn get_json(h: &ServerHandle, method: &str, path: &str, body: Option<&str>) -> (u16, JsonValue) {
    let (status, body) = request(h.addr, method, path, body).unwrap();
    let v = parse(&body).unwrap_or_else(|e| panic!("unparseable response {body:?}: {e}"));
    (status, v)
}

fn u(v: &JsonValue, path: &[&str]) -> u64 {
    let mut cur = v;
    for p in path {
        cur = cur.get(p).unwrap_or_else(|| panic!("missing field {p:?} in {v:?}"));
    }
    cur.as_u64().unwrap_or_else(|| panic!("field {path:?} is not a number"))
}

const SEARCH_BODY: &str = "{\"model\":\"bert-base\"}";

fn temp_db(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("wham-service-e2e-{}-{tag}.jsonl", std::process::id()))
}

#[test]
fn second_search_is_served_from_the_design_db() {
    let h = boot(None, 2);

    let (status, first) = get_json(&h, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);
    assert_eq!(first.get("model").unwrap().as_str(), Some("bert-base"));
    assert!(u(&first, &["scheduler_evals"]) > 0, "cold search must run the scheduler");
    assert_eq!(u(&first, &["cache_hits"]), 0);
    let fp = first.get("fingerprint").unwrap().as_str().unwrap().to_string();
    assert_eq!(fp.len(), 16, "fingerprint is 16 hex digits");

    let (status, second) = get_json(&h, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);
    assert_eq!(u(&second, &["scheduler_evals"]), 0, "repeat search must be all cache hits");
    assert_eq!(u(&second, &["cache_hits"]), u(&second, &["dims_evaluated"]));
    assert_eq!(second.get("fingerprint").unwrap().as_str().unwrap(), fp);
    assert_eq!(
        second.get("best").unwrap().get("display").unwrap().as_str(),
        first.get("best").unwrap().get("display").unwrap().as_str(),
    );

    let (status, st) = get_json(&h, "GET", "/status", None);
    assert_eq!(status, 200);
    assert_eq!(u(&st, &["search", "cold"]), 1);
    assert_eq!(u(&st, &["search", "warm"]), 1);
    assert!(u(&st, &["db", "hits"]) > 0, "second search must hit the db");
    assert!(u(&st, &["db", "entries"]) > 0);
}

#[test]
fn concurrent_identical_searches_run_the_search_once() {
    const CLIENTS: usize = 8;
    let h = boot(None, CLIENTS);

    let threads: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let addr = h.addr;
            std::thread::spawn(move || request(addr, "POST", "/search", Some(SEARCH_BODY)).unwrap())
        })
        .collect();
    let mut bests = Vec::new();
    for t in threads {
        let (status, body) = t.join().unwrap();
        assert_eq!(status, 200, "body: {body}");
        let v = parse(&body).unwrap();
        bests.push(
            v.get("best").unwrap().get("display").unwrap().as_str().unwrap().to_string(),
        );
    }
    assert!(bests.windows(2).all(|w| w[0] == w[1]), "all clients must agree: {bests:?}");

    let (_, st) = get_json(&h, "GET", "/status", None);
    // Exactly one request paid for scheduler work; everyone else either
    // joined the in-flight leader or read the warm database.
    assert_eq!(u(&st, &["search", "cold"]), 1, "status: {st:?}");
    assert_eq!(u(&st, &["search", "requests"]), CLIENTS as u64);
    let coalesced = u(&st, &["coalescer", "coalesced"]);
    let warm = u(&st, &["search", "warm"]);
    assert_eq!(coalesced + warm, (CLIENTS - 1) as u64, "status: {st:?}");
}

#[test]
fn restart_with_same_db_answers_without_scheduler() {
    let db = temp_db("restart");
    let _ = std::fs::remove_file(&db);

    let a = boot(Some(db.clone()), 2);
    let (status, cold) = get_json(&a, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);
    assert!(u(&cold, &["scheduler_evals"]) > 0);
    assert!(a.state.db.stats().appended > 0, "mined designs must reach the file");
    drop(a);

    // "Restart": a brand-new server process state over the same file.
    let b = boot(Some(db.clone()), 2);
    let (_, st) = get_json(&b, "GET", "/status", None);
    assert!(u(&st, &["db", "loaded"]) > 0, "boot must load the mined designs");

    let (status, warm) = get_json(&b, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);
    assert_eq!(
        u(&warm, &["scheduler_evals"]),
        0,
        "warm path after restart must not run the scheduler"
    );
    assert_eq!(
        warm.get("best").unwrap().get("display").unwrap().as_str(),
        cold.get("best").unwrap().get("display").unwrap().as_str(),
    );
    let (_, st) = get_json(&b, "GET", "/status", None);
    assert_eq!(u(&st, &["search", "cold"]), 0);
    assert_eq!(u(&st, &["search", "warm"]), 1);

    let _ = std::fs::remove_file(&db);
}

#[test]
fn uploaded_spec_is_mined_end_to_end() {
    let h = boot(None, 2);

    // A tiny custom workload, defined purely as data.
    let spec = r#"{
        "name": "e2e-tiny", "task": "test", "batch": 2,
        "params": {"h": 8, "bs": "batch*4"},
        "graph": [
            {"op": "embed", "elems": "bs*h", "params": "16*h"},
            {"op": "linear", "name": "fc1", "m": "bs", "n": "h", "k": "h"},
            {"op": "activation", "elems": "bs*h"},
            {"op": "linear", "m": "bs", "n": 4, "k": "h"}
        ]
    }"#;
    let (status, up) = get_json(&h, "POST", "/workloads", Some(spec));
    assert_eq!(status, 200, "upload failed: {up:?}");
    assert_eq!(up.get("name").unwrap().as_str(), Some("e2e-tiny"));
    assert_eq!(up.get("source").unwrap().as_str(), Some("uploaded"));
    let fp = up.get("fingerprint").unwrap().as_str().unwrap().to_string();
    assert_eq!(fp.len(), 16);
    assert!(u(&up, &["training_ops"]) > u(&up, &["forward_ops"]));

    // The uploaded name is now searchable like any builtin, and the
    // reply's fingerprint matches the upload's (one design-DB context).
    let (status, cold) = get_json(&h, "POST", "/search", Some("{\"model\":\"e2e-tiny\"}"));
    assert_eq!(status, 200, "search failed: {cold:?}");
    assert_eq!(cold.get("fingerprint").unwrap().as_str().unwrap(), fp);
    assert!(u(&cold, &["scheduler_evals"]) > 0);

    // And warm-cached by fingerprint, exactly like builtins.
    let (_, warm) = get_json(&h, "POST", "/search", Some("{\"model\":\"e2e-tiny\"}"));
    assert_eq!(u(&warm, &["scheduler_evals"]), 0, "repeat search must hit the design DB");

    // GET /models lists it with its registry layer.
    let (_, models) = get_json(&h, "GET", "/models", None);
    let list = models.get("models").unwrap().as_arr().unwrap();
    assert!(list.iter().any(|m| m.get("name").unwrap().as_str() == Some("e2e-tiny")
        && m.get("source").unwrap().as_str() == Some("uploaded")));

    // Malformed specs are 400s carrying the layer path.
    let bad = "{\"name\":\"bad\",\"batch\":1,\"graph\":[{\"op\":\"linear\",\"name\":\"z\",\"m\":0,\"n\":4,\"k\":4}]}";
    let (status, err) = get_json(&h, "POST", "/workloads", Some(bad));
    assert_eq!(status, 400);
    assert!(
        err.get("error").unwrap().as_str().unwrap().contains("graph/z"),
        "diagnostic must carry the layer path: {err:?}"
    );

    // Builtin names are reserved.
    let shadow = "{\"name\":\"bert-base\",\"batch\":1,\"graph\":[{\"op\":\"linear\",\"m\":4,\"n\":4,\"k\":4}]}";
    let (status, err) = get_json(&h, "POST", "/workloads", Some(shadow));
    assert_eq!(status, 400, "{err:?}");

    // Wrong method on the new endpoint.
    let (status, _) = get_json(&h, "GET", "/workloads", None);
    assert_eq!(status, 405);
}

#[test]
fn cluster_endpoint_sweeps_and_counts_events() {
    let h = boot(None, 2);

    // Screening-only sweep on a 2-device cluster keeps the test fast.
    let body = "{\"model\":\"bert-base\",\"devices\":2,\"schedules\":[\"gpipe\"],\"mine\":0}";
    let (status, r) = get_json(&h, "POST", "/cluster", Some(body));
    assert_eq!(status, 200, "cluster sweep failed: {r:?}");
    assert_eq!(r.get("model").unwrap().as_str(), Some("bert-base"));
    assert_eq!(u(&r, &["devices"]), 2);
    assert!(u(&r, &["candidates"]) >= 2, "{r:?}");
    let ranked = r.get("ranked").unwrap().as_arr().unwrap();
    assert_eq!(ranked.len() as u64, u(&r, &["candidates"]));
    let top = ranked[0].get("throughput").unwrap().as_f64().unwrap();
    let base = r.get("baseline").unwrap().get("throughput").unwrap().as_f64().unwrap();
    assert!(top >= base, "top {top} must not fall below the fixed baseline {base}");

    // The cluster-sim event counter surfaces in /status (process-wide,
    // so only monotone assertions are safe across tests).
    let (_, st) = get_json(&h, "GET", "/status", None);
    assert!(u(&st, &["perf", "cluster_sim_events_total"]) > 0, "status: {st:?}");

    // Bad shapes are request errors, not worker panics.
    let (status, _) = get_json(&h, "POST", "/cluster", Some("{\"model\":\"bert-base\",\"devices\":0}"));
    assert_eq!(status, 400);
    let (status, _) =
        get_json(&h, "POST", "/cluster", Some("{\"model\":\"bert-base\",\"topology\":\"torus\"}"));
    assert_eq!(status, 400);
    let (status, _) = get_json(&h, "POST", "/cluster", Some("{\"model\":\"vgg16\"}"));
    assert_eq!(status, 404, "non-LLM workloads cannot be pipelined");
    let (status, _) = get_json(&h, "GET", "/cluster", None);
    assert_eq!(status, 405);
}

#[test]
fn status_exposes_perf_counters() {
    let h = boot(None, 2);
    let (status, _) = get_json(&h, "POST", "/search", Some(SEARCH_BODY));
    assert_eq!(status, 200);
    let (status, st) = get_json(&h, "GET", "/status", None);
    assert_eq!(status, 200);
    // Process-wide hot-path counters (shared with other tests in this
    // binary, so only monotone assertions are safe).
    assert!(u(&st, &["perf", "backend_rows_total"]) > 0, "status: {st:?}");
    assert!(u(&st, &["perf", "scheduler_evals_total"]) > 0, "status: {st:?}");
    let rate = st.get("perf").unwrap().get("db_hit_rate").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&rate), "hit rate {rate}");
    let eps = st.get("perf").unwrap().get("endpoints").unwrap().as_arr().unwrap();
    let search = eps
        .iter()
        .find(|e| e.get("endpoint").unwrap().as_str() == Some("/search"))
        .expect("per-endpoint digest for /search");
    assert!(u(search, &["count"]) >= 1);
    let p50 = search.get("p50_ms").unwrap().as_f64().unwrap();
    let p95 = search.get("p95_ms").unwrap().as_f64().unwrap();
    assert!(p95 >= p50 && p50 >= 0.0, "p50={p50} p95={p95}");
}

#[test]
fn models_evaluate_and_errors() {
    let h = boot(None, 2);

    let (status, models) = get_json(&h, "GET", "/models", None);
    assert_eq!(status, 200);
    let list = models.get("models").unwrap().as_arr().unwrap();
    // The workload registry is process-global, so other tests in this
    // binary may have registered extra specs; the builtin layer is
    // always exactly the Table-4 zoo.
    let builtin =
        list.iter().filter(|m| m.get("source").unwrap().as_str() == Some("builtin")).count();
    assert_eq!(builtin, 11);
    assert!(list.iter().any(|m| m.get("name").unwrap().as_str() == Some("bert-base")));

    let (status, ev) = get_json(
        &h,
        "POST",
        "/evaluate",
        Some("{\"model\":\"bert-base\",\"config\":[2,128,128,2,128]}"),
    );
    assert_eq!(status, 200);
    assert_eq!(ev.get("config").unwrap().as_str(), Some("<2, 128x128, 2, 128>"));
    assert!(ev.get("eval").unwrap().get("throughput").unwrap().as_f64().unwrap() > 0.0);

    let (status, _) = get_json(&h, "POST", "/search", Some("{\"model\":\"no-such-model\"}"));
    assert_eq!(status, 404);
    let (status, _) = get_json(&h, "POST", "/global", Some("{\"depth\":0}"));
    assert_eq!(status, 400, "zero depth must be rejected, not panic a worker");
    let (status, _) = get_json(&h, "POST", "/search", Some("{not json"));
    assert_eq!(status, 400);
    let (status, _) = get_json(&h, "GET", "/nope", None);
    assert_eq!(status, 404);
    let (status, _) = get_json(&h, "GET", "/search", None);
    assert_eq!(status, 405);
}
