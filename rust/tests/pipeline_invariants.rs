//! Integration tests over the distributed substrate: partition coverage,
//! HBM footprints, pipeline-simulation bounds, and global-search family
//! orderings.

use wham::arch::presets;
use wham::cost::native::NativeCost;
use wham::distributed::global_search::{global_search, GlobalOptions};
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::pipeline::simulate;
use wham::distributed::Scheme;
use wham::graph::autodiff::Optimizer;

#[test]
fn all_llms_partition_at_their_paper_depths() {
    for (name, depth, tmp) in [("opt-1.3b", 32u64, 1u64), ("gpt2-xl", 32, 1), ("gpt3", 8, 8)] {
        let cfg = wham::models::transformer_cfg(name).unwrap();
        let p = partition_transformer(name, &cfg, depth, tmp, Optimizer::Adam);
        // Depth clamps to layer count (OPT-1.3B: 24 layers).
        assert_eq!(p.stages.len() as u64, depth.min(cfg.layers), "{name}");
        assert_eq!(p.stages[0].layers.0, 0);
        assert_eq!(p.stages.last().unwrap().layers.1, cfg.layers);
        let covered: u64 = p.stages.iter().map(|s| s.layers.1 - s.layers.0).sum();
        assert_eq!(covered, cfg.layers, "{name}: layers covered exactly once");
    }
}

#[test]
fn gpipe_stash_exceeds_1f1b_stash() {
    let cfg = wham::models::transformer_cfg("gpt2-xl").unwrap();
    let p = partition_transformer("gpt2-xl", &cfg, 16, 1, Optimizer::Adam);
    for s in &p.stages {
        let gp = s.footprint_bytes(Scheme::GPipe, p.num_micro, 16);
        let pd = s.footprint_bytes(Scheme::PipeDream1F1B, p.num_micro, 16);
        assert!(gp >= pd, "stage {}: GPipe stash must dominate 1F1B", s.index);
    }
}

#[test]
fn pipeline_time_bounded_by_bottleneck_and_serial() {
    let mut cfg = wham::models::transformer_cfg("gpt2-xl").unwrap();
    cfg.layers = 8;
    let p = partition_transformer("mini", &cfg, 4, 1, Optimizer::Adam);
    let cfgs = vec![presets::tpuv2(); 4];
    let net = Network::default();
    let mut nc = NativeCost;
    for scheme in [Scheme::GPipe, Scheme::PipeDream1F1B] {
        let e = simulate(&p, &cfgs, scheme, &net, &mut nc);
        let bt = e.stage_times.iter().map(|t| t.fwd_s + t.bwd_s).fold(0.0, f64::max);
        let serial: f64 =
            e.stage_times.iter().map(|t| (t.fwd_s + t.bwd_s) * p.num_micro as f64).sum();
        assert!(e.iter_seconds >= bt * p.num_micro as f64 * 0.99, "{scheme:?}: below bottleneck bound");
        assert!(e.iter_seconds <= serial * 1.5, "{scheme:?}: worse than serial");
    }
}

#[test]
fn deeper_pipelines_do_not_reduce_per_device_throughput_density() {
    // More stages -> smaller stages -> iteration time must not grow.
    let mut cfg = wham::models::transformer_cfg("gpt2-xl").unwrap();
    cfg.layers = 16;
    let net = Network::default();
    let mut nc = NativeCost;
    let time_at = |stages: u64, nc: &mut NativeCost| {
        let p = partition_transformer("x", &cfg, stages, 1, Optimizer::Adam);
        let cfgs = vec![presets::tpuv2(); p.stages.len()];
        simulate(&p, &cfgs, Scheme::GPipe, &net, nc).iter_seconds
    };
    let t4 = time_at(4, &mut nc);
    let t8 = time_at(8, &mut nc);
    assert!(t8 <= t4 * 1.25, "depth 8 ({t8}) much slower than depth 4 ({t4})");
}

#[test]
fn tmp_reduces_iteration_time_for_giant_models() {
    // GPT3-class layers are so large that TMP's compute split dominates
    // its all-reduce overhead.
    let mut cfg = wham::models::transformer_cfg("gpt3").unwrap();
    cfg.layers = 8;
    let net = Network::default();
    let mut nc = NativeCost;
    let t1 = {
        let p = partition_transformer("g", &cfg, 4, 1, Optimizer::Adam);
        simulate(&p, &vec![presets::tpuv2(); 4], Scheme::GPipe, &net, &mut nc).iter_seconds
    };
    let t4 = {
        let p = partition_transformer("g", &cfg, 4, 4, Optimizer::Adam);
        simulate(&p, &vec![presets::tpuv2(); 4], Scheme::GPipe, &net, &mut nc).iter_seconds
    };
    assert!(t4 < t1, "tmp=4 ({t4}) must beat tmp=1 ({t1}) for GPT3-class layers");
}

#[test]
fn global_families_ordering() {
    let mut a = wham::models::transformer_cfg("gpt2-xl").unwrap();
    a.layers = 8;
    let p = partition_transformer("mini", &a, 4, 1, Optimizer::Adam);
    let mut nc = NativeCost;
    let net = Network::default();
    let r = global_search(std::slice::from_ref(&p), &GlobalOptions::default(), &net, &mut nc);
    // Individual == common when there is a single model.
    let c = r.common.1[0].eval.throughput;
    let i = r.individual[0].eval.throughput;
    assert!((c / i - 1.0).abs() < 1e-9, "single model: common ({c}) == individual ({i})");
    // The TPUv2 pipeline is never better than WHAM-individual.
    let cfgs = vec![presets::tpuv2(); p.stages.len()];
    let tpu = simulate(&p, &cfgs, Scheme::GPipe, &net, &mut nc);
    assert!(i >= tpu.throughput * 0.999);
}

#[test]
fn boundary_bytes_match_microbatch_activations() {
    let cfg = wham::models::transformer_cfg("opt-1.3b").unwrap();
    let p = partition_transformer("opt", &cfg, 8, 1, Optimizer::Adam);
    let expect = p.micro_batch * cfg.seq * cfg.hidden * 2;
    for s in &p.stages {
        assert_eq!(s.boundary_bytes, expect);
    }
}
