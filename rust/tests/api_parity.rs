//! CLI / HTTP parity and wire-regression tests for `wham::api`.
//!
//! The typed layer's whole point is that a request built from CLI flags
//! and the same request parsed from a JSON body are *the same value* —
//! identical canonical keys, equal replies — and that every reply the
//! service emits parses back through the same codec. These tests pin
//! that, plus the wire bugs the layer fixed (Debug-escaped non-ASCII in
//! `/global`, `unwrap_or(0)` configs in `/evaluate`, the silent batch-1
//! fallback on registry misses).

use std::net::TcpListener;

use wham::api::{
    CommonReply, CommonRequest, EvaluateReply, EvaluateRequest, FromJson, GlobalRequest,
    ModelsReply, SearchReply, SearchRequest, Session, StatusReply, ToJson,
};
use wham::coordinator::BackendChoice;
use wham::cost::native::NativeCost;
use wham::metrics::Metric;
use wham::service::http::request;
use wham::service::{start, ServeOptions, ServerHandle};
use wham::util::cli::Args;
use wham::util::json::{parse, JsonValue};

const KEYS: &[&str] = &[
    "model", "models", "metric", "k", "depth", "tmp", "scheme", "hysteresis", "dims", "tc",
    "vc", "deadline-ms", "backend",
];

fn args(raw: &[&str]) -> Args {
    Args::parse(raw.iter().map(|s| s.to_string()), KEYS).unwrap()
}

fn boot(workers: usize) -> ServerHandle {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    start(
        listener,
        ServeOptions {
            workers,
            db_path: None,
            backend: BackendChoice::Native,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Strip volatile fields before comparing two reply documents.
fn strip_wall(v: &JsonValue) -> JsonValue {
    match v {
        JsonValue::Obj(m) => {
            let mut m = m.clone();
            m.remove("wall_ms");
            JsonValue::Obj(m)
        }
        other => other.clone(),
    }
}

#[test]
fn args_and_json_requests_produce_identical_canonical_keys() {
    // Property: however a SearchRequest reaches us — CLI flags or its own
    // wire bytes — the validated plan derives byte-identical keys.
    wham::util::prop::forall(
        0xA11CE,
        24,
        |g| {
            let metric = *g.rng.choose(&["throughput", "perf/tdp"]);
            let k = g.rng.range(1, 20) as usize;
            let hysteresis = g.rng.range(0, 3) as u32;
            let ilp = g.rng.chance(0.5);
            let deadline = g.rng.chance(0.3).then(|| g.rng.range(1, 10_000) as u64);
            (metric, k, hysteresis, ilp, deadline)
        },
        |&(metric, k, hysteresis, ilp, deadline)| {
            let mut raw: Vec<String> = vec![
                "--model".into(),
                "bert-base".into(),
                "--metric".into(),
                metric.into(),
                "--k".into(),
                k.to_string(),
                "--hysteresis".into(),
                hysteresis.to_string(),
            ];
            if ilp {
                raw.push("--ilp".into());
            }
            if let Some(d) = deadline {
                raw.push("--deadline-ms".into());
                raw.push(d.to_string());
            }
            let a = Args::parse(raw, KEYS).map_err(|e| e.to_string())?;
            let from_cli = SearchRequest::from_args(&a).map_err(|e| e.to_string())?;
            let from_wire =
                SearchRequest::from_json_str(&from_cli.to_json()).map_err(|e| e.to_string())?;
            if from_cli != from_wire {
                return Err(format!("requests diverged: {from_cli:?} vs {from_wire:?}"));
            }
            let (pa, pb) = (
                from_cli.validate().map_err(|e| e.to_string())?,
                from_wire.validate().map_err(|e| e.to_string())?,
            );
            for backend in ["native", "pjrt"] {
                if pa.coalescing_key(backend) != pb.coalescing_key(backend) {
                    return Err(format!("coalescing keys diverged on {backend}"));
                }
                if wham::api::context_key(pa.fingerprint, pa.batch, &pa.opts, backend)
                    != wham::api::context_key(pb.fingerprint, pb.batch, &pb.opts, backend)
                {
                    return Err(format!("context keys diverged on {backend}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn args_and_json_requests_produce_equal_replies() {
    // Full-path parity for one representative request: run the search
    // from the CLI-built request and from its wire round-trip; apart from
    // wall-clock the replies must be identical documents.
    let cli_req =
        SearchRequest::from_args(&args(&["--model", "bert-base", "--k", "3"])).unwrap();
    let wire_req = SearchRequest::from_json_str(&cli_req.to_json()).unwrap();
    assert_eq!(cli_req, wire_req);

    let mut s1 = Session::with_backend(Box::new(NativeCost));
    let mut s2 = Session::with_backend(Box::new(NativeCost));
    let r1 = s1.search(&cli_req).unwrap();
    let r2 = s2.search(&wire_req).unwrap();
    assert_eq!(
        strip_wall(&parse(&r1.to_json()).unwrap()),
        strip_wall(&parse(&r2.to_json()).unwrap()),
        "equivalent requests must produce equal replies"
    );
}

#[test]
fn every_reply_type_round_trips_through_the_service() {
    let h = boot(2);

    let (status, body) = request(h.addr, "GET", "/models", None).unwrap();
    assert_eq!(status, 200);
    let models = ModelsReply::from_json_str(&body).unwrap();
    assert_eq!(models.models.len(), 11);

    // /search with a deadline: exercises the ProgressSink cancellation
    // path end-to-end and keeps the test fast.
    let req = SearchRequest::new("bert-base").deadline_ms(0);
    let (status, body) =
        request(h.addr, "POST", "/search", Some(&req.to_json())).unwrap();
    assert_eq!(status, 200, "body: {body}");
    let reply = SearchReply::from_json_str(&body).unwrap();
    assert!(reply.cancelled, "zero deadline must cancel");
    assert!(reply.dims_evaluated >= 1);
    assert_eq!(reply.model, "bert-base");

    let ev = EvaluateRequest::from_args(&args(&[
        "--model", "bert-base", "--dims", "128x128x128",
    ]))
    .unwrap();
    let (status, body) = request(h.addr, "POST", "/evaluate", Some(&ev.to_json())).unwrap();
    assert_eq!(status, 200, "body: {body}");
    let reply = EvaluateReply::from_json_str(&body).unwrap();
    assert_eq!(reply.config, ev.config);
    // Wire-compat: `config` stays the display string.
    let v = parse(&body).unwrap();
    assert_eq!(v.get("config").unwrap().as_str(), Some("<2, 128x128, 2, 128>"));

    let common = CommonRequest::new().models(["bert-base"]).top_k(2);
    let (status, body) = request(h.addr, "POST", "/common", Some(&common.to_json())).unwrap();
    assert_eq!(status, 200, "body: {body}");
    let reply = CommonReply::from_json_str(&body).unwrap();
    assert_eq!(reply.per_workload.len(), 1);
    assert!(reply.config.in_template());

    let (status, body) = request(h.addr, "GET", "/status", None).unwrap();
    assert_eq!(status, 200);
    let st = StatusReply::from_json_str(&body).unwrap();
    assert!(st.requests >= 4);
    assert_eq!(st.search.requests, 1, "only /search increments the search counter");
}

#[test]
fn non_ascii_model_names_stay_valid_json() {
    // Regression: the old /global emitted `format!("{:?}", names)`, which
    // Debug-escapes non-ASCII/control characters into Rust-style
    // `\u{..}` — invalid JSON. The typed layer escapes through `esc()`
    // everywhere, including error bodies.
    let h = boot(2);
    let weird = "gpt-модель-模型\u{7}";

    let body = GlobalRequest::new().models([weird]).to_json();
    let (status, resp) = request(h.addr, "POST", "/global", Some(&body)).unwrap();
    assert_eq!(status, 404, "unknown workload must 404: {resp}");
    let v = parse(&resp).unwrap_or_else(|e| panic!("response is not valid JSON ({e}): {resp}"));
    assert!(
        v.get("error").unwrap().as_str().unwrap().contains("模型"),
        "error must carry the name verbatim: {resp}"
    );

    let body = SearchRequest::new(weird).to_json();
    let (status, resp) = request(h.addr, "POST", "/search", Some(&body)).unwrap();
    assert_eq!(status, 404);
    let v = parse(&resp).unwrap_or_else(|e| panic!("response is not valid JSON ({e}): {resp}"));
    assert!(v.get("error").unwrap().as_str().unwrap().contains("модель"));
}

#[test]
fn evaluate_rejects_malformed_configs_and_misses_404() {
    let h = boot(2);

    // Non-numeric entry: used to be `unwrap_or(0)`-ed into a zero-core
    // design; must now be a 400.
    let (status, resp) = request(
        h.addr,
        "POST",
        "/evaluate",
        Some("{\"model\":\"bert-base\",\"config\":[2,\"x\",128,2,128]}"),
    )
    .unwrap();
    assert_eq!(status, 400, "body: {resp}");

    // Float entries are not silently truncated either.
    let (status, _) = request(
        h.addr,
        "POST",
        "/evaluate",
        Some("{\"model\":\"bert-base\",\"config\":[2,128.5,128,2,128]}"),
    )
    .unwrap();
    assert_eq!(status, 400);

    // Registry miss: 404, never a silent batch-1 search.
    let (status, _) = request(
        h.addr,
        "POST",
        "/evaluate",
        Some("{\"model\":\"no-such\",\"config\":[2,128,128,2,128]}"),
    )
    .unwrap();
    assert_eq!(status, 404);

    // Out-of-template configs are still rejected.
    let (status, _) = request(
        h.addr,
        "POST",
        "/evaluate",
        Some("{\"model\":\"bert-base\",\"config\":[2,7000,128,2,128]}"),
    )
    .unwrap();
    assert_eq!(status, 400);

    // Mistyped option on /search: strict accessors reject it.
    let (status, _) = request(
        h.addr,
        "POST",
        "/search",
        Some("{\"model\":\"bert-base\",\"k\":\"ten\"}"),
    )
    .unwrap();
    assert_eq!(status, 400);
}

#[test]
fn client_wire_bytes_parse_back_to_the_same_request() {
    // What `wham client` puts on the wire is exactly what the server's
    // codec produces for the same flags — golden round-trips per type.
    let s = SearchRequest::from_args(&args(&[
        "--model", "gnmt4", "--metric", "perf/tdp", "--k", "7", "--deadline-ms", "1500",
    ]))
    .unwrap();
    assert_eq!(SearchRequest::from_json_str(&s.to_json()).unwrap(), s);

    let e = EvaluateRequest::from_args(&args(&[
        "--model", "vgg16", "--dims", "64x32x16", "--tc", "8", "--vc", "1",
    ]))
    .unwrap();
    assert_eq!(EvaluateRequest::from_json_str(&e.to_json()).unwrap(), e);

    let c = CommonRequest::from_args(&args(&["--models", "bert-base,vgg16", "--k", "2"]))
        .unwrap();
    assert_eq!(CommonRequest::from_json_str(&c.to_json()).unwrap(), c);

    let g = GlobalRequest::from_args(&args(&[
        "--models", "opt-1.3b", "--depth", "16", "--tmp", "2", "--scheme", "1f1b",
    ]))
    .unwrap();
    assert_eq!(GlobalRequest::from_json_str(&g.to_json()).unwrap(), g);
    assert_eq!(g.scheme, wham::distributed::Scheme::PipeDream1F1B);
    assert_eq!(g.metric, Metric::Throughput);
}
