//! Integration tests of `wham::telemetry`: span nesting across scoped
//! threads, the Prometheus text exposition, `/metrics` vs `/status`
//! counter agreement on a live service, the Chrome-trace schema of a
//! smoke search, and outcome parity with tracing on vs off.
//!
//! The trace buffer, the enabled flag, and the metrics registry are
//! process-global; every test here serializes through [`GUARD`].

use std::net::TcpListener;
use std::sync::Mutex;

use wham::api::SearchRequest;
use wham::api::Session;
use wham::coordinator::BackendChoice;
use wham::cost::native::NativeCost;
use wham::service::http::request;
use wham::service::{start, ServeOptions, ServerHandle};
use wham::telemetry::{render_prometheus, trace, Collect, Sample};
use wham::util::json::{parse, JsonValue};

static GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // A panicking test must not wedge the rest of the suite.
    GUARD.lock().unwrap_or_else(|p| p.into_inner())
}

fn session() -> Session {
    Session::with_backend(Box::new(NativeCost))
}

#[test]
fn spans_nest_per_thread_under_scoped_threads() {
    let _g = lock();
    trace::reset();
    trace::enable();
    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(|| {
                let _outer = trace::span("outer_scoped").arg("who", "telemetry-test");
                assert_eq!(trace::depth(), 1);
                {
                    let _inner = trace::span("inner_scoped");
                    assert_eq!(trace::depth(), 2);
                }
                assert_eq!(trace::depth(), 1);
            });
        }
    });
    trace::disable();

    let v = parse(&trace::chrome_json()).unwrap();
    let events = v.as_arr().unwrap();
    assert_eq!(events.len(), 4, "two spans per thread, two threads");
    let named = |n: &str| -> Vec<&JsonValue> {
        events.iter().filter(|e| e.get("name").unwrap().as_str() == Some(n)).collect()
    };
    let outers = named("outer_scoped");
    let inners = named("inner_scoped");
    assert_eq!(outers.len(), 2);
    assert_eq!(inners.len(), 2);
    // Each thread serializes under its own tid, and the two threads'
    // stacks are independent.
    let tid = |e: &JsonValue| e.get("tid").unwrap().as_u64().unwrap();
    assert_ne!(tid(outers[0]), tid(outers[1]), "threads must get distinct tids");
    for inner in &inners {
        let outer = outers
            .iter()
            .find(|o| tid(o) == tid(inner))
            .expect("every inner span has an outer on its own tid");
        // Complete events: the inner opened after (and dropped before)
        // its outer, so it is recorded first and starts no earlier.
        let ts = |e: &JsonValue| e.get("ts").unwrap().as_u64().unwrap();
        assert!(ts(inner) >= ts(outer), "inner starts inside outer");
        assert_eq!(inner.get("args"), None, "no args were attached to inner");
        assert_eq!(
            outer.get("args").unwrap().get("who").unwrap().as_str(),
            Some("telemetry-test")
        );
    }
}

#[test]
fn prometheus_exposition_matches_golden_block() {
    let _g = lock();
    struct Golden;
    impl Collect for Golden {
        fn collect(&self, out: &mut Vec<Sample>) {
            out.push(Sample::Gauge {
                name: "wham_golden_hit_rate".into(),
                help: "Fraction of probes answered from cache.".into(),
                labels: vec![],
                value: 0.25,
            });
            out.push(Sample::Summary {
                name: "wham_golden_latency_ms".into(),
                help: "Request wall-clock.".into(),
                labels: vec![("endpoint".into(), "/search".into())],
                quantiles: vec![(0.5, 1.5), (0.95, 9.0)],
                count: 100,
            });
        }
    }
    let text = render_prometheus(&[&Golden]);
    // The scrape-time section renders contiguously after the registered
    // counters, so the whole block can be pinned verbatim.
    let golden = "# HELP wham_golden_hit_rate Fraction of probes answered from cache.\n\
                  # TYPE wham_golden_hit_rate gauge\n\
                  wham_golden_hit_rate 0.25\n\
                  # HELP wham_golden_latency_ms Request wall-clock.\n\
                  # TYPE wham_golden_latency_ms summary\n\
                  wham_golden_latency_ms{endpoint=\"/search\",quantile=\"0.5\"} 1.5\n\
                  wham_golden_latency_ms{endpoint=\"/search\",quantile=\"0.95\"} 9\n\
                  wham_golden_latency_ms_count{endpoint=\"/search\"} 100\n";
    assert!(text.contains(golden), "exposition:\n{text}");
    assert_no_duplicate_metric_names(&text);
}

/// Every metric name may carry exactly one `# TYPE` header.
fn assert_no_duplicate_metric_names(text: &str) {
    let mut names: Vec<&str> = text
        .lines()
        .filter_map(|l| l.strip_prefix("# TYPE "))
        .map(|l| l.split(' ').next().unwrap())
        .collect();
    let total = names.len();
    assert!(total > 0, "exposition must not be empty");
    names.sort_unstable();
    names.dedup();
    assert_eq!(total, names.len(), "duplicate metric names in exposition:\n{text}");
}

fn boot() -> ServerHandle {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    start(
        listener,
        ServeOptions {
            workers: 2,
            db_path: None,
            backend: BackendChoice::Native,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Value of an unlabeled metric in an exposition document.
fn metric_value(text: &str, name: &str) -> Option<f64> {
    text.lines().filter(|l| !l.starts_with('#')).find_map(|l| {
        let (n, v) = l.split_once(' ')?;
        (n == name).then(|| v.trim().parse().ok())?
    })
}

#[test]
fn metrics_scrape_agrees_with_status_counters() {
    let _g = lock();
    let h = boot();
    let (status, _) = request(h.addr, "POST", "/search", Some("{\"model\":\"bert-base\"}")).unwrap();
    assert_eq!(status, 200);

    // Drive one async job to its terminal state so the jobs block has a
    // non-zero, stable counter to compare against the scrape.
    let (status, sub) =
        request(h.addr, "POST", "/jobs", Some("{\"request\":{\"model\":\"alexnet\"}}")).unwrap();
    assert_eq!(status, 202, "{sub}");
    let id = parse(&sub).unwrap().get("id").unwrap().as_str().unwrap().to_string();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let (_, body) = request(h.addr, "GET", &format!("/jobs/{id}"), None).unwrap();
        let state = parse(&body).unwrap().get("state").unwrap().as_str().unwrap().to_string();
        if state != "queued" && state != "running" {
            assert_eq!(state, "done", "{body}");
            break;
        }
        assert!(std::time::Instant::now() < deadline, "job {id} stuck in {state:?}");
        std::thread::sleep(std::time::Duration::from_millis(25));
    }

    let (code, st) = request(h.addr, "GET", "/status", None).unwrap();
    assert_eq!(code, 200);
    let st = parse(&st).unwrap();
    let (code, text) = request(h.addr, "GET", "/metrics", None).unwrap();
    assert_eq!(code, 200);
    assert!(!text.is_empty());
    assert_no_duplicate_metric_names(&text);

    // Process-global counters: `/metrics` must report exactly what
    // `/status.perf` reported (nothing ran between the two scrapes —
    // GUARD serializes this binary, and the service is otherwise idle).
    let perf = st.get("perf").unwrap();
    for (metric, field) in [
        ("wham_backend_rows_total", "backend_rows_total"),
        ("wham_scheduler_evals_total", "scheduler_evals_total"),
        ("wham_cluster_sim_events_total", "cluster_sim_events_total"),
    ] {
        let scraped = metric_value(&text, metric)
            .unwrap_or_else(|| panic!("{metric} missing from exposition:\n{text}"));
        let reported = perf.get(field).unwrap().as_u64().unwrap() as f64;
        assert_eq!(scraped, reported, "{metric} vs perf.{field}");
    }
    // The jobs block mirrors the labeled `wham_jobs_*` series from the
    // same sources (only terminal-state and since-boot counters are
    // compared — nothing is queued or running at scrape time).
    let jobs = st.get("jobs").unwrap();
    for (metric, field) in [
        ("wham_jobs_total{state=\"done\"}", "done"),
        ("wham_jobs_total{state=\"failed\"}", "failed"),
        ("wham_jobs_total{state=\"cancelled\"}", "cancelled"),
        ("wham_jobs_queue_depth", "queue_depth"),
        ("wham_jobs_submitted_total", "submitted"),
        ("wham_jobs_rejected_total{reason=\"quota\"}", "rejected_quota"),
        ("wham_jobs_rejected_total{reason=\"queue_full\"}", "rejected_depth"),
        ("wham_jobs_retries_total", "retries"),
    ] {
        let scraped = metric_value(&text, metric)
            .unwrap_or_else(|| panic!("{metric} missing from exposition:\n{text}"));
        let reported = jobs.get(field).unwrap().as_u64().unwrap() as f64;
        assert_eq!(scraped, reported, "{metric} vs jobs.{field}");
    }
    assert_eq!(jobs.get("done").unwrap().as_u64(), Some(1), "the smoke job completed");

    // Instance-local: the /metrics request itself is the only request
    // after the /status snapshot, so the totals differ by exactly one.
    let reported_requests = st.get("requests").unwrap().as_u64().unwrap() as f64;
    assert_eq!(metric_value(&text, "wham_http_requests_total"), Some(reported_requests + 1.0));
    // The per-endpoint latency summaries ride along.
    assert!(
        text.contains("wham_http_request_duration_ms{endpoint=\"/search\",quantile=\"0.5\"}"),
        "missing /search latency summary:\n{text}"
    );
    // And the wire shape of /status itself is untouched by all of this:
    // the perf block still carries exactly its pre-telemetry fields.
    for field in
        ["backend_rows_total", "scheduler_evals_total", "cluster_sim_events_total", "db_hit_rate"]
    {
        assert!(perf.get(field).is_some(), "perf.{field} missing from /status");
    }
}

#[test]
fn smoke_search_trace_file_covers_the_span_taxonomy() {
    let _g = lock();
    trace::reset();
    trace::enable();
    let reply = session().search(&SearchRequest::new("bert-base")).unwrap();
    trace::disable();
    assert!(reply.scheduler_evals > 0, "smoke search must be cold");

    let path = std::env::temp_dir()
        .join(format!("wham-telemetry-smoke-{}.json", std::process::id()));
    trace::write_to(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    let v = parse(&text).unwrap();
    let events = v.as_arr().expect("chrome trace is a top-level array");
    assert!(!events.is_empty());
    for e in events {
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"), "complete events only: {e:?}");
        assert_eq!(e.get("cat").unwrap().as_str(), Some("wham"));
        assert_eq!(e.get("pid").unwrap().as_u64(), Some(1));
        assert!(e.get("tid").unwrap().as_u64().unwrap() >= 1);
        assert!(e.get("name").unwrap().as_str().is_some());
        assert!(e.get("ts").unwrap().as_u64().is_some());
        assert!(e.get("dur").unwrap().as_u64().is_some());
    }
    let names: Vec<&str> =
        events.iter().filter_map(|e| e.get("name").unwrap().as_str()).collect();
    for required in ["annotate", "schedule", "mcr", "mcr_probe", "prune_batch", "search_phase"] {
        assert!(
            names.contains(&required),
            "span {required:?} missing from smoke-search trace; saw {names:?}"
        );
    }
}

#[test]
fn tracing_does_not_change_search_outcomes() {
    let _g = lock();
    trace::disable();
    let off = session().search(&SearchRequest::new("resnet18")).unwrap();
    trace::reset();
    trace::enable();
    let on = session().search(&SearchRequest::new("resnet18")).unwrap();
    trace::disable();
    assert!(trace::event_count() > 0, "enabled run must have recorded spans");
    assert_eq!(off.best.config.display(), on.best.config.display());
    assert_eq!(off.best.score, on.best.score, "tracing must not perturb scores");
    assert_eq!(off.dims_evaluated, on.dims_evaluated);
    assert_eq!(off.scheduler_evals, on.scheduler_evals);
}
