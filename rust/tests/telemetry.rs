//! Integration tests of `wham::telemetry`: span nesting across scoped
//! threads, the Prometheus text exposition, `/metrics` vs `/status`
//! counter agreement on a live service, the Chrome-trace schema of a
//! smoke search, and outcome parity with tracing on vs off.
//!
//! The trace buffer, the enabled flag, and the metrics registry are
//! process-global; every test here serializes through [`GUARD`].

use std::net::TcpListener;
use std::sync::Mutex;

use wham::api::SearchRequest;
use wham::api::Session;
use wham::coordinator::BackendChoice;
use wham::cost::native::NativeCost;
use wham::service::http::{request, request_full, request_stream};
use wham::service::{start, ServeOptions, ServerHandle};
use wham::telemetry::log;
use wham::telemetry::{render_prometheus, trace, Collect, Sample};
use wham::util::json::{parse, JsonValue};

static GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // A panicking test must not wedge the rest of the suite.
    GUARD.lock().unwrap_or_else(|p| p.into_inner())
}

fn session() -> Session {
    Session::with_backend(Box::new(NativeCost))
}

#[test]
fn spans_nest_per_thread_under_scoped_threads() {
    let _g = lock();
    trace::reset();
    trace::enable();
    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(|| {
                let _outer = trace::span("outer_scoped").arg("who", "telemetry-test");
                assert_eq!(trace::depth(), 1);
                {
                    let _inner = trace::span("inner_scoped");
                    assert_eq!(trace::depth(), 2);
                }
                assert_eq!(trace::depth(), 1);
            });
        }
    });
    trace::disable();

    let v = parse(&trace::chrome_json()).unwrap();
    let events = v.as_arr().unwrap();
    assert_eq!(events.len(), 4, "two spans per thread, two threads");
    let named = |n: &str| -> Vec<&JsonValue> {
        events.iter().filter(|e| e.get("name").unwrap().as_str() == Some(n)).collect()
    };
    let outers = named("outer_scoped");
    let inners = named("inner_scoped");
    assert_eq!(outers.len(), 2);
    assert_eq!(inners.len(), 2);
    // Each thread serializes under its own tid, and the two threads'
    // stacks are independent.
    let tid = |e: &JsonValue| e.get("tid").unwrap().as_u64().unwrap();
    assert_ne!(tid(outers[0]), tid(outers[1]), "threads must get distinct tids");
    for inner in &inners {
        let outer = outers
            .iter()
            .find(|o| tid(o) == tid(inner))
            .expect("every inner span has an outer on its own tid");
        // Complete events: the inner opened after (and dropped before)
        // its outer, so it is recorded first and starts no earlier.
        let ts = |e: &JsonValue| e.get("ts").unwrap().as_u64().unwrap();
        assert!(ts(inner) >= ts(outer), "inner starts inside outer");
        assert_eq!(inner.get("args"), None, "no args were attached to inner");
        assert_eq!(
            outer.get("args").unwrap().get("who").unwrap().as_str(),
            Some("telemetry-test")
        );
    }
}

#[test]
fn prometheus_exposition_matches_golden_block() {
    let _g = lock();
    struct Golden;
    impl Collect for Golden {
        fn collect(&self, out: &mut Vec<Sample>) {
            out.push(Sample::Gauge {
                name: "wham_golden_hit_rate".into(),
                help: "Fraction of probes answered from cache.".into(),
                labels: vec![],
                value: 0.25,
            });
            out.push(Sample::Summary {
                name: "wham_golden_latency_ms".into(),
                help: "Request wall-clock.".into(),
                labels: vec![("endpoint".into(), "/search".into())],
                quantiles: vec![(0.5, 1.5), (0.95, 9.0)],
                count: 100,
            });
        }
    }
    let text = render_prometheus(&[&Golden]);
    // The scrape-time section renders contiguously after the registered
    // counters, so the whole block can be pinned verbatim.
    let golden = "# HELP wham_golden_hit_rate Fraction of probes answered from cache.\n\
                  # TYPE wham_golden_hit_rate gauge\n\
                  wham_golden_hit_rate 0.25\n\
                  # HELP wham_golden_latency_ms Request wall-clock.\n\
                  # TYPE wham_golden_latency_ms summary\n\
                  wham_golden_latency_ms{endpoint=\"/search\",quantile=\"0.5\"} 1.5\n\
                  wham_golden_latency_ms{endpoint=\"/search\",quantile=\"0.95\"} 9\n\
                  wham_golden_latency_ms_count{endpoint=\"/search\"} 100\n";
    assert!(text.contains(golden), "exposition:\n{text}");
    assert_no_duplicate_metric_names(&text);
}

/// Every metric name may carry exactly one `# TYPE` header.
fn assert_no_duplicate_metric_names(text: &str) {
    let mut names: Vec<&str> = text
        .lines()
        .filter_map(|l| l.strip_prefix("# TYPE "))
        .map(|l| l.split(' ').next().unwrap())
        .collect();
    let total = names.len();
    assert!(total > 0, "exposition must not be empty");
    names.sort_unstable();
    names.dedup();
    assert_eq!(total, names.len(), "duplicate metric names in exposition:\n{text}");
}

fn boot() -> ServerHandle {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    start(
        listener,
        ServeOptions {
            workers: 2,
            db_path: None,
            backend: BackendChoice::Native,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Value of an unlabeled metric in an exposition document.
fn metric_value(text: &str, name: &str) -> Option<f64> {
    text.lines().filter(|l| !l.starts_with('#')).find_map(|l| {
        let (n, v) = l.split_once(' ')?;
        (n == name).then(|| v.trim().parse().ok())?
    })
}

#[test]
fn metrics_scrape_agrees_with_status_counters() {
    let _g = lock();
    let h = boot();
    let (status, _) = request(h.addr, "POST", "/search", Some("{\"model\":\"bert-base\"}")).unwrap();
    assert_eq!(status, 200);

    // Drive one async job to its terminal state so the jobs block has a
    // non-zero, stable counter to compare against the scrape.
    let (status, sub) =
        request(h.addr, "POST", "/jobs", Some("{\"request\":{\"model\":\"alexnet\"}}")).unwrap();
    assert_eq!(status, 202, "{sub}");
    let id = parse(&sub).unwrap().get("id").unwrap().as_str().unwrap().to_string();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let (_, body) = request(h.addr, "GET", &format!("/jobs/{id}"), None).unwrap();
        let state = parse(&body).unwrap().get("state").unwrap().as_str().unwrap().to_string();
        if state != "queued" && state != "running" {
            assert_eq!(state, "done", "{body}");
            break;
        }
        assert!(std::time::Instant::now() < deadline, "job {id} stuck in {state:?}");
        std::thread::sleep(std::time::Duration::from_millis(25));
    }

    let (code, st) = request(h.addr, "GET", "/status", None).unwrap();
    assert_eq!(code, 200);
    let st = parse(&st).unwrap();
    let (code, text) = request(h.addr, "GET", "/metrics", None).unwrap();
    assert_eq!(code, 200);
    assert!(!text.is_empty());
    assert_no_duplicate_metric_names(&text);

    // Process-global counters: `/metrics` must report exactly what
    // `/status.perf` reported (nothing ran between the two scrapes —
    // GUARD serializes this binary, and the service is otherwise idle).
    let perf = st.get("perf").unwrap();
    for (metric, field) in [
        ("wham_backend_rows_total", "backend_rows_total"),
        ("wham_scheduler_evals_total", "scheduler_evals_total"),
        ("wham_cluster_sim_events_total", "cluster_sim_events_total"),
    ] {
        let scraped = metric_value(&text, metric)
            .unwrap_or_else(|| panic!("{metric} missing from exposition:\n{text}"));
        let reported = perf.get(field).unwrap().as_u64().unwrap() as f64;
        assert_eq!(scraped, reported, "{metric} vs perf.{field}");
    }
    // The jobs block mirrors the labeled `wham_jobs_*` series from the
    // same sources (only terminal-state and since-boot counters are
    // compared — nothing is queued or running at scrape time).
    let jobs = st.get("jobs").unwrap();
    for (metric, field) in [
        ("wham_jobs_total{state=\"done\"}", "done"),
        ("wham_jobs_total{state=\"failed\"}", "failed"),
        ("wham_jobs_total{state=\"cancelled\"}", "cancelled"),
        ("wham_jobs_queue_depth", "queue_depth"),
        ("wham_jobs_submitted_total", "submitted"),
        ("wham_jobs_rejected_total{reason=\"quota\"}", "rejected_quota"),
        ("wham_jobs_rejected_total{reason=\"queue_full\"}", "rejected_depth"),
        ("wham_jobs_retries_total", "retries"),
    ] {
        let scraped = metric_value(&text, metric)
            .unwrap_or_else(|| panic!("{metric} missing from exposition:\n{text}"));
        let reported = jobs.get(field).unwrap().as_u64().unwrap() as f64;
        assert_eq!(scraped, reported, "{metric} vs jobs.{field}");
    }
    assert_eq!(jobs.get("done").unwrap().as_u64(), Some(1), "the smoke job completed");

    // Instance-local: the /metrics request itself is the only request
    // after the /status snapshot, so the totals differ by exactly one.
    let reported_requests = st.get("requests").unwrap().as_u64().unwrap() as f64;
    assert_eq!(metric_value(&text, "wham_http_requests_total"), Some(reported_requests + 1.0));
    // The per-endpoint latency summaries ride along.
    assert!(
        text.contains("wham_http_request_duration_ms{endpoint=\"/search\",quantile=\"0.5\"}"),
        "missing /search latency summary:\n{text}"
    );
    // Bucketed histograms ride the same scrape: the search populated the
    // scheduler-eval and MCR-probe families, the job its queue wait, and
    // the requests themselves the per-endpoint latency buckets.
    let mut hist_families: Vec<&str> = text
        .lines()
        .filter_map(|l| l.strip_prefix("# TYPE "))
        .filter_map(|l| {
            let mut it = l.split(' ');
            let name = it.next()?;
            (it.next()? == "histogram").then_some(name)
        })
        .collect();
    hist_families.sort_unstable();
    hist_families.dedup();
    assert!(
        hist_families.len() >= 3,
        "want >=3 histogram families, got {hist_families:?}:\n{text}"
    );
    for required in [
        "wham_scheduler_eval_duration_seconds",
        "wham_job_queue_wait_seconds",
        "wham_http_request_duration_seconds",
    ] {
        assert!(hist_families.contains(&required), "{required} missing: {hist_families:?}");
    }
    assert!(
        text.contains("wham_http_request_duration_seconds_bucket{endpoint=\"/search\",le="),
        "missing /search latency buckets:\n{text}"
    );
    // The trace-buffer and flight-recorder gauges are always present.
    for gauge in
        ["wham_trace_buffer_events", "wham_trace_buffer_occupancy", "wham_flight_recorder_last_records"]
    {
        assert!(text.contains(&format!("# TYPE {gauge} gauge")), "{gauge} missing:\n{text}");
    }
    // And the wire shape of /status itself is untouched by all of this:
    // the perf block still carries exactly its pre-telemetry fields.
    for field in
        ["backend_rows_total", "scheduler_evals_total", "cluster_sim_events_total", "db_hit_rate"]
    {
        assert!(perf.get(field).is_some(), "perf.{field} missing from /status");
    }
}

#[test]
fn smoke_search_trace_file_covers_the_span_taxonomy() {
    let _g = lock();
    trace::reset();
    trace::enable();
    let reply = session().search(&SearchRequest::new("bert-base")).unwrap();
    trace::disable();
    assert!(reply.scheduler_evals > 0, "smoke search must be cold");

    let path = std::env::temp_dir()
        .join(format!("wham-telemetry-smoke-{}.json", std::process::id()));
    trace::write_to(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    let v = parse(&text).unwrap();
    let events = v.as_arr().expect("chrome trace is a top-level array");
    assert!(!events.is_empty());
    for e in events {
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"), "complete events only: {e:?}");
        assert_eq!(e.get("cat").unwrap().as_str(), Some("wham"));
        assert_eq!(e.get("pid").unwrap().as_u64(), Some(1));
        assert!(e.get("tid").unwrap().as_u64().unwrap() >= 1);
        assert!(e.get("name").unwrap().as_str().is_some());
        assert!(e.get("ts").unwrap().as_u64().is_some());
        assert!(e.get("dur").unwrap().as_u64().is_some());
    }
    let names: Vec<&str> =
        events.iter().filter_map(|e| e.get("name").unwrap().as_str()).collect();
    for required in ["annotate", "schedule", "mcr", "mcr_probe", "prune_batch", "search_phase"] {
        assert!(
            names.contains(&required),
            "span {required:?} missing from smoke-search trace; saw {names:?}"
        );
    }
}

#[test]
fn profiler_samples_a_cold_search() {
    let _g = lock();
    let sampler = wham::telemetry::profile::attach(1000).expect("no other sampler is attached");
    // Fresh sessions have empty eval caches, so each search is real
    // scheduler work for the sampler to observe.
    for model in ["bert-base", "resnet18", "alexnet"] {
        session().search(&SearchRequest::new(model)).unwrap();
    }
    let p = sampler.stop();
    assert!(p.samples > 0, "sampler thread never woke");
    assert!(p.weight() > 0, "sampler observed no span stacks");
    let collapsed = p.collapsed();
    assert!(
        ["schedule", "mcr", "annotate", "search_phase", "prune_batch"]
            .iter()
            .any(|n| collapsed.contains(n)),
        "no search span in the profile:\n{collapsed}"
    );
    // Every collapsed line is `path;leaf N`.
    for line in collapsed.lines() {
        let (_, n) = line.rsplit_once(' ').expect("line has a weight");
        n.parse::<u64>().unwrap_or_else(|_| panic!("bad weight in {line:?}"));
    }
    // The top-k table agrees with the trie weights.
    assert!(!p.top_paths(10).is_empty());
}

#[test]
fn profile_endpoint_returns_collapsed_stacks_while_searching() {
    let _g = lock();
    let h = boot();
    // Keep cold searches running in-process while the endpoint samples —
    // the profiler is process-wide, so it sees these threads too.
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop2 = std::sync::Arc::clone(&stop);
    let bg = std::thread::spawn(move || {
        while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
            let _ = session().search(&SearchRequest::new("bert-base"));
        }
    });
    let (code, body) = request(h.addr, "GET", "/profile?seconds=1&hz=500", None).unwrap();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    bg.join().unwrap();
    assert_eq!(code, 200, "{body}");
    assert!(
        body.lines().any(|l| l.rsplit_once(' ').is_some_and(|(_, n)| n.parse::<u64>().is_ok())),
        "no collapsed stacks in /profile response:\n{body}"
    );
    // Bad parameters are rejected, not clamped silently.
    let (code, msg) = request(h.addr, "GET", "/profile?seconds=99", None).unwrap();
    assert_eq!(code, 400, "{msg}");
}

#[test]
fn correlation_id_round_trips_header_body_sse_wal_and_logs() {
    let _g = lock();
    let wal =
        std::env::temp_dir().join(format!("wham-telemetry-corr-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&wal);
    let buf = log::capture();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let h = start(
        listener,
        ServeOptions {
            workers: 2,
            db_path: None,
            backend: BackendChoice::Native,
            jobs_path: Some(wal.clone()),
            ..Default::default()
        },
    )
    .unwrap();

    let (status, headers, body) =
        request_full(h.addr, "POST", "/jobs", Some("{\"request\":{\"model\":\"alexnet\"}}"))
            .unwrap();
    assert_eq!(status, 202, "{body}");
    let corr = headers
        .iter()
        .find(|(k, _)| k == "x-wham-request-id")
        .map(|(_, v)| v.clone())
        .expect("every response carries X-Wham-Request-Id");
    assert!(corr.starts_with("r-"), "unexpected id shape: {corr}");
    let v = parse(&body).unwrap();
    assert_eq!(v.get("corr").unwrap().as_str(), Some(corr.as_str()), "{body}");
    let id = v.get("id").unwrap().as_str().unwrap().to_string();
    let tag = format!("\"corr\":\"{corr}\"");

    // The SSE stream tags its frames with the same id (the server closes
    // the stream after the job's terminal frame).
    let mut frames = String::new();
    let code = request_stream(h.addr, "GET", &format!("/jobs/{id}/events"), None, |line| {
        frames.push_str(line);
        frames.push('\n');
        true
    })
    .unwrap();
    assert_eq!(code, 200);
    assert!(frames.contains(&tag), "SSE frames untagged:\n{frames}");

    // The WAL's submitted event persists it for replay.
    let wal_text = std::fs::read_to_string(&wal).unwrap();
    let _ = std::fs::remove_file(&wal);
    assert!(
        wal_text.lines().any(|l| l.contains(&id) && l.contains(&tag)),
        "WAL submit line missing corr:\n{wal_text}"
    );

    // And one grep over the structured logs connects the access-log line
    // with the job lifecycle under that id.
    let logged = buf.lock().unwrap().clone();
    log::to_stderr();
    assert!(
        logged.lines().any(|l| l.contains(&tag) && l.contains("\"msg\":\"request\"")),
        "access log untagged:\n{logged}"
    );
    assert!(
        logged.lines().any(|l| l.contains(&tag) && l.contains("job submitted")),
        "job-submit log untagged:\n{logged}"
    );
}

#[test]
fn log_level_threshold_filters_integration_records() {
    let _g = lock();
    let buf = log::capture();
    log::set_level(log::Level::Warn);
    log::info("itest", "filtered info", &[]);
    log::warn("itest", "kept warn", &[("code", &7u64)]);
    log::set_level(log::Level::Info);
    let text = buf.lock().unwrap().clone();
    log::to_stderr();
    assert!(!text.contains("filtered info"), "{text}");
    let line = text.lines().find(|l| l.contains("kept warn")).expect("warn line present");
    let v = parse(line).unwrap();
    assert_eq!(v.get("level").unwrap().as_str(), Some("warn"));
    assert_eq!(v.get("target").unwrap().as_str(), Some("itest"));
    assert_eq!(v.get("code").unwrap().as_str(), Some("7"));
}

#[test]
fn tracing_does_not_change_search_outcomes() {
    let _g = lock();
    trace::disable();
    let off = session().search(&SearchRequest::new("resnet18")).unwrap();
    trace::reset();
    trace::enable();
    let on = session().search(&SearchRequest::new("resnet18")).unwrap();
    trace::disable();
    assert!(trace::event_count() > 0, "enabled run must have recorded spans");
    assert_eq!(off.best.config.display(), on.best.config.display());
    assert_eq!(off.best.score, on.best.score, "tracing must not perturb scores");
    assert_eq!(off.dims_evaluated, on.dims_evaluated);
    assert_eq!(off.scheduler_evals, on.scheduler_evals);
}
