//! Three-layer stack contract: the AOT Pallas/JAX artifact executed via
//! PJRT must agree with the native rust mirror to <= 1e-3 relative on
//! every operator of every workload. This is the rust half of the
//! correctness chain (the python half pins the Pallas kernel to the jnp
//! oracle).

use wham::cost::native::NativeCost;
use wham::cost::xla_rt::XlaCost;
use wham::cost::{CostBackend, Dims};
use wham::graph::autodiff::Optimizer;
use wham::graph::CostRow;
use wham::util::rng::Rng;

fn pjrt() -> Option<XlaCost> {
    match XlaCost::from_artifacts() {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("skipping PJRT tests: {e}");
            None
        }
    }
}

fn assert_agree(rows: &[CostRow], dims: Dims, pjrt: &mut XlaCost) {
    let native = NativeCost.evaluate(rows, dims);
    let xla = pjrt.evaluate(rows, dims);
    assert_eq!(native.len(), xla.len());
    for (i, (n, x)) in native.iter().zip(&xla).enumerate() {
        let rel = |a: f64, b: f64| {
            if a == 0.0 && b == 0.0 {
                0.0
            } else {
                (a - b).abs() / a.abs().max(b.abs())
            }
        };
        assert!(
            rel(n.latency, x.latency) < 1e-3,
            "row {i} {:?}: latency native={} pjrt={}",
            rows[i],
            n.latency,
            x.latency
        );
        assert!(
            rel(n.energy, x.energy) < 1e-3,
            "row {i} {:?}: energy native={} pjrt={}",
            rows[i],
            n.energy,
            x.energy
        );
        assert!(
            rel(n.util, x.util) < 1e-3,
            "row {i} {:?}: util native={} pjrt={}",
            rows[i],
            n.util,
            x.util
        );
    }
}

#[test]
fn agree_on_random_rows() {
    let Some(mut x) = pjrt() else { return };
    let mut rng = Rng::new(0xABCD);
    let dims_menu = [4u64, 8, 16, 32, 64, 128, 256];
    for trial in 0..10 {
        let rows: Vec<CostRow> = (0..200)
            .map(|_| CostRow {
                kind: rng.range(0, 2) as i32,
                m: rng.range(1, 100_000) as u64,
                n: rng.range(1, 8_192) as u64,
                k: rng.range(1, 8_192) as u64,
            })
            .collect();
        let d = Dims {
            tc_x: *rng.choose(&dims_menu),
            tc_y: *rng.choose(&dims_menu),
            vc_w: *rng.choose(&dims_menu),
        };
        assert_agree(&rows, d, &mut x);
        let _ = trial;
    }
}

#[test]
fn agree_on_every_workload_graph() {
    let Some(mut x) = pjrt() else { return };
    for name in wham::models::single_acc_models() {
        let g = wham::models::training(name, Optimizer::Adam).unwrap();
        let rows = g.cost_rows();
        assert_agree(&rows, Dims { tc_x: 128, tc_y: 64, vc_w: 128 }, &mut x);
    }
}

#[test]
fn agree_beyond_one_chunk() {
    // > 4096 rows exercises the chunked PJRT path.
    let Some(mut x) = pjrt() else { return };
    let rows: Vec<CostRow> = (0..9_000)
        .map(|i| CostRow { kind: (i % 3) as i32, m: 64 + (i as u64 % 1000), n: 64, k: 64 })
        .collect();
    assert_agree(&rows, Dims { tc_x: 64, tc_y: 64, vc_w: 64 }, &mut x);
}

#[test]
fn search_results_identical_across_backends() {
    let Some(mut x) = pjrt() else { return };
    let g = wham::models::training("bert-base", Optimizer::Adam).unwrap();
    let opts = wham::search::engine::SearchOptions::default();
    let rn = wham::search::engine::WhamSearch::new(&g, 4, opts).run(&mut NativeCost);
    let rx = wham::search::engine::WhamSearch::new(&g, 4, opts).run(&mut x);
    assert_eq!(rn.best.config, rx.best.config, "search must pick the same design");
    let rel = (rn.best.eval.seconds - rx.best.eval.seconds).abs() / rn.best.eval.seconds;
    assert!(rel < 1e-3);
}
