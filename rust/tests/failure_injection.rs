//! Failure-injection tests: the system must fail loudly and informatively
//! on corrupted artifacts, bad inputs, and impossible constraints —
//! never silently produce wrong results.

use std::io::Write;

use wham::arch::{ArchConfig, Constraints};
use wham::cost::annotate::AnnotatedGraph;
use wham::cost::native::NativeCost;
use wham::cost::Dims;
use wham::graph::autodiff::Optimizer;
use wham::runtime::pjrt::CostModelRuntime;
use wham::search::mcr::mcr;

#[test]
fn corrupted_hlo_artifact_is_rejected() {
    let dir = std::env::temp_dir().join(format!("wham-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut f = std::fs::File::create(dir.join("cost_model.hlo.txt")).unwrap();
    writeln!(f, "HloModule garbage {{ this is not hlo }}").unwrap();
    std::fs::write(dir.join("cost_model.meta"), "n_ops=4096\n").unwrap();
    let err = CostModelRuntime::load(&dir);
    assert!(err.is_err(), "corrupted HLO must fail to parse/compile");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn missing_artifact_reports_make_hint() {
    let dir = std::env::temp_dir().join(format!("wham-empty-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let err = CostModelRuntime::load(&dir).unwrap_err();
    assert!(format!("{err}").contains("make artifacts"), "error must tell the user what to run");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stale_meta_n_ops_is_rejected() {
    // Copy the real HLO (if present) but lie about n_ops.
    let Some(real) = wham::runtime::artifacts_dir() else { return };
    let dir = std::env::temp_dir().join(format!("wham-stale-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::copy(real.join("cost_model.hlo.txt"), dir.join("cost_model.hlo.txt")).unwrap();
    std::fs::write(dir.join("cost_model.meta"), "n_ops=1234\n").unwrap();
    let err = CostModelRuntime::load(&dir).unwrap_err();
    assert!(format!("{err}").contains("rebuild artifacts"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unknown_model_is_a_clean_none() {
    assert!(wham::models::forward("resnet-9000").is_none());
    assert!(wham::models::training("resnet-9000", Optimizer::Adam).is_none());
}

#[test]
fn impossible_constraints_still_return_a_design() {
    // Constraints tighter than even the smallest config: MCR must stop at
    // <1, 1> rather than crash or loop.
    let g = wham::models::training("resnet18", Optimizer::SgdMomentum).unwrap();
    let ann = AnnotatedGraph::new(&g, Dims { tc_x: 4, tc_y: 4, vc_w: 4 }, &mut NativeCost);
    let impossible = Constraints { max_area_mm2: 0.001, max_power_w: 0.001 };
    let out = mcr(&ann, &impossible);
    assert_eq!((out.cores.tc, out.cores.vc), (1, 1));
}

#[test]
#[should_panic(expected = "cycle")]
fn cyclic_graph_panics_in_topo_order() {
    let mut b = wham::graph::GraphBuilder::new();
    let a = b.gemm("a", 8, 8, 8, &[]);
    let c = b.gemm("c", 8, 8, 8, &[a]);
    let mut g = b.finish();
    g.add_edge(c, a);
    let _ = g.topo_order();
}

#[test]
fn zero_dim_ops_fail_validation() {
    let mut b = wham::graph::GraphBuilder::new();
    b.gemm("bad", 1, 1, 0, &[]);
    assert!(wham::graph::validate::validate(&b.finish()).is_err());
}

#[test]
fn oversized_dims_fail_validation() {
    let mut b = wham::graph::GraphBuilder::new();
    b.eltwise("huge", (i32::MAX as u64) + 10, 1, &[]);
    assert!(
        wham::graph::validate::validate(&b.finish()).is_err(),
        "dims beyond the i32 cost-model contract must be rejected"
    );
}

#[test]
fn out_of_template_config_is_flagged() {
    let c = ArchConfig { num_tc: 0, tc_x: 128, tc_y: 128, num_vc: 1, vc_w: 128 };
    assert!(!c.in_template());
}

#[test]
fn cli_rejects_bad_values() {
    use wham::util::cli::Args;
    let a = Args::parse(["--k=notanumber".to_string()], &[]).unwrap();
    assert!(a.get_as::<usize>("k").is_err());
    assert!(Args::parse(["--model".to_string()], &["model"]).is_err());
}
