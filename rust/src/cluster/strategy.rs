//! Parallelism-strategy auto-sweep.
//!
//! The `wham global` flow fixes the (pp, tp) degrees up front and mines
//! hardware for that one placement. This module closes the loop the
//! other way: given a transformer workload, a device budget, and a
//! topology, it enumerates every feasible `(pp, tp, dp, microbatching,
//! schedule)` split — pipeline depth dividing the device count and
//! bounded by the layer count, TMP degrees that divide the attention
//! heads and hidden width, data-parallel replicas filling the rest —
//! screens each candidate with the discrete-event simulator
//! ([`crate::cluster::event_sim`]) on a reference accelerator, then
//! drives the existing [`global_search`] hardware miner over the top
//! screened strategies (fanning per-stage local searches out via the
//! `--jobs` machinery) and re-simulates the mined designs. The result
//! is a [`StrategyReport`]: strategies ranked by simulated cluster
//! metric, with the fixed-`(pp, tp)` baseline called out so the win is
//! visible.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::event_sim::{
    rank_footprint_bytes, simulate_events, simulate_events_recorded, Placement, SimResult,
    SimSchedule,
};
use super::topology::{AllReduceAlgo, Topology};
use crate::api::progress::{Progress, ProgressSink};
use crate::arch::{presets, ArchConfig, HBM_BYTES};
use crate::cost::CostBackend;
use crate::distributed::global_search::{
    global_search_observed, stage_signatures, GlobalOptions,
};
use crate::distributed::network::Network;
use crate::distributed::partition::{partition_transformer, PartitionedModel};
use crate::distributed::pipeline::{stage_compute_times, StageTimes};
use crate::distributed::Scheme;
use crate::graph::autodiff::Optimizer;
use crate::metrics::Metric;
use crate::models::transformer::TransformerCfg;
use crate::search::engine::{CacheProvider, SearchOptions};

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// Total accelerators in the cluster.
    pub devices: u64,
    /// Topology preset name ([`Topology::preset`]).
    pub topology: String,
    /// Schedules to consider (`"gpipe"`, `"1f1b"`, `"interleaved"`);
    /// empty means all three.
    pub schedules: Vec<String>,
    pub metric: Metric,
    /// Screened strategies to mine hardware for with the global search
    /// (0 = screening only, reference accelerator throughout).
    pub mine_top: usize,
    /// Virtual chunks per device for interleaved-1F1B candidates.
    pub chunks: u64,
    /// Per-stage local-search options for the mining phase.
    pub local: SearchOptions,
    /// Worker threads for the mining phase's per-stage local searches.
    pub jobs: usize,
    /// Non-overlappable fraction of the DP gradient all-reduce.
    pub dp_exposed: f64,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            devices: 8,
            topology: "flat".to_string(),
            schedules: Vec::new(),
            metric: Metric::Throughput,
            mine_top: 2,
            chunks: 2,
            local: SearchOptions::default(),
            jobs: 1,
            dp_exposed: 0.3,
        }
    }
}

/// One evaluated `(pp, tp, dp, schedule)` strategy.
#[derive(Debug, Clone)]
pub struct StrategyPoint {
    /// Pipeline-parallel degree (devices along the pipeline).
    pub pp: u64,
    /// Tensor-model-parallel degree (devices per stage).
    pub tp: u64,
    /// Data-parallel replicas.
    pub dp: u64,
    /// Virtual chunks per device (1 unless interleaved).
    pub chunks: u64,
    /// Schedule name (`gpipe` | `1f1b` | `interleaved`).
    pub schedule: String,
    pub micro_batch: u64,
    pub num_micro: u64,
    /// Accelerator config the numbers below were simulated with.
    pub config: ArchConfig,
    /// True when `config` came from the global hardware search rather
    /// than the reference screening accelerator.
    pub mined: bool,
    /// Simulated iteration seconds (including the exposed DP
    /// all-reduce share).
    pub iter_seconds: f64,
    /// Aggregate samples/second across all replicas.
    pub throughput: f64,
    pub perf_per_tdp: f64,
    /// Pipeline bubble fraction from the event simulator.
    pub bubble_fraction: f64,
    /// Every rank's peak footprint fits HBM under this schedule.
    pub fits_hbm: bool,
    /// Ranking score under the sweep metric.
    pub score: f64,
}

/// Ranked outcome of one sweep.
#[derive(Debug, Clone)]
pub struct StrategyReport {
    pub model: String,
    pub devices: u64,
    pub topology: String,
    pub metric: Metric,
    /// Strategies screened (== `ranked.len()`; fewer than enumerated
    /// only when the sweep was cancelled mid-screening).
    pub candidates: usize,
    /// Strategies the mining phase actually upgraded with searched
    /// hardware (mined configs that lost to the screen don't count).
    pub mined: usize,
    /// The fixed-(pp, tp) reference: deepest enumerated pipeline,
    /// tp = 1 — what `wham global` would evaluate with its defaults.
    pub baseline: StrategyPoint,
    /// All evaluated strategies, best score first.
    pub ranked: Vec<StrategyPoint>,
    /// True when the sink cancelled the sweep (report holds the
    /// strategies evaluated so far).
    pub cancelled: bool,
    pub wall: Duration,
}

fn divisors(n: u64) -> Vec<u64> {
    (1..=n).filter(|d| n % d == 0).collect()
}

/// Schedule names accepted by the sweep and the cluster API.
pub fn schedule_names() -> &'static [&'static str] {
    &["gpipe", "1f1b", "interleaved"]
}

/// Whether `cfg` admits at least one strategy on `devices` accelerators
/// under `schedules`/`chunks` — the API layer rejects empty spaces as
/// caller errors (400) before a worker ever runs the sweep.
pub fn has_feasible_strategy(
    cfg: &TransformerCfg,
    devices: u64,
    schedules: &[String],
    chunks: u64,
) -> bool {
    !enumerate(cfg, devices, schedules, chunks.max(1)).is_empty()
}

struct Candidate {
    pp: u64,
    tp: u64,
    dp: u64,
    chunks: u64,
    schedule: SimSchedule,
    name: &'static str,
}

/// Enumerate the feasible strategy space for `cfg` on `devices`
/// accelerators (pp | devices, pp <= layers, tp | heads and hidden,
/// interleaved only when the virtual depth stays within the layer
/// budget and the microbatch count divides evenly).
fn enumerate(cfg: &TransformerCfg, devices: u64, schedules: &[String], chunks: u64) -> Vec<Candidate> {
    let want =
        |name: &str| schedules.is_empty() || schedules.iter().any(|s| s.as_str() == name);
    let mut out = Vec::new();
    for pp in divisors(devices) {
        if pp > cfg.layers {
            continue;
        }
        for tp in divisors(devices / pp) {
            if tp > 1 && (cfg.heads % tp != 0 || cfg.hidden % tp != 0) {
                continue;
            }
            let dp = devices / (pp * tp);
            if want("gpipe") {
                out.push(Candidate { pp, tp, dp, chunks: 1, schedule: SimSchedule::GPipe, name: "gpipe" });
            }
            if want("1f1b") {
                out.push(Candidate { pp, tp, dp, chunks: 1, schedule: SimSchedule::OneF1B, name: "1f1b" });
            }
            if want("interleaved") && chunks >= 2 && pp >= 2 && pp * chunks <= cfg.layers {
                // The Megatron slot order needs the microbatch count to
                // divide evenly across the devices.
                let micro = (cfg.batch / (pp * chunks)).max(1);
                let m = (cfg.batch / micro).max(1);
                if m % pp == 0 {
                    out.push(Candidate {
                        pp,
                        tp,
                        dp,
                        chunks,
                        schedule: SimSchedule::Interleaved1F1B { devices: pp },
                        name: "interleaved",
                    });
                }
            }
        }
    }
    out
}

/// Compute-only per-stage times, deduplicated over stage signatures
/// and memoized across candidates by `(stages, tp, config)` — schedule
/// choice never changes compute time, so screening a (pp, tp) pair
/// under three schedules pays the scheduler once.
type TimesCache = HashMap<(u64, u64, ArchConfig), Vec<StageTimes>>;

fn base_times<'c>(
    part: &PartitionedModel,
    config: &ArchConfig,
    cache: &'c mut TimesCache,
    backend: &mut dyn CostBackend,
) -> &'c [StageTimes] {
    let key = (part.stages.len() as u64, part.tmp, *config);
    cache.entry(key).or_insert_with(|| {
        let sigs = stage_signatures(part);
        let nsig = sigs.iter().copied().max().unwrap_or(0) + 1;
        let mut per: Vec<Option<StageTimes>> = vec![None; nsig];
        for (i, st) in part.stages.iter().enumerate() {
            if per[sigs[i]].is_none() {
                per[sigs[i]] = Some(stage_compute_times(st, config, backend));
            }
        }
        sigs.iter().map(|&g| per[g].unwrap()).collect()
    })
}

/// Add the TMP all-reduce, routed over each rank's device group, to the
/// compute-only times.
fn with_tmp_allreduce(
    part: &PartitionedModel,
    base: &[StageTimes],
    topo: &Topology,
    placement: &Placement,
    ranks: u64,
) -> Vec<StageTimes> {
    part.stages
        .iter()
        .enumerate()
        .map(|(i, st)| {
            if part.tmp > 1 {
                let group = &placement.groups[i % ranks as usize];
                base[i].with_allreduce(topo.allreduce_seconds(
                    group,
                    st.tmp_allreduce_fwd_bytes,
                    AllReduceAlgo::Ring,
                ))
            } else {
                base[i]
            }
        })
        .collect()
}

/// Simulate one candidate on `config`, composing DP over the topology.
#[allow(clippy::too_many_arguments)]
fn evaluate_candidate(
    c: &Candidate,
    part: &PartitionedModel,
    config: &ArchConfig,
    mined: bool,
    topo: &Topology,
    opts: &SweepOptions,
    times_cache: &mut TimesCache,
    backend: &mut dyn CostBackend,
) -> Result<StrategyPoint, String> {
    let ranks = c.pp;
    let placement = Placement::linear(topo, ranks, c.tp)?;
    let base = base_times(part, config, times_cache, backend).to_vec();
    let times = with_tmp_allreduce(part, &base, topo, &placement, ranks);
    let sim = simulate_events(part, &times, c.schedule, topo, &placement)?;

    // DP composition — the topology-routed twin of
    // `data_parallel_with_allreduce` (gradient volume shared via
    // `gradient_bytes`, same exposed-fraction model): replicas sit on
    // disjoint device blocks, the gradient all-reduce rings over one
    // representative per replica, and only the non-overlappable share
    // lands on the critical path.
    let mut iter = sim.iter_seconds;
    if c.dp > 1 {
        let reps: Vec<usize> = (0..c.dp).map(|r| (r * c.pp * c.tp) as usize).collect();
        let grad = crate::distributed::data_parallel::gradient_bytes(part);
        iter += topo.allreduce_seconds(&reps, grad, AllReduceAlgo::Ring) * opts.dp_exposed;
    }

    let global_batch = part.micro_batch * part.num_micro * c.dp;
    let throughput = global_batch as f64 / iter;
    let tdp = crate::arch::power::tdp_w(config) * (c.pp * c.tp * c.dp) as f64;
    let perf_per_tdp = throughput / tdp;
    let fits = (0..ranks as usize)
        .all(|r| rank_footprint_bytes(part, &sim, c.schedule, r) <= HBM_BYTES);
    let score = match opts.metric {
        Metric::Throughput => throughput,
        Metric::PerfPerTdp => perf_per_tdp,
    };
    Ok(StrategyPoint {
        pp: c.pp,
        tp: c.tp,
        dp: c.dp,
        chunks: c.chunks,
        schedule: c.name.to_string(),
        micro_batch: part.micro_batch,
        num_micro: part.num_micro,
        config: *config,
        mined,
        iter_seconds: iter,
        throughput,
        perf_per_tdp,
        bubble_fraction: sim.bubble_fraction,
        fits_hbm: fits,
        score,
    })
}

/// Re-simulate one already-ranked strategy in recorded mode and return
/// the result with its per-event timeline (`wham cluster
/// --timeline-out`). Reconstructs exactly what the sweep's screening
/// pass built for the same `(pp, tp, chunks, schedule, config)` —
/// partition, placement, TMP all-reduce — so the exported timeline's
/// numbers match the ranked row's pipeline simulation.
#[allow(clippy::too_many_arguments)]
pub fn strategy_timeline(
    name: &str,
    cfg: &TransformerCfg,
    topology: &str,
    devices: u64,
    pp: u64,
    tp: u64,
    chunks: u64,
    schedule: &str,
    config: &ArchConfig,
    backend: &mut dyn CostBackend,
) -> Result<SimResult, String> {
    let schedule = match schedule {
        "gpipe" => SimSchedule::GPipe,
        "1f1b" => SimSchedule::OneF1B,
        "interleaved" => SimSchedule::Interleaved1F1B { devices: pp },
        other => {
            return Err(format!(
                "unknown schedule {other:?} (expected one of: gpipe, 1f1b, interleaved)"
            ))
        }
    };
    let topo = Topology::preset(topology, devices as usize)?;
    let depth = pp * chunks.max(1);
    let part = partition_transformer(name, cfg, depth, tp, Optimizer::Adam);
    let placement = Placement::linear(&topo, pp, tp)?;
    let mut times_cache: TimesCache = HashMap::new();
    let base = base_times(&part, config, &mut times_cache, backend).to_vec();
    let times = with_tmp_allreduce(&part, &base, &topo, &placement, pp);
    simulate_events_recorded(&part, &times, schedule, &topo, &placement)
}

/// Run the auto-sweep: enumerate, screen with the event simulator on
/// the reference accelerator (TPUv2), mine hardware for the top
/// screened strategies with the global search, and rank.
pub fn sweep(
    name: &str,
    cfg: &TransformerCfg,
    opts: &SweepOptions,
    backend: &mut dyn CostBackend,
    caches: &dyn CacheProvider,
    sink: &mut dyn ProgressSink,
) -> Result<StrategyReport, String> {
    let t0 = Instant::now();
    for s in &opts.schedules {
        if !schedule_names().contains(&s.as_str()) {
            return Err(format!(
                "unknown schedule {s:?} (expected one of: gpipe, 1f1b, interleaved)"
            ));
        }
    }
    let topo = Topology::preset(&opts.topology, opts.devices as usize)?;
    let candidates = enumerate(cfg, opts.devices, &opts.schedules, opts.chunks.max(1));
    if candidates.is_empty() {
        return Err(format!(
            "no feasible strategy for {name:?} on {} devices (schedules {:?})",
            opts.devices, opts.schedules
        ));
    }
    let mut cancelled = false;

    // ---- screening: every candidate on the reference accelerator ----
    // Partitions AND their compute-only stage times are shared across
    // schedules with the same (depth, tp): the scheduler runs once per
    // unique stage signature per partition per config, not per schedule.
    let reference = presets::tpuv2();
    let mut parts: HashMap<(u64, u64), PartitionedModel> = HashMap::new();
    let mut times_cache: TimesCache = HashMap::new();
    let mut screened: Vec<StrategyPoint> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for c in &candidates {
        let _span = crate::telemetry::trace::span("strategy_screen")
            .arg("pp", c.pp)
            .arg("tp", c.tp)
            .arg("dp", c.dp)
            .arg("schedule", c.name);
        let depth = c.pp * c.chunks;
        let part = parts
            .entry((depth, c.tp))
            .or_insert_with(|| partition_transformer(name, cfg, depth, c.tp, Optimizer::Adam));
        let p = evaluate_candidate(c, part, &reference, false, &topo, opts, &mut times_cache, backend)?;
        best = best.max(p.score);
        screened.push(p);
        let elapsed = t0.elapsed();
        let go = sink.on_progress(&Progress {
            phase: "cluster",
            elapsed,
            points: screened.len(),
            best_score: best,
            rate: Progress::rate_of(screened.len(), elapsed),
            depth: 1,
        });
        if !go {
            cancelled = true;
            break;
        }
    }

    // ---- mining: global hardware search over the top screened strategies ----
    // HBM-infeasible strategies are skipped; among the feasible, best
    // screened score first.
    let mut order: Vec<usize> = (0..screened.len()).collect();
    order.sort_by(|&a, &b| {
        screened[b]
            .fits_hbm
            .cmp(&screened[a].fits_hbm)
            .then(screened[b].score.total_cmp(&screened[a].score))
    });
    let net = Network::default();
    let mut mined_count = 0usize;
    if !cancelled {
        for &i in order.iter().take(opts.mine_top) {
            if !screened[i].fits_hbm {
                continue;
            }
            let (pp, tp, chunks) = (screened[i].pp, screened[i].tp, screened[i].chunks);
            let c = candidates
                .iter()
                .find(|c| c.pp == pp && c.tp == tp && c.chunks == chunks
                    && c.name == screened[i].schedule)
                .expect("screened entries come from candidates");
            let part = &parts[&(pp * chunks, tp)];
            // The closed-form miner knows gpipe/1f1b; interleaved
            // candidates mine under the 1F1B steady-state model.
            let scheme = if c.schedule == SimSchedule::GPipe {
                Scheme::GPipe
            } else {
                Scheme::PipeDream1F1B
            };
            // Perf/TDP mines under the same TPUv2 pipeline-throughput
            // floor `Session::run_global` applies, so /cluster and
            // /global share one constraint semantics for the metric.
            // The reference stage times are already cached, so the
            // floor costs one closed-form simulation, not a reschedule.
            let min_throughput = if opts.metric == Metric::PerfPerTdp {
                let base = base_times(part, &reference, &mut times_cache, backend).to_vec();
                let times: Vec<StageTimes> = part
                    .stages
                    .iter()
                    .zip(&base)
                    .map(|(st, b)| {
                        if part.tmp > 1 {
                            b.with_allreduce(
                                net.allreduce_seconds(st.tmp_allreduce_fwd_bytes, part.tmp),
                            )
                        } else {
                            *b
                        }
                    })
                    .collect();
                let cfgs = vec![reference; part.stages.len()];
                crate::distributed::pipeline::simulate_with_times(
                    part, &cfgs, &times, scheme, &net,
                )
                .throughput
            } else {
                0.0
            };
            let gopts = GlobalOptions {
                metric: opts.metric,
                scheme,
                top_k: opts.local.top_k,
                local: opts.local,
                jobs: opts.jobs,
                min_throughput,
                ..Default::default()
            };
            let r = global_search_observed(
                std::slice::from_ref(part),
                &gopts,
                &net,
                backend,
                caches,
                sink,
            );
            cancelled |= r.cancelled;
            let config = r.individual[0].configs[0];
            let mined =
                evaluate_candidate(c, part, &config, true, &topo, opts, &mut times_cache, backend)?;
            // Keep whichever hardware simulates better — the sweep
            // never regresses a strategy below its screened reference,
            // and `mined` only counts strategies actually upgraded.
            if mined.score > screened[i].score {
                screened[i] = mined;
                mined_count += 1;
            }
            if cancelled {
                break;
            }
        }
    }

    // ---- rank, and call out the fixed-(pp, tp) baseline ----
    // Memory feasibility dominates the ranking: a placement that does
    // not fit HBM can never be "the best strategy", however fast its
    // simulated iteration looks.
    screened.sort_by(|a, b| {
        b.fits_hbm
            .cmp(&a.fits_hbm)
            .then(b.score.total_cmp(&a.score))
            .then(a.pp.cmp(&b.pp))
            .then(a.tp.cmp(&b.tp))
            .then(a.schedule.cmp(&b.schedule))
    });
    // The fixed-(pp, tp=1) reference: the deepest enumerated pipeline
    // without TMP (plain-schedule entry when one exists, else the tp=1
    // entry of the requested schedule set, else the ranked best).
    let deepest =
        screened.iter().filter(|p| p.tp == 1).map(|p| p.pp).max().unwrap_or(1);
    let baseline = screened
        .iter()
        .filter(|p| p.pp == deepest && p.tp == 1 && p.chunks == 1)
        .max_by(|a, b| a.score.total_cmp(&b.score))
        .or_else(|| {
            screened
                .iter()
                .filter(|p| p.pp == deepest && p.tp == 1)
                .max_by(|a, b| a.score.total_cmp(&b.score))
        })
        .or_else(|| screened.first())
        .expect("at least one strategy was screened")
        .clone();

    Ok(StrategyReport {
        model: name.to_string(),
        devices: opts.devices,
        topology: topo.name.clone(),
        metric: opts.metric,
        // Count what the report actually holds: a cancelled sweep has
        // screened (and ranked) fewer strategies than it enumerated,
        // and `ranked.len() == candidates` is a reply invariant.
        candidates: screened.len(),
        mined: mined_count,
        baseline,
        ranked: screened,
        cancelled,
        wall: t0.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::progress::NullSink;
    use crate::cost::native::NativeCost;
    use crate::search::engine::NoSharedCache;

    fn tiny_cfg() -> TransformerCfg {
        TransformerCfg {
            layers: 4,
            hidden: 128,
            heads: 4,
            seq: 64,
            batch: 8,
            vocab: 1000,
            ffn_mult: 4,
            tmp: 1,
        }
    }

    fn run(opts: &SweepOptions) -> StrategyReport {
        sweep("tiny", &tiny_cfg(), opts, &mut NativeCost, &NoSharedCache, &mut NullSink).unwrap()
    }

    #[test]
    fn sweep_ranks_strategies_and_beats_the_baseline() {
        let opts = SweepOptions { devices: 4, mine_top: 0, ..Default::default() };
        let r = run(&opts);
        assert!(r.candidates >= 4, "only {} candidates", r.candidates);
        assert_eq!(r.ranked.len(), r.candidates);
        for w in r.ranked.windows(2) {
            assert!(w[0].score >= w[1].score, "ranking must be descending");
        }
        // The fixed-(pp, tp) baseline is one of the ranked entries, so
        // the top strategy can never fall below it.
        assert_eq!(r.baseline.tp, 1);
        assert!(r.ranked[0].throughput >= r.baseline.throughput);
        assert!(r.ranked[0].score >= r.baseline.score);
        // Devices are fully assigned by every strategy.
        for p in &r.ranked {
            assert_eq!(p.pp * p.tp * p.dp, 4, "{p:?}");
            assert!(p.iter_seconds > 0.0 && p.throughput > 0.0);
            assert!((0.0..1.0).contains(&p.bubble_fraction), "{p:?}");
        }
    }

    #[test]
    fn mining_never_regresses_below_the_screen() {
        let screen = run(&SweepOptions { devices: 4, mine_top: 0, ..Default::default() });
        let quick = SearchOptions { top_k: 2, hysteresis: 0, ..Default::default() };
        let mined = run(&SweepOptions { devices: 4, mine_top: 1, local: quick, ..Default::default() });
        // `mined` counts only genuine upgrades, and every mined row must
        // carry a mined config.
        assert!(mined.mined <= 1);
        let flagged = mined.ranked.iter().filter(|p| p.mined).count();
        assert_eq!(flagged, mined.mined, "mined counter must match flagged rows");
        assert!(mined.ranked[0].score >= screen.ranked[0].score * 0.999);
        assert!(mined.ranked[0].throughput >= mined.baseline.throughput);
    }

    #[test]
    fn interleaved_candidates_appear_when_feasible() {
        let opts = SweepOptions { devices: 2, mine_top: 0, ..Default::default() };
        let r = run(&opts);
        // layers=4, devices=2: pp=2 with 2 chunks fits (virtual depth 4).
        assert!(
            r.ranked.iter().any(|p| p.schedule == "interleaved" && p.chunks == 2),
            "{:?}",
            r.ranked.iter().map(|p| (&p.schedule, p.pp)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn hierarchical_topologies_sweep_too() {
        for topo in ["ring", "fat-tree", "nvlink-island"] {
            let opts = SweepOptions {
                devices: 4,
                mine_top: 0,
                topology: topo.to_string(),
                schedules: vec!["1f1b".to_string()],
                ..Default::default()
            };
            let r = run(&opts);
            assert!(!r.ranked.is_empty(), "{topo}");
            assert_eq!(r.topology, Topology::preset(topo, 4).unwrap().name, "{topo}");
        }
    }

    #[test]
    fn unknown_inputs_are_errors() {
        let bad_topo = SweepOptions { topology: "hypercube".into(), ..Default::default() };
        assert!(sweep("t", &tiny_cfg(), &bad_topo, &mut NativeCost, &NoSharedCache, &mut NullSink)
            .is_err());
        let bad_sched =
            SweepOptions { schedules: vec!["zigzag".into()], ..Default::default() };
        assert!(sweep("t", &tiny_cfg(), &bad_sched, &mut NativeCost, &NoSharedCache, &mut NullSink)
            .is_err());
    }

    #[test]
    fn cancellation_returns_partial_report() {
        let mut sink = crate::api::progress::DeadlineSink::new(Duration::ZERO);
        let opts = SweepOptions { devices: 4, mine_top: 1, ..Default::default() };
        let r = sweep("tiny", &tiny_cfg(), &opts, &mut NativeCost, &NoSharedCache, &mut sink)
            .unwrap();
        assert!(r.cancelled);
        assert!(!r.ranked.is_empty(), "at least one strategy is always screened");
    }
}
