//! Hierarchical interconnect topologies and routed collective cost
//! models.
//!
//! The flat [`Network`](crate::distributed::network::Network) (one
//! latency + one bandwidth between every device pair) is the paper's
//! section-5 model; real training clusters are hierarchical — NVLink
//! islands behind InfiniBand spines, fat-tree pods, TPU-style rings —
//! and the latency a collective pays depends on how many physical hops
//! each step's message crosses. This module models a cluster as a graph
//! of devices and switches with per-link latency/bandwidth, routes
//! point-to-point transfers over it (min-hop paths: latency adds per
//! hop, bandwidth bottlenecks), and prices the standard collectives —
//! ring/tree all-reduce, all-gather, reduce-scatter — over the routed
//! paths.
//!
//! The flat `Network` survives as a compatibility shim: it is exactly
//! the single-hop uniform topology ([`Topology::flat`]), and its
//! `allreduce_seconds` delegates to the shared ring-collective model
//! here ([`ring_allreduce_uniform`]), so the two layers cannot drift.

use crate::distributed::network::Network;

/// One physical link class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    /// Bandwidth in GB/s.
    pub gbps: f64,
    /// Per-hop latency in microseconds.
    pub latency_us: f64,
}

/// ICI/NVLink-class default link — matches `Network::default()`.
pub const ICI: Link = Link { gbps: 100.0, latency_us: 2.0 };
/// NVLink/NVSwitch-class intra-island link.
pub const NVLINK: Link = Link { gbps: 300.0, latency_us: 1.0 };
/// InfiniBand-class inter-node link.
pub const IB: Link = Link { gbps: 25.0, latency_us: 5.0 };
/// Fat-tree uplink (leaf switch to spine): double-width IB.
pub const FAT_TREE_UP: Link = Link { gbps: 50.0, latency_us: 5.0 };

/// Routed cost of one device-to-device path.
#[derive(Debug, Clone, Copy)]
pub struct PathCost {
    /// Sum of per-hop latencies, in seconds.
    pub latency_s: f64,
    /// Bottleneck (minimum) bandwidth along the path, GB/s.
    pub gbps: f64,
    /// Number of links crossed.
    pub hops: u32,
}

impl PathCost {
    /// Seconds to move `bytes` along this path.
    pub fn seconds(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / (self.gbps * 1e9)
    }
}

/// All-reduce algorithm choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllReduceAlgo {
    /// Bandwidth-optimal ring: 2(g-1) steps of `bytes/g` chunks.
    Ring,
    /// Latency-optimal binomial tree: 2*ceil(log2 g) rounds of full
    /// buffers (reduce to the root, broadcast back).
    Tree,
    /// Whichever of ring/tree is cheaper for this group and size.
    Auto,
}

/// A cluster interconnect: devices `0..devices` plus internal switch
/// nodes, connected by links. Paths are min-hop routes (unique in the
/// tree-shaped presets; shortest arc on rings).
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    /// Device (accelerator) count; node ids `0..devices` are devices,
    /// higher ids are switches.
    pub devices: usize,
    /// Single-hop uniform shim: every device pair is directly connected
    /// with this link (the flat `Network` compatibility case).
    uniform: Option<Link>,
    /// Undirected adjacency over devices + switches (both directions
    /// stored).
    adj: Vec<Vec<(usize, Link)>>,
}

/// Shared ring all-reduce model over a uniform single-hop group:
/// 2(n-1) steps, each paying one hop of latency plus a `bytes/n` chunk
/// at `gbps` — i.e. 2(n-1) latency terms and 2(n-1)/n of the buffer
/// per link. `Network::allreduce_seconds` is this with its own link.
pub fn ring_allreduce_uniform(latency_s: f64, gbps: f64, bytes: u64, n: u64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    2.0 * (nf - 1.0) * (latency_s + bytes as f64 / nf / (gbps * 1e9))
}

impl Topology {
    fn empty(name: &str, devices: usize, nodes: usize) -> Self {
        Self {
            name: name.to_string(),
            devices,
            uniform: None,
            adj: vec![Vec::new(); nodes],
        }
    }

    fn connect(&mut self, a: usize, b: usize, link: Link) {
        self.adj[a].push((b, link));
        self.adj[b].push((a, link));
    }

    /// Every device pair directly connected by `link` (single hop).
    pub fn uniform(devices: usize, link: Link, name: &str) -> Self {
        let mut t = Self::empty(name, devices, devices);
        t.uniform = Some(link);
        t
    }

    /// The flat-`Network` compatibility shim: a uniform single-hop
    /// topology with the network's latency and bandwidth. Collectives
    /// over it price identically to the `Network` formulas.
    pub fn flat(net: &Network, devices: usize) -> Self {
        Self::uniform(devices, Link { gbps: net.link_gbps, latency_us: net.latency_us }, "flat")
    }

    /// Bidirectional ring of `devices` (TPU-pod style): device `i`
    /// links to `(i+1) % devices`.
    pub fn ring(devices: usize, link: Link) -> Self {
        let mut t = Self::empty("ring", devices, devices);
        for i in 0..devices {
            let j = (i + 1) % devices;
            if j == i || (devices == 2 && i == 1) {
                continue; // 1 device: no links; 2 devices: one link
            }
            t.connect(i, j, link);
        }
        t
    }

    /// Two-level fat tree: `radix` devices per leaf switch, all leaf
    /// switches on one spine. Same-leaf traffic crosses 2 `leaf` links;
    /// cross-leaf traffic crosses 2 `leaf` + 2 `up` links.
    pub fn fat_tree(devices: usize, radix: usize, leaf: Link, up: Link) -> Self {
        assert!(radix >= 1);
        let leaves = (devices + radix - 1) / radix;
        let spine = leaves > 1;
        let nodes = devices + leaves + usize::from(spine);
        let mut t = Self::empty("fat-tree", devices, nodes);
        for d in 0..devices {
            t.connect(d, devices + d / radix, leaf);
        }
        if spine {
            let root = devices + leaves;
            for l in 0..leaves {
                t.connect(devices + l, root, up);
            }
        }
        t
    }

    /// NVLink islands behind an InfiniBand spine: `island` devices per
    /// NVSwitch, island switches joined by a spine. Intra-island
    /// traffic crosses 2 `nvlink` hops; cross-island traffic crosses
    /// 2 `nvlink` + 2 `ib` hops.
    pub fn nvlink_island(devices: usize, island: usize, nvlink: Link, ib: Link) -> Self {
        assert!(island >= 1);
        let islands = (devices + island - 1) / island;
        let spine = islands > 1;
        let nodes = devices + islands + usize::from(spine);
        let mut t = Self::empty("nvlink-island", devices, nodes);
        for d in 0..devices {
            t.connect(d, devices + d / island, nvlink);
        }
        if spine {
            let root = devices + islands;
            for i in 0..islands {
                t.connect(devices + i, root, ib);
            }
        }
        t
    }

    /// Named preset constructors — the CLI/API surface. `flat` is the
    /// paper's homogeneous interconnect; the others are the
    /// hierarchical shapes real clusters use.
    pub fn preset(name: &str, devices: usize) -> Result<Self, String> {
        if devices == 0 {
            return Err("topology needs at least one device".to_string());
        }
        match name {
            "flat" => Ok(Self::uniform(devices, ICI, "flat")),
            "ring" => Ok(Self::ring(devices, ICI)),
            "fat-tree" | "fattree" => Ok(Self::fat_tree(devices, 8, IB, FAT_TREE_UP)),
            "nvlink-island" | "island" => Ok(Self::nvlink_island(devices, 8, NVLINK, IB)),
            other => Err(format!(
                "unknown topology preset {other:?} (expected one of: flat, ring, fat-tree, nvlink-island)"
            )),
        }
    }

    /// The preset names [`Topology::preset`] accepts.
    pub fn preset_names() -> &'static [&'static str] {
        &["flat", "ring", "fat-tree", "nvlink-island"]
    }

    /// Min-hop routed path between two devices (BFS over devices +
    /// switches; deterministic tie-break by construction order).
    pub fn path(&self, a: usize, b: usize) -> PathCost {
        assert!(a < self.devices && b < self.devices, "path endpoints must be devices");
        if a == b {
            return PathCost { latency_s: 0.0, gbps: f64::INFINITY, hops: 0 };
        }
        if let Some(l) = self.uniform {
            return PathCost { latency_s: l.latency_us * 1e-6, gbps: l.gbps, hops: 1 };
        }
        // BFS from `a`; first arrival at each node is a min-hop path.
        let mut seen = vec![false; self.adj.len()];
        let mut frontier: Vec<(usize, PathCost)> =
            vec![(a, PathCost { latency_s: 0.0, gbps: f64::INFINITY, hops: 0 })];
        seen[a] = true;
        while !frontier.is_empty() {
            let mut next = Vec::new();
            for (node, cost) in frontier {
                for &(peer, link) in &self.adj[node] {
                    if seen[peer] {
                        continue;
                    }
                    seen[peer] = true;
                    let c = PathCost {
                        latency_s: cost.latency_s + link.latency_us * 1e-6,
                        gbps: cost.gbps.min(link.gbps),
                        hops: cost.hops + 1,
                    };
                    if peer == b {
                        return c;
                    }
                    next.push((peer, c));
                }
            }
            frontier = next;
        }
        panic!("topology {:?} is disconnected between {a} and {b}", self.name);
    }

    /// Seconds to move `bytes` point-to-point over the routed path.
    pub fn p2p_seconds(&self, a: usize, b: usize, bytes: u64) -> f64 {
        self.path(a, b).seconds(bytes)
    }

    /// Ring all-reduce over `group` (ring order = group order): 2(g-1)
    /// steps; each step every member sends a `bytes/g` chunk to its
    /// ring successor, so the step costs the worst routed neighbor
    /// path. Reduces to [`ring_allreduce_uniform`] on uniform shims.
    pub fn ring_allreduce_seconds(&self, group: &[usize], bytes: u64) -> f64 {
        let g = group.len() as u64;
        if g <= 1 {
            return 0.0;
        }
        let chunk = bytes as f64 / g as f64;
        let mut step = 0.0f64;
        for (i, &a) in group.iter().enumerate() {
            let b = group[(i + 1) % group.len()];
            let p = self.path(a, b);
            step = step.max(p.latency_s + chunk / (p.gbps * 1e9));
        }
        2.0 * (g as f64 - 1.0) * step
    }

    /// Binomial-tree all-reduce rooted at `group[0]`: `ceil(log2 g)`
    /// reduce rounds plus the mirror broadcast, each round moving the
    /// full buffer over the worst root-to-member path.
    pub fn tree_allreduce_seconds(&self, group: &[usize], bytes: u64) -> f64 {
        let g = group.len() as u64;
        if g <= 1 {
            return 0.0;
        }
        let rounds = (64 - (g - 1).leading_zeros()) as f64; // ceil(log2 g)
        let mut worst = 0.0f64;
        for &m in &group[1..] {
            worst = worst.max(self.path(group[0], m).seconds(bytes));
        }
        2.0 * rounds * worst
    }

    /// All-reduce over `group` with the chosen algorithm.
    pub fn allreduce_seconds(&self, group: &[usize], bytes: u64, algo: AllReduceAlgo) -> f64 {
        match algo {
            AllReduceAlgo::Ring => self.ring_allreduce_seconds(group, bytes),
            AllReduceAlgo::Tree => self.tree_allreduce_seconds(group, bytes),
            AllReduceAlgo::Auto => self
                .ring_allreduce_seconds(group, bytes)
                .min(self.tree_allreduce_seconds(group, bytes)),
        }
    }

    /// Ring all-gather: (g-1) steps, each member forwarding a
    /// `shard_bytes` shard to its ring successor.
    pub fn allgather_seconds(&self, group: &[usize], shard_bytes: u64) -> f64 {
        let g = group.len();
        if g <= 1 {
            return 0.0;
        }
        let mut step = 0.0f64;
        for (i, &a) in group.iter().enumerate() {
            let b = group[(i + 1) % g];
            step = step.max(self.path(a, b).seconds(shard_bytes));
        }
        (g as f64 - 1.0) * step
    }

    /// Ring reduce-scatter of a full `bytes` buffer: (g-1) steps of
    /// `bytes/g` chunks.
    pub fn reduce_scatter_seconds(&self, group: &[usize], bytes: u64) -> f64 {
        let g = group.len() as u64;
        if g <= 1 {
            return 0.0;
        }
        self.allgather_seconds(group, bytes / g.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MIB: u64 = 1 << 20;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= b.abs() * 1e-6
    }

    // ---- golden costs pinning the preset models (satellite: golden
    // tests for p2p/all-reduce on the presets) --------------------------

    #[test]
    fn golden_flat_p2p_and_allreduce() {
        let t = Topology::preset("flat", 8).unwrap();
        // 2 us + 1 MiB / 100 GB/s.
        assert!(close(t.p2p_seconds(0, 5, MIB), 1.248576e-5));
        // 14 hops of 2 us + (14/8) MiB / 100 GB/s.
        assert!(close(t.ring_allreduce_seconds(&[0, 1, 2, 3, 4, 5, 6, 7], MIB), 4.635008e-5));
    }

    #[test]
    fn flat_matches_network_shim_exactly() {
        // The compatibility shim: the flat topology and the Network
        // formulas are the same model, bit for bit.
        let net = Network::default();
        let t = Topology::flat(&net, 16);
        let group: Vec<usize> = (0..16).collect();
        assert_eq!(t.ring_allreduce_seconds(&group, MIB), net.allreduce_seconds(MIB, 16));
        assert_eq!(t.p2p_seconds(0, 9, MIB), net.p2p_seconds(MIB));
    }

    #[test]
    fn golden_ring_p2p_routes_around_the_ring() {
        let t = Topology::preset("ring", 8).unwrap();
        // 4 hops x 2 us + 1 MiB / 100 GB/s.
        assert!(close(t.p2p_seconds(0, 4, MIB), 1.848576e-5));
        assert_eq!(t.path(0, 4).hops, 4);
        assert_eq!(t.path(0, 7).hops, 1, "shortest arc must wrap");
        // Neighbor steps are single hops, so the all-reduce matches flat.
        let group: Vec<usize> = (0..8).collect();
        assert!(close(t.ring_allreduce_seconds(&group, MIB), 4.635008e-5));
    }

    #[test]
    fn golden_fat_tree_p2p() {
        let t = Topology::preset("fat-tree", 16).unwrap();
        // Same leaf: 2 IB hops = 10 us + 1 MiB / 25 GB/s.
        assert!(close(t.p2p_seconds(0, 1, MIB), 5.194304e-5));
        assert_eq!(t.path(0, 1).hops, 2);
        // Cross leaf: leaf + up + up + leaf = 20 us, bottleneck 25 GB/s.
        assert!(close(t.p2p_seconds(0, 8, MIB), 6.194304e-5));
        assert_eq!(t.path(0, 8).hops, 4);
    }

    #[test]
    fn golden_nvlink_island_p2p_and_allreduce() {
        let t = Topology::preset("nvlink-island", 16).unwrap();
        // Intra-island: 2 NVLink hops = 2 us + 1 MiB / 300 GB/s.
        assert!(close(t.p2p_seconds(0, 1, MIB), 5.495253e-6));
        // Cross-island: nvlink + ib + ib + nvlink = 12 us, 25 GB/s.
        assert!(close(t.p2p_seconds(0, 8, MIB), 5.394304e-5));
        // Ring all-reduce over all 16: the two island-crossing steps
        // dominate every step: 30 * (12 us + (1 MiB / 16) / 25 GB/s).
        let group: Vec<usize> = (0..16).collect();
        assert!(close(t.ring_allreduce_seconds(&group, MIB), 4.386432e-4));
        // Staying inside one island is far cheaper.
        let island: Vec<usize> = (0..8).collect();
        assert!(t.ring_allreduce_seconds(&island, MIB) < 1e-4);
    }

    // ---- structural properties ----------------------------------------

    #[test]
    fn path_is_symmetric_and_zero_on_self() {
        for name in Topology::preset_names() {
            let t = Topology::preset(name, 16).unwrap();
            let ab = t.path(2, 11);
            let ba = t.path(11, 2);
            assert_eq!(ab.hops, ba.hops, "{name}");
            assert!(close(ab.latency_s.max(1e-30), ba.latency_s.max(1e-30)), "{name}");
            assert_eq!(t.path(3, 3).hops, 0);
            assert_eq!(t.p2p_seconds(3, 3, MIB), 0.0);
        }
    }

    #[test]
    fn tree_allreduce_beats_ring_for_tiny_buffers() {
        let t = Topology::preset("flat", 32).unwrap();
        let group: Vec<usize> = (0..32).collect();
        // 8 bytes across 32 devices: latency-dominated, tree wins.
        let ring = t.ring_allreduce_seconds(&group, 8);
        let tree = t.tree_allreduce_seconds(&group, 8);
        assert!(tree < ring, "tree {tree} !< ring {ring}");
        assert_eq!(t.allreduce_seconds(&group, 8, AllReduceAlgo::Auto), tree.min(ring));
        // 1 GiB: bandwidth-dominated, ring wins.
        let big = 1u64 << 30;
        assert!(
            t.ring_allreduce_seconds(&group, big) < t.tree_allreduce_seconds(&group, big)
        );
    }

    #[test]
    fn collectives_are_free_for_singleton_groups() {
        let t = Topology::preset("fat-tree", 8).unwrap();
        assert_eq!(t.ring_allreduce_seconds(&[3], MIB), 0.0);
        assert_eq!(t.tree_allreduce_seconds(&[3], MIB), 0.0);
        assert_eq!(t.allgather_seconds(&[3], MIB), 0.0);
        assert_eq!(t.reduce_scatter_seconds(&[3], MIB), 0.0);
    }

    #[test]
    fn reduce_scatter_plus_allgather_bounds_ring_allreduce() {
        let t = Topology::preset("nvlink-island", 16).unwrap();
        let group: Vec<usize> = (0..16).collect();
        let rs = t.reduce_scatter_seconds(&group, MIB);
        let ag = t.allgather_seconds(&group, MIB / 16);
        let ar = t.ring_allreduce_seconds(&group, MIB);
        assert!(close(rs + ag, ar), "rs {rs} + ag {ag} != ar {ar}");
    }

    #[test]
    fn unknown_preset_is_an_error() {
        assert!(Topology::preset("torus9d", 8).is_err());
        assert!(Topology::preset("flat", 0).is_err());
    }
}
