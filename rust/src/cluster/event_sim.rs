//! Discrete-event pipeline simulator.
//!
//! The closed-form `distributed::pipeline::simulate` covers GPipe
//! exactly and PipeDream-1F1B as a steady-state bound, but it cannot
//! express interleaved schedules, per-link contention, or placement on
//! a hierarchical topology. This simulator replays one training
//! iteration as an explicit event timeline: every pipeline rank owns a
//! static task order (forward/backward per microbatch, per virtual
//! stage), tasks wait on their cross-rank inputs, and every boundary
//! transfer is serialized on its directed rank-to-rank link (routed
//! over the [`Topology`]). It supports:
//!
//! * **GPipe** — all forwards, flush, all backwards; reproduces the
//!   closed-form wavefront recurrence *exactly* (the parity tests pin
//!   this), including heterogeneous per-stage accelerators;
//! * **1F1B** — Megatron/PipeDream warmup-steady-cooldown order
//!   (`min(s-1-rank, m)` warmup forwards, then alternate);
//! * **interleaved 1F1B** — `v` virtual chunks per device in Megatron's
//!   slot order (`2(s-d-1) + (v-1)s` warmup slots, chunk-grouped
//!   rounds), shrinking the pipeline bubble by ~`1/v`;
//!
//! and reports per-rank busy/bubble fractions, per-stage peak
//! microbatch stash (the memory-feasibility input for the strategy
//! sweep), link-contention waits, and the events-per-second counter the
//! cluster bench and `GET /status` surface.

use std::collections::HashMap;

use super::topology::Topology;
use crate::distributed::partition::PartitionedModel;
use crate::distributed::pipeline::StageTimes;

/// Cumulative simulator events (tasks + transfers) process-wide —
/// surfaced by `GET /status`, `GET /metrics`, and `benches/cluster.rs`,
/// following the `sched::evals_total` perf-counter pattern. Registered
/// in the [`crate::telemetry::registry`].
static EVENTS: crate::telemetry::Counter = crate::telemetry::Counter::new(
    "wham_cluster_sim_events_total",
    "Cluster event-simulator events (tasks + transfers) since process start.",
);

/// Total cluster-simulator events since process start.
pub fn events_total() -> u64 {
    EVENTS.get()
}

/// Wall-clock distribution of one simulated training iteration (one
/// `simulate_events` call — the strategy screen's unit of work).
static SIM_STEP_SECONDS: crate::telemetry::Histogram = crate::telemetry::Histogram::new(
    "wham_event_sim_step_duration_seconds",
    "Wall-clock of one event-simulated training iteration (per simulate_events call).",
    1e-6,
);

/// Pipeline schedule simulated at event granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimSchedule {
    /// Flush-at-end pipelining (one stage per rank).
    GPipe,
    /// PipeDream/Megatron one-forward-one-backward (one stage per rank).
    OneF1B,
    /// Interleaved 1F1B: the partition's stages are *virtual* stages
    /// assigned round-robin to `devices` ranks (stage `k` lives on rank
    /// `k % devices`; chunks per rank = `stages / devices`).
    Interleaved1F1B { devices: u64 },
}

impl SimSchedule {
    /// Canonical wire/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            SimSchedule::GPipe => "gpipe",
            SimSchedule::OneF1B => "1f1b",
            SimSchedule::Interleaved1F1B { .. } => "interleaved",
        }
    }
}

/// Device placement: topology device ids per pipeline rank (each rank
/// owns a TMP group of `tmp` devices).
#[derive(Debug, Clone)]
pub struct Placement {
    pub groups: Vec<Vec<usize>>,
}

impl Placement {
    /// Contiguous block placement starting at device `offset`: rank `r`
    /// owns devices `[offset + r*tmp, offset + (r+1)*tmp)`.
    pub fn linear_at(
        topo: &Topology,
        ranks: u64,
        tmp: u64,
        offset: u64,
    ) -> Result<Self, String> {
        let need = offset + ranks * tmp;
        if need > topo.devices as u64 {
            return Err(format!(
                "placement needs {need} devices but topology {:?} has {}",
                topo.name, topo.devices
            ));
        }
        Ok(Self {
            groups: (0..ranks)
                .map(|r| {
                    ((offset + r * tmp)..(offset + (r + 1) * tmp))
                        .map(|d| d as usize)
                        .collect()
                })
                .collect(),
        })
    }

    /// [`Placement::linear_at`] from device 0.
    pub fn linear(topo: &Topology, ranks: u64, tmp: u64) -> Result<Self, String> {
        Self::linear_at(topo, ranks, tmp, 0)
    }

    /// Representative device of a rank (boundary transfers are priced
    /// between representatives).
    fn rep(&self, rank: usize) -> usize {
        self.groups[rank][0]
    }
}

/// Outcome of one simulated training iteration.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Iteration makespan in seconds.
    pub iter_seconds: f64,
    /// Compute-busy seconds per rank.
    pub per_rank_busy: Vec<f64>,
    /// 1 - mean(busy)/iter over ranks: the pipeline bubble.
    pub bubble_fraction: f64,
    /// Peak simultaneously-stashed microbatches per stage (forward done,
    /// backward not yet) — the memory-accounting input.
    pub per_stage_peak_stash: Vec<u64>,
    /// Total seconds links spent moving boundary activations/gradients.
    pub comm_seconds: f64,
    /// Total seconds transfers queued behind a busy link (contention).
    pub link_wait_seconds: f64,
    /// Simulator events processed (tasks + transfers).
    pub events: u64,
    /// Per-event timeline — populated only by
    /// [`simulate_events_recorded`]; the default path stays
    /// allocation-free and leaves this `None`.
    pub timeline: Option<Vec<TimelineEvent>>,
}

/// One recorded simulator event (timeline mode only): a compute task on
/// a rank or a boundary transfer on a directed rank link, each with its
/// idle/contention attribution so a trace viewer shows not just *what*
/// ran but *why* it started late.
#[derive(Debug, Clone)]
pub enum TimelineEvent {
    /// A forward or backward microbatch on one rank.
    Task {
        rank: usize,
        stage: usize,
        mb: usize,
        /// `'F'` or `'B'`.
        pass: char,
        start_s: f64,
        dur_s: f64,
        /// Seconds the rank sat idle waiting for this task's cross-rank
        /// input after going free — the per-event pipeline-bubble
        /// attribution (sums to the schedule's bubble, minus ramp-down).
        bubble_s: f64,
    },
    /// A boundary activation/gradient transfer between two ranks.
    Transfer {
        from: usize,
        to: usize,
        bytes: u64,
        start_s: f64,
        dur_s: f64,
        /// Seconds queued behind earlier traffic on the same directed
        /// link — the per-event contention attribution (sums to
        /// [`SimResult::link_wait_seconds`]).
        wait_s: f64,
    },
}

/// Render a recorded timeline as a Chrome-trace JSON array (the same
/// `ph:"X"` / `cat:"wham"` document shape as the span tracer's
/// `--trace-out`, loadable in `chrome://tracing` / Perfetto). Compute
/// tasks land on the `tid` of their rank; transfers on the sender's
/// rank with the route in `name` and `args`.
pub fn chrome_trace_json(timeline: &[TimelineEvent]) -> String {
    let us = |s: f64| (s * 1e6).round().max(0.0) as u64;
    let rows: Vec<String> = timeline
        .iter()
        .map(|e| match e {
            TimelineEvent::Task { rank, stage, mb, pass, start_s, dur_s, bubble_s } => {
                let args = crate::util::json::Obj::new()
                    .u64("stage", *stage as u64)
                    .u64("mb", *mb as u64)
                    .f64("bubble_ms", bubble_s * 1e3)
                    .finish();
                crate::util::json::Obj::new()
                    .str("name", &format!("{pass} s{stage} mb{mb}"))
                    .str("ph", "X")
                    .str("cat", "wham")
                    .u64("ts", us(*start_s))
                    .u64("dur", us(*dur_s))
                    .u64("pid", 0)
                    .u64("tid", *rank as u64)
                    .raw("args", &args)
                    .finish()
            }
            TimelineEvent::Transfer { from, to, bytes, start_s, dur_s, wait_s } => {
                let args = crate::util::json::Obj::new()
                    .u64("from", *from as u64)
                    .u64("to", *to as u64)
                    .u64("bytes", *bytes)
                    .f64("link_wait_ms", wait_s * 1e3)
                    .finish();
                crate::util::json::Obj::new()
                    .str("name", &format!("xfer r{from}→r{to}"))
                    .str("ph", "X")
                    .str("cat", "wham")
                    .u64("ts", us(*start_s))
                    .u64("dur", us(*dur_s))
                    .u64("pid", 0)
                    .u64("tid", *from as u64)
                    .raw("args", &args)
                    .finish()
            }
        })
        .collect();
    format!("[{}]", rows.join(",\n"))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum P {
    F,
    B,
}

#[derive(Debug, Clone, Copy)]
struct Task {
    pass: P,
    stage: usize,
    mb: usize,
}

/// Build the static per-rank task orders for a schedule.
fn build_orders(
    schedule: SimSchedule,
    s: usize,
    m: usize,
    ranks: usize,
) -> Result<Vec<Vec<Task>>, String> {
    match schedule {
        SimSchedule::GPipe => Ok((0..ranks)
            .map(|r| {
                let mut o: Vec<Task> =
                    (0..m).map(|j| Task { pass: P::F, stage: r, mb: j }).collect();
                o.extend((0..m).map(|j| Task { pass: P::B, stage: r, mb: j }));
                o
            })
            .collect()),
        SimSchedule::OneF1B => Ok((0..ranks)
            .map(|r| {
                let warmup = (s - 1 - r).min(m);
                let mut o: Vec<Task> = Vec::with_capacity(2 * m);
                for j in 0..warmup {
                    o.push(Task { pass: P::F, stage: r, mb: j });
                }
                for j in warmup..m {
                    o.push(Task { pass: P::F, stage: r, mb: j });
                    o.push(Task { pass: P::B, stage: r, mb: j - warmup });
                }
                for j in (m - warmup)..m {
                    o.push(Task { pass: P::B, stage: r, mb: j });
                }
                o
            })
            .collect()),
        SimSchedule::Interleaved1F1B { .. } => {
            let v = s / ranks;
            if v <= 1 {
                // One chunk per device degenerates to plain 1F1B.
                return build_orders(SimSchedule::OneF1B, s, m, ranks);
            }
            if m % ranks != 0 {
                return Err(format!(
                    "interleaved-1F1B needs microbatches ({m}) divisible by devices ({ranks})"
                ));
            }
            let total = m * v;
            let group = ranks * v;
            let mut orders = Vec::with_capacity(ranks);
            for d in 0..ranks {
                // Megatron's interleaved slot order: forward slot `fi`
                // runs chunk (fi % (s*v)) / s; backward slots mirror.
                let warmup = (2 * (ranks - d - 1) + (v - 1) * ranks).min(total);
                let mut fwd_seen = vec![0usize; v];
                let mut bwd_seen = vec![0usize; v];
                let mut o: Vec<Task> = Vec::with_capacity(2 * total);
                let mut fi = 0usize;
                let mut bi = 0usize;
                while fi < warmup {
                    let chunk = (fi % group) / ranks;
                    o.push(Task { pass: P::F, stage: chunk * ranks + d, mb: fwd_seen[chunk] });
                    fwd_seen[chunk] += 1;
                    fi += 1;
                }
                while fi < total {
                    let chunk = (fi % group) / ranks;
                    o.push(Task { pass: P::F, stage: chunk * ranks + d, mb: fwd_seen[chunk] });
                    fwd_seen[chunk] += 1;
                    fi += 1;
                    let chunk = v - 1 - (bi % group) / ranks;
                    o.push(Task { pass: P::B, stage: chunk * ranks + d, mb: bwd_seen[chunk] });
                    bwd_seen[chunk] += 1;
                    bi += 1;
                }
                while bi < total {
                    let chunk = v - 1 - (bi % group) / ranks;
                    o.push(Task { pass: P::B, stage: chunk * ranks + d, mb: bwd_seen[chunk] });
                    bwd_seen[chunk] += 1;
                    bi += 1;
                }
                orders.push(o);
            }
            Ok(orders)
        }
    }
}

/// Simulate one training iteration of `part` (stage `k` timed by
/// `times[k]`) under `schedule`, placed on `topo` by `placement`.
///
/// Transfers between adjacent (virtual) stages are routed between the
/// owning ranks' representative devices and serialized per directed
/// rank pair — contention on a shared boundary link delays downstream
/// work, which the closed-form model cannot express.
pub fn simulate_events(
    part: &PartitionedModel,
    times: &[StageTimes],
    schedule: SimSchedule,
    topo: &Topology,
    placement: &Placement,
) -> Result<SimResult, String> {
    simulate_events_impl(part, times, schedule, topo, placement, false)
}

/// [`simulate_events`] with per-event recording: identical result
/// numbers, plus [`SimResult::timeline`] holding every task and
/// transfer with bubble/contention attribution. Costs one `Vec` push
/// per event — use for export (`wham cluster --timeline-out`), not in
/// the sweep's screening loop.
pub fn simulate_events_recorded(
    part: &PartitionedModel,
    times: &[StageTimes],
    schedule: SimSchedule,
    topo: &Topology,
    placement: &Placement,
) -> Result<SimResult, String> {
    simulate_events_impl(part, times, schedule, topo, placement, true)
}

fn simulate_events_impl(
    part: &PartitionedModel,
    times: &[StageTimes],
    schedule: SimSchedule,
    topo: &Topology,
    placement: &Placement,
    record: bool,
) -> Result<SimResult, String> {
    let s = part.stages.len();
    let m = part.num_micro as usize;
    let _timer = SIM_STEP_SECONDS.start_timer();
    let _span = crate::telemetry::trace::span("event_sim")
        .arg("schedule", schedule.name())
        .arg("stages", s)
        .arg("micro", m);
    if times.len() != s {
        return Err(format!("times has {} entries for {s} stages", times.len()));
    }
    if s == 0 || m == 0 {
        return Err("empty pipeline".to_string());
    }
    let ranks = match schedule {
        SimSchedule::Interleaved1F1B { devices } => {
            let d = devices as usize;
            if d == 0 || s % d != 0 {
                return Err(format!(
                    "interleaved-1F1B needs stages ({s}) divisible by devices ({d})"
                ));
            }
            d
        }
        _ => s,
    };
    if placement.groups.len() != ranks {
        return Err(format!(
            "placement has {} rank groups for {ranks} ranks",
            placement.groups.len()
        ));
    }
    let rank_of = |stage: usize| -> usize {
        match schedule {
            SimSchedule::Interleaved1F1B { .. } => stage % ranks,
            _ => stage,
        }
    };
    let orders = build_orders(schedule, s, m, ranks)?;

    // Task state. `arrive[t]` is when task `t`'s cross-rank input is
    // available at its rank; `done[t]` its completion time.
    let tid = |pass: P, stage: usize, mb: usize| -> usize {
        (match pass {
            P::F => 0,
            P::B => 1,
        }) * s
            * m
            + stage * m
            + mb
    };
    let n_tasks = 2 * s * m;
    let mut arrive = vec![0.0f64; n_tasks];
    let mut arrived = vec![false; n_tasks];
    let mut done = vec![0.0f64; n_tasks];
    for j in 0..m {
        arrived[tid(P::F, 0, j)] = true; // inputs are resident
    }

    let mut rank_free = vec![0.0f64; ranks];
    let mut busy = vec![0.0f64; ranks];
    let mut link_free: HashMap<(usize, usize), f64> = HashMap::new();
    let mut comm_seconds = 0.0f64;
    let mut link_wait = 0.0f64;
    let mut events = 0u64;
    let mut stash_events: Vec<(f64, usize, i64)> = Vec::with_capacity(n_tasks);
    let mut idx = vec![0usize; ranks];
    let mut remaining: usize = orders.iter().map(Vec::len).sum();
    let mut timeline: Vec<TimelineEvent> = Vec::new();

    // One routed transfer: serialize on the directed (from, to) rank
    // link, return the arrival time at the consumer.
    let mut transfer = |from: usize,
                        to: usize,
                        ready: f64,
                        bytes: u64,
                        link_free: &mut HashMap<(usize, usize), f64>,
                        timeline: &mut Vec<TimelineEvent>|
     -> f64 {
        let free = link_free.entry((from, to)).or_insert(0.0);
        let start = ready.max(*free);
        let dur = topo.p2p_seconds(placement.rep(from), placement.rep(to), bytes);
        *free = start + dur;
        comm_seconds += dur;
        link_wait += start - ready;
        if record {
            timeline.push(TimelineEvent::Transfer {
                from,
                to,
                bytes,
                start_s: start,
                dur_s: dur,
                wait_s: start - ready,
            });
        }
        start + dur
    };

    while remaining > 0 {
        let mut progress = false;
        for r in 0..ranks {
            while idx[r] < orders[r].len() {
                let t = orders[r][idx[r]];
                let id = tid(t.pass, t.stage, t.mb);
                if !arrived[id] {
                    break;
                }
                let dur = match t.pass {
                    P::F => times[t.stage].fwd_s,
                    P::B => times[t.stage].bwd_s,
                };
                let start = rank_free[r].max(arrive[id]);
                let end = start + dur;
                if record {
                    timeline.push(TimelineEvent::Task {
                        rank: r,
                        stage: t.stage,
                        mb: t.mb,
                        pass: match t.pass {
                            P::F => 'F',
                            P::B => 'B',
                        },
                        start_s: start,
                        dur_s: dur,
                        bubble_s: start - rank_free[r],
                    });
                }
                done[id] = end;
                rank_free[r] = end;
                busy[r] += dur;
                events += 1;
                match t.pass {
                    P::F => {
                        stash_events.push((end, t.stage, 1));
                        if t.stage + 1 < s {
                            let to = tid(P::F, t.stage + 1, t.mb);
                            let r2 = rank_of(t.stage + 1);
                            arrive[to] = if r2 == r {
                                end
                            } else {
                                events += 1;
                                transfer(
                                    r,
                                    r2,
                                    end,
                                    part.stages[t.stage].boundary_bytes,
                                    &mut link_free,
                                    &mut timeline,
                                )
                            };
                            arrived[to] = true;
                        } else {
                            // Loss at the last stage: its backward is
                            // ready the moment the forward completes.
                            let to = tid(P::B, t.stage, t.mb);
                            arrive[to] = end;
                            arrived[to] = true;
                        }
                    }
                    P::B => {
                        stash_events.push((end, t.stage, -1));
                        if t.stage > 0 {
                            let to = tid(P::B, t.stage - 1, t.mb);
                            let r2 = rank_of(t.stage - 1);
                            arrive[to] = if r2 == r {
                                end
                            } else {
                                events += 1;
                                transfer(
                                    r,
                                    r2,
                                    end,
                                    part.stages[t.stage - 1].boundary_bytes,
                                    &mut link_free,
                                    &mut timeline,
                                )
                            };
                            arrived[to] = true;
                        }
                    }
                }
                idx[r] += 1;
                remaining -= 1;
                progress = true;
            }
        }
        if !progress {
            return Err(format!(
                "pipeline schedule deadlocked with {remaining} tasks pending (invalid order)"
            ));
        }
    }

    let iter_seconds = done.iter().fold(0.0f64, |a, &b| a.max(b));
    // Peak stash per stage: replay the +/- events in time order
    // (forward completions first on ties, the conservative peak).
    stash_events.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.2.cmp(&a.2)));
    let mut in_flight = vec![0i64; s];
    let mut peak = vec![0i64; s];
    for &(_, stage, delta) in &stash_events {
        in_flight[stage] += delta;
        peak[stage] = peak[stage].max(in_flight[stage]);
    }
    EVENTS.add(events);

    let mean_busy: f64 = busy.iter().sum::<f64>() / ranks as f64;
    Ok(SimResult {
        iter_seconds,
        bubble_fraction: if iter_seconds > 0.0 { 1.0 - mean_busy / iter_seconds } else { 0.0 },
        per_rank_busy: busy,
        per_stage_peak_stash: peak.iter().map(|&p| p.max(0) as u64).collect(),
        comm_seconds,
        link_wait_seconds: link_wait,
        events,
        timeline: record.then(|| {
            // Chronological order: interleaved rank loops append tasks
            // out of global time order.
            timeline.sort_by(|a, b| {
                let t = |e: &TimelineEvent| match e {
                    TimelineEvent::Task { start_s, .. } => *start_s,
                    TimelineEvent::Transfer { start_s, .. } => *start_s,
                };
                t(a).total_cmp(&t(b))
            });
            timeline
        }),
    })
}

/// Peak HBM footprint of one rank under a simulated schedule: optimizer
/// state of every stage hosted by the rank plus its peak activation
/// stash.
pub fn rank_footprint_bytes(
    part: &PartitionedModel,
    result: &SimResult,
    schedule: SimSchedule,
    rank: usize,
) -> u64 {
    let ranks = match schedule {
        SimSchedule::Interleaved1F1B { devices } => devices as usize,
        _ => part.stages.len(),
    };
    part.stages
        .iter()
        .enumerate()
        .filter(|(k, _)| match schedule {
            SimSchedule::Interleaved1F1B { .. } => k % ranks == rank,
            _ => *k == rank,
        })
        .map(|(k, st)| st.state_bytes + st.stash_bytes * result.per_stage_peak_stash[k])
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::native::NativeCost;
    use crate::distributed::network::Network;
    use crate::distributed::partition::partition_transformer;
    use crate::distributed::pipeline::{simulate, stage_times};
    use crate::distributed::Scheme;
    use crate::graph::autodiff::Optimizer;

    fn mini_part(stages: u64) -> PartitionedModel {
        let mut cfg = crate::models::transformer::gpt2_xl();
        cfg.layers = 8;
        partition_transformer("mini", &cfg, stages, 1, Optimizer::SgdMomentum)
    }

    fn mini_times(part: &PartitionedModel) -> Vec<StageTimes> {
        let net = Network::default();
        part.stages
            .iter()
            .map(|s| stage_times(s, &presets::tpuv2(), part.tmp, &net, &mut NativeCost))
            .collect()
    }

    #[test]
    fn gpipe_event_sim_matches_closed_form_exactly() {
        let part = mini_part(4);
        let times = mini_times(&part);
        let net = Network::default();
        let closed = simulate(&part, &vec![presets::tpuv2(); 4], Scheme::GPipe, &net, &mut NativeCost);
        let topo = Topology::flat(&net, 4);
        let placement = Placement::linear(&topo, 4, 1).unwrap();
        let sim = simulate_events(&part, &times, SimSchedule::GPipe, &topo, &placement).unwrap();
        let rel = (sim.iter_seconds - closed.iter_seconds).abs() / closed.iter_seconds;
        assert!(rel < 1e-6, "event {} vs closed {}", sim.iter_seconds, closed.iter_seconds);
        assert!(sim.events > 0 && events_total() > 0);
    }

    #[test]
    fn gpipe_parity_holds_for_heterogeneous_stages() {
        let part = mini_part(4);
        let net = Network::default();
        let mut cfgs = vec![presets::tpuv2(); 4];
        cfgs[2] = crate::arch::ArchConfig::new(1, 32, 32, 1, 32); // weak stage
        let times: Vec<StageTimes> = part
            .stages
            .iter()
            .zip(&cfgs)
            .map(|(s, c)| stage_times(s, c, part.tmp, &net, &mut NativeCost))
            .collect();
        let closed = simulate(&part, &cfgs, Scheme::GPipe, &net, &mut NativeCost);
        let topo = Topology::flat(&net, 4);
        let placement = Placement::linear(&topo, 4, 1).unwrap();
        let sim = simulate_events(&part, &times, SimSchedule::GPipe, &topo, &placement).unwrap();
        let rel = (sim.iter_seconds - closed.iter_seconds).abs() / closed.iter_seconds;
        assert!(rel < 1e-6, "event {} vs closed {}", sim.iter_seconds, closed.iter_seconds);
    }

    #[test]
    fn one_f1b_event_sim_within_one_percent_of_closed_form() {
        // The closed-form 1F1B model is a steady-state bound, defined
        // for homogeneous stage times — compare on exactly that case.
        let part = mini_part(4);
        let times = vec![StageTimes { fwd_s: 1e-2, bwd_s: 2e-2, energy_j: 0.0 }; 4];
        let net = Network::default();
        let closed = crate::distributed::pipeline::simulate_with_times(
            &part,
            &vec![presets::tpuv2(); 4],
            &times,
            Scheme::PipeDream1F1B,
            &net,
        );
        let topo = Topology::flat(&net, 4);
        let placement = Placement::linear(&topo, 4, 1).unwrap();
        let sim = simulate_events(&part, &times, SimSchedule::OneF1B, &topo, &placement).unwrap();
        let rel = (sim.iter_seconds - closed.iter_seconds).abs() / closed.iter_seconds;
        assert!(rel < 0.01, "event {} vs closed {}", sim.iter_seconds, closed.iter_seconds);
    }

    #[test]
    fn recorded_mode_matches_default_and_attributes_waits() {
        let part = mini_part(4);
        let times = mini_times(&part);
        let topo = Topology::flat(&Network::default(), 4);
        let placement = Placement::linear(&topo, 4, 1).unwrap();
        let plain =
            simulate_events(&part, &times, SimSchedule::OneF1B, &topo, &placement).unwrap();
        let rec = simulate_events_recorded(&part, &times, SimSchedule::OneF1B, &topo, &placement)
            .unwrap();
        // Identical numbers; only the timeline differs.
        assert!(plain.timeline.is_none(), "default path must not allocate a timeline");
        assert_eq!(plain.iter_seconds, rec.iter_seconds);
        assert_eq!(plain.events, rec.events);
        let tl = rec.timeline.as_ref().expect("recorded mode must keep the timeline");
        assert_eq!(tl.len() as u64, rec.events, "one timeline entry per simulated event");
        // Chronological, and per-event contention sums to the total.
        let mut prev = 0.0f64;
        let mut wait_sum = 0.0f64;
        let mut task_count = 0usize;
        for e in tl {
            let start = match e {
                TimelineEvent::Task { start_s, bubble_s, .. } => {
                    assert!(*bubble_s >= 0.0);
                    task_count += 1;
                    *start_s
                }
                TimelineEvent::Transfer { start_s, wait_s, dur_s, .. } => {
                    assert!(*wait_s >= 0.0 && *dur_s > 0.0);
                    wait_sum += wait_s;
                    *start_s
                }
            };
            assert!(start >= prev, "timeline must be sorted by start time");
            prev = start;
        }
        assert_eq!(task_count, 2 * part.stages.len() * part.num_micro as usize);
        assert!((wait_sum - rec.link_wait_seconds).abs() < 1e-9);
        // The Chrome-trace rendering is a parsable array in the span
        // tracer's document shape.
        let doc = crate::util::json::parse(&chrome_trace_json(tl)).unwrap();
        let events = doc.as_arr().unwrap();
        assert_eq!(events.len(), tl.len());
        for e in events {
            assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
            assert_eq!(e.get("cat").unwrap().as_str(), Some("wham"));
            assert!(e.get("ts").unwrap().as_u64().is_some());
            assert!(e.get("dur").unwrap().as_u64().is_some());
            assert!(e.get("tid").unwrap().as_u64().is_some());
        }
    }

    #[test]
    fn one_f1b_stashes_less_than_gpipe() {
        let mut part = mini_part(4);
        // More microbatches than stages so the 1F1B stash bound bites.
        part.num_micro = 12;
        let times = mini_times(&part);
        let topo = Topology::flat(&Network::default(), 4);
        let placement = Placement::linear(&topo, 4, 1).unwrap();
        let g = simulate_events(&part, &times, SimSchedule::GPipe, &topo, &placement).unwrap();
        let d = simulate_events(&part, &times, SimSchedule::OneF1B, &topo, &placement).unwrap();
        // GPipe stashes every microbatch on every stage.
        assert!(g.per_stage_peak_stash.iter().all(|&p| p == part.num_micro));
        // 1F1B stage 0 keeps at most `stages` in flight.
        assert!(d.per_stage_peak_stash[0] <= part.stages.len() as u64);
        assert!(d.per_stage_peak_stash[0] < g.per_stage_peak_stash[0]);
        assert!(rank_footprint_bytes(&part, &d, SimSchedule::OneF1B, 0)
            <= rank_footprint_bytes(&part, &g, SimSchedule::GPipe, 0));
    }

    #[test]
    fn interleaved_with_one_chunk_is_plain_1f1b() {
        let part = mini_part(4);
        let times = mini_times(&part);
        let topo = Topology::flat(&Network::default(), 4);
        let placement = Placement::linear(&topo, 4, 1).unwrap();
        let plain = simulate_events(&part, &times, SimSchedule::OneF1B, &topo, &placement).unwrap();
        let inter = simulate_events(
            &part,
            &times,
            SimSchedule::Interleaved1F1B { devices: 4 },
            &topo,
            &placement,
        )
        .unwrap();
        assert_eq!(plain.iter_seconds, inter.iter_seconds);
    }

    #[test]
    fn interleaving_shrinks_the_bubble() {
        // 8 virtual stages on 4 devices (2 chunks each) vs the same
        // model as 4 plain stages: the bubble fraction must shrink.
        let part8 = mini_part(8);
        let part4 = mini_part(4);
        let times8 = mini_times(&part8);
        let times4 = mini_times(&part4);
        let topo = Topology::flat(&Network::default(), 4);
        let placement = Placement::linear(&topo, 4, 1).unwrap();
        let plain =
            simulate_events(&part4, &times4, SimSchedule::OneF1B, &topo, &placement).unwrap();
        let inter = simulate_events(
            &part8,
            &times8,
            SimSchedule::Interleaved1F1B { devices: 4 },
            &topo,
            &placement,
        )
        .unwrap();
        assert!(
            inter.bubble_fraction < plain.bubble_fraction,
            "interleaved bubble {} !< plain {}",
            inter.bubble_fraction,
            plain.bubble_fraction
        );
        assert!(inter.iter_seconds > 0.0 && inter.iter_seconds.is_finite());
    }

    #[test]
    fn slower_topology_slows_the_pipeline() {
        let part = mini_part(4);
        let times = mini_times(&part);
        let fast = Topology::flat(&Network::default(), 4);
        let slow = Topology::flat(&Network { link_gbps: 1.0, latency_us: 200.0 }, 4);
        let placement = Placement::linear(&fast, 4, 1).unwrap();
        let f = simulate_events(&part, &times, SimSchedule::GPipe, &fast, &placement).unwrap();
        let s = simulate_events(&part, &times, SimSchedule::GPipe, &slow, &placement).unwrap();
        assert!(s.iter_seconds > f.iter_seconds);
        assert!(s.comm_seconds > f.comm_seconds);
    }

    #[test]
    fn invalid_shapes_are_errors_not_panics() {
        let part = mini_part(4);
        let times = mini_times(&part);
        let topo = Topology::flat(&Network::default(), 4);
        let placement = Placement::linear(&topo, 4, 1).unwrap();
        // 3 devices do not divide 4 virtual stages.
        assert!(simulate_events(
            &part,
            &times,
            SimSchedule::Interleaved1F1B { devices: 3 },
            &topo,
            &placement,
        )
        .is_err());
        // Wrong times length.
        assert!(simulate_events(&part, &times[..2], SimSchedule::GPipe, &topo, &placement).is_err());
        // Placement smaller than the pipeline.
        assert!(Placement::linear(&topo, 8, 1).is_err());
    }
}
