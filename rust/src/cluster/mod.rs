//! `wham::cluster` — topology-aware cluster simulation and
//! parallelism-strategy search.
//!
//! The paper's distributed layer (section 5) models the interconnect as
//! one flat latency/bandwidth pair and evaluates pipelines with
//! closed-form schedules at caller-fixed (pp, tp) degrees. This
//! subsystem scales that into a cluster-level system:
//!
//! * [`topology`] — hierarchical node/switch interconnects (ring,
//!   fat-tree, NVLink-island-plus-IB presets) with per-link
//!   latency/bandwidth, min-hop routing, and collective cost models
//!   (ring/tree all-reduce, all-gather, reduce-scatter, routed p2p).
//!   The flat `Network` survives as the single-hop special case behind
//!   a compatibility shim.
//! * [`event_sim`] — a discrete-event pipeline simulator: explicit
//!   per-microbatch/per-stage task timelines for GPipe, 1F1B, and
//!   interleaved-1F1B, heterogeneous per-stage accelerators, serialized
//!   link contention, and per-stage memory/bubble accounting. Validated
//!   against the closed-form `distributed::pipeline::simulate` on the
//!   cases the formulas cover (exact for GPipe, within 1% for
//!   homogeneous 1F1B).
//! * [`strategy`] — the auto-sweep: enumerate feasible
//!   (pp, tp, dp, microbatch, schedule) splits under device-count and
//!   HBM constraints, screen them with the event simulator, mine
//!   hardware for the best with the existing `global_search` (fanning
//!   out via `--jobs`), and return a ranked [`strategy::StrategyReport`].
//!
//! Front doors: `wham cluster` (CLI), `POST /cluster` (service), and
//! [`crate::api::ClusterRequest`] (library) — all through
//! [`crate::api::Session::run_cluster`], with design points cached in
//! the fingerprint-keyed design database exactly like `wham global`.

pub mod event_sim;
pub mod strategy;
pub mod topology;

pub use event_sim::{
    chrome_trace_json, events_total, simulate_events, simulate_events_recorded, Placement,
    SimResult, SimSchedule, TimelineEvent,
};
pub use strategy::{strategy_timeline, sweep, StrategyPoint, StrategyReport, SweepOptions};
pub use topology::{AllReduceAlgo, Link, PathCost, Topology};
