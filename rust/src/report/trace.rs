//! Chrome-trace (about://tracing, Perfetto) export of schedules: each
//! core instance becomes a track, each operator a complete event. Handy
//! for eyeballing why MCR added a core.

use crate::cost::annotate::AnnotatedGraph;
use crate::graph::CoreType;
use crate::sched::{CoreCount, Schedule};

/// Render a schedule as Chrome trace-event JSON.
///
/// Core assignment is reconstructed greedily (the scheduler does not
/// record instance ids): each op takes the lowest-numbered free instance
/// of its type at its start cycle — consistent with any valid execution.
pub fn chrome_trace(ann: &AnnotatedGraph, sched: &Schedule, cores: CoreCount) -> String {
    let n = ann.graph.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&v| (sched.start[v], sched.finish[v], v));

    let mut tc_free = vec![0u64; cores.tc as usize];
    let mut vc_free = vec![0u64; cores.vc as usize];
    let mut events = String::from("[");
    let mut first = true;
    let take = |free: &mut [u64], start: u64, finish: u64| -> usize {
        let i = (0..free.len()).find(|&i| free[i] <= start).unwrap_or(0);
        free[i] = finish;
        i
    };
    for v in order {
        let (tid_base, idx) = match ann.core[v] {
            CoreType::Tensor => (0, take(&mut tc_free, sched.start[v], sched.finish[v])),
            CoreType::Vector => (1000, take(&mut vc_free, sched.start[v], sched.finish[v])),
            CoreType::Fused => {
                let i = take(&mut tc_free, sched.start[v], sched.finish[v]);
                let _ = take(&mut vc_free, sched.start[v], sched.finish[v]);
                (0, i)
            }
        };
        if !first {
            events.push(',');
        }
        first = false;
        // Durations in "microseconds" = cycles (1:1 for viewing).
        events.push_str(&format!(
            r#"{{"name":{:?},"ph":"X","ts":{},"dur":{},"pid":0,"tid":{}}}"#,
            ann.graph.ops[v].name,
            sched.start[v],
            (sched.finish[v] - sched.start[v]).max(1),
            tid_base + idx
        ));
    }
    events.push(']');
    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;
    use crate::cost::Dims;
    use crate::sched::{asap_alap, greedy_schedule};

    #[test]
    fn trace_is_valid_json_shape() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 64, tc_y: 64, vc_w: 64 }, &mut NativeCost);
        let cp = asap_alap(&ann);
        let cores = CoreCount { tc: 2, vc: 1 };
        let s = greedy_schedule(&ann, &cp, cores);
        let t = chrome_trace(&ann, &s, cores);
        assert!(t.starts_with('[') && t.ends_with(']'));
        assert_eq!(t.matches("\"ph\":\"X\"").count(), g.len());
        assert!(t.contains("\"root\""));
    }

    #[test]
    fn every_op_appears_once() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 64, tc_y: 64, vc_w: 64 }, &mut NativeCost);
        let cp = asap_alap(&ann);
        let cores = CoreCount { tc: 3, vc: 1 };
        let s = greedy_schedule(&ann, &cp, cores);
        let t = chrome_trace(&ann, &s, cores);
        for op in &g.ops {
            assert!(t.contains(&format!("{:?}", op.name)));
        }
    }
}
