//! Report generation: the table/figure printers shared by the CLI, the
//! benches, and EXPERIMENTS.md regeneration, plus a Chrome-trace export
//! of schedules ([`trace`]).

pub mod trace;

use crate::metrics::Evaluation;
use crate::search::DesignPoint;
use crate::util::table::Table;

/// Table of per-model design points (Table 5 shape).
pub fn design_table(rows: &[(String, DesignPoint)]) -> Table {
    let mut t = Table::new(["model", "config", "thpt (samples/s)", "perf/TDP", "area mm2", "TDP W"]);
    for (name, p) in rows {
        t.row([
            name.clone(),
            p.config.display(),
            format!("{:.3}", p.eval.throughput),
            format!("{:.4}", p.eval.perf_per_tdp),
            format!("{:.1}", p.eval.area_mm2),
            format!("{:.1}", p.eval.tdp_w),
        ]);
    }
    t
}

/// Normalized comparison row: value / baseline for every column.
pub fn speedup_table(header: &[&str], rows: &[(String, Vec<f64>)]) -> Table {
    let mut head = vec!["model".to_string()];
    head.extend(header.iter().map(|s| s.to_string()));
    let mut t = Table::new(head);
    for (name, vals) in rows {
        let mut cells = vec![name.clone()];
        cells.extend(vals.iter().map(|v| format!("{v:.3}")));
        t.row(cells);
    }
    t
}

/// Geometric mean, used for the "on average" claims.
pub fn geomean(vals: impl IntoIterator<Item = f64>) -> f64 {
    let mut log_sum = 0.0;
    let mut n = 0usize;
    for v in vals {
        debug_assert!(v > 0.0);
        log_sum += v.ln();
        n += 1;
    }
    if n == 0 {
        return f64::NAN;
    }
    (log_sum / n as f64).exp()
}

/// One-line summary of an evaluation.
pub fn eval_line(e: &Evaluation) -> String {
    format!(
        "iter={:.4}s thpt={:.3}/s energy={:.2}J area={:.0}mm2 TDP={:.0}W perf/TDP={:.4}",
        e.seconds, e.throughput, e.energy_j, e.area_mm2, e.tdp_w, e.perf_per_tdp
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean([2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean([3.0]) - 3.0).abs() < 1e-12);
        assert!(geomean(std::iter::empty::<f64>()).is_nan());
    }

    #[test]
    fn tables_render() {
        let t = speedup_table(&["wham", "tpu"], &[("bert".into(), vec![1.5, 1.0])]);
        let s = t.render();
        assert!(s.contains("bert") && s.contains("1.500"));
    }
}
