//! Checkpoint-resume incremental scheduling for the MCR probe loop.
//!
//! The MCR heuristic (paper Algorithm 1) evaluates a *monotone* sequence
//! of core configurations: every probe grows the previous accepted
//! configuration along one axis. Two exact properties of the greedy list
//! scheduler make most of that work redundant:
//!
//! 1. **Prefix identity.** A scheduling pass in which every ready op
//!    starts (nothing blocked on a core) makes decisions that do not
//!    depend on the core counts — the same ops start at the same times at
//!    any componentwise-larger capacity. Runs at capacities `c' >= c`
//!    are therefore bit-identical up to `c`'s first *blocked* pass. The
//!    engine checkpoints the entry state of that pass and resumes later
//!    probes from it, replaying only the divergent suffix.
//! 2. **Bound monotonicity.** Event times only move forward, so once the
//!    next completion event reaches the smallest makespan the caller
//!    would reject (`bound`), the final makespan is `>= bound` and the
//!    probe can abort without finishing the schedule. MCR's accept tests
//!    are threshold comparisons, so aborting changes no decision.
//!
//! Both properties are exact, not approximate: `rust/tests/
//! hotpath_parity.rs` pins bit-identical schedules, trajectories, and
//! search outcomes against the full-reschedule oracle
//! ([`greedy_schedule_scratch`] via `SearchOptions::full_reschedule`).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::asap_alap::CriticalPath;
use super::list::{eval_tick, Prio};
use super::{CoreCount, Priority, Schedule};
use crate::cost::annotate::AnnotatedGraph;
use crate::graph::CoreType;

/// Probes resumed from a checkpoint instead of scheduling from cycle 0.
static RESUMES: crate::telemetry::Counter = crate::telemetry::Counter::new(
    "wham_sched_resume_total",
    "Scheduler probes resumed from a prefix checkpoint.",
);

/// Operators whose scheduling was inherited from a checkpoint prefix —
/// work the full-reschedule engine would have repeated.
static OPS_SKIPPED: crate::telemetry::Counter = crate::telemetry::Counter::new(
    "wham_sched_ops_skipped_total",
    "Operators inherited from checkpoint prefixes instead of rescheduled.",
);

/// Probes cut short because the makespan provably reached the caller's
/// rejection bound.
static ABORTS: crate::telemetry::Counter = crate::telemetry::Counter::new(
    "wham_sched_probe_aborted_total",
    "Scheduler probes aborted early at the rejection bound.",
);

/// Entry state of a run's first blocked scheduling pass — valid to resume
/// from at any componentwise-larger core configuration.
struct Ckpt {
    cores: CoreCount,
    now: u64,
    scheduled: usize,
    free_tc: u64,
    free_vc: u64,
    indeg: Vec<u32>,
    // start/finish of the prefix; entries for ops still in the ready
    // heaps are stale but are rewritten before any read on resume.
    start: Vec<u64>,
    finish: Vec<u64>,
    ready_t: Vec<Prio>,
    ready_v: Vec<Prio>,
    ready_f: Vec<Prio>,
    events: Vec<Reverse<(u64, usize)>>,
}

/// Most checkpoints kept per MCR run. The store is tiny because a ckpt
/// only earns its slot by being undominated: strictly fewer cores *and*
/// strictly more prefix progress than the others.
const MAX_CKPTS: usize = 4;

/// Persistent scheduler for one MCR run: ready heaps, in-degrees, and
/// timelines survive across probes, and prefix checkpoints let a probe at
/// a grown configuration skip the schedule prefix shared with its parent.
#[derive(Default)]
pub struct IncrementalSched {
    // Live run state (valid for the most recent probe only).
    indeg: Vec<u32>,
    start: Vec<u64>,
    finish: Vec<u64>,
    ready_t: BinaryHeap<Prio>,
    ready_v: BinaryHeap<Prio>,
    ready_f: BinaryHeap<Prio>,
    events: BinaryHeap<Reverse<(u64, usize)>>,
    free_tc: u64,
    free_vc: u64,
    now: u64,
    scheduled: usize,
    complete: bool,
    makespan: u64,
    // Prefix checkpoints for this run (cleared by `reset_for`).
    ckpts: Vec<Ckpt>,
    // Per-pass undo log: ops started in the current scheduling pass.
    pass_started: Vec<usize>,
    started_flag: Vec<bool>,
}

impl IncrementalSched {
    /// Empty engine; buffers grow on first probe and are kept after.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a new MCR run: drop checkpoints (the annotation, and with it
    /// every priority key and event time, changes between runs) and size
    /// the buffers for an `n`-op graph.
    pub fn reset_for(&mut self, n: usize) {
        self.ckpts.clear();
        self.complete = false;
        if self.started_flag.len() != n {
            self.started_flag = vec![false; n];
        }
        if self.start.len() != n {
            self.start = vec![0; n];
            self.finish = vec![0; n];
        }
    }

    /// Greedy-schedule `ann` on `cores`, resuming from the best usable
    /// checkpoint and aborting once the makespan provably reaches
    /// `bound`. Returns the exact makespan if it is `< bound`, `None`
    /// otherwise (the caller would reject either way).
    pub fn probe(
        &mut self,
        ann: &AnnotatedGraph,
        cp: &CriticalPath,
        cores: CoreCount,
        priority: Priority,
        bound: u64,
    ) -> Option<u64> {
        assert!(cores.tc >= 1 && cores.vc >= 1, "need at least one core of each type");
        let _timer = eval_tick();
        let _span = crate::telemetry::trace::span("schedule");
        let g = ann.graph;
        let n = g.len();
        self.complete = false;

        // --- init: resume from the deepest usable checkpoint, else cycle 0.
        let usable = self
            .ckpts
            .iter()
            .enumerate()
            .filter(|(_, c)| c.cores.tc <= cores.tc && c.cores.vc <= cores.vc)
            .max_by_key(|(i, c)| (c.scheduled, usize::MAX - i))
            .map(|(i, _)| i);
        if let Some(i) = usable {
            let c = &self.ckpts[i];
            RESUMES.add(1);
            OPS_SKIPPED.add(c.scheduled as u64);
            self.indeg.clear();
            self.indeg.extend_from_slice(&c.indeg);
            self.start.copy_from_slice(&c.start);
            self.finish.copy_from_slice(&c.finish);
            self.ready_t = BinaryHeap::from(c.ready_t.clone());
            self.ready_v = BinaryHeap::from(c.ready_v.clone());
            self.ready_f = BinaryHeap::from(c.ready_f.clone());
            self.events = BinaryHeap::from(c.events.clone());
            self.free_tc = c.free_tc + (cores.tc - c.cores.tc);
            self.free_vc = c.free_vc + (cores.vc - c.cores.vc);
            self.now = c.now;
            self.scheduled = c.scheduled;
        } else {
            self.indeg.clear();
            self.indeg.extend_from_slice(g.indeg());
            self.start.iter_mut().for_each(|x| *x = 0);
            self.finish.iter_mut().for_each(|x| *x = 0);
            self.ready_t.clear();
            self.ready_v.clear();
            self.ready_f.clear();
            self.events.clear();
            self.free_tc = cores.tc;
            self.free_vc = cores.vc;
            self.now = 0;
            self.scheduled = 0;
            for &v in g.sources() {
                Self::push_ready(&mut self.ready_t, &mut self.ready_v, &mut self.ready_f, ann, cp, priority, v);
            }
        }

        // --- event loop (same decision sequence as greedy_schedule_scratch).
        let mut ckpt_taken = self.ckpts.iter().any(|c| c.cores == cores);
        loop {
            // Scheduling pass at `self.now`.
            self.pass_started.clear();
            loop {
                let head = |q: &BinaryHeap<Prio>| q.peek().map(|Reverse(k)| *k);
                let cand_t = (self.free_tc > 0).then(|| head(&self.ready_t)).flatten();
                let cand_v = (self.free_vc > 0).then(|| head(&self.ready_v)).flatten();
                let cand_f =
                    (self.free_tc > 0 && self.free_vc > 0).then(|| head(&self.ready_f)).flatten();
                let best = [cand_t, cand_v, cand_f].into_iter().flatten().min();
                let Some(key) = best else { break };
                let v = key.2;
                match ann.core[v] {
                    CoreType::Tensor => {
                        self.ready_t.pop();
                        self.free_tc -= 1;
                    }
                    CoreType::Vector => {
                        self.ready_v.pop();
                        self.free_vc -= 1;
                    }
                    CoreType::Fused => {
                        self.ready_f.pop();
                        self.free_tc -= 1;
                        self.free_vc -= 1;
                    }
                }
                self.start[v] = self.now;
                self.finish[v] = self.now + ann.cycles[v];
                self.events.push(Reverse((self.finish[v], v)));
                self.scheduled += 1;
                self.pass_started.push(v);
            }

            // First blocked pass of this run: a ready op exists that a
            // larger configuration could start right now — the exact point
            // where runs at bigger capacities diverge. Checkpoint its
            // entry state (undo this pass's starts) for those future runs.
            if !ckpt_taken {
                let blocked = (self.free_tc == 0 && !self.ready_t.is_empty())
                    || (self.free_vc == 0 && !self.ready_v.is_empty())
                    || ((self.free_tc == 0 || self.free_vc == 0) && !self.ready_f.is_empty());
                if blocked {
                    ckpt_taken = true;
                    self.record_ckpt(ann, cp, cores, priority);
                }
            }

            let Some(Reverse((t, _))) = self.events.peek().copied() else { break };
            if t >= bound {
                // Some op finishes at `t`, so makespan >= bound: reject.
                ABORTS.add(1);
                return None;
            }
            self.now = t;
            while let Some(&Reverse((ft, v))) = self.events.peek() {
                if ft != self.now {
                    break;
                }
                self.events.pop();
                match ann.core[v] {
                    CoreType::Tensor => self.free_tc += 1,
                    CoreType::Vector => self.free_vc += 1,
                    CoreType::Fused => {
                        self.free_tc += 1;
                        self.free_vc += 1;
                    }
                }
                for &s in g.succs(v) {
                    let s = s as usize;
                    self.indeg[s] -= 1;
                    if self.indeg[s] == 0 {
                        Self::push_ready(&mut self.ready_t, &mut self.ready_v, &mut self.ready_f, ann, cp, priority, s);
                    }
                }
            }
        }
        assert_eq!(self.scheduled, n, "scheduler dropped operators (cycle or starvation)");
        self.makespan = self.finish.iter().copied().max().unwrap_or(0);
        self.complete = true;
        debug_assert!(self.makespan < bound);
        Some(self.makespan)
    }

    /// Owned [`Schedule`] of the last *complete* probe. `ready_at` is
    /// reconstructed from predecessor finish times — identical to the
    /// running max the full engine maintains, without the per-release
    /// bookkeeping on the hot path.
    pub fn materialize(&self, ann: &AnnotatedGraph) -> Schedule {
        assert!(self.complete, "materialize() requires a completed probe");
        let g = ann.graph;
        let n = g.len();
        let mut ready_at = vec![0u64; n];
        for v in 0..n {
            for &p in g.preds(v) {
                ready_at[v] = ready_at[v].max(self.finish[p as usize]);
            }
        }
        Schedule {
            start: self.start.clone(),
            finish: self.finish.clone(),
            ready_at,
            makespan: self.makespan,
        }
    }

    fn push_ready(
        rt: &mut BinaryHeap<Prio>,
        rv: &mut BinaryHeap<Prio>,
        rf: &mut BinaryHeap<Prio>,
        ann: &AnnotatedGraph,
        cp: &CriticalPath,
        priority: Priority,
        v: usize,
    ) {
        let key = Self::key(cp, priority, v);
        match ann.core[v] {
            CoreType::Tensor => rt.push(key),
            CoreType::Vector => rv.push(key),
            CoreType::Fused => rf.push(key),
        }
    }

    fn key(cp: &CriticalPath, priority: Priority, v: usize) -> Prio {
        match priority {
            Priority::Criticality => Reverse((cp.slack[v], cp.asap[v], v)),
            Priority::Fifo => Reverse((cp.asap[v], v as u64, v)),
        }
    }

    /// Reconstruct the entry state of the current (blocked) scheduling
    /// pass from the live state and this pass's undo log, and store it if
    /// no existing checkpoint dominates it.
    fn record_ckpt(
        &mut self,
        ann: &AnnotatedGraph,
        cp: &CriticalPath,
        cores: CoreCount,
        priority: Priority,
    ) {
        let entry_scheduled = self.scheduled - self.pass_started.len();
        // Dominated (<= cores, >= progress elsewhere) => this ckpt can
        // never be the best pick; skip the clones entirely.
        if self.ckpts.iter().any(|c| {
            c.cores.tc <= cores.tc && c.cores.vc <= cores.vc && c.scheduled >= entry_scheduled
        }) {
            return;
        }
        let mut free_tc = self.free_tc;
        let mut free_vc = self.free_vc;
        let mut ready_t: Vec<Prio> = self.ready_t.iter().copied().collect();
        let mut ready_v: Vec<Prio> = self.ready_v.iter().copied().collect();
        let mut ready_f: Vec<Prio> = self.ready_f.iter().copied().collect();
        for &v in &self.pass_started {
            self.started_flag[v] = true;
            let key = Self::key(cp, priority, v);
            match ann.core[v] {
                CoreType::Tensor => {
                    ready_t.push(key);
                    free_tc += 1;
                }
                CoreType::Vector => {
                    ready_v.push(key);
                    free_vc += 1;
                }
                CoreType::Fused => {
                    ready_f.push(key);
                    free_tc += 1;
                    free_vc += 1;
                }
            }
        }
        let events: Vec<Reverse<(u64, usize)>> = self
            .events
            .iter()
            .filter(|Reverse((_, v))| !self.started_flag[*v])
            .copied()
            .collect();
        for &v in &self.pass_started {
            self.started_flag[v] = false;
        }
        // Evict checkpoints the new one dominates, then least progress if
        // still at capacity.
        self.ckpts.retain(|c| {
            !(cores.tc <= c.cores.tc && cores.vc <= c.cores.vc && entry_scheduled >= c.scheduled)
        });
        if self.ckpts.len() >= MAX_CKPTS {
            if let Some(i) = (0..self.ckpts.len()).min_by_key(|&i| self.ckpts[i].scheduled) {
                self.ckpts.swap_remove(i);
            }
        }
        self.ckpts.push(Ckpt {
            cores,
            now: self.now,
            scheduled: entry_scheduled,
            free_tc,
            free_vc,
            indeg: self.indeg.clone(),
            start: self.start.clone(),
            finish: self.finish.clone(),
            ready_t,
            ready_v,
            ready_f,
            events,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::annotate::AnnotatedGraph;
    use crate::cost::native::NativeCost;
    use crate::cost::Dims;
    use crate::sched::{asap_alap, greedy_schedule, CoreCount};

    const D: Dims = Dims { tc_x: 64, tc_y: 64, vc_w: 64 };

    /// Probe sequences shaped like MCR growth must match from-scratch
    /// scheduling exactly, including resumed and re-visited configs.
    #[test]
    fn probes_match_full_scheduler_bit_for_bit() {
        let fwd = crate::models::transformer::forward_range(
            &crate::models::transformer::bert_base(),
            0,
            2,
        );
        let g = crate::graph::autodiff::training_graph(
            &fwd,
            crate::graph::autodiff::Optimizer::Adam,
        );
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        let mut inc = IncrementalSched::new();
        inc.reset_for(g.len());
        // Monotone growth with a gallop-style overshoot and backtrack.
        let seq = [
            (1, 1),
            (2, 1),
            (4, 1),
            (8, 1),
            (6, 1), // binary-search midpoint below the last probe
            (6, 2),
            (6, 4),
            (6, 3),
        ];
        for (tc, vc) in seq {
            let cores = CoreCount { tc, vc };
            let full = greedy_schedule(&ann, &cp, cores);
            let got = inc.probe(&ann, &cp, cores, Priority::Criticality, u64::MAX);
            assert_eq!(got, Some(full.makespan), "makespan diverged at {cores:?}");
            let m = inc.materialize(&ann);
            assert_eq!(m.start, full.start, "start diverged at {cores:?}");
            assert_eq!(m.finish, full.finish, "finish diverged at {cores:?}");
            assert_eq!(m.ready_at, full.ready_at, "ready_at diverged at {cores:?}");
        }
    }

    /// An aborted probe must (a) return None exactly when the true
    /// makespan is >= bound and (b) leave the engine able to continue.
    #[test]
    fn bound_aborts_are_decision_preserving() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        let full = greedy_schedule(&ann, &cp, CoreCount { tc: 1, vc: 1 });
        let mut inc = IncrementalSched::new();
        inc.reset_for(g.len());
        for bound in [1, full.makespan / 2, full.makespan, full.makespan + 1, u64::MAX] {
            let got = inc.probe(&ann, &cp, CoreCount { tc: 1, vc: 1 }, Priority::Criticality, bound);
            if full.makespan < bound {
                assert_eq!(got, Some(full.makespan), "bound={bound}");
            } else {
                assert_eq!(got, None, "bound={bound}");
            }
        }
        // Engine still consistent after aborts: a full probe succeeds.
        let got = inc.probe(&ann, &cp, CoreCount { tc: 3, vc: 1 }, Priority::Criticality, u64::MAX);
        let full3 = greedy_schedule(&ann, &cp, CoreCount { tc: 3, vc: 1 });
        assert_eq!(got, Some(full3.makespan));
        assert_eq!(inc.materialize(&ann).start, full3.start);
    }

    /// Growth along one axis must reuse the prefix: the resume counter
    /// moves and results stay exact.
    #[test]
    fn checkpoints_are_actually_used() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        let mut inc = IncrementalSched::new();
        inc.reset_for(g.len());
        let before = RESUMES.get();
        inc.probe(&ann, &cp, CoreCount { tc: 1, vc: 1 }, Priority::Criticality, u64::MAX);
        inc.probe(&ann, &cp, CoreCount { tc: 2, vc: 1 }, Priority::Criticality, u64::MAX);
        inc.probe(&ann, &cp, CoreCount { tc: 3, vc: 1 }, Priority::Criticality, u64::MAX);
        assert!(RESUMES.get() > before, "growth probes never resumed a checkpoint");
        let full = greedy_schedule(&ann, &cp, CoreCount { tc: 3, vc: 1 });
        assert_eq!(inc.materialize(&ann).start, full.start);
    }

    /// reset_for must invalidate checkpoints: a new annotation with
    /// different cycle latencies would otherwise poison resumed probes.
    #[test]
    fn reset_drops_checkpoints_across_runs() {
        let g = crate::sched::fanout3();
        let ann_a = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let ann_b =
            AnnotatedGraph::new(&g, Dims { tc_x: 32, tc_y: 32, vc_w: 64 }, &mut NativeCost);
        let cp_a = asap_alap(&ann_a);
        let cp_b = asap_alap(&ann_b);
        let mut inc = IncrementalSched::new();
        inc.reset_for(g.len());
        inc.probe(&ann_a, &cp_a, CoreCount { tc: 1, vc: 1 }, Priority::Criticality, u64::MAX);
        inc.reset_for(g.len());
        let got =
            inc.probe(&ann_b, &cp_b, CoreCount { tc: 2, vc: 1 }, Priority::Criticality, u64::MAX);
        let full = greedy_schedule(&ann_b, &cp_b, CoreCount { tc: 2, vc: 1 });
        assert_eq!(got, Some(full.makespan));
        assert_eq!(inc.materialize(&ann_b).finish, full.finish);
    }
}
