//! Operator scheduling (paper section 4.3).
//!
//! [`asap_alap()`] computes the infinite-resource As-Soon-As-Possible /
//! As-Late-As-Possible schedules that bound the search: the ASAP makespan
//! is the theoretical best latency of a `<TC-Dim, VC-Width>`, operators
//! with zero ASAP/ALAP slack are the critical path, and per-op slack
//! drives the greedy scheduler's priorities.
//!
//! [`list`] is the resource-constrained greedy scheduler used inside the
//! MCR heuristic loop: ops are scheduled when their predecessors complete
//! and a core of the required type is free; ties go to lower slack.
//!
//! [`incremental`] is the hot-path variant of the same scheduler: it
//! keeps its state alive across the monotone probe sequence of one MCR
//! run, resuming each probe from a prefix checkpoint and aborting once
//! the makespan provably reaches the caller's rejection bound. It is
//! exact — `rust/tests/hotpath_parity.rs` pins bit-identical results
//! against [`list`], which stays available as the parity oracle via
//! `SearchOptions::full_reschedule`.

pub mod asap_alap;
pub mod incremental;
pub mod list;

pub use asap_alap::{asap_alap, CriticalPath, CriticalPathCache};
pub use incremental::IncrementalSched;
pub use list::{
    evals_total, greedy_schedule, greedy_schedule_scratch, greedy_schedule_with_priority,
    CoreCount, Priority, SchedScratch, Schedule,
};

/// Shared test fixture: a fan-out/fan-in graph with tensor parallelism 3.
#[cfg(test)]
pub(crate) fn fanout3() -> crate::graph::OperatorGraph {
    let mut b = crate::graph::GraphBuilder::new();
    let root = b.gemm("root", 64, 64, 64, &[]);
    let l = b.gemm("l", 64, 64, 64, &[root]);
    let c = b.gemm("c", 64, 64, 64, &[root]);
    let r = b.gemm("r", 64, 64, 64, &[root]);
    let _join = b.gemm("join", 64, 64, 64, &[l, c, r]);
    b.finish()
}

#[cfg(test)]
mod tests {
    use crate::cost::annotate::AnnotatedGraph;
    use crate::cost::native::NativeCost;
    use crate::cost::Dims;

    pub(crate) use super::fanout3;

    #[test]
    fn end_to_end_schedule_pipeline() {
        let g = fanout3();
        let mut nc = NativeCost;
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 64, tc_y: 64, vc_w: 64 }, &mut nc);
        let cp = super::asap_alap(&ann);
        let s1 = super::greedy_schedule(&ann, &cp, super::CoreCount { tc: 1, vc: 1 });
        let s3 = super::greedy_schedule(&ann, &cp, super::CoreCount { tc: 3, vc: 1 });
        // With 3 tensor cores the three middle gemms run in parallel and
        // the makespan matches the critical path; with 1 they serialize.
        assert_eq!(s3.makespan, cp.best_latency);
        assert!(s1.makespan > s3.makespan);
    }
}
