//! Resource-constrained greedy list scheduler (paper section 4.3,
//! "Greedy Scheduler for Heuristics").
//!
//! Operators become ready when all predecessors complete; ready operators
//! are started whenever a core of their type is free, lowest-slack first
//! (zero slack = critical). A lower-priority op may start ahead of a
//! blocked critical op of another core type (backfilling), which reduces
//! idle time without delaying the critical op. All operators within a
//! core execute in order; cross-core dependencies are the graph edges
//! (the semaphore block of the architectural template).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::asap_alap::CriticalPath;
use crate::cost::annotate::AnnotatedGraph;
use crate::graph::CoreType;

/// Ready-queue key: (slack|asap, asap|id, id) — see `push_ready`. Shared
/// with the incremental engine, whose checkpoints store heap snapshots.
pub(crate) type Prio = Reverse<(u64, u64, usize)>;

/// Cumulative greedy-scheduler invocations process-wide — the paper's
/// search-cost unit (Figure 8), surfaced by `GET /status`,
/// `GET /metrics`, and the hot-path bench so eval regressions are
/// visible without a profiler. Registered in the
/// [`crate::telemetry::registry`].
static EVALS: crate::telemetry::Counter = crate::telemetry::Counter::new(
    "wham_scheduler_evals_total",
    "Greedy list-scheduler runs since process start (the paper's search-cost unit).",
);

/// Total greedy-scheduler runs since process start.
pub fn evals_total() -> u64 {
    EVALS.get()
}

/// Count one scheduler evaluation and start its duration timer. The
/// incremental engine calls this once per probe so `evals_total` stays
/// the engine-independent search-cost unit the paper plots.
pub(crate) fn eval_tick() -> crate::telemetry::registry::HistTimer {
    EVALS.add(1);
    EVAL_SECONDS.start_timer()
}

/// Wall-clock distribution of single scheduler runs. Two `Instant`
/// reads and three relaxed adds per eval — noise next to the µs-scale
/// schedule itself, and the `/metrics` view that tells p50 from tail
/// when ROADMAP item 2 (incremental scheduling) lands.
static EVAL_SECONDS: crate::telemetry::Histogram = crate::telemetry::Histogram::new(
    "wham_scheduler_eval_duration_seconds",
    "Wall-clock of individual greedy list-scheduler runs.",
    1e-6,
);

/// Number of cores of each type available to the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoreCount {
    pub tc: u64,
    pub vc: u64,
}

/// Result of a greedy scheduling run.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Start cycle per op.
    pub start: Vec<u64>,
    /// Finish cycle per op.
    pub finish: Vec<u64>,
    /// Cycle at which each op's predecessors were all complete.
    pub ready_at: Vec<u64>,
    /// Total makespan in cycles.
    pub makespan: u64,
}

impl Schedule {
    /// Cycles an op waited on a core after its inputs were ready — a
    /// resource conflict in the paper's terms.
    pub fn resource_delay(&self, v: usize) -> u64 {
        self.start[v] - self.ready_at[v]
    }

    /// First operator (by start time, then id) that (a) waited on a core
    /// and (b) thereby started later than its ALAP time — the conflict
    /// MCR resolves by adding a core (Algorithm 1).
    pub fn first_critical_conflict(&self, cp: &CriticalPath) -> Option<usize> {
        self.first_conflict_where(cp, |_| true)
    }

    /// The single pass both conflict queries share: ops that waited on a
    /// core and thereby started past their ALAP time, keyed by
    /// `(start, id)` for deterministic ordering.
    fn conflicts<'a>(&'a self, cp: &'a CriticalPath) -> impl Iterator<Item = (u64, usize)> + 'a {
        (0..self.start.len()).filter_map(move |v| {
            (self.resource_delay(v) > 0 && self.start[v] > cp.alap[v])
                .then_some((self.start[v], v))
        })
    }

    /// Earliest critical conflict accepted by `pred` — single pass
    /// (perf: this runs once per MCR iteration on the hot path; sorting
    /// the whole op list was the top profile entry, see EXPERIMENTS.md
    /// section Perf).
    pub fn first_conflict_where<F: Fn(usize) -> bool>(&self, cp: &CriticalPath, pred: F) -> Option<usize> {
        self.conflicts(cp).filter(|&(_, v)| pred(v)).min().map(|(_, v)| v)
    }

    /// All critical resource conflicts in start-time order. One pass
    /// over the conflicts (the shared [`Self::first_conflict_where`]
    /// machinery), sorting only the conflict set — not the full op list.
    pub fn critical_conflicts(&self, cp: &CriticalPath) -> Vec<usize> {
        let mut order: Vec<(u64, usize)> = self.conflicts(cp).collect();
        order.sort_unstable();
        order.into_iter().map(|(_, v)| v).collect()
    }
}

/// Ready-queue ordering policy (ablation knob; the paper's scheduler uses
/// criticality).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Lowest slack first (zero slack = critical) — paper section 4.3.
    #[default]
    Criticality,
    /// Arrival order (ASAP time, then id) — the ablation baseline.
    Fifo,
}

/// Reusable scheduler buffers. The MCR loop invokes the greedy scheduler
/// dozens of times per `<TC-Dim, VC-Width>`; reusing the in-degree
/// vector and the four heaps across invocations removes the per-call
/// allocations that led the profile (EXPERIMENTS.md section Perf). The
/// `start`/`finish`/`ready_at` vectors are *not* here — they are the
/// returned [`Schedule`] and must be owned per result.
#[derive(Default)]
pub struct SchedScratch {
    indeg: Vec<u32>,
    // Per-core-type ready queues ordered by (slack, asap, id).
    ready_t: BinaryHeap<Prio>,
    ready_v: BinaryHeap<Prio>,
    ready_f: BinaryHeap<Prio>,
    // Completion events: (finish_time, op).
    events: BinaryHeap<Reverse<(u64, usize)>>,
}

impl SchedScratch {
    /// Empty scratch; buffers grow on first use and are kept after.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Greedy-schedule `ann` on `cores` with criticality priorities.
pub fn greedy_schedule(ann: &AnnotatedGraph, cp: &CriticalPath, cores: CoreCount) -> Schedule {
    greedy_schedule_with_priority(ann, cp, cores, Priority::Criticality)
}

/// Greedy-schedule with an explicit ready-queue policy (fresh buffers).
pub fn greedy_schedule_with_priority(
    ann: &AnnotatedGraph,
    cp: &CriticalPath,
    cores: CoreCount,
    priority: Priority,
) -> Schedule {
    greedy_schedule_scratch(ann, cp, cores, priority, &mut SchedScratch::new())
}

/// Greedy-schedule reusing caller-owned buffers — the MCR hot-loop form.
pub fn greedy_schedule_scratch(
    ann: &AnnotatedGraph,
    cp: &CriticalPath,
    cores: CoreCount,
    priority: Priority,
    scratch: &mut SchedScratch,
) -> Schedule {
    assert!(cores.tc >= 1 && cores.vc >= 1, "need at least one core of each type");
    EVALS.add(1);
    let _timer = EVAL_SECONDS.start_timer();
    let _span = crate::telemetry::trace::span("schedule");
    let g = ann.graph;
    let n = g.len();

    scratch.indeg.clear();
    scratch.indeg.extend_from_slice(g.indeg());
    scratch.ready_t.clear();
    scratch.ready_v.clear();
    scratch.ready_f.clear();
    scratch.events.clear();
    let SchedScratch { indeg, ready_t, ready_v, ready_f, events } = scratch;

    let mut ready_at = vec![0u64; n];
    let mut start = vec![0u64; n];
    let mut finish = vec![0u64; n];

    let mut free_tc = cores.tc;
    let mut free_vc = cores.vc;
    let push_ready =
        |v: usize, rt: &mut BinaryHeap<Prio>, rv: &mut BinaryHeap<Prio>, rf: &mut BinaryHeap<Prio>| {
            let key = match priority {
                Priority::Criticality => Reverse((cp.slack[v], cp.asap[v], v)),
                Priority::Fifo => Reverse((cp.asap[v], v as u64, v)),
            };
            match ann.core[v] {
                CoreType::Tensor => rt.push(key),
                CoreType::Vector => rv.push(key),
                CoreType::Fused => rf.push(key),
            }
        };

    for v in 0..n {
        if indeg[v] == 0 {
            push_ready(v, ready_t, ready_v, ready_f);
        }
    }

    let mut now = 0u64;
    let mut scheduled = 0usize;
    loop {
        // Scheduling pass at `now`: start the highest-priority runnable op
        // across the three queues until nothing fits.
        loop {
            let head = |q: &BinaryHeap<Prio>| q.peek().map(|Reverse(k)| *k);
            let cand_t = (free_tc > 0).then(|| head(ready_t)).flatten();
            let cand_v = (free_vc > 0).then(|| head(ready_v)).flatten();
            let cand_f = (free_tc > 0 && free_vc > 0).then(|| head(ready_f)).flatten();
            let best = [cand_t, cand_v, cand_f].into_iter().flatten().min();
            let Some(key) = best else { break };
            let v = key.2;
            match ann.core[v] {
                CoreType::Tensor => {
                    ready_t.pop();
                    free_tc -= 1;
                }
                CoreType::Vector => {
                    ready_v.pop();
                    free_vc -= 1;
                }
                CoreType::Fused => {
                    ready_f.pop();
                    free_tc -= 1;
                    free_vc -= 1;
                }
            }
            start[v] = now;
            finish[v] = now + ann.cycles[v];
            events.push(Reverse((finish[v], v)));
            scheduled += 1;
        }

        let Some(Reverse((t, _))) = events.peek().copied() else { break };
        now = t;
        // Release every op finishing at `now` before the next pass.
        while let Some(&Reverse((ft, v))) = events.peek() {
            if ft != now {
                break;
            }
            events.pop();
            match ann.core[v] {
                CoreType::Tensor => free_tc += 1,
                CoreType::Vector => free_vc += 1,
                CoreType::Fused => {
                    free_tc += 1;
                    free_vc += 1;
                }
            }
            for &s in g.succs(v) {
                let s = s as usize;
                indeg[s] -= 1;
                ready_at[s] = ready_at[s].max(now);
                if indeg[s] == 0 {
                    push_ready(s, ready_t, ready_v, ready_f);
                }
            }
        }
    }
    assert_eq!(scheduled, n, "scheduler dropped operators (cycle or starvation)");
    let makespan = finish.iter().copied().max().unwrap_or(0);
    Schedule { start, finish, ready_at, makespan }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::annotate::AnnotatedGraph;
    use crate::cost::native::NativeCost;
    use crate::cost::Dims;
    use crate::graph::GraphBuilder;
    use crate::sched::asap_alap;

    const D: Dims = Dims { tc_x: 64, tc_y: 64, vc_w: 64 };

    fn sched(g: &crate::graph::OperatorGraph, tc: u64, vc: u64) -> (Schedule, crate::sched::CriticalPath) {
        let ann = AnnotatedGraph::new(g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        let s = greedy_schedule(&ann, &cp, CoreCount { tc, vc });
        (s, cp)
    }

    #[test]
    fn respects_dependencies() {
        let g = crate::sched::fanout3();
        let (s, _) = sched(&g, 2, 1);
        for v in 0..g.len() {
            for &p in g.preds(v) {
                let p = p as usize;
                assert!(s.start[v] >= s.finish[p], "op {v} started before pred {p} finished");
            }
        }
    }

    #[test]
    fn respects_core_capacity() {
        let g = crate::sched::fanout3();
        for tc in 1..=3u64 {
            let (s, _) = sched(&g, tc, 1);
            // Sweep: concurrent tensor ops never exceed tc.
            let mut ev: Vec<(u64, i64)> = Vec::new();
            for v in 0..g.len() {
                ev.push((s.start[v], 1));
                ev.push((s.finish[v], -1));
            }
            ev.sort();
            let mut cur = 0i64;
            for (_, d) in ev {
                cur += d;
                assert!(cur <= tc as i64);
            }
        }
    }

    #[test]
    fn conflict_detected_with_one_core() {
        let g = crate::sched::fanout3();
        let (s1, cp) = sched(&g, 1, 1);
        assert!(s1.first_critical_conflict(&cp).is_some());
        let (s3, cp3) = sched(&g, 3, 1);
        assert!(s3.first_critical_conflict(&cp3).is_none());
        assert_eq!(s3.makespan, cp3.best_latency);
    }

    #[test]
    fn more_cores_never_hurt_this_workload() {
        let g = crate::sched::fanout3();
        let (s1, _) = sched(&g, 1, 1);
        let (s2, _) = sched(&g, 2, 1);
        let (s3, _) = sched(&g, 3, 1);
        assert!(s2.makespan <= s1.makespan);
        assert!(s3.makespan <= s2.makespan);
    }

    #[test]
    fn scratch_reuse_matches_fresh_buffers() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        let mut scratch = SchedScratch::new();
        for cores in
            [CoreCount { tc: 1, vc: 1 }, CoreCount { tc: 3, vc: 1 }, CoreCount { tc: 2, vc: 2 }]
        {
            let fresh = greedy_schedule(&ann, &cp, cores);
            let reused =
                greedy_schedule_scratch(&ann, &cp, cores, Priority::Criticality, &mut scratch);
            assert_eq!(fresh.start, reused.start);
            assert_eq!(fresh.finish, reused.finish);
            assert_eq!(fresh.ready_at, reused.ready_at);
            assert_eq!(fresh.makespan, reused.makespan);
        }
    }

    #[test]
    fn fused_op_needs_both_cores() {
        let mut b = GraphBuilder::new();
        // Two fused ops with no deps: with 1 TC/1 VC they serialize.
        b.fwd("f1", crate::graph::OpKind::FusedGemmAct { m: 64, n: 64, k: 64 }, 0, &[]);
        b.fwd("f2", crate::graph::OpKind::FusedGemmAct { m: 64, n: 64, k: 64 }, 0, &[]);
        let g = b.finish();
        let (s, _) = sched(&g, 1, 1);
        assert!(s.start[1] >= s.finish[0] || s.start[0] >= s.finish[1]);
        let (s2, _) = sched(&g, 2, 2);
        assert_eq!(s2.start[0], s2.start[1]);
    }

    #[test]
    fn vector_backfills_while_tensor_busy() {
        let mut b = GraphBuilder::new();
        let t1 = b.gemm("t1", 512, 512, 512, &[]);
        let _t2 = b.gemm("t2", 64, 64, 64, &[t1]);
        let _v = b.eltwise("v", 4096, 1, &[]);
        let g = b.finish();
        let (s, _) = sched(&g, 1, 1);
        // The independent vector op runs at t=0 despite the busy TC.
        assert_eq!(s.start[2], 0);
    }

    #[test]
    fn critical_ops_win_ties() {
        let mut b = GraphBuilder::new();
        // Critical chain a->c; slack op b competes with a for the one TC.
        let a = b.gemm("a", 256, 256, 256, &[]);
        let _b2 = b.gemm("b", 64, 64, 64, &[]);
        let _c = b.gemm("c", 256, 256, 256, &[a]);
        let g = b.finish();
        let (s, cp) = sched(&g, 1, 1);
        assert_eq!(cp.slack[0], 0);
        assert!(cp.slack[1] > 0);
        assert_eq!(s.start[0], 0, "critical op scheduled first");
    }
}
