//! ASAP/ALAP infinite-resource schedules and critical-path analysis
//! (paper section 4.3, Figure 5).

use crate::cost::annotate::AnnotatedGraph;

/// Critical-path information for an annotated graph.
#[derive(Debug, Clone)]
pub struct CriticalPath {
    /// Earliest possible start per op (infinite cores).
    pub asap: Vec<u64>,
    /// Latest start that does not stretch the best latency.
    pub alap: Vec<u64>,
    /// `alap - asap`: zero marks the critical operators.
    pub slack: Vec<u64>,
    /// Theoretical best makespan (ASAP finish of the last op) — the bound
    /// the MCR heuristic and ILP converge toward.
    pub best_latency: u64,
}

impl CriticalPath {
    /// Operators with zero slack.
    pub fn critical_ops(&self) -> Vec<usize> {
        (0..self.slack.len()).filter(|&v| self.slack[v] == 0).collect()
    }

    /// Upper bound on useful core counts (paper section 3: critical-path
    /// analysis bounds the count via the graph's parallelizability): the
    /// maximum number of ops of one core type simultaneously runnable in
    /// the ASAP schedule.
    pub fn max_parallelism(&self, ann: &AnnotatedGraph, core: crate::graph::CoreType) -> u64 {
        // Sweep-line over ASAP intervals of the matching ops.
        let mut events: Vec<(u64, i64)> = Vec::new();
        for v in 0..ann.graph.len() {
            let matches = match core {
                crate::graph::CoreType::Tensor => {
                    ann.core[v] == crate::graph::CoreType::Tensor
                        || ann.core[v] == crate::graph::CoreType::Fused
                }
                crate::graph::CoreType::Vector => {
                    ann.core[v] == crate::graph::CoreType::Vector
                        || ann.core[v] == crate::graph::CoreType::Fused
                }
                crate::graph::CoreType::Fused => ann.core[v] == crate::graph::CoreType::Fused,
            };
            if matches {
                events.push((self.asap[v], 1));
                events.push((self.asap[v] + ann.cycles[v], -1));
            }
        }
        events.sort();
        let mut cur = 0i64;
        let mut peak = 0i64;
        for (_, d) in events {
            cur += d;
            peak = peak.max(cur);
        }
        peak.max(0) as u64
    }
}

/// Compute ASAP and ALAP schedules over an annotated graph.
pub fn asap_alap(ann: &AnnotatedGraph) -> CriticalPath {
    let g = ann.graph;
    let n = g.len();
    // Cached on the graph: the search calls this once per candidate dims
    // and the order never changes.
    let order = g.topo_order_cached();

    let mut asap = vec![0u64; n];
    for &v in order {
        for &p in &g.preds[v] {
            asap[v] = asap[v].max(asap[p] + ann.cycles[p]);
        }
    }
    let best_latency = order
        .iter()
        .map(|&v| asap[v] + ann.cycles[v])
        .max()
        .unwrap_or(0);

    let mut alap = vec![u64::MAX; n];
    for &v in order.iter().rev() {
        if g.succs[v].is_empty() {
            alap[v] = best_latency - ann.cycles[v];
        } else {
            for &s in &g.succs[v] {
                alap[v] = alap[v].min(alap[s] - ann.cycles[v]);
            }
        }
    }

    let slack = (0..n).map(|v| alap[v] - asap[v]).collect();
    CriticalPath { asap, alap, slack, best_latency }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::annotate::AnnotatedGraph;
    use crate::cost::native::NativeCost;
    use crate::cost::Dims;
    use crate::graph::{CoreType, GraphBuilder};

    const D: Dims = Dims { tc_x: 64, tc_y: 64, vc_w: 64 };

    #[test]
    fn chain_has_zero_slack_everywhere() {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 64, 64, 64, &[]);
        let c = b.gemm("c", 64, 64, 64, &[a]);
        let _d = b.gemm("d", 64, 64, 64, &[c]);
        let g = b.finish();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        assert!(cp.slack.iter().all(|&s| s == 0));
        assert_eq!(cp.critical_ops().len(), 3);
        assert_eq!(cp.best_latency, ann.cycles.iter().sum::<u64>());
    }

    #[test]
    fn short_branch_has_slack() {
        let mut b = GraphBuilder::new();
        let root = b.gemm("root", 64, 64, 64, &[]);
        let long = b.gemm("long", 512, 512, 512, &[root]); // heavy branch
        let short = b.eltwise("short", 64, 1, &[root]); // light branch
        let _join = b.gemm("join", 64, 64, 64, &[long, short]);
        let g = b.finish();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        assert_eq!(cp.slack[long], 0, "heavy branch is critical");
        assert!(cp.slack[short] > 0, "light branch has slack");
        // ALAP start respects the join.
        assert_eq!(cp.alap[short] + ann.cycles[short], cp.alap[3]);
    }

    #[test]
    fn parallelism_bound_matches_fanout() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        assert_eq!(cp.max_parallelism(&ann, CoreType::Tensor), 3);
        assert_eq!(cp.max_parallelism(&ann, CoreType::Vector), 0);
    }

    #[test]
    fn alap_never_before_asap() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        for v in 0..g.len() {
            assert!(cp.alap[v] >= cp.asap[v]);
        }
    }
}
