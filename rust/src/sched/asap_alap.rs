//! ASAP/ALAP infinite-resource schedules and critical-path analysis
//! (paper section 4.3, Figure 5).
//!
//! Representation: alongside `asap` we keep `tail[v]` — the longest
//! cycle-weighted path *starting at* `v`, inclusive of `v` — so
//! `best_latency = max over sinks (asap + cycles)` and
//! `alap[v] = best_latency - tail[v]`. The tail form makes ALAP a purely
//! local backward recurrence, which is what lets
//! [`CriticalPathCache::refresh`] repropagate only the cone of operators
//! whose cycle latencies actually changed between two annotations (the
//! engine re-annotates the same graph at dozens of `<TC-Dim, VC-Width>`
//! candidates; phase 1 perturbs only tensor/fused cycles, phase 2 only
//! vector/fused cycles).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cost::annotate::AnnotatedGraph;

/// Full critical-path recomputations (first use, resized graph, or a
/// change set too large for the worklist to win).
static CP_FULL: crate::telemetry::Counter = crate::telemetry::Counter::new(
    "wham_critpath_refresh_full_total",
    "Full ASAP/ALAP recomputations over the whole graph.",
);

/// Incremental cone repropagations (worklist updates on cycle deltas).
static CP_INCREMENTAL: crate::telemetry::Counter = crate::telemetry::Counter::new(
    "wham_critpath_refresh_incremental_total",
    "Incremental ASAP/ALAP refreshes that repropagated only the changed cone.",
);

/// Operators actually revisited by incremental refreshes — the cone
/// size. Compare against ops x refreshes to see the work avoided.
static CP_OPS_REPROPAGATED: crate::telemetry::Counter = crate::telemetry::Counter::new(
    "wham_critpath_ops_repropagated_total",
    "Operators revisited by incremental critical-path refreshes.",
);

/// Critical-path information for an annotated graph.
#[derive(Debug, Clone)]
pub struct CriticalPath {
    /// Earliest possible start per op (infinite cores).
    pub asap: Vec<u64>,
    /// Latest start that does not stretch the best latency.
    pub alap: Vec<u64>,
    /// `alap - asap`: zero marks the critical operators.
    pub slack: Vec<u64>,
    /// Theoretical best makespan (ASAP finish of the last op) — the bound
    /// the MCR heuristic and ILP converge toward.
    pub best_latency: u64,
    /// Longest path starting at each op, inclusive (`alap = best_latency
    /// - tail`) — the backward-pass state the incremental refresh edits.
    tail: Vec<u64>,
    /// Cached zero-slack operators (ascending ids).
    critical: Vec<usize>,
}

impl CriticalPath {
    /// Operators with zero slack — cached slice, no per-call allocation.
    pub fn critical_ops(&self) -> &[usize] {
        &self.critical
    }

    /// Upper bound on useful core counts (paper section 3: critical-path
    /// analysis bounds the count via the graph's parallelizability): the
    /// maximum number of ops of one core type simultaneously runnable in
    /// the ASAP schedule.
    pub fn max_parallelism(&self, ann: &AnnotatedGraph, core: crate::graph::CoreType) -> u64 {
        // Sweep-line over ASAP intervals of the matching ops.
        let mut events: Vec<(u64, i64)> = Vec::new();
        for v in 0..ann.graph.len() {
            let matches = match core {
                crate::graph::CoreType::Tensor => {
                    ann.core[v] == crate::graph::CoreType::Tensor
                        || ann.core[v] == crate::graph::CoreType::Fused
                }
                crate::graph::CoreType::Vector => {
                    ann.core[v] == crate::graph::CoreType::Vector
                        || ann.core[v] == crate::graph::CoreType::Fused
                }
                crate::graph::CoreType::Fused => ann.core[v] == crate::graph::CoreType::Fused,
            };
            if matches {
                events.push((self.asap[v], 1));
                events.push((self.asap[v] + ann.cycles[v], -1));
            }
        }
        events.sort();
        let mut cur = 0i64;
        let mut peak = 0i64;
        for (_, d) in events {
            cur += d;
            peak = peak.max(cur);
        }
        peak.max(0) as u64
    }

    fn rebuild_critical(&mut self) {
        self.critical.clear();
        self.critical.extend((0..self.slack.len()).filter(|&v| self.slack[v] == 0));
    }
}

/// Compute ASAP and ALAP schedules over an annotated graph.
pub fn asap_alap(ann: &AnnotatedGraph) -> CriticalPath {
    let g = ann.graph;
    let n = g.len();
    // Cached on the graph: the search calls this once per candidate dims
    // and the order never changes.
    let order = g.topo_order_cached();
    let preds = g.preds_csr();
    let succs = g.succs_csr();

    let mut asap = vec![0u64; n];
    for &v in order {
        let mut a = 0u64;
        for &p in preds.row(v) {
            let p = p as usize;
            a = a.max(asap[p] + ann.cycles[p]);
        }
        asap[v] = a;
    }
    let mut tail = vec![0u64; n];
    for &v in order.iter().rev() {
        let mut t = 0u64;
        for &s in succs.row(v) {
            t = t.max(tail[s as usize]);
        }
        tail[v] = t + ann.cycles[v];
    }
    // The overall max of `asap + cycles` is attained at a sink (any
    // non-sink is strictly dominated by its successors), so the cached
    // sink list suffices.
    let best_latency =
        g.sinks().iter().map(|&v| asap[v] + ann.cycles[v]).max().unwrap_or(0);

    let alap: Vec<u64> = (0..n).map(|v| best_latency - tail[v]).collect();
    let slack = (0..n).map(|v| alap[v] - asap[v]).collect();
    let mut cp = CriticalPath { asap, alap, slack, best_latency, tail, critical: Vec::new() };
    cp.rebuild_critical();
    cp
}

/// Keeps a [`CriticalPath`] alive across annotations of the *same graph*
/// and refreshes it by repropagating only the cone of operators whose
/// cycle latencies changed — exact (bit-identical to [`asap_alap`], the
/// property `hotpath_parity.rs` pins), therefore safe under the engine's
/// deterministic parallel prefetch.
#[derive(Default)]
pub struct CriticalPathCache {
    /// Cycle latencies the cached path was computed from.
    cycles: Vec<u64>,
    cp: Option<CriticalPath>,
    /// In-worklist flags, reset via `touched` after each refresh.
    queued: Vec<bool>,
    touched: Vec<usize>,
}

impl CriticalPathCache {
    /// Empty cache; the first refresh computes from scratch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bring the cached path up to date with `ann` and return it.
    pub fn refresh(&mut self, ann: &AnnotatedGraph) -> &CriticalPath {
        let n = ann.graph.len();
        if self.cp.is_none() || self.cycles.len() != n {
            return self.refresh_full(ann);
        }
        // Diff the cycle vectors: the graph is fixed, so changed latency
        // is the only way the critical path can move.
        let changed: Vec<usize> =
            (0..n).filter(|&v| self.cycles[v] != ann.cycles[v]).collect();
        if changed.is_empty() {
            return self.cp.as_ref().unwrap();
        }
        // A majority-changed diff (e.g. the first dims of a phase) pays
        // worklist overhead for no cone to skip — recompute flat.
        if changed.len() * 2 > n {
            return self.refresh_full(ann);
        }
        CP_INCREMENTAL.add(1);
        self.cycles.copy_from_slice(&ann.cycles);
        let g = ann.graph;
        let pos = g.topo_positions();
        let preds = g.preds_csr();
        let succs = g.succs_csr();
        let cp = self.cp.as_mut().unwrap();
        self.touched.clear();
        if self.queued.len() != n {
            self.queued = vec![false; n];
        }
        let mut repropagated = 0u64;
        let mut slack_flipped = false;

        // Forward cone: asap[v] depends on preds only, so changed cycles
        // seed their successors. The min-heap on topo position guarantees
        // each node is finalized before anything downstream of it pops,
        // so every node is recomputed at most once.
        let mut fwd: BinaryHeap<Reverse<(u32, usize)>> = BinaryHeap::new();
        for &c in &changed {
            for &s in succs.row(c) {
                let s = s as usize;
                if !self.queued[s] {
                    self.queued[s] = true;
                    fwd.push(Reverse((pos[s], s)));
                }
            }
        }
        while let Some(Reverse((_, v))) = fwd.pop() {
            self.queued[v] = false;
            repropagated += 1;
            let mut a = 0u64;
            for &p in preds.row(v) {
                let p = p as usize;
                a = a.max(cp.asap[p] + ann.cycles[p]);
            }
            if a != cp.asap[v] {
                cp.asap[v] = a;
                self.touched.push(v);
                for &s in succs.row(v) {
                    let s = s as usize;
                    if !self.queued[s] {
                        self.queued[s] = true;
                        fwd.push(Reverse((pos[s], s)));
                    }
                }
            }
        }

        // Backward cone: tail[v] depends on v's own cycles, so changed
        // nodes seed themselves; deltas flow to predecessors. Max-heap on
        // topo position: downstream finalizes first.
        let mut bwd: BinaryHeap<(u32, usize)> = BinaryHeap::new();
        for &c in &changed {
            if !self.queued[c] {
                self.queued[c] = true;
                bwd.push((pos[c], c));
            }
        }
        while let Some((_, v)) = bwd.pop() {
            self.queued[v] = false;
            repropagated += 1;
            let mut t = 0u64;
            for &s in succs.row(v) {
                t = t.max(cp.tail[s as usize]);
            }
            t += ann.cycles[v];
            if t != cp.tail[v] {
                cp.tail[v] = t;
                self.touched.push(v);
                for &p in preds.row(v) {
                    let p = p as usize;
                    if !self.queued[p] {
                        self.queued[p] = true;
                        bwd.push((pos[p], p));
                    }
                }
            }
        }
        CP_OPS_REPROPAGATED.add(repropagated);

        let best =
            g.sinks().iter().map(|&v| cp.asap[v] + ann.cycles[v]).max().unwrap_or(0);
        if best != cp.best_latency {
            // A moved bound shifts every alap/slack — flat O(n) rewrite.
            cp.best_latency = best;
            for v in 0..n {
                cp.alap[v] = best - cp.tail[v];
                cp.slack[v] = cp.alap[v] - cp.asap[v];
            }
            cp.rebuild_critical();
        } else {
            // Bound unchanged: only touched nodes can have moved. The
            // changed nodes themselves are included — their asap/tail may
            // be stable while a neighbor's shift still leaves them
            // untouched, but their own tail recompute already queued them
            // via `touched` when it moved; nodes whose nothing moved keep
            // alap/slack by definition.
            for &v in &self.touched {
                cp.alap[v] = best - cp.tail[v];
                let s = cp.alap[v] - cp.asap[v];
                if (s == 0) != (cp.slack[v] == 0) {
                    slack_flipped = true;
                }
                cp.slack[v] = s;
            }
            if slack_flipped {
                cp.rebuild_critical();
            }
        }
        self.cp.as_ref().unwrap()
    }

    fn refresh_full(&mut self, ann: &AnnotatedGraph) -> &CriticalPath {
        CP_FULL.add(1);
        self.cycles.clear();
        self.cycles.extend_from_slice(&ann.cycles);
        self.cp = Some(asap_alap(ann));
        self.cp.as_ref().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::annotate::AnnotatedGraph;
    use crate::cost::native::NativeCost;
    use crate::cost::Dims;
    use crate::graph::{CoreType, GraphBuilder};

    const D: Dims = Dims { tc_x: 64, tc_y: 64, vc_w: 64 };

    #[test]
    fn chain_has_zero_slack_everywhere() {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 64, 64, 64, &[]);
        let c = b.gemm("c", 64, 64, 64, &[a]);
        let _d = b.gemm("d", 64, 64, 64, &[c]);
        let g = b.finish();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        assert!(cp.slack.iter().all(|&s| s == 0));
        assert_eq!(cp.critical_ops().len(), 3);
        assert_eq!(cp.best_latency, ann.cycles.iter().sum::<u64>());
    }

    #[test]
    fn short_branch_has_slack() {
        let mut b = GraphBuilder::new();
        let root = b.gemm("root", 64, 64, 64, &[]);
        let long = b.gemm("long", 512, 512, 512, &[root]); // heavy branch
        let short = b.eltwise("short", 64, 1, &[root]); // light branch
        let _join = b.gemm("join", 64, 64, 64, &[long, short]);
        let g = b.finish();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        assert_eq!(cp.slack[long], 0, "heavy branch is critical");
        assert!(cp.slack[short] > 0, "light branch has slack");
        // ALAP start respects the join.
        assert_eq!(cp.alap[short] + ann.cycles[short], cp.alap[3]);
    }

    #[test]
    fn parallelism_bound_matches_fanout() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        assert_eq!(cp.max_parallelism(&ann, CoreType::Tensor), 3);
        assert_eq!(cp.max_parallelism(&ann, CoreType::Vector), 0);
    }

    #[test]
    fn alap_never_before_asap() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        for v in 0..g.len() {
            assert!(cp.alap[v] >= cp.asap[v]);
        }
    }

    /// Incremental refreshes across a dims sweep must match the
    /// from-scratch computation field for field.
    #[test]
    fn incremental_refresh_matches_full_recompute() {
        let fwd = crate::models::transformer::forward_range(
            &crate::models::transformer::bert_base(),
            0,
            2,
        );
        let g = crate::graph::autodiff::training_graph(
            &fwd,
            crate::graph::autodiff::Optimizer::Adam,
        );
        let mut cache = CriticalPathCache::new();
        // Phase-1-like sweep (tc dims move) then phase-2-like (vc width
        // moves): each step perturbs a different subset of cycles.
        for d in [
            Dims { tc_x: 128, tc_y: 128, vc_w: 128 },
            Dims { tc_x: 64, tc_y: 128, vc_w: 128 },
            Dims { tc_x: 128, tc_y: 64, vc_w: 128 },
            Dims { tc_x: 128, tc_y: 64, vc_w: 64 },
            Dims { tc_x: 128, tc_y: 64, vc_w: 32 },
            Dims { tc_x: 128, tc_y: 64, vc_w: 64 }, // revisit
        ] {
            let ann = AnnotatedGraph::new(&g, d, &mut NativeCost);
            let inc = cache.refresh(&ann);
            let full = asap_alap(&ann);
            assert_eq!(inc.asap, full.asap, "asap diverged at {d:?}");
            assert_eq!(inc.alap, full.alap, "alap diverged at {d:?}");
            assert_eq!(inc.slack, full.slack, "slack diverged at {d:?}");
            assert_eq!(inc.best_latency, full.best_latency);
            assert_eq!(inc.critical_ops(), full.critical_ops());
        }
    }
}
