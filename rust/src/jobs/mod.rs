//! `wham::jobs` — the durable async job tier.
//!
//! `wham serve` originally ran every search inside the HTTP request that
//! asked for it: the connection pinned a worker for the whole search,
//! a restart lost all in-flight work, and the only backpressure was the
//! worker pool itself (ROADMAP open item #1). This subsystem splits
//! *admission* from *execution*:
//!
//! ```text
//! POST /jobs ── quota + depth check ──> JobStore (WAL) ──> queue
//!                     │ 429/503                              │
//!                     ▼                                      ▼
//!               rejected at door                   dispatcher workers
//!                                                  (own Sessions, run
//!                                                   search w/ sink)
//! ```
//!
//! * [`store`] — crash-safe JSONL write-ahead log of every lifecycle
//!   transition; replay on boot re-queues interrupted jobs, which then
//!   warm-start from the design DB (0 scheduler evals when the dead
//!   attempt had finished mining).
//! * [`quota`] — per-client token buckets; saturation is `429 +
//!   Retry-After`, not unbounded queueing.
//! * [`JobManager`] — bounded queue, dispatcher threads, retry with
//!   exponential backoff for transient failures, cooperative
//!   cancellation, live SSE frame fan-out, and graceful drain.

pub mod quota;
pub mod store;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::api::job::{JobPlan, JobState};
use crate::api::request::{ClusterRequest, CommonRequest, GlobalRequest, SearchRequest};
use crate::api::wire::{FromJson, ToJson};
use crate::api::{ApiError, Progress, Session};
use crate::telemetry::log::{self, CorrScope};
use quota::QuotaGate;
use store::{JobCounts, JobRecord, JobStore};

/// How long accepted jobs sat queued before a worker picked them up —
/// the admission tier's saturation signal (ms ticks, exported as
/// seconds).
static QUEUE_WAIT_SECONDS: crate::telemetry::Histogram = crate::telemetry::Histogram::new(
    "wham_job_queue_wait_seconds",
    "Queue wait between job submission and first execution attempt.",
    1e-3,
);

/// Dispatcher configuration.
#[derive(Debug, Clone)]
pub struct JobsOptions {
    /// Dispatcher threads (each owns a [`Session`]). Independent of the
    /// HTTP worker pool: HTTP stays responsive while jobs mine.
    pub workers: usize,
    /// Max jobs waiting in the queue; beyond it `POST /jobs` is 429.
    pub queue_depth: usize,
    /// Token-bucket refill rate per client (tokens/second); `<= 0`
    /// disables quotas.
    pub quota_rate: f64,
    /// Token-bucket capacity per client.
    pub quota_burst: f64,
    /// Total execution attempts per job (1 = never retry).
    pub max_attempts: u64,
    /// Base backoff before a retry; doubles per failed attempt.
    pub backoff_ms: u64,
}

impl Default for JobsOptions {
    fn default() -> Self {
        JobsOptions {
            workers: 2,
            queue_depth: 64,
            quota_rate: 1.0,
            quota_burst: 32.0,
            max_attempts: 3,
            backoff_ms: 250,
        }
    }
}

/// Why a submission was rejected at the door.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Client bucket empty — retry after the given seconds.
    QuotaExhausted { retry_after_secs: u64 },
    /// Queue at capacity — retry after the given seconds.
    QueueFull { retry_after_secs: u64 },
    /// Server is draining for shutdown.
    Draining,
}

impl SubmitError {
    pub fn message(&self) -> String {
        match self {
            SubmitError::QuotaExhausted { retry_after_secs } => {
                format!("client quota exhausted; retry in {retry_after_secs}s")
            }
            SubmitError::QueueFull { retry_after_secs } => {
                format!("job queue full; retry in {retry_after_secs}s")
            }
            SubmitError::Draining => "server is draining; jobs are not accepted".to_string(),
        }
    }

    /// HTTP status + optional `Retry-After` seconds.
    pub fn http(&self) -> (u16, Option<u64>) {
        match self {
            SubmitError::QuotaExhausted { retry_after_secs }
            | SubmitError::QueueFull { retry_after_secs } => (429, Some(*retry_after_secs)),
            SubmitError::Draining => (503, Some(5)),
        }
    }
}

/// Admission/queue counters (monotonic; the per-state totals live in
/// [`JobStore::counts`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JobsStats {
    pub submitted: u64,
    pub rejected_quota: u64,
    pub rejected_depth: u64,
    pub retries: u64,
}

/// Bounded frame ring: watchers index frames absolutely, old frames age
/// out from the front so an unbounded search cannot grow memory.
struct FrameLog {
    buf: VecDeque<String>,
    /// Absolute index of `buf[0]`.
    base: usize,
}

const FRAME_CAP: usize = 1024;

/// Live (non-terminal) execution channel of one job: pre-rendered SSE
/// frames plus the cooperative cancellation flags.
pub struct JobLive {
    frames: Mutex<FrameLog>,
    cv: Condvar,
    cancel: AtomicBool,
    requeue: AtomicBool,
    terminal: AtomicBool,
}

impl JobLive {
    fn new() -> Self {
        JobLive {
            frames: Mutex::new(FrameLog { buf: VecDeque::new(), base: 0 }),
            cv: Condvar::new(),
            cancel: AtomicBool::new(false),
            requeue: AtomicBool::new(false),
            terminal: AtomicBool::new(false),
        }
    }

    fn push(&self, frame: String) {
        let mut log = self.frames.lock().unwrap();
        if log.buf.len() >= FRAME_CAP {
            log.buf.pop_front();
            log.base += 1;
        }
        log.buf.push_back(frame);
        drop(log);
        self.cv.notify_all();
    }

    fn finish(&self) {
        self.terminal.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    /// Request cooperative cancellation (user intent: terminal state
    /// becomes `cancelled`).
    pub fn request_cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// Request cooperative re-queue (drain intent: job goes back to
    /// `queued` and resumes on the next boot).
    pub fn request_requeue(&self) {
        self.requeue.store(true, Ordering::SeqCst);
    }

    fn should_stop(&self) -> bool {
        self.cancel.load(Ordering::SeqCst) || self.requeue.load(Ordering::SeqCst)
    }

    /// Frames from absolute index `from` (clamped to what the ring still
    /// holds). Blocks up to `timeout` when nothing new is available.
    /// Returns `(frames, next_from, terminal)`.
    pub fn wait(&self, from: usize, timeout: Duration) -> (Vec<String>, usize, bool) {
        let mut log = self.frames.lock().unwrap();
        if from >= log.base + log.buf.len() && !self.terminal.load(Ordering::SeqCst) {
            let (l, _) = self.cv.wait_timeout(log, timeout).unwrap();
            log = l;
        }
        let start = from.max(log.base);
        let frames: Vec<String> =
            log.buf.iter().skip(start - log.base).cloned().collect();
        let next = log.base + log.buf.len();
        (frames, next, self.terminal.load(Ordering::SeqCst))
    }
}

/// One Server-Sent-Events frame (`event:` line optional).
pub fn sse_frame(event: Option<&str>, data: &str) -> String {
    match event {
        Some(e) => format!("event: {e}\ndata: {data}\n\n"),
        None => format!("data: {data}\n\n"),
    }
}

struct QueueItem {
    due: Instant,
    id: String,
}

/// What a graceful drain accomplished.
#[derive(Debug, Clone, Copy, Default)]
pub struct DrainSummary {
    /// Jobs that reached a terminal state during the drain window.
    pub completed: u64,
    /// Jobs re-queued for the next boot (budget ran out).
    pub requeued: u64,
    /// Jobs left queued untouched (never started).
    pub queued_left: u64,
}

/// The dispatcher: owns the queue, the worker threads, admission
/// control, and the live-progress fan-out.
pub struct JobManager {
    store: Arc<JobStore>,
    opts: JobsOptions,
    queue: Mutex<Vec<QueueItem>>,
    queue_cv: Condvar,
    live: Mutex<HashMap<String, Arc<JobLive>>>,
    quota: QuotaGate,
    accepting: AtomicBool,
    shutdown: AtomicBool,
    submitted: AtomicU64,
    rejected_quota: AtomicU64,
    rejected_depth: AtomicU64,
    retries: AtomicU64,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl JobManager {
    /// Spawn the dispatcher over `store`. Jobs already queued in the
    /// store (including crash-interrupted ones its replay re-queued) are
    /// enqueued immediately; `make_session` runs on each worker thread
    /// to build its private [`Session`].
    pub fn start<F>(store: Arc<JobStore>, opts: JobsOptions, make_session: F) -> Arc<JobManager>
    where
        F: Fn() -> Session + Send + Sync + 'static,
    {
        let opts = JobsOptions { workers: opts.workers.max(1), ..opts };
        let mgr = Arc::new(JobManager {
            store,
            quota: QuotaGate::new(opts.quota_rate, opts.quota_burst),
            opts,
            queue: Mutex::new(Vec::new()),
            queue_cv: Condvar::new(),
            live: Mutex::new(HashMap::new()),
            accepting: AtomicBool::new(true),
            shutdown: AtomicBool::new(false),
            submitted: AtomicU64::new(0),
            rejected_quota: AtomicU64::new(0),
            rejected_depth: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            workers: Mutex::new(Vec::new()),
        });
        {
            // Resume whatever the WAL replay left queued.
            let now = Instant::now();
            let mut q = mgr.queue.lock().unwrap();
            for id in mgr.store.queued_ids() {
                q.push(QueueItem { due: now, id });
            }
        }
        let make_session = Arc::new(make_session);
        let mut workers = mgr.workers.lock().unwrap();
        for i in 0..mgr.opts.workers {
            let mgr2 = Arc::clone(&mgr);
            let mk = Arc::clone(&make_session);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("wham-jobs-{i}"))
                    .spawn(move || {
                        let mut session = mk();
                        while let Some(id) = mgr2.next_job() {
                            mgr2.execute(&mut session, &id);
                        }
                    })
                    .expect("spawning job worker"),
            );
        }
        drop(workers);
        mgr
    }

    /// The backing store.
    pub fn store(&self) -> &Arc<JobStore> {
        &self.store
    }

    /// Per-state totals (authoritative: the store).
    pub fn counts(&self) -> JobCounts {
        self.store.counts()
    }

    /// Admission counters.
    pub fn stats(&self) -> JobsStats {
        JobsStats {
            submitted: self.submitted.load(Ordering::Relaxed),
            rejected_quota: self.rejected_quota.load(Ordering::Relaxed),
            rejected_depth: self.rejected_depth.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
        }
    }

    /// Jobs waiting in the dispatcher queue right now.
    pub fn queue_depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Configured queue capacity (the admission bound; alert rules key
    /// queue-pressure thresholds off it).
    pub fn queue_capacity(&self) -> usize {
        self.opts.queue_depth
    }

    /// Admit a validated job: quota, then queue depth, then WAL + queue.
    pub fn submit(&self, plan: &JobPlan) -> Result<JobRecord, SubmitError> {
        if !self.accepting.load(Ordering::SeqCst) {
            return Err(SubmitError::Draining);
        }
        if let Err(retry_after_secs) = self.quota.take(&plan.client) {
            self.rejected_quota.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::QuotaExhausted { retry_after_secs });
        }
        let mut q = self.queue.lock().unwrap();
        if q.len() >= self.opts.queue_depth {
            self.rejected_depth.fetch_add(1, Ordering::Relaxed);
            // One queue slot frees when any running job finishes; there
            // is no good estimate, so suggest a short constant.
            return Err(SubmitError::QueueFull { retry_after_secs: 2 });
        }
        // Admission runs on the submitting thread (the HTTP handler),
        // so the request's correlation scope is still live here.
        let corr = log::current_corr().unwrap_or_default();
        let rec = self.store.submit(plan.kind, &plan.client, &plan.request_json, &corr);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        log::info(
            "jobs",
            "job submitted",
            &[("job", &rec.id), ("kind", &plan.kind.label()), ("client", &plan.client)],
        );
        q.push(QueueItem { due: Instant::now(), id: rec.id.clone() });
        drop(q);
        self.queue_cv.notify_one();
        Ok(rec)
    }

    /// Cooperatively cancel `id`. Queued jobs cancel immediately;
    /// running jobs stop at their next progress event. Returns the
    /// post-cancel record, `None` for unknown ids.
    pub fn cancel(&self, id: &str) -> Option<JobRecord> {
        let rec = self.store.get(id)?;
        match rec.state {
            JobState::Queued => {
                // Remove from the queue so a worker never picks it up.
                let mut q = self.queue.lock().unwrap();
                q.retain(|item| item.id != id);
                drop(q);
                self.store.mark_cancelled(id);
                if let Some(live) = self.live.lock().unwrap().remove(id) {
                    live.finish();
                }
            }
            JobState::Running => {
                if let Some(live) = self.live.lock().unwrap().get(id) {
                    live.request_cancel();
                }
            }
            // Terminal states stay as they are.
            _ => {}
        }
        self.store.get(id)
    }

    /// The live channel of a non-terminal job (`None` once terminal —
    /// serve watchers from the store instead).
    pub fn watch(&self, id: &str) -> Option<Arc<JobLive>> {
        let rec = self.store.get(id)?;
        if rec.state.is_terminal() {
            return None;
        }
        let mut live = self.live.lock().unwrap();
        // A queued job may not have a channel yet; create it so early
        // watchers see frames from the first running moment.
        Some(Arc::clone(live.entry(id.to_string()).or_insert_with(|| Arc::new(JobLive::new()))))
    }

    fn live_for(&self, id: &str) -> Arc<JobLive> {
        let mut live = self.live.lock().unwrap();
        Arc::clone(live.entry(id.to_string()).or_insert_with(|| Arc::new(JobLive::new())))
    }

    fn finish_live(&self, id: &str) {
        if let Some(live) = self.live.lock().unwrap().remove(id) {
            live.finish();
        }
    }

    /// Worker loop: block until a due job or shutdown.
    fn next_job(&self) -> Option<String> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            let now = Instant::now();
            if let Some(i) = q.iter().position(|item| item.due <= now) {
                return Some(q.remove(i).id);
            }
            // Sleep until the nearest backoff expiry (or a poll tick).
            let wait = q
                .iter()
                .map(|item| item.due.saturating_duration_since(now))
                .min()
                .unwrap_or(Duration::from_millis(200))
                .min(Duration::from_millis(200));
            let (nq, _) = self.queue_cv.wait_timeout(q, wait.max(Duration::from_millis(1))).unwrap();
            q = nq;
        }
    }

    /// Run one job to a terminal state (or back into the queue).
    fn execute(&self, session: &mut Session, id: &str) {
        let Some(rec) = self.store.get(id) else { return };
        if rec.state != JobState::Queued {
            return; // cancelled while queued, or duplicate wake-up
        }
        let Some(rec) = self.store.mark_running(id) else { return };
        // Every log line of the attempt carries the submitting request's
        // correlation id (empty for pre-corr WAL records = no tag).
        let _corr = CorrScope::enter(&rec.corr);
        if rec.attempts == 1 {
            QUEUE_WAIT_SECONDS.observe(store::epoch_ms().saturating_sub(rec.submitted_ms));
        }
        log::info(
            "jobs",
            "job started",
            &[("job", &rec.id), ("kind", &rec.kind.label()), ("attempt", &rec.attempts)],
        );
        let started = Instant::now();
        let live = self.live_for(id);
        live.push(sse_frame(Some("state"), &rec.to_reply().to_json_brief()));

        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(session, &rec, &live)
        }))
        .unwrap_or_else(|p| {
            Err(ApiError::internal(format!("job panicked: {}", crate::util::panic_text(&p))))
        });

        let dur_ms = started.elapsed().as_millis() as u64;
        match outcome {
            Ok(reply_json) => {
                if live.requeue.load(Ordering::SeqCst) {
                    self.store.mark_requeued(id);
                    self.finish_live(id);
                    log::info("jobs", "job requeued for next boot", &[("job", &rec.id)]);
                } else if live.cancel.load(Ordering::SeqCst) {
                    self.store.mark_cancelled(id);
                    self.finish_live(id);
                    log::info("jobs", "job cancelled", &[("job", &rec.id), ("ms", &dur_ms)]);
                } else {
                    self.store.mark_done(id, &reply_json);
                    self.finish_live(id);
                    log::info("jobs", "job done", &[("job", &rec.id), ("ms", &dur_ms)]);
                }
            }
            Err(e) => {
                // 5xx-class failures are transient (backend hiccup);
                // validation errors would fail identically on retry.
                let transient = e.http_status() >= 500;
                if transient && rec.attempts < self.opts.max_attempts {
                    self.store.mark_failed(id, &e.message, false);
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    let shift = (rec.attempts.saturating_sub(1)).min(6) as u32;
                    let backoff = Duration::from_millis(self.opts.backoff_ms << shift);
                    log::warn(
                        "jobs",
                        "job attempt failed; retrying",
                        &[
                            ("job", &rec.id),
                            ("attempt", &rec.attempts),
                            ("backoff_ms", &backoff.as_millis()),
                            ("error", &e.message),
                        ],
                    );
                    live.push(sse_frame(
                        Some("state"),
                        &self.store.get(id).map(|r| r.to_reply().to_json_brief()).unwrap_or_default(),
                    ));
                    let mut q = self.queue.lock().unwrap();
                    q.push(QueueItem { due: Instant::now() + backoff, id: id.to_string() });
                    drop(q);
                    self.queue_cv.notify_one();
                } else {
                    self.store.mark_failed(id, &e.message, true);
                    self.finish_live(id);
                    log::warn(
                        "jobs",
                        "job failed",
                        &[("job", &rec.id), ("ms", &dur_ms), ("error", &e.message)],
                    );
                }
            }
        }
    }

    /// Stop accepting new jobs (submissions become 503).
    pub fn begin_drain(&self) {
        self.accepting.store(false, Ordering::SeqCst);
    }

    /// Graceful shutdown: stop accepting and stop starting queued jobs,
    /// give running jobs up to `budget` to finish, then ask stragglers
    /// to re-queue themselves (they resume on the next boot), and join
    /// the workers.
    pub fn drain(&self, budget: Duration) -> DrainSummary {
        self.begin_drain();
        let before = self.store.counts();
        // Workers finish their current job and exit; queued jobs stay
        // queued in the WAL for the next boot.
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue_cv.notify_all();
        let deadline = Instant::now() + budget;
        while Instant::now() < deadline && self.store.counts().running > 0 {
            std::thread::sleep(Duration::from_millis(25));
        }
        // Budget exhausted: flag survivors to re-queue at their next
        // progress event, and give them a short grace to comply.
        let mut requeued = 0u64;
        if self.store.counts().running > 0 {
            for live in self.live.lock().unwrap().values() {
                live.request_requeue();
            }
            let grace = Instant::now() + Duration::from_secs(5).min(budget.max(Duration::from_secs(1)));
            while Instant::now() < grace && self.store.counts().running > 0 {
                std::thread::sleep(Duration::from_millis(25));
            }
            requeued = self.store.counts().queued.saturating_sub(before.queued);
        }
        // Join whatever workers have exited; a worker stuck in a search
        // that ignores its sink is left detached rather than blocking
        // shutdown forever.
        let mut workers = self.workers.lock().unwrap();
        let drained: Vec<_> = workers.drain(..).collect();
        drop(workers);
        for h in drained {
            if h.is_finished() {
                let _ = h.join();
            }
        }
        let after = self.store.counts();
        DrainSummary {
            completed: (after.done + after.failed + after.cancelled)
                .saturating_sub(before.done + before.failed + before.cancelled),
            requeued,
            queued_left: after.queued,
        }
    }
}

/// Execute the stored request with a sink that renders SSE frames and
/// honors the live cancellation flags. Returns raw reply JSON.
fn run_job(session: &mut Session, rec: &JobRecord, live: &JobLive) -> Result<String, ApiError> {
    let mut n = 0usize;
    let mut sink = |p: &Progress| {
        if n % 32 == 0 {
            live.push(sse_frame(None, &p.to_ndjson_with(&rec.corr)));
        }
        n += 1;
        !live.should_stop()
    };
    match rec.kind {
        crate::api::job::JobKind::Search => {
            let plan = SearchRequest::from_json_str(&rec.request)?.validate()?;
            session.run_search(&plan, &mut sink).map(|r| r.to_json())
        }
        crate::api::job::JobKind::Common => {
            // `run_common` has no sink: common jobs report only state
            // transitions and cannot cancel mid-run.
            let plan = CommonRequest::from_json_str(&rec.request)?.validate()?;
            session.run_common(&plan).map(|r| r.to_json())
        }
        crate::api::job::JobKind::Global => {
            let plan = GlobalRequest::from_json_str(&rec.request)?.validate()?;
            session.run_global(&plan, &mut sink).map(|r| r.to_json())
        }
        crate::api::job::JobKind::Cluster => {
            let plan = ClusterRequest::from_json_str(&rec.request)?.validate()?;
            session.run_cluster(&plan, &mut sink).map(|r| r.to_json())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::JobRequest;

    fn manager(opts: JobsOptions) -> Arc<JobManager> {
        JobManager::start(Arc::new(JobStore::in_memory()), opts, || {
            Session::with_backend(Box::new(crate::cost::native::NativeCost)).with_jobs(1)
        })
    }

    fn wait_terminal(mgr: &JobManager, id: &str, secs: u64) -> JobRecord {
        let deadline = Instant::now() + Duration::from_secs(secs);
        loop {
            let rec = mgr.store().get(id).expect("job exists");
            if rec.state.is_terminal() {
                return rec;
            }
            assert!(Instant::now() < deadline, "job {id} stuck in {:?}", rec.state);
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    #[test]
    fn job_runs_to_done_with_sse_frames() {
        let mgr = manager(JobsOptions::default());
        let plan = JobRequest::search("alexnet").validate().unwrap();
        let rec = mgr.submit(&plan).unwrap();
        let live = mgr.watch(&rec.id);
        let done = wait_terminal(&mgr, &rec.id, 60);
        assert_eq!(done.state, JobState::Done);
        assert_eq!(done.attempts, 1);
        let reply = done.reply.expect("done job has a reply");
        assert!(reply.contains("\"best\""), "unexpected reply {reply}");
        // The live channel existed while running and carried frames.
        if let Some(live) = live {
            let (frames, _, terminal) = live.wait(0, Duration::from_millis(10));
            assert!(terminal);
            assert!(frames.iter().any(|f| f.starts_with("event: state")), "{frames:?}");
        }
        assert_eq!(mgr.stats().submitted, 1);
        assert_eq!(mgr.counts().done, 1);
    }

    #[test]
    fn queue_depth_and_quota_reject_at_the_door() {
        // No workers pulling fast enough matters here: depth 0 means the
        // first un-started job already fills the queue.
        let mgr = manager(JobsOptions {
            queue_depth: 0,
            quota_rate: 1000.0,
            quota_burst: 10.0,
            ..JobsOptions::default()
        });
        let plan = JobRequest::search("alexnet").validate().unwrap();
        match mgr.submit(&plan) {
            Err(SubmitError::QueueFull { retry_after_secs }) => assert!(retry_after_secs >= 1),
            other => panic!("expected QueueFull, got {other:?}"),
        }
        assert_eq!(mgr.stats().rejected_depth, 1);

        let mgr = manager(JobsOptions {
            quota_rate: 0.001,
            quota_burst: 1.0,
            ..JobsOptions::default()
        });
        let a = mgr.submit(&plan).unwrap();
        match mgr.submit(&plan) {
            Err(SubmitError::QuotaExhausted { retry_after_secs }) => {
                assert!(retry_after_secs >= 1)
            }
            other => panic!("expected QuotaExhausted, got {other:?}"),
        }
        assert_eq!(mgr.stats().rejected_quota, 1);
        // A different client has its own bucket.
        let other = JobRequest::search("alexnet").with_client("b").validate().unwrap();
        mgr.submit(&other).unwrap();
        wait_terminal(&mgr, &a.id, 60);
    }

    #[test]
    fn cancelling_a_queued_job_never_runs_it() {
        // One worker busy on a real job keeps the second one queued.
        let mgr = manager(JobsOptions { workers: 1, ..JobsOptions::default() });
        let plan = JobRequest::search("alexnet").validate().unwrap();
        let first = mgr.submit(&plan).unwrap();
        let second = mgr.submit(&plan).unwrap();
        let rec = mgr.cancel(&second.id).unwrap();
        // Either it was still queued (immediate cancel) or the first
        // finished so fast it started — both end non-running.
        if rec.state == JobState::Queued {
            panic!("cancel left the job queued");
        }
        let done = wait_terminal(&mgr, &second.id, 60);
        assert!(
            done.state == JobState::Cancelled || done.state == JobState::Done,
            "{:?}",
            done.state
        );
        if done.state == JobState::Cancelled {
            assert!(done.started_ms.is_none(), "cancelled-while-queued job must never start");
        }
        wait_terminal(&mgr, &first.id, 60);
        assert!(mgr.cancel("j-nope-0000").is_none());
    }

    #[test]
    fn drain_lets_running_jobs_finish_and_leaves_queue_for_next_boot() {
        let mgr = manager(JobsOptions { workers: 1, ..JobsOptions::default() });
        let plan = JobRequest::search("alexnet").validate().unwrap();
        let a = mgr.submit(&plan).unwrap();
        let summary = mgr.drain(Duration::from_secs(60));
        let rec = mgr.store().get(&a.id).unwrap();
        assert!(
            rec.state == JobState::Done || rec.state == JobState::Queued,
            "drain left {:?}",
            rec.state
        );
        if rec.state == JobState::Done {
            assert_eq!(summary.completed, 1);
        }
        // Draining means the door is closed.
        assert_eq!(mgr.submit(&plan), Err(SubmitError::Draining));
    }
}
