//! Per-client token-bucket admission quotas.
//!
//! Each client name (the `"client"` field of a [`crate::api::JobRequest`])
//! owns a bucket of `burst` tokens refilled at `rate` tokens/second. A
//! submission takes one token; an empty bucket is a `429 Too Many
//! Requests` with a `Retry-After` telling the client when one token will
//! have accumulated — load is shed at the door instead of queued
//! unboundedly.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Keep at most this many client buckets; beyond it, full (idle)
/// buckets are evicted so a client-name cardinality attack cannot grow
/// memory without bound.
const MAX_CLIENTS: usize = 1024;

struct Bucket {
    tokens: f64,
    refreshed: Instant,
}

/// Token-bucket table. `rate <= 0` disables quotas entirely.
pub struct QuotaGate {
    rate: f64,
    burst: f64,
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl QuotaGate {
    pub fn new(rate: f64, burst: f64) -> Self {
        QuotaGate { rate, burst: burst.max(1.0), buckets: Mutex::new(HashMap::new()) }
    }

    /// Take one token for `client`. `Err(retry_after_secs)` when the
    /// bucket is empty.
    pub fn take(&self, client: &str) -> Result<(), u64> {
        if self.rate <= 0.0 {
            return Ok(());
        }
        let mut buckets = self.buckets.lock().unwrap();
        let now = Instant::now();
        if buckets.len() >= MAX_CLIENTS && !buckets.contains_key(client) {
            let rate = self.rate;
            let burst = self.burst;
            buckets.retain(|_, b| {
                let refilled =
                    (b.tokens + now.duration_since(b.refreshed).as_secs_f64() * rate).min(burst);
                refilled < burst
            });
            // All buckets busy (cardinality attack in progress): evict
            // arbitrarily rather than grow — a refreshed bucket only
            // means one extra burst for the evicted name.
            while buckets.len() >= MAX_CLIENTS {
                let Some(k) = buckets.keys().next().cloned() else { break };
                buckets.remove(&k);
            }
        }
        let b = buckets
            .entry(client.to_string())
            .or_insert_with(|| Bucket { tokens: self.burst, refreshed: now });
        b.tokens =
            (b.tokens + now.duration_since(b.refreshed).as_secs_f64() * self.rate).min(self.burst);
        b.refreshed = now;
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            // Seconds until one whole token exists, rounded up (a
            // Retry-After of 0 would invite an immediate re-hit).
            let secs = ((1.0 - b.tokens) / self.rate).ceil().max(1.0);
            Err(secs as u64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_429_then_refill() {
        let gate = QuotaGate::new(1000.0, 2.0);
        assert!(gate.take("a").is_ok());
        assert!(gate.take("a").is_ok());
        let retry = gate.take("a").unwrap_err();
        assert!(retry >= 1, "Retry-After must be at least 1s, got {retry}");
        // Other clients have their own buckets.
        assert!(gate.take("b").is_ok());
        // At 1000 tokens/s the bucket refills almost immediately.
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(gate.take("a").is_ok());
    }

    #[test]
    fn zero_rate_disables_quota() {
        let gate = QuotaGate::new(0.0, 1.0);
        for _ in 0..100 {
            assert!(gate.take("a").is_ok());
        }
    }

    #[test]
    fn slow_refill_reports_wait() {
        let gate = QuotaGate::new(0.1, 1.0);
        assert!(gate.take("a").is_ok());
        let retry = gate.take("a").unwrap_err();
        assert!((1..=10).contains(&retry), "~10s expected, got {retry}");
    }

    #[test]
    fn bucket_table_is_bounded() {
        let gate = QuotaGate::new(1.0, 4.0);
        for i in 0..(MAX_CLIENTS * 2) {
            let _ = gate.take(&format!("client-{i}"));
        }
        // Every bucket above was left non-full (one token taken), so
        // the idle sweep reclaims nothing — the hard eviction must
        // still bound the table.
        let len = gate.buckets.lock().unwrap().len();
        assert!(len <= MAX_CLIENTS, "table grew to {len}");
    }
}
