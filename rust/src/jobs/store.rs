//! Crash-safe job persistence: a JSONL write-ahead log in the
//! design-database style ([`crate::service::cache`]).
//!
//! Every lifecycle transition appends one self-describing line —
//! `{"ev":"submit",...}`, `{"ev":"start",...}`, `{"ev":"done",...}` — and
//! the file is replayed on open. Replay is tolerant of a torn tail (a
//! `kill -9` mid-append leaves a partial last line, which is skipped
//! exactly like the design DB skips unparseable entries), and any job
//! found `running` after replay is demoted back to `queued`: its attempt
//! died with the process, so the dispatcher re-runs it. Because the
//! design DB already holds every point the dead attempt mined, the
//! re-run warm-starts and typically completes with zero scheduler
//! invocations — that is the crash-resume story of this subsystem.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::api::job::{JobKind, JobReply, JobState};
use crate::util::json::{self, JsonValue, Obj};

/// Milliseconds since the Unix epoch (0 if the clock is before it).
pub fn epoch_ms() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_millis() as u64).unwrap_or(0)
}

/// Everything the store knows about one job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub id: String,
    pub kind: JobKind,
    pub client: String,
    /// Canonical inner request JSON (what `JobPlan::request_json` held
    /// at admission) — enough to re-execute after a restart.
    pub request: String,
    pub state: JobState,
    pub attempts: u64,
    pub submitted_ms: u64,
    pub started_ms: Option<u64>,
    pub finished_ms: Option<u64>,
    pub error: Option<String>,
    /// Raw reply JSON once `Done`.
    pub reply: Option<String>,
    /// Correlation id of the submitting HTTP request (empty for jobs
    /// replayed from pre-correlation logs) — the same id the client saw
    /// in `X-Wham-Request-Id`, so a WAL line greps to its access log.
    pub corr: String,
}

impl JobRecord {
    /// The wire view of this record.
    pub fn to_reply(&self) -> JobReply {
        JobReply {
            id: self.id.clone(),
            kind: self.kind,
            client: self.client.clone(),
            state: self.state,
            attempts: self.attempts,
            submitted_ms: self.submitted_ms,
            started_ms: self.started_ms,
            finished_ms: self.finished_ms,
            error: self.error.clone(),
            reply: self.reply.clone(),
            corr: self.corr.clone(),
        }
    }
}

/// Per-state job totals (queue depth and gauge fodder).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JobCounts {
    pub queued: u64,
    pub running: u64,
    pub done: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Age in ms of the oldest still-queued job (0 when none queued).
    pub oldest_queued_ms: u64,
}

struct Inner {
    /// id → record, plus submission order for listing.
    map: HashMap<String, JobRecord>,
    order: Vec<String>,
    /// Monotonic id counter (restored past replayed ids on open).
    next_id: u64,
    /// Salt making ids from different store generations distinct.
    salt: u64,
}

/// The write-ahead job store. All mutations go through methods that
/// append an event line before returning, so the on-disk log is always
/// at least as new as what any observer saw.
pub struct JobStore {
    inner: Mutex<Inner>,
    writer: Mutex<Option<BufWriter<File>>>,
    path: Option<PathBuf>,
    /// Events skipped during replay (torn tail, foreign lines).
    skipped: u64,
    /// Jobs demoted `running → queued` during replay (crash resumes).
    resumed: u64,
}

impl JobStore {
    /// Volatile store (tests, `wham serve` without `--jobs-db`).
    pub fn in_memory() -> Self {
        JobStore {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: Vec::new(),
                next_id: 0,
                salt: epoch_ms(),
            }),
            writer: Mutex::new(None),
            path: None,
            skipped: 0,
            resumed: 0,
        }
    }

    /// Open (or create) the JSONL log at `path` and replay it.
    pub fn open(path: &Path) -> std::io::Result<Self> {
        let mut map: HashMap<String, JobRecord> = HashMap::new();
        let mut order: Vec<String> = Vec::new();
        let mut skipped = 0u64;
        if let Ok(text) = std::fs::read_to_string(path) {
            for line in text.lines() {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                match json::parse(line).ok().and_then(|v| apply_event(&mut map, &mut order, &v)) {
                    Some(()) => {}
                    // A torn tail or foreign line is data loss already —
                    // keep every event that did land.
                    None => skipped += 1,
                }
            }
        }
        // Attempts that were mid-flight when the process died re-queue.
        let mut resumed = 0u64;
        for rec in map.values_mut() {
            if rec.state == JobState::Running {
                rec.state = JobState::Queued;
                resumed += 1;
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JobStore {
            inner: Mutex::new(Inner { map, order, next_id: 0, salt: epoch_ms() }),
            writer: Mutex::new(Some(BufWriter::new(file))),
            path: Some(path.to_path_buf()),
            skipped,
            resumed,
        })
    }

    /// Where the log lives (`None` for in-memory stores).
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Lines skipped during replay.
    pub fn skipped(&self) -> u64 {
        self.skipped
    }

    /// Jobs found `running` at open time and re-queued.
    pub fn resumed(&self) -> u64 {
        self.resumed
    }

    fn append(&self, line: &str) {
        let mut w = self.writer.lock().unwrap();
        if let Some(w) = w.as_mut() {
            // Mirror the design DB: losing an event to a full disk
            // degrades restart fidelity, not correctness of this run.
            let _ = writeln!(w, "{line}");
            let _ = w.flush();
        }
    }

    /// Admit a new job in state `Queued` and return its record. `corr`
    /// is the submitting request's correlation id (empty when none).
    pub fn submit(&self, kind: JobKind, client: &str, request_json: &str, corr: &str) -> JobRecord {
        let now = epoch_ms();
        let rec = {
            let mut inner = self.inner.lock().unwrap();
            let id = loop {
                let candidate = format!("j-{:x}-{:04x}", inner.salt, inner.next_id);
                inner.next_id += 1;
                if !inner.map.contains_key(&candidate) {
                    break candidate;
                }
            };
            let rec = JobRecord {
                id: id.clone(),
                kind,
                client: client.to_string(),
                request: request_json.to_string(),
                state: JobState::Queued,
                attempts: 0,
                submitted_ms: now,
                started_ms: None,
                finished_ms: None,
                error: None,
                reply: None,
                corr: corr.to_string(),
            };
            inner.map.insert(id.clone(), rec.clone());
            inner.order.push(id);
            rec
        };
        let mut line = Obj::new()
            .str("ev", "submit")
            .str("id", &rec.id)
            .u64("t", now)
            .str("kind", kind.label())
            .str("client", client);
        if !corr.is_empty() {
            line = line.str("corr", corr);
        }
        self.append(&line.raw("request", request_json).finish());
        rec
    }

    /// Mark `id` running (one more attempt).
    pub fn mark_running(&self, id: &str) -> Option<JobRecord> {
        let now = epoch_ms();
        let rec = {
            let mut inner = self.inner.lock().unwrap();
            let rec = inner.map.get_mut(id)?;
            rec.state = JobState::Running;
            rec.attempts += 1;
            rec.started_ms = Some(now);
            rec.clone()
        };
        self.append(
            &Obj::new().str("ev", "start").str("id", id).u64("t", now).u64("attempt", rec.attempts).finish(),
        );
        Some(rec)
    }

    /// Terminal success with the raw reply JSON.
    pub fn mark_done(&self, id: &str, reply_json: &str) {
        let now = epoch_ms();
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(rec) = inner.map.get_mut(id) {
                rec.state = JobState::Done;
                rec.finished_ms = Some(now);
                rec.reply = Some(reply_json.to_string());
                rec.error = None;
            }
        }
        self.append(
            &Obj::new().str("ev", "done").str("id", id).u64("t", now).raw("reply", reply_json).finish(),
        );
    }

    /// Failure. `terminal: false` re-queues the job (retry with backoff);
    /// `terminal: true` is the end of the line.
    pub fn mark_failed(&self, id: &str, error: &str, terminal: bool) {
        let now = epoch_ms();
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(rec) = inner.map.get_mut(id) {
                rec.error = Some(error.to_string());
                if terminal {
                    rec.state = JobState::Failed;
                    rec.finished_ms = Some(now);
                } else {
                    rec.state = JobState::Queued;
                }
            }
        }
        self.append(
            &Obj::new()
                .str("ev", "fail")
                .str("id", id)
                .u64("t", now)
                .str("error", error)
                .bool("terminal", terminal)
                .finish(),
        );
    }

    /// Terminal cooperative cancellation.
    pub fn mark_cancelled(&self, id: &str) {
        let now = epoch_ms();
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(rec) = inner.map.get_mut(id) {
                rec.state = JobState::Cancelled;
                rec.finished_ms = Some(now);
            }
        }
        self.append(&Obj::new().str("ev", "cancel").str("id", id).u64("t", now).finish());
    }

    /// Put a running job back in the queue without a failure (graceful
    /// drain ran out of budget; the next boot resumes it).
    pub fn mark_requeued(&self, id: &str) {
        let now = epoch_ms();
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(rec) = inner.map.get_mut(id) {
                if !rec.state.is_terminal() {
                    rec.state = JobState::Queued;
                }
            }
        }
        self.append(&Obj::new().str("ev", "requeue").str("id", id).u64("t", now).finish());
    }

    /// Snapshot one record.
    pub fn get(&self, id: &str) -> Option<JobRecord> {
        self.inner.lock().unwrap().map.get(id).cloned()
    }

    /// All records in submission order (replayed jobs first).
    pub fn list(&self) -> Vec<JobRecord> {
        let inner = self.inner.lock().unwrap();
        inner.order.iter().filter_map(|id| inner.map.get(id).cloned()).collect()
    }

    /// Ids currently queued, in submission order — what the dispatcher
    /// re-enqueues on boot.
    pub fn queued_ids(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        inner
            .order
            .iter()
            .filter(|id| inner.map.get(*id).is_some_and(|r| r.state == JobState::Queued))
            .cloned()
            .collect()
    }

    /// Per-state totals plus oldest-queued age.
    pub fn counts(&self) -> JobCounts {
        let inner = self.inner.lock().unwrap();
        let mut c = JobCounts::default();
        let now = epoch_ms();
        let mut oldest: Option<u64> = None;
        for rec in inner.map.values() {
            match rec.state {
                JobState::Queued => {
                    c.queued += 1;
                    let age = now.saturating_sub(rec.submitted_ms);
                    oldest = Some(oldest.map_or(age, |o: u64| o.max(age)));
                }
                JobState::Running => c.running += 1,
                JobState::Done => c.done += 1,
                JobState::Failed => c.failed += 1,
                JobState::Cancelled => c.cancelled += 1,
            }
        }
        c.oldest_queued_ms = oldest.unwrap_or(0);
        c
    }

    /// Rewrite the log as one `submit`-equivalent snapshot line per job
    /// (plus its terminal event), dropping the replay cost of a long
    /// event history. Called at graceful shutdown.
    pub fn checkpoint(&self) -> std::io::Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        let records = self.list();
        let tmp = path.with_extension("jsonl.tmp");
        {
            let mut w = BufWriter::new(File::create(&tmp)?);
            for rec in &records {
                writeln!(w, "{}", snapshot_lines(rec).join("\n"))?;
            }
            w.flush()?;
        }
        // Swap the compacted log in, then reopen the appender on it.
        let mut writer = self.writer.lock().unwrap();
        std::fs::rename(&tmp, path)?;
        *writer = Some(BufWriter::new(OpenOptions::new().create(true).append(true).open(path)?));
        Ok(())
    }
}

/// The event lines that reconstruct `rec` from an empty log.
fn snapshot_lines(rec: &JobRecord) -> Vec<String> {
    let mut submit = Obj::new()
        .str("ev", "submit")
        .str("id", &rec.id)
        .u64("t", rec.submitted_ms)
        .str("kind", rec.kind.label())
        .str("client", &rec.client);
    if !rec.corr.is_empty() {
        submit = submit.str("corr", &rec.corr);
    }
    let mut lines = vec![submit.raw("request", &rec.request).finish()];
    if rec.attempts > 0 {
        lines.push(
            Obj::new()
                .str("ev", "start")
                .str("id", &rec.id)
                .u64("t", rec.started_ms.unwrap_or(rec.submitted_ms))
                .u64("attempt", rec.attempts)
                .finish(),
        );
    }
    let t = rec.finished_ms.unwrap_or(rec.submitted_ms);
    match rec.state {
        JobState::Done => lines.push(
            Obj::new()
                .str("ev", "done")
                .str("id", &rec.id)
                .u64("t", t)
                .raw("reply", rec.reply.as_deref().unwrap_or("null"))
                .finish(),
        ),
        JobState::Failed => lines.push(
            Obj::new()
                .str("ev", "fail")
                .str("id", &rec.id)
                .u64("t", t)
                .str("error", rec.error.as_deref().unwrap_or(""))
                .bool("terminal", true)
                .finish(),
        ),
        JobState::Cancelled => {
            lines.push(Obj::new().str("ev", "cancel").str("id", &rec.id).u64("t", t).finish())
        }
        // Queued/Running replay back to Queued via the demotion rule.
        JobState::Queued | JobState::Running => {}
    }
    lines
}

/// Apply one replayed event; `None` marks the line unusable.
fn apply_event(
    map: &mut HashMap<String, JobRecord>,
    order: &mut Vec<String>,
    v: &JsonValue,
) -> Option<()> {
    let ev = v.get("ev")?.as_str()?;
    let id = v.get("id")?.as_str()?.to_string();
    let t = v.get("t").and_then(JsonValue::as_u64).unwrap_or(0);
    match ev {
        "submit" => {
            let kind: JobKind = v.get("kind")?.as_str()?.parse().ok()?;
            let client = v.get("client")?.as_str()?.to_string();
            let request = json::dump(v.get("request")?);
            let corr =
                v.get("corr").and_then(JsonValue::as_str).unwrap_or_default().to_string();
            if !map.contains_key(&id) {
                order.push(id.clone());
            }
            map.insert(
                id.clone(),
                JobRecord {
                    id,
                    kind,
                    client,
                    request,
                    state: JobState::Queued,
                    attempts: 0,
                    submitted_ms: t,
                    started_ms: None,
                    finished_ms: None,
                    error: None,
                    reply: None,
                    corr,
                },
            );
            Some(())
        }
        "start" => {
            let rec = map.get_mut(&id)?;
            rec.state = JobState::Running;
            rec.attempts = v.get("attempt").and_then(JsonValue::as_u64).unwrap_or(rec.attempts + 1);
            rec.started_ms = Some(t);
            Some(())
        }
        "done" => {
            let reply = json::dump(v.get("reply")?);
            let rec = map.get_mut(&id)?;
            rec.state = JobState::Done;
            rec.finished_ms = Some(t);
            rec.reply = Some(reply);
            rec.error = None;
            Some(())
        }
        "fail" => {
            let error = v.get("error")?.as_str()?.to_string();
            let terminal = v.get("terminal").and_then(JsonValue::as_bool).unwrap_or(true);
            let rec = map.get_mut(&id)?;
            rec.error = Some(error);
            if terminal {
                rec.state = JobState::Failed;
                rec.finished_ms = Some(t);
            } else {
                rec.state = JobState::Queued;
            }
            Some(())
        }
        "cancel" => {
            let rec = map.get_mut(&id)?;
            rec.state = JobState::Cancelled;
            rec.finished_ms = Some(t);
            Some(())
        }
        "requeue" => {
            let rec = map.get_mut(&id)?;
            if !rec.state.is_terminal() {
                rec.state = JobState::Queued;
            }
            Some(())
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("wham_jobs_{tag}_{}_{}.jsonl", std::process::id(), epoch_ms()));
        let _ = std::fs::remove_file(&p);
        p
    }

    #[test]
    fn lifecycle_round_trips_through_the_log() {
        let path = temp("lifecycle");
        let store = JobStore::open(&path).unwrap();
        let a = store.submit(JobKind::Search, "ci", r#"{"model":"bert-base"}"#, "r-corr-a");
        let b = store.submit(JobKind::Search, "ci", r#"{"model":"vgg16"}"#, "");
        assert_ne!(a.id, b.id);
        store.mark_running(&a.id);
        store.mark_done(&a.id, r#"{"best":1}"#);
        store.mark_running(&b.id);
        store.mark_failed(&b.id, "backend exploded", true);
        drop(store);

        let back = JobStore::open(&path).unwrap();
        assert_eq!(back.skipped(), 0);
        assert_eq!(back.resumed(), 0);
        let a2 = back.get(&a.id).unwrap();
        assert_eq!(a2.state, JobState::Done);
        assert_eq!(a2.reply.as_deref(), Some(r#"{"best":1}"#));
        assert_eq!(a2.attempts, 1);
        assert_eq!(a2.corr, "r-corr-a", "correlation id must survive replay");
        let b2 = back.get(&b.id).unwrap();
        assert_eq!(b2.state, JobState::Failed);
        assert_eq!(b2.error.as_deref(), Some("backend exploded"));
        assert_eq!(b2.corr, "", "absent corr replays as empty");
        let counts = back.counts();
        assert_eq!((counts.done, counts.failed, counts.queued), (1, 1, 0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_is_skipped_and_running_jobs_resume_queued() {
        let path = temp("torn");
        let store = JobStore::open(&path).unwrap();
        let a = store.submit(JobKind::Search, "ci", r#"{"model":"bert-base"}"#, "");
        store.mark_running(&a.id);
        drop(store);
        // Simulate a kill -9 mid-append: a partial final line.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        write!(f, "{{\"ev\":\"done\",\"id\":\"{}\",\"reply\":{{\"tr", a.id).unwrap();
        drop(f);

        let back = JobStore::open(&path).unwrap();
        assert_eq!(back.skipped(), 1, "torn tail must be skipped, not fatal");
        assert_eq!(back.resumed(), 1, "running job must re-queue");
        let a2 = back.get(&a.id).unwrap();
        assert_eq!(a2.state, JobState::Queued);
        assert_eq!(a2.attempts, 1, "the dead attempt still counts");
        assert_eq!(back.queued_ids(), vec![a.id.clone()]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn non_terminal_failure_requeues_and_checkpoint_compacts() {
        let path = temp("ckpt");
        let store = JobStore::open(&path).unwrap();
        let a = store.submit(JobKind::Global, "x", r#"{"models":["gpt2-xl"]}"#, "r-ckpt");
        store.mark_running(&a.id);
        store.mark_failed(&a.id, "transient", false);
        assert_eq!(store.get(&a.id).unwrap().state, JobState::Queued);
        store.mark_running(&a.id);
        store.mark_done(&a.id, r#"{"rows":[]}"#);
        let before = std::fs::read_to_string(&path).unwrap().lines().count();
        store.checkpoint().unwrap();
        let after = std::fs::read_to_string(&path).unwrap().lines().count();
        assert!(after < before, "checkpoint must compact ({before} -> {after})");
        // Appends keep working on the swapped-in file, and replay agrees.
        let b = store.submit(JobKind::Search, "x", r#"{"model":"vgg16"}"#, "");
        drop(store);
        let back = JobStore::open(&path).unwrap();
        assert_eq!(back.get(&a.id).unwrap().state, JobState::Done);
        assert_eq!(back.get(&a.id).unwrap().attempts, 2);
        assert_eq!(back.get(&a.id).unwrap().corr, "r-ckpt", "corr survives checkpoint");
        assert_eq!(back.get(&b.id).unwrap().state, JobState::Queued);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn counts_track_oldest_queued_age() {
        let store = JobStore::in_memory();
        assert_eq!(store.counts().oldest_queued_ms, 0);
        store.submit(JobKind::Search, "a", "{}", "");
        std::thread::sleep(std::time::Duration::from_millis(5));
        let c = store.counts();
        assert_eq!(c.queued, 1);
        assert!(c.oldest_queued_ms >= 5, "age was {}", c.oldest_queued_ms);
    }
}
