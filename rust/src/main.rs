//! `wham` — CLI for the WHAM accelerator-mining reproduction.
//!
//! Every mining subcommand is a thin adapter over [`wham::api`]: flags
//! build the same typed request (`SearchRequest`, `EvaluateRequest`,
//! `CommonRequest`, `GlobalRequest`) that the HTTP service deserializes
//! from JSON, and a [`wham::api::Session`] executes it. `wham client`
//! serializes those requests onto the wire with the same codec the
//! server parses — the CLI and the service cannot drift apart.
//!
//! Subcommands:
//! * `models` — list the Table-4 workload zoo;
//! * `search` — per-workload accelerator search (section 4);
//! * `evaluate` — evaluate one fixed design on a workload;
//! * `common` — one design across a workload set (section 4.6);
//! * `global` — distributed pipeline/TMP search (section 5);
//! * `cluster` — topology-aware parallelism-strategy sweep over a
//!   device budget (see [`wham::cluster`]);
//! * `baseline` — run ConfuciuX+ / Spotlight+ / hand-optimized designs;
//! * `serve` — long-running design-mining service (see [`wham::service`]);
//! * `client` — drive a running `wham serve` over HTTP;
//! * `jobs` — submit/poll/watch/cancel durable async jobs on a server
//!   (see [`wham::jobs`]); also reachable as `wham client jobs ...`;
//! * `db` — design-database export/import against a server, plus local
//!   offline merge of JSONL snapshots;
//! * `selftest` — verify the PJRT artifact against the native mirror.

use anyhow::{anyhow, bail, Result};
use wham::api::request::{backend_from_args, parse_dims};
use wham::api::{
    resolve_workload, ClusterRequest, CommonRequest, EvaluateRequest, GlobalRequest, JobRequest,
    NullSink, Progress, ProgressSink, SearchRequest, Session, ToJson,
};
use wham::baselines::{confuciux, spotlight};
use wham::coordinator::{make_backend, run_parallel, BackendChoice, SearchJob};
use wham::graph::autodiff::Optimizer;
use wham::report;
use wham::search::engine::{evaluate_design, SearchOptions};
use wham::util::cli::Args;
use wham::util::table::Table;

const VALUE_KEYS: &[&str] = &[
    "model", "models", "metric", "backend", "k", "depth", "tmp", "scheme", "framework",
    "iterations", "workers", "jobs", "hysteresis", "seed", "out", "tc", "vc", "dims", "port",
    "db", "addr", "deadline-ms", "workload-dir", "devices", "topology", "schedules", "mine",
    "chunks", "trace-out", "client", "type", "jobs-db", "drain-secs", "job-workers",
    "queue-depth", "quota-rate", "quota-burst", "hz", "top", "log-level", "log-out",
    "timeline-out", "interval", "count", "window",
];

fn main() -> Result<()> {
    let args = Args::from_env(VALUE_KEYS).map_err(|e| anyhow!("{e}"))?;
    // Configure the structured-log layer before anything can emit a
    // record: `--log-level` raises/lowers the threshold, `--log-out`
    // redirects NDJSON records to a file (fully silencing the console
    // sides of `wham serve`).
    if let Some(lvl) = args.get("log-level") {
        let l = wham::telemetry::log::Level::parse(lvl)
            .ok_or_else(|| anyhow!("--log-level expects debug|info|warn|error, got {lvl:?}"))?;
        wham::telemetry::log::set_level(l);
    }
    if let Some(path) = args.get("log-out") {
        wham::telemetry::log::to_file(std::path::Path::new(path))
            .map_err(|e| anyhow!("--log-out {path}: {e}"))?;
    }
    // Populate the workload registry's user layer before dispatch, so
    // every subcommand (search/evaluate/common/global/serve/...) resolves
    // spec workloads by name. The env var applies always; the flag is
    // per-invocation.
    // Ambient config must not brick the CLI: a broken spec in the env
    // dir would otherwise abort even `wham workloads lint`, the tool for
    // diagnosing it. Warn and continue; the explicit flag stays fatal.
    match wham::workload::load_env_dir() {
        Ok(names) if !names.is_empty() => {
            wham::telemetry::log::info(
                "cli",
                "loaded workload specs from WHAM_WORKLOAD_DIR",
                &[("specs", &names.len())],
            );
        }
        Ok(_) => {}
        Err(e) => wham::telemetry::log::warn(
            "cli",
            "WHAM_WORKLOAD_DIR not loaded",
            &[("error", &e)],
        ),
    }
    if let Some(dir) = args.get("workload-dir") {
        let names = wham::workload::add_dir(dir).map_err(|e| anyhow!("--workload-dir: {e}"))?;
        wham::telemetry::log::info(
            "cli",
            "loaded workload specs",
            &[("dir", &dir), ("specs", &names.len()), ("names", &format!("{names:?}"))],
        );
    }
    match args.pos(0) {
        Some("models") => cmd_models(),
        Some("workloads") => cmd_workloads(&args),
        Some("search") => cmd_search(&args),
        Some("evaluate") => cmd_evaluate(&args),
        Some("common") => cmd_common(&args),
        Some("global") => cmd_global(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("baseline") => cmd_baseline(&args),
        Some("trace") => cmd_trace(&args),
        Some("partition") => cmd_partition(&args),
        Some("space") => cmd_space(&args),
        Some("serve") => cmd_serve(&args),
        Some("top") => cmd_top(&args),
        Some("client") => cmd_client(&args),
        Some("jobs") => cmd_jobs(&args, 1),
        Some("db") => cmd_db(&args, 1),
        Some("selftest") => cmd_selftest(&args),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "wham — Workload-Aware Hardware Accelerator Mining (CS.AR 2024 reproduction)\n\n\
         global flags: [--workload-dir DIR]  (or WHAM_WORKLOAD_DIR) — load *.json workload specs\n              \
         [--log-level debug|info|warn|error] [--log-out records.ndjson] — structured logs\n\n\
         usage:\n  \
         wham models\n  \
         wham workloads <list|show <name>|lint <path...>>\n  \
         wham search --model <name> [--metric throughput|perf/tdp] [--ilp]\n              \
         [--backend auto|native|pjrt] [--k 10] [--hysteresis 1] [--jobs N]\n              \
         [--deadline-ms N] [--progress] [--explain] [--trace-out spans.json]\n  \
         wham evaluate --model <name> --dims TXxTYxVW [--tc 2 --vc 2]\n  \
         wham common [--models a,b,c] [--metric ...]\n  \
         wham global [--models opt-1.3b,gpt2-xl] [--depth 32] [--tmp 1]\n              \
         [--scheme gpipe|1f1b] [--k 10] [--metric ...] [--jobs N] [--deadline-ms N]\n              \
         [--progress] [--trace-out spans.json]\n  \
         wham cluster --model <llm> [--devices 8] [--topology flat|ring|fat-tree|nvlink-island]\n              \
         [--schedules gpipe,1f1b,interleaved] [--mine 2] [--chunks 2]\n              \
         [--metric ...] [--jobs N] [--deadline-ms N] [--progress] [--trace-out spans.json]\n              \
         [--timeline-out timeline.json] — per-rank pipeline timeline (Chrome trace)\n  \
         wham baseline --model <name> --framework confuciux|spotlight|tpuv2|nvdla\n              \
         [--iterations 500]\n  \
         wham trace --model <name> [--out trace.json] [--tc 2 --vc 2 --dims 128x128x128]\n  \
         wham trace explain <model> — per-iteration search attribution (flight recorder)\n  \
         wham trace profile <model> [--hz 99] [--top 10] [--out prof.collapsed] [--smoke] [--full-reschedule]\n              \
         — sampled span-stack profile of the search (hottest paths + folded stacks)\n  \
         wham partition --model <llm> [--depth 32] [--tmp 1] [--scheme gpipe]\n  \
         wham space --model <name>\n  \
         wham serve [--port 8484] [--workers <cores>] [--db designs.jsonl] [--backend auto]\n              \
         [--jobs-db jobs.jsonl] [--job-workers 2] [--queue-depth 64]\n              \
         [--quota-rate 1.0] [--quota-burst 32] [--drain-secs 20] [--trace-out spans.json]\n  \
         wham top [--addr 127.0.0.1:8484] [--interval 2] [--count N] [--window 120]\n              \
         — live terminal ops view of a running server (rates, queue, alerts)\n  \
         wham client <models|search|evaluate|common|global|cluster|status|upload|jobs|db>\n              \
         [--addr 127.0.0.1:8484] ...\n  \
         wham jobs submit [--type search|common|global|cluster] [--client NAME] --model <name> ...\n  \
         wham jobs <status|watch|cancel|result> <job-id>   |   wham jobs list\n  \
         wham db export [--out db.jsonl]   |   wham db import <db.jsonl>\n  \
         wham db merge <a.jsonl> <b.jsonl> [...] --out merged.jsonl   (offline, no server)\n  \
         wham selftest"
    );
}

/// `--jobs N`: evaluation fan-out width, defaulting to the machine's
/// parallelism (searches are outcome-identical at any width).
fn jobs_from_args(args: &Args) -> Result<usize> {
    let jobs: usize =
        args.get_as_or("jobs", wham::util::default_jobs()).map_err(|e| anyhow!("{e}"))?;
    Ok(jobs.max(1))
}

/// Session over the `--backend` and `--jobs` flags.
fn session_from_args(args: &Args) -> Result<Session> {
    Ok(Session::new(backend_from_args(args)?)?.with_jobs(jobs_from_args(args)?))
}

/// `--trace-out FILE`: turn on span tracing for this invocation and
/// return the output path. Tracing stays fully off (one relaxed atomic
/// load per span site) when the flag is absent.
fn trace_out_from_args(args: &Args) -> Option<String> {
    let out = args.get("trace-out").map(str::to_string);
    if out.is_some() {
        wham::telemetry::trace::enable();
    }
    out
}

/// Flush the span buffer as Chrome-trace JSON if `--trace-out` was given.
fn flush_trace(out: &Option<String>) -> Result<()> {
    if let Some(path) = out {
        wham::telemetry::trace::write_to(std::path::Path::new(path))?;
        eprintln!(
            "wrote {} span event(s) to {path} — open in ui.perfetto.dev",
            wham::telemetry::trace::event_count()
        );
    }
    Ok(())
}

/// `--progress` emits one NDJSON object per event on stdout — machine
/// consumers get `{"phase":...,"ms":...,"points":...,"best":...,
/// "rate":...,"depth":...}` lines they can stream without a parser for
/// the human tables.
fn ndjson_progress(p: &Progress) -> bool {
    println!("{}", p.to_ndjson());
    true
}

/// Forward-graph parameter count of any registry entry, pretty-printed
/// (builtin constructors or spec lowering, depending on the layer).
fn entry_params(e: &wham::workload::SpecEntry) -> String {
    let g = match e.source {
        wham::workload::Source::Builtin => wham::models::forward(&e.name),
        _ => wham::workload::resolve_forward(&e.name).and_then(Result::ok),
    };
    g.map(|g| wham::util::human_count(g.param_elems() as f64)).unwrap_or_default()
}

fn cmd_models() -> Result<()> {
    let mut t = Table::new(["model", "task", "batch", "accelerators", "source", "params"]);
    for e in wham::workload::all_entries() {
        let params = entry_params(&e);
        t.row([
            e.name.clone(),
            e.task.clone(),
            e.batch.to_string(),
            e.accelerators.to_string(),
            e.source.label().to_string(),
            params,
        ]);
    }
    print!("{t}");
    Ok(())
}

/// `wham workloads <list|show <name>|lint <path...>>` — the registry's
/// CLI mirror.
fn cmd_workloads(args: &Args) -> Result<()> {
    match args.pos(1) {
        None | Some("list") => {
            let mut t = Table::new(["workload", "task", "batch", "source", "transformer"]);
            for e in wham::workload::all_entries() {
                t.row([
                    e.name.clone(),
                    e.task.clone(),
                    e.batch.to_string(),
                    e.source.label().to_string(),
                    wham::workload::transformer_cfg(&e.name).is_some().to_string(),
                ]);
            }
            print!("{t}");
            Ok(())
        }
        Some("show") => {
            let name = args
                .pos(2)
                .ok_or_else(|| anyhow!("usage: wham workloads show <name>"))?;
            if let Some(info) = wham::models::info(name) {
                println!("{name}: builtin Table-4 model (task={}, batch={})", info.task, info.batch);
            } else {
                let reg = wham::workload::get_spec(name)
                    .ok_or_else(|| anyhow!("unknown workload {name:?} (see `wham workloads list`)"))?;
                println!(
                    "{name}: {} spec (task={}, batch={}, transformer section: {})",
                    reg.source.label(),
                    reg.spec.task,
                    reg.spec.batch,
                    reg.spec.transformer.is_some(),
                );
            }
            let (graph, batch) = resolve_workload(name)?;
            println!(
                "  training graph: {} ops, {} edges, batch {batch}, fingerprint {}",
                graph.len(),
                graph.num_edges(),
                wham::graph::fingerprint(&graph),
            );
            let passes = graph.pass_counts();
            println!(
                "  passes: {} fwd / {} bwd / {} update / {} loss; {} param elems",
                passes[0],
                passes[1],
                passes[2],
                passes[3],
                wham::util::human_count(graph.param_elems() as f64),
            );
            Ok(())
        }
        Some("lint") => {
            let paths = &args.positionals()[2..];
            if paths.is_empty() {
                bail!("usage: wham workloads lint <spec.json> [more.json ...]");
            }
            let mut failed = 0usize;
            for path in paths {
                match std::fs::read_to_string(path)
                    .map_err(|e| format!("cannot read: {e}"))
                    .and_then(|text| wham::workload::lint(&text).map_err(|e| e.to_string()))
                {
                    Ok(r) => println!(
                        "OK   {path}: {} (batch {}, {} fwd ops -> {} training ops, fingerprint {})",
                        r.name, r.batch, r.forward_ops, r.training_ops, r.fingerprint
                    ),
                    Err(e) => {
                        println!("FAIL {path}: {e}");
                        failed += 1;
                    }
                }
            }
            if failed > 0 {
                bail!("{failed} of {} spec file(s) failed lint", paths.len());
            }
            Ok(())
        }
        Some(other) => bail!("unknown workloads subcommand {other:?} (list, show, lint)"),
    }
}

fn cmd_search(args: &Args) -> Result<()> {
    let trace_out = trace_out_from_args(args);
    let req = SearchRequest::from_args(args)?;
    let plan = req.validate()?;
    let mut session = session_from_args(args)?;
    println!(
        "searching {} ({} ops, backend={}, metric={}, {})",
        req.model,
        plan.graph.len(),
        session.backend_name(),
        req.metric,
        if req.use_ilp { "ILP" } else { "MCR heuristics" },
    );
    let mut progress = ndjson_progress;
    let mut null = NullSink;
    let sink: &mut dyn ProgressSink =
        if args.flag("progress") { &mut progress } else { &mut null };
    let r = session.run_search(&plan, sink)?;
    flush_trace(&trace_out)?;
    println!(
        "best: {}  score={:.4}  ({} dims, {} scheduler evals, {:.0}ms{})",
        r.best.config.display(),
        r.best.score,
        r.dims_evaluated,
        r.scheduler_evals,
        r.wall_ms,
        if r.cancelled { ", deadline hit" } else { "" },
    );
    println!("  {}", report::eval_line(&r.best.eval));
    println!("  vs TPUv2  : {:.3}x throughput", r.vs_tpuv2);
    println!("  vs NVDLA  : {:.3}x throughput", r.vs_nvdla);
    println!("top-{}:", r.top.len());
    let rows: Vec<(String, wham::search::DesignPoint)> =
        r.top.iter().map(|p| (req.model.clone(), *p)).collect();
    print!("{}", report::design_table(&rows));
    Ok(())
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let req = EvaluateRequest::from_args(args)?;
    let mut session = session_from_args(args)?;
    let r = session.evaluate(&req)?;
    println!("{} on {} (fingerprint {})", r.config.display(), r.model, r.fingerprint);
    println!("  {}", report::eval_line(&r.eval));
    Ok(())
}

fn cmd_common(args: &Args) -> Result<()> {
    let req = CommonRequest::from_args(args)?;
    let mut session = session_from_args(args)?;
    let r = session.common(&req)?;
    println!("WHAM-common over {} workloads (metric={})", r.models.len(), r.metric);
    println!(
        "common design: {}  weighted score={:.4}  ({} dims, {:.0}ms)",
        r.config.display(),
        r.score,
        r.dims_evaluated,
        r.wall_ms
    );
    print!("{}", report::design_table(&r.per_workload));
    Ok(())
}

fn cmd_global(args: &Args) -> Result<()> {
    let trace_out = trace_out_from_args(args);
    let req = GlobalRequest::from_args(args)?;
    let plan = req.validate()?;
    let mut session = session_from_args(args)?;
    println!(
        "global search: {} models, depth={}, tmp={}, scheme={:?}, metric={}",
        plan.models.len(),
        req.depth,
        req.tmp,
        req.scheme,
        req.metric
    );
    let mut progress = ndjson_progress;
    let mut null = NullSink;
    let sink: &mut dyn ProgressSink =
        if args.flag("progress") { &mut progress } else { &mut null };
    let r = session.run_global(&plan, sink)?;
    flush_trace(&trace_out)?;
    println!(
        "pool={} evaluated={} local_searches={} wall={:.0}ms{}",
        r.candidate_pool,
        r.candidates_evaluated,
        r.local_searches,
        r.wall_ms,
        if r.cancelled { " (deadline hit)" } else { "" },
    );
    println!("WHAM-common config: {}", r.common_config.display());
    let mut t = Table::new(["model", "family", "config(s)", "thpt", "perf/TDP", "vs TPUv2 thpt"]);
    for name in &r.models {
        for (fam, list) in
            [("common", &r.common), ("individual", &r.individual), ("mosaic", &r.mosaic)]
        {
            if let Some(m) = list.iter().find(|m| &m.model == name) {
                t.row([
                    m.model.clone(),
                    fam.to_string(),
                    m.configs.join(" "),
                    format!("{:.3}", m.throughput),
                    format!("{:.4}", m.perf_per_tdp),
                    format!("{:.3}x", m.vs_tpuv2),
                ]);
            }
        }
    }
    print!("{t}");
    Ok(())
}

/// `wham cluster` — topology-aware parallelism-strategy sweep
/// ([`wham::cluster`]): enumerate (pp, tp, dp, schedule) splits, screen
/// them with the discrete-event simulator, mine hardware for the best.
fn cmd_cluster(args: &Args) -> Result<()> {
    let trace_out = trace_out_from_args(args);
    let timeline_out = args.get("timeline-out").map(str::to_string);
    let req = ClusterRequest::from_args(args)?;
    let plan = req.validate()?;
    let mut session = session_from_args(args)?;
    println!(
        "cluster sweep: {} on {} devices ({} topology, metric={}, mine top {})",
        req.model, req.devices, req.topology, req.metric, req.mine_top
    );
    let mut progress = ndjson_progress;
    let mut null = NullSink;
    let sink: &mut dyn ProgressSink =
        if args.flag("progress") { &mut progress } else { &mut null };
    let r = session.run_cluster(&plan, sink)?;
    flush_trace(&trace_out)?;
    if let Some(path) = &timeline_out {
        write_cluster_timeline(args, &plan, &r, path)?;
    }
    println!(
        "{} strategies screened, {} mined, wall={:.0}ms{}",
        r.candidates,
        r.mined,
        r.wall_ms,
        if r.cancelled { " (deadline hit)" } else { "" },
    );
    let mut t = Table::new([
        "rank", "pp", "tp", "dp", "schedule", "micro", "config", "thpt", "perf/TDP", "bubble",
        "fits",
    ]);
    for (i, p) in r.ranked.iter().enumerate() {
        let sched = if p.chunks > 1 {
            format!("{}x{}", p.schedule, p.chunks)
        } else {
            p.schedule.clone()
        };
        t.row([
            (i + 1).to_string(),
            p.pp.to_string(),
            p.tp.to_string(),
            p.dp.to_string(),
            sched,
            format!("{}x{}", p.micro_batch, p.num_micro),
            format!("{}{}", p.config.display(), if p.mined { " *" } else { "" }),
            format!("{:.3}", p.throughput),
            format!("{:.4}", p.perf_per_tdp),
            format!("{:.1}%", p.bubble_fraction * 100.0),
            p.fits_hbm.to_string(),
        ]);
    }
    print!("{t}");
    let b = &r.baseline;
    println!(
        "baseline (fixed pp={}, tp={}, {}): {:.3} samples/s — best strategy is {:.3}x",
        b.pp,
        b.tp,
        b.schedule,
        b.throughput,
        r.ranked.first().map(|p| p.throughput / b.throughput.max(1e-12)).unwrap_or(1.0),
    );
    println!("(* = config mined by the global hardware search)");
    Ok(())
}

/// `--timeline-out FILE`: re-simulate the sweep's winning strategy in
/// recorded mode and write the per-rank task/transfer timeline as a
/// Chrome-trace document (`ui.perfetto.dev` renders one track per
/// rank; each event's args carry the bubble / link-wait attribution).
fn write_cluster_timeline(
    args: &Args,
    plan: &wham::api::plan::ClusterPlan,
    r: &wham::api::ClusterReply,
    path: &str,
) -> Result<()> {
    let Some(best) = r.ranked.first() else {
        eprintln!("--timeline-out: no ranked strategies to record; skipping");
        return Ok(());
    };
    let mut backend = make_backend(backend_from_args(args)?)?;
    let sim = wham::cluster::strategy_timeline(
        &plan.model,
        &plan.cfg,
        &plan.topology,
        plan.devices,
        best.pp,
        best.tp,
        best.chunks,
        &best.schedule,
        &best.config,
        backend.as_mut(),
    )
    .map_err(|e| anyhow!("--timeline-out: {e}"))?;
    let timeline = sim.timeline.as_deref().unwrap_or(&[]);
    let doc = wham::cluster::chrome_trace_json(timeline);
    std::fs::write(path, doc)?;
    eprintln!(
        "wrote {} timeline event(s) for pp={} tp={} {} to {path} — open in ui.perfetto.dev",
        timeline.len(),
        best.pp,
        best.tp,
        best.schedule,
    );
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
    let framework = args.get("framework").unwrap_or("confuciux");
    let iterations: usize = args.get_as_or("iterations", 500).map_err(|e| anyhow!("{e}"))?;
    // The shared request parser supplies the metric; baselines have no
    // other search options.
    let metric = SearchRequest::from_args(args)?.metric;
    let (graph, batch) = resolve_workload(name)?;
    let mut backend = make_backend(backend_from_args(args)?)?;

    match framework {
        "confuciux" => {
            let r = confuciux::run(
                &graph,
                batch,
                backend.as_mut(),
                confuciux::ConfuciuxOpts { iterations, metric, ..Default::default() },
            );
            println!(
                "ConfuciuX+ on {name}: {} score={:.4} evals={} wall={:?}",
                r.config.display(),
                r.score,
                r.evaluations,
                r.wall
            );
            println!("  {}", report::eval_line(&r.eval));
        }
        "spotlight" => {
            let r = spotlight::run(
                &graph,
                batch,
                backend.as_mut(),
                spotlight::SpotlightOpts { iterations, metric, ..Default::default() },
            );
            println!(
                "Spotlight+ on {name}: {} score={:.4} evals={} wall={:?}",
                r.config.display(),
                r.score,
                r.evaluations,
                r.wall
            );
            println!("  {}", report::eval_line(&r.eval));
        }
        "tpuv2" | "nvdla" => {
            let cfg = if framework == "tpuv2" {
                wham::arch::presets::tpuv2()
            } else {
                wham::arch::presets::nvdla_scaled()
            };
            let e = evaluate_design(&graph, batch, &cfg, backend.as_mut());
            println!("{framework} on {name}: {}", cfg.display());
            println!("  {}", report::eval_line(&e));
        }
        other => bail!("unknown framework {other:?}"),
    }
    Ok(())
}

/// Export a workload's schedule on a given design as Chrome-trace JSON,
/// or (`wham trace explain <model>`) dump the search flight recorder,
/// or (`wham trace profile <model>`) run the search under the sampling
/// profiler and print the hottest span paths.
fn cmd_trace(args: &Args) -> Result<()> {
    if args.pos(1) == Some("explain") {
        return cmd_trace_explain(args);
    }
    if args.pos(1) == Some("profile") {
        return cmd_trace_profile(args);
    }
    let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
    let out = args.get_or("out", "trace.json");
    let (graph, _batch) = resolve_workload(name)?;
    let mut session = session_from_args(args)?;

    // Design: explicit --tc/--vc/--dims, else the search's best.
    let dims_s = args.get_or("dims", "");
    let config = if dims_s.is_empty() {
        session.search(&SearchRequest::new(name))?.best.config
    } else {
        let (tx, ty, vw) = parse_dims(&dims_s)?;
        wham::arch::ArchConfig {
            num_tc: args.get_as_or("tc", 2u64).map_err(|e| anyhow!("{e}"))?,
            tc_x: tx,
            tc_y: ty,
            num_vc: args.get_as_or("vc", 2u64).map_err(|e| anyhow!("{e}"))?,
            vc_w: vw,
        }
    };
    let ann = wham::cost::annotate::AnnotatedGraph::new(
        &graph,
        wham::cost::Dims::of(&config),
        session.backend_mut(),
    );
    let cp = wham::sched::asap_alap(&ann);
    let cores = wham::sched::CoreCount { tc: config.num_tc, vc: config.num_vc };
    let sched = wham::sched::greedy_schedule(&ann, &cp, cores);
    let json = wham::report::trace::chrome_trace(&ann, &sched, cores);
    std::fs::write(&out, &json)?;
    println!(
        "wrote {} ({} events, makespan {} cycles) for {name} on {} — open in ui.perfetto.dev",
        out,
        graph.len(),
        sched.makespan,
        config.display()
    );
    Ok(())
}

/// `wham trace explain <model>` — run the search with the flight
/// recorder attached and print per-iteration critical-path attribution:
/// which dimensions were probed, what the scheduler granted, which op
/// class sat on the critical path, and whether the eval cache answered.
fn cmd_trace_explain(args: &Args) -> Result<()> {
    let name = args
        .get("model")
        .or_else(|| args.pos(2))
        .ok_or_else(|| anyhow!("usage: wham trace explain <model> (or --model <name>)"))?;
    let plan = SearchRequest::new(name).explain(true).validate()?;
    let mut session = session_from_args(args)?;
    let r = session.run_search(&plan, &mut NullSink)?;
    let rows = r.explain.unwrap_or_default();
    println!(
        "flight recorder for {name}: {} of {} evaluations retained (ring cap {}), best {} score={:.4}",
        rows.len(),
        r.dims_evaluated,
        wham::telemetry::FlightRecorder::DEFAULT_CAP,
        r.best.config.display(),
        r.best.score,
    );
    let mut t = Table::new([
        "#", "dims", "score", "best", "cache", "evals", "tc/vc", "grants t/v/f", "conflict",
    ]);
    for (i, rec) in rows.iter().enumerate() {
        t.row([
            (i + 1).to_string(),
            format!("{}x{}x{}", rec.dims.tc_x, rec.dims.tc_y, rec.dims.vc_w),
            format!("{:.4}", rec.score),
            format!("{}{:.4}", if rec.improved { "*" } else { " " }, rec.best),
            if rec.cache_hit { "hit" } else { "miss" }.to_string(),
            rec.evals.to_string(),
            format!("{}/{}", rec.cores.0, rec.cores.1),
            format!("{}/{}/{}", rec.grants.0, rec.grants.1, rec.grants.2),
            rec.conflict_op.clone().unwrap_or_else(|| "-".to_string()),
        ]);
    }
    print!("{t}");
    println!("(* = new best; grants t/v/f = tensor-core / vector-core / fused issue grants)");
    Ok(())
}

/// `wham trace profile <model>` — run the per-workload search under the
/// span sampling profiler ([`wham::telemetry::profile`]) and print the
/// hottest span paths with self/total percentages. `--out FILE` also
/// writes the collapsed-stack form for flamegraph.pl / speedscope;
/// `--smoke` bounds the run with a short deadline (CI-sized);
/// `--full-reschedule` profiles the schedule-from-scratch MCR oracle
/// instead of the incremental probe engine (outcomes are bit-identical,
/// so the two profiles isolate where the scheduler time went).
fn cmd_trace_profile(args: &Args) -> Result<()> {
    let name = args
        .get("model")
        .or_else(|| args.pos(2))
        .ok_or_else(|| anyhow!("usage: wham trace profile <model> (or --model <name>)"))?;
    let hz: u32 = args.get_as_or("hz", 99).map_err(|e| anyhow!("{e}"))?;
    let top: usize = args.get_as_or("top", 10).map_err(|e| anyhow!("{e}"))?;
    let mut plan = SearchRequest::new(name).validate()?;
    plan.opts.full_reschedule = args.flag("full-reschedule");
    let mut session = session_from_args(args)?;
    let sampler = wham::telemetry::profile::attach(hz).map_err(|e| anyhow!("{e}"))?;
    let r = if args.flag("smoke") {
        let mut sink = wham::api::DeadlineSink::new(std::time::Duration::from_secs(10));
        session.run_search(&plan, &mut sink)?
    } else {
        session.run_search(&plan, &mut NullSink)?
    };
    let profile = sampler.stop();
    println!(
        "profiled {name}: {} sample(s) at {} Hz over {:.2}s — best {} score={:.4} ({} scheduler evals)",
        profile.samples,
        profile.hz,
        profile.elapsed.as_secs_f64(),
        r.best.config.display(),
        r.best.score,
        r.scheduler_evals,
    );
    print!("{}", profile.render_table(top));
    if let Some(out) = args.get("out") {
        std::fs::write(out, profile.collapsed())?;
        println!("wrote collapsed stacks to {out} — flamegraph.pl or speedscope reads this");
    }
    Ok(())
}

/// Show the memory-balanced pipeline partition of an LLM workload.
fn cmd_partition(args: &Args) -> Result<()> {
    let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
    let depth: u64 = args.get_as_or("depth", 32).map_err(|e| anyhow!("{e}"))?;
    let tmp: u64 = args.get_as_or("tmp", 1).map_err(|e| anyhow!("{e}"))?;
    let scheme: wham::distributed::Scheme =
        args.get_or("scheme", "gpipe").parse().map_err(|e: String| anyhow!("{e}"))?;
    // Builtin LLMs or any registered spec with a `transformer` section.
    let cfg = wham::workload::transformer_cfg(name)
        .ok_or_else(|| anyhow!("{name:?} is not an LLM workload"))?;
    let p = wham::distributed::partition::partition_transformer(
        name,
        &cfg,
        depth,
        tmp,
        Optimizer::Adam,
    );
    println!(
        "{name}: {} stages x tmp {}, microbatch {}, {} microbatches/iter",
        p.stages.len(),
        p.tmp,
        p.micro_batch,
        p.num_micro
    );
    let mut t = Table::new(["stage", "layers", "ops", "state", "stash/mb", "footprint", "fits HBM"]);
    for s in &p.stages {
        let fp = s.footprint_bytes(scheme, p.num_micro, p.stages.len() as u64);
        t.row([
            s.index.to_string(),
            format!("{}..{}", s.layers.0, s.layers.1),
            s.graph.len().to_string(),
            wham::util::human_bytes(s.state_bytes),
            wham::util::human_bytes(s.stash_bytes),
            wham::util::human_bytes(fp),
            s.fits_hbm(scheme, p.num_micro, p.stages.len() as u64).to_string(),
        ]);
    }
    print!("{t}");
    Ok(())
}

/// Print the Table-3 search-space accounting for a workload.
fn cmd_space(args: &Args) -> Result<()> {
    let req = SearchRequest::from_args(args)?;
    let (graph, _batch) = resolve_workload(&req.model)?;
    let mut session = session_from_args(args)?;
    let r = session.search(&req)?;
    let ann = wham::cost::annotate::AnnotatedGraph::new(
        &graph,
        wham::cost::Dims { tc_x: 128, tc_y: 128, vc_w: 128 },
        session.backend_mut(),
    );
    let s = wham::search::space::space_sizes(&ann, r.dims_evaluated as usize);
    println!(
        "{}: {} ops, {} dims evaluated by the pruner",
        req.model,
        graph.len(),
        r.dims_evaluated
    );
    println!("  exhaustive      10^{:.0}", s.exhaustive);
    println!("  ILP unpruned    10^{:.0}", s.ilp_unpruned);
    println!("  ILP pruned      10^{:.0}", s.ilp_pruned);
    println!("  heur unpruned   10^{:.0}", s.heur_unpruned);
    println!("  heur pruned     10^{:.0}", s.heur_pruned);
    Ok(())
}

/// Run the long-lived design-mining service (see `wham::service`).
fn cmd_serve(args: &Args) -> Result<()> {
    let port: u16 = args.get_as_or("port", 8484).map_err(|e| anyhow!("{e}"))?;
    // Worker-count default follows the machine, not a magic constant;
    // `--jobs` is accepted as an alias so the serve/search flags match.
    let workers: usize =
        args.get_as_or("workers", jobs_from_args(args)?).map_err(|e| anyhow!("{e}"))?;
    let backend = backend_from_args(args)?;
    let db_path = args.get("db").map(std::path::PathBuf::from);
    let jobs_path = args.get("jobs-db").map(std::path::PathBuf::from);
    let mut jobs = wham::jobs::JobsOptions::default();
    jobs.workers = args.get_as_or("job-workers", jobs.workers).map_err(|e| anyhow!("{e}"))?;
    jobs.queue_depth =
        args.get_as_or("queue-depth", jobs.queue_depth).map_err(|e| anyhow!("{e}"))?;
    jobs.quota_rate = args.get_as_or("quota-rate", jobs.quota_rate).map_err(|e| anyhow!("{e}"))?;
    jobs.quota_burst =
        args.get_as_or("quota-burst", jobs.quota_burst).map_err(|e| anyhow!("{e}"))?;
    let drain_secs: u64 = args.get_as_or("drain-secs", 20).map_err(|e| anyhow!("{e}"))?;
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let opts = wham::service::ServeOptions {
        workers,
        db_path,
        backend,
        jobs_path,
        jobs,
        drain_secs,
        trace_out,
        tsdb: Default::default(),
    };
    wham::service::serve_forever(&format!("127.0.0.1:{port}"), opts)
}

/// `wham top` — a `top(1)`-style terminal view of a running `wham
/// serve`: polls `/status` and `/metrics/history` and redraws rates,
/// queue depth, and active alerts in place. `--count N` bounds the
/// number of refreshes (for scripts/tests); default runs until ^C.
fn cmd_top(args: &Args) -> Result<()> {
    let addr = addr_from_args(args)?;
    let interval: u64 = args.get_as_or("interval", 2).map_err(|e| anyhow!("{e}"))?;
    let count: u64 = args.get_as_or("count", 0).map_err(|e| anyhow!("{e}"))?;
    let window: u64 = args.get_as_or("window", 120).map_err(|e| anyhow!("{e}"))?;
    let fail =
        |e: std::io::Error| anyhow!("request to {addr} failed: {e} (is `wham serve` running?)");
    // Rate series worth a sparkline-style last/avg pair, in render order.
    const RATES: &[(&str, &str)] = &[
        ("wham_scheduler_evals_total", "evals/s"),
        ("wham_cluster_sim_events_total", "sim events/s"),
        ("wham_http_requests_total", "http req/s"),
        ("wham_jobs_retries_total", "job retries/s"),
    ];
    let mut iteration = 0u64;
    loop {
        let (st, status_body) =
            wham::service::http::request(addr, "GET", "/status", None).map_err(fail)?;
        if st != 200 {
            bail!("GET /status returned HTTP {st}");
        }
        let hist_path = format!("/metrics/history?window={window}");
        let (hs, hist_body) =
            wham::service::http::request(addr, "GET", &hist_path, None).map_err(fail)?;
        if hs != 200 {
            bail!("GET /metrics/history returned HTTP {hs}");
        }
        let status = wham::util::json::parse(&status_body).map_err(|e| anyhow!("{e}"))?;
        let hist = wham::util::json::parse(&hist_body).map_err(|e| anyhow!("{e}"))?;
        // One-screen redraw: home the cursor and clear below instead of
        // scrolling, so the view updates in place like top(1).
        if iteration > 0 {
            print!("\x1b[H\x1b[J");
        }
        let j = |keys: &[&str]| {
            let mut v = Some(&status);
            for k in keys {
                v = v.and_then(|v| v.get(k));
            }
            v
        };
        let num = |keys: &[&str]| j(keys).and_then(|v| v.as_f64()).unwrap_or(0.0);
        println!(
            "wham top — {addr}  (refresh {interval}s, window {window}s, ^C to quit)\n\
             uptime {:.0}s  requests {}  designs {}  db hit-rate {:.0}%  jobs queued {} running {} retries {}",
            num(&["uptime_ms"]) / 1000.0,
            num(&["requests"]) as u64,
            num(&["db", "entries"]) as u64,
            num(&["perf", "db_hit_rate"]) * 100.0,
            num(&["jobs", "queued"]) as u64,
            num(&["jobs", "running"]) as u64,
            num(&["jobs", "retries"]) as u64,
        );
        // Rates from the history: mean of the windowed per-second series
        // plus the most recent point, per metric.
        println!("\n  {:<24} {:>10} {:>10}", "metric", "now", "avg");
        let series = hist.get("series").and_then(|s| s.as_arr());
        for (name, label) in RATES {
            let mut last = 0.0f64;
            let mut sum = 0.0f64;
            let mut n = 0usize;
            if let Some(rows) = &series {
                for row in rows.iter() {
                    let matches = row
                        .get("name")
                        .and_then(|v| v.as_str())
                        .is_some_and(|s| s == *name || s.starts_with(&format!("{name}{{")));
                    if !matches {
                        continue;
                    }
                    if let Some(pts) = row.get("points").and_then(|p| p.as_arr()) {
                        for p in pts.iter() {
                            if let Some(pair) = p.as_arr() {
                                if let Some(v) = pair.get(1).and_then(|v| v.as_f64()) {
                                    sum += v;
                                    n += 1;
                                    last = v;
                                }
                            }
                        }
                    }
                }
            }
            let avg = if n > 0 { sum / n as f64 } else { 0.0 };
            println!("  {label:<24} {last:>10.2} {avg:>10.2}");
        }
        // Alerts straight from /status (the engine's snapshot).
        println!();
        match j(&["alerts"]).and_then(|a| a.as_arr()) {
            Some(alerts) if !alerts.is_empty() => {
                for a in alerts {
                    let rule = a.get("rule").and_then(|v| v.as_str()).unwrap_or("?");
                    let active =
                        a.get("active").and_then(|v| v.as_bool()).unwrap_or(false);
                    let value = a.get("value").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    let mark = if active { "\x1b[31mFIRING\x1b[0m" } else { "ok    " };
                    println!("  alert {mark} {rule:<24} value={value:.2}");
                }
            }
            _ => println!("  (no alert rules reported)"),
        }
        iteration += 1;
        if count > 0 && iteration >= count {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs(interval.max(1)));
    }
}

/// `--addr HOST:PORT` (default the `wham serve` default).
fn addr_from_args(args: &Args) -> Result<std::net::SocketAddr> {
    let addr_s = args.get_or("addr", "127.0.0.1:8484");
    addr_s.parse().map_err(|_| anyhow!("--addr expects host:port, got {addr_s:?}"))
}

/// Drive a running `wham serve` instance over HTTP. Bodies are the typed
/// requests' canonical wire form — the same bytes the server parses.
fn cmd_client(args: &Args) -> Result<()> {
    let addr = addr_from_args(args)?;
    let sub = args.pos(1).ok_or_else(|| {
        anyhow!("usage: wham client <models|search|evaluate|common|global|cluster|status|upload|jobs|db> [--addr host:port]")
    })?;

    // The async-job and design-db verbs also exist as top-level commands;
    // `wham client jobs ...` / `wham client db ...` are the same code with
    // the verb one position later.
    if sub == "jobs" {
        return cmd_jobs(args, 2);
    }
    if sub == "db" {
        return cmd_db(args, 2);
    }

    let (method, path, body) = match sub {
        "models" => ("GET", "/models", None),
        "status" => ("GET", "/status", None),
        "search" => ("POST", "/search", Some(SearchRequest::from_args(args)?.to_json())),
        "evaluate" => ("POST", "/evaluate", Some(EvaluateRequest::from_args(args)?.to_json())),
        "common" => ("POST", "/common", Some(CommonRequest::from_args(args)?.to_json())),
        "global" => ("POST", "/global", Some(GlobalRequest::from_args(args)?.to_json())),
        "cluster" => ("POST", "/cluster", Some(ClusterRequest::from_args(args)?.to_json())),
        // Upload a workload spec file to the server's registry.
        "upload" => {
            let spec = args
                .pos(2)
                .ok_or_else(|| anyhow!("usage: wham client upload <spec.json>"))?;
            ("POST", "/workloads", Some(std::fs::read_to_string(spec)?))
        }
        other => bail!("unknown client subcommand {other:?}"),
    };
    let (status, resp) = wham::service::http::request(addr, method, path, body.as_deref())
        .map_err(|e| anyhow!("request to {addr} failed: {e} (is `wham serve` running?)"))?;
    println!("{resp}");
    if status != 200 {
        bail!("server returned HTTP {status}");
    }
    Ok(())
}

/// `wham jobs <submit|status|list|watch|cancel|result>` — the async job
/// tier's CLI (`base` is the verb's positional index, so the same code
/// backs `wham jobs ...` and `wham client jobs ...`).
fn cmd_jobs(args: &Args, base: usize) -> Result<()> {
    let addr = addr_from_args(args)?;
    let verb = args.pos(base).ok_or_else(|| {
        anyhow!("usage: wham jobs <submit|status|list|watch|cancel|result> [args] [--addr host:port]")
    })?;
    let id_arg = || {
        args.pos(base + 1)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("usage: wham jobs {verb} <job-id>"))
    };
    let fail = |e: std::io::Error| anyhow!("request to {addr} failed: {e} (is `wham serve` running?)");

    let (method, path, body) = match verb {
        "submit" => {
            ("POST", "/jobs".to_string(), Some(JobRequest::from_args(args)?.to_json()))
        }
        "list" => ("GET", "/jobs".to_string(), None),
        "status" => ("GET", format!("/jobs/{}", id_arg()?), None),
        "cancel" => ("DELETE", format!("/jobs/{}", id_arg()?), None),
        "result" => ("GET", format!("/jobs/{}/reply", id_arg()?), None),
        "watch" => {
            // SSE: print each frame line as it arrives, dropping the
            // `:`-prefixed keepalive comments. The server closes the
            // stream after the terminal `done` frame.
            let path = format!("/jobs/{}/events", id_arg()?);
            let status =
                wham::service::http::request_stream(addr, "GET", &path, None, |line| {
                    if !line.starts_with(':') && !line.is_empty() {
                        println!("{line}");
                    }
                    true
                })
                .map_err(fail)?;
            if status != 200 {
                bail!("server returned HTTP {status}");
            }
            return Ok(());
        }
        other => bail!("unknown jobs subcommand {other:?} (submit, status, list, watch, cancel, result)"),
    };
    let (status, resp) =
        wham::service::http::request(addr, method, &path, body.as_deref()).map_err(fail)?;
    println!("{resp}");
    // Submission answers 202 Accepted; everything else 200.
    if status != 200 && status != 202 {
        bail!("server returned HTTP {status}");
    }
    Ok(())
}

/// `wham db <export|import|merge>` — design-database snapshots as JSONL:
/// `export` pulls a running server's database, `import` pushes one into
/// it, `merge` unions snapshot files offline (first-wins per fingerprint,
/// no server needed).
fn cmd_db(args: &Args, base: usize) -> Result<()> {
    let verb = args.pos(base).ok_or_else(|| {
        anyhow!("usage: wham db <export|import|merge> [args] [--addr host:port]")
    })?;
    match verb {
        "export" => {
            let addr = addr_from_args(args)?;
            let (status, resp) =
                wham::service::http::request(addr, "GET", "/db/export", None)
                    .map_err(|e| anyhow!("request to {addr} failed: {e} (is `wham serve` running?)"))?;
            if status != 200 {
                bail!("server returned HTTP {status}");
            }
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, &resp)?;
                    eprintln!("wrote {} design line(s) to {path}", resp.lines().count());
                }
                None => print!("{resp}"),
            }
            Ok(())
        }
        "import" => {
            let addr = addr_from_args(args)?;
            let path = args
                .pos(base + 1)
                .ok_or_else(|| anyhow!("usage: wham db import <db.jsonl>"))?;
            let text = std::fs::read_to_string(path)?;
            let (status, resp) =
                wham::service::http::request(addr, "POST", "/db/import", Some(&text))
                    .map_err(|e| anyhow!("request to {addr} failed: {e} (is `wham serve` running?)"))?;
            println!("{resp}");
            if status != 200 {
                bail!("server returned HTTP {status}");
            }
            Ok(())
        }
        "merge" => {
            let inputs = &args.positionals()[base + 1..];
            if inputs.is_empty() {
                bail!("usage: wham db merge <a.jsonl> <b.jsonl> [...] --out merged.jsonl");
            }
            let out = args
                .get("out")
                .ok_or_else(|| anyhow!("--out required (merge does not write in place)"))?;
            let db = wham::service::cache::DesignDb::in_memory();
            for path in inputs {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow!("cannot read {path}: {e}"))?;
                let s = db.import_jsonl(&text);
                println!(
                    "{path}: {} added, {} duplicate, {} malformed",
                    s.added, s.duplicate, s.malformed
                );
            }
            std::fs::write(out, db.export_jsonl())?;
            println!("merged {} design(s) into {out}", db.stats().entries);
            Ok(())
        }
        other => bail!("unknown db subcommand {other:?} (export, import, merge)"),
    }
}

fn cmd_selftest(args: &Args) -> Result<()> {
    println!("1/3 native backend ...");
    let graph = wham::models::training("bert-base", Optimizer::Adam).unwrap();
    let mut native = make_backend(BackendChoice::Native)?;
    let en = evaluate_design(&graph, 4, &wham::arch::presets::tpuv2(), native.as_mut());
    println!("    bert-base on TPUv2 (native): {}", report::eval_line(&en));

    println!("2/3 PJRT artifact ...");
    let mut pjrt = make_backend(BackendChoice::Pjrt)
        .map_err(|e| anyhow!("PJRT backend unavailable ({e}); run `make artifacts`"))?;
    let ep = evaluate_design(&graph, 4, &wham::arch::presets::tpuv2(), pjrt.as_mut());
    println!("    bert-base on TPUv2 (pjrt)  : {}", report::eval_line(&ep));

    println!("3/3 agreement ...");
    let rel = (en.seconds - ep.seconds).abs() / en.seconds;
    let rel_e = (en.energy_j - ep.energy_j).abs() / en.energy_j;
    if rel > 1e-3 || rel_e > 1e-3 {
        bail!("backends disagree: latency rel={rel:.2e}, energy rel={rel_e:.2e}");
    }
    println!("    latency rel={rel:.2e}, energy rel={rel_e:.2e}  — OK");

    // Exercise the parallel coordinator too, at the machine's width
    // (previously hardcoded to 2 workers).
    let jobs =
        vec![SearchJob { name: "bert-base".into(), graph, batch: 4, opts: SearchOptions::default() }];
    let rs = run_parallel(jobs, BackendChoice::Auto, jobs_from_args(args)?);
    let coord = rs[0].1.as_ref().map_err(|e| anyhow!("coordinator job failed: {e}"))?;
    println!("coordinator: best {}", coord.best.config.display());
    println!("selftest OK");
    Ok(())
}
