//! `wham` — CLI for the WHAM accelerator-mining reproduction.
//!
//! Subcommands:
//! * `models` — list the Table-4 workload zoo;
//! * `search` — per-workload accelerator search (section 4);
//! * `common` — one design across a workload set (section 4.6);
//! * `global` — distributed pipeline/TMP search (section 5);
//! * `baseline` — run ConfuciuX+ / Spotlight+ / hand-optimized designs;
//! * `serve` — long-running design-mining service with a persistent
//!   design database (see [`wham::service`]);
//! * `client` — drive a running `wham serve` over HTTP;
//! * `selftest` — verify the PJRT artifact against the native mirror.

use anyhow::{anyhow, bail, Result};
use wham::arch::presets;
use wham::baselines::{confuciux, spotlight};
use wham::coordinator::{make_backend, run_parallel, BackendChoice, SearchJob};
use wham::distributed::global_search::{global_search, GlobalOptions};
use wham::distributed::network::Network;
use wham::distributed::partition::partition_transformer;
use wham::distributed::Scheme;
use wham::graph::autodiff::Optimizer;
use wham::graph::OperatorGraph;
use wham::metrics::Metric;
use wham::report;
use wham::search::engine::{evaluate_design, SearchOptions};
use wham::util::cli::Args;
use wham::util::table::Table;

const VALUE_KEYS: &[&str] = &[
    "model", "models", "metric", "backend", "k", "depth", "tmp", "scheme", "framework",
    "iterations", "workers", "hysteresis", "seed", "out", "tc", "vc", "dims", "port", "db",
    "addr",
];

fn main() -> Result<()> {
    let args = Args::from_env(VALUE_KEYS).map_err(|e| anyhow!("{e}"))?;
    match args.pos(0) {
        Some("models") => cmd_models(),
        Some("search") => cmd_search(&args),
        Some("common") => cmd_common(&args),
        Some("global") => cmd_global(&args),
        Some("baseline") => cmd_baseline(&args),
        Some("trace") => cmd_trace(&args),
        Some("partition") => cmd_partition(&args),
        Some("space") => cmd_space(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("selftest") => cmd_selftest(),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "wham — Workload-Aware Hardware Accelerator Mining (CS.AR 2024 reproduction)\n\n\
         usage:\n  \
         wham models\n  \
         wham search --model <name> [--metric throughput|perf/tdp] [--ilp]\n              \
         [--backend auto|native|pjrt] [--k 10] [--hysteresis 1]\n  \
         wham common [--models a,b,c] [--metric ...]\n  \
         wham global [--models opt-1.3b,gpt2-xl] [--depth 32] [--tmp 1]\n              \
         [--scheme gpipe|1f1b] [--k 10] [--metric ...]\n  \
         wham baseline --model <name> --framework confuciux|spotlight|tpuv2|nvdla\n              \
         [--iterations 500]\n  \
         wham trace --model <name> [--out trace.json] [--tc 2 --vc 2 --dims 128x128x128]\n  \
         wham partition --model <llm> [--depth 32] [--tmp 1] [--scheme gpipe]\n  \
         wham space --model <name>\n  \
         wham serve [--port 8484] [--workers 8] [--db designs.jsonl] [--backend auto]\n  \
         wham client <models|search|evaluate|global|status> [--addr 127.0.0.1:8484] ...\n  \
         wham selftest"
    );
}

/// Resolve a registry workload to its training graph and batch size —
/// the lookup every per-workload subcommand starts with.
fn resolve_workload(name: &str) -> Result<(OperatorGraph, u64)> {
    let graph = wham::models::training(name, Optimizer::Adam)
        .ok_or_else(|| anyhow!("unknown model {name:?} (see `wham models`)"))?;
    let batch = wham::models::info(name)
        .ok_or_else(|| anyhow!("model {name:?} missing from the registry"))?
        .batch;
    Ok((graph, batch))
}

fn parse_common(args: &Args) -> Result<(Metric, BackendChoice, SearchOptions)> {
    let metric: Metric = args.get_or("metric", "throughput").parse().map_err(|e| anyhow!("{e}"))?;
    let backend: BackendChoice =
        args.get_or("backend", "auto").parse().map_err(|e| anyhow!("{e}"))?;
    let opts = SearchOptions {
        metric,
        top_k: args.get_as_or("k", 10usize).map_err(|e| anyhow!("{e}"))?,
        hysteresis: args.get_as_or("hysteresis", 1u32).map_err(|e| anyhow!("{e}"))?,
        use_ilp: args.flag("ilp"),
        ..Default::default()
    };
    Ok((metric, backend, opts))
}

fn cmd_models() -> Result<()> {
    let mut t = Table::new(["model", "task", "batch", "accelerators", "params"]);
    for m in wham::models::MODELS {
        let params = wham::models::forward(m.name)
            .map(|g| wham::util::human_count(g.param_elems() as f64))
            .unwrap_or_default();
        t.row([
            m.name.to_string(),
            m.task.to_string(),
            m.batch.to_string(),
            m.accelerators.to_string(),
            params,
        ]);
    }
    print!("{t}");
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
    let (metric, backend_choice, mut opts) = parse_common(args)?;
    let (graph, batch) = resolve_workload(name)?;
    let mut backend = make_backend(backend_choice)?;

    if metric == Metric::PerfPerTdp {
        opts.min_throughput =
            evaluate_design(&graph, batch, &presets::tpuv2(), backend.as_mut()).throughput;
    }
    println!(
        "searching {name} ({} ops, backend={}, metric={metric}, {})",
        graph.len(),
        backend.name(),
        if opts.use_ilp { "ILP" } else { "MCR heuristics" },
    );
    let r = wham::search::engine::WhamSearch::new(&graph, batch, opts).run(backend.as_mut());
    println!(
        "best: {}  score={:.4}  ({} dims, {} scheduler evals, {:?})",
        r.best.config.display(),
        r.best.score,
        r.dims_evaluated,
        r.scheduler_evals,
        r.wall
    );
    println!("  {}", report::eval_line(&r.best.eval));
    let tpu = evaluate_design(&graph, batch, &presets::tpuv2(), backend.as_mut());
    let nvdla = evaluate_design(&graph, batch, &presets::nvdla_scaled(), backend.as_mut());
    println!("  vs TPUv2  : {:.3}x throughput", r.best.eval.throughput / tpu.throughput);
    println!("  vs NVDLA  : {:.3}x throughput", r.best.eval.throughput / nvdla.throughput);
    println!("top-{}:", r.top.len());
    let rows: Vec<(String, wham::search::DesignPoint)> =
        r.top.points().iter().map(|p| (name.to_string(), *p)).collect();
    print!("{}", report::design_table(&rows));
    Ok(())
}

fn cmd_common(args: &Args) -> Result<()> {
    let names: Vec<String> = {
        let l = args.get_list("models");
        if l.is_empty() {
            wham::models::single_acc_models().iter().map(|s| s.to_string()).collect()
        } else {
            l
        }
    };
    let (metric, backend_choice, mut opts) = parse_common(args)?;
    opts.metric = metric;
    let mut backend = make_backend(backend_choice)?;
    let graphs: Vec<(String, wham::graph::OperatorGraph, u64)> = names
        .iter()
        .map(|n| {
            let (g, b) = resolve_workload(n)?;
            Ok((n.clone(), g, b))
        })
        .collect::<Result<_>>()?;
    let workloads: Vec<wham::search::common::Workload> = graphs
        .iter()
        .map(|(n, g, b)| {
            let min = if metric == Metric::PerfPerTdp {
                evaluate_design(g, *b, &presets::tpuv2(), backend.as_mut()).throughput
            } else {
                0.0
            };
            wham::search::common::Workload {
                name: n.clone(),
                graph: g,
                batch: *b,
                min_throughput: min,
                weight: 1.0,
            }
        })
        .collect();
    println!("WHAM-common over {} workloads (metric={metric})", workloads.len());
    let r = wham::search::common::search_common(&workloads, opts, backend.as_mut());
    println!(
        "common design: {}  weighted score={:.4}  ({} dims, {:?})",
        r.best.0.display(),
        r.best.1,
        r.dims_evaluated,
        r.wall
    );
    let rows: Vec<(String, wham::search::DesignPoint)> = names
        .iter()
        .cloned()
        .zip(r.per_workload.iter().copied())
        .collect();
    print!("{}", report::design_table(&rows));
    Ok(())
}

fn cmd_global(args: &Args) -> Result<()> {
    let names: Vec<String> = {
        let l = args.get_list("models");
        if l.is_empty() {
            vec!["opt-1.3b".into(), "gpt2-xl".into()]
        } else {
            l
        }
    };
    let depth: u64 = args.get_as_or("depth", 32).map_err(|e| anyhow!("{e}"))?;
    let tmp: u64 = args.get_as_or("tmp", 1).map_err(|e| anyhow!("{e}"))?;
    let scheme: Scheme = args.get_or("scheme", "gpipe").parse().map_err(|e| anyhow!("{e}"))?;
    let (metric, backend_choice, local) = parse_common(args)?;
    let mut backend = make_backend(backend_choice)?;

    let parts: Vec<_> = names
        .iter()
        .map(|n| {
            let cfg = wham::models::transformer_cfg(n)
                .ok_or_else(|| anyhow!("{n:?} is not an LLM workload"))?;
            Ok(partition_transformer(n, &cfg, depth, tmp, Optimizer::Adam))
        })
        .collect::<Result<_>>()?;
    let net = Network::default();
    // TPUv2 pipeline baseline, simulated once per model: it serves as
    // both the Perf/TDP floor and the comparison column of the table.
    let tpu_pipe: Vec<wham::distributed::pipeline::PipelineEval> = parts
        .iter()
        .map(|p| {
            let cfgs = vec![presets::tpuv2(); p.stages.len()];
            wham::distributed::pipeline::simulate(p, &cfgs, scheme, &net, backend.as_mut())
        })
        .collect();
    let mut gopts = GlobalOptions { metric, scheme, top_k: local.top_k, local, ..Default::default() };
    if metric == Metric::PerfPerTdp {
        // TPUv2 pipeline throughput as the floor (min across models).
        gopts.min_throughput =
            tpu_pipe.iter().map(|e| e.throughput).fold(f64::INFINITY, f64::min);
    }
    println!(
        "global search: {} models, depth={depth}, tmp={tmp}, scheme={scheme:?}, metric={metric}",
        parts.len()
    );
    let r = global_search(&parts, &gopts, &net, backend.as_mut());
    println!(
        "pool={} evaluated={} local_searches={} wall={:?}",
        r.candidate_pool, r.candidates_evaluated, r.local_searches, r.wall
    );
    println!("WHAM-common config: {}", r.common.0.display());
    let mut t = Table::new(["model", "family", "config(s)", "thpt", "perf/TDP", "vs TPUv2 thpt"]);
    for (p, tpu) in parts.iter().zip(&tpu_pipe) {
        let add_row =
            |t: &mut Table, fam: &str, m: &wham::distributed::global_search::ModelPipelineResult| {
                let uniq: std::collections::BTreeSet<String> =
                    m.configs.iter().map(|c| c.display()).collect();
                t.row([
                    m.model.clone(),
                    fam.to_string(),
                    uniq.into_iter().collect::<Vec<_>>().join(" "),
                    format!("{:.3}", m.eval.throughput),
                    format!("{:.4}", m.eval.perf_per_tdp),
                    format!("{:.3}x", m.eval.throughput / tpu.throughput),
                ]);
            };
        for (fam, list) in
            [("common", &r.common.1), ("individual", &r.individual), ("mosaic", &r.mosaic)]
        {
            if let Some(m) = list.iter().find(|m| m.model == p.name) {
                add_row(&mut t, fam, m);
            }
        }
    }
    print!("{t}");
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
    let framework = args.get("framework").unwrap_or("confuciux");
    let iterations: usize = args.get_as_or("iterations", 500).map_err(|e| anyhow!("{e}"))?;
    let (metric, backend_choice, _) = parse_common(args)?;
    let (graph, batch) = resolve_workload(name)?;
    let mut backend = make_backend(backend_choice)?;

    match framework {
        "confuciux" => {
            let r = confuciux::run(
                &graph,
                batch,
                backend.as_mut(),
                confuciux::ConfuciuxOpts { iterations, metric, ..Default::default() },
            );
            println!(
                "ConfuciuX+ on {name}: {} score={:.4} evals={} wall={:?}",
                r.config.display(),
                r.score,
                r.evaluations,
                r.wall
            );
            println!("  {}", report::eval_line(&r.eval));
        }
        "spotlight" => {
            let r = spotlight::run(
                &graph,
                batch,
                backend.as_mut(),
                spotlight::SpotlightOpts { iterations, metric, ..Default::default() },
            );
            println!(
                "Spotlight+ on {name}: {} score={:.4} evals={} wall={:?}",
                r.config.display(),
                r.score,
                r.evaluations,
                r.wall
            );
            println!("  {}", report::eval_line(&r.eval));
        }
        "tpuv2" | "nvdla" => {
            let cfg = if framework == "tpuv2" { presets::tpuv2() } else { presets::nvdla_scaled() };
            let e = evaluate_design(&graph, batch, &cfg, backend.as_mut());
            println!("{framework} on {name}: {}", cfg.display());
            println!("  {}", report::eval_line(&e));
        }
        other => bail!("unknown framework {other:?}"),
    }
    Ok(())
}

/// Export a workload's schedule on a given design as Chrome-trace JSON.
fn cmd_trace(args: &Args) -> Result<()> {
    let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
    let out = args.get_or("out", "trace.json");
    let (graph, batch) = resolve_workload(name)?;
    let (_, backend_choice, _) = parse_common(args)?;
    let mut backend = make_backend(backend_choice)?;

    // Design: explicit --tc/--vc/--dims, else the search's best.
    let dims_s = args.get_or("dims", "");
    let config = if dims_s.is_empty() {
        wham::search::engine::WhamSearch::new(&graph, batch, SearchOptions::default())
            .run(backend.as_mut())
            .best
            .config
    } else {
        let parts: Vec<u64> = dims_s
            .split('x')
            .map(|p| p.parse().map_err(|_| anyhow!("--dims expects TXxTYxVW, e.g. 128x128x128")))
            .collect::<Result<_>>()?;
        let [tx, ty, vw]: [u64; 3] =
            parts.try_into().map_err(|_| anyhow!("--dims expects three values"))?;
        wham::arch::ArchConfig {
            num_tc: args.get_as_or("tc", 2u64).map_err(|e| anyhow!("{e}"))?,
            tc_x: tx,
            tc_y: ty,
            num_vc: args.get_as_or("vc", 2u64).map_err(|e| anyhow!("{e}"))?,
            vc_w: vw,
        }
    };
    let ann = wham::cost::annotate::AnnotatedGraph::new(
        &graph,
        wham::cost::Dims::of(&config),
        backend.as_mut(),
    );
    let cp = wham::sched::asap_alap(&ann);
    let cores = wham::sched::CoreCount { tc: config.num_tc, vc: config.num_vc };
    let sched = wham::sched::greedy_schedule(&ann, &cp, cores);
    let json = wham::report::trace::chrome_trace(&ann, &sched, cores);
    std::fs::write(&out, &json)?;
    println!(
        "wrote {} ({} events, makespan {} cycles) for {name} on {} — open in ui.perfetto.dev",
        out,
        graph.len(),
        sched.makespan,
        config.display()
    );
    Ok(())
}

/// Show the memory-balanced pipeline partition of an LLM workload.
fn cmd_partition(args: &Args) -> Result<()> {
    let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
    let depth: u64 = args.get_as_or("depth", 32).map_err(|e| anyhow!("{e}"))?;
    let tmp: u64 = args.get_as_or("tmp", 1).map_err(|e| anyhow!("{e}"))?;
    let scheme: Scheme = args.get_or("scheme", "gpipe").parse().map_err(|e| anyhow!("{e}"))?;
    let cfg = wham::models::transformer_cfg(name)
        .ok_or_else(|| anyhow!("{name:?} is not an LLM workload"))?;
    let p = partition_transformer(name, &cfg, depth, tmp, Optimizer::Adam);
    println!(
        "{name}: {} stages x tmp {}, microbatch {}, {} microbatches/iter",
        p.stages.len(),
        p.tmp,
        p.micro_batch,
        p.num_micro
    );
    let mut t = Table::new(["stage", "layers", "ops", "state", "stash/mb", "footprint", "fits HBM"]);
    for s in &p.stages {
        let fp = s.footprint_bytes(scheme, p.num_micro, p.stages.len() as u64);
        t.row([
            s.index.to_string(),
            format!("{}..{}", s.layers.0, s.layers.1),
            s.graph.len().to_string(),
            wham::util::human_bytes(s.state_bytes),
            wham::util::human_bytes(s.stash_bytes),
            wham::util::human_bytes(fp),
            s.fits_hbm(scheme, p.num_micro, p.stages.len() as u64).to_string(),
        ]);
    }
    print!("{t}");
    Ok(())
}

/// Print the Table-3 search-space accounting for a workload.
fn cmd_space(args: &Args) -> Result<()> {
    let name = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
    let (graph, batch) = resolve_workload(name)?;
    let (_, backend_choice, opts) = parse_common(args)?;
    let mut backend = make_backend(backend_choice)?;
    let r = wham::search::engine::WhamSearch::new(&graph, batch, opts).run(backend.as_mut());
    let ann = wham::cost::annotate::AnnotatedGraph::new(
        &graph,
        wham::cost::Dims { tc_x: 128, tc_y: 128, vc_w: 128 },
        backend.as_mut(),
    );
    let s = wham::search::space::space_sizes(&ann, r.dims_evaluated);
    println!("{name}: {} ops, {} dims evaluated by the pruner", graph.len(), r.dims_evaluated);
    println!("  exhaustive      10^{:.0}", s.exhaustive);
    println!("  ILP unpruned    10^{:.0}", s.ilp_unpruned);
    println!("  ILP pruned      10^{:.0}", s.ilp_pruned);
    println!("  heur unpruned   10^{:.0}", s.heur_unpruned);
    println!("  heur pruned     10^{:.0}", s.heur_pruned);
    Ok(())
}

/// Run the long-lived design-mining service (see `wham::service`).
fn cmd_serve(args: &Args) -> Result<()> {
    let port: u16 = args.get_as_or("port", 8484).map_err(|e| anyhow!("{e}"))?;
    let workers: usize = args.get_as_or("workers", 8).map_err(|e| anyhow!("{e}"))?;
    let backend: BackendChoice =
        args.get_or("backend", "auto").parse().map_err(|e| anyhow!("{e}"))?;
    let db_path = args.get("db").map(std::path::PathBuf::from);
    let opts = wham::service::ServeOptions { workers, db_path, backend };
    wham::service::serve_forever(&format!("127.0.0.1:{port}"), opts)
}

/// Drive a running `wham serve` instance over HTTP.
fn cmd_client(args: &Args) -> Result<()> {
    let addr_s = args.get_or("addr", "127.0.0.1:8484");
    let addr: std::net::SocketAddr =
        addr_s.parse().map_err(|_| anyhow!("--addr expects host:port, got {addr_s:?}"))?;
    let sub = args.pos(1).ok_or_else(|| {
        anyhow!("usage: wham client <models|search|evaluate|global|status> [--addr host:port]")
    })?;

    let with_model = |body: &mut String| -> Result<()> {
        let model = args.get("model").ok_or_else(|| anyhow!("--model required"))?;
        body.push_str(&format!("\"model\":{}", wham::util::json::esc(model)));
        Ok(())
    };
    let (method, path, body) = match sub {
        "models" => ("GET", "/models", None),
        "status" => ("GET", "/status", None),
        "search" => {
            let mut b = String::from("{");
            with_model(&mut b)?;
            b.push_str(&format!(",\"metric\":{}", wham::util::json::esc(&args.get_or("metric", "throughput"))));
            if let Some(k) = args.get("k") {
                b.push_str(&format!(",\"k\":{k}"));
            }
            if args.flag("ilp") {
                b.push_str(",\"ilp\":true");
            }
            b.push('}');
            ("POST", "/search", Some(b))
        }
        "evaluate" => {
            let mut b = String::from("{");
            with_model(&mut b)?;
            // --dims TXxTYxVW with --tc/--vc counts, like `wham trace`.
            let dims_s = args.get("dims").ok_or_else(|| anyhow!("--dims TXxTYxVW required"))?;
            let parts: Vec<u64> = dims_s
                .split('x')
                .map(|p| p.parse().map_err(|_| anyhow!("--dims expects TXxTYxVW")))
                .collect::<Result<_>>()?;
            let [tx, ty, vw]: [u64; 3] =
                parts.try_into().map_err(|_| anyhow!("--dims expects three values"))?;
            let tc: u64 = args.get_as_or("tc", 2).map_err(|e| anyhow!("{e}"))?;
            let vc: u64 = args.get_as_or("vc", 2).map_err(|e| anyhow!("{e}"))?;
            b.push_str(&format!(",\"config\":[{tc},{tx},{ty},{vc},{vw}]}}"));
            ("POST", "/evaluate", Some(b))
        }
        "global" => {
            let models = args.get_list("models");
            let mut b = String::from("{");
            if !models.is_empty() {
                let quoted: Vec<String> =
                    models.iter().map(|m| wham::util::json::esc(m)).collect();
                b.push_str(&format!("\"models\":[{}],", quoted.join(",")));
            }
            b.push_str(&format!(
                "\"depth\":{},\"tmp\":{},\"scheme\":{}}}",
                args.get_as_or("depth", 32u64).map_err(|e| anyhow!("{e}"))?,
                args.get_as_or("tmp", 1u64).map_err(|e| anyhow!("{e}"))?,
                wham::util::json::esc(&args.get_or("scheme", "gpipe")),
            ));
            ("POST", "/global", Some(b))
        }
        other => bail!("unknown client subcommand {other:?}"),
    };
    let (status, resp) =
        wham::service::http::request(addr, method, path, body.as_deref())
            .map_err(|e| anyhow!("request to {addr} failed: {e} (is `wham serve` running?)"))?;
    println!("{resp}");
    if status != 200 {
        bail!("server returned HTTP {status}");
    }
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    println!("1/3 native backend ...");
    let graph = wham::models::training("bert-base", Optimizer::Adam).unwrap();
    let mut native = make_backend(BackendChoice::Native)?;
    let en = evaluate_design(&graph, 4, &presets::tpuv2(), native.as_mut());
    println!("    bert-base on TPUv2 (native): {}", report::eval_line(&en));

    println!("2/3 PJRT artifact ...");
    let mut pjrt = make_backend(BackendChoice::Pjrt)
        .map_err(|e| anyhow!("PJRT backend unavailable ({e}); run `make artifacts`"))?;
    let ep = evaluate_design(&graph, 4, &presets::tpuv2(), pjrt.as_mut());
    println!("    bert-base on TPUv2 (pjrt)  : {}", report::eval_line(&ep));

    println!("3/3 agreement ...");
    let rel = (en.seconds - ep.seconds).abs() / en.seconds;
    let rel_e = (en.energy_j - ep.energy_j).abs() / en.energy_j;
    if rel > 1e-3 || rel_e > 1e-3 {
        bail!("backends disagree: latency rel={rel:.2e}, energy rel={rel_e:.2e}");
    }
    println!("    latency rel={rel:.2e}, energy rel={rel_e:.2e}  — OK");

    // Exercise the parallel coordinator too.
    let jobs =
        vec![SearchJob { name: "bert-base".into(), graph, batch: 4, opts: SearchOptions::default() }];
    let rs = run_parallel(jobs, BackendChoice::Auto, 2);
    let coord = rs[0].1.as_ref().map_err(|e| anyhow!("coordinator job failed: {e}"))?;
    println!("coordinator: best {}", coord.best.config.display());
    println!("selftest OK");
    Ok(())
}
