//! The PJRT-backed cost-model executable.
//!
//! Wraps `xla::PjRtClient` (CPU) around `artifacts/cost_model.hlo.txt`:
//! compile once, execute many times from the Layer-3 search hot path.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Fixed operator-table height of the artifact (python/compile/model.py).
pub const N_OPS: usize = 4096;

/// Outputs of one estimator call.
#[derive(Debug, Clone)]
pub struct CostBatch {
    pub latency: Vec<f32>,
    pub energy: Vec<f32>,
    pub util: Vec<f32>,
    /// `[sum(latency), sum(energy), mean(util), valid count]`.
    pub totals: [f32; 4],
}

/// A compiled cost-model executable on the CPU PJRT client.
pub struct CostModelRuntime {
    exe: xla::PjRtLoadedExecutable,
    platform: String,
}

impl std::fmt::Debug for CostModelRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CostModelRuntime")
            .field("platform", &self.platform)
            .field("n_ops", &N_OPS)
            .finish_non_exhaustive()
    }
}

impl CostModelRuntime {
    /// Load and compile the artifact from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let hlo = dir.join("cost_model.hlo.txt");
        if !hlo.is_file() {
            bail!("missing artifact {} — run `make artifacts`", hlo.display());
        }
        // Sanity-check the sidecar contract before paying for compilation.
        let meta = super::read_meta(dir).context("reading cost_model.meta")?;
        if let Some((_, v)) = meta.iter().find(|(k, _)| k == "n_ops") {
            let n: usize = v.parse().context("parsing n_ops")?;
            if n != N_OPS {
                bail!("artifact n_ops={n} but runtime expects {N_OPS}; rebuild artifacts");
            }
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let platform = client.platform_name();
        let proto = xla::HloModuleProto::from_text_file(&hlo)
            .with_context(|| format!("parsing {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling cost model")?;
        Ok(Self { exe, platform })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Evaluate one padded batch. All slices must be exactly `N_OPS` long;
    /// `cfg` is `[tc_x, tc_y, vc_w]`.
    pub fn evaluate(&self, kind: &[i32], m: &[i32], n: &[i32], k: &[i32], cfg: [i32; 3]) -> Result<CostBatch> {
        for (name, s) in [("kind", kind), ("m", m), ("n", n), ("k", k)] {
            if s.len() != N_OPS {
                bail!("{name} has {} rows, artifact expects {N_OPS}", s.len());
            }
        }
        let lit = |v: &[i32]| xla::Literal::vec1(v);
        let args = [lit(kind), lit(m), lit(n), lit(k), lit(&cfg)];
        let result = self.exe.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let (lat, en, ut, tot) = result.to_tuple4().context("decomposing result tuple")?;
        let totals_v = tot.to_vec::<f32>()?;
        let mut totals = [0f32; 4];
        totals.copy_from_slice(&totals_v);
        Ok(CostBatch {
            latency: lat.to_vec::<f32>()?,
            energy: en.to_vec::<f32>()?,
            util: ut.to_vec::<f32>()?,
            totals,
        })
    }
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end by rust/tests/pjrt_vs_native.rs (needs the
    // artifact on disk); unit tests here cover argument validation only.
    use super::*;

    #[test]
    fn evaluate_rejects_wrong_length() {
        let Some(dir) = crate::runtime::artifacts_dir() else { return };
        let rt = CostModelRuntime::load(&dir).unwrap();
        let short = vec![0i32; 8];
        let full = vec![0i32; N_OPS];
        let err = rt.evaluate(&short, &full, &full, &full, [8, 8, 8]);
        assert!(err.is_err());
    }
}
