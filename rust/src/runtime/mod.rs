//! PJRT runtime: load and execute the AOT-compiled Layer-1/2 artifacts.
//!
//! The interchange format is HLO *text* (not serialized protos — see
//! DESIGN.md and python/compile/aot.py): `HloModuleProto::from_text_file`
//! reparses and reassigns instruction ids, which keeps xla_extension
//! 0.5.1 compatible with jax >= 0.5 output.

#[cfg(feature = "pjrt")]
pub mod pjrt;

/// Stub compiled when the `pjrt` feature is off (the `xla` crate and its
/// `xla_extension` native library are then not linked at all). Every
/// entry point returns a descriptive error; [`crate::coordinator`]'s
/// `Auto` backend choice falls back to the bit-compatible native mirror,
/// so searches keep working end to end.
#[cfg(not(feature = "pjrt"))]
pub mod pjrt {
    use std::path::Path;

    use anyhow::{bail, Result};

    /// Fixed operator-table height of the artifact (python/compile/model.py).
    pub const N_OPS: usize = 4096;

    /// Outputs of one estimator call.
    #[derive(Debug, Clone)]
    pub struct CostBatch {
        pub latency: Vec<f32>,
        pub energy: Vec<f32>,
        pub util: Vec<f32>,
        /// `[sum(latency), sum(energy), mean(util), valid count]`.
        pub totals: [f32; 4],
    }

    /// Placeholder for the PJRT executable wrapper.
    #[derive(Debug)]
    pub struct CostModelRuntime {
        _private: (),
    }

    impl CostModelRuntime {
        /// Always fails: the binary was built without PJRT support.
        pub fn load(_dir: &Path) -> Result<Self> {
            bail!(
                "PJRT runtime unavailable: built without the `pjrt` \
                 feature (run `make artifacts`, then rebuild with \
                 `--features pjrt` and the xla crate); the native mirror \
                 backend remains bit-compatible"
            )
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> &str {
            "unavailable"
        }

        /// Unreachable in practice (`load` never succeeds).
        pub fn evaluate(
            &self,
            _kind: &[i32],
            _m: &[i32],
            _n: &[i32],
            _k: &[i32],
            _cfg: [i32; 3],
        ) -> Result<CostBatch> {
            bail!("built without the `pjrt` feature")
        }
    }
}

use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$WHAM_ARTIFACTS` if set, else
/// `artifacts/` found by walking up from the current directory (so tests,
/// benches, and examples all work from workspace subdirectories).
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("WHAM_ARTIFACTS") {
        let p = PathBuf::from(p);
        return p.is_dir().then_some(p);
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("cost_model.hlo.txt").is_file() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}

/// Parse the `key=value` metadata sidecar written by aot.py.
pub fn read_meta(dir: &Path) -> anyhow::Result<Vec<(String, String)>> {
    let text = std::fs::read_to_string(dir.join("cost_model.meta"))?;
    Ok(text
        .lines()
        .filter_map(|l| l.split_once('=').map(|(k, v)| (k.trim().to_string(), v.trim().to_string())))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves_when_built() {
        // The Makefile builds artifacts before `cargo test`; if they are
        // missing we only require graceful None.
        match artifacts_dir() {
            Some(d) => assert!(d.join("cost_model.hlo.txt").is_file()),
            None => {}
        }
    }

    #[test]
    fn meta_parses_if_present() {
        if let Some(d) = artifacts_dir() {
            let meta = read_meta(&d).unwrap();
            let n: usize = meta
                .iter()
                .find(|(k, _)| k == "n_ops")
                .map(|(_, v)| v.parse().unwrap())
                .unwrap();
            assert_eq!(n, 4096);
        }
    }
}
