//! Training-relevant metrics (paper section 6.1 "Performance Metric"):
//! end-to-end throughput and Perf/TDP (the TCO proxy).

use crate::arch::{area, power, ArchConfig, CLOCK_GHZ};

/// What the search optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Maximize samples/second within area+power constraints.
    Throughput,
    /// Maximize throughput/TDP while sustaining a minimum throughput
    /// (the floor is supplied by the search, typically TPUv2's).
    PerfPerTdp,
}

impl std::str::FromStr for Metric {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "throughput" | "thpt" => Ok(Metric::Throughput),
            "perf-per-tdp" | "perf/tdp" | "efficiency" => Ok(Metric::PerfPerTdp),
            other => Err(format!("unknown metric {other:?}")),
        }
    }
}

impl std::fmt::Display for Metric {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Metric::Throughput => write!(f, "throughput"),
            Metric::PerfPerTdp => write!(f, "perf/tdp"),
        }
    }
}

/// Full evaluation of a design point on a workload.
#[derive(Debug, Clone, Copy)]
pub struct Evaluation {
    /// Training-iteration makespan in cycles.
    pub cycles: u64,
    /// Iteration latency in seconds.
    pub seconds: f64,
    /// Samples (sequences/images) per second.
    pub throughput: f64,
    /// Energy per iteration in joules.
    pub energy_j: f64,
    /// Thermal design power of the configuration in watts.
    pub tdp_w: f64,
    /// Die area in mm^2.
    pub area_mm2: f64,
    /// throughput / TDP.
    pub perf_per_tdp: f64,
}

/// Evaluate a scheduled iteration on a config.
pub fn evaluate(config: &ArchConfig, makespan_cycles: u64, batch: u64, energy_pj: f64) -> Evaluation {
    let seconds = makespan_cycles as f64 / (CLOCK_GHZ * 1e9);
    let throughput = batch as f64 / seconds;
    let tdp = power::tdp_w(config);
    Evaluation {
        cycles: makespan_cycles,
        seconds,
        throughput,
        energy_j: energy_pj * 1e-12,
        tdp_w: tdp,
        area_mm2: area::area_mm2(config),
        perf_per_tdp: throughput / tdp,
    }
}

impl Metric {
    /// Scalar score (higher is better). For [`Metric::PerfPerTdp`],
    /// designs below `min_throughput` are heavily penalized so the floor
    /// acts as a constraint while remaining comparable.
    pub fn score(&self, eval: &Evaluation, min_throughput: f64) -> f64 {
        match self {
            Metric::Throughput => eval.throughput,
            Metric::PerfPerTdp => {
                if eval.throughput + 1e-12 < min_throughput {
                    // Infeasible: rank strictly below all feasible designs,
                    // better designs (closer to the floor) still order.
                    -1.0 + eval.throughput / min_throughput.max(1e-12) * 1e-3
                } else {
                    eval.perf_per_tdp
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    #[test]
    fn evaluate_basic_numbers() {
        let c = presets::tpuv2();
        let e = evaluate(&c, 940_000_000, 64, 1e12);
        assert!((e.seconds - 1.0).abs() < 1e-9);
        assert!((e.throughput - 64.0).abs() < 1e-9);
        assert!((e.energy_j - 1.0).abs() < 1e-12);
        assert!(e.perf_per_tdp > 0.0);
    }

    #[test]
    fn throughput_metric_ranks_faster_higher() {
        let c = presets::tpuv2();
        let fast = evaluate(&c, 1_000_000, 64, 1e9);
        let slow = evaluate(&c, 2_000_000, 64, 1e9);
        let m = Metric::Throughput;
        assert!(m.score(&fast, 0.0) > m.score(&slow, 0.0));
    }

    #[test]
    fn perf_tdp_floor_penalizes_infeasible() {
        let c = presets::tpuv2();
        let ok = evaluate(&c, 1_000_000, 64, 1e9);
        let slow = evaluate(&c, 100_000_000_000, 64, 1e9);
        let m = Metric::PerfPerTdp;
        let floor = ok.throughput * 0.5;
        assert!(m.score(&ok, floor) > 0.0);
        assert!(m.score(&slow, floor) < 0.0);
    }

    #[test]
    fn metric_parses() {
        assert_eq!("throughput".parse::<Metric>().unwrap(), Metric::Throughput);
        assert_eq!("perf/tdp".parse::<Metric>().unwrap(), Metric::PerfPerTdp);
        assert!("latency".parse::<Metric>().is_err());
    }
}
