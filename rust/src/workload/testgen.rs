//! Random-but-valid workload-spec generation for property tests.
//!
//! Lives in the library (rather than one test binary) so every
//! integration suite can draw the same distribution:
//! `rust/tests/workload_spec.rs` proves random specs lower to clean
//! graphs; `rust/tests/hotpath_parity.rs` proves the interned/galloping
//! hot paths reproduce the legacy paths bit-for-bit across the same
//! random specs.

use crate::util::prop::Gen;

/// Build a random — but by construction valid — spec document.
pub fn random_spec_json(g: &mut Gen) -> String {
    let dim = |g: &mut Gen| g.rng.range(1, 32);
    let mut items: Vec<String> = Vec::new();
    let mut names: Vec<String> = Vec::new();

    let op = |g: &mut Gen, names: &[String], idx: usize| -> (String, String) {
        let first = idx == 0;
        let name = format!("n{idx}");
        let d1 = dim(g);
        let d2 = dim(g);
        let d3 = dim(g);
        // Explicit inputs sometimes reference an earlier named op;
        // "prev" only once a previous item exists.
        let inputs = if !first && !names.is_empty() && g.rng.chance(0.4) {
            let a = g.rng.choose(names).clone();
            if g.rng.chance(0.5) {
                format!(",\"inputs\":[{:?},\"prev\"]", a)
            } else {
                format!(",\"inputs\":[{a:?}]")
            }
        } else if first {
            ",\"inputs\":[]".to_string()
        } else {
            String::new()
        };
        let body = match g.rng.below(7) {
            0 => format!("\"op\":\"linear\",\"m\":{d1},\"n\":{d2},\"k\":{d3}"),
            1 => format!(
                "\"op\":\"activation\",\"elems\":{},\"intensity\":{}",
                d1 * d2,
                1 + g.rng.below(5)
            ),
            2 => format!("\"op\":\"pool\",\"elems\":{}", d1 * d2),
            3 => format!("\"op\":\"softmax\",\"rows\":{d1},\"cols\":{d2}"),
            4 => format!(
                "\"op\":\"conv\",\"in_c\":{d1},\"out_c\":{d2},\"k\":3,\"hw\":{}",
                1 + g.rng.below(16)
            ),
            5 => format!("\"op\":\"norm\",\"type\":\"layer\",\"rows\":{d1},\"cols\":{d2}"),
            _ => format!("\"op\":\"embed\",\"elems\":{},\"params\":{}", d1 * d2, d2 * d3),
        };
        (format!("{{{body},\"name\":{name:?}{inputs}}}"), name)
    };

    let n_items = 1 + g.len(6);
    for i in 0..n_items {
        if i > 0 && g.rng.chance(0.3) {
            // A block of 1-3 ops repeated 1-3 times; inner ops chain by
            // default and may reference the block input via "in".
            let reps = 1 + g.rng.below(3);
            let n_inner = 1 + g.rng.below(3);
            let mut inner = Vec::new();
            for j in 0..n_inner {
                let e = dim(g) * dim(g);
                if j > 0 && g.rng.chance(0.3) {
                    inner.push(format!(
                        "{{\"op\":\"residual\",\"inputs\":[\"prev\",\"in\"],\"elems\":{e}}}"
                    ));
                } else {
                    inner.push(format!("{{\"op\":\"activation\",\"elems\":{e}}}"));
                }
            }
            items.push(format!(
                "{{\"block\":\"b{i}\",\"repeat\":{reps},\"layers\":[{}]}}",
                inner.join(",")
            ));
            names.push(format!("b{i}"));
        } else {
            let (text, name) = op(g, &names, i);
            items.push(text);
            names.push(name);
        }
    }
    format!(
        "{{\"name\":\"prop-{}\",\"batch\":{},\"graph\":[{}]}}",
        g.rng.below(1_000_000),
        1 + g.rng.below(8),
        items.join(",")
    )
}
