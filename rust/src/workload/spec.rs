//! The declarative workload spec: parsed form + symmetric JSON codec.
//!
//! A spec is a JSON document describing a DNN as data — hyper-parameters,
//! then a dataflow program over a small set of layer kinds:
//!
//! | kind         | dimension fields                              | lowers to |
//! |--------------|-----------------------------------------------|-----------|
//! | `embed`      | `elems`, `params`, `intensity?` (2)           | element-wise lookup+add owning the table |
//! | `linear`     | `m`, `n`, `k`, `weights?` (true), `params?`   | GEMM (`params` defaults to `k*n`; `weights:false` → 0) |
//! | `conv`       | `batch?`, `in_c`, `out_c`, `k`\|`kh`+`kw`, `hw`\|`oh`+`ow`, `params?` | 2-D convolution (implicit GEMM) |
//! | `norm`       | `type:"batch"` `elems`+`channels`, `type:"layer"` `rows`+`cols` | BatchNorm / LayerNorm |
//! | `activation` | `elems`, `intensity?` (1)                     | element-wise |
//! | `residual`   | `elems`, `intensity?` (1), ≥2 `inputs`        | element-wise join |
//! | `pool`       | `elems`, `intensity?` (1)                     | reduction |
//! | `softmax`    | `rows`, `cols`                                | row-wise softmax |
//! | `attention`  | `tokens`, `dim`, `seq`, `softmax_rows?`, 3 `inputs` | scores GEMM + softmax + context GEMM |
//!
//! Every dimension is a [`Dim`]: a literal or an expression over the
//! spec's `params` ([`crate::workload::expr`]). Items sequence implicitly
//! (each op's default input is the previous item's output); `inputs`
//! names earlier layers explicitly, with two reserved references:
//! `"prev"` (previous output) and `"in"` (the enclosing block's input for
//! the current iteration). A *block* (`{"block": name?, "repeat": N,
//! "layers": [...]}`) repeats its body, chaining each iteration's output
//! into the next — residual stacks, LSTM chunk chains, encoder layers.
//!
//! Parsing is strict: unknown fields, mistyped values, and reserved
//! names are [`SpecError`]s carrying the item's path (`graph/enc[2]/q`).

use std::collections::BTreeMap;

use super::SpecError;
use crate::util::json::{self, esc, JsonValue, Obj};

/// One dimension: a literal or an expression over the spec params.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Dim {
    Lit(u64),
    Expr(String),
}

impl Dim {
    /// Evaluate against resolved params.
    pub fn eval(&self, params: &BTreeMap<String, u64>) -> Result<u64, String> {
        match self {
            Dim::Lit(v) => Ok(*v),
            Dim::Expr(e) => super::expr::eval(e, params),
        }
    }

    fn emit(&self) -> String {
        match self {
            Dim::Lit(v) => v.to_string(),
            Dim::Expr(e) => esc(e),
        }
    }
}

/// Transformer hyper-parameters: opts a spec into the distributed
/// pipeline/TMP paths (`wham global`, `wham partition`), which partition
/// by layer rather than by lowered graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TransformerSection {
    pub layers: u64,
    pub hidden: u64,
    pub heads: u64,
    pub seq: u64,
    pub vocab: u64,
    pub ffn_mult: u64,
}

/// Dense computation of one spec layer (field semantics in the module
/// docs; lowering in [`crate::workload::lower`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LayerKind {
    Embed { elems: Dim, params: Dim, intensity: Dim },
    Linear { m: Dim, n: Dim, k: Dim, weights: bool, params: Option<Dim> },
    Conv { batch: Dim, in_c: Dim, out_c: Dim, kh: Dim, kw: Dim, oh: Dim, ow: Dim, params: Option<Dim> },
    BatchNorm { elems: Dim, channels: Dim },
    LayerNorm { rows: Dim, cols: Dim },
    /// `residual: true` lowers identically but is arity-checked as a
    /// join (>= 2 inputs).
    Activation { elems: Dim, intensity: Dim, residual: bool },
    Pool { elems: Dim, intensity: Dim },
    Softmax { rows: Dim, cols: Dim },
    Attention { tokens: Dim, dim: Dim, seq: Dim, softmax_rows: Option<Dim> },
}

impl LayerKind {
    /// Wire name of the kind (the `"op"` field).
    pub fn wire_name(&self) -> &'static str {
        match self {
            LayerKind::Embed { .. } => "embed",
            LayerKind::Linear { .. } => "linear",
            LayerKind::Conv { .. } => "conv",
            LayerKind::BatchNorm { .. } | LayerKind::LayerNorm { .. } => "norm",
            LayerKind::Activation { residual: false, .. } => "activation",
            LayerKind::Activation { residual: true, .. } => "residual",
            LayerKind::Pool { .. } => "pool",
            LayerKind::Softmax { .. } => "softmax",
            LayerKind::Attention { .. } => "attention",
        }
    }
}

/// One operator item.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpSpec {
    pub name: Option<String>,
    /// `None` means "the previous item's output" (or no input for the
    /// first item of the top-level sequence).
    pub inputs: Option<Vec<String>>,
    pub kind: LayerKind,
}

/// A repeatable sub-sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockSpec {
    pub name: Option<String>,
    pub repeat: Dim,
    pub layers: Vec<Item>,
}

/// One entry of a `graph`/`layers` sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Item {
    Op(OpSpec),
    Block(BlockSpec),
}

/// A parsed workload spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadSpec {
    pub name: String,
    pub task: String,
    /// Training batch size (the registry's `batch`, like Table 4).
    pub batch: u64,
    pub accelerators: u64,
    pub distributed_only: bool,
    pub transformer: Option<TransformerSection>,
    /// Hyper-parameters, sorted by name; values may reference each other
    /// (resolved to a fixed point by the lowering pass). `batch` is
    /// injected from the top-level field and is reserved.
    pub params: Vec<(String, Dim)>,
    pub graph: Vec<Item>,
}

// ---- parsing ------------------------------------------------------------

fn err(path: &str, message: impl Into<String>) -> SpecError {
    SpecError { path: path.to_string(), message: message.into() }
}

/// Strict non-negative-integer JSON number.
fn strict_u64(v: &JsonValue) -> Option<u64> {
    match v {
        JsonValue::Num(n)
            if n.is_finite() && *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) =>
        {
            Some(*n as u64)
        }
        _ => None,
    }
}

fn as_obj<'v>(v: &'v JsonValue, path: &str) -> Result<&'v BTreeMap<String, JsonValue>, SpecError> {
    match v {
        JsonValue::Obj(m) => Ok(m),
        _ => Err(err(path, "must be a JSON object")),
    }
}

fn check_fields(
    o: &BTreeMap<String, JsonValue>,
    allowed: &[&str],
    path: &str,
) -> Result<(), SpecError> {
    for k in o.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(err(path, format!("unknown field {k:?} (allowed: {allowed:?})")));
        }
    }
    Ok(())
}

fn get_str(o: &BTreeMap<String, JsonValue>, key: &str, path: &str) -> Result<String, SpecError> {
    match o.get(key) {
        Some(JsonValue::Str(s)) => Ok(s.clone()),
        Some(_) => Err(err(path, format!("{key:?} must be a string"))),
        None => Err(err(path, format!("missing required field {key:?}"))),
    }
}

fn opt_str(
    o: &BTreeMap<String, JsonValue>,
    key: &str,
    path: &str,
) -> Result<Option<String>, SpecError> {
    match o.get(key) {
        None | Some(JsonValue::Null) => Ok(None),
        Some(JsonValue::Str(s)) => Ok(Some(s.clone())),
        Some(_) => Err(err(path, format!("{key:?} must be a string"))),
    }
}

fn get_u64(o: &BTreeMap<String, JsonValue>, key: &str, path: &str) -> Result<u64, SpecError> {
    o.get(key)
        .and_then(strict_u64)
        .ok_or_else(|| err(path, format!("{key:?} must be a non-negative integer")))
}

fn opt_u64_or(
    o: &BTreeMap<String, JsonValue>,
    key: &str,
    default: u64,
    path: &str,
) -> Result<u64, SpecError> {
    match o.get(key) {
        None | Some(JsonValue::Null) => Ok(default),
        Some(v) => strict_u64(v)
            .ok_or_else(|| err(path, format!("{key:?} must be a non-negative integer"))),
    }
}

fn opt_bool_or(
    o: &BTreeMap<String, JsonValue>,
    key: &str,
    default: bool,
    path: &str,
) -> Result<bool, SpecError> {
    match o.get(key) {
        None | Some(JsonValue::Null) => Ok(default),
        Some(JsonValue::Bool(b)) => Ok(*b),
        Some(_) => Err(err(path, format!("{key:?} must be a boolean"))),
    }
}

fn parse_dim(v: &JsonValue, key: &str, path: &str) -> Result<Dim, SpecError> {
    match v {
        JsonValue::Str(s) if !s.trim().is_empty() => Ok(Dim::Expr(s.clone())),
        _ => strict_u64(v).map(Dim::Lit).ok_or_else(|| {
            err(path, format!("{key:?} must be a non-negative integer or an expression string"))
        }),
    }
}

fn get_dim(o: &BTreeMap<String, JsonValue>, key: &str, path: &str) -> Result<Dim, SpecError> {
    match o.get(key) {
        Some(v) => parse_dim(v, key, path),
        None => Err(err(path, format!("missing required field {key:?}"))),
    }
}

fn opt_dim(
    o: &BTreeMap<String, JsonValue>,
    key: &str,
    path: &str,
) -> Result<Option<Dim>, SpecError> {
    match o.get(key) {
        None | Some(JsonValue::Null) => Ok(None),
        Some(v) => parse_dim(v, key, path).map(Some),
    }
}

fn opt_dim_or(
    o: &BTreeMap<String, JsonValue>,
    key: &str,
    default: Dim,
    path: &str,
) -> Result<Dim, SpecError> {
    Ok(opt_dim(o, key, path)?.unwrap_or(default))
}

/// Names an item may bind; `prev`/`in` are reserved references.
fn check_name(name: &Option<String>, path: &str) -> Result<(), SpecError> {
    if let Some(n) = name {
        if n.is_empty() || n == "prev" || n == "in" {
            return Err(err(path, format!("{n:?} is not a usable layer name")));
        }
    }
    Ok(())
}

fn parse_op(o: &BTreeMap<String, JsonValue>, path: &str) -> Result<OpSpec, SpecError> {
    let name = opt_str(o, "name", path)?;
    check_name(&name, path)?;
    let inputs = match o.get("inputs") {
        None | Some(JsonValue::Null) => None,
        Some(JsonValue::Arr(a)) => {
            let mut refs = Vec::with_capacity(a.len());
            for r in a {
                match r {
                    JsonValue::Str(s) if !s.is_empty() => refs.push(s.clone()),
                    _ => return Err(err(path, "\"inputs\" must be an array of layer names")),
                }
            }
            Some(refs)
        }
        Some(_) => return Err(err(path, "\"inputs\" must be an array of layer names")),
    };

    let base = &["op", "name", "inputs"];
    let allow = |extra: &[&str]| {
        let mut v: Vec<&str> = base.to_vec();
        v.extend_from_slice(extra);
        v
    };
    let kind_name = get_str(o, "op", path)?;
    let kind = match kind_name.as_str() {
        "embed" => {
            check_fields(o, &allow(&["elems", "params", "intensity"]), path)?;
            LayerKind::Embed {
                elems: get_dim(o, "elems", path)?,
                params: opt_dim_or(o, "params", Dim::Lit(0), path)?,
                intensity: opt_dim_or(o, "intensity", Dim::Lit(2), path)?,
            }
        }
        "linear" => {
            check_fields(o, &allow(&["m", "n", "k", "weights", "params"]), path)?;
            LayerKind::Linear {
                m: get_dim(o, "m", path)?,
                n: get_dim(o, "n", path)?,
                k: get_dim(o, "k", path)?,
                weights: opt_bool_or(o, "weights", true, path)?,
                params: opt_dim(o, "params", path)?,
            }
        }
        "conv" => {
            check_fields(
                o,
                &allow(&["batch", "in_c", "out_c", "k", "kh", "kw", "hw", "oh", "ow", "params"]),
                path,
            )?;
            let square_k = opt_dim(o, "k", path)?;
            let (kh, kw) = match square_k {
                Some(k) => {
                    if o.contains_key("kh") || o.contains_key("kw") {
                        return Err(err(path, "give either \"k\" or both \"kh\" and \"kw\""));
                    }
                    (k.clone(), k)
                }
                None => (get_dim(o, "kh", path)?, get_dim(o, "kw", path)?),
            };
            let square_hw = opt_dim(o, "hw", path)?;
            let (oh, ow) = match square_hw {
                Some(hw) => {
                    if o.contains_key("oh") || o.contains_key("ow") {
                        return Err(err(path, "give either \"hw\" or both \"oh\" and \"ow\""));
                    }
                    (hw.clone(), hw)
                }
                None => (get_dim(o, "oh", path)?, get_dim(o, "ow", path)?),
            };
            LayerKind::Conv {
                batch: opt_dim_or(o, "batch", Dim::Expr("batch".to_string()), path)?,
                in_c: get_dim(o, "in_c", path)?,
                out_c: get_dim(o, "out_c", path)?,
                kh,
                kw,
                oh,
                ow,
                params: opt_dim(o, "params", path)?,
            }
        }
        "norm" => match get_str(o, "type", path)?.as_str() {
            "batch" => {
                check_fields(o, &allow(&["type", "elems", "channels"]), path)?;
                LayerKind::BatchNorm {
                    elems: get_dim(o, "elems", path)?,
                    channels: get_dim(o, "channels", path)?,
                }
            }
            "layer" => {
                check_fields(o, &allow(&["type", "rows", "cols"]), path)?;
                LayerKind::LayerNorm {
                    rows: get_dim(o, "rows", path)?,
                    cols: get_dim(o, "cols", path)?,
                }
            }
            other => {
                return Err(err(
                    path,
                    format!("norm \"type\" must be \"batch\" or \"layer\", got {other:?}"),
                ))
            }
        },
        "activation" | "residual" => {
            check_fields(o, &allow(&["elems", "intensity"]), path)?;
            LayerKind::Activation {
                elems: get_dim(o, "elems", path)?,
                intensity: opt_dim_or(o, "intensity", Dim::Lit(1), path)?,
                residual: kind_name == "residual",
            }
        }
        "pool" => {
            check_fields(o, &allow(&["elems", "intensity"]), path)?;
            LayerKind::Pool {
                elems: get_dim(o, "elems", path)?,
                intensity: opt_dim_or(o, "intensity", Dim::Lit(1), path)?,
            }
        }
        "softmax" => {
            check_fields(o, &allow(&["rows", "cols"]), path)?;
            LayerKind::Softmax { rows: get_dim(o, "rows", path)?, cols: get_dim(o, "cols", path)? }
        }
        "attention" => {
            check_fields(o, &allow(&["tokens", "dim", "seq", "softmax_rows"]), path)?;
            LayerKind::Attention {
                tokens: get_dim(o, "tokens", path)?,
                dim: get_dim(o, "dim", path)?,
                seq: get_dim(o, "seq", path)?,
                softmax_rows: opt_dim(o, "softmax_rows", path)?,
            }
        }
        other => {
            return Err(err(
                path,
                format!(
                    "unknown op kind {other:?} (known: embed, linear, conv, norm, activation, \
                     residual, pool, softmax, attention)"
                ),
            ))
        }
    };
    Ok(OpSpec { name, inputs, kind })
}

fn parse_item(v: &JsonValue, path: &str) -> Result<Item, SpecError> {
    let o = as_obj(v, path)?;
    if o.contains_key("op") {
        return Ok(Item::Op(parse_op(o, path)?));
    }
    if o.contains_key("layers") {
        check_fields(o, &["block", "repeat", "layers"], path)?;
        let name = opt_str(o, "block", path)?;
        check_name(&name, path)?;
        let bpath = match &name {
            Some(n) => format!("{path}/{n}"),
            None => path.to_string(),
        };
        let layers = parse_items(
            o.get("layers").unwrap(),
            &format!("{bpath}.layers"),
        )?;
        if layers.is_empty() {
            return Err(err(&bpath, "\"layers\" must not be empty"));
        }
        return Ok(Item::Block(BlockSpec {
            name,
            repeat: opt_dim_or(o, "repeat", Dim::Lit(1), &bpath)?,
            layers,
        }));
    }
    Err(err(path, "item must be an op ({\"op\": ...}) or a block ({\"layers\": [...]})"))
}

fn parse_items(v: &JsonValue, path: &str) -> Result<Vec<Item>, SpecError> {
    match v {
        JsonValue::Arr(a) => a
            .iter()
            .enumerate()
            .map(|(i, item)| parse_item(item, &format!("{path}[{i}]")))
            .collect(),
        _ => Err(err(path, "must be an array of items")),
    }
}

fn parse_transformer(v: &JsonValue, path: &str) -> Result<TransformerSection, SpecError> {
    let o = as_obj(v, path)?;
    check_fields(o, &["layers", "hidden", "heads", "seq", "vocab", "ffn_mult"], path)?;
    let t = TransformerSection {
        layers: get_u64(o, "layers", path)?,
        hidden: get_u64(o, "hidden", path)?,
        heads: get_u64(o, "heads", path)?,
        seq: get_u64(o, "seq", path)?,
        vocab: get_u64(o, "vocab", path)?,
        ffn_mult: opt_u64_or(o, "ffn_mult", 4, path)?,
    };
    // The pipeline partitioner divides by these; zeros must be rejected
    // here, not panic a `/global` worker later.
    for (field, v) in [
        ("layers", t.layers),
        ("hidden", t.hidden),
        ("heads", t.heads),
        ("seq", t.seq),
        ("vocab", t.vocab),
        ("ffn_mult", t.ffn_mult),
    ] {
        if v == 0 {
            return Err(err(path, format!("{field:?} must be >= 1")));
        }
    }
    Ok(t)
}

/// Parse a spec document from JSON text.
pub fn parse_spec(text: &str) -> Result<WorkloadSpec, SpecError> {
    let v = json::parse(text).map_err(|e| err("spec", format!("invalid JSON: {e}")))?;
    let o = as_obj(&v, "spec")?;
    check_fields(
        o,
        &["name", "task", "batch", "accelerators", "distributed_only", "transformer", "params", "graph"],
        "spec",
    )?;
    let name = get_str(o, "name", "spec")?;
    if name.is_empty() {
        return Err(err("spec", "\"name\" must not be empty"));
    }
    let batch = get_u64(o, "batch", "spec")?;
    if batch == 0 {
        return Err(err("spec", "\"batch\" must be >= 1"));
    }
    let params = match o.get("params") {
        None | Some(JsonValue::Null) => Vec::new(),
        Some(pv) => {
            let po = as_obj(pv, "spec.params")?;
            // Fixed-point resolution is O(n^2) worst-case; bound n so an
            // untrusted upload cannot pin a worker on param chains.
            const MAX_PARAMS: usize = 4096;
            if po.len() > MAX_PARAMS {
                return Err(err(
                    "spec.params",
                    format!("at most {MAX_PARAMS} hyper-parameters are supported"),
                ));
            }
            let mut out = Vec::with_capacity(po.len());
            for (k, v) in po {
                if k == "batch" {
                    return Err(err(
                        "spec.params",
                        "\"batch\" is reserved (injected from the top-level field)",
                    ));
                }
                out.push((k.clone(), parse_dim(v, k, "spec.params")?));
            }
            out
        }
    };
    let graph = parse_items(
        o.get("graph").ok_or_else(|| err("spec", "missing required field \"graph\""))?,
        "graph",
    )?;
    if graph.is_empty() {
        return Err(err("spec", "\"graph\" must not be empty"));
    }
    Ok(WorkloadSpec {
        name,
        task: opt_str(o, "task", "spec")?.unwrap_or_else(|| "custom".to_string()),
        batch,
        accelerators: opt_u64_or(o, "accelerators", 1, "spec")?,
        distributed_only: opt_bool_or(o, "distributed_only", false, "spec")?,
        transformer: match o.get("transformer") {
            None | Some(JsonValue::Null) => None,
            Some(t) => Some(parse_transformer(t, "spec.transformer")?),
        },
        params,
        graph,
    })
}

// ---- serialization ------------------------------------------------------

fn emit_op(op: &OpSpec) -> String {
    let mut o = Obj::new().str("op", op.kind.wire_name());
    if let Some(n) = &op.name {
        o = o.str("name", n);
    }
    if let Some(inputs) = &op.inputs {
        o = o.raw("inputs", &json::str_arr(inputs.iter().map(String::as_str)));
    }
    o = match &op.kind {
        LayerKind::Embed { elems, params, intensity } => o
            .raw("elems", &elems.emit())
            .raw("params", &params.emit())
            .raw("intensity", &intensity.emit()),
        LayerKind::Linear { m, n, k, weights, params } => {
            let mut o = o
                .raw("m", &m.emit())
                .raw("n", &n.emit())
                .raw("k", &k.emit())
                .bool("weights", *weights);
            if let Some(p) = params {
                o = o.raw("params", &p.emit());
            }
            o
        }
        LayerKind::Conv { batch, in_c, out_c, kh, kw, oh, ow, params } => {
            let mut o = o
                .raw("batch", &batch.emit())
                .raw("in_c", &in_c.emit())
                .raw("out_c", &out_c.emit())
                .raw("kh", &kh.emit())
                .raw("kw", &kw.emit())
                .raw("oh", &oh.emit())
                .raw("ow", &ow.emit());
            if let Some(p) = params {
                o = o.raw("params", &p.emit());
            }
            o
        }
        LayerKind::BatchNorm { elems, channels } => o
            .str("type", "batch")
            .raw("elems", &elems.emit())
            .raw("channels", &channels.emit()),
        LayerKind::LayerNorm { rows, cols } => {
            o.str("type", "layer").raw("rows", &rows.emit()).raw("cols", &cols.emit())
        }
        LayerKind::Activation { elems, intensity, .. } => {
            o.raw("elems", &elems.emit()).raw("intensity", &intensity.emit())
        }
        LayerKind::Pool { elems, intensity } => {
            o.raw("elems", &elems.emit()).raw("intensity", &intensity.emit())
        }
        LayerKind::Softmax { rows, cols } => {
            o.raw("rows", &rows.emit()).raw("cols", &cols.emit())
        }
        LayerKind::Attention { tokens, dim, seq, softmax_rows } => {
            let mut o = o
                .raw("tokens", &tokens.emit())
                .raw("dim", &dim.emit())
                .raw("seq", &seq.emit());
            if let Some(r) = softmax_rows {
                o = o.raw("softmax_rows", &r.emit());
            }
            o
        }
    };
    o.finish()
}

fn emit_item(item: &Item) -> String {
    match item {
        Item::Op(op) => emit_op(op),
        Item::Block(b) => {
            let mut o = Obj::new();
            if let Some(n) = &b.name {
                o = o.str("block", n);
            }
            o.raw("repeat", &b.repeat.emit())
                .raw("layers", &json::arr(b.layers.iter().map(emit_item)))
                .finish()
        }
    }
}

impl WorkloadSpec {
    /// Canonical wire form; `parse_spec(to_json(s))` reproduces `s`
    /// field-for-field (defaults made explicit, conv sugar expanded).
    pub fn to_json(&self) -> String {
        let mut o = Obj::new()
            .str("name", &self.name)
            .str("task", &self.task)
            .u64("batch", self.batch)
            .u64("accelerators", self.accelerators)
            .bool("distributed_only", self.distributed_only);
        if let Some(t) = &self.transformer {
            o = o.raw(
                "transformer",
                &Obj::new()
                    .u64("layers", t.layers)
                    .u64("hidden", t.hidden)
                    .u64("heads", t.heads)
                    .u64("seq", t.seq)
                    .u64("vocab", t.vocab)
                    .u64("ffn_mult", t.ffn_mult)
                    .finish(),
            );
        }
        if !self.params.is_empty() {
            let mut p = Obj::new();
            for (k, d) in &self.params {
                p = p.raw(k, &d.emit());
            }
            o = o.raw("params", &p.finish());
        }
        o.raw("graph", &json::arr(self.graph.iter().map(emit_item))).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TINY: &str = r#"{
        "name": "tiny", "task": "test", "batch": 2,
        "params": {"h": 8, "bs": "batch*4"},
        "graph": [
            {"op": "embed", "elems": "bs*h", "params": "16*h"},
            {"block": "body", "repeat": 2, "layers": [
                {"op": "linear", "name": "fc", "m": "bs", "n": "h", "k": "h"},
                {"op": "residual", "inputs": ["fc", "in"], "elems": "bs*h"}
            ]},
            {"op": "linear", "weights": false, "m": "bs", "n": 10, "k": "h"}
        ]
    }"#;

    #[test]
    fn parses_and_round_trips() {
        let s = parse_spec(TINY).unwrap();
        assert_eq!(s.name, "tiny");
        assert_eq!(s.batch, 2);
        assert_eq!(s.graph.len(), 3);
        let emitted = s.to_json();
        let s2 = parse_spec(&emitted).unwrap();
        assert_eq!(s, s2, "parse(to_json(s)) must reproduce s");
        assert_eq!(s2.to_json(), emitted, "second serialization must be byte-identical");
    }

    #[test]
    fn conv_sugar_expands() {
        let s = parse_spec(
            r#"{"name":"c","batch":4,"graph":[
                {"op":"conv","in_c":3,"out_c":8,"k":3,"hw":16}
            ]}"#,
        )
        .unwrap();
        match &s.graph[0] {
            Item::Op(op) => match &op.kind {
                LayerKind::Conv { kh, kw, oh, ow, batch, .. } => {
                    assert_eq!(kh, &Dim::Lit(3));
                    assert_eq!(kw, &Dim::Lit(3));
                    assert_eq!(oh, &Dim::Lit(16));
                    assert_eq!(ow, &Dim::Lit(16));
                    assert_eq!(batch, &Dim::Expr("batch".to_string()));
                }
                other => panic!("not a conv: {other:?}"),
            },
            other => panic!("not an op: {other:?}"),
        }
        // Round-trips through the expanded form.
        assert!(parse_spec(&s.to_json()).is_ok());
    }

    #[test]
    fn unknown_fields_and_kinds_carry_paths() {
        let e = parse_spec(
            r#"{"name":"x","batch":1,"graph":[{"op":"linear","m":1,"n":1,"k":1,"parms":5}]}"#,
        )
        .unwrap_err();
        assert!(e.path.contains("graph[0]"), "{e}");
        assert!(e.message.contains("parms"), "{e}");

        let e = parse_spec(r#"{"name":"x","batch":1,"graph":[{"op":"lstm"}]}"#).unwrap_err();
        assert!(e.message.contains("unknown op kind"), "{e}");

        // Fields of the *other* norm type are rejected, not ignored.
        let e = parse_spec(
            r#"{"name":"x","batch":1,"graph":[
                {"op":"norm","type":"layer","rows":4,"cols":4,"elems":99}
            ]}"#,
        )
        .unwrap_err();
        assert!(e.message.contains("elems"), "{e}");
    }

    #[test]
    fn reserved_names_rejected() {
        let e = parse_spec(
            r#"{"name":"x","batch":1,"graph":[{"op":"pool","name":"prev","elems":4}]}"#,
        )
        .unwrap_err();
        assert!(e.message.contains("prev"), "{e}");
        let e = parse_spec(r#"{"name":"x","batch":1,"params":{"batch":3},"graph":[{"op":"pool","elems":4}]}"#)
            .unwrap_err();
        assert!(e.message.contains("reserved"), "{e}");
    }

    #[test]
    fn transformer_section_rejects_zeros() {
        let e = parse_spec(
            r#"{"name":"t","batch":1,
                "transformer":{"layers":0,"hidden":64,"heads":4,"seq":32,"vocab":100},
                "graph":[{"op":"pool","elems":4}]}"#,
        )
        .unwrap_err();
        assert!(e.message.contains("layers"), "{e}");
        assert!(parse_spec(
            r#"{"name":"t","batch":1,
                "transformer":{"layers":2,"hidden":64,"heads":4,"seq":32,"vocab":100},
                "graph":[{"op":"pool","elems":4}]}"#,
        )
        .is_ok());
    }

    #[test]
    fn missing_required_fields_error() {
        assert!(parse_spec(r#"{"batch":1,"graph":[]}"#).is_err());
        assert!(parse_spec(r#"{"name":"x","graph":[]}"#).is_err());
        assert!(parse_spec(r#"{"name":"x","batch":1,"graph":[]}"#).is_err());
        assert!(parse_spec(r#"{"name":"x","batch":0,"graph":[{"op":"pool","elems":1}]}"#).is_err());
    }
}
