//! Shape inference + lowering: a parsed [`WorkloadSpec`] into the same
//! [`OperatorGraph`] form the builtin Rust constructors produce.
//!
//! The pass runs in three steps:
//!
//! 1. **Parameter resolution** — the spec's `params` (which may reference
//!    each other and the injected `batch`) are evaluated to a fixed
//!    point; unresolvable or cyclic definitions are spec errors.
//! 2. **Lowering** — the item tree is walked in order, blocks unrolled,
//!    references resolved, and every op emitted through the shared
//!    [`GraphBuilder`] with the exact `OpKind` / `param_elems` the model
//!    zoo uses (`rust/tests/workload_spec.rs` pins fingerprint equality
//!    between the shipped specs and their Rust constructors). Each op's
//!    cost row is checked *before* emission, so zero or over-`i32` dims
//!    surface as diagnostics with the layer's path, not as a
//!    [`crate::graph::validate`] failure naming an anonymous node id.
//! 3. **Training expansion** — [`training`] applies the same pipeline as
//!    [`crate::models::training`]: fuse, then mirror into the training
//!    graph, then a final `validate()` backstop.

use std::collections::{BTreeMap, HashMap};

use super::spec::{Dim, Item, LayerKind, OpSpec, WorkloadSpec};
use super::SpecError;
use crate::graph::autodiff::{training_graph, Optimizer};
use crate::graph::fusion::fuse;
use crate::graph::validate::validate;
use crate::graph::{GraphBuilder, NodeId, OpKind, OperatorGraph};

fn err(path: &str, message: impl Into<String>) -> SpecError {
    SpecError { path: path.to_string(), message: message.into() }
}

/// Hard cap on lowered forward operators per spec. Roughly 50x the
/// largest builtin (GPT-3's forward pass is ~1.3k ops), it bounds the
/// CPU/memory a single uploaded document can consume during validation —
/// `repeat` is otherwise an arbitrary u64, and `POST /workloads` is an
/// open endpoint.
pub const MAX_SPEC_OPS: usize = 250_000;

/// Resolve the spec's hyper-parameters (plus the injected `batch`) to
/// concrete values, tolerating forward references via fixed-point
/// iteration.
pub fn resolve_params(spec: &WorkloadSpec) -> Result<BTreeMap<String, u64>, SpecError> {
    let mut env: BTreeMap<String, u64> = BTreeMap::new();
    env.insert("batch".to_string(), spec.batch);
    let mut pending: Vec<(&String, &Dim)> = spec.params.iter().map(|(k, d)| (k, d)).collect();
    while !pending.is_empty() {
        let before = pending.len();
        let mut next = Vec::new();
        let mut last_err = String::new();
        for (k, d) in pending {
            match d.eval(&env) {
                Ok(v) => {
                    env.insert(k.clone(), v);
                }
                Err(e) => {
                    last_err = format!("param {k:?}: {e}");
                    next.push((k, d));
                }
            }
        }
        if next.len() == before {
            // A full pass resolved nothing: a cycle or an unknown name.
            return Err(err("spec.params", last_err));
        }
        pending = next;
    }
    Ok(env)
}

fn eval_dim(
    d: &Dim,
    params: &BTreeMap<String, u64>,
    path: &str,
    field: &str,
) -> Result<u64, SpecError> {
    d.eval(params).map_err(|e| err(path, format!("field {field:?}: {e}")))
}

/// Check the cost row, then emit through the shared builder.
fn push_op(
    b: &mut GraphBuilder,
    kind: OpKind,
    params: u64,
    preds: &[NodeId],
    name: String,
) -> Result<NodeId, SpecError> {
    if b.len() >= MAX_SPEC_OPS {
        return Err(err(
            &name,
            format!("workload exceeds the {MAX_SPEC_OPS}-operator budget (runaway \"repeat\"?)"),
        ));
    }
    check_row(&kind, &name)?;
    Ok(b.fwd(name, kind, params, preds))
}

/// Checked product of cost-row components, bounded by the i32 cost-model
/// contract. Every multiplication that feeds a cost row or `out_elems`
/// goes through here *before* an [`OpKind`] is constructed, so huge spec
/// dims are path-tagged 400s rather than debug-build overflow panics (or
/// silent release-build wraparound) inside `cost_row()`.
fn row_dim(path: &str, what: &str, xs: &[u64]) -> Result<u64, SpecError> {
    let mut acc: u64 = 1;
    for &x in xs {
        acc = acc
            .checked_mul(x)
            .ok_or_else(|| err(path, format!("{what} overflows u64")))?;
    }
    if acc > i32::MAX as u64 {
        return Err(err(
            path,
            format!("{what} ({acc}) exceeds the i32 cost-model contract"),
        ));
    }
    Ok(acc)
}

/// Checked parameter count. Weights feed the optimizer update op's cost
/// row (`Elementwise { elems: param_elems }`), so they carry the same
/// i32 bound — enforced here with the layer's path rather than by the
/// training-graph validator's anonymous node-id backstop.
fn param_count(path: &str, what: &str, xs: &[u64]) -> Result<u64, SpecError> {
    row_dim(path, what, xs)
}

/// Pre-emission check of one cost row, with a path-tagged diagnostic.
/// All products inside `cost_row()`/`out_elems()` are already bounded by
/// [`row_dim`] at this point; this is the zero/backstop check.
fn check_row(kind: &OpKind, path: &str) -> Result<(), SpecError> {
    let r = kind.cost_row();
    if r.m == 0 || r.n == 0 || r.k == 0 {
        return Err(err(
            path,
            format!("lowers to a zero dimension (cost row m={}, n={}, k={})", r.m, r.n, r.k),
        ));
    }
    if r.m > i32::MAX as u64 || r.n > i32::MAX as u64 || r.k > i32::MAX as u64 {
        return Err(err(path, "dimensions exceed the i32 cost-model contract"));
    }
    Ok(())
}

struct Ctx<'s> {
    b: GraphBuilder,
    params: &'s BTreeMap<String, u64>,
    /// Name scopes, innermost last; one frame per sequence (so each block
    /// iteration rebinds its names freshly).
    scopes: Vec<HashMap<String, NodeId>>,
}

impl<'s> Ctx<'s> {
    fn resolve_ref(
        &self,
        r: &str,
        prev: Option<NodeId>,
        input: Option<NodeId>,
        path: &str,
    ) -> Result<NodeId, SpecError> {
        match r {
            "prev" => prev.ok_or_else(|| err(path, "\"prev\" has no previous layer here")),
            "in" => input.ok_or_else(|| {
                err(path, "\"in\" is only valid inside a block that has an input")
            }),
            name => self
                .scopes
                .iter()
                .rev()
                .find_map(|f| f.get(name))
                .copied()
                .ok_or_else(|| err(path, format!("unknown layer reference {name:?}"))),
        }
    }

    fn bind(&mut self, name: &str, node: NodeId, path: &str) -> Result<(), SpecError> {
        let frame = self.scopes.last_mut().expect("scope stack is never empty here");
        if frame.insert(name.to_string(), node).is_some() {
            return Err(err(path, format!("duplicate layer name {name:?} in this sequence")));
        }
        Ok(())
    }

    fn emit(&mut self, op: &OpSpec, preds: &[NodeId], path: &str) -> Result<NodeId, SpecError> {
        // Copied out so the closure doesn't hold a borrow of `self`
        // across the `&mut self.b` builder calls below.
        let pmap = self.params;
        let e = |field: &str, d: &Dim| eval_dim(d, pmap, path, field);
        // Single dims feeding a cost row get the same i32 bound as
        // products (row_dim over one factor).
        let one = |field: &str, d: &Dim| row_dim(path, field, &[eval_dim(d, pmap, path, field)?]);
        let b = &mut self.b;
        match &op.kind {
            LayerKind::Embed { elems, params, intensity } => {
                let kind = OpKind::Elementwise {
                    elems: one("elems", elems)?,
                    intensity: one("intensity", intensity)?,
                };
                let p = param_count(path, "params", &[e("params", params)?])?;
                push_op(b, kind, p, preds, path.to_string())
            }
            LayerKind::Linear { m, n, k, weights, params } => {
                let (m, n, k) = (one("m", m)?, one("n", n)?, one("k", k)?);
                let p = match params {
                    Some(d) => param_count(path, "params", &[e("params", d)?])?,
                    None if *weights => param_count(path, "weight count k*n", &[k, n])?,
                    None => 0,
                };
                push_op(b, OpKind::Gemm { m, n, k }, p, preds, path.to_string())
            }
            LayerKind::Conv { batch, in_c, out_c, kh, kw, oh, ow, params } => {
                let (batch, in_c, out_c) =
                    (e("batch", batch)?, e("in_c", in_c)?, one("out_c", out_c)?);
                let (kh, kw, oh, ow) = (e("kh", kh)?, e("kw", kw)?, e("oh", oh)?, e("ow", ow)?);
                // The implicit-GEMM row and out_elems multiply these.
                row_dim(path, "batch*oh*ow", &[batch, oh, ow])?;
                row_dim(path, "in_c*kh*kw", &[in_c, kh, kw])?;
                let p = match params {
                    Some(d) => param_count(path, "params", &[e("params", d)?])?,
                    None => param_count(
                        path,
                        "weight count in_c*out_c*kh*kw",
                        &[in_c, out_c, kh, kw],
                    )?,
                };
                push_op(
                    b,
                    OpKind::Conv2d { batch, in_c, out_c, kh, kw, oh, ow },
                    p,
                    preds,
                    path.to_string(),
                )
            }
            LayerKind::BatchNorm { elems, channels } => {
                let c = e("channels", channels)?;
                push_op(
                    b,
                    OpKind::Elementwise { elems: one("elems", elems)?, intensity: 2 },
                    param_count(path, "affine params 2*channels", &[2, c])?,
                    preds,
                    path.to_string(),
                )
            }
            LayerKind::LayerNorm { rows, cols } => {
                let (rows, cols) = (e("rows", rows)?, e("cols", cols)?);
                row_dim(path, "rows*cols", &[rows, cols])?;
                push_op(
                    b,
                    OpKind::LayerNorm { rows, cols },
                    param_count(path, "affine params 2*cols", &[2, cols])?,
                    preds,
                    path.to_string(),
                )
            }
            LayerKind::Activation { elems, intensity, residual } => {
                if *residual && preds.len() < 2 {
                    return Err(err(
                        path,
                        format!(
                            "residual is a join and expects >= 2 inputs, got {} (use \
                             \"activation\" for a unary op)",
                            preds.len()
                        ),
                    ));
                }
                push_op(
                    b,
                    OpKind::Elementwise {
                        elems: one("elems", elems)?,
                        intensity: one("intensity", intensity)?,
                    },
                    0,
                    preds,
                    path.to_string(),
                )
            }
            LayerKind::Pool { elems, intensity } => push_op(
                b,
                OpKind::Reduction {
                    elems: one("elems", elems)?,
                    intensity: one("intensity", intensity)?,
                },
                0,
                preds,
                path.to_string(),
            ),
            LayerKind::Softmax { rows, cols } => {
                let (rows, cols) = (e("rows", rows)?, e("cols", cols)?);
                row_dim(path, "rows*cols", &[rows, cols])?;
                push_op(b, OpKind::Softmax { rows, cols }, 0, preds, path.to_string())
            }
            LayerKind::Attention { tokens, dim, seq, softmax_rows } => {
                if preds.len() != 3 {
                    return Err(err(
                        path,
                        format!(
                            "attention expects exactly 3 inputs [query, key, value], got {}",
                            preds.len()
                        ),
                    ));
                }
                let (t, d, s) = (one("tokens", tokens)?, one("dim", dim)?, one("seq", seq)?);
                let rows = match softmax_rows {
                    Some(r) => e("softmax_rows", r)?,
                    None => t,
                };
                row_dim(path, "softmax_rows*seq", &[rows, s])?;
                let scores = push_op(
                    b,
                    OpKind::Gemm { m: t, n: s, k: d },
                    0,
                    &[preds[0], preds[1]][..],
                    format!("{path}/scores"),
                )?;
                let sm = push_op(
                    b,
                    OpKind::Softmax { rows, cols: s },
                    0,
                    &[scores][..],
                    format!("{path}/softmax"),
                )?;
                push_op(
                    b,
                    OpKind::Gemm { m: t, n: d, k: s },
                    0,
                    &[sm, preds[2]][..],
                    format!("{path}/ctx"),
                )
            }
        }
    }
}

/// Lower one item sequence. `input` is the sequence's dataflow input
/// (`"in"`); returns the output of the last item.
fn lower_seq(
    ctx: &mut Ctx<'_>,
    items: &[Item],
    input: Option<NodeId>,
    path: &str,
) -> Result<Option<NodeId>, SpecError> {
    ctx.scopes.push(HashMap::new());
    let mut prev = input;
    let result = (|| {
        for (i, item) in items.iter().enumerate() {
            match item {
                Item::Op(op) => {
                    let ipath = match &op.name {
                        Some(n) => format!("{path}/{n}"),
                        None => format!("{path}[{i}]"),
                    };
                    let preds: Vec<NodeId> = match &op.inputs {
                        Some(refs) => refs
                            .iter()
                            .map(|r| ctx.resolve_ref(r, prev, input, &ipath))
                            .collect::<Result<_, _>>()?,
                        None => prev.into_iter().collect(),
                    };
                    let node = ctx.emit(op, &preds, &ipath)?;
                    if let Some(n) = &op.name {
                        ctx.bind(n, node, &ipath)?;
                    }
                    prev = Some(node);
                }
                Item::Block(blk) => {
                    let bpath = match &blk.name {
                        Some(n) => format!("{path}/{n}"),
                        None => format!("{path}[{i}]"),
                    };
                    let n = eval_dim(&blk.repeat, ctx.params, &bpath, "repeat")?;
                    if n == 0 {
                        return Err(err(&bpath, "\"repeat\" must be >= 1"));
                    }
                    let mut cur = prev;
                    for it in 0..n {
                        cur = lower_seq(ctx, &blk.layers, cur, &format!("{bpath}[{it}]"))?;
                    }
                    if let Some(name) = &blk.name {
                        let out = cur.ok_or_else(|| err(&bpath, "block produced no output"))?;
                        ctx.bind(name, out, &bpath)?;
                    }
                    prev = cur;
                }
            }
        }
        Ok(prev)
    })();
    ctx.scopes.pop();
    result
}

/// Lower a spec into its **forward** operator graph.
pub fn lower(spec: &WorkloadSpec) -> Result<OperatorGraph, SpecError> {
    let params = resolve_params(spec)?;
    let mut ctx = Ctx { b: GraphBuilder::new(), params: &params, scopes: Vec::new() };
    lower_seq(&mut ctx, &spec.graph, None, "graph")?;
    let g = ctx.b.finish();
    validate(&g).map_err(|e| {
        err(&format!("workload {:?}", spec.name), format!("lowered forward graph is invalid: {e}"))
    })?;
    Ok(g)
}

/// Lower a spec into the full **training** graph — the same
/// fuse-then-mirror pipeline as [`crate::models::training`], so a spec
/// re-expressing a builtin fingerprints identically to it.
pub fn training(spec: &WorkloadSpec) -> Result<OperatorGraph, SpecError> {
    training_of(&spec.name, &lower(spec)?)
}

/// Training expansion of an already-lowered forward graph (lets callers
/// that need both forms — lint, `wham workloads show` — lower once).
pub fn training_of(name: &str, fwd: &OperatorGraph) -> Result<OperatorGraph, SpecError> {
    let (fused, _) = fuse(fwd);
    let g = training_graph(&fused, Optimizer::Adam);
    validate(&g).map_err(|e| {
        err(&format!("workload {name:?}"), format!("lowered training graph is invalid: {e}"))
    })?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::fingerprint;
    use crate::workload::spec::parse_spec;

    const MLP: &str = r#"{
        "name": "mlp", "batch": 2,
        "params": {"h": 16, "bs": "batch*8"},
        "graph": [
            {"op": "embed", "elems": "bs*h", "params": "32*h"},
            {"block": "body", "repeat": 3, "layers": [
                {"op": "linear", "name": "fc", "m": "bs", "n": "h", "k": "h"},
                {"op": "activation", "elems": "bs*h", "intensity": 1},
                {"op": "residual", "inputs": ["prev", "in"], "elems": "bs*h"}
            ]},
            {"op": "linear", "weights": false, "m": "bs", "n": 10, "k": "h"}
        ]
    }"#;

    #[test]
    fn lowers_blocks_and_references() {
        let spec = parse_spec(MLP).unwrap();
        let g = lower(&spec).unwrap();
        // 1 embed + 3 iterations x 3 ops + 1 head.
        assert_eq!(g.len(), 1 + 3 * 3 + 1);
        assert_eq!(g.sources(), vec![0]);
        // Residuals join the activation and the iteration input.
        let res = g.ops.iter().position(|o| o.name.contains("body[0][2]")).unwrap();
        assert_eq!(g.preds(res).len(), 2);
        // Deterministic lowering.
        assert_eq!(fingerprint(&lower(&spec).unwrap()), fingerprint(&g));
    }

    #[test]
    fn training_pipeline_matches_models_shape() {
        let spec = parse_spec(MLP).unwrap();
        let t = training(&spec).unwrap();
        assert!(t.len() > lower(&spec).unwrap().len());
        crate::graph::validate::validate(&t).unwrap();
        let passes = t.pass_counts();
        assert!(passes[1] > 0, "backward ops exist");
        assert!(passes[2] > 0, "update ops exist");
    }

    #[test]
    fn params_resolve_in_any_order() {
        // "a" references "z" which sorts after it in the BTreeMap.
        let spec = parse_spec(
            r#"{"name":"p","batch":1,"params":{"a":"z*2","z":4},
                "graph":[{"op":"pool","elems":"a"}]}"#,
        )
        .unwrap();
        let p = resolve_params(&spec).unwrap();
        assert_eq!(p.get("a"), Some(&8));
        assert_eq!(p.get("batch"), Some(&1));
    }

    #[test]
    fn cyclic_or_unknown_params_error() {
        let spec = parse_spec(
            r#"{"name":"p","batch":1,"params":{"a":"b","b":"a"},
                "graph":[{"op":"pool","elems":1}]}"#,
        )
        .unwrap();
        let e = resolve_params(&spec).unwrap_err();
        assert_eq!(e.path, "spec.params");

        let spec = parse_spec(
            r#"{"name":"p","batch":1,"graph":[{"op":"pool","elems":"nope"}]}"#,
        )
        .unwrap();
        let e = lower(&spec).unwrap_err();
        assert!(e.message.contains("nope"), "{e}");
        assert!(e.path.contains("graph[0]"), "{e}");
    }

    #[test]
    fn zero_dims_are_path_tagged() {
        let spec = parse_spec(
            r#"{"name":"z","batch":1,"graph":[
                {"op":"linear","name":"bad","m":0,"n":4,"k":4}
            ]}"#,
        )
        .unwrap();
        let e = lower(&spec).unwrap_err();
        assert_eq!(e.path, "graph/bad");
        assert!(e.message.contains("zero dimension"), "{e}");
    }

    #[test]
    fn oversized_dims_and_params_are_path_tagged() {
        // A cost-row product past the i32 contract is a spec diagnostic,
        // not a validator error naming an anonymous node.
        let spec = parse_spec(
            r#"{"name":"big","batch":1,"graph":[
                {"op":"softmax","name":"sm","rows":100000,"cols":100000}
            ]}"#,
        )
        .unwrap();
        let e = lower(&spec).unwrap_err();
        assert_eq!(e.path, "graph/sm");
        assert!(e.message.contains("i32"), "{e}");

        // Explicit weight counts hit the same bound (they become the
        // update op's cost row).
        let spec = parse_spec(
            r#"{"name":"big","batch":1,"graph":[
                {"op":"linear","name":"fat","m":4,"n":4,"k":4,"params":3000000000}
            ]}"#,
        )
        .unwrap();
        let e = lower(&spec).unwrap_err();
        assert_eq!(e.path, "graph/fat");
        assert!(e.message.contains("i32"), "{e}");
    }

    #[test]
    fn bad_references_are_path_tagged() {
        let spec = parse_spec(
            r#"{"name":"r","batch":1,"graph":[
                {"op":"pool","elems":4},
                {"op":"pool","inputs":["ghost"],"elems":4}
            ]}"#,
        )
        .unwrap();
        let e = lower(&spec).unwrap_err();
        assert!(e.message.contains("ghost"), "{e}");

        // "in" at top level has no input.
        let spec = parse_spec(
            r#"{"name":"r","batch":1,"graph":[{"op":"pool","inputs":["in"],"elems":4}]}"#,
        )
        .unwrap();
        assert!(lower(&spec).unwrap_err().message.contains("in"));
    }

    #[test]
    fn attention_expands_to_three_ops() {
        let spec = parse_spec(
            r#"{"name":"a","batch":1,"params":{"t":8,"d":4,"s":6},"graph":[
                {"op":"embed","name":"x","elems":"t*d"},
                {"op":"linear","name":"q","inputs":["x"],"m":"t","n":"d","k":"d"},
                {"op":"linear","name":"k","inputs":["x"],"m":"t","n":"d","k":"d"},
                {"op":"linear","name":"v","inputs":["x"],"m":"t","n":"d","k":"d"},
                {"op":"attention","inputs":["q","k","v"],"tokens":"t","dim":"d","seq":"s"}
            ]}"#,
        )
        .unwrap();
        let g = lower(&spec).unwrap();
        assert_eq!(g.len(), 4 + 3);
        let scores = g.ops.iter().position(|o| o.name.ends_with("/scores")).unwrap();
        let sm = g.ops.iter().position(|o| o.name.ends_with("/softmax")).unwrap();
        let ctx = g.ops.iter().position(|o| o.name.ends_with("/ctx")).unwrap();
        assert_eq!(g.preds(scores).len(), 2);
        assert_eq!(g.preds(sm), &[scores as u32]);
        assert_eq!(g.preds(ctx).len(), 2);
        assert!(matches!(g.ops[ctx].kind, OpKind::Gemm { m: 8, n: 4, k: 6 }));
        assert_eq!(g.ops[scores].param_elems, 0);
    }

    #[test]
    fn runaway_repeat_hits_the_op_budget() {
        let spec = parse_spec(
            r#"{"name":"bomb","batch":1,"graph":[
                {"block":"b","repeat":4000000000,"layers":[{"op":"pool","elems":1}]}
            ]}"#,
        )
        .unwrap();
        let e = lower(&spec).unwrap_err();
        assert!(e.message.contains("operator budget"), "{e}");
    }

    #[test]
    fn residual_requires_a_join() {
        let spec = parse_spec(
            r#"{"name":"r","batch":1,"graph":[
                {"op":"embed","elems":4},
                {"op":"residual","name":"lonely","elems":4}
            ]}"#,
        )
        .unwrap();
        let e = lower(&spec).unwrap_err();
        assert_eq!(e.path, "graph/lonely");
        assert!(e.message.contains(">= 2"), "{e}");
    }

    #[test]
    fn duplicate_names_rejected_but_iterations_rebind() {
        let dup = parse_spec(
            r#"{"name":"d","batch":1,"graph":[
                {"op":"pool","name":"x","elems":4},
                {"op":"pool","name":"x","elems":4}
            ]}"#,
        )
        .unwrap();
        assert!(lower(&dup).unwrap_err().message.contains("duplicate"));

        // The same name in successive block iterations is fine.
        let ok = parse_spec(
            r#"{"name":"d","batch":1,"graph":[
                {"op":"embed","elems":4},
                {"repeat":3,"layers":[{"op":"pool","name":"x","elems":4}]}
            ]}"#,
        )
        .unwrap();
        assert!(lower(&ok).is_ok());
    }
}
