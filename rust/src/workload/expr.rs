//! Integer dimension expressions over named workload hyper-parameters.
//!
//! Spec files write shapes as either JSON numbers or small arithmetic
//! expressions (`"batch*seq"`, `"4*hidden"`, `"chunks-1"`) evaluated
//! against the spec's `params` map. The grammar is deliberately tiny —
//! `+ - * /` with the usual precedence, parentheses, decimal literals,
//! and identifiers — and all arithmetic is checked `u64` (overflow,
//! underflow, and division by zero are spec errors, not panics).

use std::collections::BTreeMap;

/// Parenthesis-nesting cap. The parser is recursive-descent, so depth
/// costs stack frames; uploaded specs are untrusted and a worker-thread
/// stack overflow aborts the whole process, not just the request.
const MAX_DEPTH: usize = 64;

/// Evaluate `text` against `params`. Errors are human-readable and name
/// the offending token.
pub fn eval(text: &str, params: &BTreeMap<String, u64>) -> Result<u64, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0, params };
    let v = p.expr()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing content at byte {} of {text:?}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
    params: &'a BTreeMap<String, u64>,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t')) {
            self.pos += 1;
        }
    }

    fn expr(&mut self) -> Result<u64, String> {
        let mut v = self.term()?;
        loop {
            self.skip_ws();
            match self.peek() {
                Some(b'+') => {
                    self.pos += 1;
                    let r = self.term()?;
                    v = v.checked_add(r).ok_or_else(|| "addition overflows u64".to_string())?;
                }
                Some(b'-') => {
                    self.pos += 1;
                    let r = self.term()?;
                    v = v
                        .checked_sub(r)
                        .ok_or_else(|| format!("{v} - {r} is negative (dims are unsigned)"))?;
                }
                _ => return Ok(v),
            }
        }
    }

    fn term(&mut self) -> Result<u64, String> {
        let mut v = self.factor()?;
        loop {
            self.skip_ws();
            match self.peek() {
                Some(b'*') => {
                    self.pos += 1;
                    let r = self.factor()?;
                    v = v
                        .checked_mul(r)
                        .ok_or_else(|| "multiplication overflows u64".to_string())?;
                }
                Some(b'/') => {
                    self.pos += 1;
                    let r = self.factor()?;
                    if r == 0 {
                        return Err("division by zero".to_string());
                    }
                    v /= r;
                }
                _ => return Ok(v),
            }
        }
    }

    fn factor(&mut self) -> Result<u64, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'(') => {
                self.depth += 1;
                if self.depth > MAX_DEPTH {
                    return Err(format!("expression nests deeper than {MAX_DEPTH} parentheses"));
                }
                self.pos += 1;
                let v = self.expr()?;
                self.skip_ws();
                if self.peek() != Some(b')') {
                    return Err(format!("expected ')' at byte {}", self.pos));
                }
                self.pos += 1;
                self.depth -= 1;
                Ok(v)
            }
            Some(c) if c.is_ascii_digit() => {
                let start = self.pos;
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .unwrap()
                    .parse::<u64>()
                    .map_err(|_| "integer literal overflows u64".to_string())
            }
            Some(c) if c.is_ascii_alphabetic() || c == b'_' => {
                let start = self.pos;
                while matches!(self.peek(), Some(c) if c.is_ascii_alphanumeric() || c == b'_') {
                    self.pos += 1;
                }
                let name = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
                self.params
                    .get(name)
                    .copied()
                    .ok_or_else(|| format!("unknown parameter {name:?}"))
            }
            _ => Err(format!("expected a number, parameter, or '(' at byte {}", self.pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(pairs: &[(&str, u64)]) -> BTreeMap<String, u64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn precedence_and_parens() {
        let p = params(&[("h", 8)]);
        assert_eq!(eval("2+3*4", &p), Ok(14));
        assert_eq!(eval("(2+3)*4", &p), Ok(20));
        assert_eq!(eval("4*h/2", &p), Ok(16));
        assert_eq!(eval(" h - 1 ", &p), Ok(7));
        assert_eq!(eval("h*h*h", &p), Ok(512));
    }

    #[test]
    fn identifiers_resolve() {
        let p = params(&[("batch", 4), ("seq", 512)]);
        assert_eq!(eval("batch*seq", &p), Ok(2048));
        assert!(eval("batch*missing", &p).unwrap_err().contains("missing"));
    }

    #[test]
    fn checked_arithmetic() {
        let p = params(&[]);
        assert!(eval("1-2", &p).unwrap_err().contains("negative"));
        assert!(eval("3/0", &p).unwrap_err().contains("zero"));
        assert!(eval("18446744073709551615*2", &p).unwrap_err().contains("overflow"));
    }

    #[test]
    fn rejects_garbage() {
        let p = params(&[("a", 1)]);
        assert!(eval("", &p).is_err());
        assert!(eval("a a", &p).is_err());
        assert!(eval("(a", &p).is_err());
        assert!(eval("a+", &p).is_err());
    }

    #[test]
    fn integer_division_truncates() {
        let p = params(&[("c", 7)]);
        assert_eq!(eval("c/2", &p), Ok(3));
    }

    #[test]
    fn deep_nesting_is_rejected_not_a_stack_overflow() {
        let p = params(&[]);
        let ok = format!("{}1{}", "(".repeat(60), ")".repeat(60));
        assert_eq!(eval(&ok, &p), Ok(1));
        let deep = format!("{}1{}", "(".repeat(100_000), ")".repeat(100_000));
        assert!(eval(&deep, &p).unwrap_err().contains("nests deeper"));
    }
}
