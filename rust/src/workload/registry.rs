//! The layered workload registry.
//!
//! Three sources feed one map-backed index (no linear scans on the
//! request path):
//!
//! 1. **Builtin** — specs embedded in the binary via `include_str!`
//!    ([`BUILTIN_SPECS`]); the Table-4 Rust constructors also count as
//!    builtin and always win lookups for their names.
//! 2. **User** — `*.json` files discovered from `--workload-dir` /
//!    `WHAM_WORKLOAD_DIR` ([`Registry::add_dir`]).
//! 3. **Uploaded** — specs POSTed to a running service's `/workloads`.
//!
//! Later sources take precedence on name collisions (uploaded > user >
//! builtin spec), except that Table-4 builtin names are reserved: a user
//! or uploaded spec may not shadow them, so a cached fingerprint for
//! `"bert-base"` always means the Table-4 BERT.

use std::collections::HashMap;
use std::path::Path;

use super::spec::{parse_spec, WorkloadSpec};
use super::SpecError;
use crate::models::transformer::TransformerCfg;

/// Shipped builtin specs, embedded at compile time. The first three
/// re-express Table-4 builtins (one vision, one GNMT-class, one
/// transformer LLM); `rust/tests/workload_spec.rs` pins their training
/// graphs fingerprint-equal to the Rust constructors, which is the
/// expressiveness proof for the spec language.
pub const BUILTIN_SPECS: &[(&str, &str)] = &[
    ("vgg16.json", include_str!("specs/vgg16.json")),
    ("gnmt4.json", include_str!("specs/gnmt4.json")),
    ("bert-base.json", include_str!("specs/bert-base.json")),
];

/// Which layer a registry entry came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Source {
    Builtin = 0,
    User = 1,
    Uploaded = 2,
}

impl Source {
    /// Wire label (`GET /models` `source` field).
    pub fn label(self) -> &'static str {
        match self {
            Source::Builtin => "builtin",
            Source::User => "user",
            Source::Uploaded => "uploaded",
        }
    }
}

/// One registered spec.
#[derive(Debug, Clone)]
pub struct RegisteredSpec {
    pub spec: WorkloadSpec,
    pub source: Source,
}

/// Registry row surfaced by `GET /models` / `wham workloads list`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecEntry {
    pub name: String,
    pub task: String,
    pub batch: u64,
    pub accelerators: u64,
    pub distributed_only: bool,
    pub source: Source,
}

/// The spec layers of the workload registry (the Rust builtins stay in
/// [`crate::models`]; [`crate::workload`]'s module-level helpers merge
/// the two views).
#[derive(Debug, Default)]
pub struct Registry {
    specs: HashMap<String, RegisteredSpec>,
}

impl Registry {
    /// Empty registry (no builtin specs) — for tests.
    pub fn empty() -> Self {
        Self::default()
    }

    /// Registry pre-loaded with the shipped builtin specs. Builtins are
    /// validated by unit tests and the CI `workloads lint` step, so a
    /// parse failure here is a packaging bug; the entry is skipped rather
    /// than poisoning every caller.
    pub fn with_builtins() -> Self {
        let mut r = Self::default();
        for (file, text) in BUILTIN_SPECS {
            match parse_spec(text) {
                Ok(spec) => {
                    r.specs.insert(
                        spec.name.clone(),
                        RegisteredSpec { spec, source: Source::Builtin },
                    );
                }
                Err(e) => debug_assert!(false, "embedded spec {file} failed to parse: {e}"),
            }
        }
        r
    }

    /// Number of registered specs (all layers).
    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// True when no specs are registered.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Look up a spec by name (map-backed, O(1)).
    pub fn get(&self, name: &str) -> Option<&RegisteredSpec> {
        self.specs.get(name)
    }

    /// Register a validated spec. The caller is expected to have run
    /// [`crate::workload::lint`]-level validation first (the module-level
    /// `add_*` helpers do). Collisions: Table-4 builtin names are
    /// rejected for non-builtin sources; an existing entry from a
    /// higher-precedence source is kept (returns `Ok` without
    /// replacing); same-or-lower precedence is replaced.
    pub fn insert(&mut self, spec: WorkloadSpec, source: Source) -> Result<(), SpecError> {
        if source != Source::Builtin && crate::models::info(&spec.name).is_some() {
            return Err(SpecError {
                path: format!("workload {:?}", spec.name),
                message: "this name is reserved by a builtin Table-4 model".to_string(),
            });
        }
        // The registry never evicts, and `/workloads` is unauthenticated:
        // cap how many distinct uploaded names a process retains
        // (re-uploading an existing name still replaces it).
        const MAX_UPLOADED: usize = 1024;
        if source == Source::Uploaded
            && !self.specs.contains_key(&spec.name)
            && self.specs.values().filter(|r| r.source == Source::Uploaded).count()
                >= MAX_UPLOADED
        {
            return Err(SpecError {
                path: format!("workload {:?}", spec.name),
                message: format!(
                    "uploaded-workload capacity reached ({MAX_UPLOADED} specs); restart the \
                     service or reuse an existing name"
                ),
            });
        }
        match self.specs.get(&spec.name) {
            Some(existing) if existing.source > source => Ok(()),
            _ => {
                self.specs.insert(spec.name.clone(), RegisteredSpec { spec, source });
                Ok(())
            }
        }
    }

    /// Load every `*.json` spec in `dir` (sorted by file name) as
    /// [`Source::User`] entries. Returns the registered names; the first
    /// unreadable or invalid file aborts with its path in the error.
    pub fn add_dir(&mut self, dir: &Path) -> Result<Vec<String>, SpecError> {
        let fail = |m: String| SpecError { path: dir.display().to_string(), message: m };
        let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| fail(format!("cannot read workload dir: {e}")))?
            .filter_map(|entry| entry.ok().map(|e| e.path()))
            .filter(|p| p.extension() == Some(std::ffi::OsStr::new("json")))
            .collect();
        files.sort();
        let mut names = Vec::with_capacity(files.len());
        for path in files {
            let text = std::fs::read_to_string(&path).map_err(|e| SpecError {
                path: path.display().to_string(),
                message: format!("cannot read spec file: {e}"),
            })?;
            let tag = |e: SpecError| SpecError {
                path: format!("{}: {}", path.display(), e.path),
                message: e.message,
            };
            let spec = parse_spec(&text).map_err(tag)?;
            let report = super::lint_spec(&spec).map_err(tag)?;
            self.insert(spec, Source::User).map_err(tag)?;
            names.push(report.name);
        }
        Ok(names)
    }

    /// All spec entries whose names are not shadowed by a Rust builtin,
    /// sorted by name.
    pub fn entries(&self) -> Vec<SpecEntry> {
        let mut out: Vec<SpecEntry> = self
            .specs
            .values()
            .filter(|r| crate::models::info(&r.spec.name).is_none())
            .map(|r| SpecEntry {
                name: r.spec.name.clone(),
                task: r.spec.task.clone(),
                batch: r.spec.batch,
                accelerators: r.spec.accelerators,
                distributed_only: r.spec.distributed_only,
                source: r.source,
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Transformer hyper-parameters of a registered spec, if its
    /// `transformer` section opts it into the distributed paths.
    pub fn transformer_cfg(&self, name: &str) -> Option<TransformerCfg> {
        let r = self.specs.get(name)?;
        let t = r.spec.transformer.as_ref()?;
        Some(TransformerCfg {
            layers: t.layers,
            hidden: t.hidden,
            heads: t.heads,
            seq: t.seq,
            batch: r.spec.batch,
            vocab: t.vocab,
            ffn_mult: t.ffn_mult,
            tmp: 1,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(name: &str) -> WorkloadSpec {
        parse_spec(&format!(
            "{{\"name\":{:?},\"batch\":2,\"graph\":[{{\"op\":\"linear\",\"m\":4,\"n\":4,\"k\":4}}]}}",
            name
        ))
        .unwrap()
    }

    #[test]
    fn builtin_specs_all_parse_and_load() {
        let r = Registry::with_builtins();
        assert_eq!(r.len(), BUILTIN_SPECS.len());
        for name in ["vgg16", "gnmt4", "bert-base"] {
            let e = r.get(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(e.source, Source::Builtin);
            assert_eq!(e.spec.batch, crate::models::info(name).unwrap().batch);
        }
    }

    #[test]
    fn reserved_builtin_names_reject_user_specs() {
        let mut r = Registry::empty();
        let e = r.insert(tiny("bert-base"), Source::User).unwrap_err();
        assert!(e.message.contains("reserved"), "{e}");
        assert!(r.insert(tiny("my-model"), Source::User).is_ok());
    }

    #[test]
    fn precedence_uploaded_over_user_over_builtin() {
        let mut r = Registry::empty();
        let mut a = tiny("m");
        a.task = "builtin-spec".into();
        // Builtin-source inserts are allowed any name.
        r.insert(a, Source::Builtin).unwrap();
        let mut b = tiny("m");
        b.task = "user".into();
        r.insert(b, Source::User).unwrap();
        assert_eq!(r.get("m").unwrap().spec.task, "user");
        let mut c = tiny("m");
        c.task = "uploaded".into();
        r.insert(c, Source::Uploaded).unwrap();
        assert_eq!(r.get("m").unwrap().spec.task, "uploaded");
        // A later user-layer load does not clobber the upload.
        let mut d = tiny("m");
        d.task = "user2".into();
        r.insert(d, Source::User).unwrap();
        assert_eq!(r.get("m").unwrap().spec.task, "uploaded");
    }

    #[test]
    fn entries_hide_shadowed_builtins_and_sort() {
        let mut r = Registry::with_builtins();
        r.insert(tiny("zeta"), Source::User).unwrap();
        r.insert(tiny("alpha"), Source::Uploaded).unwrap();
        let names: Vec<String> = r.entries().iter().map(|e| e.name.clone()).collect();
        // vgg16/gnmt4/bert-base are shadowed by the Rust builtins.
        assert_eq!(names, vec!["alpha".to_string(), "zeta".to_string()]);
    }

    #[test]
    fn uploaded_layer_has_a_capacity_cap() {
        let mut r = Registry::empty();
        for i in 0..1024 {
            r.insert(tiny(&format!("u{i}")), Source::Uploaded).unwrap();
        }
        let e = r.insert(tiny("one-too-many"), Source::Uploaded).unwrap_err();
        assert!(e.message.contains("capacity"), "{e}");
        // Replacing an existing name is still allowed at capacity.
        assert!(r.insert(tiny("u7"), Source::Uploaded).is_ok());
        // And the user layer (operator-controlled) is not capped.
        assert!(r.insert(tiny("from-disk"), Source::User).is_ok());
    }

    #[test]
    fn transformer_cfg_needs_the_section() {
        let mut r = Registry::empty();
        r.insert(tiny("plain"), Source::User).unwrap();
        assert!(r.transformer_cfg("plain").is_none());
        let spec = parse_spec(
            r#"{"name":"llm","batch":8,
                "transformer":{"layers":4,"hidden":64,"heads":4,"seq":32,"vocab":100},
                "graph":[{"op":"linear","m":4,"n":4,"k":4}]}"#,
        )
        .unwrap();
        r.insert(spec, Source::User).unwrap();
        let cfg = r.transformer_cfg("llm").unwrap();
        assert_eq!(cfg.layers, 4);
        assert_eq!(cfg.batch, 8);
        assert_eq!(cfg.ffn_mult, 4, "ffn_mult defaults to 4");
        assert_eq!(cfg.tmp, 1);
    }
}
