//! `wham::workload` — declarative workload specs, shape inference, and
//! the layered registry.
//!
//! The Table-4 zoo ([`crate::models`]) is code: adding a workload means a
//! Rust edit plus a recompile. This subsystem makes workloads *data*: a
//! JSON spec ([`spec`]) names hyper-parameters and a dataflow program
//! over a small set of layer kinds; a shape-inference + lowering pass
//! ([`lower`]) turns it into the exact [`crate::graph::OperatorGraph`]
//! form the builtins produce (same builder, same fusion, same autodiff
//! mirror — the shipped specs fingerprint-identical to their Rust
//! constructors); and a layered registry ([`registry`]) resolves names
//! from embedded builtin specs, a user directory (`--workload-dir` /
//! `WHAM_WORKLOAD_DIR`), and service uploads (`POST /workloads`).
//!
//! Every front door goes through
//! [`crate::api::plan::resolve_workload`], which consults this module
//! after the builtin fast path — so the CLI, the HTTP service, the
//! fingerprint-keyed design database, and `wham global` all accept any
//! registered workload by name with zero recompilation.
//!
//! The registry is process-global (like `models::MODELS`): one
//! `RwLock`ed instance shared by every session and service worker.

pub mod expr;
pub mod lower;
pub mod registry;
pub mod spec;
pub mod testgen;

use std::path::Path;
use std::sync::{OnceLock, RwLock};

use crate::graph::{fingerprint, Fingerprint, OperatorGraph};
use crate::models::transformer::TransformerCfg;

pub use registry::{RegisteredSpec, Registry, Source, SpecEntry, BUILTIN_SPECS};
pub use spec::{parse_spec, WorkloadSpec};

/// A spec-level diagnostic: the path of the offending item
/// (`graph/enc[2]/q`) plus a message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecError {
    pub path: String,
    pub message: String,
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.path, self.message)
    }
}

impl std::error::Error for SpecError {}

/// What `lint` learned about a valid spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LintReport {
    pub name: String,
    pub batch: u64,
    pub forward_ops: usize,
    pub forward_edges: usize,
    pub training_ops: usize,
    /// Fingerprint of the full training graph (the design-database key).
    pub fingerprint: Fingerprint,
}

/// Validate spec text without registering it: parse, lower, expand to
/// the training graph, and run the graph validator. This is what
/// `wham workloads lint` and the upload endpoint run.
pub fn lint(text: &str) -> Result<LintReport, SpecError> {
    lint_spec(&spec::parse_spec(text)?)
}

/// [`lint`] over an already-parsed spec — one parse, one lowering.
pub fn lint_spec(spec: &WorkloadSpec) -> Result<LintReport, SpecError> {
    let fwd = lower::lower(spec)?;
    let training = lower::training_of(&spec.name, &fwd)?;
    Ok(LintReport {
        name: spec.name.clone(),
        batch: spec.batch,
        forward_ops: fwd.len(),
        forward_edges: fwd.num_edges(),
        training_ops: training.len(),
        fingerprint: fingerprint(&training),
    })
}

static REGISTRY: OnceLock<RwLock<Registry>> = OnceLock::new();

/// The process-global registry (builtin specs pre-loaded).
pub fn global_registry() -> &'static RwLock<Registry> {
    REGISTRY.get_or_init(|| RwLock::new(Registry::with_builtins()))
}

/// Validate and register spec text under `source`. Returns the lint
/// report of the registered spec.
pub fn add_spec_text(text: &str, source: Source) -> Result<LintReport, SpecError> {
    let spec = spec::parse_spec(text)?;
    let report = lint_spec(&spec)?;
    global_registry().write().unwrap().insert(spec, source)?;
    Ok(report)
}

/// Load every `*.json` spec in `dir` into the user layer. Returns the
/// registered names.
pub fn add_dir(dir: impl AsRef<Path>) -> Result<Vec<String>, SpecError> {
    global_registry().write().unwrap().add_dir(dir.as_ref())
}

/// Load `WHAM_WORKLOAD_DIR` (if set and non-empty) into the user layer.
pub fn load_env_dir() -> Result<Vec<String>, SpecError> {
    match std::env::var("WHAM_WORKLOAD_DIR") {
        Ok(dir) if !dir.trim().is_empty() => add_dir(dir.trim()),
        _ => Ok(Vec::new()),
    }
}

/// Resolve a registered spec to its training graph + batch. `None` when
/// the name is not in the spec layers (the builtin Rust constructors are
/// checked by [`crate::api::plan::resolve_workload`], not here).
pub fn resolve(name: &str) -> Option<Result<(OperatorGraph, u64), SpecError>> {
    // Clone the spec out so lowering (which can be long for deep
    // models) never holds the registry lock against uploads.
    let r = global_registry().read().unwrap().get(name).cloned()?;
    Some(lower::training(&r.spec).map(|g| (g, r.spec.batch)))
}

/// Forward graph of a registered spec (for `wham models` param counts
/// and `wham workloads show`).
pub fn resolve_forward(name: &str) -> Option<Result<OperatorGraph, SpecError>> {
    let r = global_registry().read().unwrap().get(name).cloned()?;
    Some(lower::lower(&r.spec))
}

/// The registered spec (cloned) — `wham workloads show`.
pub fn get_spec(name: &str) -> Option<RegisteredSpec> {
    global_registry().read().unwrap().get(name).cloned()
}

/// Spec-layer entries not shadowed by a Rust builtin, sorted by name.
pub fn spec_entries() -> Vec<SpecEntry> {
    global_registry().read().unwrap().entries()
}

/// Every resolvable workload: the Table-4 builtins (in zoo order)
/// followed by the spec-layer entries (sorted by name). The single
/// registry view behind `GET /models`, `wham models`, and
/// `wham workloads list`.
pub fn all_entries() -> Vec<SpecEntry> {
    let mut out: Vec<SpecEntry> = crate::models::MODELS
        .iter()
        .map(|m| SpecEntry {
            name: m.name.to_string(),
            task: m.task.to_string(),
            batch: m.batch,
            accelerators: m.accelerators,
            distributed_only: m.distributed_only,
            source: Source::Builtin,
        })
        .collect();
    out.extend(spec_entries());
    out
}

/// Transformer hyper-parameters for a workload name: the builtin LLMs
/// first, then any registered spec with a `transformer` section. This is
/// what makes `wham global` / `wham partition` accept spec workloads.
pub fn transformer_cfg(name: &str) -> Option<TransformerCfg> {
    if crate::models::info(name).is_some() {
        return crate::models::transformer_cfg(name);
    }
    global_registry().read().unwrap().transformer_cfg(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_reports_graph_shape() {
        let r = lint(
            r#"{"name":"lint-me","batch":2,"graph":[
                {"op":"embed","elems":64,"params":32},
                {"op":"linear","m":8,"n":8,"k":8}
            ]}"#,
        )
        .unwrap();
        assert_eq!(r.name, "lint-me");
        assert_eq!(r.forward_ops, 2);
        assert_eq!(r.forward_edges, 1);
        assert!(r.training_ops > r.forward_ops);
    }

    #[test]
    fn every_builtin_spec_lints_clean() {
        for (file, text) in BUILTIN_SPECS {
            let r = lint(text).unwrap_or_else(|e| panic!("{file}: {e}"));
            assert!(r.forward_ops > 10, "{file} suspiciously small");
        }
    }

    #[test]
    fn global_registry_round_trip() {
        let report = add_spec_text(
            r#"{"name":"mod-test-mlp","batch":2,"graph":[
                {"op":"linear","m":8,"n":8,"k":8},
                {"op":"activation","elems":64}
            ]}"#,
            Source::Uploaded,
        )
        .unwrap();
        let (g, batch) = resolve("mod-test-mlp").unwrap().unwrap();
        assert_eq!(batch, 2);
        assert_eq!(fingerprint(&g), report.fingerprint);
        assert!(resolve("never-registered").is_none());
        assert!(spec_entries().iter().any(|e| e.name == "mod-test-mlp"));
    }

    #[test]
    fn transformer_cfg_prefers_builtins() {
        let cfg = transformer_cfg("bert-base").unwrap();
        assert_eq!(cfg.hidden, 768);
        assert!(transformer_cfg("vgg16").is_none());
        assert!(transformer_cfg("not-registered").is_none());
    }
}
