//! Operator definitions and their mapping to the cost-model contract.
//!
//! Every operator executes on a tensor core, a vector core, or a fused
//! TC+VC unit (paper section 3). The cost model sees each op as a
//! `(kind, m, n, k)` row — see `python/compile/kernels/ref.py`, the
//! single source of truth for the row semantics.

/// bf16 operand width used throughout the memory model.
pub const DTYPE_BYTES: u64 = 2;

/// Which core a given operator occupies while executing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CoreType {
    /// 2-D systolic array (GEMM / convolution).
    Tensor,
    /// 1-D lane array (element-wise, reductions, normalizations).
    Vector,
    /// A computational unit holding both cores (fused GEMM+activation).
    Fused,
}

/// Which training pass an operator belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pass {
    Forward = 0,
    Backward = 1,
    Update = 2,
    Loss = 3,
}

/// One row of the cost-model input table (contract of ref.py).
/// `Eq`/`Hash` are derived so rows can be interned into cost classes
/// ([`crate::graph::CostClasses`]) — all fields are integers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CostRow {
    /// 0 = tensor, 1 = vector, 2 = fused (< 0 is padding, never emitted).
    pub kind: i32,
    pub m: u64,
    pub n: u64,
    pub k: u64,
}

/// Dense computation performed by one operator.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Matrix multiply `[m,k] x [k,n]`.
    Gemm { m: u64, n: u64, k: u64 },
    /// 2-D convolution, modeled as its implicit GEMM
    /// (`m = batch*oh*ow`, `n = out_c`, `k = in_c*kh*kw`).
    Conv2d { batch: u64, in_c: u64, out_c: u64, kh: u64, kw: u64, oh: u64, ow: u64 },
    /// Element-wise / pointwise op over `elems` values; `intensity` is
    /// cycles (and vector-lane ops) per element: add/mul = 1, BN scale
    /// ~2, sigmoid/tanh ~4.
    Elementwise { elems: u64, intensity: u64 },
    /// Row-wise softmax: max, sub/exp, sum, div (intensity 4).
    Softmax { rows: u64, cols: u64 },
    /// LayerNorm: mean, var, normalize, affine (intensity 6).
    LayerNorm { rows: u64, cols: u64 },
    /// Reduction over `elems` values (losses, pooling, all-reduce prep).
    Reduction { elems: u64, intensity: u64 },
    /// GEMM with an element-wise epilogue fused onto a TC+VC unit.
    FusedGemmAct { m: u64, n: u64, k: u64 },
}

impl OpKind {
    /// Core type this op occupies (paper: each operator executes on a
    /// single computation core; fused ops occupy a whole unit).
    pub fn core_type(&self) -> CoreType {
        match self {
            OpKind::Gemm { .. } | OpKind::Conv2d { .. } => CoreType::Tensor,
            OpKind::FusedGemmAct { .. } => CoreType::Fused,
            _ => CoreType::Vector,
        }
    }

    /// Map to the cost-model row (contract of ref.py).
    pub fn cost_row(&self) -> CostRow {
        match *self {
            OpKind::Gemm { m, n, k } => CostRow { kind: 0, m, n, k },
            OpKind::Conv2d { batch, in_c, out_c, kh, kw, oh, ow } => {
                CostRow { kind: 0, m: batch * oh * ow, n: out_c, k: in_c * kh * kw }
            }
            OpKind::Elementwise { elems, intensity } => {
                CostRow { kind: 1, m: elems, n: intensity, k: 1 }
            }
            OpKind::Softmax { rows, cols } => CostRow { kind: 1, m: rows * cols, n: 4, k: 1 },
            OpKind::LayerNorm { rows, cols } => CostRow { kind: 1, m: rows * cols, n: 6, k: 1 },
            OpKind::Reduction { elems, intensity } => {
                CostRow { kind: 1, m: elems, n: intensity, k: 1 }
            }
            OpKind::FusedGemmAct { m, n, k } => CostRow { kind: 2, m, n, k },
        }
    }

    /// FLOPs performed by this op (2 per MAC for tensor ops).
    pub fn flops(&self) -> f64 {
        let r = self.cost_row();
        match r.kind {
            0 | 2 => 2.0 * r.m as f64 * r.n as f64 * r.k as f64,
            _ => r.m as f64 * r.n as f64,
        }
    }

    /// Elements produced by this op (drives activation stashing).
    pub fn out_elems(&self) -> u64 {
        match *self {
            OpKind::Gemm { m, n, .. } | OpKind::FusedGemmAct { m, n, .. } => m * n,
            OpKind::Conv2d { batch, out_c, oh, ow, .. } => batch * out_c * oh * ow,
            OpKind::Elementwise { elems, .. } => elems,
            OpKind::Softmax { rows, cols } | OpKind::LayerNorm { rows, cols } => rows * cols,
            OpKind::Reduction { .. } => 1,
        }
    }
}

/// One operator instance in a graph.
#[derive(Debug, Clone)]
pub struct Op {
    /// Human-readable name (`enc3/qkv/q`, `conv2_1/dW`, ...).
    pub name: String,
    pub kind: OpKind,
    pub pass: Pass,
    /// Weight elements owned by this op (forward ops only; drives the
    /// memory-balanced pipeline partitioner and update-op sizing).
    pub param_elems: u64,
    /// Activation elements produced (stashed fwd -> bwd).
    pub out_elems: u64,
    /// For backward ops: the forward node they mirror.
    pub fwd_peer: Option<super::NodeId>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_maps_to_implicit_gemm() {
        let c = OpKind::Conv2d { batch: 4, in_c: 64, out_c: 128, kh: 3, kw: 3, oh: 56, ow: 56 };
        let r = c.cost_row();
        assert_eq!(r.kind, 0);
        assert_eq!(r.m, 4 * 56 * 56);
        assert_eq!(r.n, 128);
        assert_eq!(r.k, 64 * 9);
        assert_eq!(c.out_elems(), 4 * 128 * 56 * 56);
    }

    #[test]
    fn softmax_is_vector_with_intensity_4() {
        let s = OpKind::Softmax { rows: 96, cols: 128 };
        assert_eq!(s.core_type(), CoreType::Vector);
        let r = s.cost_row();
        assert_eq!((r.kind, r.m, r.n), (1, 96 * 128, 4));
    }

    #[test]
    fn fused_occupies_unit() {
        let f = OpKind::FusedGemmAct { m: 8, n: 8, k: 8 };
        assert_eq!(f.core_type(), CoreType::Fused);
        assert_eq!(f.cost_row().kind, 2);
    }

    #[test]
    fn flops_gemm() {
        let g = OpKind::Gemm { m: 10, n: 20, k: 30 };
        assert_eq!(g.flops(), 2.0 * 10.0 * 20.0 * 30.0);
    }
}
