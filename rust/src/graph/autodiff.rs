//! Mirror the forward pass into the full training graph.
//!
//! Paper section 4.3: "auto-grad in training mirrors the forward pass
//! dataflow to the backward pass, where the backward operators correspond
//! to partial derivatives of forward operators". This module implements
//! that mirror:
//!
//! * a loss node follows the forward sinks;
//! * every forward op gets backward peer op(s) in reverse dataflow order —
//!   a GEMM/conv expands into **two** GEMMs (`dX = dY*W^T`, `dW = X^T*dY`),
//!   vector ops mirror one-for-one;
//! * every parameter-owning op gets an optimizer update op fed by its
//!   weight-gradient node.
//!
//! The backward subgraph's edges are the forward edges reversed, which is
//! exactly the structure MCR exploits (resolving a conflict early in the
//! forward pass tends to resolve its mirror in the backward pass).

use super::op::{Op, OpKind, Pass};
use super::{NodeId, OperatorGraph};

/// Optimizer choice; sets the per-parameter update intensity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    /// w -= lr * (g + mu*v): ~4 vector ops / param.
    SgdMomentum,
    /// Adam: 2 moments + bias correction: ~10 vector ops / param.
    Adam,
}

impl Optimizer {
    fn intensity(self) -> u64 {
        match self {
            Optimizer::SgdMomentum => 4,
            Optimizer::Adam => 10,
        }
    }
}

/// Expand a forward graph into the full training graph.
pub fn training_graph(fwd: &OperatorGraph, opt: Optimizer) -> OperatorGraph {
    let mut g = fwd.clone();
    for (v, op) in g.ops.iter().enumerate() {
        assert!(
            op.pass == Pass::Forward,
            "training_graph expects a forward-only graph (node {v} is {:?})",
            op.pass
        );
    }

    // ---- loss node -------------------------------------------------------
    // Owned copy: the cached sinks slice borrows `g`, which is mutated
    // below (every push invalidates and later rebuilds the analysis).
    let sinks: Vec<NodeId> = g.sinks().to_vec();
    let loss_elems: u64 = sinks.iter().map(|&s| g.ops[s].out_elems).sum::<u64>().max(1);
    let loss = push(&mut g, Op {
        name: "loss".into(),
        kind: OpKind::Reduction { elems: loss_elems, intensity: 2 },
        pass: Pass::Loss,
        param_elems: 0,
        out_elems: 1,
        fwd_peer: None,
    }, &sinks);

    // ---- backward mirror ---------------------------------------------------
    // For each forward node v we create grad-input node bx(v) (and for
    // parameterized tensor ops a grad-weight node bw(v)). bx(v) depends on
    // the bx of v's forward *successors* (reverse dataflow); forward sinks
    // hang off the loss node.
    let n_fwd = fwd.len();
    let mut bx = vec![usize::MAX; n_fwd];
    let mut order = fwd.topo_order();
    order.reverse();

    for &v in &order {
        let fop = g.ops[v].clone();
        let grad_preds: Vec<NodeId> = if fwd.succs(v).is_empty() {
            vec![loss]
        } else {
            fwd.succs(v).iter().map(|&s| bx[s as usize]).collect()
        };
        debug_assert!(grad_preds.iter().all(|&p| p != usize::MAX));

        let (bx_kind, bw_kind): (OpKind, Option<OpKind>) = match fop.kind {
            OpKind::Gemm { m, n, k } => (
                // dX[m,k] = dY[m,n] * W^T[n,k]
                OpKind::Gemm { m, n: k, k: n },
                // dW[k,n] = X^T[k,m] * dY[m,n] — only if weights exist.
                (fop.param_elems > 0).then_some(OpKind::Gemm { m: k, n, k: m }),
            ),
            OpKind::Conv2d { batch, in_c, out_c, kh, kw, oh, ow } => {
                let (m, n, k) = (batch * oh * ow, out_c, in_c * kh * kw);
                (OpKind::Gemm { m, n: k, k: n }, Some(OpKind::Gemm { m: k, n, k: m }))
            }
            OpKind::FusedGemmAct { m, n, k } => (
                // Activation grad folds into the fused unit.
                OpKind::FusedGemmAct { m, n: k, k: n },
                Some(OpKind::Gemm { m: k, n, k: m }),
            ),
            OpKind::Elementwise { elems, intensity } => {
                (OpKind::Elementwise { elems, intensity: intensity + 1 }, None)
            }
            OpKind::Softmax { rows, cols } => {
                // Softmax backward: dot product + scale per row.
                (OpKind::Elementwise { elems: rows * cols, intensity: 3 }, None)
            }
            OpKind::LayerNorm { rows, cols } => {
                (OpKind::Elementwise { elems: rows * cols, intensity: 8 }, None)
            }
            OpKind::Reduction { elems, intensity } => {
                (OpKind::Elementwise { elems, intensity }, None)
            }
        };

        let bxv = push(&mut g, Op {
            name: format!("{}/dX", fop.name),
            kind: bx_kind.clone(),
            pass: Pass::Backward,
            param_elems: 0,
            out_elems: bx_kind.out_elems(),
            fwd_peer: Some(v),
        }, &grad_preds);
        bx[v] = bxv;

        if let Some(bwk) = bw_kind {
            let bwv = push(&mut g, Op {
                name: format!("{}/dW", fop.name),
                kind: bwk.clone(),
                pass: Pass::Backward,
                param_elems: 0,
                out_elems: bwk.out_elems(),
                fwd_peer: Some(v),
            }, &grad_preds);
            // Optimizer update consumes dW.
            if fop.param_elems > 0 {
                push(&mut g, Op {
                    name: format!("{}/upd", fop.name),
                    kind: OpKind::Elementwise { elems: fop.param_elems, intensity: opt.intensity() },
                    pass: Pass::Update,
                    param_elems: 0,
                    out_elems: 0,
                    fwd_peer: Some(v),
                }, &[bwv]);
            }
        } else if fop.param_elems > 0 {
            // Vector op with params (batchnorm/layernorm affine): update
            // hangs off the op's own grad node.
            push(&mut g, Op {
                name: format!("{}/upd", fop.name),
                kind: OpKind::Elementwise { elems: fop.param_elems, intensity: opt.intensity() },
                pass: Pass::Update,
                param_elems: 0,
                out_elems: 0,
                fwd_peer: Some(v),
            }, &[bxv]);
        }
    }
    g
}

fn push(g: &mut OperatorGraph, op: Op, preds: &[NodeId]) -> NodeId {
    g.push_op(op, preds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn mlp() -> OperatorGraph {
        let mut b = GraphBuilder::new();
        let fc1 = b.gemm("fc1", 32, 128, 64, &[]);
        let act = b.eltwise("relu", 32 * 128, 1, &[fc1]);
        let _fc2 = b.gemm("fc2", 32, 10, 128, &[act]);
        b.finish()
    }

    #[test]
    fn mirrors_forward_into_backward() {
        let g = training_graph(&mlp(), Optimizer::SgdMomentum);
        let [fwd, bwd, upd, loss] = g.pass_counts();
        assert_eq!(fwd, 3);
        assert_eq!(loss, 1);
        // fc1: dX+dW, relu: dX, fc2: dX+dW = 5 backward ops.
        assert_eq!(bwd, 5);
        // Two parameterized gemms -> two update ops.
        assert_eq!(upd, 2);
    }

    #[test]
    fn gemm_backward_dims_are_transposed() {
        let g = training_graph(&mlp(), Optimizer::Adam);
        let dx = g.ops.iter().find(|o| o.name == "fc2/dX").unwrap();
        // fc2 fwd: m=32, n=10, k=128 -> dX: m=32, n=128, k=10.
        assert_eq!(dx.kind, OpKind::Gemm { m: 32, n: 128, k: 10 });
        let dw = g.ops.iter().find(|o| o.name == "fc2/dW").unwrap();
        assert_eq!(dw.kind, OpKind::Gemm { m: 128, n: 10, k: 32 });
    }

    #[test]
    fn result_is_acyclic_dag() {
        let g = training_graph(&mlp(), Optimizer::SgdMomentum);
        let order = g.topo_order(); // panics on cycle
        assert_eq!(order.len(), g.len());
    }

    #[test]
    fn backward_peers_point_at_forward() {
        let g = training_graph(&mlp(), Optimizer::SgdMomentum);
        for op in g.ops.iter().filter(|o| o.pass == Pass::Backward) {
            let peer = op.fwd_peer.expect("backward op must have a peer");
            assert_eq!(g.ops[peer].pass, Pass::Forward);
        }
    }

    #[test]
    fn loss_follows_sinks() {
        let g = training_graph(&mlp(), Optimizer::SgdMomentum);
        let loss = g.ops.iter().position(|o| o.pass == Pass::Loss).unwrap();
        assert_eq!(g.preds(loss).len(), 1); // single sink (fc2)
    }

    #[test]
    fn adam_updates_are_heavier_than_sgd() {
        let sgd = training_graph(&mlp(), Optimizer::SgdMomentum);
        let adam = training_graph(&mlp(), Optimizer::Adam);
        let upd_cycles = |g: &OperatorGraph| -> u64 {
            g.ops
                .iter()
                .filter(|o| o.pass == Pass::Update)
                .map(|o| match o.kind {
                    OpKind::Elementwise { elems, intensity } => elems * intensity,
                    _ => 0,
                })
                .sum()
        };
        assert!(upd_cycles(&adam) > upd_cycles(&sgd));
    }

    #[test]
    #[should_panic(expected = "forward-only")]
    fn rejects_already_expanded_graph() {
        let g = training_graph(&mlp(), Optimizer::SgdMomentum);
        training_graph(&g, Optimizer::SgdMomentum);
    }
}
