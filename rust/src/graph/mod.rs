//! Training operator-graph IR.
//!
//! A model is a DAG of dense operators. Model builders ([`crate::models`])
//! emit the **forward** pass; [`autodiff`] mirrors it into the full
//! training graph (forward + backward + parameter update + loss), the
//! structure WHAM's search optimizes over (paper section 2.1: backward
//! operators are partial derivatives of forward operators arranged in a
//! mirror dataflow, and must be co-located with their forward peers).

pub mod autodiff;
pub mod builder;
pub mod fingerprint;
pub mod fusion;
pub mod op;
pub mod validate;

pub use builder::GraphBuilder;
pub use fingerprint::{fingerprint, Fingerprint};
pub use op::{CoreType, CostRow, Op, OpKind, Pass};

/// Index of a node in an [`OperatorGraph`].
pub type NodeId = usize;

/// A DAG of training operators with adjacency in both directions.
#[derive(Debug, Clone, Default)]
pub struct OperatorGraph {
    pub ops: Vec<Op>,
    pub preds: Vec<Vec<NodeId>>,
    pub succs: Vec<Vec<NodeId>>,
}

impl OperatorGraph {
    /// Number of operators.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when the graph has no operators.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Nodes with no predecessors.
    pub fn sources(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&v| self.preds[v].is_empty()).collect()
    }

    /// Nodes with no successors.
    pub fn sinks(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&v| self.succs[v].is_empty()).collect()
    }

    /// Edge count.
    pub fn num_edges(&self) -> usize {
        self.succs.iter().map(Vec::len).sum()
    }

    /// Topological order (Kahn). Panics if the graph has a cycle — the
    /// builder can only create forward edges, so this is an invariant.
    pub fn topo_order(&self) -> Vec<NodeId> {
        let mut indeg: Vec<usize> = self.preds.iter().map(Vec::len).collect();
        let mut queue: std::collections::VecDeque<NodeId> =
            (0..self.len()).filter(|&v| indeg[v] == 0).collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for &s in &self.succs[v] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push_back(s);
                }
            }
        }
        assert_eq!(order.len(), self.len(), "operator graph has a cycle");
        order
    }

    /// Total parameter elements owned by forward operators.
    pub fn param_elems(&self) -> u64 {
        self.ops.iter().filter(|o| o.pass == Pass::Forward).map(|o| o.param_elems).sum()
    }

    /// Total training FLOPs (fwd+bwd+update) of the graph.
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.kind.flops()).sum()
    }

    /// Bytes of activations stashed for the backward pass per microbatch
    /// (paper section 2.1: every forward activation persists until its
    /// backward peer executes).
    pub fn activation_stash_bytes(&self) -> u64 {
        self.ops
            .iter()
            .filter(|o| o.pass == Pass::Forward)
            .map(|o| o.out_elems * op::DTYPE_BYTES)
            .sum()
    }

    /// Per-op rows in the cost-model contract order (kind, m, n, k).
    pub fn cost_rows(&self) -> Vec<CostRow> {
        self.ops.iter().map(|o| o.kind.cost_row()).collect()
    }

    /// Count operators per pass.
    pub fn pass_counts(&self) -> [usize; 4] {
        let mut c = [0usize; 4];
        for o in &self.ops {
            c[o.pass as usize] += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> OperatorGraph {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 8, 8, 8, &[]);
        let l = b.eltwise("l", 64, 1, &[a]);
        let r = b.eltwise("r", 64, 1, &[a]);
        let j = b.gemm("j", 8, 8, 8, &[l, r]);
        let _ = j;
        b.finish()
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = diamond();
        let order = g.topo_order();
        let pos: Vec<usize> = {
            let mut p = vec![0; g.len()];
            for (i, &v) in order.iter().enumerate() {
                p[v] = i;
            }
            p
        };
        for v in 0..g.len() {
            for &s in &g.succs[v] {
                assert!(pos[v] < pos[s]);
            }
        }
    }

    #[test]
    fn sources_and_sinks() {
        let g = diamond();
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![3]);
        assert_eq!(g.num_edges(), 4);
    }

    #[test]
    fn stash_counts_only_forward() {
        let mut g = diamond();
        g.ops[3].pass = Pass::Backward;
        let expect: u64 = g.ops[..3].iter().map(|o| o.out_elems * op::DTYPE_BYTES).sum();
        assert_eq!(g.activation_stash_bytes(), expect);
    }
}
