//! Training operator-graph IR.
//!
//! A model is a DAG of dense operators. Model builders ([`crate::models`])
//! emit the **forward** pass; [`autodiff`] mirrors it into the full
//! training graph (forward + backward + parameter update + loss), the
//! structure WHAM's search optimizes over (paper section 2.1: backward
//! operators are partial derivatives of forward operators arranged in a
//! mirror dataflow, and must be co-located with their forward peers).
//!
//! Storage is a struct-of-arrays arena: operators live in one flat
//! `Vec<Op>`, edges in one flat append-only `(src, dst)` list. The
//! adjacency the schedulers traverse is a **CSR view** (offsets + one
//! flat `u32` neighbor array per direction) built lazily with the rest of
//! the per-graph analysis and cached for the graph's lifetime — the
//! search walks the same edges thousands of times per candidate design,
//! and a flat array walk is both allocation-free and cache-friendly where
//! the old `Vec<Vec<NodeId>>` paid a pointer chase per node.

pub mod autodiff;
pub mod builder;
pub mod fingerprint;
pub mod fusion;
pub mod op;
pub mod validate;

pub use builder::GraphBuilder;
pub use fingerprint::{fingerprint, Fingerprint};
pub use op::{CoreType, CostRow, Op, OpKind, Pass};

/// Index of a node in an [`OperatorGraph`].
pub type NodeId = usize;

/// Cost-class interning table: training graphs are dozens of identical
/// transformer/conv layers, so the unique `(kind, shape)` classes are an
/// order of magnitude fewer than the operators. The estimator evaluates
/// the cost backend once per *class* and scatters the results by id
/// (see [`crate::cost::annotate::AnnotatedGraph::new`]), which shrinks
/// every backend call — and, for the batched PJRT backend, the number of
/// artifact dispatches — by the same factor.
#[derive(Debug, Clone, Default)]
pub struct CostClasses {
    /// One representative row per unique `(kind, m, n, k)` class, in
    /// first-appearance order (deterministic across runs).
    pub rows: Vec<CostRow>,
    /// Class id per operator — an index into `rows`.
    pub class_of: Vec<u32>,
}

impl CostClasses {
    fn build(ops: &[Op]) -> Self {
        let mut index: std::collections::HashMap<CostRow, u32> = std::collections::HashMap::new();
        let mut rows: Vec<CostRow> = Vec::new();
        let mut class_of = Vec::with_capacity(ops.len());
        for o in ops {
            let row = o.kind.cost_row();
            let id = *index.entry(row).or_insert_with(|| {
                rows.push(row);
                (rows.len() - 1) as u32
            });
            class_of.push(id);
        }
        Self { rows, class_of }
    }

    /// Number of unique classes.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the graph had no operators.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Compressed-sparse-row adjacency: per-node neighbor lists packed into
/// one flat `u32` array with an offsets table. Neighbor order within a
/// row reproduces edge-insertion order exactly (the builder's push
/// order), which the fingerprint, the cached topo order, and the
/// scheduler's release loop all depend on for determinism.
#[derive(Debug, Clone, Default)]
pub struct Csr {
    /// `off[v]..off[v+1]` indexes `adj` — length `n + 1`.
    off: Vec<u32>,
    /// Flat neighbor ids, grouped by node.
    adj: Vec<u32>,
}

impl Csr {
    /// Stable counting-sort construction: group `edges` by `key` (src or
    /// dst), preserving the global append order within each group.
    fn build(n: usize, edges: &[(u32, u32)], by_src: bool) -> Self {
        let mut off = vec![0u32; n + 1];
        for &(s, d) in edges {
            off[1 + if by_src { s } else { d } as usize] += 1;
        }
        for i in 0..n {
            off[i + 1] += off[i];
        }
        let mut cursor: Vec<u32> = off[..n].to_vec();
        let mut adj = vec![0u32; edges.len()];
        for &(s, d) in edges {
            let (k, v) = if by_src { (s, d) } else { (d, s) };
            let c = &mut cursor[k as usize];
            adj[*c as usize] = v;
            *c += 1;
        }
        Self { off, adj }
    }

    /// Neighbors of `v` in insertion order.
    #[inline]
    pub fn row(&self, v: usize) -> &[u32] {
        &self.adj[self.off[v] as usize..self.off[v + 1] as usize]
    }

    /// Number of neighbors of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> u32 {
        self.off[v + 1] - self.off[v]
    }
}

/// Per-graph derived state built once and shared by every evaluation
/// (the search annotates the same graph at dozens of `<TC-Dim,
/// VC-Width>` candidates — none of this depends on the dims).
#[derive(Debug, Clone, Default)]
struct GraphAnalysis {
    classes: CostClasses,
    preds: Csr,
    succs: Csr,
    /// Predecessor count per node — the scheduler's in-degree reset is a
    /// straight memcpy of this.
    indeg: Vec<u32>,
    sources: Vec<NodeId>,
    sinks: Vec<NodeId>,
    topo: Vec<NodeId>,
    /// Position of each node in `topo` (meaningless when `cyclic`).
    topo_pos: Vec<u32>,
    /// Kahn did not consume every node. The analysis stays usable for
    /// adjacency queries (validation reports the cycle as an error);
    /// only the topo-order accessors panic.
    cyclic: bool,
}

impl GraphAnalysis {
    fn build(ops: &[Op], edges: &[(u32, u32)]) -> Self {
        let n = ops.len();
        let succs = Csr::build(n, edges, true);
        let preds = Csr::build(n, edges, false);
        let indeg: Vec<u32> = (0..n).map(|v| preds.degree(v)).collect();
        let sources: Vec<NodeId> = (0..n).filter(|&v| preds.degree(v) == 0).collect();
        let sinks: Vec<NodeId> = (0..n).filter(|&v| succs.degree(v) == 0).collect();

        // Kahn over the CSR; identical visit order to the historical
        // Vec<Vec> walk (sources ascending, successors in insertion
        // order), so downstream tie-breaks are unchanged.
        let mut deg = indeg.clone();
        let mut queue: std::collections::VecDeque<NodeId> = sources.iter().copied().collect();
        let mut topo = Vec::with_capacity(n);
        while let Some(v) = queue.pop_front() {
            topo.push(v);
            for &s in succs.row(v) {
                let s = s as usize;
                deg[s] -= 1;
                if deg[s] == 0 {
                    queue.push_back(s);
                }
            }
        }
        let cyclic = topo.len() != n;
        let mut topo_pos = vec![0u32; n];
        for (i, &v) in topo.iter().enumerate() {
            topo_pos[v] = i as u32;
        }
        Self {
            classes: CostClasses::build(ops),
            preds,
            succs,
            indeg,
            sources,
            sinks,
            topo,
            topo_pos,
            cyclic,
        }
    }
}

/// A DAG of training operators. Adjacency is held as a flat edge list;
/// all traversal goes through the cached CSR views ([`Self::preds`],
/// [`Self::succs`], [`Self::preds_csr`], [`Self::succs_csr`]).
#[derive(Debug, Default)]
pub struct OperatorGraph {
    pub ops: Vec<Op>,
    /// Append-only `(src, dst)` edge list in insertion order — the single
    /// source of truth both CSR directions are derived from (they cannot
    /// go asymmetric by construction).
    edges: Vec<(u32, u32)>,
    /// Lazily-built cost-class table + topo order + CSR adjacency.
    /// First read freezes the cache; the mutators ([`Self::push_op`],
    /// [`Self::add_edge`]) invalidate it, so construction and analysis
    /// may interleave (autodiff reads the sinks of a clone before
    /// appending the backward mirror).
    analysis: std::sync::OnceLock<GraphAnalysis>,
}

/// Cloning an [`OperatorGraph`] copies the operators and edges but
/// **deliberately drops the frozen analysis cache** (cost classes, topo
/// order, CSR adjacency). Graphs are cloned precisely to be mutated —
/// autodiff appends the backward mirror onto a forward clone — and a
/// frozen class table or topo order must not survive onto a different
/// node set. The clone rebuilds an *identical* analysis on first use if
/// left unmutated (interning is deterministic in op order; pinned by
/// `clone_rebuilds_identical_class_ids` below), so the only cost of the
/// drop is one re-derivation — never a behavior change.
impl Clone for OperatorGraph {
    fn clone(&self) -> Self {
        Self {
            ops: self.ops.clone(),
            edges: self.edges.clone(),
            analysis: std::sync::OnceLock::new(),
        }
    }
}

impl OperatorGraph {
    /// Number of operators.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when the graph has no operators.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Append an operator with edges from `preds` (which must already
    /// exist — the graph stays a DAG by construction). Invalidates the
    /// frozen analysis.
    pub fn push_op(&mut self, op: Op, preds: &[NodeId]) -> NodeId {
        let id = self.ops.len();
        assert!(id < u32::MAX as usize, "operator count exceeds the u32 arena");
        self.analysis.take();
        self.ops.push(op);
        for &p in preds {
            assert!(p < id, "edges must point forward (pred {p} >= node {id})");
            self.edges.push((p as u32, id as u32));
        }
        id
    }

    /// Append one `from -> to` edge between existing nodes. Both CSR
    /// directions update together (the edge list is the single source of
    /// truth). Invalidates the frozen analysis. Back-edges are accepted
    /// here — [`validate::validate`] and the topo accessors detect the
    /// resulting cycle.
    pub fn add_edge(&mut self, from: NodeId, to: NodeId) {
        let n = self.ops.len();
        assert!(from < n && to < n, "edge ({from}, {to}) out of range (n = {n})");
        self.analysis.take();
        self.edges.push((from as u32, to as u32));
    }

    /// Predecessors of `v` in edge-insertion order.
    #[inline]
    pub fn preds(&self, v: NodeId) -> &[u32] {
        self.analysis().preds.row(v)
    }

    /// Successors of `v` in edge-insertion order.
    #[inline]
    pub fn succs(&self, v: NodeId) -> &[u32] {
        self.analysis().succs.row(v)
    }

    /// The full predecessor CSR — the scheduler-hot-loop form (one bounds
    /// check amortized over the whole traversal).
    pub fn preds_csr(&self) -> &Csr {
        &self.analysis().preds
    }

    /// The full successor CSR.
    pub fn succs_csr(&self) -> &Csr {
        &self.analysis().succs
    }

    /// Predecessor count per node (the scheduler's in-degree seed).
    pub fn indeg(&self) -> &[u32] {
        &self.analysis().indeg
    }

    /// Nodes with no predecessors — cached slice (callers needing to
    /// mutate the graph afterwards copy it out first).
    pub fn sources(&self) -> &[NodeId] {
        &self.analysis().sources
    }

    /// Nodes with no successors — cached slice.
    pub fn sinks(&self) -> &[NodeId] {
        &self.analysis().sinks
    }

    /// Edge count.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Topological order (Kahn), as an owned vector. Panics if the graph
    /// has a cycle — the builder can only create forward edges, so this
    /// is an invariant. Hot paths use [`Self::topo_order_cached`].
    pub fn topo_order(&self) -> Vec<NodeId> {
        self.topo_order_cached().to_vec()
    }

    /// Total parameter elements owned by forward operators.
    pub fn param_elems(&self) -> u64 {
        self.ops.iter().filter(|o| o.pass == Pass::Forward).map(|o| o.param_elems).sum()
    }

    /// Total training FLOPs (fwd+bwd+update) of the graph.
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.kind.flops()).sum()
    }

    /// Bytes of activations stashed for the backward pass per microbatch
    /// (paper section 2.1: every forward activation persists until its
    /// backward peer executes).
    pub fn activation_stash_bytes(&self) -> u64 {
        self.ops
            .iter()
            .filter(|o| o.pass == Pass::Forward)
            .map(|o| o.out_elems * op::DTYPE_BYTES)
            .sum()
    }

    /// Per-op rows in the cost-model contract order (kind, m, n, k).
    pub fn cost_rows(&self) -> Vec<CostRow> {
        self.ops.iter().map(|o| o.kind.cost_row()).collect()
    }

    fn analysis(&self) -> &GraphAnalysis {
        self.analysis.get_or_init(|| GraphAnalysis::build(&self.ops, &self.edges))
    }

    /// The graph's cost-class interning table, built on first use and
    /// cached for the graph's lifetime (thread-safe; concurrent sibling
    /// evaluations share one table).
    pub fn cost_classes(&self) -> &CostClasses {
        &self.analysis().classes
    }

    /// Cached topological order — the hot-path form of [`Self::topo_order`]
    /// for callers that re-traverse the same graph per candidate design
    /// (ASAP/ALAP, the exact solver). Panics on a cyclic graph.
    pub fn topo_order_cached(&self) -> &[NodeId] {
        let a = self.analysis();
        assert!(!a.cyclic, "operator graph has a cycle");
        &a.topo
    }

    /// Position of each node in the cached topo order — the worklist key
    /// for incremental critical-path repropagation. Panics on a cyclic
    /// graph.
    pub fn topo_positions(&self) -> &[u32] {
        let a = self.analysis();
        assert!(!a.cyclic, "operator graph has a cycle");
        &a.topo_pos
    }

    /// Count operators per pass.
    pub fn pass_counts(&self) -> [usize; 4] {
        let mut c = [0usize; 4];
        for o in &self.ops {
            c[o.pass as usize] += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> OperatorGraph {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 8, 8, 8, &[]);
        let l = b.eltwise("l", 64, 1, &[a]);
        let r = b.eltwise("r", 64, 1, &[a]);
        let j = b.gemm("j", 8, 8, 8, &[l, r]);
        let _ = j;
        b.finish()
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = diamond();
        let order = g.topo_order();
        let pos: Vec<usize> = {
            let mut p = vec![0; g.len()];
            for (i, &v) in order.iter().enumerate() {
                p[v] = i;
            }
            p
        };
        for v in 0..g.len() {
            for &s in g.succs(v) {
                assert!(pos[v] < pos[s as usize]);
            }
        }
        // The cached positions agree with the cached order.
        let tp = g.topo_positions();
        for (i, &v) in g.topo_order_cached().iter().enumerate() {
            assert_eq!(tp[v] as usize, i);
        }
    }

    #[test]
    fn sources_and_sinks() {
        let g = diamond();
        assert_eq!(g.sources(), &[0]);
        assert_eq!(g.sinks(), &[3]);
        assert_eq!(g.num_edges(), 4);
        // Cached slices are stable across calls (no per-call allocation).
        assert_eq!(g.sources().as_ptr(), g.sources().as_ptr());
    }

    #[test]
    fn csr_rows_match_builder_insertion_order() {
        let g = diamond();
        assert_eq!(g.preds(3), &[1, 2]); // join preds in push order
        assert_eq!(g.succs(0), &[1, 2]); // fanout in creation order
        assert_eq!(g.indeg(), &[0, 1, 1, 2]);
    }

    #[test]
    fn cost_classes_intern_repeated_shapes() {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 8, 8, 8, &[]);
        let c = b.gemm("c", 8, 8, 8, &[a]); // same (kind, shape) class as a
        let _d = b.eltwise("d", 64, 1, &[c]);
        let g = b.finish();
        let cls = g.cost_classes();
        assert_eq!(cls.len(), 2);
        assert_eq!(cls.class_of, vec![0, 0, 1]);
        // Scattering by class id reconstructs the naive table exactly.
        let scattered: Vec<CostRow> =
            cls.class_of.iter().map(|&i| cls.rows[i as usize]).collect();
        assert_eq!(scattered, g.cost_rows());
        // The cached topo order matches the allocating form.
        assert_eq!(g.topo_order_cached(), &g.topo_order()[..]);
    }

    #[test]
    fn clone_drops_the_analysis_cache() {
        // Regression: training_graph clones a forward graph and appends
        // nodes — a cloned-and-frozen class table / topo order would be
        // stale for the longer graph (out-of-bounds cycles at schedule
        // time).
        let g = diamond();
        assert_eq!(g.cost_classes().class_of.len(), g.len()); // freeze on the original
        let mut h = g.clone();
        h.push_op(
            Op {
                name: "extra".into(),
                kind: OpKind::Elementwise { elems: 4, intensity: 1 },
                pass: Pass::Forward,
                param_elems: 0,
                out_elems: 4,
                fwd_peer: None,
            },
            &[],
        );
        assert_eq!(h.cost_classes().class_of.len(), h.len());
        assert_eq!(h.topo_order_cached().len(), h.len());
    }

    #[test]
    fn clone_rebuilds_identical_class_ids() {
        // The Clone impl drops the analysis cache (see its doc); the
        // contract making that safe is that a clone left unmutated
        // rebuilds the *same* interning — same rows, same per-op class
        // ids — so annotations (and therefore schedules and design-DB
        // entries) of a clone are bit-identical to the original's.
        let g = diamond();
        let orig = g.cost_classes().clone();
        let h = g.clone();
        let rebuilt = h.cost_classes();
        assert_eq!(rebuilt.rows, orig.rows);
        assert_eq!(rebuilt.class_of, orig.class_of);
        assert_eq!(h.topo_order_cached(), g.topo_order_cached());
    }

    #[test]
    fn mutation_after_freeze_invalidates_analysis() {
        let mut g = diamond();
        let frozen_edges = g.num_edges();
        assert_eq!(g.succs(1), &[3]);
        // Mutate through the public mutator: the cache must rebuild.
        let extra = g.push_op(
            Op {
                name: "tail".into(),
                kind: OpKind::Elementwise { elems: 4, intensity: 1 },
                pass: Pass::Forward,
                param_elems: 0,
                out_elems: 4,
                fwd_peer: None,
            },
            &[3],
        );
        assert_eq!(g.num_edges(), frozen_edges + 1);
        assert_eq!(g.succs(3), &[extra as u32]);
        assert_eq!(g.sinks(), &[extra]);
        assert_eq!(g.topo_order_cached().len(), g.len());
    }

    #[test]
    fn stash_counts_only_forward() {
        let mut g = diamond();
        g.ops[3].pass = Pass::Backward;
        let expect: u64 = g.ops[..3].iter().map(|o| o.out_elems * op::DTYPE_BYTES).sum();
        assert_eq!(g.activation_stash_bytes(), expect);
    }
}
