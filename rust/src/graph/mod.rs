//! Training operator-graph IR.
//!
//! A model is a DAG of dense operators. Model builders ([`crate::models`])
//! emit the **forward** pass; [`autodiff`] mirrors it into the full
//! training graph (forward + backward + parameter update + loss), the
//! structure WHAM's search optimizes over (paper section 2.1: backward
//! operators are partial derivatives of forward operators arranged in a
//! mirror dataflow, and must be co-located with their forward peers).

pub mod autodiff;
pub mod builder;
pub mod fingerprint;
pub mod fusion;
pub mod op;
pub mod validate;

pub use builder::GraphBuilder;
pub use fingerprint::{fingerprint, Fingerprint};
pub use op::{CoreType, CostRow, Op, OpKind, Pass};

/// Index of a node in an [`OperatorGraph`].
pub type NodeId = usize;

/// Cost-class interning table: training graphs are dozens of identical
/// transformer/conv layers, so the unique `(kind, shape)` classes are an
/// order of magnitude fewer than the operators. The estimator evaluates
/// the cost backend once per *class* and scatters the results by id
/// (see [`crate::cost::annotate::AnnotatedGraph::new`]), which shrinks
/// every backend call — and, for the batched PJRT backend, the number of
/// artifact dispatches — by the same factor.
#[derive(Debug, Clone, Default)]
pub struct CostClasses {
    /// One representative row per unique `(kind, m, n, k)` class, in
    /// first-appearance order (deterministic across runs).
    pub rows: Vec<CostRow>,
    /// Class id per operator — an index into `rows`.
    pub class_of: Vec<u32>,
}

impl CostClasses {
    fn build(ops: &[Op]) -> Self {
        let mut index: std::collections::HashMap<CostRow, u32> = std::collections::HashMap::new();
        let mut rows: Vec<CostRow> = Vec::new();
        let mut class_of = Vec::with_capacity(ops.len());
        for o in ops {
            let row = o.kind.cost_row();
            let id = *index.entry(row).or_insert_with(|| {
                rows.push(row);
                (rows.len() - 1) as u32
            });
            class_of.push(id);
        }
        Self { rows, class_of }
    }

    /// Number of unique classes.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the graph had no operators.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Per-graph derived state built once and shared by every evaluation
/// (the search annotates the same graph at dozens of `<TC-Dim,
/// VC-Width>` candidates — none of this depends on the dims).
#[derive(Debug, Clone, Default)]
struct GraphAnalysis {
    classes: CostClasses,
    topo: Vec<NodeId>,
}

/// A DAG of training operators with adjacency in both directions.
#[derive(Debug, Default)]
pub struct OperatorGraph {
    pub ops: Vec<Op>,
    pub preds: Vec<Vec<NodeId>>,
    pub succs: Vec<Vec<NodeId>>,
    /// Lazily-built cost-class table + topo order. Graphs are immutable
    /// once handed to the estimator/schedulers, so first use freezes the
    /// cache; construction-time mutation (builder pushes, partition
    /// slicing) happens before anything reads it.
    analysis: std::sync::OnceLock<GraphAnalysis>,
}

impl Clone for OperatorGraph {
    fn clone(&self) -> Self {
        Self {
            ops: self.ops.clone(),
            preds: self.preds.clone(),
            succs: self.succs.clone(),
            // Deliberately NOT cloned: graphs are cloned precisely to be
            // mutated (autodiff appends the backward mirror onto a
            // forward clone), and a frozen class table / topo order must
            // not survive onto a different node set.
            analysis: std::sync::OnceLock::new(),
        }
    }
}

impl OperatorGraph {
    /// Number of operators.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when the graph has no operators.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Nodes with no predecessors.
    pub fn sources(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&v| self.preds[v].is_empty()).collect()
    }

    /// Nodes with no successors.
    pub fn sinks(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&v| self.succs[v].is_empty()).collect()
    }

    /// Edge count.
    pub fn num_edges(&self) -> usize {
        self.succs.iter().map(Vec::len).sum()
    }

    /// Topological order (Kahn). Panics if the graph has a cycle — the
    /// builder can only create forward edges, so this is an invariant.
    pub fn topo_order(&self) -> Vec<NodeId> {
        let mut indeg: Vec<usize> = self.preds.iter().map(Vec::len).collect();
        let mut queue: std::collections::VecDeque<NodeId> =
            (0..self.len()).filter(|&v| indeg[v] == 0).collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for &s in &self.succs[v] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push_back(s);
                }
            }
        }
        assert_eq!(order.len(), self.len(), "operator graph has a cycle");
        order
    }

    /// Total parameter elements owned by forward operators.
    pub fn param_elems(&self) -> u64 {
        self.ops.iter().filter(|o| o.pass == Pass::Forward).map(|o| o.param_elems).sum()
    }

    /// Total training FLOPs (fwd+bwd+update) of the graph.
    pub fn total_flops(&self) -> f64 {
        self.ops.iter().map(|o| o.kind.flops()).sum()
    }

    /// Bytes of activations stashed for the backward pass per microbatch
    /// (paper section 2.1: every forward activation persists until its
    /// backward peer executes).
    pub fn activation_stash_bytes(&self) -> u64 {
        self.ops
            .iter()
            .filter(|o| o.pass == Pass::Forward)
            .map(|o| o.out_elems * op::DTYPE_BYTES)
            .sum()
    }

    /// Per-op rows in the cost-model contract order (kind, m, n, k).
    pub fn cost_rows(&self) -> Vec<CostRow> {
        self.ops.iter().map(|o| o.kind.cost_row()).collect()
    }

    fn analysis(&self) -> &GraphAnalysis {
        self.analysis
            .get_or_init(|| GraphAnalysis { classes: CostClasses::build(&self.ops), topo: self.topo_order() })
    }

    /// The graph's cost-class interning table, built on first use and
    /// cached for the graph's lifetime (thread-safe; concurrent sibling
    /// evaluations share one table).
    pub fn cost_classes(&self) -> &CostClasses {
        &self.analysis().classes
    }

    /// Cached topological order — the hot-path form of [`Self::topo_order`]
    /// for callers that re-traverse the same graph per candidate design
    /// (ASAP/ALAP, the exact solver).
    pub fn topo_order_cached(&self) -> &[NodeId] {
        &self.analysis().topo
    }

    /// Count operators per pass.
    pub fn pass_counts(&self) -> [usize; 4] {
        let mut c = [0usize; 4];
        for o in &self.ops {
            c[o.pass as usize] += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> OperatorGraph {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 8, 8, 8, &[]);
        let l = b.eltwise("l", 64, 1, &[a]);
        let r = b.eltwise("r", 64, 1, &[a]);
        let j = b.gemm("j", 8, 8, 8, &[l, r]);
        let _ = j;
        b.finish()
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = diamond();
        let order = g.topo_order();
        let pos: Vec<usize> = {
            let mut p = vec![0; g.len()];
            for (i, &v) in order.iter().enumerate() {
                p[v] = i;
            }
            p
        };
        for v in 0..g.len() {
            for &s in &g.succs[v] {
                assert!(pos[v] < pos[s]);
            }
        }
    }

    #[test]
    fn sources_and_sinks() {
        let g = diamond();
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![3]);
        assert_eq!(g.num_edges(), 4);
    }

    #[test]
    fn cost_classes_intern_repeated_shapes() {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 8, 8, 8, &[]);
        let c = b.gemm("c", 8, 8, 8, &[a]); // same (kind, shape) class as a
        let _d = b.eltwise("d", 64, 1, &[c]);
        let g = b.finish();
        let cls = g.cost_classes();
        assert_eq!(cls.len(), 2);
        assert_eq!(cls.class_of, vec![0, 0, 1]);
        // Scattering by class id reconstructs the naive table exactly.
        let scattered: Vec<CostRow> =
            cls.class_of.iter().map(|&i| cls.rows[i as usize]).collect();
        assert_eq!(scattered, g.cost_rows());
        // The cached topo order matches the allocating form.
        assert_eq!(g.topo_order_cached(), &g.topo_order()[..]);
    }

    #[test]
    fn clone_drops_the_analysis_cache() {
        // Regression: training_graph clones a forward graph and appends
        // nodes — a cloned-and-frozen class table / topo order would be
        // stale for the longer graph (out-of-bounds cycles at schedule
        // time).
        let g = diamond();
        assert_eq!(g.cost_classes().class_of.len(), g.len()); // freeze on the original
        let mut h = g.clone();
        h.ops.push(Op {
            name: "extra".into(),
            kind: OpKind::Elementwise { elems: 4, intensity: 1 },
            pass: Pass::Forward,
            param_elems: 0,
            out_elems: 4,
            fwd_peer: None,
        });
        h.preds.push(Vec::new());
        h.succs.push(Vec::new());
        assert_eq!(h.cost_classes().class_of.len(), h.len());
        assert_eq!(h.topo_order_cached().len(), h.len());
    }

    #[test]
    fn stash_counts_only_forward() {
        let mut g = diamond();
        g.ops[3].pass = Pass::Backward;
        let expect: u64 = g.ops[..3].iter().map(|o| o.out_elems * op::DTYPE_BYTES).sum();
        assert_eq!(g.activation_stash_bytes(), expect);
    }
}
