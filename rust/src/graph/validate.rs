//! Structural validation of operator graphs — used by tests and asserted
//! by the search engine before committing to a workload.

use super::op::Pass;
use super::OperatorGraph;

/// Validation failure description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Invalid(pub String);

impl std::fmt::Display for Invalid {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "invalid graph: {}", self.0)
    }
}
impl std::error::Error for Invalid {}

/// Check DAG structure, peer links, and dims. (Adjacency symmetry is a
/// construction invariant now — both CSR directions derive from one edge
/// list — so there is no asymmetry left to detect.)
pub fn validate(g: &OperatorGraph) -> Result<(), Invalid> {
    let n = g.len();
    // Acyclic (Kahn must consume all nodes). Runs on the CSR directly
    // rather than the cached topo order: the cached accessor panics on a
    // cycle, and validation must report it as an error instead.
    let mut indeg: Vec<u32> = g.indeg().to_vec();
    let mut queue: Vec<usize> = (0..n).filter(|&v| indeg[v] == 0).collect();
    let mut seen = 0usize;
    while let Some(v) = queue.pop() {
        seen += 1;
        for &s in g.succs(v) {
            let s = s as usize;
            indeg[s] -= 1;
            if indeg[s] == 0 {
                queue.push(s);
            }
        }
    }
    if seen != n {
        return Err(Invalid("graph has a cycle".into()));
    }
    // Peers + dims.
    for (v, op) in g.ops.iter().enumerate() {
        match op.pass {
            Pass::Backward | Pass::Update => {
                if let Some(p) = op.fwd_peer {
                    if p >= n || g.ops[p].pass != Pass::Forward {
                        return Err(Invalid(format!("node {v} has bad fwd_peer")));
                    }
                }
            }
            _ => {}
        }
        let r = op.kind.cost_row();
        if r.m == 0 || r.n == 0 || r.k == 0 {
            return Err(Invalid(format!("node {v} ({}) has a zero dimension", op.name)));
        }
        if r.m > i32::MAX as u64 || r.n > i32::MAX as u64 || r.k > i32::MAX as u64 {
            return Err(Invalid(format!(
                "node {v} ({}) dims exceed the i32 cost-model contract",
                op.name
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::{training_graph, Optimizer};
    use crate::graph::GraphBuilder;

    #[test]
    fn valid_training_graph_passes() {
        let mut b = GraphBuilder::new();
        let x = b.gemm("x", 8, 8, 8, &[]);
        let _ = b.eltwise("r", 64, 1, &[x]);
        let g = training_graph(&b.finish(), Optimizer::Adam);
        validate(&g).unwrap();
    }

    #[test]
    fn detects_cycle() {
        let mut b = GraphBuilder::new();
        let x = b.gemm("x", 8, 8, 8, &[]);
        let y = b.eltwise("y", 64, 1, &[x]);
        let mut g = b.finish();
        // Force a back edge y -> x (updates both CSR directions).
        g.add_edge(y, x);
        assert!(validate(&g).unwrap_err().0.contains("cycle"));
    }

    #[test]
    fn detects_cycle_added_after_freeze() {
        // Mutators must invalidate the frozen analysis: freeze first,
        // then add the back edge, and validation must still see it.
        let mut b = GraphBuilder::new();
        let x = b.gemm("x", 8, 8, 8, &[]);
        let y = b.eltwise("y", 64, 1, &[x]);
        let mut g = b.finish();
        validate(&g).unwrap(); // freezes the analysis
        g.add_edge(y, x);
        assert!(validate(&g).unwrap_err().0.contains("cycle"));
    }

    #[test]
    fn detects_zero_dim() {
        let mut b = GraphBuilder::new();
        b.gemm("bad", 0, 8, 8, &[]);
        assert!(validate(&b.finish()).is_err());
    }
}
