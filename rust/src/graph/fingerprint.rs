//! Stable workload fingerprints.
//!
//! The design database ([`crate::service::cache`]) memoizes evaluated
//! design points *across* processes, so it needs a key that identifies a
//! training graph by **structure** — op kinds, shapes, passes, and edges
//! (the optimizer is visible through the update-op shapes autodiff
//! emits) — and not by the incidental order model builders inserted
//! nodes in. Two graphs that differ only by a permutation of node ids
//! must hash identically; any change to a shape, an edge, or an op kind
//! must (with overwhelming probability) change the hash.
//!
//! Implementation: Weisfeiler-Lehman iterative relabeling. Each node
//! starts from a hash of its intrinsic attributes; a few rounds fold in
//! the *sorted* multisets of predecessor and successor labels; the final
//! fingerprint combines the sorted multiset of node labels with the node
//! and edge counts. Sorting at every aggregation point is what buys
//! insertion-order invariance.

use super::{OpKind, OperatorGraph};
use crate::util::fnv::{Fnv, OFFSET};

/// A 64-bit structural hash of a training graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub u64);

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

impl Fingerprint {
    /// Parse the `Display` form (16 hex digits).
    pub fn parse(s: &str) -> Option<Self> {
        u64::from_str_radix(s, 16).ok().map(Fingerprint)
    }
}

/// Fold one `u64` into an FNV-1a state.
#[inline]
fn fold(h: u64, x: u64) -> u64 {
    Fnv(h).word(x).0
}

fn fold_all(seed: u64, xs: &[u64]) -> u64 {
    Fnv(seed).words(xs).0
}

/// Variant index of an [`OpKind`] (shape fields are hashed separately via
/// the cost row, which collapses e.g. Softmax and Reduction onto the same
/// row — the tag keeps them distinct).
fn kind_tag(k: &OpKind) -> u64 {
    match k {
        OpKind::Gemm { .. } => 0,
        OpKind::Conv2d { .. } => 1,
        OpKind::Elementwise { .. } => 2,
        OpKind::Softmax { .. } => 3,
        OpKind::LayerNorm { .. } => 4,
        OpKind::Reduction { .. } => 5,
        OpKind::FusedGemmAct { .. } => 6,
    }
}

/// Hash of one node's intrinsic attributes (no names, no ids).
fn node_seed(g: &OperatorGraph, v: usize) -> u64 {
    let o = &g.ops[v];
    let r = o.kind.cost_row();
    fold_all(
        OFFSET,
        &[
            kind_tag(&o.kind),
            r.kind as u64,
            r.m,
            r.n,
            r.k,
            o.pass as u64,
            o.param_elems,
            o.out_elems,
        ],
    )
}

/// Compute the structural fingerprint of a graph.
pub fn fingerprint(g: &OperatorGraph) -> Fingerprint {
    let n = g.len();
    if n == 0 {
        return Fingerprint(fold(OFFSET, 0));
    }
    let mut labels: Vec<u64> = (0..n).map(|v| node_seed(g, v)).collect();
    // Three rounds reach neighbors-of-neighbors-of-neighbors — plenty to
    // separate every stage/layer position in the mirrored training DAGs
    // this repo builds, while staying O(rounds * (V + E log E)).
    let mut scratch: Vec<u64> = Vec::new();
    for _ in 0..3 {
        let mut next = Vec::with_capacity(n);
        for v in 0..n {
            let mut h = fold(OFFSET, labels[v]);
            for (tag, nbrs) in [(0xA5u64, g.preds(v)), (0x5Au64, g.succs(v))] {
                scratch.clear();
                scratch.extend(nbrs.iter().map(|&u| labels[u as usize]));
                scratch.sort_unstable();
                h = fold(h, tag);
                h = fold(h, scratch.len() as u64);
                h = fold_all(h, &scratch);
            }
            next.push(h);
        }
        labels = next;
    }
    labels.sort_unstable();
    let mut h = fold(OFFSET, n as u64);
    h = fold(h, g.num_edges() as u64);
    h = fold_all(h, &labels);
    Fingerprint(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::autodiff::{training_graph, Optimizer};
    use crate::graph::GraphBuilder;

    /// Diamond graph, nodes inserted left branch first.
    fn diamond_lr() -> OperatorGraph {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 8, 8, 8, &[]);
        let l = b.eltwise("l", 64, 1, &[a]);
        let r = b.eltwise("r", 64, 2, &[a]);
        let _j = b.gemm("j", 8, 8, 8, &[l, r]);
        b.finish()
    }

    /// Same diamond, branches inserted in the opposite order (node ids
    /// and adjacency-list orders permute).
    fn diamond_rl() -> OperatorGraph {
        let mut b = GraphBuilder::new();
        let a = b.gemm("root", 8, 8, 8, &[]);
        let r = b.eltwise("right", 64, 2, &[a]);
        let l = b.eltwise("left", 64, 1, &[a]);
        let _j = b.gemm("join", 8, 8, 8, &[r, l]);
        b.finish()
    }

    #[test]
    fn same_structure_same_fingerprint() {
        assert_eq!(fingerprint(&diamond_lr()), fingerprint(&diamond_rl()));
    }

    #[test]
    fn permuted_insertion_order_same_fingerprint_on_real_model() {
        // Two independent builds of the same workload must agree.
        let a = crate::models::training("bert-base", Optimizer::Adam).unwrap();
        let b = crate::models::training("bert-base", Optimizer::Adam).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn changed_shape_changes_fingerprint() {
        let base = diamond_lr();
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 8, 8, 16, &[]); // k: 8 -> 16
        let l = b.eltwise("l", 64, 1, &[a]);
        let r = b.eltwise("r", 64, 2, &[a]);
        let _j = b.gemm("j", 8, 8, 8, &[l, r]);
        assert_ne!(fingerprint(&base), fingerprint(&b.finish()));
    }

    #[test]
    fn changed_edge_changes_fingerprint() {
        let base = diamond_lr();
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 8, 8, 8, &[]);
        let l = b.eltwise("l", 64, 1, &[a]);
        let _r = b.eltwise("r", 64, 2, &[a]);
        // join now depends only on the left branch.
        let _j = b.gemm("j", 8, 8, 8, &[l]);
        assert_ne!(fingerprint(&base), fingerprint(&b.finish()));
    }

    #[test]
    fn optimizer_changes_fingerprint() {
        let fwd = crate::models::transformer::forward_range(
            &crate::models::transformer::bert_base(),
            0,
            1,
        );
        let sgd = training_graph(&fwd, Optimizer::SgdMomentum);
        let adam = training_graph(&fwd, Optimizer::Adam);
        assert_ne!(fingerprint(&sgd), fingerprint(&adam));
    }

    #[test]
    fn names_do_not_matter() {
        let mut g = diamond_lr();
        for o in &mut g.ops {
            o.name = format!("renamed/{}", o.name);
        }
        assert_eq!(fingerprint(&g), fingerprint(&diamond_lr()));
    }

    #[test]
    fn display_parses_back() {
        let fp = fingerprint(&diamond_lr());
        assert_eq!(Fingerprint::parse(&fp.to_string()), Some(fp));
    }

    #[test]
    fn distinct_models_distinct_fingerprints() {
        let a = crate::models::training("bert-base", Optimizer::Adam).unwrap();
        let b = crate::models::training("resnet18", Optimizer::Adam).unwrap();
        assert_ne!(fingerprint(&a), fingerprint(&b));
    }
}
