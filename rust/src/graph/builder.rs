//! Forward-pass graph construction API used by the model zoo.

use super::op::{Op, OpKind, Pass, DTYPE_BYTES};
use super::{NodeId, OperatorGraph};

/// Builds forward operator graphs; edges always point from earlier to
/// later insertions, so the result is a DAG by construction.
#[derive(Debug, Default)]
pub struct GraphBuilder {
    graph: OperatorGraph,
}

impl GraphBuilder {
    /// Empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add an operator with explicit kind / pass / params.
    pub fn add(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        pass: Pass,
        param_elems: u64,
        preds: &[NodeId],
    ) -> NodeId {
        let out_elems = kind.out_elems();
        self.graph.push_op(
            Op { name: name.into(), kind, pass, param_elems, out_elems, fwd_peer: None },
            preds,
        )
    }

    /// Forward op shorthand.
    pub fn fwd(&mut self, name: impl Into<String>, kind: OpKind, params: u64, preds: &[NodeId]) -> NodeId {
        self.add(name, kind, Pass::Forward, params, preds)
    }

    /// GEMM `[m,k] x [k,n]` owning a `k x n` weight matrix.
    pub fn gemm(&mut self, name: impl Into<String>, m: u64, n: u64, k: u64, preds: &[NodeId]) -> NodeId {
        self.fwd(name, OpKind::Gemm { m, n, k }, k * n, preds)
    }

    /// GEMM over shared/activations only (no owned weights), e.g.
    /// attention score and context matmuls.
    pub fn gemm_act(&mut self, name: impl Into<String>, m: u64, n: u64, k: u64, preds: &[NodeId]) -> NodeId {
        self.fwd(name, OpKind::Gemm { m, n, k }, 0, preds)
    }

    /// 2-D convolution (square spatial output `oh x ow`).
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        &mut self,
        name: impl Into<String>,
        batch: u64,
        in_c: u64,
        out_c: u64,
        kh: u64,
        kw: u64,
        oh: u64,
        ow: u64,
        preds: &[NodeId],
    ) -> NodeId {
        self.fwd(
            name,
            OpKind::Conv2d { batch, in_c, out_c, kh, kw, oh, ow },
            in_c * out_c * kh * kw,
            preds,
        )
    }

    /// Element-wise op (ReLU, add, scale ...).
    pub fn eltwise(&mut self, name: impl Into<String>, elems: u64, intensity: u64, preds: &[NodeId]) -> NodeId {
        self.fwd(name, OpKind::Elementwise { elems, intensity }, 0, preds)
    }

    /// BatchNorm: per-element normalize+affine (intensity 2) with 2C params.
    pub fn batchnorm(&mut self, name: impl Into<String>, elems: u64, channels: u64, preds: &[NodeId]) -> NodeId {
        self.fwd(name, OpKind::Elementwise { elems, intensity: 2 }, 2 * channels, preds)
    }

    /// Row-wise softmax.
    pub fn softmax(&mut self, name: impl Into<String>, rows: u64, cols: u64, preds: &[NodeId]) -> NodeId {
        self.fwd(name, OpKind::Softmax { rows, cols }, 0, preds)
    }

    /// LayerNorm with 2*cols params.
    pub fn layernorm(&mut self, name: impl Into<String>, rows: u64, cols: u64, preds: &[NodeId]) -> NodeId {
        self.fwd(name, OpKind::LayerNorm { rows, cols }, 2 * cols, preds)
    }

    /// Reduction (pooling, loss prep).
    pub fn reduce(&mut self, name: impl Into<String>, elems: u64, intensity: u64, preds: &[NodeId]) -> NodeId {
        self.fwd(name, OpKind::Reduction { elems, intensity }, 0, preds)
    }

    /// Current node count.
    pub fn len(&self) -> usize {
        self.graph.len()
    }

    /// True when nothing has been added.
    pub fn is_empty(&self) -> bool {
        self.graph.is_empty()
    }

    /// Finish and return the graph.
    pub fn finish(self) -> OperatorGraph {
        self.graph
    }

    /// Estimated parameter bytes so far (bf16).
    pub fn param_bytes(&self) -> u64 {
        self.graph.param_elems() * DTYPE_BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_edges_both_directions() {
        let mut b = GraphBuilder::new();
        let x = b.gemm("x", 4, 4, 4, &[]);
        let y = b.eltwise("y", 16, 1, &[x]);
        let g = b.finish();
        assert_eq!(g.succs(x), &[y as u32]);
        assert_eq!(g.preds(y), &[x as u32]);
    }

    #[test]
    #[should_panic(expected = "edges must point forward")]
    fn rejects_self_edge() {
        let mut b = GraphBuilder::new();
        let x = b.gemm("x", 4, 4, 4, &[]);
        // A pred >= own id is a forward reference.
        b.eltwise("bad", 4, 1, &[x + 1]);
    }

    #[test]
    fn gemm_params_are_kxn() {
        let mut b = GraphBuilder::new();
        b.gemm("fc", 32, 1000, 4096, &[]);
        assert_eq!(b.param_bytes(), 1000 * 4096 * 2);
    }

    #[test]
    fn gemm_act_owns_no_params() {
        let mut b = GraphBuilder::new();
        b.gemm_act("scores", 512, 512, 64, &[]);
        assert_eq!(b.param_bytes(), 0);
    }
}
