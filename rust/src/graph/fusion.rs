//! Op-fusion compiler pass (paper section 6.2, "Compiler and runtime
//! optimizations"): a GEMM/convolution followed by a single element-wise
//! activation is fused into one `FusedGemmAct` op executing on a TC+VC
//! unit, eliminating the HBM round trip for the intermediate.

use super::op::{OpKind, Pass};
use super::OperatorGraph;

/// Fuse producer(GEMM/Conv) -> consumer(cheap element-wise) pairs where the
/// element-wise op has exactly that producer as its only predecessor and
/// the producer has exactly that consumer as its only successor. Returns
/// the rewritten graph and the number of fused pairs.
pub fn fuse(g: &OperatorGraph) -> (OperatorGraph, usize) {
    let n = g.len();
    let mut absorbed = vec![false; n]; // element-wise node folded away
    let mut fused_kind: Vec<Option<OpKind>> = vec![None; n];

    for v in 0..n {
        if g.ops[v].pass != Pass::Forward || g.succs(v).len() != 1 {
            continue;
        }
        let s = g.succs(v)[0] as usize;
        if g.preds(s).len() != 1 || g.ops[s].pass != Pass::Forward {
            continue;
        }
        // Only cheap activations fuse (intensity <= 4: relu/gelu/sigmoid).
        let act_ok = matches!(g.ops[s].kind, OpKind::Elementwise { intensity, .. } if intensity <= 4);
        if !act_ok {
            continue;
        }
        let row = match g.ops[v].kind {
            OpKind::Gemm { m, n, k } | OpKind::FusedGemmAct { m, n, k } => Some((m, n, k)),
            OpKind::Conv2d { .. } => {
                let r = g.ops[v].kind.cost_row();
                Some((r.m, r.n, r.k))
            }
            _ => None,
        };
        if let Some((m, nn, k)) = row {
            // The epilogue must cover exactly the producer's outputs.
            if g.ops[s].kind.out_elems() == m * nn && !absorbed[v] {
                fused_kind[v] = Some(OpKind::FusedGemmAct { m, n: nn, k });
                absorbed[s] = true;
            }
        }
    }

    // Rebuild without absorbed nodes; edges through an absorbed node are
    // re-routed to its producer.
    let mut new_id = vec![usize::MAX; n];
    let mut out = OperatorGraph::default();
    for v in 0..n {
        if absorbed[v] {
            continue;
        }
        let mut op = g.ops[v].clone();
        if let Some(kind) = fused_kind[v].take() {
            // Absorb the activation's name for readability.
            let s = g.succs(v)[0] as usize;
            op.name = format!("{}+{}", op.name, g.ops[s].name);
            op.out_elems = kind.out_elems();
            op.kind = kind;
        }
        new_id[v] = out.push_op(op, &[]);
    }
    let resolve = |mut v: usize| {
        while absorbed[v] {
            v = g.preds(v)[0] as usize;
        }
        new_id[v]
    };
    // Dedup per consumer: re-routing through an absorbed node can map two
    // old edges onto the same new edge. Each node's preds are emitted
    // consecutively, so a small per-node buffer replaces the old
    // scan-the-adjacency check.
    let mut seen_preds: Vec<usize> = Vec::new();
    for v in 0..n {
        if absorbed[v] {
            continue;
        }
        let nv = new_id[v];
        seen_preds.clear();
        for &p in g.preds(v) {
            let np = resolve(p as usize);
            if np != nv && !seen_preds.contains(&np) {
                seen_preds.push(np);
                out.add_edge(np, nv);
            }
        }
    }
    let fused = absorbed.iter().filter(|&&a| a).count();
    (out, fused)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CoreType, GraphBuilder};

    #[test]
    fn fuses_gemm_relu_pair() {
        let mut b = GraphBuilder::new();
        let g1 = b.gemm("fc", 16, 16, 16, &[]);
        let r = b.eltwise("relu", 256, 1, &[g1]);
        let _next = b.gemm("fc2", 16, 16, 16, &[r]);
        let (fused, count) = fuse(&b.finish());
        assert_eq!(count, 1);
        assert_eq!(fused.len(), 2);
        assert_eq!(fused.ops[0].kind.core_type(), CoreType::Fused);
        assert_eq!(fused.ops[0].name, "fc+relu");
        // Edge re-routed through the fused node.
        assert_eq!(fused.succs(0), &[1]);
    }

    #[test]
    fn no_fuse_when_activation_has_fanin() {
        let mut b = GraphBuilder::new();
        let g1 = b.gemm("a", 16, 16, 16, &[]);
        let g2 = b.gemm("b", 16, 16, 16, &[]);
        let _add = b.eltwise("add", 256, 1, &[g1, g2]);
        let (fused, count) = fuse(&b.finish());
        assert_eq!(count, 0);
        assert_eq!(fused.len(), 3);
    }

    #[test]
    fn no_fuse_when_producer_has_fanout() {
        let mut b = GraphBuilder::new();
        let g1 = b.gemm("a", 16, 16, 16, &[]);
        let _r = b.eltwise("relu", 256, 1, &[g1]);
        let _branch = b.eltwise("branch", 256, 1, &[g1]);
        let (_, count) = fuse(&b.finish());
        assert_eq!(count, 0);
    }

    #[test]
    fn no_fuse_on_size_mismatch() {
        let mut b = GraphBuilder::new();
        let g1 = b.gemm("a", 16, 16, 16, &[]);
        let _pool = b.eltwise("pool", 64, 1, &[g1]); // 64 != 256
        let (_, count) = fuse(&b.finish());
        assert_eq!(count, 0);
    }

    #[test]
    fn expensive_epilogues_stay_separate() {
        let mut b = GraphBuilder::new();
        let g1 = b.gemm("a", 16, 16, 16, &[]);
        let _n = b.eltwise("norm", 256, 6, &[g1]);
        let (_, count) = fuse(&b.finish());
        assert_eq!(count, 0);
    }

    #[test]
    fn conv_relu_fuses() {
        let mut b = GraphBuilder::new();
        let c = b.conv("c", 2, 3, 8, 3, 3, 8, 8, &[]);
        let _r = b.eltwise("relu", 2 * 8 * 8 * 8, 1, &[c]);
        let (fused, count) = fuse(&b.finish());
        assert_eq!(count, 1);
        assert!(matches!(fused.ops[0].kind, OpKind::FusedGemmAct { .. }));
    }
}
