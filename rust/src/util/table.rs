//! Minimal ASCII table renderer for CLI reports and bench output.

/// Column-aligned ASCII table with a header row.
#[derive(Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given header cells.
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append one row; shorter rows are padded with empty cells.
    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        self.rows.push(cells.into_iter().map(Into::into).collect());
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with `|` separators and a dashed header rule.
    pub fn render(&self) -> String {
        let cols = self
            .header
            .len()
            .max(self.rows.iter().map(Vec::len).max().unwrap_or(0));
        let mut widths = vec![0usize; cols];
        let measure = |widths: &mut Vec<usize>, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        };
        measure(&mut widths, &self.header);
        for r in &self.rows {
            measure(&mut widths, r);
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = w - cell.chars().count();
                line.push(' ');
                line.push_str(cell);
                line.extend(std::iter::repeat(' ').take(pad));
                line.push_str(" |");
            }
            line
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let mut rule = String::from("|");
        for w in &widths {
            rule.push_str(&"-".repeat(w + 2));
            rule.push('|');
        }
        out.push_str(&rule);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(["model", "thpt"]);
        t.row(["bert-base", "1.25"]);
        t.row(["vgg", "900.0"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines same width.
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
        assert!(lines[0].contains("model"));
        assert!(lines[2].contains("bert-base"));
    }

    #[test]
    fn pads_short_rows() {
        let mut t = Table::new(["a", "b", "c"]);
        t.row(["1"]);
        let s = t.render();
        assert!(s.lines().all(|l| l.matches('|').count() == 4));
    }

    #[test]
    fn empty_table() {
        let t = Table::new(["x"]);
        assert!(t.is_empty());
        assert_eq!(t.render().lines().count(), 2);
    }
}
