//! Deterministic seeded RNG (SplitMix64 + xoshiro256**).
//!
//! The offline crate cache has no `rand`; the baseline searches
//! (ConfuciuX+ RL/GA, Spotlight+ BO) and the property-test harness need a
//! reproducible generator, so we carry a small, well-known one.

/// xoshiro256** seeded through SplitMix64 (Blackman/Vigna reference
/// constants). Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator; any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 stream to fill the state, per the xoshiro paper.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift bounded sampling; bias < 2^-64, irrelevant here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Pick a uniformly random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call, simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive_endpoints() {
        let mut r = Rng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2_000 {
            match r.range(-2, 2) {
                -2 => lo_seen = true,
                2 => hi_seen = true,
                v => assert!((-2..=2).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
