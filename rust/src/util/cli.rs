//! Minimal CLI argument parser (the offline cache has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed getters and an unknown-flag check.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Error produced by typed getters.
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of raw arguments (without argv0).
    /// `value_keys` lists the `--key`s that consume a following value;
    /// everything else starting with `--` is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, value_keys: &[&str]) -> Result<Self, CliError> {
        let mut out = Self::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if value_keys.contains(&body) {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError(format!("--{body} expects a value")))?;
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env(value_keys: &[&str]) -> Result<Self, CliError> {
        Self::parse(std::env::args().skip(1), value_keys)
    }

    /// Positional argument by index.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(String::as_str)
    }

    /// All positional arguments.
    pub fn positionals(&self) -> &[String] {
        &self.positional
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Boolean flag presence.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Typed getter for anything `FromStr`.
    pub fn get_as<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>, CliError> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| CliError(format!("--{key}: cannot parse {s:?}"))),
        }
    }

    /// Typed getter with default.
    pub fn get_as_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, CliError> {
        Ok(self.get_as(key)?.unwrap_or(default))
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .map(|s| s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], keys: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), keys).unwrap()
    }

    #[test]
    fn parses_key_value_and_flags() {
        let a = parse(&["search", "--model", "bert-base", "--ilp", "--k=10"], &["model"]);
        assert_eq!(a.pos(0), Some("search"));
        assert_eq!(a.get("model"), Some("bert-base"));
        assert!(a.flag("ilp"));
        assert_eq!(a.get_as::<usize>("k").unwrap(), Some(10));
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(["--model".to_string()], &["model"]);
        assert!(r.is_err());
    }

    #[test]
    fn typed_parse_error() {
        let a = parse(&["--k=abc"], &[]);
        assert!(a.get_as::<usize>("k").is_err());
    }

    #[test]
    fn list_option() {
        let a = parse(&["--models=bert-base, vgg16,,resnet18"], &[]);
        assert_eq!(a.get_list("models"), vec!["bert-base", "vgg16", "resnet18"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_or("metric", "throughput"), "throughput");
        assert_eq!(a.get_as_or("depth", 32usize).unwrap(), 32);
    }
}
