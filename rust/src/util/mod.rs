//! Supporting substrates built in-repo because the offline crate cache
//! carries only the `xla` dependency closure: a seeded RNG ([`rng`]),
//! ASCII table rendering ([`table`]), a minimal CLI argument parser
//! ([`cli`]), a wall-clock bench harness ([`mod@bench`]), and a tiny
//! property-testing helper ([`prop`]).

pub mod bench;
pub mod cli;
pub mod fnv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;

/// Best-effort text of a caught panic payload (shared by the coordinator
/// workers and the service's request coalescer).
pub fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// Machine parallelism, the default for every `--jobs`-shaped knob (CLI
/// `--jobs`, `wham serve --workers`). Falls back to 1 where the OS
/// refuses to answer.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(std::num::NonZeroUsize::get).unwrap_or(1)
}

/// Integer ceiling division. The cost model and schedulers use this in
/// many places; keep it `u64` so GEMM tile products cannot overflow.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    a / b + u64::from(a % b != 0)
}

/// Round `v` up to the next multiple of `m`.
#[inline]
pub fn round_up(v: u64, m: u64) -> u64 {
    ceil_div(v, m) * m
}

/// Format a byte count with binary units.
pub fn human_bytes(b: u64) -> String {
    const U: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut i = 0;
    while v >= 1024.0 && i + 1 < U.len() {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", U[i])
    }
}

/// Format a count in engineering notation (1.2 K, 3.4 M, ...).
pub fn human_count(c: f64) -> String {
    let a = c.abs();
    if a >= 1e12 {
        format!("{:.2} T", c / 1e12)
    } else if a >= 1e9 {
        format!("{:.2} G", c / 1e9)
    } else if a >= 1e6 {
        format!("{:.2} M", c / 1e6)
    } else if a >= 1e3 {
        format!("{:.2} K", c / 1e3)
    } else {
        format!("{c:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        // u64::MAX - 3 = 2^64 - 4 divides 4 exactly; no overflow either.
        assert_eq!(ceil_div(u64::MAX - 3, 4), (u64::MAX - 3) / 4);
        assert_eq!(ceil_div(u64::MAX, 2), u64::MAX / 2 + 1);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(16 * 1024 * 1024 * 1024), "16.00 GiB");
    }

    #[test]
    fn human_count_units() {
        assert_eq!(human_count(999.0), "999.00");
        assert_eq!(human_count(1.5e6), "1.50 M");
        assert_eq!(human_count(2.0e13), "20.00 T");
    }
}
