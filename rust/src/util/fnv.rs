//! FNV-1a 64-bit hashing, shared by the workload fingerprint, the
//! design-database keys, and the request-coalescing keys so the fold
//! logic (and its constants) exist exactly once.

/// FNV-1a 64-bit offset basis.
pub const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime (0x100000001b3).
pub const PRIME: u64 = 0x100_0000_01b3;

/// A running FNV-1a state with by-value chaining.
#[derive(Debug, Clone, Copy)]
pub struct Fnv(pub u64);

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv {
    /// Start from the offset basis.
    pub fn new() -> Self {
        Fnv(OFFSET)
    }

    /// Fold in raw bytes.
    pub fn bytes(mut self, bs: &[u8]) -> Self {
        for &b in bs {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(PRIME);
        }
        self
    }

    /// Fold in one `u64` (little-endian bytes).
    pub fn word(self, x: u64) -> Self {
        self.bytes(&x.to_le_bytes())
    }

    /// Fold in a slice of `u64`s.
    pub fn words(mut self, xs: &[u64]) -> Self {
        for &x in xs {
            self = self.word(x);
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sensitive() {
        let a = Fnv::new().word(1).word(2).0;
        assert_eq!(a, Fnv::new().word(1).word(2).0);
        assert_ne!(a, Fnv::new().word(2).word(1).0);
        assert_ne!(a, Fnv::new().word(1).0);
        assert_ne!(Fnv::new().bytes(b"native").0, Fnv::new().bytes(b"pjrt").0);
    }

    #[test]
    fn prime_is_the_standard_fnv64_prime() {
        assert_eq!(PRIME, 1_099_511_628_211);
    }
}
