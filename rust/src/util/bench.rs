//! Wall-clock bench harness (the offline cache has no `criterion`).
//!
//! Each `benches/*.rs` target is `harness = false` and drives this:
//! warmup, N timed iterations, median/mean/min report, plus free-form
//! "series" rows so every bench can print the table/figure data it
//! regenerates in the paper's own shape.

use std::time::{Duration, Instant};

/// Timing summary of one benchmarked closure.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} iters={:<3} mean={:>12?} median={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.median, self.min
        )
    }
}

/// Run `f` `iters` times after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let sum: Duration = samples.iter().sum();
    BenchStats {
        name: name.to_string(),
        iters,
        mean: sum / iters as u32,
        median: samples[iters / 2],
        min: samples[0],
        max: samples[iters - 1],
    }
}

/// Convenience: time a single run of `f`, returning its value + duration.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

/// Standard bench-output banner so all figure benches look alike.
pub fn banner(id: &str, what: &str) {
    println!("================================================================");
    println!("{id}: {what}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let s = bench("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d < Duration::from_secs(1));
    }
}
