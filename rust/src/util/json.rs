//! Minimal JSON support (the offline cache has no `serde`).
//!
//! The service layer needs to *read* request bodies and the design
//! database, not just write them, so alongside the hand-rolled
//! `format!`-style emission used by [`crate::report::trace`] this module
//! provides a small recursive-descent parser into [`JsonValue`] plus the
//! string-escaping helper the emitters share.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON document.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    /// Object field by key.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String content, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric content, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric content truncated to `u64`, if this is a non-negative number.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(n) if *n >= 0.0 && n.is_finite() => Some(*n as u64),
            _ => None,
        }
    }

    /// Boolean content, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a JSON document (with quotes).
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Emit an `f64` as a JSON number token (non-finite values become `null`,
/// which JSON cannot represent).
pub fn num(v: f64) -> String {
    if v.is_finite() {
        // `{}` on f64 is the shortest representation that round-trips.
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Incremental JSON-object emitter. The wire layer (`crate::api::wire`)
/// builds every request/reply body through this instead of hand-rolled
/// `format!` assembly, so key escaping and number formatting share one
/// code path with [`esc`]/[`num`] — the same helpers the parser's tests
/// round-trip through.
#[derive(Debug, Clone)]
pub struct Obj {
    buf: String,
}

impl Default for Obj {
    fn default() -> Self {
        Self::new()
    }
}

impl Obj {
    /// Start an empty object.
    pub fn new() -> Self {
        Obj { buf: String::from("{") }
    }

    fn key(&mut self, k: &str) {
        if self.buf.len() > 1 {
            self.buf.push(',');
        }
        self.buf.push_str(&esc(k));
        self.buf.push(':');
    }

    /// Field whose value is already-serialized JSON.
    pub fn raw(mut self, k: &str, raw: &str) -> Self {
        self.key(k);
        self.buf.push_str(raw);
        self
    }

    /// String field (escaped).
    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.key(k);
        self.buf.push_str(&esc(v));
        self
    }

    /// Unsigned-integer field.
    pub fn u64(mut self, k: &str, v: u64) -> Self {
        self.key(k);
        let _ = write!(self.buf, "{v}");
        self
    }

    /// Float field (non-finite becomes `null`, see [`num`]).
    pub fn f64(mut self, k: &str, v: f64) -> Self {
        self.key(k);
        self.buf.push_str(&num(v));
        self
    }

    /// Boolean field.
    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.key(k);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    /// Optional unsigned-integer field — omitted entirely when `None`.
    pub fn opt_u64(self, k: &str, v: Option<u64>) -> Self {
        match v {
            Some(x) => self.u64(k, x),
            None => self,
        }
    }

    /// String-or-null field.
    pub fn nullable_str(self, k: &str, v: Option<&str>) -> Self {
        match v {
            Some(s) => self.str(k, s),
            None => self.raw(k, "null"),
        }
    }

    /// Close the object and return its serialized bytes.
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

/// Serialize pre-serialized items as a JSON array.
pub fn arr<I: IntoIterator<Item = String>>(items: I) -> String {
    let items: Vec<String> = items.into_iter().collect();
    format!("[{}]", items.join(","))
}

/// Serialize strings as a JSON array of (escaped) strings.
pub fn str_arr<'a, I: IntoIterator<Item = &'a str>>(items: I) -> String {
    arr(items.into_iter().map(esc))
}

/// Serialize a parsed [`JsonValue`] back to compact JSON text. Object
/// keys come out in `BTreeMap` (sorted) order, so `dump(parse(x))` is a
/// *canonical* form of `x`, not necessarily the original bytes.
pub fn dump(v: &JsonValue) -> String {
    let mut out = String::new();
    dump_into(v, &mut out);
    out
}

fn dump_into(v: &JsonValue, out: &mut String) {
    match v {
        JsonValue::Null => out.push_str("null"),
        JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        JsonValue::Num(n) => out.push_str(&num(*n)),
        JsonValue::Str(s) => out.push_str(&esc(s)),
        JsonValue::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                dump_into(item, out);
            }
            out.push(']');
        }
        JsonValue::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&esc(k));
                out.push(':');
                dump_into(val, out);
            }
            out.push('}');
        }
    }
}

/// Parse a JSON document. Errors carry a byte offset and a message.
pub fn parse(text: &str) -> Result<JsonValue, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing content at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn eat_lit(&mut self, lit: &str, v: JsonValue) -> Result<JsonValue, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.eat_lit("true", JsonValue::Bool(true)),
            Some(b'f') => self.eat_lit("false", JsonValue::Bool(false)),
            Some(b'n') => self.eat_lit("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected content at byte {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("truncated \\u escape".to_string());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            // Surrogate pairs are not needed by our own
                            // emitters; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Multi-byte UTF-8 sequences pass through verbatim.
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(JsonValue::Num)
            .map_err(|_| format!("bad number {text:?} at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let v = parse(r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny"}, "ok": true, "z": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("z"), Some(&JsonValue::Null));
    }

    #[test]
    fn escape_round_trips() {
        let s = "he said \"hi\\\"\n\tdone\u{1}";
        let v = parse(&format!("{{\"k\": {}}}", esc(s))).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some(s));
    }

    #[test]
    fn f64_round_trips_through_num() {
        for x in [0.0, 1.5, -2.25e-7, 123456789.123, f64::MAX] {
            let v = parse(&num(x)).unwrap();
            assert_eq!(v.as_f64(), Some(x));
        }
        assert_eq!(num(f64::INFINITY), "null");
    }

    #[test]
    fn obj_emitter_round_trips_through_parser() {
        let s = Obj::new()
            .str("name", "bërt \"x\"\n")
            .u64("k", 10)
            .f64("score", 1.5)
            .bool("ilp", true)
            .opt_u64("absent", None)
            .nullable_str("path", None)
            .raw("top", &arr(["1".to_string(), "2".to_string()]))
            .finish();
        let v = parse(&s).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("bërt \"x\"\n"));
        assert_eq!(v.get("k").unwrap().as_u64(), Some(10));
        assert_eq!(v.get("score").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("ilp").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("absent"), None);
        assert_eq!(v.get("path"), Some(&JsonValue::Null));
        assert_eq!(v.get("top").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(parse(&Obj::new().finish()).unwrap(), JsonValue::Obj(Default::default()));
        assert_eq!(str_arr(["a", "b"]), "[\"a\",\"b\"]");
    }

    #[test]
    fn dump_is_a_canonical_fixed_point() {
        let text = r#"{"b":[1,2.5,{"y":null,"x":"q\"z"}],"a":true}"#;
        let v = parse(text).unwrap();
        let d = dump(&v);
        // Keys are re-emitted sorted; a second round trip is stable.
        assert_eq!(d, r#"{"a":true,"b":[1,2.5,{"x":"q\"z","y":null}]}"#);
        assert_eq!(dump(&parse(&d).unwrap()), d);
        assert_eq!(parse(&d).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn chrome_trace_output_parses() {
        // The existing hand-rolled emitters must be readable back.
        let g = crate::sched::fanout3();
        let ann = crate::cost::annotate::AnnotatedGraph::new(
            &g,
            crate::cost::Dims { tc_x: 64, tc_y: 64, vc_w: 64 },
            &mut crate::cost::native::NativeCost,
        );
        let cp = crate::sched::asap_alap(&ann);
        let cores = crate::sched::CoreCount { tc: 2, vc: 1 };
        let s = crate::sched::greedy_schedule(&ann, &cp, cores);
        let t = crate::report::trace::chrome_trace(&ann, &s, cores);
        let v = parse(&t).unwrap();
        assert_eq!(v.as_arr().unwrap().len(), g.len());
    }
}
