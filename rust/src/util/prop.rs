//! Tiny property-testing harness (the offline cache has no `proptest`).
//!
//! `forall(seed, cases, gen, check)` draws `cases` random inputs from
//! `gen` and asserts `check`. On failure it retries with progressively
//! "smaller" regenerated inputs (size-bounded generation rather than
//! structural shrinking) and reports the smallest failing case's seed so
//! the failure is replayable.

use super::rng::Rng;

/// Generation context handed to generators; `size` shrinks on failure.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

impl<'a> Gen<'a> {
    /// Integer in `[lo, hi]` biased by the current size bound.
    pub fn int_sized(&mut self, lo: i64, hi: i64) -> i64 {
        let span = (hi - lo).min(self.size as i64).max(0);
        self.rng.range(lo, lo + span)
    }

    /// Length for a collection: `[0, size]` capped at `max`.
    pub fn len(&mut self, max: usize) -> usize {
        self.rng.below(self.size.min(max) + 1)
    }
}

/// Run a property over `cases` random inputs. Panics (test failure) with
/// the replay seed and case description on the smallest failure found.
pub fn forall<T, G, C>(seed: u64, cases: usize, mut gen: G, mut check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Gen) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    let mut failure: Option<(u64, usize, T, String)> = None;
    'outer: for case in 0..cases {
        let case_seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let mut g = Gen { rng: &mut rng, size: 2 + case % 64 };
        let input = gen(&mut g);
        if let Err(msg) = check(&input) {
            // Try to find a smaller failing input by regenerating at
            // decreasing sizes from derived seeds.
            for shrink in 0..200u64 {
                let s2 = case_seed.wrapping_add(shrink.wrapping_mul(0x5851_F42D_4C95_7F2D));
                let mut rng2 = Rng::new(s2);
                let mut g2 = Gen { rng: &mut rng2, size: 1 + (shrink % 8) as usize };
                let small = gen(&mut g2);
                if let Err(m2) = check(&small) {
                    failure = Some((s2, case, small, m2));
                    break 'outer;
                }
            }
            failure = Some((case_seed, case, input, msg));
            break 'outer;
        }
    }
    if let Some((s, case, input, msg)) = failure {
        panic!("property failed (case {case}, replay seed {s:#x}):\n  input: {input:?}\n  {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            1,
            200,
            |g| g.int_sized(0, 100),
            |&x| if x >= 0 { Ok(()) } else { Err("negative".into()) },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(
            2,
            200,
            |g| g.int_sized(0, 100),
            |&x| if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) },
        );
    }

    #[test]
    fn gen_len_respects_max() {
        let mut rng = Rng::new(3);
        let mut g = Gen { rng: &mut rng, size: 100 };
        for _ in 0..100 {
            assert!(g.len(10) <= 10);
        }
    }
}
