//! Annotated operator graphs: the estimator's output consumed by the
//! critical-path search (paper Figure 4, module 1 -> module 2 handoff).

use super::{CostBackend, Dims, OpCost};
use crate::arch::CLOCK_GHZ;
use crate::graph::{CoreType, OperatorGraph};

/// An operator graph plus per-op costs for one `<TC-Dim, VC-Width>`.
#[derive(Debug, Clone)]
pub struct AnnotatedGraph<'g> {
    pub graph: &'g OperatorGraph,
    pub dims: Dims,
    pub costs: Vec<OpCost>,
    /// Integer cycle latencies used by the schedulers (>= 1 per op so no
    /// operator is free).
    pub cycles: Vec<u64>,
    /// Core type per op, cached for the scheduler's hot loop.
    pub core: Vec<CoreType>,
}

impl<'g> AnnotatedGraph<'g> {
    /// Run the estimator over the graph's *cost classes*: the backend
    /// sees one row per unique `(kind, shape)` class and the results are
    /// scattered back per op by class id. Training graphs repeat the
    /// same layer shapes dozens of times, so this evaluates an order of
    /// magnitude fewer rows than [`Self::new_naive`] while producing a
    /// bit-identical annotation (same rows in, same `OpCost` out — the
    /// backends are pure functions of the row).
    pub fn new(graph: &'g OperatorGraph, dims: Dims, backend: &mut dyn CostBackend) -> Self {
        let _span = crate::telemetry::trace::span("annotate")
            .arg("ops", graph.len())
            .arg("tc", format!("{}x{}", dims.tc_x, dims.tc_y))
            .arg("vc", dims.vc_w);
        let classes = graph.cost_classes();
        super::note_backend_rows(classes.len() as u64);
        let class_costs = backend.evaluate(&classes.rows, dims);
        assert_eq!(class_costs.len(), classes.len(), "backend returned wrong row count");
        let costs: Vec<OpCost> =
            classes.class_of.iter().map(|&c| class_costs[c as usize]).collect();
        Self::from_costs(graph, dims, costs)
    }

    /// Legacy per-op path: evaluate the backend on the full operator
    /// table, one row per op. Kept as the parity baseline for the
    /// interned path (`rust/tests/hotpath_parity.rs`) and for ablations.
    pub fn new_naive(graph: &'g OperatorGraph, dims: Dims, backend: &mut dyn CostBackend) -> Self {
        let _span = crate::telemetry::trace::span("annotate")
            .arg("ops", graph.len())
            .arg("naive", true)
            .arg("tc", format!("{}x{}", dims.tc_x, dims.tc_y))
            .arg("vc", dims.vc_w);
        let rows = graph.cost_rows();
        super::note_backend_rows(rows.len() as u64);
        let costs = backend.evaluate(&rows, dims);
        assert_eq!(costs.len(), graph.len(), "backend returned wrong row count");
        Self::from_costs(graph, dims, costs)
    }

    fn from_costs(graph: &'g OperatorGraph, dims: Dims, costs: Vec<OpCost>) -> Self {
        let cycles = costs.iter().map(|c| (c.latency.ceil() as u64).max(1)).collect();
        let core = graph.ops.iter().map(|o| o.kind.core_type()).collect();
        Self { graph, dims, costs, cycles, core }
    }

    /// Sum of all op energies in pJ.
    pub fn total_energy_pj(&self) -> f64 {
        self.costs.iter().map(|c| c.energy).sum()
    }

    /// Serial-execution latency (sum of all cycles): upper bound used by
    /// schedulers for slot estimation.
    pub fn serial_cycles(&self) -> u64 {
        self.cycles.iter().sum()
    }

    /// Convert cycles to seconds at the modeled clock.
    pub fn cycles_to_seconds(cycles: u64) -> f64 {
        cycles as f64 / (CLOCK_GHZ * 1e9)
    }

    /// Mean utilization across ops of a core type (Fig. 2 data).
    pub fn mean_util(&self, core: CoreType) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for (i, c) in self.core.iter().enumerate() {
            if *c == core {
                sum += self.costs[i].util;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;
    use crate::graph::GraphBuilder;

    fn tiny() -> OperatorGraph {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 64, 64, 64, &[]);
        let _ = b.eltwise("r", 64 * 64, 1, &[a]);
        b.finish()
    }

    #[test]
    fn annotates_every_op() {
        let g = tiny();
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 64, tc_y: 64, vc_w: 64 }, &mut NativeCost);
        assert_eq!(ann.costs.len(), 2);
        assert!(ann.cycles.iter().all(|&c| c >= 1));
        assert_eq!(ann.core, vec![CoreType::Tensor, CoreType::Vector]);
    }

    #[test]
    fn serial_is_sum() {
        let g = tiny();
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 64, tc_y: 64, vc_w: 64 }, &mut NativeCost);
        assert_eq!(ann.serial_cycles(), ann.cycles[0] + ann.cycles[1]);
    }

    #[test]
    fn interned_annotation_matches_naive() {
        // Two ops of the same class + one distinct: the interned path
        // evaluates 2 backend rows, the naive path 3 — same annotation.
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 64, 64, 64, &[]);
        let c = b.gemm("c", 64, 64, 64, &[a]);
        let _ = b.eltwise("r", 64 * 64, 1, &[c]);
        let g = b.finish();
        assert_eq!(g.cost_classes().len(), 2);
        let d = Dims { tc_x: 64, tc_y: 64, vc_w: 64 };
        let fast = AnnotatedGraph::new(&g, d, &mut NativeCost);
        let naive = AnnotatedGraph::new_naive(&g, d, &mut NativeCost);
        assert_eq!(fast.costs, naive.costs);
        assert_eq!(fast.cycles, naive.cycles);
        assert_eq!(fast.core, naive.core);
    }

    #[test]
    fn seconds_conversion() {
        let s = AnnotatedGraph::cycles_to_seconds(940_000_000);
        assert!((s - 1.0).abs() < 1e-9);
    }
}
