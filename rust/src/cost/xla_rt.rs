//! PJRT-backed cost backend: evaluates operator tables through the
//! AOT-compiled Layer-1/2 artifact in `N_OPS`-row chunks.
//!
//! This is the production estimator of the three-layer stack; the search
//! makes one batched call per candidate `<TC-Dim, VC-Width>` (plus
//! chunking for graphs above 4096 ops), so PJRT dispatch cost is amortized
//! across the whole operator table.

use super::{CostBackend, Dims, OpCost};
use crate::graph::CostRow;
use crate::runtime::pjrt::{CostModelRuntime, N_OPS};

/// Cost backend executing `artifacts/cost_model.hlo.txt` via PJRT.
pub struct XlaCost {
    rt: CostModelRuntime,
}

impl XlaCost {
    /// Load from the discovered artifacts directory.
    pub fn from_artifacts() -> anyhow::Result<Self> {
        let dir = crate::runtime::artifacts_dir()
            .ok_or_else(|| anyhow::anyhow!("artifacts/ not found — run `make artifacts`"))?;
        Ok(Self { rt: CostModelRuntime::load(&dir)? })
    }

    /// Wrap an already-loaded runtime.
    pub fn new(rt: CostModelRuntime) -> Self {
        Self { rt }
    }
}

impl CostBackend for XlaCost {
    fn evaluate(&mut self, rows: &[CostRow], dims: Dims) -> Vec<OpCost> {
        let cfg = [dims.tc_x as i32, dims.tc_y as i32, dims.vc_w as i32];
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(N_OPS) {
            let mut kind = vec![-1i32; N_OPS];
            let mut m = vec![1i32; N_OPS];
            let mut n = vec![1i32; N_OPS];
            let mut k = vec![1i32; N_OPS];
            for (i, r) in chunk.iter().enumerate() {
                // validate.rs guarantees dims fit in i32.
                kind[i] = r.kind;
                m[i] = r.m as i32;
                n[i] = r.n as i32;
                k[i] = r.k as i32;
            }
            let batch = self
                .rt
                .evaluate(&kind, &m, &n, &k, cfg)
                .expect("PJRT cost evaluation failed");
            for i in 0..chunk.len() {
                out.push(OpCost {
                    latency: batch.latency[i] as f64,
                    energy: batch.energy[i] as f64,
                    util: batch.util[i] as f64,
                });
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
