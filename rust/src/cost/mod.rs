//! Architecture estimator (paper section 4.2): annotates every operator
//! with latency, energy, and utilization under a candidate
//! `<TC-Dim, VC-Width>`.
//!
//! Two interchangeable backends implement [`CostBackend`]:
//! * [`native`] — pure-rust mirror of `python/compile/kernels/ref.py`;
//! * [`xla_rt`] — executes the AOT-compiled Layer-1/2 artifact
//!   (`artifacts/cost_model.hlo.txt`) through PJRT, in 4096-op batches.
//!
//! The `pjrt_vs_native` integration test pins the two to <= 1e-3 relative.

pub mod annotate;
pub mod native;
pub mod xla_rt;

use crate::graph::CostRow;

/// Per-operator cost estimate.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct OpCost {
    /// Execution latency in core cycles.
    pub latency: f64,
    /// Energy in pJ.
    pub energy: f64,
    /// Core utilization in [0, 1].
    pub util: f64,
}

/// Dimension slice of a design the estimator depends on (only TC-Dim and
/// VC-Width matter for per-op costs — paper section 4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Dims {
    pub tc_x: u64,
    pub tc_y: u64,
    pub vc_w: u64,
}

impl Dims {
    /// Dimension slice of a full config.
    pub fn of(c: &crate::arch::ArchConfig) -> Self {
        Self { tc_x: c.tc_x, tc_y: c.tc_y, vc_w: c.vc_w }
    }
}

/// Cumulative cost-backend rows evaluated process-wide. Fed by the
/// annotation paths ([`annotate::AnnotatedGraph`]), surfaced by the
/// service's `GET /status` perf counters, `GET /metrics`, and the
/// hot-path bench — the unit the operator-class interner shrinks.
/// Registered in the [`crate::telemetry::registry`].
static BACKEND_ROWS: crate::telemetry::Counter = crate::telemetry::Counter::new(
    "wham_backend_rows_total",
    "Cost-backend rows evaluated since process start.",
);

/// Record `n` rows handed to a cost backend.
pub fn note_backend_rows(n: u64) {
    BACKEND_ROWS.add(n);
}

/// Total rows handed to cost backends since process start.
pub fn backend_rows_total() -> u64 {
    BACKEND_ROWS.get()
}

/// A batched cost evaluator.
pub trait CostBackend {
    /// Cost every row under `dims`. Must return one cost per row.
    fn evaluate(&mut self, rows: &[CostRow], dims: Dims) -> Vec<OpCost>;

    /// Human-readable backend name (logs / reports).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_of_config() {
        let c = crate::arch::ArchConfig::new(3, 128, 64, 3, 128);
        assert_eq!(Dims::of(&c), Dims { tc_x: 128, tc_y: 64, vc_w: 128 });
    }
}
