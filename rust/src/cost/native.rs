//! Native rust mirror of the operator cost model.
//!
//! MUST match `python/compile/kernels/ref.py` — that file is the single
//! source of truth for the semantics; the `pjrt_vs_native` integration
//! test enforces agreement with the AOT artifact at <= 1e-3 relative.
//! Arithmetic is f64 here vs f32 in XLA, hence a tolerance rather than
//! bit equality; the integer ceil-divisions are exact in both.

use super::{CostBackend, Dims, OpCost};
use crate::graph::CostRow;
use crate::util::ceil_div;

/// bf16 operand width.
pub const BYTES: f64 = 2.0;
/// TPUv2-like clock in GHz.
pub const CLOCK_GHZ: f64 = 0.94;
/// HBM bandwidth in GB/s.
pub const HBM_GBPS: f64 = 900.0;
/// HBM bytes per core cycle.
pub const BPC: f64 = HBM_GBPS / CLOCK_GHZ;
/// pJ per bf16 MAC.
pub const E_MAC_PJ: f64 = 0.56;
/// pJ per SRAM byte.
pub const E_SRAM_PJ: f64 = 1.3;
/// pJ per HBM byte.
pub const E_HBM_PJ: f64 = 7.0;
/// pJ per vector lane op.
pub const E_VEC_PJ: f64 = 0.31;

/// Cost one operator row under the given dims (ref.py `cost_ref`).
pub fn cost_op(row: CostRow, d: Dims) -> OpCost {
    let (m, n) = (row.m as f64, row.n as f64);
    match row.kind {
        0 => tensor_cost(row, d),
        1 => {
            let groups = ceil_div(row.m, d.vc_w) as f64;
            let compute = groups * n;
            let bytes = 2.0 * m * BYTES;
            let mem = bytes / BPC;
            OpCost {
                latency: compute.max(mem),
                energy: m * n * E_VEC_PJ + bytes * E_HBM_PJ + bytes * E_SRAM_PJ,
                util: m / (groups * d.vc_w as f64),
            }
        }
        2 => {
            let t = tensor_cost(CostRow { kind: 0, ..row }, d);
            let f_groups = (m * n / d.vc_w as f64).ceil();
            OpCost {
                latency: t_compute(row, d).max(f_groups).max(t_mem(row)),
                energy: t.energy + m * n * E_VEC_PJ,
                util: t.util,
            }
        }
        _ => OpCost::default(),
    }
}

fn t_compute(row: CostRow, d: Dims) -> f64 {
    let tiles = (ceil_div(row.m, d.tc_x) * ceil_div(row.n, d.tc_y)) as f64;
    tiles * (row.k as f64 + d.tc_x as f64 + d.tc_y as f64)
}

fn t_mem(row: CostRow) -> f64 {
    let (m, n, k) = (row.m as f64, row.n as f64, row.k as f64);
    (m * k + k * n + m * n) * BYTES / BPC
}

fn tensor_cost(row: CostRow, d: Dims) -> OpCost {
    let (m, n, k) = (row.m as f64, row.n as f64, row.k as f64);
    let tiles_m = ceil_div(row.m, d.tc_x) as f64;
    let tiles_n = ceil_div(row.n, d.tc_y) as f64;
    let bytes = (m * k + k * n + m * n) * BYTES;
    let macs = m * n * k;
    OpCost {
        latency: t_compute(row, d).max(bytes / BPC),
        energy: macs * E_MAC_PJ + bytes * E_HBM_PJ + bytes * E_SRAM_PJ,
        util: (m * n) / (tiles_m * d.tc_x as f64 * tiles_n * d.tc_y as f64),
    }
}

/// The native backend: straightforward batched evaluation.
#[derive(Debug, Default, Clone)]
pub struct NativeCost;

impl CostBackend for NativeCost {
    fn evaluate(&mut self, rows: &[CostRow], dims: Dims) -> Vec<OpCost> {
        rows.iter().map(|&r| cost_op(r, dims)).collect()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const D: Dims = Dims { tc_x: 128, tc_y: 128, vc_w: 128 };

    #[test]
    fn gemm_compute_formula_matches_ref_case() {
        // Pinned against python/tests/test_kernel.py::test_gemm_compute_formula.
        let c = cost_op(CostRow { kind: 0, m: 256, n: 256, k: 256 }, D);
        assert_eq!(c.latency, 4.0 * (256.0 + 128.0 + 128.0));
    }

    #[test]
    fn memory_bound_vector_matches_ref_case() {
        let mf = 1_000_000u64;
        let c = cost_op(CostRow { kind: 1, m: mf, n: 1, k: 1 }, Dims { vc_w: 256, ..D });
        let expect = 2.0 * mf as f64 * 2.0 / BPC;
        assert!((c.latency - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn full_utilization_when_divisible() {
        let c = cost_op(CostRow { kind: 0, m: 256, n: 256, k: 64 }, D);
        assert!((c.util - 1.0).abs() < 1e-12);
    }

    #[test]
    fn low_utilization_small_op() {
        let d = Dims { tc_x: 256, tc_y: 256, vc_w: 256 };
        let c = cost_op(CostRow { kind: 0, m: 4, n: 4, k: 64 }, d);
        assert!((c.util - 16.0 / 65536.0).abs() < 1e-9);
    }

    #[test]
    fn fused_latency_dominates_tensor() {
        let row = CostRow { kind: 0, m: 512, n: 512, k: 512 };
        let frow = CostRow { kind: 2, ..row };
        assert!(cost_op(frow, D).latency >= cost_op(row, D).latency);
    }

    #[test]
    fn fused_energy_adds_epilogue() {
        let row = CostRow { kind: 0, m: 64, n: 64, k: 64 };
        let t = cost_op(row, D).energy;
        let f = cost_op(CostRow { kind: 2, ..row }, D).energy;
        assert!((f - t - 64.0 * 64.0 * E_VEC_PJ).abs() < 1e-6);
    }

    #[test]
    fn backend_is_elementwise() {
        let rows = vec![
            CostRow { kind: 0, m: 128, n: 128, k: 128 },
            CostRow { kind: 1, m: 1000, n: 2, k: 1 },
        ];
        let mut b = NativeCost;
        let out = b.evaluate(&rows, D);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0], cost_op(rows[0], D));
        assert_eq!(out[1], cost_op(rows[1], D));
    }

    #[test]
    fn smaller_core_means_more_cycles_for_big_gemm() {
        let row = CostRow { kind: 0, m: 4096, n: 4096, k: 4096 };
        let small = cost_op(row, Dims { tc_x: 64, tc_y: 64, vc_w: 64 }).latency;
        let large = cost_op(row, Dims { tc_x: 256, tc_y: 256, vc_w: 64 }).latency;
        assert!(large <= small);
    }
}
