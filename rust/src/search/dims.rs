//! Core-dimension generator (paper section 4.1): candidate
//! `<TC-Dim, VC-Width>` values, largest-first in powers of two
//! ("to accommodate common tensor shapes"; any step size is supported
//! through [`ladder_with_step`]).

use crate::arch::{DIM_MAX, DIM_MIN};

/// Power-of-two ladder from `DIM_MAX` down to `DIM_MIN`: 256, 128, ..., 4.
pub fn ladder() -> Vec<u64> {
    ladder_with_step(2)
}

/// Dimension ladder with a custom divisor step (>= 2).
pub fn ladder_with_step(step: u64) -> Vec<u64> {
    assert!(step >= 2);
    let mut v = Vec::new();
    let mut d = DIM_MAX;
    while d >= DIM_MIN {
        v.push(d);
        d /= step;
    }
    v
}

/// All `(tc_x, tc_y)` pairs on the ladder, largest area first — the
/// unpruned tensor-core dimension space Algorithm 2 walks.
pub fn tc_dim_space() -> Vec<(u64, u64)> {
    let l = ladder();
    let mut v: Vec<(u64, u64)> = l.iter().flat_map(|&x| l.iter().map(move |&y| (x, y))).collect();
    v.sort_by_key(|&(x, y)| std::cmp::Reverse(x * y));
    v
}

/// Children of a tensor-core dimension in the pruner's tree (Figure 6):
/// halve one side at a time, skipping out-of-range results.
pub fn tc_children((x, y): (u64, u64)) -> Vec<(u64, u64)> {
    let mut out = Vec::with_capacity(2);
    if x / 2 >= DIM_MIN {
        out.push((x / 2, y));
    }
    if y / 2 >= DIM_MIN {
        out.push((x, y / 2));
    }
    out
}

/// Children of a vector-core width (1-D chain).
pub fn vc_children(w: u64) -> Vec<u64> {
    if w / 2 >= DIM_MIN {
        vec![w / 2]
    } else {
        vec![]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_powers_of_two() {
        assert_eq!(ladder(), vec![256, 128, 64, 32, 16, 8, 4]);
    }

    #[test]
    fn custom_step() {
        assert_eq!(ladder_with_step(4), vec![256, 64, 16, 4]);
    }

    #[test]
    fn space_starts_at_largest() {
        let s = tc_dim_space();
        assert_eq!(s[0], (256, 256));
        assert_eq!(s.len(), 49);
    }

    #[test]
    fn children_halve_each_side() {
        assert_eq!(tc_children((256, 256)), vec![(128, 256), (256, 128)]);
        assert_eq!(tc_children((4, 8)), vec![(4, 4)]);
        assert!(tc_children((4, 4)).is_empty());
    }

    #[test]
    fn vc_chain_terminates() {
        assert_eq!(vc_children(8), vec![4]);
        assert!(vc_children(4).is_empty());
    }
}
