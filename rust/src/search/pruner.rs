//! Architecture configuration pruner — paper Algorithm 2 / Figure 6.
//!
//! The dimension space is a tree: the largest dimension at the root,
//! children reduced by the step size. Breadth-first exploration prunes an
//! entire subtree when its children fail to beat the best-so-far; a
//! hysteresis allowance keeps exploring a few levels below a
//! locally-worse child to escape local minima before pruning.
//!
//! The tree node type is generic so the same pruner drives tensor-core
//! dimensions (2-D halving), vector widths (1-D), and the global
//! distributed search's area-ordered config tree (section 5.1).

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;

/// Exploration result over one pruned tree.
#[derive(Debug, Clone)]
pub struct PruneOutcome<N> {
    /// Best node found and its score (higher is better).
    pub best: Option<(N, f64)>,
    /// Every node evaluated, in exploration order.
    pub explored: Vec<(N, f64)>,
    /// Nodes pruned without evaluation (subtree cuts), for Table 3.
    pub pruned_estimate: usize,
}

/// Breadth-first prune (Algorithm 2) with per-node scoring.
///
/// * `roots` — the starting (largest) configuration(s);
/// * `children(n)` — next-level configurations derived from `n`;
/// * `score(n)` — the training metric, higher is better (the paper
///   minimizes runtime; callers pass e.g. negative makespan or
///   throughput);
/// * `hysteresis` — extra levels explored when all children of a node
///   are worse than the node itself.
pub fn prune_tree<N, FC, FS>(
    roots: Vec<N>,
    children: FC,
    mut score: FS,
    hysteresis: u32,
) -> PruneOutcome<N>
where
    N: Clone + Eq + Hash,
    FC: FnMut(&N) -> Vec<N>,
    FS: FnMut(&N) -> f64,
{
    prune_tree_batched(roots, children, |ns: &[N]| ns.iter().map(&mut score).collect(), hysteresis)
}

/// Breadth-first prune scoring whole *sibling groups* per call.
///
/// Identical exploration to [`prune_tree`] — same node order, same
/// pruning decisions — but the evaluator sees each node's fresh children
/// as one slice, which is the unit the engine fans out across threads
/// (per-thread cost backends; see `search/engine.rs`). `score_batch`
/// must return one score per node, in order.
pub fn prune_tree_batched<N, FC, FB>(
    roots: Vec<N>,
    mut children: FC,
    mut score_batch: FB,
    hysteresis: u32,
) -> PruneOutcome<N>
where
    N: Clone + Eq + Hash,
    FC: FnMut(&N) -> Vec<N>,
    FB: FnMut(&[N]) -> Vec<f64>,
{
    let mut seen: HashMap<N, f64> = HashMap::new();
    let mut explored: Vec<(N, f64)> = Vec::new();
    let mut best: Option<(N, f64)> = None;
    let mut pruned_estimate = 0usize;

    // Queue entries carry the hysteresis budget left on their branch.
    let mut queue: VecDeque<(N, u32)> = VecDeque::new();
    // Score the not-yet-seen members of `batch` (first occurrence wins —
    // duplicate dimensions reached via another path are skipped exactly
    // like the per-node walk did) and record them in order. Returns the
    // fresh `(node, score)` pairs.
    let mut eval_batch = |batch: &[N],
                          seen: &mut HashMap<N, f64>,
                          explored: &mut Vec<(N, f64)>,
                          best: &mut Option<(N, f64)>|
     -> Vec<(N, f64)> {
        let mut fresh: Vec<N> = Vec::new();
        for n in batch {
            if !seen.contains_key(n) && !fresh.contains(n) {
                fresh.push(n.clone());
            }
        }
        if fresh.is_empty() {
            return Vec::new();
        }
        let scores = score_batch(&fresh);
        assert_eq!(scores.len(), fresh.len(), "score_batch must return one score per node");
        let out: Vec<(N, f64)> = fresh.into_iter().zip(scores).collect();
        for (n, s) in &out {
            seen.insert(n.clone(), *s);
            explored.push((n.clone(), *s));
            if best.as_ref().map_or(true, |(_, bs)| *s > *bs) {
                *best = Some((n.clone(), *s));
            }
        }
        out
    };

    let _ = eval_batch(&roots, &mut seen, &mut explored, &mut best);
    for r in roots {
        queue.push_back((r, hysteresis));
    }

    while let Some((node, hys_left)) = queue.pop_front() {
        let parent_score = seen[&node];
        let kids = children(&node);
        if kids.is_empty() {
            continue;
        }
        let fresh = eval_batch(&kids, &mut seen, &mut explored, &mut best);
        let any_better = fresh.iter().any(|(_, s)| *s > parent_score);
        if any_better {
            // GetBetterConfigs: only the improving children continue with
            // a refreshed hysteresis budget; the worse siblings' subtrees
            // are pruned.
            for (k, s) in fresh {
                if s > parent_score {
                    queue.push_back((k, hysteresis));
                } else {
                    pruned_estimate += subtree_size_estimate(&k, &mut children);
                }
            }
        } else if hys_left > 0 {
            // All children worse: keep digging for `hysteresis` levels.
            for (k, _) in fresh {
                queue.push_back((k, hys_left - 1));
            }
        } else {
            for (k, _) in fresh {
                pruned_estimate += subtree_size_estimate(&k, &mut children);
            }
        }
    }

    PruneOutcome { best, explored, pruned_estimate }
}

/// Count the nodes a pruned subtree would have contained (bounded walk —
/// used only for Table 3's reporting, not on the search path).
fn subtree_size_estimate<N: Clone + Eq + Hash>(root: &N, children: &mut impl FnMut(&N) -> Vec<N>) -> usize {
    let mut seen = std::collections::HashSet::new();
    let mut stack = vec![root.clone()];
    let mut count = 0usize;
    while let Some(n) = stack.pop() {
        if !seen.insert(n.clone()) || count > 10_000 {
            continue;
        }
        count += 1;
        stack.extend(children(&n));
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::dims;

    /// Score favoring a specific dimension pair — unimodal on the tree.
    fn peaked(target: (u64, u64)) -> impl FnMut(&(u64, u64)) -> f64 {
        move |&(x, y)| {
            let d = |a: u64, b: u64| (a as f64).log2() - (b as f64).log2();
            -(d(x, target.0).abs() + d(y, target.1).abs())
        }
    }

    #[test]
    fn finds_peak_on_unimodal_landscape() {
        let out = prune_tree(vec![(256u64, 256u64)], |n| dims::tc_children(*n), peaked((64, 32)), 1);
        assert_eq!(out.best.unwrap().0, (64, 32));
    }

    #[test]
    fn prunes_most_of_space_when_root_is_best() {
        let out = prune_tree(
            vec![(256u64, 256u64)],
            |n| dims::tc_children(*n),
            |&(x, y)| (x * y) as f64, // bigger is always better
            1,
        );
        assert_eq!(out.best.unwrap().0, (256, 256));
        // 49-point space: with hysteresis 1 we explore root + 2 children
        // + grandchildren, far fewer than the full space.
        assert!(out.explored.len() < 20, "explored {}", out.explored.len());
        assert!(out.pruned_estimate > 0);
    }

    #[test]
    fn hysteresis_escapes_local_minimum() {
        // Score dips at level 2 then rises at level 3: hysteresis 0 stops
        // early, hysteresis 2 finds the deep optimum.
        let score = |&(x, y): &(u64, u64)| match (x, y) {
            (256, 256) => 10.0,
            (64, 64) => 50.0,
            _ => 1.0,
        };
        let shallow = prune_tree(vec![(256u64, 256u64)], |n| dims::tc_children(*n), score, 0);
        let deep = prune_tree(vec![(256u64, 256u64)], |n| dims::tc_children(*n), score, 3);
        assert_eq!(shallow.best.unwrap().1, 10.0);
        assert_eq!(deep.best.unwrap().0, (64, 64));
    }

    #[test]
    fn batched_walk_matches_per_node_walk() {
        let per_node =
            prune_tree(vec![(256u64, 256u64)], |n| dims::tc_children(*n), peaked((64, 32)), 2);
        let mut batches = 0usize;
        let mut f = peaked((64, 32));
        let batched = prune_tree_batched(
            vec![(256u64, 256u64)],
            |n| dims::tc_children(*n),
            |ns: &[(u64, u64)]| {
                batches += 1;
                ns.iter().map(&mut f).collect()
            },
            2,
        );
        assert_eq!(per_node.best, batched.best);
        assert_eq!(per_node.explored, batched.explored);
        assert_eq!(per_node.pruned_estimate, batched.pruned_estimate);
        // Whole sibling groups per call: far fewer calls than nodes.
        assert!(batches < per_node.explored.len(), "{batches} batches");
    }

    #[test]
    fn never_evaluates_duplicates() {
        let mut calls = 0usize;
        let _ = prune_tree(
            vec![(256u64, 256u64)],
            |n| dims::tc_children(*n),
            |_| {
                calls += 1;
                1.0 // flat landscape with hysteresis floods everything once
            },
            10,
        );
        assert!(calls <= dims::tc_dim_space().len());
    }

    #[test]
    fn one_dimensional_chain_prunes() {
        let out = prune_tree(
            vec![256u64],
            |&w| dims::vc_children(w),
            |&w| if w == 32 { 5.0 } else { 1.0 },
            2,
        );
        assert_eq!(out.best.unwrap().0, 32);
    }
}
