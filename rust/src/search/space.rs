//! Search-space accounting for paper Table 3.
//!
//! The paper compares the *number of candidate solutions* each technique
//! must consider, excluding per-operator dataflow mapping. Exact
//! magnitudes depend on accounting conventions the paper does not fully
//! specify; we use a transparent decomposition over **unique problem
//! shapes** (distinct `(kind, m, n, k)` rows — repeated layers share a
//! decision, the dedup Spotlight also exploits) and report log10 sizes:
//!
//! * **exhaustive** — full template ranges (Table 2: 253 values per
//!   dimension, 256 per core count) x an independent mapping choice per
//!   unique shape (~6 loop orders);
//! * **ILP unpruned** — power-of-two dimension ladder x core counts
//!   bounded by critical-path parallelism x per-shape start-slot freedom
//!   (~4 positions within the slack window) — the y(v,t) space;
//! * **ILP pruned** — only dimension configs the Algorithm-2 pruner
//!   evaluates; the critical-path analysis pins zero-slack shapes, so
//!   only non-critical shapes keep schedule freedom;
//! * **heuristics unpruned/pruned** — the greedy scheduler replaces
//!   slot freedom with a binary add-core-or-not decision per shape.

use std::collections::HashSet;

use crate::cost::annotate::AnnotatedGraph;
use crate::graph::CoreType;
use crate::sched::asap_alap;

/// log10 sizes for one workload (Table 3 row).
#[derive(Debug, Clone, Copy)]
pub struct SpaceSizes {
    pub exhaustive: f64,
    pub ilp_unpruned: f64,
    pub ilp_pruned: f64,
    pub heur_unpruned: f64,
    pub heur_pruned: f64,
}

/// Power-of-two dimension configs: |ladder|^2 TC dims x |ladder| widths.
fn dim_configs() -> f64 {
    let l = super::dims::ladder().len() as f64;
    l * l * l
}

/// Compute Table 3 sizes. `dims_evaluated` is the number of dimension
/// configs the pruner explored in an actual search run.
pub fn space_sizes(ann: &AnnotatedGraph, dims_evaluated: usize) -> SpaceSizes {
    let cp = asap_alap(ann);
    // Unique problem shapes, and the subset with scheduling slack.
    let mut all: HashSet<(i32, u64, u64, u64)> = HashSet::new();
    let mut noncrit: HashSet<(i32, u64, u64, u64)> = HashSet::new();
    for (v, op) in ann.graph.ops.iter().enumerate() {
        let r = op.kind.cost_row();
        let key = (r.kind, r.m, r.n, r.k);
        all.insert(key);
        if cp.slack[v] > 0 {
            noncrit.insert(key);
        }
    }
    let u = all.len() as f64;
    let u_nc = noncrit.len() as f64;
    let par_t = cp.max_parallelism(ann, CoreType::Tensor).max(1) as f64;
    let par_v = cp.max_parallelism(ann, CoreType::Vector).max(1) as f64;

    // Template ranges (Table 2): 253 values per dim, 256 per count.
    let arch_full = 253f64.log10() * 3.0 + 256f64.log10() * 2.0;
    let exhaustive = arch_full + u * 6f64.log10();

    let ilp_unpruned = dim_configs().log10() + (par_t * par_v).log10() + u * 4f64.log10();
    let ilp_pruned =
        (dims_evaluated.max(1) as f64).log10() + (par_t * par_v).log10() + u_nc * 4f64.log10();

    let heur_unpruned = dim_configs().log10() + (par_t + par_v).log10() + u * 2f64.log10();
    let heur_pruned =
        (dims_evaluated.max(1) as f64).log10() + (par_t + par_v).log10() + u_nc * 2f64.log10();

    SpaceSizes { exhaustive, ilp_unpruned, ilp_pruned, heur_unpruned, heur_pruned }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;
    use crate::cost::Dims;
    use crate::graph::autodiff::{training_graph, Optimizer};

    #[test]
    fn orderings_match_table3() {
        let fwd = crate::models::vision::resnet18(8);
        let g = training_graph(&fwd, Optimizer::SgdMomentum);
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 128, tc_y: 128, vc_w: 128 }, &mut NativeCost);
        let s = space_sizes(&ann, 12);
        assert!(s.exhaustive > s.ilp_unpruned, "{s:?}");
        assert!(s.ilp_unpruned > s.ilp_pruned, "{s:?}");
        assert!(s.ilp_unpruned > s.heur_unpruned, "{s:?}");
        assert!(s.heur_unpruned > s.heur_pruned, "{s:?}");
        assert!(s.heur_pruned > 2.0, "space never collapses to trivial: {s:?}");
    }

    #[test]
    fn pruner_cuts_many_orders() {
        let fwd = crate::models::vision::inception_v3(4);
        let g = training_graph(&fwd, Optimizer::SgdMomentum);
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 128, tc_y: 128, vc_w: 128 }, &mut NativeCost);
        let s = space_sizes(&ann, 12);
        assert!(
            s.heur_unpruned - s.heur_pruned > 3.0,
            "pruner + critical-path pinning must cut several orders: {s:?}"
        );
    }
}
