//! The per-workload WHAM search engine: dimension pruning (Algorithm 2)
//! around the MCR core-count heuristic (Algorithm 1) or the exact B&B
//! "ILP", producing the best design, a top-k set for the global
//! distributed search, and a convergence log for Figures 1 and 8.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::ilp::ilp_search;
use super::mcr::mcr;
use super::pruner::prune_tree;
use super::{dims, DesignPoint, TopK};
use crate::api::progress::{NullSink, Progress, ProgressSink};
use crate::arch::{ArchConfig, Constraints, DIM_MAX};
use crate::cost::annotate::AnnotatedGraph;
use crate::cost::{CostBackend, Dims};
use crate::metrics::{evaluate, Metric};
use crate::graph::OperatorGraph;
use crate::sched::{asap_alap, greedy_schedule, CoreCount};

/// Search configuration.
#[derive(Debug, Clone, Copy)]
pub struct SearchOptions {
    pub metric: Metric,
    pub constraints: Constraints,
    /// Throughput floor for [`Metric::PerfPerTdp`] (samples/s).
    pub min_throughput: f64,
    /// Designs retained per workload for the global search (section 5.1).
    pub top_k: usize,
    /// Pruner hysteresis levels (Algorithm 2).
    pub hysteresis: u32,
    /// Use the exact B&B "ILP" instead of the MCR heuristics.
    pub use_ilp: bool,
    /// Node budget for the exact solver.
    pub ilp_node_budget: u64,
}

impl Default for SearchOptions {
    fn default() -> Self {
        Self {
            metric: Metric::Throughput,
            constraints: Constraints::default(),
            min_throughput: 0.0,
            top_k: 10,
            hysteresis: 1,
            use_ilp: false,
            ilp_node_budget: 1_000_000,
        }
    }
}

/// Outcome of one workload search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: DesignPoint,
    pub top: TopK,
    /// Every design point evaluated, in exploration order (Fig. 1 data).
    pub explored: Vec<DesignPoint>,
    /// `<TC-Dim, VC-Width>` combinations evaluated.
    pub dims_evaluated: usize,
    /// Greedy-scheduler / B&B invocations — the convergence-cost unit.
    pub scheduler_evals: usize,
    /// Design points served by the [`EvalCache`] instead of a fresh
    /// scheduler run (0 on cold runs; on a warm shared database every
    /// point can be a hit and `scheduler_evals` drops to 0).
    pub cache_hits: usize,
    /// Wall-clock of the whole search.
    pub wall: Duration,
    /// (elapsed, best-score-so-far) log for convergence plots (Fig. 8).
    pub trajectory: Vec<(Duration, f64)>,
    /// True when a [`ProgressSink`] cancelled the search cooperatively
    /// (deadline hit, client gone): `best`/`top` are best-so-far, not
    /// the full exploration's.
    pub cancelled: bool,
}

/// Memoization layer for per-`Dims` design-point evaluations.
///
/// [`WhamSearch::run`] uses a private per-run `HashMap`; the long-running
/// service substitutes a process-wide, persistent design database
/// ([`crate::service::cache::DesignDb`]) so repeat searches over the same
/// workload skip the scheduler entirely. Implementations must only be
/// consulted for a fixed evaluation context (same graph, batch, metric,
/// floor, constraints, and backend) — keying by that context is the
/// *caller's* job, which keeps the engine oblivious to key layout.
pub trait EvalCache {
    /// Cached point for these dims, if any.
    fn get(&mut self, d: &Dims) -> Option<DesignPoint>;
    /// Record a freshly evaluated point.
    fn put(&mut self, d: Dims, p: DesignPoint);
}

/// The default private per-run cache.
impl EvalCache for HashMap<Dims, DesignPoint> {
    fn get(&mut self, d: &Dims) -> Option<DesignPoint> {
        HashMap::get(self, d).copied()
    }
    fn put(&mut self, d: Dims, p: DesignPoint) {
        self.insert(d, p);
    }
}

/// Hands out an [`EvalCache`] scoped to one evaluation context. Lets the
/// distributed global search thread a shared design database through its
/// internal per-stage local searches without depending on the service
/// layer (see [`crate::distributed::global_search::global_search_cached`]).
pub trait CacheProvider {
    /// Cache scoped to `(graph, batch, opts, backend)`.
    fn cache_for<'a>(
        &'a self,
        graph: &OperatorGraph,
        batch: u64,
        opts: &SearchOptions,
        backend: &str,
    ) -> Box<dyn EvalCache + 'a>;
}

/// Provider used when no shared database is attached: every search gets
/// a fresh private map.
pub struct NoSharedCache;

impl CacheProvider for NoSharedCache {
    fn cache_for<'a>(
        &'a self,
        _graph: &OperatorGraph,
        _batch: u64,
        _opts: &SearchOptions,
        _backend: &str,
    ) -> Box<dyn EvalCache + 'a> {
        Box::new(HashMap::<Dims, DesignPoint>::new())
    }
}

/// WHAM per-workload search (paper Figure 4).
pub struct WhamSearch<'a> {
    pub graph: &'a OperatorGraph,
    /// Samples per training iteration (Table 4 batch size).
    pub batch: u64,
    pub opts: SearchOptions,
}

impl<'a> WhamSearch<'a> {
    /// New search over a training graph.
    pub fn new(graph: &'a OperatorGraph, batch: u64, opts: SearchOptions) -> Self {
        Self { graph, batch, opts }
    }

    /// Run the full two-phase dimension search with a private per-run
    /// cache (one-shot CLI behavior).
    pub fn run(&self, backend: &mut dyn CostBackend) -> SearchResult {
        let mut local: HashMap<Dims, DesignPoint> = HashMap::new();
        self.run_cached(backend, &mut local)
    }

    /// [`WhamSearch::run_with`] without progress observation.
    pub fn run_cached(
        &self,
        backend: &mut dyn CostBackend,
        cache: &mut dyn EvalCache,
    ) -> SearchResult {
        self.run_with(backend, cache, &mut NullSink)
    }

    /// Run the full two-phase dimension search:
    /// 1. prune tensor-core dims with the vector width at max;
    /// 2. prune vector width at the winning tensor dims.
    /// Each dimension evaluation runs MCR (or B&B) to pick core counts,
    /// consulting `cache` first — with a warm shared design database the
    /// whole search completes without a single scheduler invocation.
    /// Every evaluated point is reported to `sink`; a `false` return
    /// cancels cooperatively (remaining dims are skipped and the result
    /// is flagged [`SearchResult::cancelled`]).
    pub fn run_with(
        &self,
        backend: &mut dyn CostBackend,
        cache: &mut dyn EvalCache,
        sink: &mut dyn ProgressSink,
    ) -> SearchResult {
        let t0 = Instant::now();
        // Intra-run memo: the pruner revisits dims (phase 2 starts at the
        // phase-1 winner); those repeats are neither fresh evaluations nor
        // cache hits.
        let mut seen: HashMap<Dims, f64> = HashMap::new();
        let mut explored: Vec<DesignPoint> = Vec::new();
        let mut top = TopK::new(self.opts.top_k);
        let mut trajectory: Vec<(Duration, f64)> = Vec::new();
        let mut scheduler_evals = 0usize;
        let mut cache_hits = 0usize;
        let mut cancelled = false;

        {
            let mut eval_dims = |d: Dims| -> f64 {
                // After cancellation the pruner's remaining probes are
                // answered with the worst score so it terminates fast
                // without recording phantom evaluations.
                if cancelled {
                    return f64::NEG_INFINITY;
                }
                if let Some(&score) = seen.get(&d) {
                    return score;
                }
                let point = match cache.get(&d) {
                    Some(p) => {
                        cache_hits += 1;
                        p
                    }
                    None => {
                        let (p, evals) = self.evaluate_dims(d, backend);
                        scheduler_evals += evals;
                        cache.put(d, p);
                        p
                    }
                };
                seen.insert(d, point.score);
                explored.push(point);
                top.offer(point);
                let best = top.best().map(|b| b.score).unwrap_or(f64::NEG_INFINITY);
                trajectory.push((t0.elapsed(), best));
                let go = sink.on_progress(&Progress {
                    phase: "search",
                    elapsed: t0.elapsed(),
                    points: explored.len(),
                    best_score: best,
                });
                if !go {
                    cancelled = true;
                }
                point.score
            };

            // Phase 1: tensor dims, vector width fixed at the maximum.
            let p1 = prune_tree(
                vec![(DIM_MAX, DIM_MAX)],
                |n| dims::tc_children(*n),
                |&(x, y)| eval_dims(Dims { tc_x: x, tc_y: y, vc_w: DIM_MAX }),
                self.opts.hysteresis,
            );
            let (bx, by) = p1.best.expect("phase 1 explored at least the root").0;

            // Phase 2: vector width at the winning tensor dims.
            let _p2 = prune_tree(
                vec![DIM_MAX],
                |&w| dims::vc_children(w),
                |&w| eval_dims(Dims { tc_x: bx, tc_y: by, vc_w: w }),
                self.opts.hysteresis,
            );
        }

        let best = *top.best().expect("search evaluated at least one point");
        SearchResult {
            best,
            top,
            dims_evaluated: explored.len(),
            explored,
            scheduler_evals,
            cache_hits,
            wall: t0.elapsed(),
            trajectory,
            cancelled,
        }
    }

    /// Evaluate one `<TC-Dim, VC-Width>`: annotate, pick core counts,
    /// schedule, score. Returns the design point and scheduler-eval count.
    fn evaluate_dims(&self, d: Dims, backend: &mut dyn CostBackend) -> (DesignPoint, usize) {
        let ann = AnnotatedGraph::new(self.graph, d, backend);
        let energy = ann.total_energy_pj();
        let mk_point = |cores: CoreCount, makespan: u64| -> DesignPoint {
            let config = ArchConfig {
                num_tc: cores.tc,
                tc_x: d.tc_x,
                tc_y: d.tc_y,
                num_vc: cores.vc,
                vc_w: d.vc_w,
            };
            let eval = evaluate(&config, makespan, self.batch, energy);
            let score = self.opts.metric.score(&eval, self.opts.min_throughput);
            DesignPoint { config, eval, score }
        };
        if self.opts.use_ilp {
            let out = ilp_search(&ann, &self.opts.constraints, self.opts.ilp_node_budget);
            (mk_point(out.cores, out.makespan), out.nodes.max(1) as usize)
        } else {
            // Score every accepted point of the MCR trajectory: under
            // Perf/TDP the most efficient design is often an intermediate
            // core count (paper: "maximize Perf/TDP while maintaining a
            // minimum throughput").
            let out = mcr(&ann, &self.opts.constraints);
            let best = out
                .trajectory
                .iter()
                .map(|&(c, ms)| mk_point(c, ms))
                .max_by(|a, b| a.score.total_cmp(&b.score))
                .expect("trajectory is non-empty");
            (best, out.evals)
        }
    }
}

/// Evaluate a *given* design (e.g. TPUv2, NVDLA, or a baseline-framework
/// suggestion) on a workload: annotate at its dims, greedy-schedule at
/// its core counts, and report the full evaluation.
pub fn evaluate_design(
    graph: &OperatorGraph,
    batch: u64,
    config: &ArchConfig,
    backend: &mut dyn CostBackend,
) -> crate::metrics::Evaluation {
    let ann = AnnotatedGraph::new(graph, Dims::of(config), backend);
    let cp = asap_alap(&ann);
    let sched = greedy_schedule(&ann, &cp, CoreCount { tc: config.num_tc, vc: config.num_vc });
    evaluate(config, sched.makespan, batch, ann.total_energy_pj())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::native::NativeCost;
    use crate::graph::autodiff::{training_graph, Optimizer};

    fn bert1_graph() -> OperatorGraph {
        let fwd = crate::models::transformer::forward_range(&crate::models::transformer::bert_base(), 0, 1);
        training_graph(&fwd, Optimizer::SgdMomentum)
    }

    #[test]
    fn search_produces_valid_design() {
        let g = bert1_graph();
        let s = WhamSearch::new(&g, 4, SearchOptions::default());
        let r = s.run(&mut NativeCost);
        assert!(r.best.config.in_template());
        assert!(SearchOptions::default().constraints.allows(&r.best.config));
        assert!(r.dims_evaluated >= 3, "explored {}", r.dims_evaluated);
        assert!(!r.top.is_empty());
    }

    #[test]
    fn search_beats_or_ties_tpuv2_on_throughput() {
        let g = bert1_graph();
        let r = WhamSearch::new(&g, 4, SearchOptions::default()).run(&mut NativeCost);
        let tpu = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        assert!(
            r.best.eval.throughput >= tpu.throughput * 0.99,
            "wham {} vs tpu {}",
            r.best.eval.throughput,
            tpu.throughput
        );
    }

    #[test]
    fn trajectory_is_monotone_nondecreasing() {
        let g = bert1_graph();
        let r = WhamSearch::new(&g, 4, SearchOptions::default()).run(&mut NativeCost);
        for w in r.trajectory.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn perf_tdp_metric_respects_floor() {
        let g = bert1_graph();
        let tpu = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        let opts = SearchOptions {
            metric: Metric::PerfPerTdp,
            min_throughput: tpu.throughput,
            ..Default::default()
        };
        let r = WhamSearch::new(&g, 4, opts).run(&mut NativeCost);
        assert!(
            r.best.eval.throughput >= tpu.throughput * 0.99,
            "floor violated: {} < {}",
            r.best.eval.throughput,
            tpu.throughput
        );
        assert!(r.best.eval.perf_per_tdp >= tpu.perf_per_tdp);
    }

    #[test]
    fn ilp_mode_runs_on_small_graph() {
        let mut b = crate::graph::GraphBuilder::new();
        let a = b.gemm("a", 64, 64, 64, &[]);
        let x = b.gemm("x", 64, 64, 64, &[a]);
        let y = b.gemm("y", 64, 64, 64, &[a]);
        let _j = b.gemm("j", 64, 64, 64, &[x, y]);
        let g = b.finish();
        let opts = SearchOptions { use_ilp: true, ilp_node_budget: 100_000, ..Default::default() };
        let r = WhamSearch::new(&g, 1, opts).run(&mut NativeCost);
        assert!(r.best.config.num_tc >= 1);
    }

    #[test]
    fn warm_cache_skips_every_scheduler_eval() {
        let g = bert1_graph();
        let s = WhamSearch::new(&g, 4, SearchOptions::default());
        let mut shared: HashMap<Dims, DesignPoint> = HashMap::new();
        let cold = s.run_cached(&mut NativeCost, &mut shared);
        assert_eq!(cold.cache_hits, 0);
        assert!(cold.scheduler_evals > 0);
        let warm = s.run_cached(&mut NativeCost, &mut shared);
        assert_eq!(warm.scheduler_evals, 0, "warm run re-ran the scheduler");
        assert_eq!(warm.cache_hits, warm.dims_evaluated);
        assert_eq!(warm.best.config, cold.best.config);
        assert_eq!(warm.dims_evaluated, cold.dims_evaluated);
    }

    #[test]
    fn sink_cancellation_returns_best_so_far() {
        let g = bert1_graph();
        let s = WhamSearch::new(&g, 4, SearchOptions::default());
        let full = s.run(&mut NativeCost);
        assert!(!full.cancelled);

        let mut cache: HashMap<Dims, DesignPoint> = HashMap::new();
        let mut calls = 0usize;
        let mut sink = |_: &crate::api::progress::Progress| {
            calls += 1;
            calls < 2
        };
        let r = s.run_with(&mut NativeCost, &mut cache, &mut sink);
        assert!(r.cancelled, "sink returned false, search must flag cancellation");
        assert_eq!(r.dims_evaluated, 2, "no evaluations after the cancel signal");
        assert!(full.dims_evaluated > r.dims_evaluated);
        assert!(r.best.config.in_template());
    }

    #[test]
    fn evaluate_design_is_deterministic() {
        let g = bert1_graph();
        let a = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        let b = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        assert_eq!(a.cycles, b.cycles);
    }
}
