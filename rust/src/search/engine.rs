//! The per-workload WHAM search engine: dimension pruning (Algorithm 2)
//! around the MCR core-count heuristic (Algorithm 1) or the exact B&B
//! "ILP", producing the best design, a top-k set for the global
//! distributed search, and a convergence log for Figures 1 and 8.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::ilp::ilp_search;
use super::mcr::{mcr_with_scratch, GrowthMode, McrScratch};
use super::pruner::prune_tree_batched;
use super::{dims, DesignPoint, TopK};
use crate::api::progress::{NullSink, Progress, ProgressSink};
use crate::arch::{ArchConfig, Constraints, DIM_MAX};
use crate::cost::annotate::AnnotatedGraph;
use crate::cost::{CostBackend, Dims};
use crate::metrics::{evaluate, Metric};
use crate::graph::OperatorGraph;
use crate::sched::{asap_alap, greedy_schedule, CoreCount};
use crate::telemetry::recorder::{ExplainRecord, FlightRecorder};

/// Search configuration.
#[derive(Debug, Clone, Copy)]
pub struct SearchOptions {
    pub metric: Metric,
    pub constraints: Constraints,
    /// Throughput floor for [`Metric::PerfPerTdp`] (samples/s).
    pub min_throughput: f64,
    /// Designs retained per workload for the global search (section 5.1).
    pub top_k: usize,
    /// Pruner hysteresis levels (Algorithm 2).
    pub hysteresis: u32,
    /// Use the exact B&B "ILP" instead of the MCR heuristics.
    pub use_ilp: bool,
    /// Node budget for the exact solver.
    pub ilp_node_budget: u64,
    /// Worker threads for evaluating pruner siblings concurrently
    /// (`1` = fully serial, the library default; the CLI defaults to
    /// `available_parallelism` via `--jobs`). The fan-out is a pure
    /// prefetch — results, exploration order, and counters are identical
    /// to the serial walk. Not part of the design-DB context key.
    pub jobs: usize,
    /// Force the paper-literal one-core-per-reschedule MCR growth
    /// (ablation / parity knob; Perf/TDP searches use it regardless, to
    /// score every intermediate trajectory point).
    pub mcr_one_at_a_time: bool,
    /// Evaluate the cost backend per-op instead of per cost class
    /// (ablation / parity knob — annotations are bit-identical).
    pub naive_annotation: bool,
    /// Force the legacy schedule-from-scratch MCR probes instead of the
    /// incremental checkpoint-resume engine (ablation / parity oracle —
    /// results are bit-identical, see `rust/tests/hotpath_parity.rs`).
    pub full_reschedule: bool,
}

impl Default for SearchOptions {
    fn default() -> Self {
        Self {
            metric: Metric::Throughput,
            constraints: Constraints::default(),
            min_throughput: 0.0,
            top_k: 10,
            hysteresis: 1,
            use_ilp: false,
            ilp_node_budget: 1_000_000,
            jobs: 1,
            mcr_one_at_a_time: false,
            naive_annotation: false,
            full_reschedule: false,
        }
    }
}

/// Outcome of one workload search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: DesignPoint,
    pub top: TopK,
    /// Every design point evaluated, in exploration order (Fig. 1 data).
    pub explored: Vec<DesignPoint>,
    /// `<TC-Dim, VC-Width>` combinations evaluated.
    pub dims_evaluated: usize,
    /// Greedy-scheduler / B&B invocations — the convergence-cost unit.
    pub scheduler_evals: usize,
    /// Design points served by the [`EvalCache`] instead of a fresh
    /// scheduler run (0 on cold runs; on a warm shared database every
    /// point can be a hit and `scheduler_evals` drops to 0).
    pub cache_hits: usize,
    /// Wall-clock of the whole search.
    pub wall: Duration,
    /// (elapsed, best-score-so-far) log for convergence plots (Fig. 8).
    pub trajectory: Vec<(Duration, f64)>,
    /// True when a [`ProgressSink`] cancelled the search cooperatively
    /// (deadline hit, client gone): `best`/`top` are best-so-far, not
    /// the full exploration's.
    pub cancelled: bool,
    /// Flight-recorder log: per-evaluation critical-path attribution in
    /// exploration order, bounded to the most recent
    /// [`FlightRecorder::DEFAULT_CAP`] entries. Pure observation — the
    /// search result is bit-identical with or without a reader.
    pub explain: Vec<ExplainRecord>,
}

/// Memoization layer for per-`Dims` design-point evaluations.
///
/// [`WhamSearch::run`] uses a private per-run `HashMap`; the long-running
/// service substitutes a process-wide, persistent design database
/// ([`crate::service::cache::DesignDb`]) so repeat searches over the same
/// workload skip the scheduler entirely. Implementations must only be
/// consulted for a fixed evaluation context (same graph, batch, metric,
/// floor, constraints, and backend) — keying by that context is the
/// *caller's* job, which keeps the engine oblivious to key layout.
pub trait EvalCache {
    /// Cached point for these dims, if any.
    fn get(&mut self, d: &Dims) -> Option<DesignPoint>;
    /// Record a freshly evaluated point.
    fn put(&mut self, d: Dims, p: DesignPoint);
}

/// The default private per-run cache.
impl EvalCache for HashMap<Dims, DesignPoint> {
    fn get(&mut self, d: &Dims) -> Option<DesignPoint> {
        HashMap::get(self, d).copied()
    }
    fn put(&mut self, d: Dims, p: DesignPoint) {
        self.insert(d, p);
    }
}

/// Hands out an [`EvalCache`] scoped to one evaluation context. Lets the
/// distributed global search thread a shared design database through its
/// internal per-stage local searches without depending on the service
/// layer (see [`crate::distributed::global_search::global_search_cached`]).
///
/// `Sync` is a supertrait: the global search fans its per-stage local
/// searches out across threads, each obtaining its cache from the shared
/// provider behind a mutex (implementors like the design database are
/// internally locked anyway).
pub trait CacheProvider: Sync {
    /// Cache scoped to `(graph, batch, opts, backend)`.
    fn cache_for<'a>(
        &'a self,
        graph: &OperatorGraph,
        batch: u64,
        opts: &SearchOptions,
        backend: &str,
    ) -> Box<dyn EvalCache + 'a>;
}

/// Provider used when no shared database is attached: every search gets
/// a fresh private map.
pub struct NoSharedCache;

impl CacheProvider for NoSharedCache {
    fn cache_for<'a>(
        &'a self,
        _graph: &OperatorGraph,
        _batch: u64,
        _opts: &SearchOptions,
        _backend: &str,
    ) -> Box<dyn EvalCache + 'a> {
        Box::new(HashMap::<Dims, DesignPoint>::new())
    }
}

/// Attribution of one dims evaluation, fed to the flight recorder:
/// where the MCR loop granted cores and which operator conflicted last.
/// Empty (`Default`) for cache hits and exact-solver runs.
#[derive(Debug, Clone, Default)]
pub struct EvalAttribution {
    /// Cores granted per conflicted class (tensor, vector, fused units).
    pub grants: (u64, u64, u64),
    /// Name of the last operator whose critical conflict MCR resolved.
    pub conflict_op: Option<String>,
}

/// WHAM per-workload search (paper Figure 4).
pub struct WhamSearch<'a> {
    pub graph: &'a OperatorGraph,
    /// Samples per training iteration (Table 4 batch size).
    pub batch: u64,
    pub opts: SearchOptions,
}

impl<'a> WhamSearch<'a> {
    /// New search over a training graph.
    pub fn new(graph: &'a OperatorGraph, batch: u64, opts: SearchOptions) -> Self {
        Self { graph, batch, opts }
    }

    /// Run the full two-phase dimension search with a private per-run
    /// cache (one-shot CLI behavior).
    pub fn run(&self, backend: &mut dyn CostBackend) -> SearchResult {
        let mut local: HashMap<Dims, DesignPoint> = HashMap::new();
        self.run_cached(backend, &mut local)
    }

    /// [`WhamSearch::run_with`] without progress observation.
    pub fn run_cached(
        &self,
        backend: &mut dyn CostBackend,
        cache: &mut dyn EvalCache,
    ) -> SearchResult {
        self.run_with(backend, cache, &mut NullSink)
    }

    /// Run the full two-phase dimension search:
    /// 1. prune tensor-core dims with the vector width at max;
    /// 2. prune vector width at the winning tensor dims.
    /// Each dimension evaluation runs MCR (or B&B) to pick core counts,
    /// consulting `cache` first — with a warm shared design database the
    /// whole search completes without a single scheduler invocation.
    /// Every evaluated point is reported to `sink`; a `false` return
    /// cancels cooperatively (remaining dims are skipped and the result
    /// is flagged [`SearchResult::cancelled`]).
    pub fn run_with(
        &self,
        backend: &mut dyn CostBackend,
        cache: &mut dyn EvalCache,
        sink: &mut dyn ProgressSink,
    ) -> SearchResult {
        let t0 = Instant::now();
        // Intra-run memo: the pruner revisits dims (phase 2 starts at the
        // phase-1 winner); those repeats are neither fresh evaluations nor
        // cache hits.
        let mut seen: HashMap<Dims, f64> = HashMap::new();
        let mut explored: Vec<DesignPoint> = Vec::new();
        let mut top = TopK::new(self.opts.top_k);
        let mut trajectory: Vec<(Duration, f64)> = Vec::new();
        let mut scheduler_evals = 0usize;
        let mut cache_hits = 0usize;
        let mut cancelled = false;
        let mut recorder = FlightRecorder::new(FlightRecorder::DEFAULT_CAP);
        // MCR scratch shared by every serial dims evaluation of this run:
        // the critical-path cache repropagates only the cycle-cone that
        // changed between dims candidates, and the incremental scheduler
        // reuses its buffers. Parallel prefetch workers own one each.
        let mut mcr_scratch = McrScratch::new();
        // Which pruning phase is running (1 = tensor dims, 2 = vector
        // width) — reported as `Progress::depth`. A `Cell` because the
        // batch closure below holds a shared borrow across both phases.
        let phase = std::cell::Cell::new(1usize);

        {
            // Per-slot outcome of the probe pass over one sibling batch.
            enum Slot {
                /// Engine-level repeat (phase 2 revisits phase-1 dims):
                /// neither a fresh evaluation nor a cache hit.
                Known(f64),
                /// Served by the [`EvalCache`].
                Hit(DesignPoint),
                /// Needs a scheduler evaluation.
                Miss,
            }
            let mut eval_batch = |ds: &[Dims]| -> Vec<f64> {
                // After cancellation the pruner's remaining probes are
                // answered with the worst score so it terminates fast
                // without recording phantom evaluations.
                if cancelled {
                    return vec![f64::NEG_INFINITY; ds.len()];
                }
                let _span = crate::telemetry::trace::span("prune_batch")
                    .arg("siblings", ds.len())
                    .arg("phase", phase.get());
                // Probe pass: exactly one engine-seen / cache lookup per
                // dims (the cache probe feeds the design-DB hit/miss
                // counters, so it must not repeat).
                let slots: Vec<Slot> = ds
                    .iter()
                    .map(|d| {
                        if let Some(&score) = seen.get(d) {
                            Slot::Known(score)
                        } else {
                            match cache.get(d) {
                                Some(p) => Slot::Hit(p),
                                None => Slot::Miss,
                            }
                        }
                    })
                    .collect();
                // Parallel prefetch (tentpole 3): evaluate this sibling
                // group's misses concurrently, each worker on its own
                // backend (PJRT clients are not `Sync` — the coordinator's
                // policy). The threads only warm a private map; all
                // bookkeeping below stays serial and in batch order, so
                // results are bit-identical to the jobs=1 walk.
                let mut prefetched: HashMap<Dims, (DesignPoint, usize, EvalAttribution)> =
                    HashMap::new();
                let misses: Vec<Dims> = ds
                    .iter()
                    .zip(&slots)
                    .filter(|(_, s)| matches!(s, Slot::Miss))
                    .map(|(d, _)| *d)
                    .collect();
                // Native only: workers build a fresh backend per sibling
                // batch, which is free for `NativeCost` but would repeat
                // the PJRT client + artifact load dozens of times per
                // search (PJRT fan-out happens one level up, in the
                // global search, where construction is per worker per
                // phase).
                if self.opts.jobs > 1
                    && misses.len() > 1
                    && backend.name().parse::<crate::coordinator::BackendChoice>()
                        == Ok(crate::coordinator::BackendChoice::Native)
                {
                    prefetched =
                        self.prefetch_parallel(&misses, crate::coordinator::BackendChoice::Native);
                }
                // Record pass: serial, in batch order — identical
                // explored order, trajectory, and cancellation points to
                // the per-node walk.
                let mut scores = Vec::with_capacity(ds.len());
                for (d, slot) in ds.iter().zip(slots) {
                    if cancelled {
                        scores.push(f64::NEG_INFINITY);
                        continue;
                    }
                    let (point, iter_evals, attr, hit) = match slot {
                        Slot::Known(score) => {
                            scores.push(score);
                            continue;
                        }
                        Slot::Hit(p) => {
                            cache_hits += 1;
                            (p, 0usize, EvalAttribution::default(), true)
                        }
                        Slot::Miss => {
                            let (p, evals, attr) = match prefetched.remove(d) {
                                Some(r) => r,
                                None => self.evaluate_dims(*d, backend, &mut mcr_scratch),
                            };
                            scheduler_evals += evals;
                            cache.put(*d, p);
                            (p, evals, attr, false)
                        }
                    };
                    seen.insert(*d, point.score);
                    explored.push(point);
                    let prev_best = top.best().map(|b| b.score).unwrap_or(f64::NEG_INFINITY);
                    top.offer(point);
                    let best = top.best().map(|b| b.score).unwrap_or(f64::NEG_INFINITY);
                    recorder.push(ExplainRecord {
                        dims: *d,
                        score: point.score,
                        best,
                        improved: best > prev_best,
                        cache_hit: hit,
                        evals: iter_evals as u64,
                        cores: (point.config.num_tc, point.config.num_vc),
                        grants: attr.grants,
                        conflict_op: attr.conflict_op,
                    });
                    let elapsed = t0.elapsed();
                    trajectory.push((elapsed, best));
                    let go = sink.on_progress(&Progress {
                        phase: "search",
                        elapsed,
                        points: explored.len(),
                        best_score: best,
                        rate: Progress::rate_of(explored.len(), elapsed),
                        depth: phase.get(),
                    });
                    if !go {
                        cancelled = true;
                    }
                    scores.push(point.score);
                }
                scores
            };

            // Phase 1: tensor dims, vector width fixed at the maximum.
            let p1 = {
                let _span = crate::telemetry::trace::span("search_phase").arg("phase", 1);
                prune_tree_batched(
                    vec![(DIM_MAX, DIM_MAX)],
                    |n| dims::tc_children(*n),
                    |ns: &[(u64, u64)]| {
                        let ds: Vec<Dims> = ns
                            .iter()
                            .map(|&(x, y)| Dims { tc_x: x, tc_y: y, vc_w: DIM_MAX })
                            .collect();
                        eval_batch(&ds)
                    },
                    self.opts.hysteresis,
                )
            };
            let (bx, by) = p1.best.expect("phase 1 explored at least the root").0;

            // Phase 2: vector width at the winning tensor dims.
            phase.set(2);
            let _p2 = {
                let _span = crate::telemetry::trace::span("search_phase").arg("phase", 2);
                prune_tree_batched(
                    vec![DIM_MAX],
                    |&w| dims::vc_children(w),
                    |ws: &[u64]| {
                        let ds: Vec<Dims> =
                            ws.iter().map(|&w| Dims { tc_x: bx, tc_y: by, vc_w: w }).collect();
                        eval_batch(&ds)
                    },
                    self.opts.hysteresis,
                )
            };
        }

        let best = *top.best().expect("search evaluated at least one point");
        SearchResult {
            best,
            top,
            dims_evaluated: explored.len(),
            explored,
            scheduler_evals,
            cache_hits,
            wall: t0.elapsed(),
            trajectory,
            cancelled,
            explain: recorder.into_records(),
        }
    }

    /// Evaluate `ds` concurrently on up to `opts.jobs` threads, each with
    /// its own cost backend built from `choice` (the coordinator's
    /// per-thread-backend pattern). Returns whatever finished; on
    /// backend-construction failure the map is simply incomplete and the
    /// caller evaluates the rest on its own backend.
    fn prefetch_parallel(
        &self,
        ds: &[Dims],
        choice: crate::coordinator::BackendChoice,
    ) -> HashMap<Dims, (DesignPoint, usize, EvalAttribution)> {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let workers = self.opts.jobs.min(ds.len());
        let next = AtomicUsize::new(0);
        let results: Vec<std::sync::Mutex<Option<(DesignPoint, usize, EvalAttribution)>>> =
            (0..ds.len()).map(|_| std::sync::Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    let Ok(mut backend) = crate::coordinator::make_backend(choice) else {
                        return;
                    };
                    let mut scratch = McrScratch::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= ds.len() {
                            break;
                        }
                        let out = self.evaluate_dims(ds[i], backend.as_mut(), &mut scratch);
                        *results[i].lock().unwrap() = Some(out);
                    }
                });
            }
        });
        ds.iter()
            .zip(results)
            .filter_map(|(d, r)| r.into_inner().unwrap().map(|out| (*d, out)))
            .collect()
    }

    /// Evaluate one `<TC-Dim, VC-Width>`: annotate, pick core counts,
    /// schedule, score. Returns the design point, the scheduler-eval
    /// count, and the flight-recorder attribution.
    fn evaluate_dims(
        &self,
        d: Dims,
        backend: &mut dyn CostBackend,
        scratch: &mut McrScratch,
    ) -> (DesignPoint, usize, EvalAttribution) {
        let ann = if self.opts.naive_annotation {
            AnnotatedGraph::new_naive(self.graph, d, backend)
        } else {
            AnnotatedGraph::new(self.graph, d, backend)
        };
        let energy = ann.total_energy_pj();
        let mk_point = |cores: CoreCount, makespan: u64| -> DesignPoint {
            let config = ArchConfig {
                num_tc: cores.tc,
                tc_x: d.tc_x,
                tc_y: d.tc_y,
                num_vc: cores.vc,
                vc_w: d.vc_w,
            };
            let eval = evaluate(&config, makespan, self.batch, energy);
            let score = self.opts.metric.score(&eval, self.opts.min_throughput);
            DesignPoint { config, eval, score }
        };
        if self.opts.use_ilp {
            let out = ilp_search(&ann, &self.opts.constraints, self.opts.ilp_node_budget);
            (mk_point(out.cores, out.makespan), out.nodes.max(1) as usize, EvalAttribution::default())
        } else {
            // Score every accepted point of the MCR trajectory: under
            // Perf/TDP the most efficient design is often an intermediate
            // core count (paper: "maximize Perf/TDP while maintaining a
            // minimum throughput") — which is also why Perf/TDP keeps the
            // one-at-a-time growth (gallop skips intermediate points).
            let mode = if self.opts.mcr_one_at_a_time || self.opts.metric == Metric::PerfPerTdp {
                GrowthMode::OneAtATime
            } else {
                GrowthMode::Gallop
            };
            let out = mcr_with_scratch(
                &ann,
                &self.opts.constraints,
                mode,
                scratch,
                self.opts.full_reschedule,
            );
            let best = out
                .trajectory
                .iter()
                .map(|&(c, ms)| mk_point(c, ms))
                .max_by(|a, b| a.score.total_cmp(&b.score))
                .expect("trajectory is non-empty");
            let attr = EvalAttribution {
                grants: out.grants,
                conflict_op: out.last_conflict.map(|v| self.graph.ops[v].name.clone()),
            };
            (best, out.evals, attr)
        }
    }
}

/// Evaluate a *given* design (e.g. TPUv2, NVDLA, or a baseline-framework
/// suggestion) on a workload: annotate at its dims, greedy-schedule at
/// its core counts, and report the full evaluation.
pub fn evaluate_design(
    graph: &OperatorGraph,
    batch: u64,
    config: &ArchConfig,
    backend: &mut dyn CostBackend,
) -> crate::metrics::Evaluation {
    let ann = AnnotatedGraph::new(graph, Dims::of(config), backend);
    let cp = asap_alap(&ann);
    let sched = greedy_schedule(&ann, &cp, CoreCount { tc: config.num_tc, vc: config.num_vc });
    evaluate(config, sched.makespan, batch, ann.total_energy_pj())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::native::NativeCost;
    use crate::graph::autodiff::{training_graph, Optimizer};

    fn bert1_graph() -> OperatorGraph {
        let fwd = crate::models::transformer::forward_range(&crate::models::transformer::bert_base(), 0, 1);
        training_graph(&fwd, Optimizer::SgdMomentum)
    }

    #[test]
    fn search_produces_valid_design() {
        let g = bert1_graph();
        let s = WhamSearch::new(&g, 4, SearchOptions::default());
        let r = s.run(&mut NativeCost);
        assert!(r.best.config.in_template());
        assert!(SearchOptions::default().constraints.allows(&r.best.config));
        assert!(r.dims_evaluated >= 3, "explored {}", r.dims_evaluated);
        assert!(!r.top.is_empty());
    }

    #[test]
    fn search_beats_or_ties_tpuv2_on_throughput() {
        let g = bert1_graph();
        let r = WhamSearch::new(&g, 4, SearchOptions::default()).run(&mut NativeCost);
        let tpu = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        assert!(
            r.best.eval.throughput >= tpu.throughput * 0.99,
            "wham {} vs tpu {}",
            r.best.eval.throughput,
            tpu.throughput
        );
    }

    #[test]
    fn trajectory_is_monotone_nondecreasing() {
        let g = bert1_graph();
        let r = WhamSearch::new(&g, 4, SearchOptions::default()).run(&mut NativeCost);
        for w in r.trajectory.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn perf_tdp_metric_respects_floor() {
        let g = bert1_graph();
        let tpu = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        let opts = SearchOptions {
            metric: Metric::PerfPerTdp,
            min_throughput: tpu.throughput,
            ..Default::default()
        };
        let r = WhamSearch::new(&g, 4, opts).run(&mut NativeCost);
        assert!(
            r.best.eval.throughput >= tpu.throughput * 0.99,
            "floor violated: {} < {}",
            r.best.eval.throughput,
            tpu.throughput
        );
        assert!(r.best.eval.perf_per_tdp >= tpu.perf_per_tdp);
    }

    #[test]
    fn ilp_mode_runs_on_small_graph() {
        let mut b = crate::graph::GraphBuilder::new();
        let a = b.gemm("a", 64, 64, 64, &[]);
        let x = b.gemm("x", 64, 64, 64, &[a]);
        let y = b.gemm("y", 64, 64, 64, &[a]);
        let _j = b.gemm("j", 64, 64, 64, &[x, y]);
        let g = b.finish();
        let opts = SearchOptions { use_ilp: true, ilp_node_budget: 100_000, ..Default::default() };
        let r = WhamSearch::new(&g, 1, opts).run(&mut NativeCost);
        assert!(r.best.config.num_tc >= 1);
    }

    #[test]
    fn parallel_sibling_evaluation_matches_serial() {
        let g = bert1_graph();
        let serial = WhamSearch::new(&g, 4, SearchOptions::default()).run(&mut NativeCost);
        let par = WhamSearch::new(&g, 4, SearchOptions { jobs: 4, ..Default::default() })
            .run(&mut NativeCost);
        assert_eq!(par.best.config, serial.best.config);
        assert_eq!(par.best.score, serial.best.score);
        assert_eq!(par.dims_evaluated, serial.dims_evaluated);
        assert_eq!(par.scheduler_evals, serial.scheduler_evals);
        let s_top: Vec<_> = serial.top.points().iter().map(|p| p.config).collect();
        let p_top: Vec<_> = par.top.points().iter().map(|p| p.config).collect();
        assert_eq!(s_top, p_top, "top-k set must not depend on --jobs");
        for (a, b) in serial.explored.iter().zip(&par.explored) {
            assert_eq!(a.config, b.config, "exploration order must not depend on --jobs");
        }
    }

    #[test]
    fn legacy_knobs_pin_the_fast_paths() {
        // The whole perf pass is outcome-preserving: naive per-op
        // annotation + one-core-at-a-time MCR + schedule-from-scratch
        // probes must land on the same best design as the interned +
        // galloping + incremental defaults, with the legacy path paying
        // at least as many scheduler evals.
        let g = bert1_graph();
        let fast = WhamSearch::new(&g, 4, SearchOptions::default()).run(&mut NativeCost);
        let legacy_opts = SearchOptions {
            mcr_one_at_a_time: true,
            naive_annotation: true,
            full_reschedule: true,
            ..Default::default()
        };
        let legacy = WhamSearch::new(&g, 4, legacy_opts).run(&mut NativeCost);
        assert_eq!(fast.best.config, legacy.best.config);
        assert_eq!(fast.best.eval.cycles, legacy.best.eval.cycles);
        assert_eq!(fast.dims_evaluated, legacy.dims_evaluated);
        assert!(
            fast.scheduler_evals <= legacy.scheduler_evals,
            "gallop must not pay more evals: {} vs {}",
            fast.scheduler_evals,
            legacy.scheduler_evals
        );
    }

    #[test]
    fn warm_cache_skips_every_scheduler_eval() {
        let g = bert1_graph();
        let s = WhamSearch::new(&g, 4, SearchOptions::default());
        let mut shared: HashMap<Dims, DesignPoint> = HashMap::new();
        let cold = s.run_cached(&mut NativeCost, &mut shared);
        assert_eq!(cold.cache_hits, 0);
        assert!(cold.scheduler_evals > 0);
        let warm = s.run_cached(&mut NativeCost, &mut shared);
        assert_eq!(warm.scheduler_evals, 0, "warm run re-ran the scheduler");
        assert_eq!(warm.cache_hits, warm.dims_evaluated);
        assert_eq!(warm.best.config, cold.best.config);
        assert_eq!(warm.dims_evaluated, cold.dims_evaluated);
    }

    #[test]
    fn sink_cancellation_returns_best_so_far() {
        let g = bert1_graph();
        let s = WhamSearch::new(&g, 4, SearchOptions::default());
        let full = s.run(&mut NativeCost);
        assert!(!full.cancelled);

        let mut cache: HashMap<Dims, DesignPoint> = HashMap::new();
        let mut calls = 0usize;
        let mut sink = |_: &crate::api::progress::Progress| {
            calls += 1;
            calls < 2
        };
        let r = s.run_with(&mut NativeCost, &mut cache, &mut sink);
        assert!(r.cancelled, "sink returned false, search must flag cancellation");
        assert_eq!(r.dims_evaluated, 2, "no evaluations after the cancel signal");
        assert!(full.dims_evaluated > r.dims_evaluated);
        assert!(r.best.config.in_template());
    }

    #[test]
    fn flight_recorder_logs_every_evaluation() {
        let g = bert1_graph();
        let s = WhamSearch::new(&g, 4, SearchOptions::default());
        let mut shared: HashMap<Dims, DesignPoint> = HashMap::new();
        let cold = s.run_cached(&mut NativeCost, &mut shared);
        assert_eq!(cold.explain.len(), cold.dims_evaluated.min(FlightRecorder::DEFAULT_CAP));
        assert!(cold.explain.iter().all(|e| !e.cache_hit));
        // The search must attribute at least one core grant somewhere.
        assert!(cold.explain.iter().any(|e| e.grants.0 + e.grants.1 + e.grants.2 > 0));
        // Exactly the improving records raise the running best.
        let mut best = f64::NEG_INFINITY;
        for e in &cold.explain {
            assert!(e.best >= best);
            assert_eq!(e.improved, e.best > best);
            best = e.best;
        }
        // Warm run: every record is a cache hit with no scheduler cost.
        let warm = s.run_cached(&mut NativeCost, &mut shared);
        assert!(warm.explain.iter().all(|e| e.cache_hit && e.evals == 0));
    }

    #[test]
    fn evaluate_design_is_deterministic() {
        let g = bert1_graph();
        let a = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        let b = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        assert_eq!(a.cycles, b.cycles);
    }
}
