//! The per-workload WHAM search engine: dimension pruning (Algorithm 2)
//! around the MCR core-count heuristic (Algorithm 1) or the exact B&B
//! "ILP", producing the best design, a top-k set for the global
//! distributed search, and a convergence log for Figures 1 and 8.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::ilp::ilp_search;
use super::mcr::mcr;
use super::pruner::prune_tree;
use super::{dims, DesignPoint, TopK};
use crate::arch::{ArchConfig, Constraints, DIM_MAX};
use crate::cost::annotate::AnnotatedGraph;
use crate::cost::{CostBackend, Dims};
use crate::metrics::{evaluate, Metric};
use crate::graph::OperatorGraph;
use crate::sched::{asap_alap, greedy_schedule, CoreCount};

/// Search configuration.
#[derive(Debug, Clone, Copy)]
pub struct SearchOptions {
    pub metric: Metric,
    pub constraints: Constraints,
    /// Throughput floor for [`Metric::PerfPerTdp`] (samples/s).
    pub min_throughput: f64,
    /// Designs retained per workload for the global search (section 5.1).
    pub top_k: usize,
    /// Pruner hysteresis levels (Algorithm 2).
    pub hysteresis: u32,
    /// Use the exact B&B "ILP" instead of the MCR heuristics.
    pub use_ilp: bool,
    /// Node budget for the exact solver.
    pub ilp_node_budget: u64,
}

impl Default for SearchOptions {
    fn default() -> Self {
        Self {
            metric: Metric::Throughput,
            constraints: Constraints::default(),
            min_throughput: 0.0,
            top_k: 10,
            hysteresis: 1,
            use_ilp: false,
            ilp_node_budget: 1_000_000,
        }
    }
}

/// Outcome of one workload search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: DesignPoint,
    pub top: TopK,
    /// Every design point evaluated, in exploration order (Fig. 1 data).
    pub explored: Vec<DesignPoint>,
    /// `<TC-Dim, VC-Width>` combinations evaluated.
    pub dims_evaluated: usize,
    /// Greedy-scheduler / B&B invocations — the convergence-cost unit.
    pub scheduler_evals: usize,
    /// Wall-clock of the whole search.
    pub wall: Duration,
    /// (elapsed, best-score-so-far) log for convergence plots (Fig. 8).
    pub trajectory: Vec<(Duration, f64)>,
}

/// WHAM per-workload search (paper Figure 4).
pub struct WhamSearch<'a> {
    pub graph: &'a OperatorGraph,
    /// Samples per training iteration (Table 4 batch size).
    pub batch: u64,
    pub opts: SearchOptions,
}

impl<'a> WhamSearch<'a> {
    /// New search over a training graph.
    pub fn new(graph: &'a OperatorGraph, batch: u64, opts: SearchOptions) -> Self {
        Self { graph, batch, opts }
    }

    /// Run the full two-phase dimension search:
    /// 1. prune tensor-core dims with the vector width at max;
    /// 2. prune vector width at the winning tensor dims.
    /// Each dimension evaluation runs MCR (or B&B) to pick core counts.
    pub fn run(&self, backend: &mut dyn CostBackend) -> SearchResult {
        let t0 = Instant::now();
        let mut cache: HashMap<Dims, DesignPoint> = HashMap::new();
        let mut explored: Vec<DesignPoint> = Vec::new();
        let mut top = TopK::new(self.opts.top_k);
        let mut trajectory: Vec<(Duration, f64)> = Vec::new();
        let mut scheduler_evals = 0usize;

        {
            let mut eval_dims = |d: Dims| -> f64 {
                if let Some(p) = cache.get(&d) {
                    return p.score;
                }
                let (point, evals) = self.evaluate_dims(d, backend);
                scheduler_evals += evals;
                cache.insert(d, point);
                explored.push(point);
                top.offer(point);
                let best = top.best().map(|b| b.score).unwrap_or(f64::NEG_INFINITY);
                trajectory.push((t0.elapsed(), best));
                point.score
            };

            // Phase 1: tensor dims, vector width fixed at the maximum.
            let p1 = prune_tree(
                vec![(DIM_MAX, DIM_MAX)],
                |n| dims::tc_children(*n),
                |&(x, y)| eval_dims(Dims { tc_x: x, tc_y: y, vc_w: DIM_MAX }),
                self.opts.hysteresis,
            );
            let (bx, by) = p1.best.expect("phase 1 explored at least the root").0;

            // Phase 2: vector width at the winning tensor dims.
            let _p2 = prune_tree(
                vec![DIM_MAX],
                |&w| dims::vc_children(w),
                |&w| eval_dims(Dims { tc_x: bx, tc_y: by, vc_w: w }),
                self.opts.hysteresis,
            );
        }

        let best = *top.best().expect("search evaluated at least one point");
        SearchResult {
            best,
            top,
            dims_evaluated: explored.len(),
            explored,
            scheduler_evals,
            wall: t0.elapsed(),
            trajectory,
        }
    }

    /// Evaluate one `<TC-Dim, VC-Width>`: annotate, pick core counts,
    /// schedule, score. Returns the design point and scheduler-eval count.
    fn evaluate_dims(&self, d: Dims, backend: &mut dyn CostBackend) -> (DesignPoint, usize) {
        let ann = AnnotatedGraph::new(self.graph, d, backend);
        let energy = ann.total_energy_pj();
        let mk_point = |cores: CoreCount, makespan: u64| -> DesignPoint {
            let config = ArchConfig {
                num_tc: cores.tc,
                tc_x: d.tc_x,
                tc_y: d.tc_y,
                num_vc: cores.vc,
                vc_w: d.vc_w,
            };
            let eval = evaluate(&config, makespan, self.batch, energy);
            let score = self.opts.metric.score(&eval, self.opts.min_throughput);
            DesignPoint { config, eval, score }
        };
        if self.opts.use_ilp {
            let out = ilp_search(&ann, &self.opts.constraints, self.opts.ilp_node_budget);
            (mk_point(out.cores, out.makespan), out.nodes.max(1) as usize)
        } else {
            // Score every accepted point of the MCR trajectory: under
            // Perf/TDP the most efficient design is often an intermediate
            // core count (paper: "maximize Perf/TDP while maintaining a
            // minimum throughput").
            let out = mcr(&ann, &self.opts.constraints);
            let best = out
                .trajectory
                .iter()
                .map(|&(c, ms)| mk_point(c, ms))
                .max_by(|a, b| a.score.total_cmp(&b.score))
                .expect("trajectory is non-empty");
            (best, out.evals)
        }
    }
}

/// Evaluate a *given* design (e.g. TPUv2, NVDLA, or a baseline-framework
/// suggestion) on a workload: annotate at its dims, greedy-schedule at
/// its core counts, and report the full evaluation.
pub fn evaluate_design(
    graph: &OperatorGraph,
    batch: u64,
    config: &ArchConfig,
    backend: &mut dyn CostBackend,
) -> crate::metrics::Evaluation {
    let ann = AnnotatedGraph::new(graph, Dims::of(config), backend);
    let cp = asap_alap(&ann);
    let sched = greedy_schedule(&ann, &cp, CoreCount { tc: config.num_tc, vc: config.num_vc });
    evaluate(config, sched.makespan, batch, ann.total_energy_pj())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::cost::native::NativeCost;
    use crate::graph::autodiff::{training_graph, Optimizer};

    fn bert1_graph() -> OperatorGraph {
        let fwd = crate::models::transformer::forward_range(&crate::models::transformer::bert_base(), 0, 1);
        training_graph(&fwd, Optimizer::SgdMomentum)
    }

    #[test]
    fn search_produces_valid_design() {
        let g = bert1_graph();
        let s = WhamSearch::new(&g, 4, SearchOptions::default());
        let r = s.run(&mut NativeCost);
        assert!(r.best.config.in_template());
        assert!(SearchOptions::default().constraints.allows(&r.best.config));
        assert!(r.dims_evaluated >= 3, "explored {}", r.dims_evaluated);
        assert!(!r.top.is_empty());
    }

    #[test]
    fn search_beats_or_ties_tpuv2_on_throughput() {
        let g = bert1_graph();
        let r = WhamSearch::new(&g, 4, SearchOptions::default()).run(&mut NativeCost);
        let tpu = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        assert!(
            r.best.eval.throughput >= tpu.throughput * 0.99,
            "wham {} vs tpu {}",
            r.best.eval.throughput,
            tpu.throughput
        );
    }

    #[test]
    fn trajectory_is_monotone_nondecreasing() {
        let g = bert1_graph();
        let r = WhamSearch::new(&g, 4, SearchOptions::default()).run(&mut NativeCost);
        for w in r.trajectory.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn perf_tdp_metric_respects_floor() {
        let g = bert1_graph();
        let tpu = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        let opts = SearchOptions {
            metric: Metric::PerfPerTdp,
            min_throughput: tpu.throughput,
            ..Default::default()
        };
        let r = WhamSearch::new(&g, 4, opts).run(&mut NativeCost);
        assert!(
            r.best.eval.throughput >= tpu.throughput * 0.99,
            "floor violated: {} < {}",
            r.best.eval.throughput,
            tpu.throughput
        );
        assert!(r.best.eval.perf_per_tdp >= tpu.perf_per_tdp);
    }

    #[test]
    fn ilp_mode_runs_on_small_graph() {
        let mut b = crate::graph::GraphBuilder::new();
        let a = b.gemm("a", 64, 64, 64, &[]);
        let x = b.gemm("x", 64, 64, 64, &[a]);
        let y = b.gemm("y", 64, 64, 64, &[a]);
        let _j = b.gemm("j", 64, 64, 64, &[x, y]);
        let g = b.finish();
        let opts = SearchOptions { use_ilp: true, ilp_node_budget: 100_000, ..Default::default() };
        let r = WhamSearch::new(&g, 1, opts).run(&mut NativeCost);
        assert!(r.best.config.num_tc >= 1);
    }

    #[test]
    fn evaluate_design_is_deterministic() {
        let g = bert1_graph();
        let a = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        let b = evaluate_design(&g, 4, &presets::tpuv2(), &mut NativeCost);
        assert_eq!(a.cycles, b.cycles);
    }
}
