//! Exact core-count + schedule co-optimization — the paper's ILP
//! (section 4.4), reproduced as branch-and-bound.
//!
//! The paper solves a time-indexed ILP with Gurobi: decision variables
//! x(c) (cores per type) and y(v,t) (operator start slots), objective
//! lexicographic (iteration time, then area/power), constraints
//! (3) schedule-once, (4) core capacity, (5) precedence. Gurobi is not
//! available offline, so we solve the identical optimization exactly:
//!
//! * outer loop over x(c) — bounded by the critical-path parallelism
//!   limit exactly as the paper bounds its ILP;
//! * inner exact makespan via depth-first branch-and-bound over active
//!   schedules with critical-path ("tail") lower bounds — equivalent to
//!   the y(v,t) solve, without slotted-time discretization error;
//! * the same practical caveat: a node budget substitutes for Gurobi's
//!   wall-clock limit, and exceeding it returns the incumbent flagged
//!   `optimal = false` (the paper's language models hit 7-day timeouts).

use crate::arch::{ArchConfig, Constraints};
use crate::cost::annotate::AnnotatedGraph;
use crate::graph::CoreType;
use crate::sched::{asap_alap, greedy_schedule, CoreCount};

/// Result of the exact search.
#[derive(Debug, Clone)]
pub struct IlpOutcome {
    pub cores: CoreCount,
    pub makespan: u64,
    /// Proven optimal within the node budget.
    pub optimal: bool,
    /// Branch-and-bound nodes visited (the ILP-cost proxy for Fig. 8).
    pub nodes: u64,
}

/// Exact-makespan scheduling is attempted up to this many operators;
/// larger graphs fall back to the greedy bound and report non-optimal,
/// mirroring the paper's ILP timeouts on language models.
pub const EXACT_OP_LIMIT: usize = 48;

/// Solve for the core counts and schedule minimizing iteration time, then
/// area, under `constraints`. `node_budget` bounds total B&B work.
pub fn ilp_search(ann: &AnnotatedGraph, constraints: &Constraints, node_budget: u64) -> IlpOutcome {
    let cp = asap_alap(ann);
    let max_tc = cp.max_parallelism(ann, CoreType::Tensor).max(1);
    let max_vc = cp.max_parallelism(ann, CoreType::Vector).max(1);

    let mut best: Option<(u64, f64, CoreCount)> = None; // (makespan, area, cores)
    let mut optimal = true;
    let mut nodes_total = 0u64;

    'outer: for tc in 1..=max_tc {
        for vc in 1..=max_vc {
            let cfg = ArchConfig {
                num_tc: tc,
                tc_x: ann.dims.tc_x,
                tc_y: ann.dims.tc_y,
                num_vc: vc,
                vc_w: ann.dims.vc_w,
            };
            if !constraints.allows(&cfg) {
                continue;
            }
            let area = crate::arch::area::area_mm2(&cfg);
            // Incumbent from the greedy scheduler (upper bound).
            let greedy = greedy_schedule(ann, &cp, CoreCount { tc, vc }).makespan;
            let (ms, exact, used) = if ann.graph.len() <= EXACT_OP_LIMIT && nodes_total < node_budget {
                let mut bb = BranchBound::new(ann, tc, vc, node_budget - nodes_total);
                let ms = bb.solve(greedy);
                (ms, bb.complete, bb.nodes)
            } else {
                (greedy, false, 0)
            };
            nodes_total += used;
            optimal &= exact;
            let cand = (ms, area, CoreCount { tc, vc });
            let better = match &best {
                None => true,
                Some((bms, barea, _)) => ms < *bms || (ms == *bms && area < *barea),
            };
            if better {
                best = Some(cand);
            }
            // Objective 1 cannot go below the critical path: stop at the
            // bound with the smallest area (we iterate small-to-large).
            if ms == cp.best_latency {
                break 'outer;
            }
        }
    }

    let (makespan, _, cores) = best.expect("at least <1,1> is explored");
    IlpOutcome { cores, makespan, optimal, nodes: nodes_total }
}

/// Exact makespan for fixed core counts: DFS over active schedules.
struct BranchBound<'a> {
    ann: &'a AnnotatedGraph<'a>,
    tc: u64,
    vc: u64,
    /// Longest path (inclusive) from each op to a sink — the lower bound.
    tail: Vec<u64>,
    budget: u64,
    nodes: u64,
    complete: bool,
    best: u64,
}

impl<'a> BranchBound<'a> {
    fn new(ann: &'a AnnotatedGraph<'a>, tc: u64, vc: u64, budget: u64) -> Self {
        let g = ann.graph;
        let mut tail = vec![0u64; g.len()];
        for &v in g.topo_order_cached().iter().rev() {
            let succ_max = g.succs(v).iter().map(|&s| tail[s as usize]).max().unwrap_or(0);
            tail[v] = ann.cycles[v] + succ_max;
        }
        Self { ann, tc, vc, tail, budget, nodes: 0, complete: true, best: u64::MAX }
    }

    fn solve(&mut self, incumbent: u64) -> u64 {
        self.best = incumbent;
        let n = self.ann.graph.len();
        let finish = vec![0u64; n];
        let mut indeg: Vec<u32> = self.ann.graph.indeg().to_vec();
        // Busy-until times per core instance (identical cores: keep sorted).
        let tc_free = vec![0u64; self.tc as usize];
        let vc_free = vec![0u64; self.vc as usize];
        self.dfs(0, finish, &mut indeg, tc_free, vc_free, 0);
        self.best
    }

    #[allow(clippy::too_many_arguments)]
    fn dfs(
        &mut self,
        scheduled: usize,
        finish: Vec<u64>,
        indeg: &mut [u32],
        tc_free: Vec<u64>,
        vc_free: Vec<u64>,
        cur_max: u64,
    ) {
        let g = self.ann.graph;
        let n = g.len();
        if scheduled == n {
            self.best = self.best.min(cur_max);
            return;
        }
        self.nodes += 1;
        if self.nodes > self.budget {
            self.complete = false;
            return;
        }
        for v in 0..n {
            if finish[v] != 0 || indeg[v] != 0 {
                continue; // done or not ready
            }
            // Earliest start: preds + the required core(s).
            let pred_ready = g.preds(v).iter().map(|&p| finish[p as usize]).max().unwrap_or(0);
            let (est, tci, vci) = match self.ann.core[v] {
                CoreType::Tensor => {
                    let (i, &t) = min_idx(&tc_free);
                    (pred_ready.max(t), Some(i), None)
                }
                CoreType::Vector => {
                    let (i, &t) = min_idx(&vc_free);
                    (pred_ready.max(t), None, Some(i))
                }
                CoreType::Fused => {
                    let (i, &t1) = min_idx(&tc_free);
                    let (j, &t2) = min_idx(&vc_free);
                    (pred_ready.max(t1).max(t2), Some(i), Some(j))
                }
            };
            let fin = est + self.ann.cycles[v];
            // Lower bound: this op's tail from its start.
            if est + self.tail[v] >= self.best || fin >= self.best {
                continue;
            }
            let mut f2 = finish.clone();
            f2[v] = fin;
            let mut tf2 = tc_free.clone();
            let mut vf2 = vc_free.clone();
            if let Some(i) = tci {
                tf2[i] = fin;
            }
            if let Some(j) = vci {
                vf2[j] = fin;
            }
            for &s in g.succs(v) {
                indeg[s as usize] -= 1;
            }
            self.dfs(scheduled + 1, f2, indeg, tf2, vf2, cur_max.max(fin));
            for &s in g.succs(v) {
                indeg[s as usize] += 1;
            }
            if !self.complete && self.nodes > self.budget {
                return;
            }
        }
    }
}

fn min_idx(v: &[u64]) -> (usize, &u64) {
    v.iter()
        .enumerate()
        .min_by_key(|(_, &t)| t)
        .expect("at least one core")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;
    use crate::cost::Dims;
    use crate::graph::GraphBuilder;

    const D: Dims = Dims { tc_x: 64, tc_y: 64, vc_w: 64 };

    fn solve(g: &crate::graph::OperatorGraph) -> IlpOutcome {
        let ann = AnnotatedGraph::new(g, D, &mut NativeCost);
        ilp_search(&ann, &Constraints::default(), 2_000_000)
    }

    #[test]
    fn matches_critical_path_on_fanout() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        let out = solve(&g);
        assert!(out.optimal);
        assert_eq!(out.makespan, cp.best_latency);
        assert!(out.cores.tc >= 3, "needs 3 TCs for the bound, got {:?}", out.cores);
    }

    #[test]
    fn ilp_never_worse_than_greedy() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        let out = solve(&g);
        for tc in 1..=3 {
            let gs = greedy_schedule(&ann, &cp, CoreCount { tc, vc: 1 });
            assert!(out.makespan <= gs.makespan);
        }
    }

    #[test]
    fn prefers_smaller_area_at_equal_makespan() {
        // Serial chain: every core count gives the same makespan, so the
        // lexicographic objective must choose <1, 1>.
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 64, 64, 64, &[]);
        let _c = b.gemm("c", 64, 64, 64, &[a]);
        let out = solve(&b.finish());
        assert_eq!(out.cores, CoreCount { tc: 1, vc: 1 });
        assert!(out.optimal);
    }

    #[test]
    fn large_graph_times_out_not_crash() {
        let fwd = crate::models::vision::resnet18(8);
        let g = crate::graph::autodiff::training_graph(&fwd, crate::graph::autodiff::Optimizer::SgdMomentum);
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let out = ilp_search(&ann, &Constraints::default(), 10_000);
        assert!(!out.optimal, "past EXACT_OP_LIMIT must report non-optimal");
        assert!(out.makespan > 0);
    }

    #[test]
    fn exact_beats_or_ties_greedy_on_interval_puzzle() {
        // Layout where naive greedy can go wrong: two long ops and two
        // short ops on one TC; optimal pairs them.
        let mut b = GraphBuilder::new();
        b.gemm("long1", 256, 256, 512, &[]);
        b.gemm("long2", 256, 256, 512, &[]);
        b.gemm("short1", 64, 64, 64, &[]);
        b.gemm("short2", 64, 64, 64, &[]);
        let g = b.finish();
        let ann = AnnotatedGraph::new(&g, D, &mut NativeCost);
        let cp = asap_alap(&ann);
        let out = solve(&g);
        let gs = greedy_schedule(&ann, &cp, out.cores);
        assert!(out.makespan <= gs.makespan);
        assert!(out.optimal);
    }
}
