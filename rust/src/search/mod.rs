//! WHAM's accelerator search (paper section 4, Figure 4).
//!
//! * [`dims`] — core-dimension generator (module 1 of Figure 4);
//! * [`mcr`] — Mirror Conflict Resolution heuristics (Algorithm 1);
//! * [`ilp`] — exact branch-and-bound core-count + schedule co-optimizer
//!   (the Gurobi-ILP substitution, same optimality-within-time-budget
//!   contract — section 4.4);
//! * [`pruner`] — architecture configuration pruner (Algorithm 2);
//! * [`engine`] — ties everything into per-workload search with top-k;
//! * [`common`] — WHAM-common multi-workload search (section 4.6);
//! * [`space`] — search-space accounting for Table 3.

pub mod common;
pub mod dims;
pub mod engine;
pub mod ilp;
pub mod mcr;
pub mod pruner;
pub mod space;

use crate::arch::ArchConfig;
use crate::metrics::Evaluation;

/// One fully-evaluated design point.
#[derive(Debug, Clone, Copy)]
pub struct DesignPoint {
    pub config: ArchConfig,
    pub eval: Evaluation,
    /// Metric score, higher is better.
    pub score: f64,
}

/// Keep the best-k design points (descending score).
#[derive(Debug, Clone, Default)]
pub struct TopK {
    k: usize,
    points: Vec<DesignPoint>,
}

impl TopK {
    /// Track up to `k` points.
    pub fn new(k: usize) -> Self {
        Self { k, points: Vec::new() }
    }

    /// Offer a point; keeps the list sorted, deduplicated by config.
    pub fn offer(&mut self, p: DesignPoint) {
        if let Some(existing) = self.points.iter_mut().find(|e| e.config == p.config) {
            if p.score > existing.score {
                *existing = p;
            }
        } else {
            self.points.push(p);
        }
        self.points.sort_by(|a, b| b.score.total_cmp(&a.score));
        self.points.truncate(self.k);
    }

    /// Best point, if any.
    pub fn best(&self) -> Option<&DesignPoint> {
        self.points.first()
    }

    /// All retained points, best first.
    pub fn points(&self) -> &[DesignPoint] {
        &self.points
    }

    /// Number retained.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// True if nothing retained yet.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    fn dp(score: f64, cfg: ArchConfig) -> DesignPoint {
        let eval = crate::metrics::evaluate(&cfg, 1_000_000, 1, 1.0);
        DesignPoint { config: cfg, eval, score }
    }

    #[test]
    fn topk_keeps_best_sorted() {
        let mut t = TopK::new(2);
        t.offer(dp(1.0, presets::tpuv2()));
        t.offer(dp(3.0, presets::nvdla_scaled()));
        t.offer(dp(2.0, presets::tpuv3()));
        assert_eq!(t.len(), 2);
        assert_eq!(t.best().unwrap().score, 3.0);
        assert_eq!(t.points()[1].score, 2.0);
    }

    #[test]
    fn topk_dedupes_by_config() {
        let mut t = TopK::new(4);
        t.offer(dp(1.0, presets::tpuv2()));
        t.offer(dp(5.0, presets::tpuv2()));
        assert_eq!(t.len(), 1);
        assert_eq!(t.best().unwrap().score, 5.0);
    }
}
