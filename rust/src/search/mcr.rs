//! Mirror Conflict Resolution heuristics — paper Algorithm 1.
//!
//! For a fixed `<TC-Dim, VC-Width>`, grow core counts from `<1, 1>`: each
//! iteration greedy-schedules the graph, finds the first operator whose
//! resource wait pushed it past its ALAP start (a *critical* conflict),
//! and adds one core of the type that operator needs (a whole TC+VC unit
//! for fused ops). The loop commits an addition only if area/power
//! constraints hold and the makespan did not get worse; it stops at the
//! critical-path bound — the graph's parallelizability limit — or when no
//! critical conflicts remain.

use crate::arch::{ArchConfig, Constraints, CORES_MAX};
use crate::cost::annotate::AnnotatedGraph;
use crate::graph::CoreType;
use crate::sched::{asap_alap, greedy_schedule, CoreCount, CriticalPath, Schedule};

/// Outcome of the MCR loop for one dimension configuration.
#[derive(Debug, Clone)]
pub struct McrOutcome {
    /// Chosen core counts.
    pub cores: CoreCount,
    /// Final schedule at those counts.
    pub schedule: Schedule,
    /// Critical-path analysis (reused by callers for reporting).
    pub critical: CriticalPath,
    /// Greedy-scheduler invocations (the search-cost unit of Figure 8).
    pub evals: usize,
    /// Whether the theoretical best latency was reached.
    pub hit_bound: bool,
    /// Every accepted `(cores, makespan)` along the growth trajectory —
    /// metric-aware callers (Perf/TDP with a throughput floor) score all
    /// of them, since the most efficient point is often before the last
    /// core addition.
    pub trajectory: Vec<(CoreCount, u64)>,
}

/// Run Algorithm 1 over an annotated graph.
pub fn mcr(ann: &AnnotatedGraph, constraints: &Constraints) -> McrOutcome {
    let cp = asap_alap(ann);
    // Critical-path bound on useful core counts (section 3): adding more
    // cores than the graph's peak parallelism cannot help.
    let max_tc = cp.max_parallelism(ann, CoreType::Tensor).clamp(1, CORES_MAX);
    let max_vc = cp.max_parallelism(ann, CoreType::Vector).clamp(1, CORES_MAX);

    let mut cores = CoreCount { tc: 1, vc: 1 };
    let mut sched = greedy_schedule(ann, &cp, cores);
    let mut evals = 1usize;
    let mut trajectory = vec![(cores, sched.makespan)];
    // A core type saturates when growing it stops helping (constraint hit
    // or CheckRuntimeIsWorse); a successful addition of the other type can
    // change the schedule, so saturation resets on acceptance.
    let mut sat_tc = false;
    let mut sat_vc = false;

    loop {
        if sched.makespan == cp.best_latency {
            break; // converged to the theoretical best
        }
        // First critical conflict whose required core type is not
        // saturated (fused units need both).
        let conflict = sched.first_conflict_where(&cp, |v| match ann.core[v] {
            CoreType::Tensor => !sat_tc,
            CoreType::Vector => !sat_vc,
            CoreType::Fused => !sat_tc && !sat_vc,
        });
        let Some(conflict) = conflict else {
            break; // no resolvable conflicts remain
        };
        let needed = ann.core[conflict];
        let saturate = |t: CoreType, sat_tc: &mut bool, sat_vc: &mut bool| match t {
            CoreType::Tensor => *sat_tc = true,
            CoreType::Vector => *sat_vc = true,
            CoreType::Fused => {
                *sat_tc = true;
                *sat_vc = true;
            }
        };
        // Add the core the conflicted operator needs (whole unit if fused).
        let mut cand = cores;
        match needed {
            CoreType::Tensor => cand.tc += 1,
            CoreType::Vector => cand.vc += 1,
            CoreType::Fused => {
                cand.tc += 1;
                cand.vc += 1;
            }
        }
        if cand.tc > max_tc || cand.vc > max_vc {
            saturate(needed, &mut sat_tc, &mut sat_vc); // parallelizability bound
            continue;
        }
        let cfg = ArchConfig {
            num_tc: cand.tc,
            tc_x: ann.dims.tc_x,
            tc_y: ann.dims.tc_y,
            num_vc: cand.vc,
            vc_w: ann.dims.vc_w,
        };
        if !constraints.allows(&cfg) {
            saturate(needed, &mut sat_tc, &mut sat_vc); // AddCoreCheckConstraints
            continue;
        }
        let cand_sched = greedy_schedule(ann, &cp, cand);
        evals += 1;
        if cand_sched.makespan >= sched.makespan {
            saturate(needed, &mut sat_tc, &mut sat_vc); // CheckRuntimeIsWorse
            continue;
        }
        cores = cand;
        sched = cand_sched;
        trajectory.push((cores, sched.makespan));
        sat_tc = false;
        sat_vc = false;
    }

    // Polish: aggregate contention can shorten the makespan even when no
    // single operator crosses its ALAP (the conflict criterion). Greedily
    // grow either core type while it strictly improves the schedule —
    // still bounded by the parallelism limit and constraints.
    let mut improved = true;
    while improved && sched.makespan > cp.best_latency {
        improved = false;
        for add_tc in [true, false] {
            let cand = CoreCount {
                tc: cores.tc + u64::from(add_tc),
                vc: cores.vc + u64::from(!add_tc),
            };
            if cand.tc > max_tc || cand.vc > max_vc {
                continue;
            }
            let cfg = ArchConfig {
                num_tc: cand.tc,
                tc_x: ann.dims.tc_x,
                tc_y: ann.dims.tc_y,
                num_vc: cand.vc,
                vc_w: ann.dims.vc_w,
            };
            if !constraints.allows(&cfg) {
                continue;
            }
            let cand_sched = greedy_schedule(ann, &cp, cand);
            evals += 1;
            if cand_sched.makespan < sched.makespan {
                cores = cand;
                sched = cand_sched;
                trajectory.push((cores, sched.makespan));
                improved = true;
                break;
            }
        }
    }

    let hit_bound = sched.makespan == cp.best_latency;
    McrOutcome { cores, schedule: sched, critical: cp, evals, hit_bound, trajectory }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;
    use crate::cost::Dims;
    use crate::graph::GraphBuilder;

    const D: Dims = Dims { tc_x: 64, tc_y: 64, vc_w: 64 };

    fn run(g: &crate::graph::OperatorGraph) -> McrOutcome {
        let ann = AnnotatedGraph::new(g, D, &mut NativeCost);
        mcr(&ann, &Constraints::default())
    }

    #[test]
    fn grows_cores_for_parallel_branches() {
        let g = crate::sched::fanout3();
        let out = run(&g);
        assert!(out.cores.tc >= 2, "fanout-3 should earn extra tensor cores, got {:?}", out.cores);
        assert!(out.hit_bound, "small graph should reach the ASAP bound");
    }

    #[test]
    fn chain_needs_single_core() {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 64, 64, 64, &[]);
        let c = b.gemm("c", 64, 64, 64, &[a]);
        let _d = b.gemm("d", 64, 64, 64, &[c]);
        let out = run(&b.finish());
        assert_eq!(out.cores, CoreCount { tc: 1, vc: 1 });
        assert!(out.hit_bound);
    }

    #[test]
    fn respects_constraints() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 256, tc_y: 256, vc_w: 256 }, &mut NativeCost);
        // Constraint so tight only one big core fits.
        let tight = Constraints { max_area_mm2: 170.0, max_power_w: 80.0 };
        let out = mcr(&ann, &tight);
        assert_eq!(out.cores.tc, 1, "tight constraint must stop growth");
    }

    #[test]
    fn mirror_conflicts_resolve_in_backward_pass() {
        // Training graph of a branchy model: adding TCs for forward QKV
        // also fixes the mirrored backward conflicts (the paper's core
        // rationale) — so MCR should reach the bound with few additions.
        let fwd = crate::models::transformer::forward_range(&crate::models::transformer::bert_base(), 0, 1);
        let g = crate::graph::autodiff::training_graph(&fwd, crate::graph::autodiff::Optimizer::SgdMomentum);
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 128, tc_y: 64, vc_w: 128 }, &mut NativeCost);
        let out = mcr(&ann, &Constraints::default());
        assert!(out.cores.tc >= 2, "QKV branching earns cores: {:?}", out.cores);
        // Makespan must improve monotonically vs the single-core start.
        let single = greedy_schedule(&ann, &out.critical, CoreCount { tc: 1, vc: 1 });
        assert!(out.schedule.makespan < single.makespan);
    }

    #[test]
    fn makespan_never_below_critical_path() {
        let g = crate::sched::fanout3();
        let out = run(&g);
        assert!(out.schedule.makespan >= out.critical.best_latency);
    }
}
