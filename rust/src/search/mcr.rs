//! Mirror Conflict Resolution heuristics — paper Algorithm 1.
//!
//! For a fixed `<TC-Dim, VC-Width>`, grow core counts from `<1, 1>`: each
//! iteration greedy-schedules the graph, finds the first operator whose
//! resource wait pushed it past its ALAP start (a *critical* conflict),
//! and adds one core of the type that operator needs (a whole TC+VC unit
//! for fused ops). The loop commits an addition only if area/power
//! constraints hold and the makespan did not get worse; it stops at the
//! critical-path bound — the graph's parallelizability limit — or when no
//! critical conflicts remain.
//!
//! Probes run on the incremental engine by default
//! ([`crate::sched::IncrementalSched`]): the monotone growth sequence
//! lets each candidate resume from a checkpointed schedule prefix, and
//! every accept test is a threshold comparison, so probes abort as soon
//! as the makespan reaches the smallest rejected value. Both shortcuts
//! are exact; `SearchOptions::full_reschedule` forces the legacy
//! schedule-from-scratch path, kept as the parity oracle
//! (`rust/tests/hotpath_parity.rs`).

use crate::arch::{ArchConfig, Constraints, CORES_MAX};
use crate::cost::annotate::AnnotatedGraph;
use crate::graph::CoreType;
use crate::sched::{
    asap_alap, greedy_schedule_scratch, CoreCount, CriticalPath, CriticalPathCache,
    IncrementalSched, Priority, SchedScratch, Schedule,
};

/// How the loop grows a conflicted core type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GrowthMode {
    /// Grow geometrically (1, 2, 4, …) and binary-search back to the
    /// smallest count whose schedule passes the accept checks:
    /// O(log final cores) scheduler runs per conflict instead of
    /// O(final cores). Lands on the same `(cores, makespan)` as
    /// [`GrowthMode::OneAtATime`] whenever makespan plateaus stop both
    /// walks at the same count — true of the branching structure of the
    /// Table-4 workloads, and pinned by `rust/tests/hotpath_parity.rs`.
    /// (A plateau-then-improve staircase at an unmeasured count *could*
    /// make gallop land deeper/better; the design-DB context key keeps
    /// the two modes' mined points separate for exactly that reason.)
    /// Records only the measured points in the trajectory.
    #[default]
    Gallop,
    /// Paper-literal Algorithm 1: one core per iteration, one greedy
    /// reschedule per addition. The parity baseline — and the mode the
    /// engine picks for Perf/TDP, where every intermediate trajectory
    /// point is scored (the most efficient design is often before the
    /// last addition).
    OneAtATime,
}

/// Outcome of the MCR loop for one dimension configuration.
#[derive(Debug, Clone)]
pub struct McrOutcome {
    /// Chosen core counts.
    pub cores: CoreCount,
    /// Final schedule at those counts.
    pub schedule: Schedule,
    /// Critical-path analysis (reused by callers for reporting).
    pub critical: CriticalPath,
    /// Greedy-scheduler invocations (the search-cost unit of Figure 8).
    pub evals: usize,
    /// Whether the theoretical best latency was reached.
    pub hit_bound: bool,
    /// Accepted `(cores, makespan)` points along the growth trajectory —
    /// metric-aware callers (Perf/TDP with a throughput floor) score all
    /// of them, since the most efficient point is often before the last
    /// core addition. Under [`GrowthMode::OneAtATime`] this is *every*
    /// accepted addition; under [`GrowthMode::Gallop`] only the measured
    /// landing points (the endpoint is identical).
    pub trajectory: Vec<(CoreCount, u64)>,
    /// Cores granted per conflicted class over the run (tensor, vector,
    /// fused units) — the flight recorder's attribution of *where* the
    /// growth went. Polish-loop additions count toward their axis.
    pub grants: (u64, u64, u64),
    /// Graph index of the last operator whose critical conflict the loop
    /// resolved (`None` when the single-core schedule already met the
    /// bound or only the polish loop grew cores).
    pub last_conflict: Option<usize>,
}

/// One core count plus `k` cores of `t` (a whole TC+VC unit if fused).
fn add_cores(c: CoreCount, t: CoreType, k: u64) -> CoreCount {
    match t {
        CoreType::Tensor => CoreCount { tc: c.tc + k, vc: c.vc },
        CoreType::Vector => CoreCount { tc: c.tc, vc: c.vc + k },
        CoreType::Fused => CoreCount { tc: c.tc + k, vc: c.vc + k },
    }
}

/// Cross-probe state reused by every MCR run inside one search: the
/// incremental critical-path cache (cones repropagated between dims
/// candidates) and the incremental scheduler (checkpoints reused between
/// growth probes *within* a run). One per search thread.
#[derive(Default)]
pub struct McrScratch {
    cp: CriticalPathCache,
    sched: IncrementalSched,
}

impl McrScratch {
    /// Empty scratch; every buffer grows on first use and is kept after.
    pub fn new() -> Self {
        Self::default()
    }
}

/// Run Algorithm 1 over an annotated graph with the default (galloping)
/// growth mode.
pub fn mcr(ann: &AnnotatedGraph, constraints: &Constraints) -> McrOutcome {
    mcr_with(ann, constraints, GrowthMode::default())
}

/// Run Algorithm 1 with an explicit growth mode (fresh scratch).
pub fn mcr_with(ann: &AnnotatedGraph, constraints: &Constraints, mode: GrowthMode) -> McrOutcome {
    mcr_with_scratch(ann, constraints, mode, &mut McrScratch::new(), false)
}

/// The probe backend of one MCR run. `Incremental` is the production
/// path; `Full` re-runs the from-scratch list scheduler per probe and
/// exists as the parity oracle. Both count probes identically, so
/// [`McrOutcome::evals`] — and every decision — is engine-independent.
enum Engine<'a> {
    Incremental(&'a mut IncrementalSched),
    Full { scratch: SchedScratch, last: Option<Schedule> },
}

/// Shared machinery of one MCR run: the critical-path bounds, the probe
/// engine, and the galloping axis growth used by both the conflict loop
/// and the polish loop.
struct McrCtx<'a> {
    ann: &'a AnnotatedGraph<'a>,
    cp: &'a CriticalPath,
    constraints: &'a Constraints,
    max_tc: u64,
    max_vc: u64,
    engine: Engine<'a>,
    evals: usize,
}

/// Latency distribution of MCR probes (one candidate core count →
/// one scheduler run, resumed and bounded on the incremental engine).
/// Sits one level above `wham_scheduler_eval_duration_seconds`, so
/// their ratio exposes probe overhead beyond the schedule itself.
static PROBE_SECONDS: crate::telemetry::Histogram = crate::telemetry::Histogram::new(
    "wham_mcr_probe_duration_seconds",
    "Wall-clock of MCR candidate probes (reschedule of one core-count candidate).",
    1e-6,
);

impl McrCtx<'_> {
    /// Schedule `cand` and return its makespan iff it is `< bound` — the
    /// smallest value the caller would reject. The incremental engine
    /// uses the bound to abort mid-schedule; the oracle completes and
    /// applies the same threshold, so both engines return identical
    /// values from identical call sequences.
    fn probe(&mut self, cand: CoreCount, bound: u64) -> Option<u64> {
        self.evals += 1;
        let _timer = PROBE_SECONDS.start_timer();
        let _span =
            crate::telemetry::trace::span("mcr_probe").arg("tc", cand.tc).arg("vc", cand.vc);
        match &mut self.engine {
            Engine::Incremental(inc) => {
                inc.probe(self.ann, self.cp, cand, Priority::Criticality, bound)
            }
            Engine::Full { scratch, last } => {
                let s = greedy_schedule_scratch(
                    self.ann,
                    self.cp,
                    cand,
                    Priority::Criticality,
                    scratch,
                );
                let ms = s.makespan;
                *last = Some(s);
                (ms < bound).then_some(ms)
            }
        }
    }

    /// Owned schedule of the most recent *accepted* probe. Must be called
    /// before the next probe overwrites the engine state.
    fn materialize(&self) -> Schedule {
        match &self.engine {
            Engine::Incremental(inc) => inc.materialize(self.ann),
            Engine::Full { last, .. } => {
                last.clone().expect("materialize follows a completed probe")
            }
        }
    }

    fn cfg_of(&self, c: CoreCount) -> ArchConfig {
        ArchConfig {
            num_tc: c.tc,
            tc_x: self.ann.dims.tc_x,
            tc_y: self.ann.dims.tc_y,
            num_vc: c.vc,
            vc_w: self.ann.dims.vc_w,
        }
    }

    fn feasible(&self, c: CoreCount) -> bool {
        c.tc <= self.max_tc && c.vc <= self.max_vc && self.constraints.allows(&self.cfg_of(c))
    }

    /// Largest feasible addition along `axis` from `cores`. Area/power
    /// are monotone in counts, so feasibility is a prefix: O(log)
    /// constraint checks, zero scheduler runs.
    fn room(&self, cores: CoreCount, axis: CoreType) -> u64 {
        let lim = match axis {
            CoreType::Tensor => self.max_tc - cores.tc.min(self.max_tc),
            CoreType::Vector => self.max_vc - cores.vc.min(self.max_vc),
            CoreType::Fused => (self.max_tc - cores.tc.min(self.max_tc))
                .min(self.max_vc - cores.vc.min(self.max_vc)),
        };
        if lim == 0 || self.feasible(add_cores(cores, axis, lim)) {
            return lim;
        }
        let (mut lo, mut hi) = (0u64, lim);
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if self.feasible(add_cores(cores, axis, mid)) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Galloping growth along one axis: measure additions k = 1, 2, 4, …
    /// (clamped to the feasible room) while each measured point strictly
    /// improves on the previous one — the same accept check Algorithm 1
    /// applies per single addition, at doubling distance — then
    /// binary-search back to the smallest addition whose schedule
    /// reaches the best measured makespan. With the scheduler's makespan
    /// non-increasing in the count, that is exactly where the
    /// one-at-a-time accept chain stops (each unit step up to it
    /// strictly improves). Returns `Some((k, landing))` with `k >= 1`,
    /// or `None` when a single addition is infeasible or does not
    /// improve on `cur_ms`.
    fn gallop_axis(
        &mut self,
        cores: CoreCount,
        cur_ms: u64,
        axis: CoreType,
        best_latency: u64,
    ) -> Option<(u64, Schedule)> {
        let room = self.room(cores, axis);
        if room == 0 {
            return None;
        }
        let _span = crate::telemetry::trace::span("mcr_gallop")
            .arg("axis", format!("{axis:?}"))
            .arg("room", room);
        let mut prev_k = 0u64; // measured improving point below `last_k`
        let mut last_k = 0u64; // best measured improving point
        let mut last_ms = cur_ms;
        let mut last_sched: Option<Schedule> = None;
        let mut k = 1u64;
        loop {
            // Doubling accepts strict improvement: reject at `last_ms`.
            let Some(ms) = self.probe(add_cores(cores, axis, k), last_ms) else {
                break; // first non-improving measured point brackets the landing
            };
            prev_k = last_k;
            last_k = k;
            last_ms = ms;
            last_sched = Some(self.materialize());
            if last_ms == best_latency || k == room {
                break;
            }
            k = (k * 2).min(room);
        }
        let mut landing = last_sched?; // None: even +1 does not improve
        let (mut lo, mut hi) = (prev_k, last_k);
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            // The walk-back accepts ties with the best measured makespan:
            // reject only past it.
            if let Some(ms) = self.probe(add_cores(cores, axis, mid), last_ms.saturating_add(1)) {
                last_ms = ms;
                hi = mid;
                landing = self.materialize();
            } else {
                lo = mid;
            }
        }
        Some((hi, landing))
    }
}

/// Run Algorithm 1 with an explicit growth mode, probe engine, and
/// reusable cross-run scratch — the search-engine hot path.
pub fn mcr_with_scratch(
    ann: &AnnotatedGraph,
    constraints: &Constraints,
    mode: GrowthMode,
    scratch: &mut McrScratch,
    full_reschedule: bool,
) -> McrOutcome {
    let _span = crate::telemetry::trace::span("mcr").arg("ops", ann.graph.len());
    // Split borrow: the critical path lives in the scratch (refreshed
    // incrementally across runs); the scheduler state is reset per run.
    let McrScratch { cp: cp_cache, sched: inc } = scratch;
    let cp_oracle;
    let cp: &CriticalPath = if full_reschedule {
        // The oracle recomputes from scratch — it must not share even the
        // (exact) incremental critical-path machinery with the fast path.
        cp_oracle = asap_alap(ann);
        &cp_oracle
    } else {
        cp_cache.refresh(ann)
    };
    // Critical-path bound on useful core counts (section 3): adding more
    // cores than the graph's peak parallelism cannot help.
    let max_tc = cp.max_parallelism(ann, CoreType::Tensor).clamp(1, CORES_MAX);
    let max_vc = cp.max_parallelism(ann, CoreType::Vector).clamp(1, CORES_MAX);
    let engine = if full_reschedule {
        Engine::Full { scratch: SchedScratch::new(), last: None }
    } else {
        inc.reset_for(ann.graph.len());
        Engine::Incremental(inc)
    };
    let mut ctx = McrCtx { ann, cp, constraints, max_tc, max_vc, engine, evals: 0 };

    let mut cores = CoreCount { tc: 1, vc: 1 };
    let ms = ctx.probe(cores, u64::MAX).expect("unbounded probe completes");
    let mut sched = ctx.materialize();
    debug_assert_eq!(ms, sched.makespan);
    let mut trajectory = vec![(cores, sched.makespan)];
    // Flight-recorder attribution: cores granted per conflicted class
    // and the last conflict resolved. Pure observation — never read by
    // the growth decisions above it.
    let mut grants = (0u64, 0u64, 0u64);
    let mut last_conflict: Option<usize> = None;
    let grant = |g: &mut (u64, u64, u64), t: CoreType, k: u64| match t {
        CoreType::Tensor => g.0 += k,
        CoreType::Vector => g.1 += k,
        CoreType::Fused => g.2 += k,
    };
    // A core type saturates when growing it stops helping (constraint hit
    // or CheckRuntimeIsWorse); a successful addition of the other type can
    // change the schedule, so saturation resets on acceptance.
    let mut sat_tc = false;
    let mut sat_vc = false;
    let saturate = |t: CoreType, sat_tc: &mut bool, sat_vc: &mut bool| match t {
        CoreType::Tensor => *sat_tc = true,
        CoreType::Vector => *sat_vc = true,
        CoreType::Fused => {
            *sat_tc = true;
            *sat_vc = true;
        }
    };

    loop {
        if sched.makespan == cp.best_latency {
            break; // converged to the theoretical best
        }
        // First critical conflict whose required core type is not
        // saturated (fused units need both).
        let conflict = sched.first_conflict_where(cp, |v| match ann.core[v] {
            CoreType::Tensor => !sat_tc,
            CoreType::Vector => !sat_vc,
            CoreType::Fused => !sat_tc && !sat_vc,
        });
        let Some(conflict) = conflict else {
            break; // no resolvable conflicts remain
        };
        let needed = ann.core[conflict];

        match mode {
            GrowthMode::OneAtATime => {
                // Paper-literal: add the one core the conflicted operator
                // needs (whole unit if fused), accept iff strictly better.
                let cand = add_cores(cores, needed, 1);
                if cand.tc > max_tc || cand.vc > max_vc {
                    saturate(needed, &mut sat_tc, &mut sat_vc); // parallelizability bound
                    continue;
                }
                if !constraints.allows(&ctx.cfg_of(cand)) {
                    saturate(needed, &mut sat_tc, &mut sat_vc); // AddCoreCheckConstraints
                    continue;
                }
                let Some(_) = ctx.probe(cand, sched.makespan) else {
                    saturate(needed, &mut sat_tc, &mut sat_vc); // CheckRuntimeIsWorse
                    continue;
                };
                cores = cand;
                sched = ctx.materialize();
                grant(&mut grants, needed, 1);
                last_conflict = Some(conflict);
            }
            GrowthMode::Gallop => {
                // Run the whole accept chain for this core type at
                // doubling distance (Algorithm 1 would re-find the same
                // conflict type until the type stops helping).
                let Some((k, landing)) =
                    ctx.gallop_axis(cores, sched.makespan, needed, cp.best_latency)
                else {
                    // Infeasible or not an improvement — the same three
                    // saturation cases as the one-at-a-time walk.
                    saturate(needed, &mut sat_tc, &mut sat_vc);
                    continue;
                };
                cores = add_cores(cores, needed, k);
                sched = landing;
                grant(&mut grants, needed, k);
                last_conflict = Some(conflict);
            }
        }
        trajectory.push((cores, sched.makespan));
        sat_tc = false;
        sat_vc = false;
    }

    // Polish: aggregate contention can shorten the makespan even when no
    // single operator crosses its ALAP (the conflict criterion). Greedily
    // grow either core type while it strictly improves the schedule —
    // still bounded by the parallelism limit and constraints. Under
    // galloping growth a run of same-axis improvements costs O(log run)
    // reschedules (the one-at-a-time walk retries the same axis first
    // after every accept, so a maximal run is the identical chain).
    let mut improved = true;
    while improved && sched.makespan > cp.best_latency {
        improved = false;
        for axis in [CoreType::Tensor, CoreType::Vector] {
            match mode {
                GrowthMode::Gallop => {
                    if let Some((k, landing)) =
                        ctx.gallop_axis(cores, sched.makespan, axis, cp.best_latency)
                    {
                        cores = add_cores(cores, axis, k);
                        sched = landing;
                        trajectory.push((cores, sched.makespan));
                        grant(&mut grants, axis, k);
                        improved = true;
                        break;
                    }
                }
                GrowthMode::OneAtATime => {
                    let cand = add_cores(cores, axis, 1);
                    if !ctx.feasible(cand) {
                        continue;
                    }
                    if ctx.probe(cand, sched.makespan).is_some() {
                        cores = cand;
                        sched = ctx.materialize();
                        trajectory.push((cores, sched.makespan));
                        grant(&mut grants, axis, 1);
                        improved = true;
                        break;
                    }
                }
            }
        }
    }

    let hit_bound = sched.makespan == cp.best_latency;
    let evals = ctx.evals;
    let critical = cp.clone();
    drop(ctx); // ends the ctx borrow of the scratch before returning
    McrOutcome {
        cores,
        schedule: sched,
        critical,
        evals,
        hit_bound,
        trajectory,
        grants,
        last_conflict,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;
    use crate::cost::Dims;
    use crate::graph::GraphBuilder;
    use crate::sched::greedy_schedule;

    const D: Dims = Dims { tc_x: 64, tc_y: 64, vc_w: 64 };

    fn run(g: &crate::graph::OperatorGraph) -> McrOutcome {
        let ann = AnnotatedGraph::new(g, D, &mut NativeCost);
        mcr(&ann, &Constraints::default())
    }

    #[test]
    fn grows_cores_for_parallel_branches() {
        let g = crate::sched::fanout3();
        let out = run(&g);
        assert!(out.cores.tc >= 2, "fanout-3 should earn extra tensor cores, got {:?}", out.cores);
        assert!(out.hit_bound, "small graph should reach the ASAP bound");
    }

    #[test]
    fn chain_needs_single_core() {
        let mut b = GraphBuilder::new();
        let a = b.gemm("a", 64, 64, 64, &[]);
        let c = b.gemm("c", 64, 64, 64, &[a]);
        let _d = b.gemm("d", 64, 64, 64, &[c]);
        let out = run(&b.finish());
        assert_eq!(out.cores, CoreCount { tc: 1, vc: 1 });
        assert!(out.hit_bound);
    }

    #[test]
    fn respects_constraints() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 256, tc_y: 256, vc_w: 256 }, &mut NativeCost);
        // Constraint so tight only one big core fits.
        let tight = Constraints { max_area_mm2: 170.0, max_power_w: 80.0 };
        let out = mcr(&ann, &tight);
        assert_eq!(out.cores.tc, 1, "tight constraint must stop growth");
    }

    #[test]
    fn mirror_conflicts_resolve_in_backward_pass() {
        // Training graph of a branchy model: adding TCs for forward QKV
        // also fixes the mirrored backward conflicts (the paper's core
        // rationale) — so MCR should reach the bound with few additions.
        let fwd = crate::models::transformer::forward_range(&crate::models::transformer::bert_base(), 0, 1);
        let g = crate::graph::autodiff::training_graph(&fwd, crate::graph::autodiff::Optimizer::SgdMomentum);
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 128, tc_y: 64, vc_w: 128 }, &mut NativeCost);
        let out = mcr(&ann, &Constraints::default());
        assert!(out.cores.tc >= 2, "QKV branching earns cores: {:?}", out.cores);
        // Makespan must improve monotonically vs the single-core start.
        let single = greedy_schedule(&ann, &out.critical, CoreCount { tc: 1, vc: 1 });
        assert!(out.schedule.makespan < single.makespan);
    }

    #[test]
    fn makespan_never_below_critical_path() {
        let g = crate::sched::fanout3();
        let out = run(&g);
        assert!(out.schedule.makespan >= out.critical.best_latency);
    }

    #[test]
    fn gallop_lands_where_one_at_a_time_lands() {
        // The tentpole contract: same `(cores, makespan)`, fewer evals.
        let fwd = crate::models::transformer::forward_range(
            &crate::models::transformer::bert_base(),
            0,
            2,
        );
        let bert2 =
            crate::graph::autodiff::training_graph(&fwd, crate::graph::autodiff::Optimizer::Adam);
        for (g, d) in [
            (crate::sched::fanout3(), D),
            (bert2, Dims { tc_x: 128, tc_y: 64, vc_w: 128 }),
        ] {
            let ann = AnnotatedGraph::new(&g, d, &mut NativeCost);
            let fast = mcr_with(&ann, &Constraints::default(), GrowthMode::Gallop);
            let slow = mcr_with(&ann, &Constraints::default(), GrowthMode::OneAtATime);
            assert_eq!(fast.cores, slow.cores, "gallop endpoint must match");
            assert_eq!(fast.schedule.makespan, slow.schedule.makespan);
            assert_eq!(fast.hit_bound, slow.hit_bound);
            assert!(
                fast.evals <= slow.evals,
                "gallop must not pay more scheduler runs: {} vs {}",
                fast.evals,
                slow.evals
            );
        }
    }

    #[test]
    fn gallop_respects_tight_constraints_like_one_at_a_time() {
        let g = crate::sched::fanout3();
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 256, tc_y: 256, vc_w: 256 }, &mut NativeCost);
        let tight = Constraints { max_area_mm2: 170.0, max_power_w: 80.0 };
        let fast = mcr_with(&ann, &tight, GrowthMode::Gallop);
        let slow = mcr_with(&ann, &tight, GrowthMode::OneAtATime);
        assert_eq!(fast.cores, slow.cores);
        assert_eq!(fast.schedule.makespan, slow.schedule.makespan);
    }

    /// The incremental engine and the full-reschedule oracle must agree
    /// on every observable outcome field, including eval counts — the
    /// per-run version of the `hotpath_parity.rs` contract.
    #[test]
    fn incremental_engine_matches_full_reschedule_oracle() {
        let fwd = crate::models::transformer::forward_range(
            &crate::models::transformer::bert_base(),
            0,
            2,
        );
        let g =
            crate::graph::autodiff::training_graph(&fwd, crate::graph::autodiff::Optimizer::Adam);
        let ann = AnnotatedGraph::new(&g, Dims { tc_x: 128, tc_y: 64, vc_w: 128 }, &mut NativeCost);
        let mut scratch = McrScratch::new();
        for mode in [GrowthMode::Gallop, GrowthMode::OneAtATime] {
            let fast =
                mcr_with_scratch(&ann, &Constraints::default(), mode, &mut scratch, false);
            let full = mcr_with_scratch(&ann, &Constraints::default(), mode, &mut scratch, true);
            assert_eq!(fast.cores, full.cores, "{mode:?}");
            assert_eq!(fast.schedule.start, full.schedule.start, "{mode:?}");
            assert_eq!(fast.schedule.finish, full.schedule.finish, "{mode:?}");
            assert_eq!(fast.schedule.ready_at, full.schedule.ready_at, "{mode:?}");
            assert_eq!(fast.schedule.makespan, full.schedule.makespan, "{mode:?}");
            assert_eq!(fast.evals, full.evals, "{mode:?}");
            assert_eq!(fast.trajectory, full.trajectory, "{mode:?}");
            assert_eq!(fast.grants, full.grants, "{mode:?}");
            assert_eq!(fast.last_conflict, full.last_conflict, "{mode:?}");
            assert_eq!(fast.hit_bound, full.hit_bound, "{mode:?}");
        }
    }
}
