//! WHAM-Common (paper section 4.6): one architecture for a *set* of
//! workloads. The pruner tracks a weighted average of the metric across
//! workloads (equal weights in the evaluation).

use std::collections::HashMap;
use std::time::Instant;

use super::engine::SearchOptions;
use super::ilp::ilp_search;
use super::mcr::{mcr_with, GrowthMode};
use super::pruner::prune_tree;
use super::{dims, DesignPoint, TopK};
use crate::arch::{ArchConfig, DIM_MAX};
use crate::cost::annotate::AnnotatedGraph;
use crate::cost::{CostBackend, Dims};
use crate::graph::OperatorGraph;
use crate::metrics::evaluate;

/// One workload in the common search.
pub struct Workload<'g> {
    pub name: String,
    pub graph: &'g OperatorGraph,
    pub batch: u64,
    /// Per-workload throughput floor (PerfPerTdp metric).
    pub min_throughput: f64,
    /// Weight in the average (1.0 in the paper's evaluation).
    pub weight: f64,
}

/// Result of the common search.
#[derive(Debug, Clone)]
pub struct CommonResult {
    /// Best common config and its weighted score.
    pub best: (ArchConfig, f64),
    /// Per-workload design points of the best config (same config,
    /// per-workload core counts folded to the max — see notes).
    pub per_workload: Vec<DesignPoint>,
    /// Top-k common configs.
    pub top: TopK,
    pub dims_evaluated: usize,
    pub wall: std::time::Duration,
}

/// Search one architecture serving every workload: for each candidate
/// dimension, each workload runs MCR independently; the common core count
/// is the max across workloads (the design must host the most demanding
/// graph), scores are re-evaluated at that count and weight-averaged.
pub fn search_common(
    workloads: &[Workload<'_>],
    opts: SearchOptions,
    backend: &mut dyn CostBackend,
) -> CommonResult {
    assert!(!workloads.is_empty());
    let t0 = Instant::now();
    let mut cache: HashMap<Dims, (f64, ArchConfig, Vec<DesignPoint>)> = HashMap::new();
    let mut top = TopK::new(opts.top_k);
    let mut count = 0usize;

    let mut eval_dims = |d: Dims, count: &mut usize| -> f64 {
        if let Some((s, _, _)) = cache.get(&d) {
            return *s;
        }
        *count += 1;
        // Per-workload MCR at these dims: collect every core-count the
        // trajectories visit — the common design's best count is often
        // below the union max (especially under Perf/TDP).
        let mut candidates: std::collections::BTreeSet<(u64, u64)> = std::collections::BTreeSet::new();
        let mut anns = Vec::with_capacity(workloads.len());
        for w in workloads {
            let ann = AnnotatedGraph::new(w.graph, d, backend);
            if opts.use_ilp {
                let o = ilp_search(&ann, &opts.constraints, opts.ilp_node_budget);
                candidates.insert((o.cores.tc, o.cores.vc));
            } else {
                // One-at-a-time growth on purpose: the common search's
                // candidate pool is the *full* trajectory — the galloping
                // mode records only its measured landing points and would
                // starve the pool of intermediate core counts.
                for (c, _) in mcr_with(&ann, &opts.constraints, GrowthMode::OneAtATime).trajectory
                {
                    candidates.insert((c.tc, c.vc));
                }
            }
            anns.push(ann);
        }
        // Union max is also a candidate (hosts the most demanding graph).
        let max_tc = candidates.iter().map(|&(t, _)| t).max().unwrap_or(1);
        let max_vc = candidates.iter().map(|&(_, v)| v).max().unwrap_or(1);
        candidates.insert((max_tc, max_vc));

        // Pick the candidate core count maximizing the weighted score.
        let mut best: Option<(f64, ArchConfig, Vec<DesignPoint>)> = None;
        for &(tc, vc) in &candidates {
            let config = ArchConfig { num_tc: tc, tc_x: d.tc_x, tc_y: d.tc_y, num_vc: vc, vc_w: d.vc_w };
            if !opts.constraints.allows(&config) {
                continue;
            }
            let mut weighted = 0.0;
            let mut wsum = 0.0;
            let mut points = Vec::with_capacity(workloads.len());
            for (w, ann) in workloads.iter().zip(&anns) {
                let cp = crate::sched::asap_alap(ann);
                let sched = crate::sched::greedy_schedule(
                    ann,
                    &cp,
                    crate::sched::CoreCount { tc, vc },
                );
                let eval = evaluate(&config, sched.makespan, w.batch, ann.total_energy_pj());
                let score = opts.metric.score(&eval, w.min_throughput);
                // Normalize throughput-like scores so heavy and light
                // workloads weigh comparably (relative to the per-workload
                // floor when present, else raw).
                let norm = if w.min_throughput > 0.0 { score / w.min_throughput } else { score };
                weighted += w.weight * norm;
                wsum += w.weight;
                points.push(DesignPoint { config, eval, score });
            }
            let s = weighted / wsum;
            if best.as_ref().map_or(true, |(bs, _, _)| s > *bs) {
                best = Some((s, config, points));
            }
        }
        let (s, config, points) =
            best.expect("at least <1,1> fits the default constraints");
        cache.insert(d, (s, config, points));
        s
    };

    let p1 = prune_tree(
        vec![(DIM_MAX, DIM_MAX)],
        |n| dims::tc_children(*n),
        |&(x, y)| eval_dims(Dims { tc_x: x, tc_y: y, vc_w: DIM_MAX }, &mut count),
        opts.hysteresis,
    );
    let (bx, by) = p1.best.expect("root evaluated").0;
    let _p2 = prune_tree(
        vec![DIM_MAX],
        |&w| dims::vc_children(w),
        |&w| eval_dims(Dims { tc_x: bx, tc_y: by, vc_w: w }, &mut count),
        opts.hysteresis,
    );

    // Collect the best and top-k from the cache.
    let mut entries: Vec<(&Dims, &(f64, ArchConfig, Vec<DesignPoint>))> = cache.iter().collect();
    entries.sort_by(|a, b| b.1 .0.total_cmp(&a.1 .0));
    for (_, (s, cfg, pts)) in entries.iter().take(opts.top_k) {
        // Represent the common config in the TopK by its weighted score
        // using the first workload's evaluation as the carrier.
        if let Some(p0) = pts.first() {
            top.offer(DesignPoint { config: *cfg, eval: p0.eval, score: *s });
        }
    }
    let (best_score, best_cfg, best_points) = entries[0].1.clone();
    CommonResult {
        best: (best_cfg, best_score),
        per_workload: best_points,
        top,
        dims_evaluated: count,
        wall: t0.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;
    use crate::graph::autodiff::{training_graph, Optimizer};

    fn graphs() -> Vec<crate::graph::OperatorGraph> {
        let b1 = crate::models::transformer::forward_range(&crate::models::transformer::bert_base(), 0, 1);
        let mut small = crate::graph::GraphBuilder::new();
        let a = small.gemm("a", 128, 128, 128, &[]);
        let _ = small.eltwise("r", 128 * 128, 1, &[a]);
        vec![
            training_graph(&b1, Optimizer::SgdMomentum),
            training_graph(&small.finish(), Optimizer::SgdMomentum),
        ]
    }

    #[test]
    fn common_design_serves_all_workloads() {
        let gs = graphs();
        let ws: Vec<Workload> = gs
            .iter()
            .enumerate()
            .map(|(i, g)| Workload {
                name: format!("w{i}"),
                graph: g,
                batch: 4,
                min_throughput: 0.0,
                weight: 1.0,
            })
            .collect();
        let r = search_common(&ws, SearchOptions::default(), &mut NativeCost);
        assert!(r.best.0.in_template());
        assert_eq!(r.per_workload.len(), 2);
        assert!(r.dims_evaluated >= 3);
        // Single shared config across workloads.
        assert!(r.per_workload.iter().all(|p| p.config == r.best.0));
    }

    #[test]
    fn weights_shift_the_winner() {
        // With all weight on the tiny workload the common design should
        // score at least as well for it as the balanced design does.
        let gs = graphs();
        let mk = |w0: f64, w1: f64, gs: &[crate::graph::OperatorGraph]| {
            let ws: Vec<Workload> = gs
                .iter()
                .enumerate()
                .map(|(i, g)| Workload {
                    name: format!("w{i}"),
                    graph: g,
                    batch: 4,
                    min_throughput: 0.0,
                    weight: if i == 0 { w0 } else { w1 },
                })
                .collect();
            search_common(&ws, SearchOptions::default(), &mut NativeCost)
        };
        let balanced = mk(1.0, 1.0, &gs);
        let skewed = mk(0.01, 1.0, &gs);
        let small_score = |r: &CommonResult| r.per_workload[1].score;
        assert!(small_score(&skewed) >= small_score(&balanced) * 0.99);
    }
}
