//! Typed API errors.
//!
//! Every front door (CLI, HTTP service, library callers) reports request
//! failures through [`ApiError`]: a machine-readable [`ErrorKind`] plus a
//! human message. The HTTP adapter maps kinds to status codes with
//! [`ApiError::http_status`]; the CLI prints the message; library callers
//! can match on the kind. This replaces the stringly `Response::error`
//! calls and `anyhow!` duplication the frontends used to hand-roll.

use std::fmt;

/// What went wrong, at the granularity callers can act on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// The request is malformed or semantically invalid (HTTP 400).
    InvalidRequest,
    /// A named entity (model, endpoint) does not exist (HTTP 404).
    NotFound,
    /// The mining core failed mid-execution (HTTP 500).
    Internal,
}

/// A typed API failure: kind + message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ApiError {
    pub kind: ErrorKind,
    pub message: String,
}

impl ApiError {
    /// A 400-class request error.
    pub fn invalid(message: impl Into<String>) -> Self {
        Self { kind: ErrorKind::InvalidRequest, message: message.into() }
    }

    /// A 404-class lookup failure.
    pub fn not_found(message: impl Into<String>) -> Self {
        Self { kind: ErrorKind::NotFound, message: message.into() }
    }

    /// A 500-class execution failure.
    pub fn internal(message: impl Into<String>) -> Self {
        Self { kind: ErrorKind::Internal, message: message.into() }
    }

    /// The HTTP status code this error maps to.
    pub fn http_status(&self) -> u16 {
        match self.kind {
            ErrorKind::InvalidRequest => 400,
            ErrorKind::NotFound => 404,
            ErrorKind::Internal => 500,
        }
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for ApiError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn status_mapping() {
        assert_eq!(ApiError::invalid("x").http_status(), 400);
        assert_eq!(ApiError::not_found("x").http_status(), 404);
        assert_eq!(ApiError::internal("x").http_status(), 500);
    }

    #[test]
    fn converts_into_anyhow() {
        fn fails() -> anyhow::Result<()> {
            Err(ApiError::not_found("unknown model"))?;
            Ok(())
        }
        assert!(fails().unwrap_err().to_string().contains("unknown model"));
    }
}
