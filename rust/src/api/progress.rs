//! Search-progress observation and cooperative cancellation.
//!
//! A [`ProgressSink`] is threaded through [`crate::search::engine::WhamSearch`]
//! and [`crate::distributed::global_search`]: the engine reports every
//! design-point evaluation as a [`Progress`] event, and the sink's boolean
//! return is a cooperative cancellation signal — returning `false` makes
//! the search stop exploring and return its best-so-far result (flagged
//! `cancelled` in the outcome). This is how the API layer implements
//! per-request deadlines and how frontends stream trajectories without
//! the engine knowing who is watching.

use std::time::{Duration, Instant};

/// One observed step of a running search.
#[derive(Debug, Clone, Copy)]
pub struct Progress {
    /// Which layer emitted the event: `"search"` for per-workload
    /// dimension evaluations, `"global"` for top-level candidate
    /// evaluations of the distributed search, `"cluster"` for strategy
    /// screening in the auto-sweep.
    pub phase: &'static str,
    /// Wall-clock since that layer's search started.
    pub elapsed: Duration,
    /// Points evaluated so far in this phase.
    pub points: usize,
    /// Best score seen so far (higher is better).
    pub best_score: f64,
    /// Evaluation rate since the phase started (points per second; 0.0
    /// until the clock has advanced).
    pub rate: f64,
    /// How deep the emitting layer is in its own phase structure: the
    /// engine reports its pruning phase (1 = tensor dims, 2 = vector
    /// width); the global and cluster sweeps report 1 for their
    /// top-level loops.
    pub depth: usize,
}

impl Progress {
    /// Points-per-second rate, 0.0 while `elapsed` is still zero.
    pub fn rate_of(points: usize, elapsed: Duration) -> f64 {
        let secs = elapsed.as_secs_f64();
        if secs > 0.0 {
            points as f64 / secs
        } else {
            0.0
        }
    }

    /// One NDJSON line (no trailing newline) describing this event — the
    /// schema shared by the CLI's `--progress` stream and the service's
    /// `GET /jobs/:id/events` SSE data frames.
    pub fn to_ndjson(&self) -> String {
        self.to_ndjson_with("")
    }

    /// Like [`Progress::to_ndjson`], but tagged with the request
    /// correlation id when one is known (empty = omitted), so every SSE
    /// data frame of a job greps back to the submitting request's logs.
    pub fn to_ndjson_with(&self, corr: &str) -> String {
        let mut o = crate::util::json::Obj::new()
            .str("phase", self.phase)
            .u64("ms", self.elapsed.as_millis() as u64)
            .u64("points", self.points as u64)
            .f64("best", self.best_score)
            .f64("rate", self.rate)
            .u64("depth", self.depth as u64);
        if !corr.is_empty() {
            o = o.str("corr", corr);
        }
        o.finish()
    }
}

/// Observer of search progress; also the cancellation channel.
pub trait ProgressSink {
    /// Observe one step. Return `false` to request cooperative
    /// cancellation: the search stops exploring and returns best-so-far.
    fn on_progress(&mut self, p: &Progress) -> bool;
}

/// Ignores progress and never cancels.
pub struct NullSink;

impl ProgressSink for NullSink {
    fn on_progress(&mut self, _p: &Progress) -> bool {
        true
    }
}

/// Any `FnMut(&Progress) -> bool` closure is a sink.
impl<F: FnMut(&Progress) -> bool> ProgressSink for F {
    fn on_progress(&mut self, p: &Progress) -> bool {
        self(p)
    }
}

/// Cancels cooperatively once a wall-clock budget is exhausted,
/// forwarding every event to an optional inner sink first.
pub struct DeadlineSink<'a> {
    deadline: Instant,
    inner: Option<&'a mut (dyn ProgressSink + 'a)>,
}

impl<'a> DeadlineSink<'a> {
    /// Cancel all searches `budget` from now.
    pub fn new(budget: Duration) -> Self {
        Self { deadline: Instant::now() + budget, inner: None }
    }

    /// Like [`DeadlineSink::new`], but still forwarding events to (and
    /// honoring cancellations from) `inner`.
    pub fn wrapping(budget: Duration, inner: &'a mut (dyn ProgressSink + 'a)) -> Self {
        Self { deadline: Instant::now() + budget, inner: Some(inner) }
    }
}

impl ProgressSink for DeadlineSink<'_> {
    fn on_progress(&mut self, p: &Progress) -> bool {
        let inner_go = match self.inner.as_mut() {
            Some(s) => s.on_progress(p),
            None => true,
        };
        inner_go && Instant::now() < self.deadline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step() -> Progress {
        Progress {
            phase: "search",
            elapsed: Duration::ZERO,
            points: 1,
            best_score: 1.0,
            rate: 0.0,
            depth: 1,
        }
    }

    #[test]
    fn ndjson_corr_tag_is_optional() {
        assert!(!step().to_ndjson().contains("corr"));
        assert!(step().to_ndjson_with("r-1-0001").contains("\"corr\":\"r-1-0001\""));
    }

    #[test]
    fn rate_of_handles_zero_elapsed() {
        assert_eq!(Progress::rate_of(5, Duration::ZERO), 0.0);
        assert_eq!(Progress::rate_of(10, Duration::from_secs(2)), 5.0);
    }

    #[test]
    fn null_sink_never_cancels() {
        assert!(NullSink.on_progress(&step()));
    }

    #[test]
    fn closure_is_a_sink() {
        let mut seen = 0usize;
        let mut sink = |p: &Progress| {
            seen += p.points;
            true
        };
        assert!(ProgressSink::on_progress(&mut sink, &step()));
        assert_eq!(seen, 1);
    }

    #[test]
    fn zero_deadline_cancels_immediately() {
        let mut d = DeadlineSink::new(Duration::ZERO);
        assert!(!d.on_progress(&step()));
    }

    #[test]
    fn wrapping_honors_inner_cancellation() {
        let mut inner = |_: &Progress| false;
        let mut d = DeadlineSink::wrapping(Duration::from_secs(3600), &mut inner);
        assert!(!d.on_progress(&step()));
    }
}
