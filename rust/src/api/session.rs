//! The [`Session`] facade: one cost backend (plus an optional shared
//! design database) executing validated plans into typed replies.
//!
//! Every front door funnels here — `main.rs` subcommands, the HTTP
//! service's worker threads (one session each; PJRT clients are not
//! `Sync`), and library callers (`examples/api_session.rs`). The
//! TPUv2 floor, the design-database context scoping, and the reply
//! assembly exist only in this file.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::error::ApiError;
use crate::api::plan::{
    context_key, ClusterPlan, CommonPlan, EvaluatePlan, GlobalPlan, SearchPlan,
};
use crate::api::progress::{DeadlineSink, NullSink, ProgressSink};
use crate::api::reply::{
    ClusterReply, CommonReply, EvaluateReply, GlobalReply, GlobalRow, ModelEntry, ModelsReply,
    SearchReply, StrategyRow,
};
use crate::api::request::{
    ClusterRequest, CommonRequest, EvaluateRequest, GlobalRequest, SearchRequest,
};
use crate::arch::presets;
use crate::coordinator::{make_backend, BackendChoice};
use crate::cost::{CostBackend, Dims};
use crate::distributed::global_search::{
    global_search_observed, GlobalOptions, ModelPipelineResult,
};
use crate::distributed::network::Network;
use crate::graph::OperatorGraph;
use crate::metrics::{Evaluation, Metric};
use crate::search::common::{search_common, Workload};
use crate::search::engine::{evaluate_design, NoSharedCache, SearchOptions, WhamSearch};
use crate::search::DesignPoint;
use crate::service::cache::DesignDb;

/// TPUv2 baseline evaluation of a workload — the single definition of
/// the Perf/TDP throughput floor (paper section 6.1) and of the
/// `vs_tpuv2` comparison denominator.
pub fn tpuv2_baseline(
    graph: &OperatorGraph,
    batch: u64,
    backend: &mut dyn CostBackend,
) -> Evaluation {
    evaluate_design(graph, batch, &presets::tpuv2(), backend)
}

/// The Perf/TDP throughput floor: what a TPUv2 sustains on the workload.
pub fn tpuv2_floor(graph: &OperatorGraph, batch: u64, backend: &mut dyn CostBackend) -> f64 {
    tpuv2_baseline(graph, batch, backend).throughput
}

fn ratio(num: f64, denom: f64) -> f64 {
    num / denom.max(1e-12)
}

/// One mining session: a cost backend plus an optional shared design
/// database, executing requests (or pre-validated plans) into replies.
pub struct Session {
    backend: Box<dyn CostBackend>,
    db: Option<Arc<DesignDb>>,
    /// `(fingerprint, batch)` → (TPUv2, NVDLA) baseline evaluations, so
    /// warm repeat searches skip the two baseline scheduler runs. Valid
    /// for the session's lifetime because the backend never changes.
    baselines: HashMap<(u64, u64), (Evaluation, Evaluation)>,
    /// Worker threads for the engine's sibling-evaluation fan-out and
    /// the global search's per-stage local searches (1 = serial; the CLI
    /// sets `--jobs`, the service derives a per-request budget from its
    /// worker count). Outcome-preserving — see `SearchOptions::jobs`.
    jobs: usize,
}

impl Session {
    /// Session over a backend choice (`auto` falls back to native).
    pub fn new(choice: BackendChoice) -> Result<Self, ApiError> {
        make_backend(choice)
            .map(Self::with_backend)
            .map_err(|e| ApiError::internal(format!("cost backend unavailable: {e}")))
    }

    /// Session over an already-built backend.
    pub fn with_backend(backend: Box<dyn CostBackend>) -> Self {
        Self { backend, db: None, baselines: HashMap::new(), jobs: 1 }
    }

    /// Attach a shared design database: searches are answered from (and
    /// mined points persisted to) it, scoped by [`context_key`].
    pub fn with_db(mut self, db: Arc<DesignDb>) -> Self {
        self.db = Some(db);
        self
    }

    /// Evaluation fan-out width for this session's searches (clamped to
    /// at least 1). Results are identical at any width.
    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs.max(1);
        self
    }

    /// Name of the cost backend this session evaluates with.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Mutable access to the session's cost backend — for callers that
    /// need raw engine access (graph annotation, traces) without paying
    /// for a second backend.
    pub fn backend_mut(&mut self) -> &mut dyn CostBackend {
        self.backend.as_mut()
    }

    /// Every workload the registry can resolve: the Table-4 zoo plus
    /// registered specs (user dir / uploads), each tagged with its
    /// registry layer.
    pub fn models(&self) -> ModelsReply {
        ModelsReply {
            models: crate::workload::all_entries()
                .into_iter()
                .map(|e| ModelEntry {
                    name: e.name,
                    task: e.task,
                    batch: e.batch,
                    accelerators: e.accelerators,
                    distributed_only: e.distributed_only,
                    source: e.source.label().to_string(),
                })
                .collect(),
        }
    }

    /// Validate and run a per-workload search.
    pub fn search(&mut self, req: &SearchRequest) -> Result<SearchReply, ApiError> {
        self.run_search(&req.validate()?, &mut NullSink)
    }

    /// Run a pre-validated search plan, streaming progress to `sink`.
    pub fn run_search(
        &mut self,
        plan: &SearchPlan,
        sink: &mut dyn ProgressSink,
    ) -> Result<SearchReply, ApiError> {
        let t0 = Instant::now();
        let backend = self.backend.as_mut();
        // The reply's vs_tpuv2 / vs_nvdla fields (and the Perf/TDP floor)
        // need the two baseline evaluations; the memo bounds that cost to
        // two scheduler runs per (workload, batch) per session.
        let (tpu, nvdla) =
            *self.baselines.entry((plan.fingerprint.0, plan.batch)).or_insert_with(|| {
                (
                    tpuv2_baseline(&plan.graph, plan.batch, backend),
                    evaluate_design(&plan.graph, plan.batch, &presets::nvdla_scaled(), backend),
                )
            });
        let mut opts = plan.opts;
        opts.jobs = self.jobs;
        if opts.metric == Metric::PerfPerTdp {
            opts.min_throughput = tpu.throughput;
        }
        let mut guard;
        let sink: &mut dyn ProgressSink = match plan.deadline_ms {
            Some(ms) => {
                guard = DeadlineSink::wrapping(Duration::from_millis(ms), sink);
                &mut guard
            }
            None => sink,
        };
        let search = WhamSearch::new(&plan.graph, plan.batch, opts);
        let r = match &self.db {
            Some(db) => {
                let ctx = context_key(plan.fingerprint, plan.batch, &opts, backend.name());
                let mut cache = db.scoped(ctx);
                search.run_with(backend, &mut cache, sink)
            }
            None => {
                let mut cache: HashMap<Dims, DesignPoint> = HashMap::new();
                search.run_with(backend, &mut cache, sink)
            }
        };
        Ok(SearchReply {
            model: plan.model.clone(),
            fingerprint: plan.fingerprint,
            backend: backend.name().to_string(),
            metric: opts.metric,
            vs_tpuv2: ratio(r.best.eval.throughput, tpu.throughput),
            vs_nvdla: ratio(r.best.eval.throughput, nvdla.throughput),
            best: r.best,
            top: r.top.points().to_vec(),
            dims_evaluated: r.dims_evaluated as u64,
            scheduler_evals: r.scheduler_evals as u64,
            cache_hits: r.cache_hits as u64,
            cancelled: r.cancelled,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            explain: if plan.explain { Some(r.explain) } else { None },
        })
    }

    /// Evaluate one fixed design on a workload.
    pub fn evaluate(&mut self, req: &EvaluateRequest) -> Result<EvaluateReply, ApiError> {
        self.run_evaluate(&req.validate()?)
    }

    /// Run a pre-validated evaluate plan.
    pub fn run_evaluate(&mut self, plan: &EvaluatePlan) -> Result<EvaluateReply, ApiError> {
        let eval = evaluate_design(&plan.graph, plan.batch, &plan.config, self.backend.as_mut());
        Ok(EvaluateReply {
            model: plan.model.clone(),
            fingerprint: plan.fingerprint,
            config: plan.config,
            eval,
        })
    }

    /// Validate and run a WHAM-common search over a workload set.
    pub fn common(&mut self, req: &CommonRequest) -> Result<CommonReply, ApiError> {
        self.run_common(&req.validate()?)
    }

    /// Run a pre-validated common plan.
    pub fn run_common(&mut self, plan: &CommonPlan) -> Result<CommonReply, ApiError> {
        let t0 = Instant::now();
        let backend = self.backend.as_mut();
        let workloads: Vec<Workload<'_>> = plan
            .workloads
            .iter()
            .map(|(name, graph, batch)| {
                let min = if plan.opts.metric == Metric::PerfPerTdp {
                    tpuv2_floor(graph, *batch, backend)
                } else {
                    0.0
                };
                Workload {
                    name: name.clone(),
                    graph,
                    batch: *batch,
                    min_throughput: min,
                    weight: 1.0,
                }
            })
            .collect();
        let r = search_common(&workloads, plan.opts, backend);
        let per_workload: Vec<(String, DesignPoint)> =
            plan.models.iter().cloned().zip(r.per_workload.iter().copied()).collect();
        Ok(CommonReply {
            models: plan.models.clone(),
            metric: plan.opts.metric,
            backend: backend.name().to_string(),
            config: r.best.0,
            score: r.best.1,
            per_workload,
            dims_evaluated: r.dims_evaluated as u64,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Validate and run the distributed global search.
    pub fn global(&mut self, req: &GlobalRequest) -> Result<GlobalReply, ApiError> {
        self.run_global(&req.validate()?, &mut NullSink)
    }

    /// Run a pre-validated global plan, streaming progress to `sink`.
    pub fn run_global(
        &mut self,
        plan: &GlobalPlan,
        sink: &mut dyn ProgressSink,
    ) -> Result<GlobalReply, ApiError> {
        let t0 = Instant::now();
        let backend = self.backend.as_mut();
        let net = Network::default();
        // TPUv2 pipeline baseline, simulated once per model: both the
        // Perf/TDP floor and the `vs_tpuv2` denominator.
        let tpu: Vec<f64> = plan
            .parts
            .iter()
            .map(|p| {
                let cfgs = vec![presets::tpuv2(); p.stages.len()];
                crate::distributed::pipeline::simulate(p, &cfgs, plan.scheme, &net, backend)
                    .throughput
            })
            .collect();
        let local = SearchOptions {
            metric: plan.metric,
            top_k: plan.top_k,
            hysteresis: plan.hysteresis,
            use_ilp: plan.use_ilp,
            ..Default::default()
        };
        let mut gopts = GlobalOptions {
            metric: plan.metric,
            scheme: plan.scheme,
            top_k: plan.top_k,
            local,
            jobs: self.jobs,
            ..Default::default()
        };
        if plan.metric == Metric::PerfPerTdp {
            gopts.min_throughput = tpu.iter().copied().fold(f64::INFINITY, f64::min);
        }
        let mut guard;
        let sink: &mut dyn ProgressSink = match plan.deadline_ms {
            Some(ms) => {
                guard = DeadlineSink::wrapping(Duration::from_millis(ms), sink);
                &mut guard
            }
            None => sink,
        };
        let r = match &self.db {
            Some(db) => global_search_observed(&plan.parts, &gopts, &net, backend, &**db, sink),
            None => {
                global_search_observed(&plan.parts, &gopts, &net, backend, &NoSharedCache, sink)
            }
        };
        let family = |list: &[ModelPipelineResult]| -> Vec<GlobalRow> {
            list.iter()
                .enumerate()
                .map(|(i, m)| {
                    let uniq: std::collections::BTreeSet<String> =
                        m.configs.iter().map(|c| c.display()).collect();
                    GlobalRow {
                        model: m.model.clone(),
                        configs: uniq.into_iter().collect(),
                        throughput: m.eval.throughput,
                        perf_per_tdp: m.eval.perf_per_tdp,
                        vs_tpuv2: ratio(m.eval.throughput, tpu[i]),
                    }
                })
                .collect()
        };
        Ok(GlobalReply {
            models: plan.models.clone(),
            depth: plan.depth,
            tmp: plan.tmp,
            scheme: plan.scheme,
            metric: plan.metric,
            backend: backend.name().to_string(),
            candidate_pool: r.candidate_pool as u64,
            candidates_evaluated: r.candidates_evaluated as u64,
            local_searches: r.local_searches as u64,
            common_config: r.common.0,
            common: family(&r.common.1),
            individual: family(&r.individual),
            mosaic: family(&r.mosaic),
            cancelled: r.cancelled,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Validate and run a cluster parallelism-strategy sweep.
    pub fn cluster(&mut self, req: &ClusterRequest) -> Result<ClusterReply, ApiError> {
        self.run_cluster(&req.validate()?, &mut NullSink)
    }

    /// Run a pre-validated cluster plan, streaming progress to `sink`.
    /// The mining phase shares the session's design database (per-stage
    /// points cached under the stage-graph fingerprints), so repeat
    /// sweeps over the same strategies mine for free.
    pub fn run_cluster(
        &mut self,
        plan: &ClusterPlan,
        sink: &mut dyn ProgressSink,
    ) -> Result<ClusterReply, ApiError> {
        let t0 = Instant::now();
        let backend = self.backend.as_mut();
        let local = SearchOptions {
            metric: plan.metric,
            top_k: plan.top_k,
            hysteresis: plan.hysteresis,
            use_ilp: plan.use_ilp,
            ..Default::default()
        };
        let opts = crate::cluster::SweepOptions {
            devices: plan.devices,
            topology: plan.topology.clone(),
            schedules: plan.schedules.clone(),
            metric: plan.metric,
            mine_top: plan.mine_top as usize,
            chunks: plan.chunks,
            local,
            jobs: self.jobs,
            ..Default::default()
        };
        let mut guard;
        let sink: &mut dyn ProgressSink = match plan.deadline_ms {
            Some(ms) => {
                guard = DeadlineSink::wrapping(Duration::from_millis(ms), sink);
                &mut guard
            }
            None => sink,
        };
        let r = match &self.db {
            Some(db) => {
                crate::cluster::sweep(&plan.model, &plan.cfg, &opts, backend, &**db, sink)
            }
            None => crate::cluster::sweep(
                &plan.model,
                &plan.cfg,
                &opts,
                backend,
                &NoSharedCache,
                sink,
            ),
        }
        // The plan pre-validated the topology and schedules, so a sweep
        // error here is an internal inconsistency, not a caller error.
        .map_err(ApiError::internal)?;
        let row = |p: &crate::cluster::StrategyPoint| StrategyRow {
            pp: p.pp,
            tp: p.tp,
            dp: p.dp,
            chunks: p.chunks,
            schedule: p.schedule.clone(),
            micro_batch: p.micro_batch,
            num_micro: p.num_micro,
            config: p.config,
            mined: p.mined,
            iter_seconds: p.iter_seconds,
            throughput: p.throughput,
            perf_per_tdp: p.perf_per_tdp,
            bubble_fraction: p.bubble_fraction,
            fits_hbm: p.fits_hbm,
        };
        Ok(ClusterReply {
            model: r.model,
            devices: r.devices,
            topology: r.topology,
            metric: r.metric,
            backend: backend.name().to_string(),
            candidates: r.candidates as u64,
            mined: r.mined as u64,
            baseline: row(&r.baseline),
            ranked: r.ranked.iter().map(row).collect(),
            cancelled: r.cancelled,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::native::NativeCost;

    fn session() -> Session {
        Session::with_backend(Box::new(NativeCost))
    }

    #[test]
    fn models_reply_lists_the_zoo() {
        // Other tests in this binary may register specs in the global
        // registry; the builtin layer is always exactly the Table-4 zoo.
        let reply = session().models();
        let builtin = reply.models.iter().filter(|m| m.source == "builtin").count();
        assert_eq!(builtin, crate::models::MODELS.len());
        assert!(reply.models.len() >= builtin);
    }

    #[test]
    fn registered_spec_is_searchable_through_a_session() {
        crate::workload::add_spec_text(
            r#"{"name":"session-test-mlp","batch":2,"graph":[
                {"op":"embed","elems":64,"params":32},
                {"op":"linear","m":8,"n":8,"k":8},
                {"op":"activation","elems":64}
            ]}"#,
            crate::workload::Source::Uploaded,
        )
        .unwrap();
        let mut s = session();
        let reply = s.search(&SearchRequest::new("session-test-mlp")).unwrap();
        assert_eq!(reply.model, "session-test-mlp");
        assert!(reply.best.config.in_template());
        assert!(reply.dims_evaluated > 0);
        assert!(s.models().models.iter().any(|m| m.name == "session-test-mlp"
            && m.source == "uploaded"));
    }

    #[test]
    fn evaluate_matches_engine_direct() {
        let mut s = session();
        let req = EvaluateRequest::new("bert-base", presets::tpuv2());
        let reply = s.evaluate(&req).unwrap();
        let (graph, batch) = crate::api::plan::resolve_workload("bert-base").unwrap();
        let direct = evaluate_design(&graph, batch, &presets::tpuv2(), &mut NativeCost);
        assert_eq!(reply.eval.cycles, direct.cycles);
        assert_eq!(reply.model, "bert-base");
    }

    #[test]
    fn zero_deadline_cancels_search_quickly() {
        let mut s = session();
        let reply = s.search(&SearchRequest::new("bert-base").deadline_ms(0)).unwrap();
        assert!(reply.cancelled, "zero deadline must cancel");
        assert!(
            reply.dims_evaluated <= 2,
            "cancelled search explored {} dims",
            reply.dims_evaluated
        );
        assert!(reply.best.config.in_template());
    }

    #[test]
    fn cluster_sweep_runs_through_a_session() {
        let mut s = session();
        let req = ClusterRequest::new("bert-base")
            .devices(2)
            .schedules(["gpipe"])
            .mine_top(0);
        let reply = s.cluster(&req).unwrap();
        assert_eq!(reply.model, "bert-base");
        assert_eq!(reply.devices, 2);
        assert!(reply.candidates >= 2, "only {} candidates", reply.candidates);
        assert_eq!(reply.ranked.len(), reply.candidates as usize);
        assert!(reply.ranked[0].throughput >= reply.baseline.throughput);
        for w in reply.ranked.windows(2) {
            assert!(w[0].throughput >= w[1].throughput);
        }
    }

    #[test]
    fn explain_rows_attach_only_when_requested() {
        let mut s = session();
        let plain = s.search(&SearchRequest::new("bert-base")).unwrap();
        assert!(plain.explain.is_none(), "unrequested replies must omit explain");
        let with = s.search(&SearchRequest::new("bert-base").explain(true)).unwrap();
        let rows = with.explain.expect("requested explain rows");
        let cap = crate::telemetry::FlightRecorder::DEFAULT_CAP as u64;
        assert_eq!(rows.len() as u64, with.dims_evaluated.min(cap));
        assert!(rows.iter().any(|r| !r.cache_hit), "cold search must have misses");
    }

    #[test]
    fn shared_db_answers_repeat_searches_without_scheduler() {
        let db = Arc::new(DesignDb::in_memory());
        let mut s = Session::with_backend(Box::new(NativeCost)).with_db(Arc::clone(&db));
        let req = SearchRequest::new("bert-base");
        let cold = s.search(&req).unwrap();
        assert!(cold.scheduler_evals > 0);
        let warm = s.search(&req).unwrap();
        assert_eq!(warm.scheduler_evals, 0);
        assert_eq!(warm.best.config, cold.best.config);
        assert_eq!(warm.cache_hits, warm.dims_evaluated);
    }
}
