//! `wham::api` — the typed request/plan/reply layer every front door
//! shares.
//!
//! The mining core (search engine, WHAM-common, distributed global
//! search) is reachable from many scenarios: the one-shot CLI, the
//! long-running HTTP service, `wham client`, and library callers. Before
//! this module each of those re-implemented workload resolution, option
//! parsing, the TPUv2 Perf/TDP floor, cache/coalescing keys, and JSON —
//! and they had drifted (the service defaulted a missing batch to 1
//! where the CLI errored; `/global` emitted Rust `Debug` strings as
//! JSON). Now there is exactly one path:
//!
//! ```text
//! request ── validate() ──> plan ── Session::run_*() ──> reply
//!    │                        │                            │
//!    ├ builders (library)     ├ context_key (design DB)    ├ ToJson (wire out)
//!    ├ from_args (CLI)        └ coalescing_key (single-    └ FromJson (wire in)
//!    └ FromJson (HTTP)             flight)
//! ```
//!
//! * [`request`] — [`SearchRequest`], [`EvaluateRequest`],
//!   [`CommonRequest`], [`GlobalRequest`]: builders, CLI-flag parsing,
//!   wire codec, validation.
//! * [`job`] — [`JobRequest`]/[`JobReply`]: the async job tier's wire
//!   types, wrapping the long-running requests for [`crate::jobs`].
//! * [`plan`] — validated, executable work + the canonical
//!   [`context_key`](plan::context_key) / coalescing-key derivations.
//! * [`reply`] — [`SearchReply`], [`EvaluateReply`], [`CommonReply`],
//!   [`GlobalReply`], [`ModelsReply`], [`StatusReply`]: typed results
//!   with a symmetric wire codec.
//! * [`session`] — the [`Session`] facade owning the cost backend and
//!   optional design database.
//! * [`progress`] — [`ProgressSink`]: trajectory streaming plus
//!   cooperative deadline/cancellation, threaded through the engine.
//! * [`error`] — [`ApiError`] with an HTTP-status mapping.
//! * [`wire`] — the [`ToJson`]/[`FromJson`] traits and strict field
//!   accessors.

pub mod error;
pub mod job;
pub mod plan;
pub mod progress;
pub mod reply;
pub mod request;
pub mod session;
pub mod wire;

pub use error::{ApiError, ErrorKind};
pub use job::{
    DbImportReply, JobKind, JobListReply, JobPlan, JobReply, JobRequest, JobSpec, JobState,
};
pub use plan::{context_key, resolve_workload};
pub use progress::{DeadlineSink, NullSink, Progress, ProgressSink};
pub use reply::{
    ClusterReply, CommonReply, EvaluateReply, GlobalReply, GlobalRow, ModelEntry, ModelsReply,
    SearchReply, StatusReply, StrategyRow, WorkloadReply,
};
pub use request::{
    ClusterRequest, CommonRequest, EvaluateRequest, GlobalRequest, SearchRequest,
};
pub use session::{tpuv2_floor, Session};
pub use wire::{FromJson, ToJson};
