//! Typed replies — the single definition of every front door's outputs.
//!
//! Each reply implements [`ToJson`] (what the service and `wham client`
//! emit) and [`FromJson`] (what clients and tests parse), so wire bytes
//! are produced and consumed by the same code on both ends. Field names
//! and meanings are wire-compatible with the pre-`api` hand-rolled
//! service JSON; additions (`vs_tpuv2`, `vs_nvdla`, `config_vec`,
//! `cancelled`, …) only ever extend objects.

use crate::api::error::ApiError;
use crate::api::request::scheme_wire_name;
use crate::api::wire::{
    config_arr, opt_str, opt_u64, parse_config, parse_design_point, req_arr, req_bool, req_f64,
    req_str, req_u64, FromJson, ToJson,
};
use crate::arch::ArchConfig;
use crate::cost::Dims;
use crate::distributed::Scheme;
use crate::graph::Fingerprint;
use crate::metrics::{Evaluation, Metric};
use crate::search::DesignPoint;
use crate::telemetry::ExplainRecord;
use crate::util::json::{arr, str_arr, JsonValue, Obj};

fn parse_fingerprint(v: &JsonValue) -> Result<Fingerprint, ApiError> {
    Fingerprint::parse(&req_str(v, "fingerprint")?)
        .ok_or_else(|| ApiError::invalid("\"fingerprint\" must be 16 hex digits"))
}

fn parse_metric_field(v: &JsonValue) -> Result<Metric, ApiError> {
    req_str(v, "metric")?.parse().map_err(ApiError::invalid)
}

fn parse_points(v: &JsonValue, key: &str) -> Result<Vec<DesignPoint>, ApiError> {
    req_arr(v, key)?
        .iter()
        .map(|p| {
            parse_design_point(p)
                .ok_or_else(|| ApiError::invalid(format!("malformed design point in \"{key}\"")))
        })
        .collect()
}

// ---- GET /models --------------------------------------------------------

/// One workload-registry row: a Table-4 builtin or a registered spec.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelEntry {
    pub name: String,
    pub task: String,
    pub batch: u64,
    pub accelerators: u64,
    pub distributed_only: bool,
    /// Registry layer: `"builtin"` | `"user"` | `"uploaded"`.
    pub source: String,
}

/// Reply of `GET /models` / [`crate::api::Session::models`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelsReply {
    pub models: Vec<ModelEntry>,
}

impl ToJson for ModelsReply {
    fn to_json(&self) -> String {
        let rows = self.models.iter().map(|m| {
            Obj::new()
                .str("name", &m.name)
                .str("task", &m.task)
                .u64("batch", m.batch)
                .u64("accelerators", m.accelerators)
                .bool("distributed_only", m.distributed_only)
                .str("source", &m.source)
                .finish()
        });
        Obj::new().raw("models", &arr(rows)).finish()
    }
}

impl FromJson for ModelsReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let models = req_arr(v, "models")?
            .iter()
            .map(|m| {
                Ok(ModelEntry {
                    name: req_str(m, "name")?,
                    task: req_str(m, "task")?,
                    batch: req_u64(m, "batch")?,
                    accelerators: req_u64(m, "accelerators")?,
                    distributed_only: req_bool(m, "distributed_only")?,
                    // Lenient for pre-registry replies.
                    source: opt_str(m, "source")?.unwrap_or_else(|| "builtin".to_string()),
                })
            })
            .collect::<Result<_, ApiError>>()?;
        Ok(Self { models })
    }
}

// ---- POST /workloads ----------------------------------------------------

/// Reply of `POST /workloads`: the registered spec's identity plus the
/// lowering stats callers need to sanity-check what they uploaded.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadReply {
    pub name: String,
    /// Fingerprint of the lowered training graph — the same key `/search`
    /// replies carry and the design database is scoped by.
    pub fingerprint: Fingerprint,
    pub batch: u64,
    pub forward_ops: u64,
    pub training_ops: u64,
    /// Registry layer the spec landed in (`"uploaded"` for this endpoint).
    pub source: String,
}

impl ToJson for WorkloadReply {
    fn to_json(&self) -> String {
        Obj::new()
            .str("name", &self.name)
            .str("fingerprint", &self.fingerprint.to_string())
            .u64("batch", self.batch)
            .u64("forward_ops", self.forward_ops)
            .u64("training_ops", self.training_ops)
            .str("source", &self.source)
            .finish()
    }
}

impl FromJson for WorkloadReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        Ok(Self {
            name: req_str(v, "name")?,
            fingerprint: parse_fingerprint(v)?,
            batch: req_u64(v, "batch")?,
            forward_ops: req_u64(v, "forward_ops")?,
            training_ops: req_u64(v, "training_ops")?,
            source: req_str(v, "source")?,
        })
    }
}

// ---- POST /search -------------------------------------------------------

/// Reply of `POST /search` / [`crate::api::Session::search`].
#[derive(Debug, Clone)]
pub struct SearchReply {
    pub model: String,
    pub fingerprint: Fingerprint,
    pub backend: String,
    pub metric: Metric,
    pub best: DesignPoint,
    pub top: Vec<DesignPoint>,
    pub dims_evaluated: u64,
    pub scheduler_evals: u64,
    pub cache_hits: u64,
    /// Best-design throughput over the TPUv2 baseline's.
    pub vs_tpuv2: f64,
    /// Best-design throughput over the scaled-NVDLA baseline's.
    pub vs_nvdla: f64,
    /// True when a deadline/cancellation truncated the search.
    pub cancelled: bool,
    pub wall_ms: f64,
    /// Flight-recorder attribution of the search's most recent
    /// iterations ([`crate::telemetry::FlightRecorder`]). Only attached
    /// when the request asked for it (`"explain": true`); omitted from
    /// the wire form when `None`, so pre-telemetry replies are
    /// byte-identical.
    pub explain: Option<Vec<ExplainRecord>>,
}

/// Wire form of one flight-recorder record (`"explain"` rows).
fn explain_record_json(r: &ExplainRecord) -> String {
    Obj::new()
        .raw("dims", &format!("[{},{},{}]", r.dims.tc_x, r.dims.tc_y, r.dims.vc_w))
        .f64("score", r.score)
        .f64("best", r.best)
        .bool("improved", r.improved)
        .bool("cache_hit", r.cache_hit)
        .u64("evals", r.evals)
        .raw("cores", &format!("[{},{}]", r.cores.0, r.cores.1))
        .raw("grants", &format!("[{},{},{}]", r.grants.0, r.grants.1, r.grants.2))
        .nullable_str("conflict_op", r.conflict_op.as_deref())
        .finish()
}

fn parse_explain_record(v: &JsonValue) -> Option<ExplainRecord> {
    let d = v.get("dims")?.as_arr()?;
    let cores = v.get("cores")?.as_arr()?;
    let grants = v.get("grants")?.as_arr()?;
    if d.len() != 3 || cores.len() != 2 || grants.len() != 3 {
        return None;
    }
    let conflict_op = match v.get("conflict_op") {
        None | Some(JsonValue::Null) => None,
        Some(s) => Some(s.as_str()?.to_string()),
    };
    Some(ExplainRecord {
        dims: Dims { tc_x: d[0].as_u64()?, tc_y: d[1].as_u64()?, vc_w: d[2].as_u64()? },
        score: v.get("score")?.as_f64()?,
        best: v.get("best")?.as_f64()?,
        improved: v.get("improved")?.as_bool()?,
        cache_hit: v.get("cache_hit")?.as_bool()?,
        evals: v.get("evals")?.as_u64()?,
        cores: (cores[0].as_u64()?, cores[1].as_u64()?),
        grants: (grants[0].as_u64()?, grants[1].as_u64()?, grants[2].as_u64()?),
        conflict_op,
    })
}

/// Lenient `"explain"` parse: absent or null means not requested.
fn parse_explain(v: &JsonValue) -> Result<Option<Vec<ExplainRecord>>, ApiError> {
    let a = match v.get("explain") {
        None | Some(JsonValue::Null) => return Ok(None),
        Some(x) => x
            .as_arr()
            .ok_or_else(|| ApiError::invalid("\"explain\" must be an array"))?,
    };
    a.iter()
        .map(|r| {
            parse_explain_record(r)
                .ok_or_else(|| ApiError::invalid("malformed \"explain\" record"))
        })
        .collect::<Result<_, _>>()
        .map(Some)
}

impl ToJson for SearchReply {
    fn to_json(&self) -> String {
        let o = Obj::new()
            .str("model", &self.model)
            .str("fingerprint", &self.fingerprint.to_string())
            .str("backend", &self.backend)
            .str("metric", &self.metric.to_string())
            .raw("best", &self.best.to_json())
            .raw("top", &arr(self.top.iter().map(|p| p.to_json())))
            .u64("dims_evaluated", self.dims_evaluated)
            .u64("scheduler_evals", self.scheduler_evals)
            .u64("cache_hits", self.cache_hits)
            .f64("vs_tpuv2", self.vs_tpuv2)
            .f64("vs_nvdla", self.vs_nvdla)
            .bool("cancelled", self.cancelled)
            .f64("wall_ms", self.wall_ms);
        match &self.explain {
            Some(records) => {
                o.raw("explain", &arr(records.iter().map(explain_record_json))).finish()
            }
            None => o.finish(),
        }
    }
}

impl FromJson for SearchReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        Ok(Self {
            model: req_str(v, "model")?,
            fingerprint: parse_fingerprint(v)?,
            backend: req_str(v, "backend")?,
            metric: parse_metric_field(v)?,
            best: DesignPoint::from_json(
                v.get("best").ok_or_else(|| ApiError::invalid("body must include \"best\""))?,
            )?,
            top: parse_points(v, "top")?,
            dims_evaluated: req_u64(v, "dims_evaluated")?,
            scheduler_evals: req_u64(v, "scheduler_evals")?,
            cache_hits: req_u64(v, "cache_hits")?,
            vs_tpuv2: req_f64(v, "vs_tpuv2")?,
            vs_nvdla: req_f64(v, "vs_nvdla")?,
            cancelled: req_bool(v, "cancelled")?,
            wall_ms: req_f64(v, "wall_ms")?,
            explain: parse_explain(v)?,
        })
    }
}

// ---- POST /evaluate -----------------------------------------------------

/// Reply of `POST /evaluate` / [`crate::api::Session::evaluate`].
#[derive(Debug, Clone)]
pub struct EvaluateReply {
    pub model: String,
    pub fingerprint: Fingerprint,
    pub config: ArchConfig,
    pub eval: Evaluation,
}

impl ToJson for EvaluateReply {
    fn to_json(&self) -> String {
        Obj::new()
            .str("model", &self.model)
            .str("fingerprint", &self.fingerprint.to_string())
            // `config` stays the display string for wire compatibility;
            // `config_vec` is the typed form clients parse back.
            .str("config", &self.config.display())
            .raw("config_vec", &config_arr(&self.config))
            .raw("eval", &self.eval.to_json())
            .finish()
    }
}

impl FromJson for EvaluateReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        Ok(Self {
            model: req_str(v, "model")?,
            fingerprint: parse_fingerprint(v)?,
            config: parse_config(
                v.get("config_vec")
                    .ok_or_else(|| ApiError::invalid("body must include \"config_vec\""))?,
            )?,
            eval: Evaluation::from_json(
                v.get("eval").ok_or_else(|| ApiError::invalid("body must include \"eval\""))?,
            )?,
        })
    }
}

// ---- POST /common -------------------------------------------------------

/// Reply of `POST /common` / [`crate::api::Session::common`].
#[derive(Debug, Clone)]
pub struct CommonReply {
    pub models: Vec<String>,
    pub metric: Metric,
    pub backend: String,
    /// The best common config and its weighted score.
    pub config: ArchConfig,
    pub score: f64,
    /// Per-workload design points of the common config, in `models` order.
    pub per_workload: Vec<(String, DesignPoint)>,
    pub dims_evaluated: u64,
    pub wall_ms: f64,
}

impl ToJson for CommonReply {
    fn to_json(&self) -> String {
        let rows = self.per_workload.iter().map(|(name, p)| {
            Obj::new().str("model", name).raw("point", &p.to_json()).finish()
        });
        Obj::new()
            .raw("models", &str_arr(self.models.iter().map(String::as_str)))
            .str("metric", &self.metric.to_string())
            .str("backend", &self.backend)
            .str("config", &self.config.display())
            .raw("config_vec", &config_arr(&self.config))
            .f64("score", self.score)
            .raw("per_workload", &arr(rows))
            .u64("dims_evaluated", self.dims_evaluated)
            .f64("wall_ms", self.wall_ms)
            .finish()
    }
}

impl FromJson for CommonReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let per_workload = req_arr(v, "per_workload")?
            .iter()
            .map(|row| {
                let name = req_str(row, "model")?;
                let p = row
                    .get("point")
                    .and_then(parse_design_point)
                    .ok_or_else(|| ApiError::invalid("malformed \"per_workload\" row"))?;
                Ok((name, p))
            })
            .collect::<Result<_, ApiError>>()?;
        Ok(Self {
            models: crate::api::wire::opt_str_list(v, "models")?
                .ok_or_else(|| ApiError::invalid("body must include \"models\""))?,
            metric: parse_metric_field(v)?,
            backend: req_str(v, "backend")?,
            config: parse_config(
                v.get("config_vec")
                    .ok_or_else(|| ApiError::invalid("body must include \"config_vec\""))?,
            )?,
            score: req_f64(v, "score")?,
            per_workload,
            dims_evaluated: req_u64(v, "dims_evaluated")?,
            wall_ms: req_f64(v, "wall_ms")?,
        })
    }
}

// ---- POST /global -------------------------------------------------------

/// One model's outcome under one design family.
#[derive(Debug, Clone)]
pub struct GlobalRow {
    pub model: String,
    /// Unique per-stage config display strings.
    pub configs: Vec<String>,
    pub throughput: f64,
    pub perf_per_tdp: f64,
    /// Pipeline throughput over the TPUv2-pipeline baseline's.
    pub vs_tpuv2: f64,
}

impl ToJson for GlobalRow {
    fn to_json(&self) -> String {
        Obj::new()
            .str("model", &self.model)
            .raw("configs", &str_arr(self.configs.iter().map(String::as_str)))
            .f64("throughput", self.throughput)
            .f64("perf_per_tdp", self.perf_per_tdp)
            .f64("vs_tpuv2", self.vs_tpuv2)
            .finish()
    }
}

impl FromJson for GlobalRow {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        Ok(Self {
            model: req_str(v, "model")?,
            configs: crate::api::wire::opt_str_list(v, "configs")?
                .ok_or_else(|| ApiError::invalid("row must include \"configs\""))?,
            throughput: req_f64(v, "throughput")?,
            perf_per_tdp: req_f64(v, "perf_per_tdp")?,
            vs_tpuv2: req_f64(v, "vs_tpuv2")?,
        })
    }
}

/// Reply of `POST /global` / [`crate::api::Session::global`].
#[derive(Debug, Clone)]
pub struct GlobalReply {
    pub models: Vec<String>,
    pub depth: u64,
    pub tmp: u64,
    pub scheme: Scheme,
    pub metric: Metric,
    pub backend: String,
    pub candidate_pool: u64,
    pub candidates_evaluated: u64,
    pub local_searches: u64,
    /// The WHAM-common config across stages and models.
    pub common_config: ArchConfig,
    pub common: Vec<GlobalRow>,
    pub individual: Vec<GlobalRow>,
    pub mosaic: Vec<GlobalRow>,
    /// True when a deadline/cancellation truncated the search.
    pub cancelled: bool,
    pub wall_ms: f64,
}

fn rows_json(rows: &[GlobalRow]) -> String {
    arr(rows.iter().map(|r| r.to_json()))
}

fn parse_rows(v: &JsonValue, key: &str) -> Result<Vec<GlobalRow>, ApiError> {
    req_arr(v, key)?.iter().map(GlobalRow::from_json).collect()
}

impl ToJson for GlobalReply {
    fn to_json(&self) -> String {
        Obj::new()
            .raw("models", &str_arr(self.models.iter().map(String::as_str)))
            .u64("depth", self.depth)
            .u64("tmp", self.tmp)
            .str("scheme", scheme_wire_name(self.scheme))
            .str("metric", &self.metric.to_string())
            .str("backend", &self.backend)
            .u64("candidate_pool", self.candidate_pool)
            .u64("candidates_evaluated", self.candidates_evaluated)
            .u64("local_searches", self.local_searches)
            .str("common_config", &self.common_config.display())
            .raw("common_config_vec", &config_arr(&self.common_config))
            .raw("common", &rows_json(&self.common))
            .raw("individual", &rows_json(&self.individual))
            .raw("mosaic", &rows_json(&self.mosaic))
            .bool("cancelled", self.cancelled)
            .f64("wall_ms", self.wall_ms)
            .finish()
    }
}

impl FromJson for GlobalReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        Ok(Self {
            models: crate::api::wire::opt_str_list(v, "models")?
                .ok_or_else(|| ApiError::invalid("body must include \"models\""))?,
            depth: req_u64(v, "depth")?,
            tmp: req_u64(v, "tmp")?,
            scheme: req_str(v, "scheme")?.parse().map_err(ApiError::invalid)?,
            metric: parse_metric_field(v)?,
            backend: req_str(v, "backend")?,
            candidate_pool: req_u64(v, "candidate_pool")?,
            candidates_evaluated: req_u64(v, "candidates_evaluated")?,
            local_searches: req_u64(v, "local_searches")?,
            common_config: parse_config(v.get("common_config_vec").ok_or_else(|| {
                ApiError::invalid("body must include \"common_config_vec\"")
            })?)?,
            common: parse_rows(v, "common")?,
            individual: parse_rows(v, "individual")?,
            mosaic: parse_rows(v, "mosaic")?,
            cancelled: req_bool(v, "cancelled")?,
            wall_ms: req_f64(v, "wall_ms")?,
        })
    }
}

// ---- POST /cluster ------------------------------------------------------

/// One evaluated parallelism strategy of a cluster sweep.
#[derive(Debug, Clone)]
pub struct StrategyRow {
    pub pp: u64,
    pub tp: u64,
    pub dp: u64,
    /// Virtual chunks per device (1 unless interleaved).
    pub chunks: u64,
    /// `gpipe` | `1f1b` | `interleaved`.
    pub schedule: String,
    pub micro_batch: u64,
    pub num_micro: u64,
    /// Accelerator config the numbers were simulated with.
    pub config: ArchConfig,
    /// True when the config came from the global hardware search.
    pub mined: bool,
    pub iter_seconds: f64,
    pub throughput: f64,
    pub perf_per_tdp: f64,
    pub bubble_fraction: f64,
    pub fits_hbm: bool,
}

impl ToJson for StrategyRow {
    fn to_json(&self) -> String {
        Obj::new()
            .u64("pp", self.pp)
            .u64("tp", self.tp)
            .u64("dp", self.dp)
            .u64("chunks", self.chunks)
            .str("schedule", &self.schedule)
            .u64("micro_batch", self.micro_batch)
            .u64("num_micro", self.num_micro)
            .str("config", &self.config.display())
            .raw("config_vec", &config_arr(&self.config))
            .bool("mined", self.mined)
            .f64("iter_seconds", self.iter_seconds)
            .f64("throughput", self.throughput)
            .f64("perf_per_tdp", self.perf_per_tdp)
            .f64("bubble_fraction", self.bubble_fraction)
            .bool("fits_hbm", self.fits_hbm)
            .finish()
    }
}

impl FromJson for StrategyRow {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        Ok(Self {
            pp: req_u64(v, "pp")?,
            tp: req_u64(v, "tp")?,
            dp: req_u64(v, "dp")?,
            chunks: req_u64(v, "chunks")?,
            schedule: req_str(v, "schedule")?,
            micro_batch: req_u64(v, "micro_batch")?,
            num_micro: req_u64(v, "num_micro")?,
            config: parse_config(v.get("config_vec").ok_or_else(|| {
                ApiError::invalid("strategy row must include \"config_vec\"")
            })?)?,
            mined: req_bool(v, "mined")?,
            iter_seconds: req_f64(v, "iter_seconds")?,
            throughput: req_f64(v, "throughput")?,
            perf_per_tdp: req_f64(v, "perf_per_tdp")?,
            bubble_fraction: req_f64(v, "bubble_fraction")?,
            fits_hbm: req_bool(v, "fits_hbm")?,
        })
    }
}

/// Reply of `POST /cluster` / [`crate::api::Session::cluster`].
#[derive(Debug, Clone)]
pub struct ClusterReply {
    pub model: String,
    pub devices: u64,
    pub topology: String,
    pub metric: Metric,
    pub backend: String,
    /// Strategies screened (== `ranked.len()`).
    pub candidates: u64,
    /// Strategies actually upgraded with mined hardware.
    pub mined: u64,
    /// The fixed-(pp, tp) reference strategy.
    pub baseline: StrategyRow,
    /// All strategies, best simulated score first.
    pub ranked: Vec<StrategyRow>,
    pub cancelled: bool,
    pub wall_ms: f64,
}

impl ToJson for ClusterReply {
    fn to_json(&self) -> String {
        Obj::new()
            .str("model", &self.model)
            .u64("devices", self.devices)
            .str("topology", &self.topology)
            .str("metric", &self.metric.to_string())
            .str("backend", &self.backend)
            .u64("candidates", self.candidates)
            .u64("mined", self.mined)
            .raw("baseline", &self.baseline.to_json())
            .raw("ranked", &arr(self.ranked.iter().map(|r| r.to_json())))
            .bool("cancelled", self.cancelled)
            .f64("wall_ms", self.wall_ms)
            .finish()
    }
}

impl FromJson for ClusterReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        Ok(Self {
            model: req_str(v, "model")?,
            devices: req_u64(v, "devices")?,
            topology: req_str(v, "topology")?,
            metric: parse_metric_field(v)?,
            backend: req_str(v, "backend")?,
            candidates: req_u64(v, "candidates")?,
            mined: req_u64(v, "mined")?,
            baseline: StrategyRow::from_json(v.get("baseline").ok_or_else(|| {
                ApiError::invalid("body must include \"baseline\"")
            })?)?,
            ranked: req_arr(v, "ranked")?
                .iter()
                .map(StrategyRow::from_json)
                .collect::<Result<_, _>>()?,
            cancelled: req_bool(v, "cancelled")?,
            wall_ms: req_f64(v, "wall_ms")?,
        })
    }
}

// ---- GET /status --------------------------------------------------------

/// `/search` work counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SearchCounters {
    pub requests: u64,
    /// Leader computations that ran at least one scheduler eval.
    pub cold: u64,
    /// Leader computations answered entirely from the database.
    pub warm: u64,
    pub scheduler_evals_total: u64,
}

/// Single-flight coalescer counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CoalescerCounters {
    pub led: u64,
    pub coalesced: u64,
    pub in_flight: u64,
}

/// Design-database counters.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct DbCounters {
    pub path: Option<String>,
    pub entries: u64,
    pub loaded: u64,
    pub appended: u64,
    pub hits: u64,
    pub misses: u64,
}

/// Wall-clock digest of one endpoint (sliding window of recent requests).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct EndpointStat {
    pub endpoint: String,
    /// Requests served since boot.
    pub count: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

/// Hot-path perf observability (EXPERIMENTS.md section Perf): the
/// counters a service operator needs to see an eval-cost regression
/// without attaching a profiler.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PerfCounters {
    /// Cost-backend rows evaluated process-wide — the unit operator-class
    /// interning shrinks (one row per unique `(kind, shape)` class).
    pub backend_rows_total: u64,
    /// Greedy-scheduler runs process-wide. Unlike
    /// [`SearchCounters::scheduler_evals_total`] (per-`/search` leader
    /// accounting) this includes `/common`, `/global`, and baseline work.
    pub scheduler_evals_total: u64,
    /// Cluster-simulator events (tasks + transfers) process-wide —
    /// the `/cluster` work unit ([`crate::cluster::events_total`]).
    pub cluster_sim_events_total: u64,
    /// Design-database hits / (hits + misses); 0 before any probe.
    pub db_hit_rate: f64,
    /// Per-endpoint latency digests, endpoints that served >= 1 request.
    pub endpoints: Vec<EndpointStat>,
}

/// Async job-tier counters (`wham::jobs`): per-state population of the
/// job store plus dispatcher admission/retry totals. Mirrored one-to-one
/// by the `wham_jobs_*` series of `GET /metrics`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct JobsCounters {
    pub queued: u64,
    pub running: u64,
    pub done: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Jobs currently waiting in the dispatcher queue (== `queued`).
    pub queue_depth: u64,
    /// Age of the oldest still-queued job, 0 when the queue is empty.
    pub oldest_age_ms: u64,
    /// Submissions admitted since boot.
    pub submitted: u64,
    /// Submissions rejected by per-client quota (429).
    pub rejected_quota: u64,
    /// Submissions rejected by queue depth (429).
    pub rejected_depth: u64,
    /// Transient-failure retries scheduled since boot.
    pub retries: u64,
}

/// One alert rule's state as reported by `GET /status` (and mirrored
/// by the `wham_alert_active{rule=...}` gauges of `GET /metrics`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct AlertStatus {
    /// Stable rule id (`job-queue-pressure`).
    pub rule: String,
    /// Operator-facing description of the condition.
    pub describe: String,
    pub active: bool,
    /// When the current firing episode started (0 while resolved).
    pub since_ms: u64,
    /// The rule expression's value at the latest evaluation.
    pub value: f64,
}

/// Reply of `GET /status`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StatusReply {
    pub uptime_ms: u64,
    pub workers: u64,
    pub requests: u64,
    pub search: SearchCounters,
    pub coalescer: CoalescerCounters,
    pub db: DbCounters,
    pub perf: PerfCounters,
    pub jobs: JobsCounters,
    /// Per-rule alert state ([`crate::telemetry::tsdb`]).
    pub alerts: Vec<AlertStatus>,
}

impl ToJson for StatusReply {
    fn to_json(&self) -> String {
        let search = Obj::new()
            .u64("requests", self.search.requests)
            .u64("cold", self.search.cold)
            .u64("warm", self.search.warm)
            .u64("scheduler_evals_total", self.search.scheduler_evals_total)
            .finish();
        let coalescer = Obj::new()
            .u64("led", self.coalescer.led)
            .u64("coalesced", self.coalescer.coalesced)
            .u64("in_flight", self.coalescer.in_flight)
            .finish();
        let db = Obj::new()
            .nullable_str("path", self.db.path.as_deref())
            .u64("entries", self.db.entries)
            .u64("loaded", self.db.loaded)
            .u64("appended", self.db.appended)
            .u64("hits", self.db.hits)
            .u64("misses", self.db.misses)
            .finish();
        let endpoints = arr(self.perf.endpoints.iter().map(|e| {
            Obj::new()
                .str("endpoint", &e.endpoint)
                .u64("count", e.count)
                .f64("p50_ms", e.p50_ms)
                .f64("p95_ms", e.p95_ms)
                .finish()
        }));
        let perf = Obj::new()
            .u64("backend_rows_total", self.perf.backend_rows_total)
            .u64("scheduler_evals_total", self.perf.scheduler_evals_total)
            .u64("cluster_sim_events_total", self.perf.cluster_sim_events_total)
            .f64("db_hit_rate", self.perf.db_hit_rate)
            .raw("endpoints", &endpoints)
            .finish();
        let jobs = Obj::new()
            .u64("queued", self.jobs.queued)
            .u64("running", self.jobs.running)
            .u64("done", self.jobs.done)
            .u64("failed", self.jobs.failed)
            .u64("cancelled", self.jobs.cancelled)
            .u64("queue_depth", self.jobs.queue_depth)
            .u64("oldest_age_ms", self.jobs.oldest_age_ms)
            .u64("submitted", self.jobs.submitted)
            .u64("rejected_quota", self.jobs.rejected_quota)
            .u64("rejected_depth", self.jobs.rejected_depth)
            .u64("retries", self.jobs.retries)
            .finish();
        let alerts = arr(self.alerts.iter().map(|a| {
            Obj::new()
                .str("rule", &a.rule)
                .str("describe", &a.describe)
                .bool("active", a.active)
                .u64("since_ms", a.since_ms)
                .f64("value", a.value)
                .finish()
        }));
        Obj::new()
            .u64("uptime_ms", self.uptime_ms)
            .u64("workers", self.workers)
            .u64("requests", self.requests)
            .raw("search", &search)
            .raw("coalescer", &coalescer)
            .raw("db", &db)
            .raw("perf", &perf)
            .raw("jobs", &jobs)
            .raw("alerts", &alerts)
            .finish()
    }
}

impl FromJson for StatusReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let sub = |key: &str| -> Result<&JsonValue, ApiError> {
            v.get(key).ok_or_else(|| ApiError::invalid(format!("body must include \"{key}\"")))
        };
        let s = sub("search")?;
        let c = sub("coalescer")?;
        let d = sub("db")?;
        // Lenient for pre-perf replies.
        let perf = match v.get("perf") {
            None => PerfCounters::default(),
            Some(p) => PerfCounters {
                backend_rows_total: req_u64(p, "backend_rows_total")?,
                scheduler_evals_total: req_u64(p, "scheduler_evals_total")?,
                // Lenient for pre-cluster replies.
                cluster_sim_events_total: opt_u64(p, "cluster_sim_events_total")?.unwrap_or(0),
                db_hit_rate: req_f64(p, "db_hit_rate")?,
                endpoints: req_arr(p, "endpoints")?
                    .iter()
                    .map(|e| {
                        Ok(EndpointStat {
                            endpoint: req_str(e, "endpoint")?,
                            count: req_u64(e, "count")?,
                            p50_ms: req_f64(e, "p50_ms")?,
                            p95_ms: req_f64(e, "p95_ms")?,
                        })
                    })
                    .collect::<Result<_, ApiError>>()?,
            },
        };
        // Lenient for pre-jobs replies.
        let jobs = match v.get("jobs") {
            None => JobsCounters::default(),
            Some(j) => JobsCounters {
                queued: req_u64(j, "queued")?,
                running: req_u64(j, "running")?,
                done: req_u64(j, "done")?,
                failed: req_u64(j, "failed")?,
                cancelled: req_u64(j, "cancelled")?,
                queue_depth: req_u64(j, "queue_depth")?,
                oldest_age_ms: req_u64(j, "oldest_age_ms")?,
                submitted: req_u64(j, "submitted")?,
                rejected_quota: req_u64(j, "rejected_quota")?,
                rejected_depth: req_u64(j, "rejected_depth")?,
                retries: req_u64(j, "retries")?,
            },
        };
        // Lenient for pre-alert-engine replies.
        let alerts = match v.get("alerts") {
            None => Vec::new(),
            Some(a) => a
                .as_arr()
                .ok_or_else(|| ApiError::invalid("\"alerts\" must be an array"))?
                .iter()
                .map(|e| {
                    Ok(AlertStatus {
                        rule: req_str(e, "rule")?,
                        describe: req_str(e, "describe")?,
                        active: req_bool(e, "active")?,
                        since_ms: req_u64(e, "since_ms")?,
                        value: req_f64(e, "value")?,
                    })
                })
                .collect::<Result<_, ApiError>>()?,
        };
        Ok(Self {
            uptime_ms: req_u64(v, "uptime_ms")?,
            workers: req_u64(v, "workers")?,
            requests: req_u64(v, "requests")?,
            search: SearchCounters {
                requests: req_u64(s, "requests")?,
                cold: req_u64(s, "cold")?,
                warm: req_u64(s, "warm")?,
                scheduler_evals_total: req_u64(s, "scheduler_evals_total")?,
            },
            coalescer: CoalescerCounters {
                led: req_u64(c, "led")?,
                coalesced: req_u64(c, "coalesced")?,
                in_flight: req_u64(c, "in_flight")?,
            },
            db: DbCounters {
                path: opt_str(d, "path")?,
                entries: req_u64(d, "entries")?,
                loaded: req_u64(d, "loaded")?,
                appended: req_u64(d, "appended")?,
                hits: req_u64(d, "hits")?,
                misses: req_u64(d, "misses")?,
            },
            perf,
            jobs,
            alerts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::util::json::parse;

    fn point(score: f64) -> DesignPoint {
        let cfg = presets::tpuv2();
        DesignPoint { config: cfg, eval: crate::metrics::evaluate(&cfg, 1_000_000, 8, 1e9), score }
    }

    #[test]
    fn search_reply_round_trips_byte_identically() {
        let r = SearchReply {
            model: "bert-base".into(),
            fingerprint: Fingerprint(0xdead_beef_0123_4567),
            backend: "native".into(),
            metric: Metric::PerfPerTdp,
            best: point(3.0),
            top: vec![point(3.0), point(2.0)],
            dims_evaluated: 12,
            scheduler_evals: 40,
            cache_hits: 0,
            vs_tpuv2: 1.25,
            vs_nvdla: 2.5,
            cancelled: false,
            wall_ms: 17.25,
            explain: None,
        };
        let bytes = r.to_json();
        assert!(!bytes.contains("explain"), "unrequested explain must stay off the wire");
        let q = SearchReply::from_json(&parse(&bytes).unwrap()).unwrap();
        assert_eq!(q.to_json(), bytes, "reply wire form must round-trip byte-identically");
        assert_eq!(q.fingerprint, r.fingerprint);
        assert_eq!(q.top.len(), 2);
        assert_eq!(q.explain, None);
    }

    #[test]
    fn search_reply_explain_round_trips() {
        let rec = |hit: bool, op: Option<&str>| ExplainRecord {
            dims: Dims { tc_x: 128, tc_y: 64, vc_w: 256 },
            score: 2.5,
            best: 3.0,
            improved: false,
            cache_hit: hit,
            evals: if hit { 0 } else { 7 },
            cores: (2, 3),
            grants: (1, 2, 0),
            conflict_op: op.map(str::to_string),
        };
        let r = SearchReply {
            model: "bert-base".into(),
            fingerprint: Fingerprint(0xdead_beef_0123_4567),
            backend: "native".into(),
            metric: Metric::Throughput,
            best: point(3.0),
            top: vec![point(3.0)],
            dims_evaluated: 2,
            scheduler_evals: 7,
            cache_hits: 1,
            vs_tpuv2: 1.0,
            vs_nvdla: 1.0,
            cancelled: false,
            wall_ms: 1.0,
            explain: Some(vec![rec(false, Some("attn.qk")), rec(true, None)]),
        };
        let bytes = r.to_json();
        let q = SearchReply::from_json(&parse(&bytes).unwrap()).unwrap();
        assert_eq!(q.to_json(), bytes, "explain rows must round-trip byte-identically");
        assert_eq!(q.explain, r.explain);
        let ex = q.explain.unwrap();
        assert_eq!(ex[0].conflict_op.as_deref(), Some("attn.qk"));
        assert!(ex[1].cache_hit && ex[1].conflict_op.is_none());
    }

    #[test]
    fn status_reply_round_trips() {
        let r = StatusReply {
            uptime_ms: 5,
            workers: 8,
            requests: 3,
            search: SearchCounters { requests: 2, cold: 1, warm: 1, scheduler_evals_total: 9 },
            coalescer: CoalescerCounters { led: 2, coalesced: 0, in_flight: 0 },
            db: DbCounters { path: None, entries: 4, loaded: 0, appended: 4, hits: 6, misses: 4 },
            perf: PerfCounters {
                backend_rows_total: 1234,
                scheduler_evals_total: 99,
                cluster_sim_events_total: 4321,
                db_hit_rate: 0.6,
                endpoints: vec![EndpointStat {
                    endpoint: "/search".into(),
                    count: 2,
                    p50_ms: 1.5,
                    p95_ms: 3.25,
                }],
            },
            jobs: JobsCounters {
                queued: 1,
                running: 1,
                done: 3,
                failed: 0,
                cancelled: 1,
                queue_depth: 1,
                oldest_age_ms: 250,
                submitted: 6,
                rejected_quota: 2,
                rejected_depth: 1,
                retries: 1,
            },
            alerts: vec![AlertStatus {
                rule: "job-queue-pressure".into(),
                describe: "queue near capacity".into(),
                active: true,
                since_ms: 17,
                value: 51.0,
            }],
        };
        let q = StatusReply::from_json(&parse(&r.to_json()).unwrap()).unwrap();
        assert_eq!(q, r);
        let with_path = StatusReply {
            db: DbCounters { path: Some("designs.jsonl".into()), ..r.db.clone() },
            ..r
        };
        let q = StatusReply::from_json(&parse(&with_path.to_json()).unwrap()).unwrap();
        assert_eq!(q.db.path.as_deref(), Some("designs.jsonl"));
    }

    #[test]
    fn status_reply_without_perf_still_parses() {
        // Pre-perf servers omit the "perf" object entirely.
        let legacy = r#"{"uptime_ms":1,"workers":2,"requests":0,
            "search":{"requests":0,"cold":0,"warm":0,"scheduler_evals_total":0},
            "coalescer":{"led":0,"coalesced":0,"in_flight":0},
            "db":{"path":null,"entries":0,"loaded":0,"appended":0,"hits":0,"misses":0}}"#;
        let q = StatusReply::from_json(&parse(legacy).unwrap()).unwrap();
        assert_eq!(q.perf, PerfCounters::default());
        // Pre-jobs servers omit the "jobs" object entirely.
        assert_eq!(q.jobs, JobsCounters::default());
        // Pre-alert-engine servers omit the "alerts" array entirely.
        assert!(q.alerts.is_empty());
    }

    #[test]
    fn global_reply_round_trips_byte_identically() {
        let row = |m: &str| GlobalRow {
            model: m.into(),
            configs: vec!["<2, 128x128, 2, 128>".into()],
            throughput: 10.5,
            perf_per_tdp: 0.25,
            vs_tpuv2: 1.5,
        };
        let r = GlobalReply {
            models: vec!["opt-1.3b".into(), "gpt2-xl".into()],
            depth: 8,
            tmp: 1,
            scheme: Scheme::PipeDream1F1B,
            metric: Metric::Throughput,
            backend: "native".into(),
            candidate_pool: 14,
            candidates_evaluated: 9,
            local_searches: 3,
            common_config: presets::tpuv2(),
            common: vec![row("opt-1.3b"), row("gpt2-xl")],
            individual: vec![row("opt-1.3b"), row("gpt2-xl")],
            mosaic: vec![row("opt-1.3b"), row("gpt2-xl")],
            cancelled: false,
            wall_ms: 99.0,
        };
        let bytes = r.to_json();
        let q = GlobalReply::from_json(&parse(&bytes).unwrap()).unwrap();
        assert_eq!(q.to_json(), bytes);
        assert_eq!(q.scheme, Scheme::PipeDream1F1B);
    }

    #[test]
    fn cluster_reply_round_trips_byte_identically() {
        let row = |pp: u64, mined: bool| StrategyRow {
            pp,
            tp: 2,
            dp: 1,
            chunks: 1,
            schedule: "1f1b".into(),
            micro_batch: 4,
            num_micro: 8,
            config: presets::tpuv2(),
            mined,
            iter_seconds: 0.125,
            throughput: 256.0,
            perf_per_tdp: 0.5,
            bubble_fraction: 0.21,
            fits_hbm: true,
        };
        let r = ClusterReply {
            model: "gpt2-xl".into(),
            devices: 8,
            topology: "nvlink-island".into(),
            metric: Metric::Throughput,
            backend: "native".into(),
            candidates: 9,
            mined: 2,
            baseline: row(8, false),
            ranked: vec![row(4, true), row(8, false)],
            cancelled: false,
            wall_ms: 42.5,
        };
        let bytes = r.to_json();
        let q = ClusterReply::from_json(&parse(&bytes).unwrap()).unwrap();
        assert_eq!(q.to_json(), bytes, "reply wire form must round-trip byte-identically");
        assert_eq!(q.ranked.len(), 2);
        assert!(q.ranked[0].mined);
        assert_eq!(q.baseline.pp, 8);
    }

    #[test]
    fn models_reply_round_trips() {
        let r = ModelsReply {
            models: vec![ModelEntry {
                name: "bert-base".into(),
                task: "language".into(),
                batch: 4,
                accelerators: 1,
                distributed_only: false,
                source: "builtin".into(),
            }],
        };
        assert_eq!(ModelsReply::from_json(&parse(&r.to_json()).unwrap()).unwrap(), r);
        // Pre-registry replies without a source still parse.
        let legacy = r#"{"models":[{"name":"x","task":"t","batch":1,
            "accelerators":1,"distributed_only":false}]}"#;
        let q = ModelsReply::from_json(&parse(legacy).unwrap()).unwrap();
        assert_eq!(q.models[0].source, "builtin");
    }

    #[test]
    fn workload_reply_round_trips() {
        let r = WorkloadReply {
            name: "llama-decoder".into(),
            fingerprint: Fingerprint(0x0123_4567_89ab_cdef),
            batch: 8,
            forward_ops: 131,
            training_ops: 402,
            source: "uploaded".into(),
        };
        let bytes = r.to_json();
        let q = WorkloadReply::from_json(&parse(&bytes).unwrap()).unwrap();
        assert_eq!(q, r);
        assert_eq!(q.to_json(), bytes);
    }
}
