//! Executable plans — validated requests with resolved workloads — plus
//! the canonical key derivations every frontend shares.
//!
//! Two keys exist, with different scopes:
//! * [`context_key`] identifies one *evaluation context* (workload
//!   fingerprint × batch × value-shaping options × backend). Design
//!   points are memoized under it in the persistent
//!   [`crate::service::cache::DesignDb`]; options that only shape
//!   exploration (`top_k`, `hysteresis`) are deliberately excluded so
//!   differently-shaped requests share mined points.
//! * `coalescing_key` (per plan) identifies one *response*: everything
//!   that changes the reply bytes, folded through FNV-1a. It replaces the
//!   service's old additive salt (`key + top_k + (hysteresis << 32)`),
//!   whose sums collide — e.g. `k = 2^32` versus `hysteresis = 1`.

use crate::api::error::ApiError;
use crate::arch::ArchConfig;
use crate::distributed::partition::PartitionedModel;
use crate::distributed::Scheme;
use crate::graph::{Fingerprint, OperatorGraph};
use crate::metrics::Metric;
use crate::search::engine::SearchOptions;
use crate::util::fnv::Fnv;

/// Namespace tags keeping per-endpoint keys disjoint.
const NS_SEARCH: u64 = 0x73; // 's'
const NS_COMMON: u64 = 0x63; // 'c'
const NS_GLOBAL: u64 = 0x67; // 'g'
const NS_CLUSTER: u64 = 0x6b; // 'k'

/// Resolve a registry workload to its training graph and batch size —
/// the lookup every per-workload frontend starts with. Builtin Table-4
/// constructors win first (map-backed, no JSON); everything else comes
/// from the layered spec registry ([`crate::workload`]), so specs
/// dropped in `--workload-dir` or uploaded to `POST /workloads` resolve
/// exactly like builtins — including fingerprint-keyed design-database
/// caching. A miss in both is a
/// [`404`](crate::api::ErrorKind::NotFound), never a silent default.
pub fn resolve_workload(name: &str) -> Result<(OperatorGraph, u64), ApiError> {
    if let Some(info) = crate::models::info(name) {
        let graph = crate::models::training(name, crate::graph::autodiff::Optimizer::Adam)
            .ok_or_else(|| {
                ApiError::internal(format!("builtin model {name:?} failed to build"))
            })?;
        return Ok((graph, info.batch));
    }
    match crate::workload::resolve(name) {
        Some(Ok(pair)) => Ok(pair),
        // Specs are validated at registration, so a lowering failure here
        // is an internal inconsistency, not a caller error.
        Some(Err(e)) => {
            Err(ApiError::internal(format!("registered workload {name:?} failed to lower: {e}")))
        }
        None => Err(ApiError::not_found(format!(
            "unknown model {name:?} (see `wham workloads list` / GET /models)"
        ))),
    }
}

/// Key identifying one evaluation context (see module docs). Two
/// searches with the same context key may share every per-dims point.
pub fn context_key(fp: Fingerprint, batch: u64, opts: &SearchOptions, backend: &str) -> u64 {
    Fnv::new()
        .word(fp.0)
        .word(batch)
        .word(match opts.metric {
            Metric::Throughput => 0,
            Metric::PerfPerTdp => 1,
        })
        .word(opts.min_throughput.to_bits())
        .word(opts.constraints.max_area_mm2.to_bits())
        .word(opts.constraints.max_power_w.to_bits())
        .word(opts.use_ilp as u64)
        .word(opts.ilp_node_budget)
        // The MCR growth mode is outcome-preserving on the pinned
        // workload classes, but a pathological plateau-then-improve
        // makespan staircase could let the two walks land on different
        // core counts — keep their mined points in separate contexts so
        // a cached design can never cross modes. (`naive_annotation`,
        // `full_reschedule`, and `jobs` are provably bit-identical and
        // deliberately excluded.)
        .word(opts.mcr_one_at_a_time as u64)
        .bytes(backend.as_bytes())
        .0
}

fn fold_deadline(f: Fnv, deadline_ms: Option<u64>) -> Fnv {
    // Deadlines truncate the reply, so they must separate coalescing
    // batches; `u64::MAX` marks "none" (an explicit MAX is equivalent).
    f.word(deadline_ms.unwrap_or(u64::MAX))
}

/// Validated `/search` work: resolved workload + engine options (the
/// Perf/TDP floor is resolved later, by the session, because it needs a
/// cost backend).
pub struct SearchPlan {
    pub model: String,
    pub fingerprint: Fingerprint,
    pub graph: OperatorGraph,
    pub batch: u64,
    pub opts: SearchOptions,
    pub deadline_ms: Option<u64>,
    /// Attach flight-recorder rows to the reply — reply-shaping, so it
    /// participates in the coalescing key (a follower without `explain`
    /// must not receive the leader's recorder dump, and vice versa).
    pub explain: bool,
}

impl SearchPlan {
    /// Single-flight key: everything that shapes the *reply*, so
    /// followers can share the leader's bytes verbatim.
    pub fn coalescing_key(&self, backend: &str) -> u64 {
        fold_deadline(
            Fnv::new()
                .word(NS_SEARCH)
                .word(context_key(self.fingerprint, self.batch, &self.opts, backend))
                .word(self.opts.top_k as u64)
                .word(self.opts.hysteresis as u64)
                .word(self.explain as u64),
            self.deadline_ms,
        )
        .0
    }
}

/// Validated `/evaluate` work.
pub struct EvaluatePlan {
    pub model: String,
    pub fingerprint: Fingerprint,
    pub graph: OperatorGraph,
    pub batch: u64,
    pub config: ArchConfig,
}

/// Validated `/common` work: the resolved workload set.
pub struct CommonPlan {
    pub models: Vec<String>,
    /// `(name, training graph, batch)` per workload, in request order.
    pub workloads: Vec<(String, OperatorGraph, u64)>,
    pub opts: SearchOptions,
}

impl CommonPlan {
    /// Single-flight key over the whole workload set.
    pub fn coalescing_key(&self, backend: &str) -> u64 {
        let mut f = Fnv::new().word(NS_COMMON);
        for (name, _, batch) in &self.workloads {
            f = f.bytes(name.as_bytes()).word(0).word(*batch);
        }
        f.word(self.opts.top_k as u64)
            .word(self.opts.hysteresis as u64)
            .word(self.opts.use_ilp as u64)
            .word(match self.opts.metric {
                Metric::Throughput => 0,
                Metric::PerfPerTdp => 1,
            })
            .bytes(backend.as_bytes())
            .0
    }
}

/// Validated `/global` work: partitioned models plus search shape.
pub struct GlobalPlan {
    pub models: Vec<String>,
    pub parts: Vec<PartitionedModel>,
    pub depth: u64,
    pub tmp: u64,
    pub scheme: Scheme,
    pub metric: Metric,
    pub top_k: usize,
    /// Pruner hysteresis of the per-stage local searches.
    pub hysteresis: u32,
    /// Exact B&B "ILP" in the per-stage local searches.
    pub use_ilp: bool,
    pub deadline_ms: Option<u64>,
}

impl GlobalPlan {
    /// Single-flight key over the full request shape.
    pub fn coalescing_key(&self, backend: &str) -> u64 {
        let mut f = Fnv::new().word(NS_GLOBAL);
        for n in &self.models {
            f = f.bytes(n.as_bytes()).word(0);
        }
        fold_deadline(
            f.word(self.depth)
                .word(self.tmp)
                .word(self.top_k as u64)
                .word(self.hysteresis as u64)
                .word(self.use_ilp as u64)
                .word(matches!(self.scheme, Scheme::GPipe) as u64)
                .word(matches!(self.metric, Metric::PerfPerTdp) as u64)
                .bytes(backend.as_bytes()),
            self.deadline_ms,
        )
        .0
    }
}

/// Validated `/cluster` work: the resolved transformer shape plus the
/// sweep's full request surface. The design database needs no new key
/// form — the sweep's mining phase caches per-stage points under the
/// stage-graph fingerprints via [`CacheProvider`], so strategies that
/// share a (pp, tp) partition share mined designs across requests —
/// but the coalescing key must separate every reply-shaping field:
/// (workload, topology, strategy-space) in the issue's terms.
///
/// [`CacheProvider`]: crate::search::engine::CacheProvider
pub struct ClusterPlan {
    pub model: String,
    pub cfg: crate::models::transformer::TransformerCfg,
    pub devices: u64,
    pub topology: String,
    pub schedules: Vec<String>,
    pub metric: Metric,
    pub mine_top: u64,
    pub chunks: u64,
    pub top_k: usize,
    pub hysteresis: u32,
    pub use_ilp: bool,
    pub deadline_ms: Option<u64>,
}

impl ClusterPlan {
    /// Single-flight key over (fingerprint-bearing workload name,
    /// topology, strategy shape, search knobs, backend).
    pub fn coalescing_key(&self, backend: &str) -> u64 {
        let mut f = Fnv::new()
            .word(NS_CLUSTER)
            .bytes(self.model.as_bytes())
            .word(0)
            .bytes(self.topology.as_bytes())
            .word(self.devices);
        for s in &self.schedules {
            f = f.bytes(s.as_bytes()).word(0);
        }
        fold_deadline(
            f.word(self.mine_top)
                .word(self.chunks)
                .word(self.top_k as u64)
                .word(self.hysteresis as u64)
                .word(self.use_ilp as u64)
                .word(matches!(self.metric, Metric::PerfPerTdp) as u64)
                .bytes(backend.as_bytes()),
            self.deadline_ms,
        )
        .0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::request::{ClusterRequest, GlobalRequest, SearchRequest};

    #[test]
    fn coalescing_key_fixes_the_additive_salt_collision() {
        // Under the old salt (`key + top_k + (hysteresis << 32)`) these
        // two requests collided: 2^32 + 0<<32 == 0 + 1<<32.
        let a = SearchRequest::new("bert-base").top_k((1u64 << 32) as usize).hysteresis(0);
        let b = SearchRequest::new("bert-base").top_k(1).hysteresis(1);
        let (pa, pb) = (a.validate().unwrap(), b.validate().unwrap());
        let old = |k: u64, h: u64| {
            context_key(pa.fingerprint, pa.batch, &pa.opts, "native")
                .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                .wrapping_add(k)
                .wrapping_add(h << 32)
        };
        assert_eq!(old(1 << 32, 0), old(0, 1), "the old salt collides by construction");
        assert_ne!(pa.coalescing_key("native"), pb.coalescing_key("native"));
    }

    #[test]
    fn keys_are_stable_and_separate_requests() {
        let p = SearchRequest::new("bert-base").validate().unwrap();
        assert_eq!(p.coalescing_key("native"), p.coalescing_key("native"));
        assert_ne!(p.coalescing_key("native"), p.coalescing_key("pjrt"));
        let q = SearchRequest::new("bert-base").top_k(3).validate().unwrap();
        assert_ne!(p.coalescing_key("native"), q.coalescing_key("native"));
        let d = SearchRequest::new("bert-base").deadline_ms(5).validate().unwrap();
        assert_ne!(p.coalescing_key("native"), d.coalescing_key("native"));
        let e = SearchRequest::new("bert-base").explain(true).validate().unwrap();
        assert_ne!(p.coalescing_key("native"), e.coalescing_key("native"));
    }

    #[test]
    fn global_key_separates_shape() {
        let a = GlobalRequest::new().depth(4).validate().unwrap();
        let b = GlobalRequest::new().depth(8).validate().unwrap();
        assert_ne!(a.coalescing_key("native"), b.coalescing_key("native"));
        let c = GlobalRequest::new().depth(4).scheme(Scheme::PipeDream1F1B).validate().unwrap();
        assert_ne!(a.coalescing_key("native"), c.coalescing_key("native"));
    }

    #[test]
    fn cluster_key_separates_workload_topology_and_strategy_space() {
        let base = ClusterRequest::new("gpt2-xl").validate().unwrap();
        assert_eq!(base.coalescing_key("native"), base.coalescing_key("native"));
        let topo = ClusterRequest::new("gpt2-xl").topology("ring").validate().unwrap();
        assert_ne!(base.coalescing_key("native"), topo.coalescing_key("native"));
        let devs = ClusterRequest::new("gpt2-xl").devices(16).validate().unwrap();
        assert_ne!(base.coalescing_key("native"), devs.coalescing_key("native"));
        let sched =
            ClusterRequest::new("gpt2-xl").schedules(["gpipe"]).validate().unwrap();
        assert_ne!(base.coalescing_key("native"), sched.coalescing_key("native"));
        let model = ClusterRequest::new("opt-1.3b").validate().unwrap();
        assert_ne!(base.coalescing_key("native"), model.coalescing_key("native"));
        assert_ne!(base.coalescing_key("native"), base.coalescing_key("pjrt"));
    }

    #[test]
    fn resolve_workload_misses_are_404() {
        assert_eq!(resolve_workload("nope").unwrap_err().http_status(), 404);
        let (g, batch) = resolve_workload("bert-base").unwrap();
        assert!(g.len() > 20);
        assert_eq!(batch, 4);
    }

    #[test]
    fn registered_specs_resolve_like_builtins() {
        crate::workload::add_spec_text(
            r#"{"name":"plan-test-net","batch":3,"graph":[
                {"op":"linear","m":8,"n":8,"k":8},
                {"op":"activation","elems":64}
            ]}"#,
            crate::workload::Source::User,
        )
        .unwrap();
        let (g, batch) = resolve_workload("plan-test-net").unwrap();
        assert_eq!(batch, 3);
        assert!(g.len() >= 2);
        // Spec workloads flow through the same plan machinery.
        let p = SearchRequest::new("plan-test-net").validate().unwrap();
        assert_eq!(p.batch, 3);
        assert_eq!(p.fingerprint, crate::graph::fingerprint(&p.graph));
    }
}
