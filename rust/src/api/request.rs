//! Typed requests — the single definition of every front door's inputs.
//!
//! Each request type has:
//! * a builder (`SearchRequest::new("bert-base").top_k(5)…`) for library
//!   callers;
//! * a `from_args` constructor so the CLI subcommands and `wham client`
//!   parse flags identically;
//! * [`ToJson`]/[`FromJson`] so the HTTP client and server share one wire
//!   codec;
//! * `validate()`, which resolves registry names and bounds-checks fields
//!   into an executable plan ([`crate::api::plan`]).

use crate::api::error::ApiError;
use crate::api::plan::{
    resolve_workload, ClusterPlan, CommonPlan, EvaluatePlan, GlobalPlan, SearchPlan,
};
use crate::api::wire::{
    config_arr, opt_bool, opt_str, opt_str_list, opt_u64, parse_config, req_str, FromJson, ToJson,
};
use crate::arch::ArchConfig;
use crate::coordinator::BackendChoice;
use crate::distributed::Scheme;
use crate::graph::fingerprint;
use crate::metrics::Metric;
use crate::search::engine::SearchOptions;
use crate::util::cli::Args;
use crate::util::json::{str_arr, JsonValue, Obj};

/// The backend flag is session-level (one cost backend per [`crate::api::Session`]),
/// parsed here so the CLI subcommands share one definition.
pub fn backend_from_args(args: &Args) -> Result<BackendChoice, ApiError> {
    args.get_or("backend", "auto").parse().map_err(ApiError::invalid)
}

/// Canonical wire name of a pipeline scheme (parseable by
/// `Scheme::from_str`, unlike the Debug form).
pub fn scheme_wire_name(s: Scheme) -> &'static str {
    match s {
        Scheme::GPipe => "gpipe",
        Scheme::PipeDream1F1B => "1f1b",
    }
}

/// Parse `TXxTYxVW` (e.g. `128x128x256`) — shared by `--dims` flags.
pub fn parse_dims(s: &str) -> Result<(u64, u64, u64), ApiError> {
    let parts: Vec<u64> = s
        .split('x')
        .map(|p| {
            p.parse::<u64>()
                .map_err(|_| ApiError::invalid("--dims expects TXxTYxVW, e.g. 128x128x128"))
        })
        .collect::<Result<_, _>>()?;
    match parts.as_slice() {
        [tx, ty, vw] => Ok((*tx, *ty, *vw)),
        _ => Err(ApiError::invalid("--dims expects three values, e.g. 128x128x128")),
    }
}

fn cli_err(e: crate::util::cli::CliError) -> ApiError {
    ApiError::invalid(e.to_string())
}

fn parse_metric(v: &JsonValue) -> Result<Option<Metric>, ApiError> {
    match opt_str(v, "metric")? {
        None => Ok(None),
        Some(m) => m.parse::<Metric>().map(Some).map_err(ApiError::invalid),
    }
}

// The four search-shaping knobs (`metric`, `k`, `hysteresis`, `ilp`)
// appear on every search-shaped request; their flag names, wire names,
// and parsing exist only in the three helpers below.

fn knobs_from_args(
    args: &Args,
    metric: &mut Metric,
    top_k: &mut usize,
    hysteresis: &mut u32,
    use_ilp: &mut bool,
) -> Result<(), ApiError> {
    if let Some(m) = args.get("metric") {
        *metric = m.parse().map_err(ApiError::invalid)?;
    }
    *top_k = args.get_as_or("k", *top_k).map_err(cli_err)?;
    *hysteresis = args.get_as_or("hysteresis", *hysteresis).map_err(cli_err)?;
    *use_ilp = args.flag("ilp");
    Ok(())
}

fn knobs_from_json(
    v: &JsonValue,
    metric: &mut Metric,
    top_k: &mut usize,
    hysteresis: &mut u32,
    use_ilp: &mut bool,
) -> Result<(), ApiError> {
    if let Some(m) = parse_metric(v)? {
        *metric = m;
    }
    if let Some(k) = opt_u64(v, "k")? {
        *top_k = k as usize;
    }
    if let Some(h) = opt_u64(v, "hysteresis")? {
        *hysteresis = h as u32;
    }
    if let Some(b) = opt_bool(v, "ilp")? {
        *use_ilp = b;
    }
    Ok(())
}

fn knobs_json(o: Obj, metric: Metric, top_k: usize, hysteresis: u32, use_ilp: bool) -> Obj {
    o.str("metric", &metric.to_string())
        .u64("k", top_k as u64)
        .u64("hysteresis", hysteresis as u64)
        .bool("ilp", use_ilp)
}

// ---- /search ------------------------------------------------------------

/// Per-workload accelerator search (paper section 4).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SearchRequest {
    pub model: String,
    pub metric: Metric,
    /// Designs retained for the global search / reply `top` list (>= 1).
    pub top_k: usize,
    /// Pruner hysteresis levels (Algorithm 2).
    pub hysteresis: u32,
    /// Exact B&B "ILP" instead of the MCR heuristics.
    pub use_ilp: bool,
    /// Optional wall-clock budget; on expiry the search cancels
    /// cooperatively and replies with best-so-far (`cancelled: true`).
    pub deadline_ms: Option<u64>,
    /// Attach the search flight recorder's per-iteration attribution to
    /// the reply (`"explain"` rows — see [`crate::telemetry::recorder`]).
    pub explain: bool,
}

impl SearchRequest {
    /// New request with the engine's default options.
    pub fn new(model: impl Into<String>) -> Self {
        let d = SearchOptions::default();
        Self {
            model: model.into(),
            metric: d.metric,
            top_k: d.top_k,
            hysteresis: d.hysteresis,
            use_ilp: d.use_ilp,
            deadline_ms: None,
            explain: false,
        }
    }

    pub fn metric(mut self, m: Metric) -> Self {
        self.metric = m;
        self
    }

    pub fn top_k(mut self, k: usize) -> Self {
        self.top_k = k;
        self
    }

    pub fn hysteresis(mut self, h: u32) -> Self {
        self.hysteresis = h;
        self
    }

    pub fn ilp(mut self, on: bool) -> Self {
        self.use_ilp = on;
        self
    }

    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn explain(mut self, on: bool) -> Self {
        self.explain = on;
        self
    }

    /// Build from CLI flags: `--model --metric --k --hysteresis --ilp
    /// --deadline-ms --explain`. `wham search` and `wham client search`
    /// both call this, so the two frontends cannot diverge.
    pub fn from_args(args: &Args) -> Result<Self, ApiError> {
        let model = args.get("model").ok_or_else(|| ApiError::invalid("--model required"))?;
        let mut r = Self::new(model);
        knobs_from_args(args, &mut r.metric, &mut r.top_k, &mut r.hysteresis, &mut r.use_ilp)?;
        r.deadline_ms = args.get_as::<u64>("deadline-ms").map_err(cli_err)?;
        r.explain = args.flag("explain");
        Ok(r)
    }

    /// Resolve and bounds-check into an executable plan.
    pub fn validate(&self) -> Result<SearchPlan, ApiError> {
        let (graph, batch) = resolve_workload(&self.model)?;
        let opts = SearchOptions {
            metric: self.metric,
            top_k: self.top_k.max(1),
            hysteresis: self.hysteresis,
            use_ilp: self.use_ilp,
            ..Default::default()
        };
        Ok(SearchPlan {
            model: self.model.clone(),
            fingerprint: fingerprint(&graph),
            graph,
            batch,
            opts,
            deadline_ms: self.deadline_ms,
            explain: self.explain,
        })
    }
}

impl ToJson for SearchRequest {
    fn to_json(&self) -> String {
        knobs_json(
            Obj::new().str("model", &self.model),
            self.metric,
            self.top_k,
            self.hysteresis,
            self.use_ilp,
        )
        .opt_u64("deadline_ms", self.deadline_ms)
        .bool("explain", self.explain)
        .finish()
    }
}

impl FromJson for SearchRequest {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let mut r = Self::new(req_str(v, "model")?);
        knobs_from_json(v, &mut r.metric, &mut r.top_k, &mut r.hysteresis, &mut r.use_ilp)?;
        r.deadline_ms = opt_u64(v, "deadline_ms")?;
        if let Some(b) = opt_bool(v, "explain")? {
            r.explain = b;
        }
        Ok(r)
    }
}

// ---- /evaluate ----------------------------------------------------------

/// Evaluate one fixed design on a workload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EvaluateRequest {
    pub model: String,
    pub config: ArchConfig,
}

impl EvaluateRequest {
    pub fn new(model: impl Into<String>, config: ArchConfig) -> Self {
        Self { model: model.into(), config }
    }

    /// Build from CLI flags: `--model --dims TXxTYxVW [--tc N --vc N]`.
    pub fn from_args(args: &Args) -> Result<Self, ApiError> {
        let model = args.get("model").ok_or_else(|| ApiError::invalid("--model required"))?;
        let dims =
            args.get("dims").ok_or_else(|| ApiError::invalid("--dims TXxTYxVW required"))?;
        let (tx, ty, vw) = parse_dims(dims)?;
        let config = ArchConfig {
            num_tc: args.get_as_or("tc", 2u64).map_err(cli_err)?,
            tc_x: tx,
            tc_y: ty,
            num_vc: args.get_as_or("vc", 2u64).map_err(cli_err)?,
            vc_w: vw,
        };
        Ok(Self::new(model, config))
    }

    /// Resolve and bounds-check into an executable plan.
    pub fn validate(&self) -> Result<EvaluatePlan, ApiError> {
        if !self.config.in_template() {
            return Err(ApiError::invalid(format!(
                "{} is outside the template bounds",
                self.config.display()
            )));
        }
        let (graph, batch) = resolve_workload(&self.model)?;
        Ok(EvaluatePlan {
            model: self.model.clone(),
            fingerprint: fingerprint(&graph),
            graph,
            batch,
            config: self.config,
        })
    }
}

impl ToJson for EvaluateRequest {
    fn to_json(&self) -> String {
        Obj::new()
            .str("model", &self.model)
            .raw("config", &config_arr(&self.config))
            .finish()
    }
}

impl FromJson for EvaluateRequest {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let model = req_str(v, "model")?;
        let config = parse_config(v.get("config").ok_or_else(|| {
            ApiError::invalid("body must include \"config\":[num_tc,tc_x,tc_y,num_vc,vc_w]")
        })?)?;
        Ok(Self::new(model, config))
    }
}

// ---- /common ------------------------------------------------------------

/// WHAM-common: one design across a workload set (paper section 4.6).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CommonRequest {
    /// Workload set; empty means the single-accelerator zoo.
    pub models: Vec<String>,
    pub metric: Metric,
    pub top_k: usize,
    pub hysteresis: u32,
    pub use_ilp: bool,
}

impl CommonRequest {
    /// New request over the default (single-accelerator) workload set.
    pub fn new() -> Self {
        let d = SearchOptions::default();
        Self {
            models: Vec::new(),
            metric: d.metric,
            top_k: d.top_k,
            hysteresis: d.hysteresis,
            use_ilp: d.use_ilp,
        }
    }

    pub fn models<I: IntoIterator<Item = S>, S: Into<String>>(mut self, names: I) -> Self {
        self.models = names.into_iter().map(Into::into).collect();
        self
    }

    pub fn metric(mut self, m: Metric) -> Self {
        self.metric = m;
        self
    }

    pub fn top_k(mut self, k: usize) -> Self {
        self.top_k = k;
        self
    }

    pub fn ilp(mut self, on: bool) -> Self {
        self.use_ilp = on;
        self
    }

    /// Build from CLI flags: `--models a,b,c --metric --k --hysteresis --ilp`.
    pub fn from_args(args: &Args) -> Result<Self, ApiError> {
        let mut r = Self::new();
        r.models = args.get_list("models");
        knobs_from_args(args, &mut r.metric, &mut r.top_k, &mut r.hysteresis, &mut r.use_ilp)?;
        Ok(r)
    }

    /// Resolve the workload set into an executable plan.
    pub fn validate(&self) -> Result<CommonPlan, ApiError> {
        let names: Vec<String> = if self.models.is_empty() {
            crate::models::single_acc_models().iter().map(|s| s.to_string()).collect()
        } else {
            self.models.clone()
        };
        let mut workloads = Vec::with_capacity(names.len());
        for n in &names {
            let (graph, batch) = resolve_workload(n)?;
            workloads.push((n.clone(), graph, batch));
        }
        let opts = SearchOptions {
            metric: self.metric,
            top_k: self.top_k.max(1),
            hysteresis: self.hysteresis,
            use_ilp: self.use_ilp,
            ..Default::default()
        };
        Ok(CommonPlan { models: names, workloads, opts })
    }
}

impl Default for CommonRequest {
    fn default() -> Self {
        Self::new()
    }
}

impl ToJson for CommonRequest {
    fn to_json(&self) -> String {
        let mut o = Obj::new();
        if !self.models.is_empty() {
            o = o.raw("models", &str_arr(self.models.iter().map(String::as_str)));
        }
        knobs_json(o, self.metric, self.top_k, self.hysteresis, self.use_ilp).finish()
    }
}

impl FromJson for CommonRequest {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let mut r = Self::new();
        if let Some(models) = opt_str_list(v, "models")? {
            if models.is_empty() {
                return Err(ApiError::invalid("\"models\" must not be empty"));
            }
            r.models = models;
        }
        knobs_from_json(v, &mut r.metric, &mut r.top_k, &mut r.hysteresis, &mut r.use_ilp)?;
        Ok(r)
    }
}

// ---- /global ------------------------------------------------------------

/// Distributed pipeline/TMP global search (paper section 5).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GlobalRequest {
    /// LLM workloads; empty means `opt-1.3b, gpt2-xl`.
    pub models: Vec<String>,
    /// Pipeline depth (stages).
    pub depth: u64,
    /// Tensor-model-parallel degree.
    pub tmp: u64,
    pub scheme: Scheme,
    pub metric: Metric,
    pub top_k: usize,
    /// Pruner hysteresis of the per-stage local searches.
    pub hysteresis: u32,
    /// Exact B&B "ILP" in the per-stage local searches.
    pub use_ilp: bool,
    /// Optional wall-clock budget (cooperative, best-so-far on expiry).
    pub deadline_ms: Option<u64>,
}

impl GlobalRequest {
    pub fn new() -> Self {
        let d = SearchOptions::default();
        Self {
            models: Vec::new(),
            depth: 32,
            tmp: 1,
            scheme: Scheme::GPipe,
            metric: Metric::Throughput,
            top_k: 10,
            hysteresis: d.hysteresis,
            use_ilp: d.use_ilp,
            deadline_ms: None,
        }
    }

    pub fn models<I: IntoIterator<Item = S>, S: Into<String>>(mut self, names: I) -> Self {
        self.models = names.into_iter().map(Into::into).collect();
        self
    }

    pub fn depth(mut self, d: u64) -> Self {
        self.depth = d;
        self
    }

    pub fn tmp(mut self, t: u64) -> Self {
        self.tmp = t;
        self
    }

    pub fn scheme(mut self, s: Scheme) -> Self {
        self.scheme = s;
        self
    }

    pub fn metric(mut self, m: Metric) -> Self {
        self.metric = m;
        self
    }

    pub fn top_k(mut self, k: usize) -> Self {
        self.top_k = k;
        self
    }

    pub fn hysteresis(mut self, h: u32) -> Self {
        self.hysteresis = h;
        self
    }

    pub fn ilp(mut self, on: bool) -> Self {
        self.use_ilp = on;
        self
    }

    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Build from CLI flags: `--models --depth --tmp --scheme --metric
    /// --k --hysteresis --ilp --deadline-ms`.
    pub fn from_args(args: &Args) -> Result<Self, ApiError> {
        let mut r = Self::new();
        r.models = args.get_list("models");
        r.depth = args.get_as_or("depth", r.depth).map_err(cli_err)?;
        r.tmp = args.get_as_or("tmp", r.tmp).map_err(cli_err)?;
        if let Some(s) = args.get("scheme") {
            r.scheme = s.parse().map_err(ApiError::invalid)?;
        }
        knobs_from_args(args, &mut r.metric, &mut r.top_k, &mut r.hysteresis, &mut r.use_ilp)?;
        r.deadline_ms = args.get_as::<u64>("deadline-ms").map_err(cli_err)?;
        Ok(r)
    }

    /// Resolve workloads, partition them, and bounds-check into a plan.
    pub fn validate(&self) -> Result<GlobalPlan, ApiError> {
        // partition_transformer asserts on zero values; reject them (and
        // absurd sizes) at the API boundary instead of panicking a worker.
        if !(1..=1024).contains(&self.depth) || !(1..=1024).contains(&self.tmp) {
            return Err(ApiError::invalid("\"depth\" and \"tmp\" must be in 1..=1024"));
        }
        let names: Vec<String> = if self.models.is_empty() {
            vec!["opt-1.3b".to_string(), "gpt2-xl".to_string()]
        } else {
            self.models.clone()
        };
        let mut parts = Vec::with_capacity(names.len());
        for n in &names {
            // Builtin LLMs or any registered spec carrying a
            // `transformer` section — custom workloads partition too.
            match crate::workload::transformer_cfg(n) {
                Some(cfg) => parts.push(crate::distributed::partition::partition_transformer(
                    n,
                    &cfg,
                    self.depth,
                    self.tmp,
                    crate::graph::autodiff::Optimizer::Adam,
                )),
                None => {
                    return Err(ApiError::not_found(format!(
                        "{n:?} is not an LLM workload (builtin LLM or spec with a \
                         \"transformer\" section required)"
                    )))
                }
            }
        }
        Ok(GlobalPlan {
            models: names,
            parts,
            depth: self.depth,
            tmp: self.tmp,
            scheme: self.scheme,
            metric: self.metric,
            top_k: self.top_k.max(1),
            hysteresis: self.hysteresis,
            use_ilp: self.use_ilp,
            deadline_ms: self.deadline_ms,
        })
    }
}

impl Default for GlobalRequest {
    fn default() -> Self {
        Self::new()
    }
}

impl ToJson for GlobalRequest {
    fn to_json(&self) -> String {
        let mut o = Obj::new();
        if !self.models.is_empty() {
            o = o.raw("models", &str_arr(self.models.iter().map(String::as_str)));
        }
        o = o
            .u64("depth", self.depth)
            .u64("tmp", self.tmp)
            .str("scheme", scheme_wire_name(self.scheme));
        knobs_json(o, self.metric, self.top_k, self.hysteresis, self.use_ilp)
            .opt_u64("deadline_ms", self.deadline_ms)
            .finish()
    }
}

impl FromJson for GlobalRequest {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let mut r = Self::new();
        if let Some(models) = opt_str_list(v, "models")? {
            if models.is_empty() {
                return Err(ApiError::invalid("\"models\" must not be empty"));
            }
            r.models = models;
        }
        if let Some(d) = opt_u64(v, "depth")? {
            r.depth = d;
        }
        if let Some(t) = opt_u64(v, "tmp")? {
            r.tmp = t;
        }
        if let Some(s) = opt_str(v, "scheme")? {
            r.scheme = s.parse().map_err(ApiError::invalid)?;
        }
        knobs_from_json(v, &mut r.metric, &mut r.top_k, &mut r.hysteresis, &mut r.use_ilp)?;
        r.deadline_ms = opt_u64(v, "deadline_ms")?;
        Ok(r)
    }
}

// ---- /cluster -----------------------------------------------------------

/// Cluster-level parallelism-strategy sweep ([`crate::cluster`]): place
/// one LLM workload on a topology, enumerate (pp, tp, dp, schedule)
/// strategies, and mine hardware for the best of them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterRequest {
    pub model: String,
    /// Total accelerators in the cluster.
    pub devices: u64,
    /// Topology preset (`flat` | `ring` | `fat-tree` | `nvlink-island`).
    pub topology: String,
    /// Schedules to consider; empty = gpipe, 1f1b, and interleaved.
    pub schedules: Vec<String>,
    pub metric: Metric,
    /// Screened strategies to mine hardware for (0 = screening only).
    pub mine_top: u64,
    /// Virtual chunks per device for interleaved-1F1B candidates.
    pub chunks: u64,
    pub top_k: usize,
    pub hysteresis: u32,
    pub use_ilp: bool,
    /// Optional wall-clock budget (cooperative, best-so-far on expiry).
    pub deadline_ms: Option<u64>,
}

impl ClusterRequest {
    pub fn new(model: impl Into<String>) -> Self {
        let d = SearchOptions::default();
        Self {
            model: model.into(),
            devices: 8,
            topology: "flat".to_string(),
            schedules: Vec::new(),
            metric: Metric::Throughput,
            mine_top: 2,
            chunks: 2,
            top_k: d.top_k,
            hysteresis: d.hysteresis,
            use_ilp: d.use_ilp,
            deadline_ms: None,
        }
    }

    pub fn devices(mut self, n: u64) -> Self {
        self.devices = n;
        self
    }

    pub fn topology(mut self, t: impl Into<String>) -> Self {
        self.topology = t.into();
        self
    }

    pub fn schedules<I: IntoIterator<Item = S>, S: Into<String>>(mut self, names: I) -> Self {
        self.schedules = names.into_iter().map(Into::into).collect();
        self
    }

    pub fn metric(mut self, m: Metric) -> Self {
        self.metric = m;
        self
    }

    pub fn mine_top(mut self, n: u64) -> Self {
        self.mine_top = n;
        self
    }

    pub fn chunks(mut self, v: u64) -> Self {
        self.chunks = v;
        self
    }

    pub fn top_k(mut self, k: usize) -> Self {
        self.top_k = k;
        self
    }

    pub fn hysteresis(mut self, h: u32) -> Self {
        self.hysteresis = h;
        self
    }

    pub fn ilp(mut self, on: bool) -> Self {
        self.use_ilp = on;
        self
    }

    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Build from CLI flags: `--model --devices --topology --schedules
    /// --mine --chunks --metric --k --hysteresis --ilp --deadline-ms`.
    /// `wham cluster` and `wham client cluster` both call this.
    pub fn from_args(args: &Args) -> Result<Self, ApiError> {
        let model = args.get("model").ok_or_else(|| ApiError::invalid("--model required"))?;
        let mut r = Self::new(model);
        r.devices = args.get_as_or("devices", r.devices).map_err(cli_err)?;
        if let Some(t) = args.get("topology") {
            r.topology = t.to_string();
        }
        r.schedules = args.get_list("schedules");
        r.mine_top = args.get_as_or("mine", r.mine_top).map_err(cli_err)?;
        r.chunks = args.get_as_or("chunks", r.chunks).map_err(cli_err)?;
        knobs_from_args(args, &mut r.metric, &mut r.top_k, &mut r.hysteresis, &mut r.use_ilp)?;
        r.deadline_ms = args.get_as::<u64>("deadline-ms").map_err(cli_err)?;
        Ok(r)
    }

    /// Resolve the workload and bounds-check into an executable plan.
    pub fn validate(&self) -> Result<ClusterPlan, ApiError> {
        if !(1..=4096).contains(&self.devices) {
            return Err(ApiError::invalid("\"devices\" must be in 1..=4096"));
        }
        if !(1..=8).contains(&self.chunks) {
            return Err(ApiError::invalid("\"chunks\" must be in 1..=8"));
        }
        // Fail the request, not the worker, on a bad preset or schedule.
        crate::cluster::Topology::preset(&self.topology, self.devices as usize)
            .map_err(ApiError::invalid)?;
        for s in &self.schedules {
            if !crate::cluster::strategy::schedule_names().contains(&s.as_str()) {
                return Err(ApiError::invalid(format!(
                    "unknown schedule {s:?} (expected gpipe, 1f1b, or interleaved)"
                )));
            }
        }
        let cfg = match crate::workload::transformer_cfg(&self.model) {
            Some(cfg) => cfg,
            None => {
                return Err(ApiError::not_found(format!(
                    "{:?} is not an LLM workload (builtin LLM or spec with a \
                     \"transformer\" section required)",
                    self.model
                )))
            }
        };
        // An empty strategy space is a caller error (e.g. interleaved-only
        // on 1 device, or chunks deeper than the layer budget), not a
        // worker failure — reject it here as a 400.
        if !crate::cluster::strategy::has_feasible_strategy(
            &cfg,
            self.devices,
            &self.schedules,
            self.chunks,
        ) {
            return Err(ApiError::invalid(format!(
                "no feasible (pp, tp, dp) strategy for {:?} on {} devices with schedules {:?} \
                 and {} chunks",
                self.model, self.devices, self.schedules, self.chunks
            )));
        }
        Ok(ClusterPlan {
            model: self.model.clone(),
            cfg,
            devices: self.devices,
            topology: self.topology.clone(),
            schedules: self.schedules.clone(),
            metric: self.metric,
            mine_top: self.mine_top,
            chunks: self.chunks,
            top_k: self.top_k.max(1),
            hysteresis: self.hysteresis,
            use_ilp: self.use_ilp,
            deadline_ms: self.deadline_ms,
        })
    }
}

impl ToJson for ClusterRequest {
    fn to_json(&self) -> String {
        let mut o = Obj::new()
            .str("model", &self.model)
            .u64("devices", self.devices)
            .str("topology", &self.topology);
        if !self.schedules.is_empty() {
            o = o.raw("schedules", &str_arr(self.schedules.iter().map(String::as_str)));
        }
        o = o.u64("mine", self.mine_top).u64("chunks", self.chunks);
        knobs_json(o, self.metric, self.top_k, self.hysteresis, self.use_ilp)
            .opt_u64("deadline_ms", self.deadline_ms)
            .finish()
    }
}

impl FromJson for ClusterRequest {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let mut r = Self::new(req_str(v, "model")?);
        if let Some(d) = opt_u64(v, "devices")? {
            r.devices = d;
        }
        if let Some(t) = opt_str(v, "topology")? {
            r.topology = t;
        }
        if let Some(s) = opt_str_list(v, "schedules")? {
            if s.is_empty() {
                return Err(ApiError::invalid("\"schedules\" must not be empty"));
            }
            r.schedules = s;
        }
        if let Some(m) = opt_u64(v, "mine")? {
            r.mine_top = m;
        }
        if let Some(c) = opt_u64(v, "chunks")? {
            r.chunks = c;
        }
        knobs_from_json(v, &mut r.metric, &mut r.top_k, &mut r.hysteresis, &mut r.use_ilp)?;
        r.deadline_ms = opt_u64(v, "deadline_ms")?;
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(raw: &[&str]) -> Args {
        Args::parse(
            raw.iter().map(|s| s.to_string()),
            &["model", "models", "metric", "k", "depth", "tmp", "scheme", "hysteresis", "dims", "tc", "vc", "deadline-ms", "backend", "devices", "topology", "schedules", "mine", "chunks"],
        )
        .unwrap()
    }

    #[test]
    fn search_request_args_and_json_agree() {
        let a = SearchRequest::from_args(&args(&[
            "--model", "bert-base", "--metric", "perf/tdp", "--k", "5", "--ilp",
        ]))
        .unwrap();
        let j = SearchRequest::from_json_str(&a.to_json()).unwrap();
        assert_eq!(a, j);
        assert_eq!(a.metric, Metric::PerfPerTdp);
        assert_eq!(a.top_k, 5);
        assert!(a.use_ilp);
    }

    #[test]
    fn search_request_requires_model() {
        assert_eq!(SearchRequest::from_args(&args(&[])).unwrap_err().http_status(), 400);
        assert_eq!(SearchRequest::from_json_str("{}").unwrap_err().http_status(), 400);
    }

    #[test]
    fn unknown_model_is_not_found() {
        let e = SearchRequest::new("no-such-model").validate().unwrap_err();
        assert_eq!(e.http_status(), 404);
    }

    #[test]
    fn evaluate_request_round_trips() {
        let r = EvaluateRequest::from_args(&args(&[
            "--model", "bert-base", "--dims", "128x64x32", "--tc", "4",
        ]))
        .unwrap();
        assert_eq!(r.config.tc_x, 128);
        assert_eq!(r.config.num_tc, 4);
        assert_eq!(r.config.num_vc, 2);
        assert_eq!(EvaluateRequest::from_json_str(&r.to_json()).unwrap(), r);
    }

    #[test]
    fn evaluate_rejects_non_numeric_config() {
        let e = EvaluateRequest::from_json_str(
            "{\"model\":\"bert-base\",\"config\":[2,\"x\",128,2,128]}",
        )
        .unwrap_err();
        assert_eq!(e.http_status(), 400);
    }

    #[test]
    fn global_request_defaults_and_bounds() {
        let r = GlobalRequest::from_json_str("{}").unwrap();
        assert_eq!(r.depth, 32);
        let plan = r.validate().unwrap();
        assert_eq!(plan.models, vec!["opt-1.3b".to_string(), "gpt2-xl".to_string()]);
        assert_eq!(
            GlobalRequest::new().depth(0).validate().unwrap_err().http_status(),
            400
        );
        assert_eq!(
            GlobalRequest::from_json_str("{\"models\":[]}").unwrap_err().http_status(),
            400
        );
        let e = GlobalRequest::new().models(["vgg16"]).validate().unwrap_err();
        assert_eq!(e.http_status(), 404);
    }

    #[test]
    fn global_request_wire_round_trips() {
        let r = GlobalRequest::new()
            .models(["gpt2-xl"])
            .depth(8)
            .tmp(2)
            .scheme(Scheme::PipeDream1F1B)
            .metric(Metric::PerfPerTdp)
            .top_k(4)
            .hysteresis(2)
            .ilp(true)
            .deadline_ms(250);
        assert_eq!(GlobalRequest::from_json_str(&r.to_json()).unwrap(), r);
    }

    #[test]
    fn cluster_request_args_json_and_bounds_agree() {
        let a = ClusterRequest::from_args(&args(&[
            "--model", "gpt2-xl", "--devices", "16", "--topology", "nvlink-island",
            "--schedules", "gpipe,interleaved", "--mine", "1", "--chunks", "3",
            "--metric", "perf/tdp", "--k", "4",
        ]))
        .unwrap();
        assert_eq!(a.devices, 16);
        assert_eq!(a.topology, "nvlink-island");
        assert_eq!(a.schedules, vec!["gpipe".to_string(), "interleaved".to_string()]);
        assert_eq!(a.mine_top, 1);
        assert_eq!(a.chunks, 3);
        assert_eq!(a.metric, Metric::PerfPerTdp);
        let j = ClusterRequest::from_json_str(&a.to_json()).unwrap();
        assert_eq!(a, j, "wire round-trip must preserve the request");
        // Defaults survive an empty body except the required model.
        assert_eq!(ClusterRequest::from_json_str("{}").unwrap_err().http_status(), 400);
        let d = ClusterRequest::from_json_str("{\"model\":\"gpt2-xl\"}").unwrap();
        assert_eq!(d.devices, 8);
        assert!(d.validate().is_ok());
    }

    #[test]
    fn cluster_request_rejects_bad_shapes() {
        assert_eq!(
            ClusterRequest::new("gpt2-xl").devices(0).validate().unwrap_err().http_status(),
            400
        );
        assert_eq!(
            ClusterRequest::new("gpt2-xl")
                .topology("moebius")
                .validate()
                .unwrap_err()
                .http_status(),
            400
        );
        assert_eq!(
            ClusterRequest::new("gpt2-xl")
                .schedules(["zigzag"])
                .validate()
                .unwrap_err()
                .http_status(),
            400
        );
        // Non-LLM workloads cannot be partitioned into a pipeline.
        assert_eq!(
            ClusterRequest::new("vgg16").validate().unwrap_err().http_status(),
            404
        );
    }

    #[test]
    fn common_request_wire_round_trips() {
        let r = CommonRequest::new().models(["bert-base", "vgg16"]).top_k(3).ilp(true);
        assert_eq!(CommonRequest::from_json_str(&r.to_json()).unwrap(), r);
        // Default (empty) models expand to the single-accelerator zoo.
        assert_eq!(
            CommonRequest::new().validate().unwrap().models.len(),
            crate::models::single_acc_models().len()
        );
    }
}
