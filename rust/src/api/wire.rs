//! The wire layer: one (de)serialization code path per type.
//!
//! Every request and reply implements [`ToJson`] and [`FromJson`], so the
//! bytes a client emits are parsed by the same code the server uses (and
//! vice versa) — the CLI `wham client`, the HTTP service, and library
//! callers can no longer drift apart. Field accessors here are *strict*:
//! a present-but-mistyped field is an [`ApiError`] rather than a silent
//! default (the old `/evaluate` handler `unwrap_or(0)`-ed non-numeric
//! config entries into a zero-core design).

use crate::api::error::ApiError;
use crate::arch::ArchConfig;
use crate::metrics::Evaluation;
use crate::search::DesignPoint;
use crate::util::json::{self, JsonValue, Obj};

/// Serialize to canonical wire JSON.
pub trait ToJson {
    fn to_json(&self) -> String;
}

/// Parse from wire JSON, with typed errors.
pub trait FromJson: Sized {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError>;

    /// Parse from raw body text. An empty (or whitespace) body is treated
    /// as `{}` so endpoints with all-optional fields accept bare POSTs.
    fn from_json_str(text: &str) -> Result<Self, ApiError> {
        Self::from_json(&parse_body(text)?)
    }
}

/// Parse a request body: empty text means the empty object.
pub fn parse_body(text: &str) -> Result<JsonValue, ApiError> {
    if text.trim().is_empty() {
        return Ok(JsonValue::Obj(Default::default()));
    }
    json::parse(text).map_err(|e| ApiError::invalid(format!("invalid JSON body: {e}")))
}

// ---- strict field accessors --------------------------------------------

/// `v` as a non-negative integer JSON number (rejects floats and
/// anything beyond exact f64 integer range).
pub fn strict_u64(v: &JsonValue) -> Option<u64> {
    match v {
        JsonValue::Num(n)
            if n.is_finite() && *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) =>
        {
            Some(*n as u64)
        }
        _ => None,
    }
}

/// Required string field.
pub fn req_str(v: &JsonValue, key: &str) -> Result<String, ApiError> {
    match v.get(key) {
        Some(JsonValue::Str(s)) => Ok(s.clone()),
        Some(_) => Err(ApiError::invalid(format!("\"{key}\" must be a string"))),
        None => Err(ApiError::invalid(format!("body must include \"{key}\""))),
    }
}

/// Optional string field (present-but-mistyped is an error).
pub fn opt_str(v: &JsonValue, key: &str) -> Result<Option<String>, ApiError> {
    match v.get(key) {
        None | Some(JsonValue::Null) => Ok(None),
        Some(JsonValue::Str(s)) => Ok(Some(s.clone())),
        Some(_) => Err(ApiError::invalid(format!("\"{key}\" must be a string"))),
    }
}

/// Optional non-negative-integer field.
pub fn opt_u64(v: &JsonValue, key: &str) -> Result<Option<u64>, ApiError> {
    match v.get(key) {
        None | Some(JsonValue::Null) => Ok(None),
        Some(x) => strict_u64(x)
            .map(Some)
            .ok_or_else(|| ApiError::invalid(format!("\"{key}\" must be a non-negative integer"))),
    }
}

/// Optional boolean field.
pub fn opt_bool(v: &JsonValue, key: &str) -> Result<Option<bool>, ApiError> {
    match v.get(key) {
        None | Some(JsonValue::Null) => Ok(None),
        Some(JsonValue::Bool(b)) => Ok(Some(*b)),
        Some(_) => Err(ApiError::invalid(format!("\"{key}\" must be a boolean"))),
    }
}

/// Required float field.
pub fn req_f64(v: &JsonValue, key: &str) -> Result<f64, ApiError> {
    v.get(key)
        .and_then(|x| x.as_f64())
        .ok_or_else(|| ApiError::invalid(format!("\"{key}\" must be a number")))
}

/// Required non-negative-integer field.
pub fn req_u64(v: &JsonValue, key: &str) -> Result<u64, ApiError> {
    v.get(key)
        .and_then(strict_u64)
        .ok_or_else(|| ApiError::invalid(format!("\"{key}\" must be a non-negative integer")))
}

/// Required boolean field.
pub fn req_bool(v: &JsonValue, key: &str) -> Result<bool, ApiError> {
    v.get(key)
        .and_then(|x| x.as_bool())
        .ok_or_else(|| ApiError::invalid(format!("\"{key}\" must be a boolean")))
}

/// Required array field.
pub fn req_arr<'v>(v: &'v JsonValue, key: &str) -> Result<&'v [JsonValue], ApiError> {
    v.get(key)
        .and_then(|x| x.as_arr())
        .ok_or_else(|| ApiError::invalid(format!("\"{key}\" must be an array")))
}

/// Optional array-of-strings field (e.g. `"models"`).
pub fn opt_str_list(v: &JsonValue, key: &str) -> Result<Option<Vec<String>>, ApiError> {
    let a = match v.get(key) {
        None | Some(JsonValue::Null) => return Ok(None),
        Some(x) => x
            .as_arr()
            .ok_or_else(|| ApiError::invalid(format!("\"{key}\" must be an array of names")))?,
    };
    let mut out = Vec::with_capacity(a.len());
    for item in a {
        match item.as_str() {
            Some(s) => out.push(s.to_string()),
            None => {
                return Err(ApiError::invalid(format!("\"{key}\" must be an array of names")))
            }
        }
    }
    Ok(Some(out))
}

// ---- domain-type wire forms --------------------------------------------

/// `[num_tc, tc_x, tc_y, num_vc, vc_w]` — the wire form of a config.
pub fn config_arr(c: &ArchConfig) -> String {
    format!("[{},{},{},{},{}]", c.num_tc, c.tc_x, c.tc_y, c.num_vc, c.vc_w)
}

/// Parse the [`config_arr`] form, strictly: exactly five non-negative
/// integer entries.
pub fn parse_config(v: &JsonValue) -> Result<ArchConfig, ApiError> {
    let bad = || ApiError::invalid("\"config\" must be [num_tc,tc_x,tc_y,num_vc,vc_w]");
    let a = v.as_arr().ok_or_else(bad)?;
    if a.len() != 5 {
        return Err(bad());
    }
    let n = |i: usize| -> Result<u64, ApiError> {
        strict_u64(&a[i]).ok_or_else(|| {
            ApiError::invalid(format!("\"config\"[{i}] must be a non-negative integer"))
        })
    };
    Ok(ArchConfig { num_tc: n(0)?, tc_x: n(1)?, tc_y: n(2)?, num_vc: n(3)?, vc_w: n(4)? })
}

impl ToJson for Evaluation {
    fn to_json(&self) -> String {
        Obj::new()
            .u64("cycles", self.cycles)
            .f64("seconds", self.seconds)
            .f64("throughput", self.throughput)
            .f64("energy_j", self.energy_j)
            .f64("tdp_w", self.tdp_w)
            .f64("area_mm2", self.area_mm2)
            .f64("perf_per_tdp", self.perf_per_tdp)
            .finish()
    }
}

/// Parse the [`Evaluation`] wire object (`None` on shape mismatch).
pub fn parse_eval(v: &JsonValue) -> Option<Evaluation> {
    Some(Evaluation {
        cycles: v.get("cycles")?.as_u64()?,
        seconds: v.get("seconds")?.as_f64()?,
        throughput: v.get("throughput")?.as_f64()?,
        energy_j: v.get("energy_j")?.as_f64()?,
        tdp_w: v.get("tdp_w")?.as_f64()?,
        area_mm2: v.get("area_mm2")?.as_f64()?,
        perf_per_tdp: v.get("perf_per_tdp")?.as_f64()?,
    })
}

impl FromJson for Evaluation {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        parse_eval(v).ok_or_else(|| ApiError::invalid("malformed \"eval\" object"))
    }
}

impl ToJson for DesignPoint {
    fn to_json(&self) -> String {
        Obj::new()
            .raw("config", &config_arr(&self.config))
            .str("display", &self.config.display())
            .f64("score", self.score)
            .raw("eval", &self.eval.to_json())
            .finish()
    }
}

/// Parse the [`DesignPoint`] wire object (`None` on shape mismatch).
pub fn parse_design_point(v: &JsonValue) -> Option<DesignPoint> {
    let config = parse_config(v.get("config")?).ok()?;
    Some(DesignPoint { config, eval: parse_eval(v.get("eval")?)?, score: v.get("score")?.as_f64()? })
}

impl FromJson for DesignPoint {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        parse_design_point(v).ok_or_else(|| ApiError::invalid("malformed design-point object"))
    }
}

/// Serialize an [`Evaluation`] — compatibility alias for the design
/// database and older call sites.
pub fn eval_json(e: &Evaluation) -> String {
    e.to_json()
}

/// Serialize a [`DesignPoint`] — compatibility alias.
pub fn design_point_json(p: &DesignPoint) -> String {
    p.to_json()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;

    fn point() -> DesignPoint {
        let cfg = presets::tpuv2();
        DesignPoint { config: cfg, eval: crate::metrics::evaluate(&cfg, 1_000_000, 8, 1e9), score: 2.5 }
    }

    #[test]
    fn design_point_round_trips() {
        let p = point();
        let v = json::parse(&p.to_json()).unwrap();
        let q = DesignPoint::from_json(&v).unwrap();
        assert_eq!(p.config, q.config);
        assert_eq!(p.score, q.score);
        assert_eq!(p.eval.cycles, q.eval.cycles);
        assert_eq!(p.eval.throughput, q.eval.throughput);
        assert_eq!(v.get("display").unwrap().as_str(), Some(p.config.display().as_str()));
    }

    #[test]
    fn strict_u64_rejects_non_integers() {
        assert_eq!(strict_u64(&JsonValue::Num(2.0)), Some(2));
        assert_eq!(strict_u64(&JsonValue::Num(2.5)), None);
        assert_eq!(strict_u64(&JsonValue::Num(-1.0)), None);
        assert_eq!(strict_u64(&JsonValue::Str("2".into())), None);
    }

    #[test]
    fn parse_config_rejects_non_numeric_entries() {
        let v = json::parse("[2,\"x\",128,2,128]").unwrap();
        let e = parse_config(&v).unwrap_err();
        assert_eq!(e.http_status(), 400);
        assert!(e.message.contains("[1]"), "{}", e.message);
        assert!(parse_config(&json::parse("[2,128,128,2]").unwrap()).is_err());
        assert!(parse_config(&json::parse("[2,128,128,2,128]").unwrap()).is_ok());
    }

    #[test]
    fn empty_body_parses_as_empty_object() {
        assert_eq!(parse_body("  ").unwrap(), JsonValue::Obj(Default::default()));
        assert!(parse_body("{oops").is_err());
    }
}
