//! Typed requests and replies for the async job tier ([`crate::jobs`]).
//!
//! A job wraps one of the long-running mining requests — `search`,
//! `common`, `global`, or `cluster` — behind `POST /jobs`: the service
//! answers with an id immediately and runs the work on a dispatcher
//! thread, so clients poll `GET /jobs/:id` or stream `GET
//! /jobs/:id/events` instead of holding an HTTP connection for the
//! whole search. The wire shapes here follow the [`crate::api`]
//! conventions exactly: builders for library callers, `from_args` for
//! the CLI, and a symmetric [`ToJson`]/[`FromJson`] codec shared by
//! `wham client` and the server.

use std::fmt;
use std::str::FromStr;

use crate::api::error::ApiError;
use crate::api::plan::{ClusterPlan, CommonPlan, GlobalPlan, SearchPlan};
use crate::api::request::{ClusterRequest, CommonRequest, GlobalRequest, SearchRequest};
use crate::api::wire::{opt_str, req_str, FromJson, ToJson};
use crate::util::cli::Args;
use crate::util::fnv::Fnv;
use crate::util::json::{self, JsonValue, Obj};

/// Job-key namespace tag ('j'), keeping job coalescing keys disjoint
/// from the synchronous per-kind namespaces in [`crate::api::plan`].
const NS_JOB: u64 = 0x6a;

/// Clients that do not identify themselves share one quota bucket.
pub const ANON_CLIENT: &str = "anon";

/// Which long-running request a job wraps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobKind {
    Search,
    Common,
    Global,
    Cluster,
}

impl JobKind {
    /// Wire/CLI label.
    pub fn label(&self) -> &'static str {
        match self {
            JobKind::Search => "search",
            JobKind::Common => "common",
            JobKind::Global => "global",
            JobKind::Cluster => "cluster",
        }
    }
}

impl fmt::Display for JobKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

impl FromStr for JobKind {
    type Err = ApiError;
    fn from_str(s: &str) -> Result<Self, ApiError> {
        match s {
            "search" => Ok(JobKind::Search),
            "common" => Ok(JobKind::Common),
            "global" => Ok(JobKind::Global),
            "cluster" => Ok(JobKind::Cluster),
            other => Err(ApiError::invalid(format!(
                "unknown job kind {other:?} (expected search|common|global|cluster)"
            ))),
        }
    }
}

/// Lifecycle of a job. Transitions are `Queued → Running → {Done,
/// Failed, Cancelled}`, with `Running → Queued` on a transient failure
/// (retry with backoff) or a crash-interrupted attempt found during
/// write-ahead-log replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    /// Wire label.
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// A terminal job never runs again (its live progress channel is
    /// gone; watchers are served from the store).
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

impl fmt::Display for JobState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

impl FromStr for JobState {
    type Err = ApiError;
    fn from_str(s: &str) -> Result<Self, ApiError> {
        match s {
            "queued" => Ok(JobState::Queued),
            "running" => Ok(JobState::Running),
            "done" => Ok(JobState::Done),
            "failed" => Ok(JobState::Failed),
            "cancelled" => Ok(JobState::Cancelled),
            other => Err(ApiError::invalid(format!("unknown job state {other:?}"))),
        }
    }
}

/// The typed inner request a job carries.
#[derive(Debug, Clone)]
pub enum JobSpec {
    Search(SearchRequest),
    Common(CommonRequest),
    Global(GlobalRequest),
    Cluster(ClusterRequest),
}

impl JobSpec {
    pub fn kind(&self) -> JobKind {
        match self {
            JobSpec::Search(_) => JobKind::Search,
            JobSpec::Common(_) => JobKind::Common,
            JobSpec::Global(_) => JobKind::Global,
            JobSpec::Cluster(_) => JobKind::Cluster,
        }
    }

    fn inner_json(&self) -> String {
        match self {
            JobSpec::Search(r) => r.to_json(),
            JobSpec::Common(r) => r.to_json(),
            JobSpec::Global(r) => r.to_json(),
            JobSpec::Cluster(r) => r.to_json(),
        }
    }
}

/// `POST /jobs` body: `{"kind":"search","client":"ci","request":{...}}`.
/// `kind` defaults to `"search"`, `client` to [`ANON_CLIENT`]; the
/// `request` object is the same body the synchronous endpoint of that
/// kind accepts.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub client: String,
    pub spec: JobSpec,
}

impl JobRequest {
    /// A search job for `model` — the common case, used by tests and
    /// library callers.
    pub fn search(model: &str) -> Self {
        JobRequest {
            client: ANON_CLIENT.to_string(),
            spec: JobSpec::Search(SearchRequest::new(model)),
        }
    }

    /// Set the client (quota bucket) name.
    pub fn with_client(mut self, client: &str) -> Self {
        self.client = client.to_string();
        self
    }

    /// Build from CLI flags: `--type search|common|global|cluster`
    /// selects the inner request parser (which consumes its own flags),
    /// `--client NAME` names the quota bucket.
    pub fn from_args(args: &Args) -> Result<Self, ApiError> {
        let kind: JobKind = args.get("type").unwrap_or("search").parse()?;
        let spec = match kind {
            JobKind::Search => JobSpec::Search(SearchRequest::from_args(args)?),
            JobKind::Common => JobSpec::Common(CommonRequest::from_args(args)?),
            JobKind::Global => JobSpec::Global(GlobalRequest::from_args(args)?),
            JobKind::Cluster => JobSpec::Cluster(ClusterRequest::from_args(args)?),
        };
        let client = args.get("client").unwrap_or(ANON_CLIENT).to_string();
        Ok(JobRequest { client, spec })
    }

    /// Validate into an executable [`JobPlan`] (inner request validation
    /// runs at admission, so a bad job is a 400 at `POST /jobs`, not a
    /// failed job discovered by polling).
    pub fn validate(&self) -> Result<JobPlan, ApiError> {
        if self.client.is_empty() || self.client.len() > 64 {
            return Err(ApiError::invalid("\"client\" must be 1..=64 characters"));
        }
        let inner = match &self.spec {
            JobSpec::Search(r) => InnerPlan::Search(r.validate()?),
            JobSpec::Common(r) => InnerPlan::Common(r.validate()?),
            JobSpec::Global(r) => InnerPlan::Global(r.validate()?),
            JobSpec::Cluster(r) => InnerPlan::Cluster(r.validate()?),
        };
        Ok(JobPlan {
            kind: self.spec.kind(),
            client: self.client.clone(),
            request_json: self.spec.inner_json(),
            inner,
        })
    }
}

impl ToJson for JobRequest {
    fn to_json(&self) -> String {
        Obj::new()
            .str("kind", self.spec.kind().label())
            .str("client", &self.client)
            .raw("request", &self.spec.inner_json())
            .finish()
    }
}

impl FromJson for JobRequest {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let kind: JobKind = match opt_str(v, "kind")? {
            Some(k) => k.parse()?,
            None => JobKind::Search,
        };
        let client = opt_str(v, "client")?.unwrap_or_else(|| ANON_CLIENT.to_string());
        let inner = match v.get("request") {
            Some(obj @ JsonValue::Obj(_)) => obj,
            Some(_) => return Err(ApiError::invalid("\"request\" must be an object")),
            None => {
                return Err(ApiError::invalid(
                    "body must include \"request\" (the inner search/common/global/cluster body)",
                ))
            }
        };
        let spec = match kind {
            JobKind::Search => JobSpec::Search(SearchRequest::from_json(inner)?),
            JobKind::Common => JobSpec::Common(CommonRequest::from_json(inner)?),
            JobKind::Global => JobSpec::Global(GlobalRequest::from_json(inner)?),
            JobKind::Cluster => JobSpec::Cluster(ClusterRequest::from_json(inner)?),
        };
        Ok(JobRequest { client, spec })
    }
}

/// The validated inner plan (kept so executing a job re-uses the exact
/// plan admission checked, not a re-parse). Not `Clone`/`Debug`: the
/// plans carry resolved operator graphs.
pub enum InnerPlan {
    Search(SearchPlan),
    Common(CommonPlan),
    Global(GlobalPlan),
    Cluster(ClusterPlan),
}

/// A validated, executable job.
pub struct JobPlan {
    pub kind: JobKind,
    pub client: String,
    /// Canonical wire form of the inner request — what the write-ahead
    /// store persists, so a replayed job revalidates the same bytes.
    pub request_json: String,
    pub inner: InnerPlan,
}

impl JobPlan {
    /// Single-flight identity of the wrapped work, namespaced under
    /// [`NS_JOB`] so a job never coalesces with a synchronous request.
    pub fn coalescing_key(&self, backend: &str) -> u64 {
        let inner = match &self.inner {
            InnerPlan::Search(p) => p.coalescing_key(backend),
            InnerPlan::Common(p) => p.coalescing_key(backend),
            InnerPlan::Global(p) => p.coalescing_key(backend),
            InnerPlan::Cluster(p) => p.coalescing_key(backend),
        };
        Fnv::new().word(NS_JOB).word(inner).0
    }
}

/// `GET /jobs/:id` reply — the full visible state of one job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobReply {
    pub id: String,
    pub kind: JobKind,
    pub client: String,
    pub state: JobState,
    /// Execution attempts so far (1 on the first run; transient
    /// failures and crash-resumes increment it).
    pub attempts: u64,
    pub submitted_ms: u64,
    pub started_ms: Option<u64>,
    pub finished_ms: Option<u64>,
    /// Terminal error text (`state == failed`).
    pub error: Option<String>,
    /// Raw JSON reply of the wrapped request (`state == done`) —
    /// byte-identical to what the synchronous endpoint would have sent.
    pub reply: Option<String>,
    /// Correlation id of the submitting request (empty when unknown,
    /// e.g. jobs replayed from pre-correlation logs). Matches the
    /// `X-Wham-Request-Id` header the submitter received, so the 202
    /// body, every SSE frame, the WAL line, and the access log all
    /// grep to the same id.
    pub corr: String,
}

impl JobReply {
    fn base_json(&self) -> Obj {
        let mut o = Obj::new()
            .str("id", &self.id)
            .str("kind", self.kind.label())
            .str("client", &self.client)
            .str("state", self.state.label())
            .u64("attempts", self.attempts)
            .u64("submitted_ms", self.submitted_ms)
            .opt_u64("started_ms", self.started_ms)
            .opt_u64("finished_ms", self.finished_ms);
        if !self.corr.is_empty() {
            o = o.str("corr", &self.corr);
        }
        match &self.error {
            Some(e) => o.str("error", e),
            None => o,
        }
    }

    /// Wire form without the (possibly large) embedded reply — what
    /// `GET /jobs` lists and SSE state frames carry.
    pub fn to_json_brief(&self) -> String {
        self.base_json().finish()
    }
}

impl ToJson for JobReply {
    fn to_json(&self) -> String {
        let o = self.base_json();
        match &self.reply {
            Some(r) => o.raw("reply", r).finish(),
            None => o.finish(),
        }
    }
}

impl FromJson for JobReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let kind: JobKind = req_str(v, "kind")?.parse()?;
        let state: JobState = req_str(v, "state")?.parse()?;
        let ms = |key: &str| v.get(key).and_then(JsonValue::as_u64);
        Ok(JobReply {
            id: req_str(v, "id")?,
            kind,
            client: req_str(v, "client")?,
            state,
            attempts: ms("attempts").unwrap_or(0),
            submitted_ms: ms("submitted_ms").unwrap_or(0),
            started_ms: ms("started_ms"),
            finished_ms: ms("finished_ms"),
            error: opt_str(v, "error")?,
            // Re-serialized canonically (sorted keys); byte-level
            // consumers fetch `GET /jobs/:id/reply` instead.
            reply: v.get("reply").map(json::dump),
            corr: opt_str(v, "corr")?.unwrap_or_default(),
        })
    }
}

/// `GET /jobs` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct JobListReply {
    pub jobs: Vec<JobReply>,
}

impl ToJson for JobListReply {
    fn to_json(&self) -> String {
        Obj::new()
            .raw("jobs", &json::arr(self.jobs.iter().map(|j| j.to_json_brief())))
            .finish()
    }
}

impl FromJson for JobListReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let arr = v
            .get("jobs")
            .and_then(JsonValue::as_arr)
            .ok_or_else(|| ApiError::invalid("\"jobs\" must be an array"))?;
        let jobs = arr.iter().map(JobReply::from_json).collect::<Result<Vec<_>, _>>()?;
        Ok(JobListReply { jobs })
    }
}

/// `POST /db/import` / `wham db import` reply: what merging a JSONL
/// export into the design database did.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DbImportReply {
    /// New entries inserted.
    pub added: u64,
    /// Entries whose fingerprint key was already present (kept local).
    pub duplicate: u64,
    /// Lines that did not parse as design-DB entries.
    pub malformed: u64,
    /// Database size after the import.
    pub entries: u64,
}

impl ToJson for DbImportReply {
    fn to_json(&self) -> String {
        Obj::new()
            .u64("added", self.added)
            .u64("duplicate", self.duplicate)
            .u64("malformed", self.malformed)
            .u64("entries", self.entries)
            .finish()
    }
}

impl FromJson for DbImportReply {
    fn from_json(v: &JsonValue) -> Result<Self, ApiError> {
        let n = |key: &str| {
            v.get(key)
                .and_then(JsonValue::as_u64)
                .ok_or_else(|| ApiError::invalid(format!("\"{key}\" must be a number")))
        };
        Ok(DbImportReply {
            added: n("added")?,
            duplicate: n("duplicate")?,
            malformed: n("malformed")?,
            entries: n("entries")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_request_round_trips_and_validates() {
        let r = JobRequest::search("bert-base").with_client("ci");
        let v = json::parse(&r.to_json()).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("search"));
        let back = JobRequest::from_json(&v).unwrap();
        assert_eq!(back.client, "ci");
        let plan = back.validate().unwrap();
        assert_eq!(plan.kind, JobKind::Search);
        assert_eq!(plan.client, "ci");
        // The persisted request is the canonical inner wire form, which
        // the sync endpoint's parser accepts unchanged.
        assert!(SearchRequest::from_json_str(&plan.request_json).is_ok());
    }

    #[test]
    fn job_request_defaults_and_rejects() {
        let v = json::parse(r#"{"request":{"model":"vgg16"}}"#).unwrap();
        let r = JobRequest::from_json(&v).unwrap();
        assert_eq!(r.spec.kind(), JobKind::Search);
        assert_eq!(r.client, ANON_CLIENT);

        let v = json::parse(r#"{"kind":"search"}"#).unwrap();
        let e = JobRequest::from_json(&v).unwrap_err();
        assert!(e.message.contains("request"), "{}", e.message);

        let v = json::parse(r#"{"kind":"mine-faster","request":{}}"#).unwrap();
        assert!(JobRequest::from_json(&v).is_err());

        let bad = JobRequest::search("bert-base").with_client("");
        assert_eq!(bad.validate().unwrap_err().http_status(), 400);
    }

    #[test]
    fn job_keys_are_namespaced_away_from_sync_requests() {
        let plan = JobRequest::search("bert-base").validate().unwrap();
        let sync = SearchRequest::new("bert-base").validate().unwrap();
        assert_ne!(plan.coalescing_key("native"), sync.coalescing_key("native"));
        // Same work, same key; different client must not split the key.
        let other = JobRequest::search("bert-base").with_client("b").validate().unwrap();
        assert_eq!(plan.coalescing_key("native"), other.coalescing_key("native"));
        let vgg = JobRequest::search("vgg16").validate().unwrap();
        assert_ne!(plan.coalescing_key("native"), vgg.coalescing_key("native"));
    }

    #[test]
    fn job_reply_codec_round_trips() {
        let r = JobReply {
            id: "j-1f-0001".into(),
            kind: JobKind::Search,
            client: "ci".into(),
            state: JobState::Done,
            attempts: 2,
            submitted_ms: 1_700_000_000_000,
            started_ms: Some(1_700_000_000_100),
            finished_ms: Some(1_700_000_000_900),
            error: None,
            reply: Some(r#"{"best":1,"model":"bert-base"}"#.to_string()),
            corr: "r-1a2b-0001".into(),
        };
        let v = json::parse(&r.to_json()).unwrap();
        assert_eq!(v.get("corr").unwrap().as_str(), Some("r-1a2b-0001"));
        let back = JobReply::from_json(&v).unwrap();
        assert_eq!(back, r);
        // Brief form drops the embedded reply but keeps the lifecycle.
        let brief = json::parse(&r.to_json_brief()).unwrap();
        assert!(brief.get("reply").is_none());
        assert_eq!(brief.get("state").unwrap().as_str(), Some("done"));

        let failed = JobReply { state: JobState::Failed, error: Some("boom".into()), reply: None, ..r };
        let v = json::parse(&failed.to_json()).unwrap();
        assert_eq!(JobReply::from_json(&v).unwrap(), failed);
    }

    #[test]
    fn list_and_import_replies_round_trip() {
        let j = JobReply {
            id: "j-a".into(),
            kind: JobKind::Global,
            client: ANON_CLIENT.into(),
            state: JobState::Queued,
            attempts: 0,
            submitted_ms: 5,
            started_ms: None,
            finished_ms: None,
            error: None,
            reply: None,
            corr: String::new(),
        };
        let list = JobListReply { jobs: vec![j] };
        let v = json::parse(&list.to_json()).unwrap();
        assert_eq!(JobListReply::from_json(&v).unwrap(), list);

        let imp = DbImportReply { added: 3, duplicate: 1, malformed: 2, entries: 9 };
        let v = json::parse(&imp.to_json()).unwrap();
        assert_eq!(DbImportReply::from_json(&v).unwrap(), imp);
    }
}
