//! The persistent, fingerprint-keyed design database.
//!
//! Every `<TC-Dim, VC-Width>` point the engine evaluates is memoized
//! under a *context key* — the workload
//! [`Fingerprint`](crate::graph::Fingerprint) combined with
//! batch size, metric, throughput floor, constraints, solver choice, and
//! backend name (anything that changes the evaluation's value changes
//! the key). The map is striped across [`SHARDS`] `RwLock`s so concurrent
//! searches on different workloads never contend, and mirrored to a
//! JSONL file: load-on-boot, append-on-write, so a restarted server
//! answers previously-mined requests without touching the scheduler.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use crate::cost::Dims;
use crate::graph::{fingerprint, OperatorGraph};
use crate::search::engine::{CacheProvider, EvalCache, SearchOptions};
use crate::search::DesignPoint;
use crate::util::fnv::Fnv;
use crate::util::json;

// The canonical key/codec definitions live in the API layer; re-exported
// here so database callers keep one import site.
pub use crate::api::plan::context_key;
pub use crate::api::wire::{design_point_json, eval_json, parse_design_point};

/// Lock stripes. 16 keeps contention negligible at the service's worker
/// counts while staying cache-friendly.
pub const SHARDS: usize = 16;

fn shard_of(ctx: u64, d: &Dims) -> usize {
    let h = Fnv::new().word(ctx).word(d.tc_x).word(d.tc_y).word(d.vc_w).0;
    (h % SHARDS as u64) as usize
}

/// Aggregate database statistics for `/status`.
#[derive(Debug, Clone, Copy)]
pub struct DbStats {
    pub entries: usize,
    pub hits: u64,
    pub misses: u64,
    pub appended: u64,
    pub loaded: usize,
}

/// Sharded, persistent design-point database.
pub struct DesignDb {
    shards: Vec<RwLock<HashMap<(u64, Dims), DesignPoint>>>,
    writer: Mutex<Option<BufWriter<File>>>,
    path: Option<PathBuf>,
    loaded: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    appended: AtomicU64,
}

impl DesignDb {
    /// Volatile database (no persistence).
    pub fn in_memory() -> Self {
        Self {
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            writer: Mutex::new(None),
            path: None,
            loaded: 0,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            appended: AtomicU64::new(0),
        }
    }

    /// Open (and create if needed) a JSONL-backed database. Unparseable
    /// lines are skipped so a torn final append cannot brick the boot.
    pub fn open(path: &Path) -> anyhow::Result<Self> {
        let mut db = Self::in_memory();
        if path.is_file() {
            let text = std::fs::read_to_string(path)?;
            for line in text.lines() {
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                if let Some((ctx, dims, point)) = parse_entry(line) {
                    let shard = shard_of(ctx, &dims);
                    db.shards[shard].write().unwrap().insert((ctx, dims), point);
                    db.loaded += 1;
                }
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        db.writer = Mutex::new(Some(BufWriter::new(file)));
        db.path = Some(path.to_path_buf());
        Ok(db)
    }

    /// Backing file, if persistent.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// Point for `(ctx, dims)`, counting hit/miss.
    pub fn get(&self, ctx: u64, d: &Dims) -> Option<DesignPoint> {
        let found = self.shards[shard_of(ctx, d)].read().unwrap().get(&(ctx, *d)).copied();
        match found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Insert a point; first insertion of a key is appended to the file.
    pub fn put(&self, ctx: u64, d: Dims, p: DesignPoint) {
        let fresh = self.shards[shard_of(ctx, &d)]
            .write()
            .unwrap()
            .insert((ctx, d), p)
            .is_none();
        if !fresh {
            return;
        }
        let mut w = self.writer.lock().unwrap();
        if let Some(w) = w.as_mut() {
            let line = entry_json(ctx, &d, &p);
            if writeln!(w, "{line}").and_then(|_| w.flush()).is_ok() {
                self.appended.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Total entries across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// True when no entries are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter snapshot.
    pub fn stats(&self) -> DbStats {
        DbStats {
            entries: self.len(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            appended: self.appended.load(Ordering::Relaxed),
            loaded: self.loaded,
        }
    }

    /// An [`EvalCache`] view scoped to one context key.
    pub fn scoped(&self, ctx: u64) -> ScopedCache<'_> {
        ScopedCache { db: self, ctx }
    }

    /// Flush the append writer (graceful shutdown; appends already flush
    /// per line, so this only matters after an I/O hiccup).
    pub fn flush(&self) {
        if let Some(w) = self.writer.lock().unwrap().as_mut() {
            let _ = w.flush();
        }
    }

    /// Serialize every entry as the same JSONL lines the backing file
    /// holds, sorted by `(ctx, dims)` so exports are deterministic.
    /// This is the portability format: fingerprint-derived context keys
    /// mean another instance can import these lines directly.
    pub fn export_jsonl(&self) -> String {
        let mut entries: Vec<((u64, Dims), DesignPoint)> = Vec::with_capacity(self.len());
        for shard in &self.shards {
            entries.extend(shard.read().unwrap().iter().map(|(k, v)| (*k, *v)));
        }
        entries.sort_by_key(|((ctx, d), _)| (*ctx, d.tc_x, d.tc_y, d.vc_w));
        let mut out = String::new();
        for ((ctx, d), p) in &entries {
            out.push_str(&entry_json(*ctx, d, p));
            out.push('\n');
        }
        out
    }

    /// Merge a JSONL export into this database. Existing keys win (the
    /// local entry was mined under the same context, so the values agree
    /// up to backend noise); new entries are inserted and appended to the
    /// backing file. Unparseable lines are counted, not fatal.
    pub fn import_jsonl(&self, text: &str) -> ImportStats {
        let mut stats = ImportStats::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            match parse_entry(line) {
                Some((ctx, d, p)) => {
                    let exists =
                        self.shards[shard_of(ctx, &d)].read().unwrap().contains_key(&(ctx, d));
                    if exists {
                        stats.duplicate += 1;
                    } else {
                        self.put(ctx, d, p);
                        stats.added += 1;
                    }
                }
                None => stats.malformed += 1,
            }
        }
        stats
    }
}

/// What [`DesignDb::import_jsonl`] did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ImportStats {
    pub added: u64,
    pub duplicate: u64,
    pub malformed: u64,
}

/// Borrowed [`EvalCache`] over one evaluation context of a [`DesignDb`].
pub struct ScopedCache<'a> {
    db: &'a DesignDb,
    ctx: u64,
}

impl EvalCache for ScopedCache<'_> {
    fn get(&mut self, d: &Dims) -> Option<DesignPoint> {
        self.db.get(self.ctx, d)
    }
    fn put(&mut self, d: Dims, p: DesignPoint) {
        self.db.put(self.ctx, d, p);
    }
}

impl CacheProvider for DesignDb {
    fn cache_for<'a>(
        &'a self,
        graph: &OperatorGraph,
        batch: u64,
        opts: &SearchOptions,
        backend: &str,
    ) -> Box<dyn EvalCache + 'a> {
        let ctx = context_key(fingerprint(graph), batch, opts, backend);
        Box::new(self.scoped(ctx))
    }
}

// ---- JSONL (de)serialization -------------------------------------------
// The per-type codecs ([`design_point_json`] / [`parse_design_point`])
// are the API wire layer's; only the JSONL envelope is database-specific.

fn entry_json(ctx: u64, d: &Dims, p: &DesignPoint) -> String {
    format!(
        "{{\"ctx\":\"{ctx:016x}\",\"dims\":[{},{},{}],\"point\":{}}}",
        d.tc_x,
        d.tc_y,
        d.vc_w,
        design_point_json(p),
    )
}

fn parse_entry(line: &str) -> Option<(u64, Dims, DesignPoint)> {
    let v = json::parse(line).ok()?;
    let ctx = u64::from_str_radix(v.get("ctx")?.as_str()?, 16).ok()?;
    let dims = v.get("dims")?.as_arr()?;
    if dims.len() != 3 {
        return None;
    }
    let d = Dims { tc_x: dims[0].as_u64()?, tc_y: dims[1].as_u64()?, vc_w: dims[2].as_u64()? };
    Some((ctx, d, parse_design_point(v.get("point")?)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use std::sync::atomic::AtomicUsize;

    fn temp_db_path(tag: &str) -> PathBuf {
        static N: AtomicUsize = AtomicUsize::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("wham-db-{}-{tag}-{n}.jsonl", std::process::id()))
    }

    fn point(score: f64) -> DesignPoint {
        let cfg = presets::tpuv2();
        DesignPoint { config: cfg, eval: crate::metrics::evaluate(&cfg, 1_000_000, 8, 1e9), score }
    }

    #[test]
    fn round_trips_through_jsonl() {
        let path = temp_db_path("roundtrip");
        let d = Dims { tc_x: 128, tc_y: 64, vc_w: 32 };
        {
            let db = DesignDb::open(&path).unwrap();
            db.put(7, d, point(1.25));
            db.put(7, d, point(9.0)); // duplicate key: not re-appended
            assert_eq!(db.stats().appended, 1);
        }
        let db = DesignDb::open(&path).unwrap();
        assert_eq!(db.len(), 1);
        assert_eq!(db.stats().loaded, 1);
        let p = db.get(7, &d).unwrap();
        assert_eq!(p.score, 1.25);
        assert_eq!(p.config, presets::tpuv2());
        assert_eq!(p.eval.cycles, 1_000_000);
        assert!(db.get(8, &d).is_none(), "different context must miss");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_lines_are_skipped() {
        let path = temp_db_path("corrupt");
        let d = Dims { tc_x: 8, tc_y: 8, vc_w: 8 };
        {
            let db = DesignDb::open(&path).unwrap();
            db.put(1, d, point(2.0));
        }
        // Simulate a torn append.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "{{\"ctx\":\"zz\",").unwrap();
        }
        let db = DesignDb::open(&path).unwrap();
        assert_eq!(db.stats().loaded, 1);
        assert!(db.get(1, &d).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn context_key_separates_options() {
        let g = crate::models::training("bert-base", crate::graph::autodiff::Optimizer::Adam)
            .unwrap();
        let fp = fingerprint(&g);
        let base = SearchOptions::default();
        let k0 = context_key(fp, 4, &base, "native");
        assert_eq!(k0, context_key(fp, 4, &base, "native"), "key must be stable");
        let ilp = SearchOptions { use_ilp: true, ..base };
        assert_ne!(k0, context_key(fp, 4, &ilp, "native"));
        assert_ne!(k0, context_key(fp, 8, &base, "native"));
        assert_ne!(k0, context_key(fp, 4, &base, "pjrt"));
        let eff = SearchOptions {
            metric: crate::metrics::Metric::PerfPerTdp,
            min_throughput: 10.0,
            ..base
        };
        assert_ne!(k0, context_key(fp, 4, &eff, "native"));
        // top_k and hysteresis shape exploration, not per-point values —
        // they share the cache.
        let wide = SearchOptions { top_k: 50, hysteresis: 3, ..base };
        assert_eq!(k0, context_key(fp, 4, &wide, "native"));
        // The MCR growth modes keep separate contexts (a staircase
        // makespan could land them on different cores); the interning
        // and jobs knobs are bit-identical and share the cache.
        let legacy_mcr = SearchOptions { mcr_one_at_a_time: true, ..base };
        assert_ne!(k0, context_key(fp, 4, &legacy_mcr, "native"));
        let fast_knobs = SearchOptions { naive_annotation: true, jobs: 8, ..base };
        assert_eq!(k0, context_key(fp, 4, &fast_knobs, "native"));
    }

    #[test]
    fn export_import_merges_between_databases() {
        let a = DesignDb::in_memory();
        let d1 = Dims { tc_x: 128, tc_y: 64, vc_w: 32 };
        let d2 = Dims { tc_x: 64, tc_y: 64, vc_w: 64 };
        a.put(1, d1, point(1.0));
        a.put(2, d2, point(2.0));
        let export = a.export_jsonl();
        assert_eq!(export.lines().count(), 2);
        // Exports are deterministic (sorted), so they are diffable.
        assert_eq!(export, a.export_jsonl());

        let b = DesignDb::in_memory();
        b.put(1, d1, point(9.0)); // local entry must win over the import
        let stats = b.import_jsonl(&export);
        assert_eq!(stats, ImportStats { added: 1, duplicate: 1, malformed: 0 });
        assert_eq!(b.len(), 2);
        assert_eq!(b.get(1, &d1).unwrap().score, 9.0);
        assert_eq!(b.get(2, &d2).unwrap().score, 2.0);

        // Corrupt lines count as malformed, everything else still lands.
        let stats = b.import_jsonl("{oops\n");
        assert_eq!(stats, ImportStats { added: 0, duplicate: 0, malformed: 1 });

        // Importing into a persistent db appends the new entries.
        let path = temp_db_path("import");
        {
            let c = DesignDb::open(&path).unwrap();
            let s = c.import_jsonl(&export);
            assert_eq!(s.added, 2);
        }
        let c = DesignDb::open(&path).unwrap();
        assert_eq!(c.stats().loaded, 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn scoped_cache_feeds_engine() {
        use crate::cost::native::NativeCost;
        use crate::search::engine::WhamSearch;
        let fwd = crate::models::transformer::forward_range(
            &crate::models::transformer::bert_base(),
            0,
            1,
        );
        let g = crate::graph::autodiff::training_graph(
            &fwd,
            crate::graph::autodiff::Optimizer::SgdMomentum,
        );
        let db = DesignDb::in_memory();
        let opts = SearchOptions::default();
        let ctx = context_key(fingerprint(&g), 4, &opts, "native");
        let s = WhamSearch::new(&g, 4, opts);
        let cold = s.run_cached(&mut NativeCost, &mut db.scoped(ctx));
        assert!(cold.scheduler_evals > 0);
        assert_eq!(db.len(), cold.dims_evaluated);
        let warm = s.run_cached(&mut NativeCost, &mut db.scoped(ctx));
        assert_eq!(warm.scheduler_evals, 0);
        assert_eq!(warm.best.config, cold.best.config);
    }
}
