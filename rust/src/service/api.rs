//! HTTP endpoints of the mining service — thin adapters over
//! [`crate::api`].
//!
//! Each endpoint deserializes its body into the corresponding typed
//! request, validates it into a plan, and executes it on the worker's
//! [`Session`]; replies are the typed reply's `to_json()` bytes. The
//! request/reply schemas live with the types:
//!
//! | endpoint          | request type                      | reply type |
//! |-------------------|-----------------------------------|------------|
//! | `GET /models`     | —                                 | [`crate::api::ModelsReply`] |
//! | `POST /workloads` | a workload spec document          | [`crate::api::WorkloadReply`] |
//! | `POST /search`    | [`crate::api::SearchRequest`]     | [`crate::api::SearchReply`] (coalesced + cached) |
//! | `POST /evaluate`  | [`crate::api::EvaluateRequest`]   | [`crate::api::EvaluateReply`] |
//! | `POST /common`    | [`crate::api::CommonRequest`]     | [`crate::api::CommonReply`] |
//! | `POST /global`    | [`crate::api::GlobalRequest`]     | [`crate::api::GlobalReply`] |
//! | `POST /cluster`   | [`crate::api::ClusterRequest`]    | [`crate::api::ClusterReply`] (coalesced + cached) |
//! | `GET /status`     | —                                 | [`crate::api::StatusReply`] |
//! | `GET /metrics`    | —                                 | Prometheus text exposition ([`crate::telemetry::registry`]) |
//!
//! `POST /workloads` validates and registers a declarative spec
//! ([`crate::workload`]); the name is then mineable by every other
//! endpoint, with design points cached under the spec's graph
//! fingerprint exactly like builtins.
//!
//! [`ApiError`] kinds map to HTTP statuses (400/404/500); `/search`,
//! `/common`, `/global`, and `/cluster` coalesce identical in-flight
//! requests by the plan's canonical coalescing key
//! ([`crate::api::plan`]).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::api::reply::{
    CoalescerCounters, DbCounters, EndpointStat, PerfCounters, SearchCounters,
};
use crate::api::{
    ApiError, ClusterRequest, CommonRequest, EvaluateRequest, FromJson, GlobalRequest, NullSink,
    SearchRequest, Session, StatusReply, ToJson, WorkloadReply,
};
use crate::coordinator::{make_backend, BackendChoice};
use crate::cost::native::NativeCost;
use crate::service::cache::DesignDb;
use crate::service::http::{Handler, Request, Response};
use crate::service::queue::Coalescer;
use crate::telemetry::{Collect, Sample};

/// Sliding-window latency recorder for one endpoint: a ring of the most
/// recent [`LatencyRing::CAP`] request walls (microseconds), enough for
/// p50/p95 without unbounded memory or a histogram dependency.
pub struct LatencyRing {
    name: &'static str,
    count: AtomicU64,
    samples: std::sync::Mutex<Vec<u32>>,
}

impl LatencyRing {
    const CAP: usize = 512;

    fn new(name: &'static str) -> Self {
        Self { name, count: AtomicU64::new(0), samples: std::sync::Mutex::new(Vec::new()) }
    }

    /// Record one request's wall clock.
    pub fn note(&self, wall: std::time::Duration) {
        let v = wall.as_micros().min(u128::from(u32::MAX)) as u32;
        let mut s = self.samples.lock().unwrap();
        // Ticket taken under the lock so the slot index stays consistent
        // with the vec length during warm-up and wrap-around.
        let n = self.count.fetch_add(1, Ordering::Relaxed) as usize;
        if s.len() < Self::CAP {
            s.push(v);
        } else {
            s[n % Self::CAP] = v;
        }
    }

    /// Digest over the current window; `None` before the first request.
    pub fn stat(&self) -> Option<EndpointStat> {
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return None;
        }
        let mut s = self.samples.lock().unwrap().clone();
        if s.is_empty() {
            return None;
        }
        s.sort_unstable();
        let pick = |q: f64| s[((s.len() - 1) as f64 * q).round() as usize] as f64 / 1e3;
        Some(EndpointStat {
            endpoint: self.name.to_string(),
            count,
            p50_ms: pick(0.5),
            p95_ms: pick(0.95),
        })
    }
}

/// Shared state of one running service.
pub struct ServiceState {
    pub db: Arc<DesignDb>,
    pub coalescer: Coalescer,
    pub backend_choice: BackendChoice,
    pub workers: usize,
    pub started: Instant,
    // Counters surfaced by `/status`.
    pub requests: AtomicU64,
    pub search_requests: AtomicU64,
    /// `/search` leader computations that ran at least one scheduler eval.
    pub cold_searches: AtomicU64,
    /// `/search` leader computations answered entirely from the database.
    pub warm_searches: AtomicU64,
    /// Scheduler invocations across all leader computations.
    pub scheduler_evals_total: AtomicU64,
    /// Per-endpoint latency windows (perf observability — `/status`).
    pub latency: Vec<LatencyRing>,
}

impl ServiceState {
    pub fn new(db: Arc<DesignDb>, backend_choice: BackendChoice, workers: usize) -> Self {
        Self {
            db,
            coalescer: Coalescer::new(),
            backend_choice,
            workers,
            started: Instant::now(),
            requests: AtomicU64::new(0),
            search_requests: AtomicU64::new(0),
            cold_searches: AtomicU64::new(0),
            warm_searches: AtomicU64::new(0),
            scheduler_evals_total: AtomicU64::new(0),
            latency: [
                "/models", "/status", "/search", "/evaluate", "/common", "/global", "/cluster",
                "/workloads", "/metrics",
            ]
            .into_iter()
            .map(LatencyRing::new)
            .collect(),
        }
    }

    /// Snapshot of the service counters as the typed `/status` reply.
    pub fn status(&self) -> StatusReply {
        let db = self.db.stats();
        let probes = db.hits + db.misses;
        let perf = PerfCounters {
            backend_rows_total: crate::cost::backend_rows_total(),
            scheduler_evals_total: crate::sched::evals_total(),
            cluster_sim_events_total: crate::cluster::events_total(),
            db_hit_rate: if probes == 0 { 0.0 } else { db.hits as f64 / probes as f64 },
            endpoints: self.latency.iter().filter_map(LatencyRing::stat).collect(),
        };
        StatusReply {
            perf,
            uptime_ms: self.started.elapsed().as_millis() as u64,
            workers: self.workers as u64,
            requests: self.requests.load(Ordering::Relaxed),
            search: SearchCounters {
                requests: self.search_requests.load(Ordering::Relaxed),
                cold: self.cold_searches.load(Ordering::Relaxed),
                warm: self.warm_searches.load(Ordering::Relaxed),
                scheduler_evals_total: self.scheduler_evals_total.load(Ordering::Relaxed),
            },
            coalescer: CoalescerCounters {
                led: self.coalescer.led.load(Ordering::Relaxed),
                coalesced: self.coalescer.coalesced.load(Ordering::Relaxed),
                in_flight: self.coalescer.in_flight() as u64,
            },
            db: DbCounters {
                path: self.db.path().map(|p| p.display().to_string()),
                entries: db.entries as u64,
                loaded: db.loaded as u64,
                appended: db.appended,
                hits: db.hits,
                misses: db.misses,
            },
        }
    }
}

/// Scrape-time samples for `GET /metrics`: per-instance state that must
/// NOT live in the process-global registry (tests start several services
/// in one process, and their counters would collide). The process-global
/// counters (`wham_backend_rows_total`, …) render alongside these from
/// the registry itself.
impl Collect for ServiceState {
    fn collect(&self, out: &mut Vec<Sample>) {
        let n = |v: &AtomicU64| v.load(Ordering::Relaxed);
        let label = |k: &str, v: &str| vec![(k.to_string(), v.to_string())];
        out.push(Sample::Counter {
            name: "wham_http_requests_total".into(),
            help: "HTTP requests handled by this service instance.".into(),
            labels: vec![],
            value: n(&self.requests),
        });
        out.push(Sample::Counter {
            name: "wham_search_requests_total".into(),
            help: "POST /search requests that validated into a plan.".into(),
            labels: vec![],
            value: n(&self.search_requests),
        });
        for (kind, v) in
            [("cold", n(&self.cold_searches)), ("warm", n(&self.warm_searches))]
        {
            out.push(Sample::Counter {
                name: "wham_search_leader_computations_total".into(),
                help: "Search leader computations by outcome: cold ran the \
                       scheduler, warm answered entirely from the database."
                    .into(),
                labels: label("result", kind),
                value: v,
            });
        }
        out.push(Sample::Counter {
            name: "wham_service_scheduler_evals_total".into(),
            help: "Scheduler invocations across this instance's leader computations.".into(),
            labels: vec![],
            value: n(&self.scheduler_evals_total),
        });
        for (role, v) in [
            ("led", self.coalescer.led.load(Ordering::Relaxed)),
            ("coalesced", self.coalescer.coalesced.load(Ordering::Relaxed)),
        ] {
            out.push(Sample::Counter {
                name: "wham_coalescer_requests_total".into(),
                help: "Coalescable requests by role (leader vs follower).".into(),
                labels: label("role", role),
                value: v,
            });
        }
        out.push(Sample::Gauge {
            name: "wham_coalescer_in_flight".into(),
            help: "Coalesced computations currently executing.".into(),
            labels: vec![],
            value: self.coalescer.in_flight() as f64,
        });
        let db = self.db.stats();
        let probes = db.hits + db.misses;
        out.push(Sample::Gauge {
            name: "wham_db_hit_rate".into(),
            help: "Design-database probe hit rate since start (0 before any probe).".into(),
            labels: vec![],
            value: if probes == 0 { 0.0 } else { db.hits as f64 / probes as f64 },
        });
        out.push(Sample::Gauge {
            name: "wham_db_entries".into(),
            help: "Design points currently in the database.".into(),
            labels: vec![],
            value: db.entries as f64,
        });
        for ring in &self.latency {
            if let Some(stat) = ring.stat() {
                out.push(Sample::Summary {
                    name: "wham_http_request_duration_ms".into(),
                    help: "Request wall-clock per endpoint over the latest window \
                           (includes error responses and coalesced followers)."
                        .into(),
                    labels: label("endpoint", &stat.endpoint),
                    quantiles: vec![(0.5, stat.p50_ms), (0.95, stat.p95_ms)],
                    count: stat.count,
                });
            }
        }
    }
}

/// The HTTP handler: one [`Session`] (cost backend + shared design
/// database) per worker thread — PJRT clients are not `Sync`, the same
/// policy as [`crate::coordinator`].
pub struct Api {
    pub state: Arc<ServiceState>,
}

impl Handler for Api {
    type Ctx = Session;

    fn make_ctx(&self) -> Self::Ctx {
        // `start()` validated the choice once; an explicit-PJRT failure
        // here can only race an artifact deletion, so fall back rather
        // than serve nothing.
        let backend = make_backend(self.state.backend_choice)
            .unwrap_or_else(|_| Box::new(NativeCost));
        // Per-request fan-out budget: split the machine across the
        // request workers, so a lone heavy `/global` on a low-worker
        // deployment still scales with cores without oversubscribing a
        // fully-parallel one.
        let jobs = (crate::util::default_jobs() / self.state.workers.max(1)).max(1);
        Session::with_backend(backend).with_db(Arc::clone(&self.state.db)).with_jobs(jobs)
    }

    fn handle(&self, session: &mut Self::Ctx, req: &Request) -> Response {
        let s = &self.state;
        s.requests.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let resp = match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/models") => Response::json(session.models().to_json()),
            ("GET", "/status") => Response::json(s.status().to_json()),
            ("GET", "/metrics") => metrics_response(s),
            ("POST", "/search") => search_response(s, session, &req.body),
            ("POST", "/evaluate") => api_result(
                EvaluateRequest::from_json_str(&req.body)
                    .and_then(|r| session.evaluate(&r))
                    .map(|reply| reply.to_json()),
            ),
            ("POST", "/common") => common_response(s, session, &req.body),
            ("POST", "/global") => global_response(s, session, &req.body),
            ("POST", "/cluster") => cluster_response(s, session, &req.body),
            ("POST", "/workloads") => api_result(upload_workload(&req.body)),
            (
                _,
                "/models" | "/status" | "/metrics" | "/search" | "/evaluate" | "/common"
                | "/global" | "/cluster" | "/workloads",
            ) => Response::error(405, "wrong method for this endpoint"),
            _ => Response::error(
                404,
                "unknown endpoint; see GET /models, POST /workloads, POST /search, POST /evaluate, POST /common, POST /global, POST /cluster, GET /status, GET /metrics",
            ),
        };
        // Latency-window recording policy (pinned by the tests below):
        // every request whose path names a known endpoint records its
        // wall, regardless of outcome — 4xx/5xx responses count because
        // the client waited for them, and coalesced followers count
        // because their wait is what that client experienced (the leader
        // and its followers each record once). Unknown paths are not
        // tracked: their cardinality is attacker-controlled.
        if let Some(ring) = s.latency.iter().find(|r| r.name == req.path) {
            ring.note(t0.elapsed());
        }
        resp
    }
}

/// `GET /metrics` — the Prometheus text exposition: every registered
/// process-global counter plus this instance's scrape-time samples.
fn metrics_response(s: &ServiceState) -> Response {
    // Touch the process-global counters so a scrape before any search
    // still exposes every counter `/status.perf` reports (`get()`
    // lazily registers them).
    crate::cost::backend_rows_total();
    crate::sched::evals_total();
    crate::cluster::events_total();
    let collect: &dyn Collect = s;
    Response::prometheus(crate::telemetry::render_prometheus(&[collect]))
}

/// Map a typed API outcome onto an HTTP response.
fn api_result(r: Result<String, ApiError>) -> Response {
    match r {
        Ok(body) => Response::json(body),
        Err(e) => Response::error(e.http_status(), &e.message),
    }
}

/// Unwrap a coalesced (string-typed) leader outcome.
fn into_response(outcome: &Result<String, String>) -> Response {
    match outcome {
        Ok(body) => Response::json(body.clone()),
        Err(e) => Response::error(500, e),
    }
}

/// Validate and register an uploaded workload spec. Spec diagnostics
/// (with layer paths) surface as 400s; the reply carries the training
/// fingerprint the design database will key the workload's points by.
fn upload_workload(body: &str) -> Result<String, ApiError> {
    let report = crate::workload::add_spec_text(body, crate::workload::Source::Uploaded)
        .map_err(|e| ApiError::invalid(e.to_string()))?;
    Ok(WorkloadReply {
        name: report.name,
        fingerprint: report.fingerprint,
        batch: report.batch,
        forward_ops: report.forward_ops as u64,
        training_ops: report.training_ops as u64,
        source: crate::workload::Source::Uploaded.label().to_string(),
    }
    .to_json())
}

fn search_response(s: &ServiceState, session: &mut Session, body: &str) -> Response {
    let plan = match SearchRequest::from_json_str(body).and_then(|r| r.validate()) {
        Ok(p) => p,
        Err(e) => return api_result(Err(e)),
    };
    s.search_requests.fetch_add(1, Ordering::Relaxed);
    let key = plan.coalescing_key(session.backend_name());
    let (outcome, _led) = s.coalescer.run(key, || {
        let reply = session.run_search(&plan, &mut NullSink).map_err(|e| e.message)?;
        if reply.scheduler_evals > 0 {
            s.cold_searches.fetch_add(1, Ordering::Relaxed);
        } else {
            s.warm_searches.fetch_add(1, Ordering::Relaxed);
        }
        s.scheduler_evals_total.fetch_add(reply.scheduler_evals, Ordering::Relaxed);
        Ok(reply.to_json())
    });
    into_response(&outcome)
}

fn common_response(s: &ServiceState, session: &mut Session, body: &str) -> Response {
    let plan = match CommonRequest::from_json_str(body).and_then(|r| r.validate()) {
        Ok(p) => p,
        Err(e) => return api_result(Err(e)),
    };
    let key = plan.coalescing_key(session.backend_name());
    let (outcome, _led) = s.coalescer.run(key, || {
        session.run_common(&plan).map(|r| r.to_json()).map_err(|e| e.message)
    });
    into_response(&outcome)
}

fn global_response(s: &ServiceState, session: &mut Session, body: &str) -> Response {
    let plan = match GlobalRequest::from_json_str(body).and_then(|r| r.validate()) {
        Ok(p) => p,
        Err(e) => return api_result(Err(e)),
    };
    let key = plan.coalescing_key(session.backend_name());
    let (outcome, _led) = s.coalescer.run(key, || {
        session.run_global(&plan, &mut NullSink).map(|r| r.to_json()).map_err(|e| e.message)
    });
    into_response(&outcome)
}

fn cluster_response(s: &ServiceState, session: &mut Session, body: &str) -> Response {
    let plan = match ClusterRequest::from_json_str(body).and_then(|r| r.validate()) {
        Ok(p) => p,
        Err(e) => return api_result(Err(e)),
    };
    let key = plan.coalescing_key(session.backend_name());
    let (outcome, _led) = s.coalescer.run(key, || {
        session.run_cluster(&plan, &mut NullSink).map(|r| r.to_json()).map_err(|e| e.message)
    });
    into_response(&outcome)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn api() -> (Api, Session) {
        let state =
            Arc::new(ServiceState::new(Arc::new(DesignDb::in_memory()), BackendChoice::Native, 1));
        let api = Api { state };
        let session = api.make_ctx();
        (api, session)
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.to_string(),
            path: path.to_string(),
            query: String::new(),
            body: body.to_string(),
        }
    }

    fn ring_count(state: &ServiceState, path: &str) -> u64 {
        let ring = state.latency.iter().find(|r| r.name == path).expect("known endpoint");
        ring.stat().map_or(0, |s| s.count)
    }

    /// Pins the latency-recording policy: error responses (400 and 405)
    /// record under the endpoint the client hit, unknown paths are not
    /// tracked at all, and successes record too. Coalesced followers
    /// share this path structurally — `handle` notes the ring after
    /// `Coalescer::run` returns for leaders and followers alike.
    #[test]
    fn latency_rings_record_errors_and_skip_unknown_paths() {
        let (api, mut s) = api();
        let r = api.handle(&mut s, &req("POST", "/search", "{"));
        assert_eq!(r.status, 400, "malformed body: {}", r.body);
        assert_eq!(ring_count(&api.state, "/search"), 1, "4xx responses must record");

        let r = api.handle(&mut s, &req("DELETE", "/search", ""));
        assert_eq!(r.status, 405);
        assert_eq!(ring_count(&api.state, "/search"), 2, "405 responses must record");

        let r = api.handle(&mut s, &req("GET", "/nope", ""));
        assert_eq!(r.status, 404);
        assert!(
            api.state.latency.iter().all(|ring| ring.name != "/nope"),
            "unknown paths must not grow the ring set"
        );

        let r = api.handle(&mut s, &req("GET", "/status", ""));
        assert_eq!(r.status, 200);
        assert_eq!(ring_count(&api.state, "/status"), 1);
    }

    #[test]
    fn metrics_exposes_status_perf_counters_as_prometheus_text() {
        let (api, mut s) = api();
        let r = api.handle(&mut s, &req("POST", "/search", "{\"model\":\"bert-base\"}"));
        assert_eq!(r.status, 200, "search failed: {}", r.body);

        let m = api.handle(&mut s, &req("GET", "/metrics", ""));
        assert_eq!(m.status, 200);
        assert!(m.content_type.starts_with("text/plain"), "{}", m.content_type);
        for name in [
            "wham_backend_rows_total",
            "wham_scheduler_evals_total",
            "wham_db_hit_rate",
            "wham_http_requests_total",
            "wham_search_leader_computations_total{result=\"cold\"}",
            "wham_http_request_duration_ms{endpoint=\"/search\",quantile=\"0.5\"}",
        ] {
            assert!(
                m.body.lines().any(|l| l.starts_with(name)),
                "missing {name} in exposition:\n{}",
                m.body
            );
        }
        // Scrapes record into their own ring (the body is rendered
        // before the note, so a scrape never sees itself).
        assert_eq!(ring_count(&api.state, "/metrics"), 1);
    }
}
