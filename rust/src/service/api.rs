//! JSON endpoints of the mining service.
//!
//! | endpoint        | body                                                  | result |
//! |-----------------|-------------------------------------------------------|--------|
//! | `GET /models`   | —                                                     | the Table-4 workload zoo |
//! | `POST /search`  | `{"model", "metric"?, "k"?, "hysteresis"?, "ilp"?}`   | per-workload search (coalesced + cached) |
//! | `POST /evaluate`| `{"model", "config":[#tc,tcx,tcy,#vc,vcw]}`           | evaluate a fixed design |
//! | `POST /global`  | `{"models":[..], "depth"?, "tmp"?, "scheme"?, "k"?}`  | distributed pipeline search |
//! | `GET /status`   | —                                                     | counters: cache, coalescing, uptime |
//!
//! JSON is hand-rolled on the way out (the idiom of
//! [`crate::report::trace`]) and parsed on the way in by
//! [`crate::util::json`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{make_backend, BackendChoice};
use crate::cost::native::NativeCost;
use crate::cost::CostBackend;
use crate::distributed::global_search::{global_search_cached, GlobalOptions, ModelPipelineResult};
use crate::distributed::network::Network;
use crate::distributed::partition::partition_transformer;
use crate::distributed::Scheme;
use crate::graph::autodiff::Optimizer;
use crate::graph::{fingerprint, OperatorGraph};
use crate::metrics::Metric;
use crate::search::engine::{evaluate_design, SearchOptions, WhamSearch};
use crate::service::cache::{context_key, design_point_json, eval_json, DesignDb};
use crate::service::http::{Handler, Request, Response};
use crate::service::queue::Coalescer;
use crate::util::fnv::Fnv;
use crate::util::json::{self, esc, num, JsonValue};

/// Shared state of one running service.
pub struct ServiceState {
    pub db: DesignDb,
    pub coalescer: Coalescer,
    pub backend_choice: BackendChoice,
    pub workers: usize,
    pub started: Instant,
    // Counters surfaced by `/status`.
    pub requests: AtomicU64,
    pub search_requests: AtomicU64,
    /// `/search` leader computations that ran at least one scheduler eval.
    pub cold_searches: AtomicU64,
    /// `/search` leader computations answered entirely from the database.
    pub warm_searches: AtomicU64,
    /// Scheduler invocations across all leader computations.
    pub scheduler_evals_total: AtomicU64,
}

impl ServiceState {
    pub fn new(db: DesignDb, backend_choice: BackendChoice, workers: usize) -> Self {
        Self {
            db,
            coalescer: Coalescer::new(),
            backend_choice,
            workers,
            started: Instant::now(),
            requests: AtomicU64::new(0),
            search_requests: AtomicU64::new(0),
            cold_searches: AtomicU64::new(0),
            warm_searches: AtomicU64::new(0),
            scheduler_evals_total: AtomicU64::new(0),
        }
    }
}

/// The HTTP handler: one cost backend per worker thread (PJRT clients are
/// not `Sync` — same policy as [`crate::coordinator`]).
pub struct Api {
    pub state: Arc<ServiceState>,
}

impl Handler for Api {
    type Ctx = Box<dyn CostBackend>;

    fn make_ctx(&self) -> Self::Ctx {
        // `start()` validated the choice once; an explicit-PJRT failure
        // here can only race an artifact deletion, so fall back rather
        // than serve nothing.
        make_backend(self.state.backend_choice).unwrap_or_else(|_| Box::new(NativeCost))
    }

    fn handle(&self, backend: &mut Self::Ctx, req: &Request) -> Response {
        let s = &self.state;
        s.requests.fetch_add(1, Ordering::Relaxed);
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/models") => models_response(),
            ("GET", "/status") => status_response(s),
            ("POST", "/search") => search_response(s, backend.as_mut(), &req.body),
            ("POST", "/evaluate") => evaluate_response(backend.as_mut(), &req.body),
            ("POST", "/global") => global_response(s, backend.as_mut(), &req.body),
            (_, "/models" | "/status" | "/search" | "/evaluate" | "/global") => {
                Response::error(405, "wrong method for this endpoint")
            }
            _ => Response::error(404, "unknown endpoint; see GET /models, POST /search, POST /evaluate, POST /global, GET /status"),
        }
    }
}

fn models_response() -> Response {
    let rows: Vec<String> = crate::models::MODELS
        .iter()
        .map(|m| {
            format!(
                "{{\"name\":{},\"task\":{},\"batch\":{},\"accelerators\":{},\"distributed_only\":{}}}",
                esc(m.name),
                esc(m.task),
                m.batch,
                m.accelerators,
                m.distributed_only
            )
        })
        .collect();
    Response::json(format!("{{\"models\":[{}]}}", rows.join(",")))
}

fn status_response(s: &ServiceState) -> Response {
    let db = s.db.stats();
    let path = match s.db.path() {
        Some(p) => esc(&p.display().to_string()),
        None => "null".to_string(),
    };
    // `search` counts only `/search` work; the coalescer is shared by
    // `/search` and `/global`, so its counters get their own block.
    Response::json(format!(
        "{{\"uptime_ms\":{},\"workers\":{},\"requests\":{},\
         \"search\":{{\"requests\":{},\"cold\":{},\"warm\":{},\"scheduler_evals_total\":{}}},\
         \"coalescer\":{{\"led\":{},\"coalesced\":{},\"in_flight\":{}}},\
         \"db\":{{\"path\":{},\"entries\":{},\"loaded\":{},\"appended\":{},\"hits\":{},\"misses\":{}}}}}",
        s.started.elapsed().as_millis(),
        s.workers,
        s.requests.load(Ordering::Relaxed),
        s.search_requests.load(Ordering::Relaxed),
        s.cold_searches.load(Ordering::Relaxed),
        s.warm_searches.load(Ordering::Relaxed),
        s.scheduler_evals_total.load(Ordering::Relaxed),
        s.coalescer.led.load(Ordering::Relaxed),
        s.coalescer.coalesced.load(Ordering::Relaxed),
        s.coalescer.in_flight(),
        path,
        db.entries,
        db.loaded,
        db.appended,
        db.hits,
        db.misses,
    ))
}

/// Parse the request body as a JSON object (empty body → empty object).
fn parse_body(body: &str) -> Result<JsonValue, Response> {
    if body.trim().is_empty() {
        return Ok(JsonValue::Obj(Default::default()));
    }
    json::parse(body).map_err(|e| Response::error(400, &format!("invalid JSON body: {e}")))
}

fn resolve_model(v: &JsonValue) -> Result<(String, OperatorGraph, u64), Response> {
    let name = v
        .get("model")
        .and_then(|m| m.as_str())
        .ok_or_else(|| Response::error(400, "body must include \"model\""))?;
    let graph = crate::models::training(name, Optimizer::Adam)
        .ok_or_else(|| Response::error(404, &format!("unknown model {name:?}; see GET /models")))?;
    let batch = crate::models::info(name).map(|i| i.batch).unwrap_or(1);
    Ok((name.to_string(), graph, batch))
}

fn parse_search_options(v: &JsonValue) -> Result<SearchOptions, Response> {
    let metric = match v.get("metric").and_then(|m| m.as_str()) {
        None => Metric::Throughput,
        Some(m) => m
            .parse::<Metric>()
            .map_err(|e| Response::error(400, &e))?,
    };
    let d = SearchOptions::default();
    Ok(SearchOptions {
        metric,
        top_k: v.get("k").and_then(|k| k.as_u64()).unwrap_or(d.top_k as u64).max(1) as usize,
        hysteresis: v
            .get("hysteresis")
            .and_then(|h| h.as_u64())
            .unwrap_or(d.hysteresis as u64) as u32,
        use_ilp: v.get("ilp").and_then(|b| b.as_bool()).unwrap_or(d.use_ilp),
        ..d
    })
}

fn search_response(s: &ServiceState, backend: &mut dyn CostBackend, body: &str) -> Response {
    let parsed = match parse_body(body) {
        Ok(v) => v,
        Err(r) => return r,
    };
    let (name, graph, batch) = match resolve_model(&parsed) {
        Ok(t) => t,
        Err(r) => return r,
    };
    let mut opts = match parse_search_options(&parsed) {
        Ok(o) => o,
        Err(r) => return r,
    };
    s.search_requests.fetch_add(1, Ordering::Relaxed);

    let fp = fingerprint(&graph);
    // Coalescing key: everything that shapes the *response*, so followers
    // can share the leader's bytes verbatim.
    let mut key = crate::service::cache::context_key(fp, batch, &opts, backend.name());
    key = key
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(opts.top_k as u64)
        .wrapping_add((opts.hysteresis as u64) << 32);

    let (outcome, _leader) = s.coalescer.run(key, || {
        if opts.metric == Metric::PerfPerTdp {
            // TPUv2 throughput floor, mirroring `wham search`.
            opts.min_throughput =
                evaluate_design(&graph, batch, &crate::arch::presets::tpuv2(), backend).throughput;
        }
        let ctx = context_key(fp, batch, &opts, backend.name());
        let t0 = Instant::now();
        let mut cache = s.db.scoped(ctx);
        let r = WhamSearch::new(&graph, batch, opts).run_cached(backend, &mut cache);
        if r.scheduler_evals > 0 {
            s.cold_searches.fetch_add(1, Ordering::Relaxed);
        } else {
            s.warm_searches.fetch_add(1, Ordering::Relaxed);
        }
        s.scheduler_evals_total.fetch_add(r.scheduler_evals as u64, Ordering::Relaxed);
        let top: Vec<String> = r.top.points().iter().map(design_point_json).collect();
        Ok(format!(
            "{{\"model\":{},\"fingerprint\":\"{}\",\"backend\":{},\"metric\":{},\
             \"best\":{},\"top\":[{}],\"dims_evaluated\":{},\"scheduler_evals\":{},\
             \"cache_hits\":{},\"wall_ms\":{}}}",
            esc(&name),
            fp,
            esc(backend.name()),
            esc(&opts.metric.to_string()),
            design_point_json(&r.best),
            top.join(","),
            r.dims_evaluated,
            r.scheduler_evals,
            r.cache_hits,
            num(t0.elapsed().as_secs_f64() * 1e3),
        ))
    });
    into_response(&outcome)
}

fn evaluate_response(backend: &mut dyn CostBackend, body: &str) -> Response {
    let parsed = match parse_body(body) {
        Ok(v) => v,
        Err(r) => return r,
    };
    let (name, graph, batch) = match resolve_model(&parsed) {
        Ok(t) => t,
        Err(r) => return r,
    };
    let cfg = match parsed.get("config").and_then(|c| c.as_arr()) {
        Some(a) if a.len() == 5 => a,
        _ => {
            return Response::error(
                400,
                "body must include \"config\":[num_tc,tc_x,tc_y,num_vc,vc_w]",
            )
        }
    };
    let n = |i: usize| cfg[i].as_u64().unwrap_or(0);
    let config = crate::arch::ArchConfig {
        num_tc: n(0),
        tc_x: n(1),
        tc_y: n(2),
        num_vc: n(3),
        vc_w: n(4),
    };
    if !config.in_template() {
        return Response::error(400, &format!("{} is outside the template bounds", config.display()));
    }
    let e = evaluate_design(&graph, batch, &config, backend);
    Response::json(format!(
        "{{\"model\":{},\"fingerprint\":\"{}\",\"config\":{},\"eval\":{}}}",
        esc(&name),
        fingerprint(&graph),
        esc(&config.display()),
        eval_json(&e),
    ))
}

fn global_response(s: &ServiceState, backend: &mut dyn CostBackend, body: &str) -> Response {
    let parsed = match parse_body(body) {
        Ok(v) => v,
        Err(r) => return r,
    };
    let names: Vec<String> = match parsed.get("models").and_then(|m| m.as_arr()) {
        Some(a) => {
            let mut out = Vec::new();
            for v in a {
                match v.as_str() {
                    Some(s) => out.push(s.to_string()),
                    None => return Response::error(400, "\"models\" must be an array of names"),
                }
            }
            out
        }
        None => vec!["opt-1.3b".to_string(), "gpt2-xl".to_string()],
    };
    if names.is_empty() {
        return Response::error(400, "\"models\" must not be empty");
    }
    let depth = parsed.get("depth").and_then(|d| d.as_u64()).unwrap_or(32);
    let tmp = parsed.get("tmp").and_then(|d| d.as_u64()).unwrap_or(1);
    // partition_transformer asserts on zero values; reject them (and
    // absurd sizes) at the API boundary instead of panicking a worker.
    if !(1..=1024).contains(&depth) || !(1..=1024).contains(&tmp) {
        return Response::error(400, "\"depth\" and \"tmp\" must be in 1..=1024");
    }
    let scheme: Scheme = match parsed.get("scheme").and_then(|x| x.as_str()).unwrap_or("gpipe").parse()
    {
        Ok(sc) => sc,
        Err(e) => return Response::error(400, &e),
    };
    let metric = match parsed.get("metric").and_then(|m| m.as_str()) {
        None => Metric::Throughput,
        Some(m) => match m.parse::<Metric>() {
            Ok(m) => m,
            Err(e) => return Response::error(400, &e),
        },
    };
    let top_k = parsed.get("k").and_then(|k| k.as_u64()).unwrap_or(10).max(1) as usize;

    let mut parts = Vec::with_capacity(names.len());
    for n in &names {
        match crate::models::transformer_cfg(n) {
            Some(cfg) if crate::models::info(n).is_some() => {
                parts.push(partition_transformer(n, &cfg, depth, tmp, Optimizer::Adam))
            }
            _ => return Response::error(404, &format!("{n:?} is not an LLM workload")),
        }
    }

    // Request-level coalescing key (0x67 tags the /global namespace).
    let mut key = Fnv::new().word(0x67);
    for n in &names {
        key = key.bytes(n.as_bytes()).word(0);
    }
    let key = key
        .word(depth)
        .word(tmp)
        .word(top_k as u64)
        .word(matches!(scheme, Scheme::GPipe) as u64)
        .word(matches!(metric, Metric::PerfPerTdp) as u64)
        .0;

    let (outcome, _leader) = s.coalescer.run(key, || {
        let net = Network::default();
        // TPUv2 pipeline baseline, computed once per model: it is both
        // the Perf/TDP floor and the speedup denominator in the response.
        let tpu: Vec<f64> = parts
            .iter()
            .map(|p| {
                let cfgs = vec![crate::arch::presets::tpuv2(); p.stages.len()];
                crate::distributed::pipeline::simulate(p, &cfgs, scheme, &net, backend).throughput
            })
            .collect();
        let local = SearchOptions { metric, top_k, ..Default::default() };
        let mut gopts =
            GlobalOptions { metric, scheme, top_k, local, ..Default::default() };
        if metric == Metric::PerfPerTdp {
            gopts.min_throughput = tpu.iter().copied().fold(f64::INFINITY, f64::min);
        }
        let t0 = Instant::now();
        let r = global_search_cached(&parts, &gopts, &net, backend, &s.db);
        let family = |list: &[ModelPipelineResult]| -> String {
            let rows: Vec<String> = list
                .iter()
                .enumerate()
                .map(|(i, m)| {
                    let uniq: std::collections::BTreeSet<String> =
                        m.configs.iter().map(|c| c.display()).collect();
                    format!(
                        "{{\"model\":{},\"configs\":{:?},\"throughput\":{},\"perf_per_tdp\":{},\"vs_tpuv2\":{}}}",
                        esc(&m.model),
                        uniq.into_iter().collect::<Vec<_>>(),
                        num(m.eval.throughput),
                        num(m.eval.perf_per_tdp),
                        num(m.eval.throughput / tpu[i].max(1e-12)),
                    )
                })
                .collect();
            format!("[{}]", rows.join(","))
        };
        Ok(format!(
            "{{\"models\":{:?},\"depth\":{depth},\"tmp\":{tmp},\"scheme\":{},\"metric\":{},\
             \"candidate_pool\":{},\"candidates_evaluated\":{},\"local_searches\":{},\
             \"common_config\":{},\"common\":{},\"individual\":{},\"mosaic\":{},\"wall_ms\":{}}}",
            names,
            esc(&format!("{scheme:?}").to_lowercase()),
            esc(&metric.to_string()),
            r.candidate_pool,
            r.candidates_evaluated,
            r.local_searches,
            esc(&r.common.0.display()),
            family(&r.common.1),
            family(&r.individual),
            family(&r.mosaic),
            num(t0.elapsed().as_secs_f64() * 1e3),
        ))
    });
    into_response(&outcome)
}

fn into_response(outcome: &Result<String, String>) -> Response {
    match outcome {
        Ok(body) => Response::json(body.clone()),
        Err(e) => Response::error(500, e),
    }
}
